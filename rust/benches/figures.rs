//! `cargo bench` — regenerates every performance figure/table of the paper
//! (Figs. 11, 12, 13; Table 1; footprint claims §5.3/§5.4; plus the PJRT
//! artifact comparison). Custom harness (no criterion in the offline
//! environment); medians over repeated runs via `hfav::bench::time_it`.

fn main() {
    println!("{}", hfav::bench::sysinfo());
    println!();
    hfav::bench::footprint();
    println!();
    hfav::bench::normalization(&[128, 256, 512, 1024, 2048]);
    println!();
    hfav::bench::cosmo(&[64, 128, 256, 512], 8);
    println!();
    hfav::bench::hydro2d(&[64, 128, 256], 5);
    println!();
    hfav::bench::serving(4, 8, None, hfav::engine::Threads::Serial);
    println!();
    hfav::bench::vectorization(hfav::analysis::auto_vector_len(), 4);
    println!();
    match hfav::bench::pjrt(&hfav::runtime::default_artifacts_dir()) {
        Ok(_) => {}
        Err(e) => println!("PJRT bench unavailable: {e}"),
    }
}
