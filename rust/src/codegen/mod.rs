//! Code generation back-ends (paper §3.6, §4): C99 and Rust source
//! emitters, DOT debug graphs, and a native harness that compiles the C
//! output with the system compiler and loads it via `dlopen` — the
//! benchmark vehicle (stands in for the paper's "icc -O3 -xHost" on the
//! generated code).
//!
//! Both source emitters consume the same compiled [`crate::plan::Program`]
//! and emit the same loop structure: statically peeled
//! prologue/steady-state/epilogue segments from the fusion shifts, and
//! one of three vectorized shapes — inner strips with in-register window
//! rotation, outer-dim lane loops, or the aligned specialization's
//! alignment heads (see [`c99`] for the strategy overview; [`rs`]
//! mirrors it with iterator-free `while` strips). Strip-mining
//! invariants the emitters rely on are established by
//! [`crate::analysis`]: inner windows padded to `w + vlen − 1` slots
//! (so a whole strip fits without wraparound), lane slots for
//! loop-carried scalars, outer-lane slot expansion, and the shared
//! [`crate::analysis::layout_order`] stride layout that the interpreter
//! uses too. The emitters never decide legality themselves — they only
//! act on [`crate::analysis::lane_fission_safe`] /
//! [`crate::analysis::outer_vectorizable`] verdicts.

pub mod c99;
pub mod dot;
pub mod native;
pub mod rs;

use crate::ir::Bound;

/// Render a symbolic bound as a C/Rust expression over `int64_t` extent
/// variables (extent `Ni` is in scope as `Ni`).
pub(crate) fn bound_expr(b: &Bound) -> String {
    match &b.base {
        None => format!("{}", b.offset),
        Some(base) => match b.offset.cmp(&0) {
            std::cmp::Ordering::Equal => base.clone(),
            std::cmp::Ordering::Greater => format!("({base} + {})", b.offset),
            std::cmp::Ordering::Less => format!("({base} - {})", -b.offset),
        },
    }
}

/// Partial order on symbolic bounds under the "extents are large"
/// assumption: constants sort below any extent-based bound; same-base
/// bounds compare by offset; distinct extent bases are incomparable.
pub(crate) fn cmp_bound(a: &Bound, b: &Bound) -> Option<std::cmp::Ordering> {
    match (&a.base, &b.base) {
        (None, None) => Some(a.offset.cmp(&b.offset)),
        (None, Some(_)) => Some(std::cmp::Ordering::Less),
        (Some(_), None) => Some(std::cmp::Ordering::Greater),
        (Some(x), Some(y)) if x == y => Some(a.offset.cmp(&b.offset)),
        _ => None,
    }
}

/// Sanitize an identifier for use in generated code.
pub(crate) fn mangle(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Ordering;

    #[test]
    fn bound_exprs() {
        assert_eq!(bound_expr(&Bound::constant(3)), "3");
        assert_eq!(bound_expr(&Bound::of("Ni", 0)), "Ni");
        assert_eq!(bound_expr(&Bound::of("Ni", -1)), "(Ni - 1)");
        assert_eq!(bound_expr(&Bound::of("Ni", 2)), "(Ni + 2)");
    }

    #[test]
    fn bound_ordering() {
        assert_eq!(cmp_bound(&Bound::constant(0), &Bound::of("N", -1)), Some(Ordering::Less));
        assert_eq!(
            cmp_bound(&Bound::of("N", -1), &Bound::of("N", 0)),
            Some(Ordering::Less)
        );
        assert_eq!(cmp_bound(&Bound::of("N", 0), &Bound::of("M", 0)), None);
    }

    #[test]
    fn mangles() {
        assert_eq!(mangle("laplace(cell)"), "laplace_cell_");
        assert_eq!(mangle("__buf(u)"), "__buf_u_");
    }
}
