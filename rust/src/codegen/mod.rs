//! Code generation back-ends (paper §3.6, §4): C99 and Rust source
//! emitters, DOT debug graphs, and a native harness that compiles the C
//! output with the system compiler and loads it via `dlopen` — the
//! benchmark vehicle (stands in for the paper's "icc -O3 -xHost" on the
//! generated code).
//!
//! Both source emitters are **syntax printers** over the lowered
//! schedule IR ([`crate::schedule`]): they walk the same loop tree the
//! interpreter executes and print it — peeled segments, inner strips
//! with in-register window rotation, outer-dim lane loops, alignment
//! heads, multi-dim tiles — without deciding a single shape themselves
//! (see [`c99`]; [`rs`] mirrors it with iterator-free `while` strips,
//! and both stamp [`crate::plan::Program::schedule_digest`] into the
//! output header). Storage invariants the printed code relies on are
//! established by [`crate::analysis`]: window padding, lane slots, and
//! the shared [`crate::analysis::layout_order`] stride layout that the
//! interpreter uses too.

pub mod c99;
pub mod dot;
pub mod native;
pub mod rs;

use crate::ir::Bound;

/// Render a symbolic bound as a C/Rust expression over `int64_t` extent
/// variables (extent `Ni` is in scope as `Ni`). Delegates to the one
/// spelling in [`crate::schedule::bound_text`], which the schedule IR's
/// access decomposition also uses — loop-variable declarations and the
/// index strings referencing them can never drift apart.
pub(crate) fn bound_expr(b: &Bound) -> String {
    crate::schedule::bound_text(b)
}

/// Sanitize an identifier for use in generated code.
pub(crate) fn mangle(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_exprs() {
        assert_eq!(bound_expr(&Bound::constant(3)), "3");
        assert_eq!(bound_expr(&Bound::of("Ni", 0)), "Ni");
        assert_eq!(bound_expr(&Bound::of("Ni", -1)), "(Ni - 1)");
        assert_eq!(bound_expr(&Bound::of("Ni", 2)), "(Ni + 2)");
    }

    #[test]
    fn mangles() {
        assert_eq!(mangle("laplace(cell)"), "laplace_cell_");
        assert_eq!(mangle("__buf(u)"), "__buf_u_");
    }
}
