//! DOT (graphviz) debug output (paper §4 "Debugging output" — the basis
//! for the paper's Figures 2–4, 6 and 8).

use crate::analysis::StoragePlan;
use crate::dataflow::{Dataflow, Terminal};
use crate::fusion::FusedDag;
use std::fmt::Write;

/// Dataflow DAG (Fig. 2/3): kernel callsites as vertices, variables as
/// edges; load/store pseudo-kernels for terminals.
pub fn dataflow(df: &Dataflow) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph dataflow {{");
    let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontname=monospace];");
    for cs in &df.callsites {
        let _ = writeln!(s, "  k{} [label=\"{}\"];", cs.id, cs.name);
    }
    for v in &df.vars {
        match &v.terminal {
            Terminal::Input { storage, .. } => {
                let _ = writeln!(
                    s,
                    "  in_{} [label=\"load {}\", shape=ellipse, style=dashed];",
                    v.id, storage
                );
                for r in &df.reads_of[v.id] {
                    let _ = writeln!(
                        s,
                        "  in_{} -> k{} [label=\"{}{:?}\"];",
                        v.id, r.consumer, v.ident, r.offsets
                    );
                }
            }
            Terminal::Output { storage, .. } => {
                let _ = writeln!(
                    s,
                    "  out_{} [label=\"store {}\", shape=ellipse, style=dashed];",
                    v.id, storage
                );
                if let Some(p) = v.producer {
                    let _ = writeln!(s, "  k{} -> out_{} [label=\"{}\"];", p, v.id, v.ident);
                }
            }
            Terminal::No => {}
        }
        if let Some(p) = v.producer {
            for r in &df.reads_of[v.id] {
                let _ = writeln!(
                    s,
                    "  k{} -> k{} [label=\"{}{:?}\"];",
                    p, r.consumer, v.ident, r.offsets
                );
            }
        }
    }
    let _ = writeln!(s, "}}");
    s
}

/// Fused iteration-nest DAG (Fig. 4/6): one cluster per nest, members
/// listed with their phase roles; splits annotated.
pub fn inest(df: &Dataflow, fd: &FusedDag) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph inest {{");
    let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontname=monospace];");
    for nest in &fd.nests {
        let _ = writeln!(s, "  subgraph cluster_{} {{", nest.id);
        let _ = writeln!(s, "    label=\"nest {} ({})\";", nest.id, nest.dims.join(","));
        for m in &nest.members {
            let cs = &df.callsites[m.callsite];
            let roles: Vec<String> = nest
                .dims
                .iter()
                .zip(&m.roles)
                .map(|(d, r)| format!("{d}:{r:?}"))
                .collect();
            let _ = writeln!(
                s,
                "    k{} [label=\"{}\\n{}\"];",
                cs.id,
                cs.name,
                roles.join(" ")
            );
        }
        let _ = writeln!(s, "  }}");
    }
    for (a, b, vars) in df.edges() {
        let labels: Vec<&str> = vars.iter().map(|&v| df.vars[v].ident.as_str()).collect();
        let split = fd
            .splits
            .iter()
            .any(|sp| sp.producer == a && sp.consumer == b);
        let _ = writeln!(
            s,
            "  k{a} -> k{b} [label=\"{}\"{}];",
            labels.join(","),
            if split { ", color=red, style=bold" } else { "" }
        );
    }
    let _ = writeln!(s, "}}");
    s
}

/// Reuse diagram for one variable (Fig. 8): read offsets linked along the
/// Hamiltonian reuse path.
pub fn reuse(df: &Dataflow, sp: &StoragePlan, ident: &str) -> Option<String> {
    let v = df.var(ident)?;
    let r = sp.reuse.iter().find(|r| r.var == v.id)?;
    let mut s = String::new();
    let _ = writeln!(s, "digraph reuse {{");
    let _ = writeln!(s, "  rankdir=LR; node [shape=circle, fontname=monospace];");
    for (k, off) in r.path.iter().enumerate() {
        let label: Vec<String> = v
            .dims
            .iter()
            .zip(off.iter())
            .map(|(d, o)| match o.cmp(&0) {
                std::cmp::Ordering::Equal => d.clone(),
                std::cmp::Ordering::Greater => format!("{d}+{o}"),
                std::cmp::Ordering::Less => format!("{d}{o}"),
            })
            .collect();
        let _ = writeln!(s, "  n{k} [label=\"({})\"];", label.join(","));
    }
    for k in 0..r.path.len().saturating_sub(1) {
        let _ = writeln!(s, "  n{k} -> n{} [color=orange];", k + 1);
    }
    let _ = writeln!(s, "}}");
    Some(s)
}

#[cfg(test)]
mod tests {
    use crate::frontend::testdecks;
    use crate::plan::{compile_src, CompileOptions};

    #[test]
    fn dot_outputs_nonempty() {
        let prog = compile_src(testdecks::NORMALIZE, CompileOptions::default()).unwrap();
        let d = super::dataflow(&prog.df);
        assert!(d.contains("digraph dataflow"));
        assert!(d.contains("norm_acc"));
        let i = super::inest(&prog.df, &prog.fd);
        assert!(i.contains("cluster_0"));
        assert!(i.contains("cluster_1"));
        assert!(i.contains("color=red"), "split edge should be marked:\n{i}");
    }

    #[test]
    fn reuse_diagram_for_laplace() {
        let prog = compile_src(testdecks::LAPLACE, CompileOptions::default()).unwrap();
        let r = super::reuse(&prog.df, &prog.sp, "cell").unwrap();
        assert!(r.contains("(j+1,i)"), "{r}");
        assert!(r.contains("orange"));
        assert!(super::reuse(&prog.df, &prog.sp, "nosuch").is_none());
    }
}
