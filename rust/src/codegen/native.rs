//! Native harness: compile the C99 output with the system compiler (or
//! the Rust output with `rustc`, see [`build_rust`]) and load it via
//! `dlopen` — this is the measured artifact in benchmarks, the analogue
//! of the paper compiling HFAV's output with `icc -O3 -xHost`.

use super::{c99, rs};
use crate::plan::Program;
use std::collections::BTreeMap;
use std::ffi::{c_char, c_int, c_void, CString};
use std::io::Write;
use std::path::{Path, PathBuf};

// Minimal dlopen binding — no external crates, so the crate builds with
// a bare toolchain. Linux/glibc only (matches the CI and deploy targets).
mod dl {
    use super::{c_char, c_int, c_void};

    pub const RTLD_NOW: c_int = 2;

    #[link(name = "dl")]
    extern "C" {
        pub fn dlopen(filename: *const c_char, flag: c_int) -> *mut c_void;
        pub fn dlsym(handle: *mut c_void, symbol: *const c_char) -> *mut c_void;
        pub fn dlclose(handle: *mut c_void) -> c_int;
        pub fn dlerror() -> *mut c_char;
    }
}

fn dl_error(context: &str) -> String {
    let msg = unsafe {
        let p = dl::dlerror();
        if p.is_null() {
            "unknown dl error".to_string()
        } else {
            std::ffi::CStr::from_ptr(p).to_string_lossy().into_owned()
        }
    };
    format!("{context}: {msg}")
}

/// An open shared library. Closed on drop; the raw handle is thread-safe
/// to use (glibc dlopen handles are), hence the unsafe Send/Sync impls.
struct Library {
    handle: *mut c_void,
}

unsafe impl Send for Library {}
unsafe impl Sync for Library {}

impl Library {
    fn open(path: &Path) -> Result<Library, String> {
        use std::os::unix::ffi::OsStrExt;
        let c = CString::new(path.as_os_str().as_bytes())
            .map_err(|e| format!("bad library path: {e}"))?;
        unsafe { dl::dlerror() }; // clear any stale error
        let handle = unsafe { dl::dlopen(c.as_ptr(), dl::RTLD_NOW) };
        if handle.is_null() {
            return Err(dl_error(&format!("dlopen {}", path.display())));
        }
        Ok(Library { handle })
    }

    fn sym(&self, name: &str) -> Result<*mut c_void, String> {
        let c = CString::new(name).map_err(|e| format!("bad symbol `{name}`: {e}"))?;
        unsafe { dl::dlerror() };
        let p = unsafe { dl::dlsym(self.handle, c.as_ptr()) };
        if p.is_null() {
            return Err(dl_error(&format!("dlsym {name}")));
        }
        Ok(p)
    }
}

impl Drop for Library {
    fn drop(&mut self) {
        unsafe { dl::dlclose(self.handle) };
    }
}

/// A compiled, loaded generated-code module. `run` is reentrant (the
/// generated C has no global state), so one module may be shared across
/// worker threads behind an `Arc`.
pub struct NativeModule {
    /// Keep the library alive for the lifetime of `run_fn`.
    _lib: Library,
    run_fn: unsafe extern "C" fn(*const i64, *const *mut f64),
    /// Optional runtime thread knob exported by generated code that has a
    /// parallel chunk level (`hfav_set_threads`). `None` for older or
    /// chunk-free artifacts — the knob silently degrades to serial.
    set_threads_fn: Option<unsafe extern "C" fn(i64)>,
    pub extents: Vec<String>,
    pub externals: Vec<String>,
    /// The emitted source this module was compiled from (C99 for
    /// [`build`], Rust for [`build_rust`]).
    pub c_source: String,
    pub so_path: PathBuf,
}

/// Compiler configuration.
#[derive(Debug, Clone)]
pub struct CcOptions {
    pub cc: String,
    pub flags: Vec<String>,
}

impl Default for CcOptions {
    fn default() -> Self {
        CcOptions {
            cc: std::env::var("CC").unwrap_or_else(|_| "cc".to_string()),
            flags: vec![
                "-O3".into(),
                "-march=native".into(),
                "-fno-math-errno".into(),
                // Full OpenMP: `#pragma omp simd` on strip-mined lane
                // loops AND `#pragma omp parallel for` on parallel chunk
                // levels (the intra-job multicore schedule level).
                "-fopenmp".into(),
                "-shared".into(),
                "-fPIC".into(),
            ],
        }
    }
}

/// `rustc` configuration for the Rust-backend native harness.
#[derive(Debug, Clone)]
pub struct RustcOptions {
    pub rustc: String,
    pub flags: Vec<String>,
}

impl Default for RustcOptions {
    fn default() -> Self {
        RustcOptions {
            rustc: std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string()),
            flags: vec![
                "--edition".into(),
                "2021".into(),
                "--crate-type".into(),
                "cdylib".into(),
                "-O".into(),
                "-C".into(),
                "panic=abort".into(),
                "-C".into(),
                "target-cpu=native".into(),
            ],
        }
    }
}

/// Is a working C compiler reachable (the fuzz driver uses this to skip
/// the native-C differential engine in toolchain-less environments)?
pub fn cc_available() -> bool {
    std::process::Command::new(CcOptions::default().cc)
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Is a working `rustc` reachable (used by tests to skip the generated-
/// Rust engine in toolchain-less environments)?
pub fn rustc_available() -> bool {
    std::process::Command::new(RustcOptions::default().rustc)
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Emit, compile and load a program's generated C.
pub fn build(prog: &Program, opts: &CcOptions) -> Result<NativeModule, String> {
    let c_source = c99::emit(prog)?;
    let (c_path, so_path) = gen_paths(prog, &c_source, "c")?;
    write_source(&c_path, &c_source)?;
    let output = std::process::Command::new(&opts.cc)
        .args(&opts.flags)
        .arg("-o")
        .arg(&so_path)
        .arg(&c_path)
        .arg("-lm")
        .output()
        .map_err(|e| format!("failed to spawn {}: {e}", opts.cc))?;
    if !output.status.success() {
        return Err(format!(
            "{} failed:\n{}\n--- source ---\n{}",
            opts.cc,
            String::from_utf8_lossy(&output.stderr),
            c_source
        ));
    }
    load_module(prog, c_source, so_path, "hfav_run")
}

/// Emit the Rust backend's output (with its C-ABI wrapper), compile it
/// with `rustc --crate-type cdylib`, and load it through the same dlopen
/// harness as the C backend. This makes the Rust emitter an *executable*
/// engine rather than a source-only artifact.
pub fn build_rust(prog: &Program, opts: &RustcOptions) -> Result<NativeModule, String> {
    let rs_source = rs::emit_cdylib(prog)?;
    let (rs_path, so_path) = gen_paths(prog, &rs_source, "rs")?;
    write_source(&rs_path, &rs_source)?;
    let output = std::process::Command::new(&opts.rustc)
        .args(&opts.flags)
        .arg("-o")
        .arg(&so_path)
        .arg(&rs_path)
        .output()
        .map_err(|e| format!("failed to spawn {}: {e}", opts.rustc))?;
    if !output.status.success() {
        return Err(format!(
            "{} failed:\n{}\n--- source ---\n{}",
            opts.rustc,
            String::from_utf8_lossy(&output.stderr),
            rs_source
        ));
    }
    load_module(prog, rs_source, so_path, "hfav_run_ffi")
}

/// Scratch-file paths for one emitted source, unique per content digest
/// (avoids stale dlopen caching).
fn gen_paths(
    prog: &Program,
    source: &str,
    ext: &str,
) -> Result<(PathBuf, PathBuf), String> {
    let dir = std::env::temp_dir().join(format!(
        "hfav-{}-{}",
        super::mangle(&prog.deck.name),
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    let digest = {
        let mut h = crate::plan::cache::Fnv64::new();
        h.write(source.as_bytes());
        h.finish()
    };
    let src_path = dir.join(format!("gen_{digest:016x}.{ext}"));
    let so_path = dir.join(format!("gen_{digest:016x}_{ext}.so"));
    Ok((src_path, so_path))
}

fn write_source(path: &Path, source: &str) -> Result<(), String> {
    let mut f = std::fs::File::create(path).map_err(|e| e.to_string())?;
    f.write_all(source.as_bytes()).map_err(|e| e.to_string())
}

fn load_module(
    prog: &Program,
    source: String,
    so_path: PathBuf,
    symbol: &str,
) -> Result<NativeModule, String> {
    let lib = Library::open(&so_path)?;
    let sym = lib.sym(symbol)?;
    // SAFETY: both generated sources define the entry point as
    // `void <symbol>(const int64_t*, double* const*)`.
    let run_fn = unsafe {
        std::mem::transmute::<*mut c_void, unsafe extern "C" fn(*const i64, *const *mut f64)>(sym)
    };
    // SAFETY: both generators declare it `void hfav_set_threads(int64_t)`
    // when present.
    let set_threads_fn = lib.sym("hfav_set_threads").ok().map(|p| unsafe {
        std::mem::transmute::<*mut c_void, unsafe extern "C" fn(i64)>(p)
    });
    Ok(NativeModule {
        _lib: lib,
        run_fn,
        set_threads_fn,
        extents: c99::extent_names(prog),
        externals: c99::external_names(prog),
        c_source: source,
        so_path,
    })
}

impl NativeModule {
    /// Run with named extents and external arrays. Externals must include
    /// every array (inputs and outputs); alias pairs may map two names to
    /// the same buffer by passing the same Vec under one name and declaring
    /// the pair in the deck.
    pub fn run(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
    ) -> Result<(), String> {
        self.run_with(extents, arrays, crate::engine::Threads::Serial)
    }

    /// [`run`](NativeModule::run) at an explicit chunk-thread count. The
    /// knob is a module global behind an atomic in the generated code
    /// (`hfav_set_threads`): last writer wins, and *any* count yields
    /// bitwise-identical results, so concurrent runs of one shared module
    /// at different counts stay correct (one may merely run at the
    /// other's width). Artifacts without a parallel level ignore it.
    pub fn run_with(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        threads: crate::engine::Threads,
    ) -> Result<(), String> {
        if let Some(set) = self.set_threads_fn {
            let n: i64 = match threads {
                crate::engine::Threads::Serial => 1,
                crate::engine::Threads::Fixed(n) => n.max(1) as i64,
                // <= 0 means "all cores" to the generated code.
                crate::engine::Threads::Auto => 0,
            };
            unsafe { set(n) };
        }
        let ext: Vec<i64> = self
            .extents
            .iter()
            .map(|e| extents.get(e).copied().ok_or(format!("missing extent `{e}`")))
            .collect::<Result<_, _>>()?;
        // Collect raw pointers in declaration order; disjointness is
        // guaranteed by BTreeMap ownership of separate Vecs.
        let mut ptrs: Vec<*mut f64> = Vec::with_capacity(self.externals.len());
        for name in &self.externals {
            let a = arrays
                .get_mut(name)
                .ok_or_else(|| format!("missing external array `{name}`"))?;
            ptrs.push(a.as_mut_ptr());
        }
        unsafe { (self.run_fn)(ext.as_ptr(), ptrs.as_ptr()) };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{self, ExecOptions};
    use crate::frontend::testdecks;
    use crate::plan::{compile_src, CompileOptions};

    fn seeded(n: usize, seed: u64) -> Vec<f64> {
        let mut s = seed.wrapping_mul(2685821657736338717).max(1);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64) / ((1u64 << 53) as f64)
            })
            .collect()
    }

    fn extmap(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    /// Compile the generated C for each test deck and check it agrees with
    /// the interpreter executor.
    #[test]
    fn native_matches_executor() {
        let regs: Vec<(&str, crate::exec::registry::Registry)> = vec![
            (testdecks::LAPLACE, {
                let mut r = crate::exec::registry::Registry::new();
                r.register("laplace5", |i, o| o[0] = 0.25 * (i[0] + i[1] + i[2] + i[3]) - i[4]);
                r
            }),
            (testdecks::CHAIN1D, {
                let mut r = crate::exec::registry::Registry::new();
                r.register("dbl", |i, o| o[0] = 2.0 * i[0]);
                r.register("diff", |i, o| o[0] = i[1] - i[0]);
                r
            }),
            (testdecks::NORMALIZE, {
                let mut r = crate::exec::registry::Registry::new();
                r.register("flux", |i, o| o[0] = i[1] - i[0]);
                r.register("norm_init", |_i, o| o[0] = 0.0);
                r.register("norm_acc", |i, o| o[0] = i[0] + i[1] * i[1]);
                r.register("norm_root", |i, o| o[0] = 1.0 / (i[0] + 1e-30).sqrt());
                r.register("normalize", |i, o| o[0] = i[0] * i[1]);
                r
            }),
        ];
        let ext = extmap(&[("Nj", 12), ("Ni", 15), ("N", 33)]);
        for (src, reg) in regs {
            let prog = compile_src(src, CompileOptions::default()).unwrap();
            // Interpreter result.
            let mut inputs = BTreeMap::new();
            for (name, _, _) in prog.external_inputs() {
                let len = exec::external_len(&prog, &name, &ext).unwrap();
                inputs.insert(name, seeded(len, 5));
            }
            let want = exec::run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();

            // Native result.
            let module = build(&prog, &CcOptions::default()).unwrap();
            let mut arrays: BTreeMap<String, Vec<f64>> = BTreeMap::new();
            for name in &module.externals {
                match inputs.get(name) {
                    Some(v) => {
                        arrays.insert(name.clone(), v.clone());
                    }
                    None => {
                        let len = exec::external_len(&prog, name, &ext).unwrap();
                        arrays.insert(name.clone(), vec![0.0; len]);
                    }
                }
            }
            module.run(&ext, &mut arrays).unwrap();
            for (name, w) in &want {
                let got = &arrays[name];
                assert_eq!(got.len(), w.len());
                for (k, (a, b)) in got.iter().zip(w.iter()).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-12 * (1.0 + a.abs().max(b.abs())),
                        "deck `{}` out `{name}` elem {k}: {a} vs {b}",
                        prog.deck.name
                    );
                }
            }
        }
    }

    /// The Rust backend compiled via rustc + dlopen agrees with the
    /// interpreter (scalar and vector-expanded plans).
    #[test]
    fn rust_native_matches_executor() {
        if !rustc_available() {
            eprintln!("skipping rust_native_matches_executor: no rustc on PATH");
            return;
        }
        let ext = extmap(&[("N", 29)]);
        for vlen in [1usize, 4] {
            let opts = CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(vlen),
                    ..Default::default()
                },
                ..Default::default()
            };
            let prog = compile_src(testdecks::CHAIN1D, opts).unwrap();
            let mut reg = crate::exec::registry::Registry::new();
            reg.register("dbl", |i, o| o[0] = 2.0 * i[0]);
            reg.register("diff", |i, o| o[0] = i[1] - i[0]);
            let mut inputs = BTreeMap::new();
            for (name, _, _) in prog.external_inputs() {
                let len = exec::external_len(&prog, &name, &ext).unwrap();
                inputs.insert(name, seeded(len, 9));
            }
            let want = exec::run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
            let module = build_rust(&prog, &RustcOptions::default())
                .unwrap_or_else(|e| panic!("vlen {vlen}: {e}"));
            let mut arrays = inputs.clone();
            for name in &module.externals {
                if !arrays.contains_key(name) {
                    let len = exec::external_len(&prog, name, &ext).unwrap();
                    arrays.insert(name.clone(), vec![0.0; len]);
                }
            }
            module.run(&ext, &mut arrays).unwrap();
            for (name, w) in &want {
                for (k, (a, b)) in arrays[name].iter().zip(w.iter()).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-12 * (1.0 + a.abs().max(b.abs())),
                        "vlen {vlen} out `{name}` elem {k}: {a} vs {b}"
                    );
                }
            }
        }
    }
}
