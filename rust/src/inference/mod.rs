//! The inference engine (paper §4.1): backward chaining from goals to
//! axioms with term unification, producing the dataflow graph.
//!
//! Inference operates at *term family* granularity: `flux(q)[j][i±k]` for
//! all `k` is one variable family; individual displacements become read
//! offsets on dataflow edges. This bakes in the paper's "Grouping" step
//! (§3.2.2): two applications of the same rule that differ only by spatial
//! displacement canonicalize to the same grouped callsite.
//!
//! As in the paper, at most one rule may produce a given term family.

use crate::dataflow::{
    domain_shift, domain_union, Callsite, Dataflow, Read, Terminal, VarId, VarInfo,
};
use crate::ir::{Deck, Domain, Rule, Scalar, Term};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// A term family: identifier plus ordered dimension variables.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Family {
    ident: String,
    dims: Vec<String>,
}

/// Binding produced by unifying a rule pattern against a family.
#[derive(Debug, Clone, Default, PartialEq, Eq, PartialOrd, Ord)]
struct Binding {
    /// pattern base var -> concrete base name
    bases: BTreeMap<String, String>,
    /// pattern subscript var -> concrete loop var
    subs: BTreeMap<String, String>,
}

/// Unify a *pattern* term against a concrete family (tags + base + dim
/// vars; offsets are irrelevant at family granularity). Returns the binding
/// or None.
fn unify_family(pattern: &Term, fam: &Family) -> Option<Binding> {
    // ident = tags applied to base; compare tags structurally by
    // reconstructing the pattern ident with the candidate base binding.
    let mut b = Binding::default();
    // Split fam.ident into tags + base: tags are everything up to the last
    // '(' chain. We reconstruct from pattern side instead: pattern tags must
    // be a prefix-match of the family ident.
    let mut expected = String::new();
    for t in &pattern.tags {
        expected.push_str(t);
        expected.push('(');
    }
    if !fam.ident.starts_with(&expected) {
        return None;
    }
    let base_part = &fam.ident[expected.len()..];
    let base = base_part.trim_end_matches(')');
    // Validate the paren count matches tag count.
    let expected_closers = pattern.tags.len();
    if base_part.len() != base.len() + expected_closers {
        return None;
    }
    if base.contains('(') {
        return None; // family has more tags than pattern
    }
    if pattern.base_pattern {
        b.bases.insert(pattern.base.clone(), base.to_string());
    } else if pattern.base != base {
        return None;
    }
    if pattern.subs.len() != fam.dims.len() {
        return None;
    }
    for (s, d) in pattern.subs.iter().zip(fam.dims.iter()) {
        if s.pattern {
            match b.subs.get(&s.var) {
                Some(existing) if existing != d => return None,
                _ => {
                    b.subs.insert(s.var.clone(), d.clone());
                }
            }
        } else if &s.var != d {
            return None;
        }
    }
    Some(b)
}

/// Instantiate a pattern term under a binding: returns (family, offsets).
/// Unbound subscript pattern vars bind to the like-named loop var (offset
/// preserved) — this is how reduction dims enter a callsite's space.
fn instantiate(pattern: &Term, b: &Binding, deck: &Deck) -> Result<(Family, Vec<i64>), String> {
    let base = if pattern.base_pattern {
        b.bases
            .get(&pattern.base)
            .cloned()
            .ok_or_else(|| format!("unbound base var `{}?` in `{pattern}`", pattern.base))?
    } else {
        pattern.base.clone()
    };
    let mut ident = String::new();
    for t in &pattern.tags {
        ident.push_str(t);
        ident.push('(');
    }
    ident.push_str(&base);
    for _ in &pattern.tags {
        ident.push(')');
    }
    let mut dims = Vec::new();
    let mut offsets = Vec::new();
    for s in &pattern.subs {
        let var = if s.pattern {
            match b.subs.get(&s.var) {
                Some(v) => v.clone(),
                None => {
                    // Free pattern var: bind by name to the loop var.
                    if deck.iteration.order.contains(&s.var) {
                        s.var.clone()
                    } else {
                        return Err(format!(
                            "free pattern var `{}?` in `{pattern}` is not a loop var",
                            s.var
                        ));
                    }
                }
            }
        } else {
            s.var.clone()
        };
        dims.push(var);
        offsets.push(s.offset);
    }
    Ok((Family { ident, dims }, offsets))
}

/// Family of a concrete term (goal / axiom instantiation).
fn family_of_concrete(t: &Term) -> Family {
    Family { ident: t.ident_closed(), dims: t.dims() }
}

impl Term {
    /// Like [`Term::ident`] but with balanced closing parens, used as the
    /// canonical family identifier.
    pub fn ident_closed(&self) -> String {
        let mut s = String::new();
        for t in &self.tags {
            s.push_str(t);
            s.push('(');
        }
        s.push_str(&self.base);
        for _ in &self.tags {
            s.push(')');
        }
        s
    }
}

/// Run inference over a deck, producing the dataflow graph with propagated
/// iteration domains.
pub fn infer(deck: &Deck) -> Result<Dataflow, String> {
    let mut df = Dataflow { loop_order: deck.iteration.order.clone(), ..Default::default() };
    let mut fam_of_var: Vec<Family> = Vec::new();
    let mut var_of_fam: BTreeMap<Family, VarId> = BTreeMap::new();
    // Callsite dedup key: (rule idx, binding).
    let mut cs_by_key: BTreeMap<(usize, Binding), usize> = BTreeMap::new();

    let mut queue: VecDeque<VarId> = VecDeque::new();

    // Seed with goals.
    for g in &deck.goals {
        let fam = family_of_concrete(&g.requires);
        if var_of_fam.contains_key(&fam) {
            return Err(format!("duplicate goal for `{}`", g.requires));
        }
        let v = intern_var_free(deck, &mut df, &mut fam_of_var, &mut var_of_fam, fam, g.ty)?;
        df.vars[v].terminal = Terminal::Output { storage: g.storage.base.clone(), ty: g.ty };
        queue.push_back(v);
    }

    // Resolve producers breadth-first.
    while let Some(v) = queue.pop_front() {
        if df.vars[v].producer.is_some() || matches!(df.vars[v].terminal, Terminal::Input { .. })
        {
            continue;
        }
        let fam = fam_of_var[v].clone();

        // Try axioms first.
        let mut axiom_hit = None;
        for a in &deck.axioms {
            if unify_family(&a.provides, &fam).is_some() {
                if axiom_hit.is_some() {
                    return Err(format!("multiple axioms provide `{}`", fam.ident));
                }
                axiom_hit = Some(a);
            }
        }
        // Try rules.
        let mut rule_hit: Option<(usize, usize, Binding)> = None;
        for (ri, r) in deck.rules.iter().enumerate() {
            for (oi, (_, out_pat)) in r.outputs.iter().enumerate() {
                if let Some(b) = unify_family(out_pat, &fam) {
                    if let Some((pri, _, _)) = &rule_hit {
                        if *pri != ri {
                            return Err(format!(
                                "ambiguous producers for `{}`: rules `{}` and `{}`",
                                fam.ident, deck.rules[*pri].name, r.name
                            ));
                        }
                    } else {
                        rule_hit = Some((ri, oi, b));
                    }
                }
            }
        }

        match (axiom_hit, rule_hit) {
            (Some(_), Some((ri, _, _))) => {
                return Err(format!(
                    "`{}` provided by both an axiom and rule `{}`",
                    fam.ident, deck.rules[ri].name
                ));
            }
            (Some(a), None) => {
                df.vars[v].terminal =
                    Terminal::Input { storage: a.storage.base.clone(), ty: a.ty };
                df.vars[v].ty = a.ty;
            }
            (None, Some((ri, _oi, binding))) => {
                let rule = &deck.rules[ri];
                // A rule produces ALL of its outputs at once; complete the
                // binding by instantiating every output/input, creating the
                // callsite if new.
                let key = (ri, binding.clone());
                if !cs_by_key.contains_key(&key) {
                    let id = df.callsites.len();
                    let cs = instantiate_callsite(
                        id, ri, rule, &binding, deck, &mut df, &mut fam_of_var,
                        &mut var_of_fam, &mut queue,
                    )?;
                    df.callsites.push(cs);
                    cs_by_key.insert(key, id);
                }
            }
            (None, None) => {
                return Err(format!(
                    "no axiom or rule produces `{}` (dims {:?})",
                    fam.ident, fam.dims
                ));
            }
        }
    }

    propagate_domains(deck, &mut df)?;
    Ok(df)
}

// ---- helpers that avoid double-borrow of the intern closure ----

#[allow(clippy::too_many_arguments)]
fn instantiate_callsite(
    id: usize,
    ri: usize,
    rule: &Rule,
    binding: &Binding,
    deck: &Deck,
    df: &mut Dataflow,
    fam_of_var: &mut Vec<Family>,
    var_of_fam: &mut BTreeMap<Family, VarId>,
    queue: &mut VecDeque<VarId>,
) -> Result<Callsite, String> {
    let mut space: BTreeSet<String> = BTreeSet::new();
    let mut writes = Vec::new();
    let mut out_dims_union: BTreeSet<String> = BTreeSet::new();

    for (pname, out_pat) in &rule.outputs {
        let (fam, offsets) = instantiate(out_pat, binding, deck)?;
        let ty = rule
            .params
            .iter()
            .find(|p| &p.name == pname)
            .map(|p| p.ty)
            .unwrap_or(Scalar::F64);
        let v = intern_var_free(deck, df, fam_of_var, var_of_fam, fam.clone(), ty)?;
        if let Some(prev) = df.vars[v].producer {
            if prev != id {
                return Err(format!(
                    "`{}` has multiple producers (rule `{}` and callsite {prev})",
                    fam.ident, rule.name
                ));
            }
        }
        df.vars[v].producer = Some(id);
        df.vars[v].write_offset = offsets.clone();
        for d in &fam.dims {
            space.insert(d.clone());
            out_dims_union.insert(d.clone());
        }
        writes.push((pname.clone(), v, offsets));
    }

    let mut reads = Vec::new();
    for (pname, in_pat) in &rule.inputs {
        let (fam, offsets) = instantiate(in_pat, binding, deck)?;
        let ty = rule
            .params
            .iter()
            .find(|p| &p.name == pname)
            .map(|p| p.ty)
            .unwrap_or(Scalar::F64);
        let v = intern_var_free(deck, df, fam_of_var, var_of_fam, fam.clone(), ty)?;
        for d in &fam.dims {
            space.insert(d.clone());
        }
        df.reads_of[v].push(Read { consumer: id, param: pname.clone(), offsets: offsets.clone() });
        reads.push((pname.clone(), v, offsets));
        queue.push_back(v);
    }

    let mut dims: Vec<String> = space.iter().cloned().collect();
    deck.iteration.sort_outer_first(&mut dims);
    let reduce_dims: BTreeSet<String> =
        dims.iter().filter(|d| !out_dims_union.contains(*d)).cloned().collect();

    Ok(Callsite {
        id,
        rule: ri,
        name: rule.name.clone(),
        base_binding: binding.bases.clone(),
        dims,
        domain: BTreeMap::new(),
        reads,
        writes,
        reduce_dims,
    })
}

fn intern_var_free(
    deck: &Deck,
    df: &mut Dataflow,
    fam_of_var: &mut Vec<Family>,
    var_of_fam: &mut BTreeMap<Family, VarId>,
    fam: Family,
    ty: Scalar,
) -> Result<VarId, String> {
    if let Some(&v) = var_of_fam.get(&fam) {
        if fam_of_var[v].dims != fam.dims {
            return Err(format!(
                "family `{}` used with inconsistent dims {:?} vs {:?}",
                fam.ident, fam_of_var[v].dims, fam.dims
            ));
        }
        return Ok(v);
    }
    let id = df.vars.len();
    let mut dims = fam.dims.clone();
    deck.iteration.sort_outer_first(&mut dims);
    if dims != fam.dims {
        return Err(format!(
            "family `{}` subscripts {:?} do not follow the global loop order {:?}",
            fam.ident, fam.dims, deck.iteration.order
        ));
    }
    df.vars.push(VarInfo {
        id,
        ident: fam.ident.clone(),
        dims: dims.clone(),
        producer: None,
        write_offset: vec![0; dims.len()],
        terminal: Terminal::No,
        span: BTreeMap::new(),
        ty,
    });
    df.reads_of.push(Vec::new());
    df.var_by_ident.insert(fam.ident.clone(), id);
    var_of_fam.insert(fam.clone(), id);
    fam_of_var.push(fam);
    Ok(id)
}

/// Propagate iteration domains (paper §3.2: "the iteration space for each
/// kernel callsite [is] the union of all iteration spaces found on incident
/// variables"). Goals fix the spans of terminal outputs; walking callsites
/// in reverse topological order then fixes every callsite's domain and
/// every variable's required span (including terminal-input halos).
fn propagate_domains(deck: &Deck, df: &mut Dataflow) -> Result<(), String> {
    // Seed goal spans from deck domains.
    for v in df.vars.iter_mut() {
        if matches!(v.terminal, Terminal::Output { .. }) {
            for d in &v.dims {
                let dom = deck
                    .iteration
                    .domains
                    .get(d)
                    .ok_or_else(|| format!("no domain for loop var `{d}`"))?;
                v.span.insert(d.clone(), dom.clone());
            }
        }
    }

    let order = df.topo_order()?;
    for &cs_id in order.iter().rev() {
        // Compute the callsite's domain from its outputs' spans.
        let mut domain: BTreeMap<String, Domain> = BTreeMap::new();
        {
            let cs = &df.callsites[cs_id];
            for (_, v, offsets) in &cs.writes {
                let var = &df.vars[*v];
                for (k, d) in var.dims.iter().enumerate() {
                    let span = var.span.get(d).ok_or_else(|| {
                        format!(
                            "variable `{}` has no span for `{d}` (unconsumed output?)",
                            var.ident
                        )
                    })?;
                    // producer iterates t, writes at t + wo.
                    let dom = domain_shift(span, -offsets[k], -offsets[k]);
                    let merged = match domain.get(d) {
                        Some(prev) => domain_union(prev, &dom)?,
                        None => dom,
                    };
                    domain.insert(d.clone(), merged);
                }
            }
            // Reduction dims (present in space, absent from all outputs) get
            // the deck's declared domain.
            for d in &cs.dims {
                if !domain.contains_key(d) {
                    let dom = deck
                        .iteration
                        .domains
                        .get(d)
                        .ok_or_else(|| format!("no domain for loop var `{d}`"))?;
                    domain.insert(d.clone(), dom.clone());
                }
            }
        }
        // A callsite with several outputs executes over the *union* of
        // their required domains and writes every output at every point;
        // widen each output span to cover the whole domain so storage (and
        // halo accounting) matches what is actually written.
        let writes = df.callsites[cs_id].writes.clone();
        for (_, v, offsets) in &writes {
            let dims = df.vars[*v].dims.clone();
            for (k, d) in dims.iter().enumerate() {
                let base = &domain[d];
                let contrib = domain_shift(base, offsets[k], offsets[k]);
                let var = &mut df.vars[*v];
                let merged = match var.span.get(d) {
                    Some(prev) => domain_union(prev, &contrib)?,
                    None => contrib,
                };
                var.span.insert(d.clone(), merged);
            }
        }

        // Push spans to inputs.
        let reads = df.callsites[cs_id].reads.clone();
        for (_, v, offsets) in &reads {
            let dims = df.vars[*v].dims.clone();
            for (k, d) in dims.iter().enumerate() {
                let base = domain
                    .get(d)
                    .ok_or_else(|| format!("read dim `{d}` outside callsite space"))?;
                let contrib = domain_shift(base, offsets[k], offsets[k]);
                let var = &mut df.vars[*v];
                let merged = match var.span.get(d) {
                    Some(prev) => domain_union(prev, &contrib)?,
                    None => contrib,
                };
                var.span.insert(d.clone(), merged);
            }
        }
        df.callsites[cs_id].domain = domain;
    }

    // Any producer-less, non-terminal var is a bug.
    for v in &df.vars {
        if v.producer.is_none() && matches!(v.terminal, Terminal::No) {
            return Err(format!("variable `{}` has no producer", v.ident));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;

    #[test]
    fn unify_basic() {
        let pat = Term::parse("q?[j?-1][i?]").unwrap();
        let fam = Family { ident: "cell".into(), dims: vec!["j".into(), "i".into()] };
        let b = unify_family(&pat, &fam).unwrap();
        assert_eq!(b.bases["q"], "cell");
        assert_eq!(b.subs["j"], "j");
    }

    #[test]
    fn unify_tag_mismatch() {
        let pat = Term::parse("laplace(q?[j?][i?])").unwrap();
        let fam = Family { ident: "cell".into(), dims: vec!["j".into(), "i".into()] };
        assert!(unify_family(&pat, &fam).is_none());
        let fam2 = Family { ident: "laplace(cell)".into(), dims: vec!["j".into(), "i".into()] };
        assert!(unify_family(&pat, &fam2).is_some());
    }

    #[test]
    fn unify_arity_mismatch() {
        let pat = Term::parse("q?[i?]").unwrap();
        let fam = Family { ident: "cell".into(), dims: vec!["j".into(), "i".into()] };
        assert!(unify_family(&pat, &fam).is_none());
    }

    #[test]
    fn unify_repeated_var_consistency() {
        let pat = Term::parse("q?[i?][i?]").unwrap();
        let fam = Family { ident: "c".into(), dims: vec!["j".into(), "i".into()] };
        assert!(unify_family(&pat, &fam).is_none());
        let fam2 = Family { ident: "c".into(), dims: vec!["i".into(), "i".into()] };
        assert!(unify_family(&pat, &fam2).is_some());
    }

    #[test]
    fn laplace_domains() {
        let deck = crate::frontend::parse_deck(testdecks::LAPLACE).unwrap();
        let df = infer(&deck).unwrap();
        let cs = &df.callsites[0];
        assert_eq!(cs.domain["i"].lo, crate::ir::Bound::constant(1));
        assert_eq!(cs.domain["i"].hi, crate::ir::Bound::of("Ni", -1));
        assert!(cs.reduce_dims.is_empty());
    }

    #[test]
    fn chain1d_extends_producer_domain() {
        let deck = crate::frontend::parse_deck(testdecks::CHAIN1D).unwrap();
        let df = infer(&deck).unwrap();
        // diff over [1, N-1); dbl must cover [0, N).
        let dbl = df.callsites.iter().find(|c| c.name == "dbl").unwrap();
        assert_eq!(dbl.domain["i"].lo, crate::ir::Bound::constant(0));
        assert_eq!(dbl.domain["i"].hi, crate::ir::Bound::of("N", 0));
        // and u's span covers [0, N) as well.
        let u = df.var("u").unwrap();
        assert_eq!(u.span["i"].lo, crate::ir::Bound::constant(0));
        assert_eq!(u.span["i"].hi, crate::ir::Bound::of("N", 0));
    }

    #[test]
    fn normalize_reduction_domains() {
        let deck = crate::frontend::parse_deck(testdecks::NORMALIZE).unwrap();
        let df = infer(&deck).unwrap();
        let acc = df.callsites.iter().find(|c| c.name == "norm_acc").unwrap();
        // The reduction dim i takes the deck domain... but flux(q) is read at
        // offset 0 so i also appears via the read; domain should be [0, Ni).
        assert_eq!(acc.domain["i"].lo, crate::ir::Bound::constant(0));
        assert_eq!(acc.domain["i"].hi, crate::ir::Bound::of("Ni", 0));
        // flux must cover reads at i and i+1 → q span [0, Ni+1)... actually
        // flux's own domain is [0, Ni) (union of consumers), q reads at +1.
        let q = df.var("q").unwrap();
        assert_eq!(q.span["i"].hi, crate::ir::Bound::of("Ni", 1));
    }

    #[test]
    fn missing_producer_reported() {
        let src = r#"
name: bad
iteration:
  order: [i]
  domains:
    i: [0, N]
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    mystery(u[i]) => double g_o[i]
"#;
        let deck = crate::frontend::parse_deck(src).unwrap();
        let err = infer(&deck).unwrap_err();
        assert!(err.contains("no axiom or rule produces"), "{err}");
    }

    #[test]
    fn ambiguous_producer_reported() {
        let src = r#"
name: bad
iteration:
  order: [i]
  domains:
    i: [0, N]
kernels:
  a:
    declaration: a(double x, double &y);
    inputs: |
      x : u?[i?]
    outputs: |
      y : f(u?[i?])
  b:
    declaration: b(double x, double &y);
    inputs: |
      x : u?[i?]
    outputs: |
      y : f(u?[i?])
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    f(u[i]) => double g_o[i]
"#;
        let deck = crate::frontend::parse_deck(src).unwrap();
        let err = infer(&deck).unwrap_err();
        assert!(err.contains("ambiguous"), "{err}");
    }
}
