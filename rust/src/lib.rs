//! # HFAV-rs
//!
//! A production Rust implementation of **High-performance Fusion And
//! Vectorization** (Sewall & Pennycook, 2017): a code generator that fuses
//! kernel-based loop nests, contracts intermediate storage into rolling
//! buffers, and emits vectorizable code — plus an in-process schedule
//! executor, PJRT runtime for AOT-compiled JAX/Pallas artifacts, and a job
//! coordinator.
//!
//! Pipeline (paper §3.1):
//! 1. [`frontend`] parses a declarative deck (rules + axioms + goals).
//! 2. [`inference`] backward-chains goals→axioms into the dataflow graph
//!    ([`dataflow`]).
//! 3. [`fusion`] builds and fuses the iteration-nest DAG.
//! 4. [`analysis`] computes liveness, reuse, storage contraction,
//!    alias chaining and vectorization legality.
//! 5. [`schedule`] lowers one explicit loop-schedule tree per fused
//!    nest (strips, lanes, peels, alignment heads, multi-dim tiles) —
//!    the single place loop shapes are decided.
//! 6. [`plan`] assembles the executable schedule; [`codegen`] prints it
//!    as C99 / Rust / DOT; [`exec`] interprets the same tree in-process.
//! 7. [`verify`] independently re-proves the lowered schedule safe —
//!    bounds, race freedom, def-before-use — behind `hfav check` and
//!    the `HFAV_VERIFY` compile gate.
//!
//! Serving layer: *what* to compile is a [`plan::PlanSpec`] (deck target
//! + variant + tuning knobs) whose canonical fingerprint is the cache
//! identity, and *where* to run it is an execution backend looked up by
//! name in the [`engine`] registry (interpreter, native C, generated
//! Rust, PJRT — all behind one `Backend`/`Executable` trait pair, so new
//! engines are additive registrations). Compilation is expensive but a
//! compiled [`plan::Program`] is immutable and reusable, so
//! [`plan::cache`] provides a shared compile-once plan cache with
//! hit/miss/compile counters, and [`coordinator`] serves job traces over
//! it — a worker pool with pool-wide plan + prepared-executable caches,
//! same-key job batching, executor buffer reuse ([`exec::Workspace`])
//! and latency/throughput/cache metrics ([`coordinator::metrics`]).
//!
//! Testing layer: beyond the differential/property suites, [`fuzz`]
//! generates random legal decks and pushes them through the full
//! pipeline at random knob settings — verifier as the stage-1 oracle,
//! cross-engine differential as stage 2, failures auto-minimized into
//! replayable reproducer decks (`hfav fuzz`).

pub mod ir;
pub mod json;
pub mod yaml;
pub mod frontend;
pub mod inference;
pub mod dataflow;
pub mod runtime;
pub mod fusion;
pub mod analysis;
pub mod schedule;
pub mod plan;
pub mod verify;
pub mod exec;
pub mod codegen;
pub mod apps;
pub mod engine;
pub mod coordinator;
pub mod bench;
pub mod fuzz;
pub mod e2e;
