//! `hfav` CLI: generate code from decks, inspect schedules and graphs,
//! run the built-in apps on any engine, serve job traces through the
//! coordinator (with plan-cache and throughput reporting), and regenerate
//! the paper's benchmark figures.

use hfav::apps::Variant;
use hfav::coordinator::{
    deck_of, distinct_plan_keys, parse_trace_line, repeat_jobs, Coordinator, Engine, Job,
};
use std::collections::BTreeMap;

type CliError = Box<dyn std::error::Error>;
type CliResult = Result<(), CliError>;

fn usage() -> ! {
    eprintln!(
        "usage: hfav <command> [args]
  generate <deck.yaml|app> [--backend c99|rust|dot-dataflow|dot-inest|schedule] [--variant hfav|autovec]
      [--vlen auto|N]
  footprint <deck.yaml|app> --extents Ni=512,Nj=512
  run --app <laplace|normalize|cosmo|hydro2d> [--engine exec|native|pjrt] [--variant hfav|autovec]
      [--size N] [--steps S] [--vlen auto|N]
  serve --trace <file> [--workers N] [--repeat R] [--artifacts DIR] [--vlen auto|N]
  e2e [--size N] [--steps S]
  bench <sysinfo|normalization|cosmo|hydro2d|footprint|serving|pjrt|all> [--vlen auto|N]
  smoke [hlo.txt]

  --vlen: vector length for strip-mined codegen (Fig. 9c); `auto` picks
          the host's SIMD width (runtime-detected), N forces N lanes
          (1 = scalar), omitted = each deck's declared default."
    );
    std::process::exit(2)
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> CliResult {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().cloned().unwrap_or_default();
    let rest = &args[1..];
    match cmd.as_str() {
        "generate" => generate(rest),
        "footprint" => footprint(rest),
        "run" => run(rest),
        "serve" => serve(rest),
        "e2e" => e2e(rest),
        "bench" => bench(rest),
        "smoke" => {
            let path = rest.first().cloned().unwrap_or_else(|| "/tmp/fn_hlo.txt".into());
            let v = hfav::runtime::smoke(&path)?;
            println!("result={v:?}");
            Ok(())
        }
        _ => usage(),
    }
}

fn load_deck_arg(arg: &str) -> Result<String, CliError> {
    if let Ok(deck) = deck_of(arg) {
        return Ok(deck.to_string());
    }
    Ok(std::fs::read_to_string(arg)?)
}

fn variant_of(rest: &[String]) -> Variant {
    match flag(rest, "--variant").as_deref() {
        Some("autovec") => Variant::Autovec,
        _ => Variant::Hfav,
    }
}

/// Parse `--vlen auto|N` into the Option override the plan layer takes.
fn vlen_of(rest: &[String]) -> Result<Option<usize>, CliError> {
    match flag(rest, "--vlen").as_deref() {
        None => Ok(None),
        Some("auto") => Ok(Some(hfav::analysis::auto_vector_len())),
        Some(v) => {
            let n: usize = v.parse().map_err(|e| format!("--vlen: {e}"))?;
            if n == 0 {
                return Err("--vlen must be >= 1 (1 = forced scalar)".into());
            }
            Ok(Some(n))
        }
    }
}

fn compile_arg(rest: &[String]) -> Result<hfav::plan::Program, CliError> {
    let target = rest.first().map(String::as_str).unwrap_or("laplace");
    let src = load_deck_arg(target)?;
    // Same options path the coordinator's plan cache fingerprints, so the
    // CLI inspects exactly what serving would run.
    Ok(hfav::apps::compile_variant_vlen(&src, variant_of(rest), vlen_of(rest)?)?)
}

fn generate(rest: &[String]) -> CliResult {
    let prog = compile_arg(rest)?;
    match flag(rest, "--backend").as_deref().unwrap_or("c99") {
        "c99" => print!("{}", hfav::codegen::c99::emit(&prog)?),
        "rust" => print!("{}", hfav::codegen::rs::emit(&prog)?),
        "dot-dataflow" => print!("{}", hfav::codegen::dot::dataflow(&prog.df)),
        "dot-inest" => print!("{}", hfav::codegen::dot::inest(&prog.df, &prog.fd)),
        "schedule" => print!("{}", prog.schedule_text()),
        other => return Err(format!("unknown backend `{other}`").into()),
    }
    Ok(())
}

fn footprint(rest: &[String]) -> CliResult {
    let prog = compile_arg(rest)?;
    let mut extents = BTreeMap::new();
    if let Some(spec) = flag(rest, "--extents") {
        for kv in spec.split(',') {
            let (k, v) = kv.split_once('=').ok_or("bad extents (want Ni=512,Nj=512)")?;
            extents.insert(k.trim().to_string(), v.trim().parse::<i64>()?);
        }
    }
    println!("deck `{}`:", prog.deck.name);
    for s in &prog.sp.storages {
        let words = hfav::analysis::storage_words(s, &prog.df, &extents).unwrap_or(-1);
        println!(
            "  {:<24} {:<40} {:>12} words{}",
            s.name,
            format!("{:?}", s.sizes),
            words,
            if s.external.is_some() { "  (external)" } else { "" }
        );
    }
    println!("total intermediate: {} words", prog.footprint_words(&extents)?);
    Ok(())
}

fn run(rest: &[String]) -> CliResult {
    let app = flag(rest, "--app").unwrap_or_else(|| "laplace".into());
    let engine: Engine =
        flag(rest, "--engine").unwrap_or_else(|| "native".into()).parse()?;
    let size: usize = flag(rest, "--size").unwrap_or_else(|| "256".into()).parse()?;
    let steps: usize = flag(rest, "--steps").unwrap_or_else(|| "10".into()).parse()?;
    let c = Coordinator::start(1, Some(hfav::runtime::default_artifacts_dir()));
    let r = c
        .submit(Job {
            id: 0,
            app,
            variant: variant_of(rest),
            engine,
            size,
            steps,
            vlen: vlen_of(rest)?,
        })
        .recv()?;
    if r.ok {
        println!(
            "ok: {:.1} Mcells/s latency={:?} checksum={:.6e}",
            r.cups / 1e6,
            r.latency,
            r.checksum
        );
    } else {
        println!("FAILED: {}", r.detail);
    }
    c.shutdown();
    Ok(())
}

fn serve(rest: &[String]) -> CliResult {
    let trace = flag(rest, "--trace").ok_or("--trace required")?;
    let workers: usize = flag(rest, "--workers").unwrap_or_else(|| "4".into()).parse()?;
    let repeat: usize = flag(rest, "--repeat").unwrap_or_else(|| "1".into()).parse()?;
    let artifacts = flag(rest, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(hfav::runtime::default_artifacts_dir);
    let text = std::fs::read_to_string(&trace)?;
    let lines: Vec<&str> = text
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .collect();
    let mut template = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        template.push(parse_trace_line(i as u64, l)?);
    }
    // `--vlen` overrides every job in the trace (per-job vlens come from
    // the optional sixth trace field).
    if let Some(v) = vlen_of(rest)? {
        for j in template.iter_mut() {
            j.vlen = Some(v);
        }
    }
    let jobs = repeat_jobs(&template, repeat);
    println!(
        "serving {} jobs ({} distinct plan keys) on {workers} workers",
        jobs.len(),
        distinct_plan_keys(&jobs)
    );
    let c = Coordinator::start(workers, Some(artifacts));
    let t0 = std::time::Instant::now();
    let results = c.run_batch(jobs);
    let wall = t0.elapsed();
    let mut failed = 0usize;
    for r in &results {
        if !r.ok {
            println!("job {} FAILED: {}", r.id, r.detail);
            failed += 1;
        }
    }
    println!("{}", c.report(wall));
    c.shutdown();
    if failed > 0 {
        // Nonzero exit so CI smoke runs catch serving regressions.
        return Err(format!("{failed} of {} jobs failed", results.len()).into());
    }
    Ok(())
}

fn e2e(rest: &[String]) -> CliResult {
    let size: usize = flag(rest, "--size").unwrap_or_else(|| "128".into()).parse()?;
    let steps: usize = flag(rest, "--steps").unwrap_or_else(|| "200".into()).parse()?;
    hfav::e2e::sod_demo(size, steps)?;
    Ok(())
}

fn bench(rest: &[String]) -> CliResult {
    let which = rest.first().map(String::as_str).unwrap_or("all");
    println!("{}", hfav::bench::sysinfo());
    let sizes_small = [64usize, 128, 256, 512];
    let sizes_big = [128usize, 256, 512, 1024];
    match which {
        "sysinfo" => {}
        "normalization" => {
            hfav::bench::normalization(&sizes_big);
        }
        "cosmo" => {
            hfav::bench::cosmo(&sizes_small, 8);
        }
        "hydro2d" => {
            hfav::bench::hydro2d(&[64, 128, 256], 5);
        }
        "footprint" => {
            hfav::bench::footprint();
        }
        "serving" => {
            hfav::bench::serving(4, 6, vlen_of(rest)?);
        }
        "pjrt" => {
            hfav::bench::pjrt(&hfav::runtime::default_artifacts_dir())?;
        }
        "all" => {
            hfav::bench::footprint();
            hfav::bench::normalization(&sizes_big);
            hfav::bench::cosmo(&sizes_small, 8);
            hfav::bench::hydro2d(&[64, 128, 256], 5);
            hfav::bench::serving(4, 6, vlen_of(rest)?);
            let _ = hfav::bench::pjrt(&hfav::runtime::default_artifacts_dir());
        }
        other => return Err(format!("unknown bench `{other}`").into()),
    }
    Ok(())
}
