//! `hfav` CLI: generate code from decks, inspect schedules and graphs,
//! run built-in apps or external deck files on any registered engine,
//! serve job traces through the coordinator (with plan-cache and
//! throughput reporting), and regenerate the paper's benchmark figures.
//!
//! Engines are resolved through [`hfav::engine::registry`]; `hfav
//! engines` lists them with availability, and `run` fails fast (with the
//! backend's own message) when the requested engine's toolchain is
//! missing.

use hfav::apps::Variant;
use hfav::coordinator::{
    distinct_plan_keys, parse_trace_line, repeat_jobs, target_spec, Coordinator, Job,
};
use hfav::engine::Availability;
use hfav::plan::{PlanSpec, Vlen};

type CliError = Box<dyn std::error::Error>;
type CliResult = Result<(), CliError>;

fn usage() -> ! {
    eprintln!(
        "usage: hfav <command> [args]
  generate <deck.yaml|app> [--backend c99|rust|dot-dataflow|dot-inest|schedule|schedule-ir]
      [--variant hfav|autovec] [--vlen auto|N] [--vec-dim inner|auto|outer:<dim>]
      [--aligned] [--tile] [--time-tile N] [--tuned]
  footprint <deck.yaml|app> --extents Ni=512,Nj=512
  check <deck.yaml|app> [--vlen auto|N] [--vec-dim inner|auto|outer:<dim>]
      [--aligned] [--tile] [--time-tile N] [--tuned] [--variant hfav|autovec]
  engines
  run --app <app|deck.yaml> [--engine exec|native|rust|pjrt] [--variant hfav|autovec]
      [--size N] [--steps S] [--extents NxM[xK]] [--vlen auto|N]
      [--vec-dim inner|auto|outer:<dim>] [--aligned] [--tile] [--time-tile N] [--tuned]
      [--threads serial|auto|N]
  serve --trace <file> [--workers N] [--repeat R] [--artifacts DIR] [--vlen auto|N]
      [--vec-dim inner|auto|outer:<dim>] [--aligned] [--tile] [--time-tile N]
      [--threads serial|auto|N] [--db FILE]
  tune <app|deck.yaml> --extents NxM[xK] [--budget N] [--engine exec|native|rust|pjrt]
      [--db FILE] [--min-reps N] [--min-time SECS]
  tune --report [--db FILE]
  e2e [--size N] [--steps S]
  bench <sysinfo|normalization|cosmo|hydro2d|advect3d|footprint|serving|vectorization
      |time-tiling|pjrt|all> [--vlen auto|N] [--threads serial|auto|N] [--json]
  fuzz [--seeds N] [--seed S] [--engine exec[,native,rust]] [--out DIR] [--stage1-only]
  smoke [hlo.txt]

  engines: list the registered execution backends and their availability
  fuzz:    random-deck differential fuzzing — generate N seeded decks,
           compile each at random knob settings with the schedule
           verifier as the stage-1 oracle, then differential-test every
           surviving plan on each engine against the interpreted unfused
           scalar baseline (1e-12). `--seed` takes decimal or 0x-hex;
           `--out DIR` writes minimized reproducer decks as
           DIR/fuzz-regress-s<seed>.yaml (replayable via `hfav check`);
           `--stage1-only` skips the differential. Exit is nonzero when
           any finding fires.
  check:   static schedule verification — deck lints plus independent
           bounds / race / def-before-use proofs over the lowered
           schedule (see also the HFAV_VERIFY env knob on compiles).
           With no knob flags it sweeps the tuner's whole knob
           cross-product; with explicit flags it checks that one plan.
           Exit is nonzero when any error-severity finding fires.
  --vlen:    vector length for strip-mined codegen (Fig. 9c); `auto` picks
             the host's SIMD width (runtime-detected), N forces N lanes
             (1 = scalar), omitted = each deck's declared default.
  --vec-dim: which loop dim the lanes run along. `inner` (default)
             strip-mines the innermost loop with in-register window
             rotation; `outer:<dim>` strip-mines a k-independent outer
             loop instead — legal only when every kernel iterates <dim>
             with offset-0 accesses, nothing reduces over it, and every
             written variable is indexed by it (compile fails otherwise);
             `auto` picks the outermost legal outer dim, else inner.
  --aligned: aligned-load specialization — 64-byte-aligned intermediates
             plus scalar alignment heads so steady-state strips start at
             multiples of the vector length (no effect at vlen 1). Heads
             are elided at compile time when a strip's lower bound is
             statically a multiple of the vector length.
  --tile:    multi-dim lane tiling — outer-dim lanes x inner strips
             together (vlen x vlen iteration tiles per kernel). Needs a
             k-independent outer dim: combine with --vec-dim outer:<dim>
             or let it auto-resolve; compilation fails when no dim
             qualifies (no effect at vlen 1).
  --time-tile: temporal blocking depth N — fuse N timestep sweeps over each
             cache-resident spatial block, replaying a per-kernel stencil
             halo between passes. Gated per nest by the time_tileable
             legality analysis (reductions over the block dim, in-place
             alias chains and unbounded step dependences fall back to
             N=1 silently); part of the plan fingerprint. The trace v4
             `tt=<n>` field carries it per job; on `serve` the flag
             overrides every job in the trace.
  --report:  (tune) print the cost-model calibration report for the
             tuned-plans DB (predicted rank vs measured winner per shape
             class) instead of tuning
  --extents: (run) per-job grid override, positional values bound to the
             deck's extents in sorted-name order (e.g. cosmo: Ni x Nj x
             Nk) — also the trace v3 `extents=` field. NOTE: `footprint
             --extents` takes the *named* form Ni=512,Nj=512 instead.
  --threads: intra-job worker count for the plan's parallel chunk levels —
             a pure *runtime* knob (never part of the plan fingerprint;
             one compiled plan serves every core count, bitwise
             identically). `serial`/`1` (default) runs single-threaded,
             `auto` uses all cores, N fixes N workers. On `serve` it
             overrides every job in the trace.
  --json:    (bench serving|vectorization|all) also write the
             machine-readable reports BENCH_serving.json /
             BENCH_vectorization.json (stable schema, see README)
  --tuned:   paper §5.3 'HFAV + Tuning' (innermost windows stay full rows)
  --db:      tuned-plans database file (default tuned_plans.json).
             `tune` writes the measured winner for (deck, shape class)
             into it; `serve --db` consults it for trace jobs whose
             variant is `tuned` — a hit re-applies the recorded knobs, a
             miss falls back to heuristic hfav+tuned (never an error).
  --budget:  (tune) how many top-ranked candidates to actually time
             after the cost model orders the legal knob cross-product"
    );
    std::process::exit(2)
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn main() -> CliResult {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().cloned().unwrap_or_default();
    let rest = &args[1..];
    match cmd.as_str() {
        "generate" => generate(rest),
        "footprint" => footprint(rest),
        "check" => check(rest),
        "engines" => engines(),
        "run" => run(rest),
        "serve" => serve(rest),
        "tune" => tune(rest),
        "e2e" => e2e(rest),
        "bench" => bench(rest),
        "fuzz" => fuzz(rest),
        "smoke" => {
            let path = rest.first().cloned().unwrap_or_else(|| "/tmp/fn_hlo.txt".into());
            let v = hfav::runtime::smoke(&path)?;
            println!("result={v:?}");
            Ok(())
        }
        _ => usage(),
    }
}

fn variant_of(rest: &[String]) -> Result<Variant, CliError> {
    match flag(rest, "--variant") {
        None => Ok(Variant::Hfav),
        Some(v) => Ok(v.parse()?),
    }
}

/// Parse `--vlen auto|N` into a [`Vlen`] request (`Deck` when omitted).
fn vlen_of(rest: &[String]) -> Result<Vlen, CliError> {
    match flag(rest, "--vlen") {
        None => Ok(Vlen::Deck),
        Some(v) => Ok(v.parse().map_err(|e| format!("--vlen: {e}"))?),
    }
}

/// Parse `--vec-dim inner|auto|outer:<dim>` (`Inner` when omitted).
fn vec_dim_of(rest: &[String]) -> Result<hfav::analysis::VecDim, CliError> {
    match flag(rest, "--vec-dim") {
        None => Ok(hfav::analysis::VecDim::Inner),
        Some(v) => Ok(v.parse().map_err(|e| format!("--vec-dim: {e}"))?),
    }
}

/// Parse `--time-tile N` (1 = off when omitted; 0 clamps to 1).
fn time_tile_of(rest: &[String]) -> Result<usize, CliError> {
    match flag(rest, "--time-tile") {
        None => Ok(1),
        Some(v) => Ok(v.parse::<usize>().map_err(|e| format!("--time-tile: {e}"))?.max(1)),
    }
}

/// Parse `--threads serial|auto|N` (`Serial` when omitted).
fn threads_of(rest: &[String]) -> Result<hfav::engine::Threads, CliError> {
    match flag(rest, "--threads") {
        None => Ok(hfav::engine::Threads::Serial),
        Some(v) => Ok(v.parse::<hfav::engine::Threads>()?),
    }
}

/// Build the [`PlanSpec`] a subcommand's flags describe: a built-in app
/// or deck-file target, variant, vectorization knobs and tuning — the
/// exact spec (and plan-cache identity) serving would use.
fn spec_of(target: &str, rest: &[String]) -> Result<PlanSpec, CliError> {
    Ok(target_spec(target)?
        .variant(variant_of(rest)?)
        .vlen(vlen_of(rest)?)
        .vec_dim(vec_dim_of(rest)?)
        .aligned(has_flag(rest, "--aligned"))
        .tiled(has_flag(rest, "--tile"))
        .time_tile(time_tile_of(rest)?)
        .tuned(has_flag(rest, "--tuned")))
}

fn compile_arg(rest: &[String]) -> Result<hfav::plan::Program, CliError> {
    let target = rest.first().map(String::as_str).unwrap_or("laplace");
    Ok(spec_of(target, rest)?.compile()?)
}

fn generate(rest: &[String]) -> CliResult {
    let prog = compile_arg(rest)?;
    match flag(rest, "--backend").as_deref().unwrap_or("c99") {
        "c99" => print!("{}", hfav::codegen::c99::emit(&prog)?),
        "rust" => print!("{}", hfav::codegen::rs::emit(&prog)?),
        "dot-dataflow" => print!("{}", hfav::codegen::dot::dataflow(&prog.df)),
        "dot-inest" => print!("{}", hfav::codegen::dot::inest(&prog.df, &prog.fd)),
        "schedule" => print!("{}", prog.schedule_text()),
        "schedule-ir" => {
            print!("{}", prog.sched.render());
            // Walk-derived counters at a sample shape: 16 per extent,
            // serial and 4-worker chunking side by side.
            let names = hfav::codegen::c99::extent_names(&prog);
            let ext: std::collections::BTreeMap<String, i64> =
                names.into_iter().map(|n| (n, 16i64)).collect();
            println!("# stats @16/dim threads=1: {}", prog.schedule_stats(&ext, 1)?.summary());
            println!("# stats @16/dim threads=4: {}", prog.schedule_stats(&ext, 4)?.summary());
        }
        other => return Err(format!("unknown backend `{other}`").into()),
    }
    Ok(())
}

fn footprint(rest: &[String]) -> CliResult {
    let prog = compile_arg(rest)?;
    let mut extents = std::collections::BTreeMap::new();
    if let Some(spec) = flag(rest, "--extents") {
        for kv in spec.split(',') {
            let (k, v) = kv.split_once('=').ok_or("bad extents (want Ni=512,Nj=512)")?;
            extents.insert(k.trim().to_string(), v.trim().parse::<i64>()?);
        }
    }
    println!("deck `{}`:", prog.deck.name);
    for s in &prog.sp.storages {
        let words = hfav::analysis::storage_words(s, &prog.df, &extents).unwrap_or(-1);
        println!(
            "  {:<24} {:<40} {:>12} words{}",
            s.name,
            format!("{:?}", s.sizes),
            words,
            if s.external.is_some() { "  (external)" } else { "" }
        );
    }
    println!("total intermediate: {} words", prog.footprint_words(&extents)?);
    Ok(())
}

/// `hfav check`: static verification of one deck's lowered schedules.
/// Deck lints run once; the bounds/race/def-before-use proofs run per
/// plan — over the tuner's full knob cross-product by default, or the
/// single plan the explicit knob flags describe. Nonzero exit on any
/// error-severity finding.
fn check(rest: &[String]) -> CliResult {
    let target = match rest.first() {
        Some(t) if !t.starts_with("--") => t.clone(),
        _ => return Err("check: target <app|deck.yaml> required".into()),
    };
    let explicit =
        ["--vlen", "--vec-dim", "--aligned", "--tile", "--time-tile", "--tuned", "--variant"]
            .iter()
            .any(|f| has_flag(rest, f));
    let base = spec_of(&target, rest)?;
    let specs = if explicit {
        vec![base]
    } else {
        hfav::bench::tune::candidate_specs(&base)
    };
    let mut errors = 0usize;
    let mut warnings = 0usize;
    let mut checked = 0usize;
    let mut skipped = 0usize;
    let mut linted = false;
    for spec in specs {
        let prog = match spec.compile() {
            Ok(p) => p,
            Err(e) => {
                if explicit {
                    return Err(format!("compile failed: {e}").into());
                }
                // Illegal knob corner for this deck (e.g. no legal outer
                // dim) — the same filter tuning applies.
                skipped += 1;
                continue;
            }
        };
        // Deck lints are knob-independent: report them once, against the
        // first plan that compiles.
        if !linted {
            linted = true;
            for d in hfav::verify::lint_deck(&prog) {
                println!("{d}");
                match d.severity {
                    hfav::verify::Severity::Error => errors += 1,
                    hfav::verify::Severity::Warning => warnings += 1,
                }
            }
        }
        checked += 1;
        let label = format!(
            "variant={} vlen={} vec_dim={} aligned={} tiled={} time_tile={}",
            spec.variant_label(),
            prog.vector_len(),
            prog.vec_dim(),
            spec.is_aligned(),
            prog.tiled(),
            prog.time_tile()
        );
        let report = hfav::verify::check_schedule(&prog)?;
        for d in &report.diagnostics {
            println!("[{label}] {d}");
        }
        errors += report.error_count();
        warnings += report.warning_count();
        println!("{} {label}", if report.has_errors() { "FAIL" } else { "ok  " });
    }
    if checked == 0 {
        return Err(format!("no plan for `{target}` compiles ({skipped} knob sets tried)").into());
    }
    println!(
        "checked {checked} plan(s), {skipped} illegal knob corner(s) skipped: \
         {errors} error(s), {warnings} warning(s)"
    );
    if errors > 0 {
        return Err(format!("check failed with {errors} error(s)").into());
    }
    Ok(())
}

/// `--seed` accepts decimal or `0x`-prefixed hex (campaign seeds read
/// better in hex).
fn parse_seed(s: &str) -> Result<u64, CliError> {
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    parsed.map_err(|e| format!("--seed `{s}`: {e}").into())
}

/// Random-deck differential fuzz campaign (see `hfav::fuzz`). Exit is
/// nonzero when any finding fires, so CI can gate on it directly.
fn fuzz(rest: &[String]) -> CliResult {
    let seeds = match flag(rest, "--seeds") {
        Some(s) => s.parse::<u64>().map_err(|e| format!("--seeds: {e}"))?,
        None => 100,
    };
    let seed0 = match flag(rest, "--seed") {
        Some(s) => parse_seed(&s)?,
        None => 0,
    };
    let engines = match flag(rest, "--engine") {
        Some(list) => Some(
            list.split(',')
                .map(|e| e.trim().parse::<hfav::fuzz::FuzzEngine>())
                .collect::<Result<Vec<_>, String>>()?,
        ),
        None => None,
    };
    let cfg = hfav::fuzz::FuzzConfig {
        seeds,
        seed0,
        engines,
        stage2: !has_flag(rest, "--stage1-only"),
        out_dir: flag(rest, "--out").map(std::path::PathBuf::from),
        verbose: true,
    };
    let report = hfav::fuzz::run(&cfg)?;
    print!("{}", report.summary());
    if !report.clean() {
        let wrote = cfg
            .out_dir
            .as_ref()
            .map(|d| format!(" — minimized reproducers in {}", d.display()))
            .unwrap_or_else(|| " — re-run with --out DIR to write reproducers".to_string());
        return Err(format!("fuzz: {} finding(s){wrote}", report.findings.len()).into());
    }
    Ok(())
}

/// List every registered backend with its availability — one line per
/// engine, machine-parseable (`name<TAB>available|unavailable<TAB>why`),
/// so CI can smoke every engine the registry knows about.
fn engines() -> CliResult {
    for b in hfav::engine::registry().iter() {
        match b.available() {
            Availability::Ready => println!("{}\tavailable\t-", b.name()),
            Availability::Missing(why) => println!("{}\tunavailable\t{why}", b.name()),
        }
    }
    // Knob summary (comment lines — the tab-separated listing above stays
    // machine-parseable for the CI engine smoke).
    println!("# knobs: --vlen auto|N (strip width; 1 = scalar)");
    println!("#        --vec-dim inner|auto|outer:<dim> (outer needs a k-independent loop:");
    println!("#          offset-0 accesses, no reduction over it, all writes indexed by it)");
    println!("#        --aligned (aligned intermediates + aligned strip heads; vlen > 1)");
    println!("#        --tile (outer lanes x inner strips; needs a k-independent outer dim)");
    Ok(())
}

fn run(rest: &[String]) -> CliResult {
    let app = flag(rest, "--app").unwrap_or_else(|| "laplace".into());
    let engine = flag(rest, "--engine").unwrap_or_else(|| "native".into());
    let size: usize = flag(rest, "--size").unwrap_or_else(|| "256".into()).parse()?;
    let steps: usize = flag(rest, "--steps").unwrap_or_else(|| "10".into()).parse()?;
    // Fail fast: resolve the backend and probe its toolchain before
    // spawning a coordinator, so `--engine pjrt` (or a rustc-less
    // `--engine rust`) reports the backend's own message immediately
    // instead of a worker-side job failure.
    let backend = hfav::engine::registry().get(&engine)?;
    if let Availability::Missing(why) = backend.available() {
        return Err(format!("engine `{}` unavailable: {why}", backend.name()).into());
    }
    let spec = spec_of(&app, rest)?;
    let mut job = Job::new(0, spec, backend.name(), size, steps).with_threads(threads_of(rest)?);
    if let Some(s) = flag(rest, "--extents") {
        job = job.with_extents(hfav::coordinator::parse_extents(&s)?);
    }
    let c = Coordinator::start(1, Some(hfav::runtime::default_artifacts_dir()));
    let r = c.submit(job).recv()?;
    let out = if r.ok {
        println!(
            "ok: {:.1} Mcells/s latency={:?} checksum={:.6e}",
            r.cups / 1e6,
            r.latency,
            r.checksum
        );
        Ok(())
    } else {
        Err(format!("job failed: {}", r.detail).into())
    };
    c.shutdown();
    out
}

fn serve(rest: &[String]) -> CliResult {
    let trace = flag(rest, "--trace").ok_or("--trace required")?;
    let workers: usize = flag(rest, "--workers").unwrap_or_else(|| "4".into()).parse()?;
    let repeat: usize = flag(rest, "--repeat").unwrap_or_else(|| "1".into()).parse()?;
    let artifacts = flag(rest, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(hfav::runtime::default_artifacts_dir);
    let text = std::fs::read_to_string(&trace)?;
    let lines: Vec<&str> = text
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .collect();
    let mut template = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        template.push(parse_trace_line(i as u64, l)?);
    }
    // Tuned-plans resolution: trace jobs with `variant=tuned` look up the
    // DB by (deck digest, shape class) and re-apply the recorded knobs; a
    // miss keeps the heuristic hfav+tuned fallback the parser installed.
    // Resolution compiles through the same plan cache the coordinator
    // serves from, so nothing is compiled twice — and it runs *before*
    // the CLI template overrides below, which therefore still win.
    let plans = std::sync::Arc::new(hfav::plan::cache::PlanCache::new());
    let db_flag = flag(rest, "--db");
    // An explicitly named DB must exist and parse — catch a typo'd path
    // or a corrupt file at startup with a clear message, instead of
    // failing mid-trace (or silently serving all-miss fallbacks). The
    // default path keeps its lenient semantics: missing file = empty DB,
    // per-job lookup misses still fall back silently.
    if let Some(p) = &db_flag {
        if !std::path::Path::new(p).exists() {
            return Err(format!(
                "--db {p}: tuned-plans DB not found (run `hfav tune <target> --db {p}` to create it)"
            )
            .into());
        }
        hfav::plan::tunedb::TunedDb::load(p)
            .map_err(|e| format!("--db {p}: not a usable tuned-plans DB: {e}"))?;
    }
    let db_path = db_flag.unwrap_or_else(|| hfav::plan::tunedb::DEFAULT_DB_PATH.into());
    if template.iter().any(|j| j.tuned_request) {
        let db = hfav::plan::tunedb::TunedDb::load(&db_path)?;
        for j in template.iter_mut() {
            match hfav::coordinator::resolve_tuned(j, &db, &plans)? {
                Some(label) => println!("job {}: tuned db hit -> {label}", j.id),
                None if j.tuned_request => println!(
                    "job {}: tuned db miss ({}) -> heuristic hfav+tuned fallback",
                    j.id, db_path
                ),
                None => {}
            }
        }
    }
    // `--vlen` overrides every job in the trace (per-job vlens come from
    // the optional sixth trace field), as do `--vec-dim`, `--aligned`
    // and `--tile`.
    if let vlen @ (Vlen::Auto | Vlen::Fixed(_)) = vlen_of(rest)? {
        for j in template.iter_mut() {
            j.spec = j.spec.clone().vlen(vlen);
        }
    }
    if let Some(vd) = flag(rest, "--vec-dim") {
        let vd: hfav::analysis::VecDim = vd.parse().map_err(|e| format!("--vec-dim: {e}"))?;
        for j in template.iter_mut() {
            j.spec = j.spec.clone().vec_dim(vd.clone());
        }
    }
    if has_flag(rest, "--aligned") {
        for j in template.iter_mut() {
            j.spec = j.spec.clone().aligned(true);
        }
    }
    if has_flag(rest, "--tile") {
        for j in template.iter_mut() {
            j.spec = j.spec.clone().tiled(true);
        }
    }
    if flag(rest, "--time-tile").is_some() {
        let tt = time_tile_of(rest)?;
        for j in template.iter_mut() {
            j.spec = j.spec.clone().time_tile(tt);
        }
    }
    // `--threads` is the one trace-global override that does NOT touch
    // the specs: it sets each job's runtime knob, so the trace's plan
    // keys (and cache behavior) are exactly what they were serially.
    if flag(rest, "--threads").is_some() {
        let threads = threads_of(rest)?;
        for j in template.iter_mut() {
            j.threads = threads;
        }
    }
    let jobs = repeat_jobs(&template, repeat);
    println!(
        "serving {} jobs ({} distinct plan keys) on {workers} workers",
        jobs.len(),
        distinct_plan_keys(&jobs)
    );
    let c = Coordinator::start_with_cache(workers, Some(artifacts), plans);
    let t0 = std::time::Instant::now();
    let results = c.run_batch(jobs);
    let wall = t0.elapsed();
    let mut failed = 0usize;
    for r in &results {
        if !r.ok {
            println!("job {} FAILED: {}", r.id, r.detail);
            failed += 1;
        }
    }
    println!("{}", c.report(wall));
    c.shutdown();
    if failed > 0 {
        // Nonzero exit so CI smoke runs catch serving regressions.
        return Err(format!("{failed} of {} jobs failed", results.len()).into());
    }
    Ok(())
}

/// `hfav tune`: enumerate + rank + time candidate plans for one deck at
/// one shape, then persist the measured winner in the tuned-plans DB
/// (keyed by deck digest and shape class, so nearby shapes share it).
fn tune(rest: &[String]) -> CliResult {
    // `tune --report`: read-only calibration view of the tuned-plans DB —
    // how well the cost model's pre-timing ranking predicted the measured
    // winners, per shape class.
    if has_flag(rest, "--report") {
        let db_path =
            flag(rest, "--db").unwrap_or_else(|| hfav::plan::tunedb::DEFAULT_DB_PATH.into());
        let db = hfav::plan::tunedb::TunedDb::load(&db_path)?;
        print!("{}", hfav::schedule::cost::calibration_report(&db));
        return Ok(());
    }
    let target = match rest.first() {
        Some(t) if !t.starts_with("--") => t.clone(),
        _ => return Err("tune: target <app|deck.yaml> required".into()),
    };
    let extents_s = flag(rest, "--extents").ok_or("--extents required (e.g. 32x32x32)")?;
    let extents = hfav::coordinator::parse_extents(&extents_s)?;
    let mut cfg = hfav::bench::tune::TuneConfig::for_extents(extents);
    if let Some(b) = flag(rest, "--budget") {
        cfg.budget = b.parse::<usize>()?.max(1);
    }
    if let Some(e) = flag(rest, "--engine") {
        cfg.engine = e;
    }
    if let Some(r) = flag(rest, "--min-reps") {
        cfg.min_reps = r.parse()?;
    }
    if let Some(t) = flag(rest, "--min-time") {
        cfg.min_time_s = t.parse()?;
    }
    // Fail fast on an unavailable engine, like `run` does, instead of
    // letting every candidate fail the same way one by one.
    let backend = hfav::engine::registry().get(&cfg.engine)?;
    if let Availability::Missing(why) = backend.available() {
        return Err(format!("engine `{}` unavailable: {why}", backend.name()).into());
    }
    let db_path = flag(rest, "--db").unwrap_or_else(|| hfav::plan::tunedb::DEFAULT_DB_PATH.into());
    let base = target_spec(&target)?;
    let entry = hfav::bench::tune::tune(&base, &cfg)?;
    let mut db = hfav::plan::tunedb::TunedDb::load(&db_path)?;
    db.insert(entry);
    db.save(&db_path)?;
    println!("recorded -> {db_path} ({} entries)", db.len());
    Ok(())
}

fn e2e(rest: &[String]) -> CliResult {
    let size: usize = flag(rest, "--size").unwrap_or_else(|| "128".into()).parse()?;
    let steps: usize = flag(rest, "--steps").unwrap_or_else(|| "200".into()).parse()?;
    hfav::e2e::sod_demo(size, steps)?;
    Ok(())
}

fn bench(rest: &[String]) -> CliResult {
    let which = rest.first().map(String::as_str).unwrap_or("all");
    println!("{}", hfav::bench::sysinfo());
    let sizes_small = [64usize, 128, 256, 512];
    let sizes_big = [128usize, 256, 512, 1024];
    let json = has_flag(rest, "--json");
    let threads = threads_of(rest)?;
    // Worker count for the vectorization bench's `parallel` rows: the
    // --threads knob when given, else 4 (the acceptance shape).
    let tcount = match threads {
        hfav::engine::Threads::Serial => 4,
        other => other.resolve(),
    };
    let write_json = |path: &str, text: String| -> CliResult {
        std::fs::write(path, text)?;
        println!("wrote {path}");
        Ok(())
    };
    match which {
        "sysinfo" => {}
        "normalization" => {
            hfav::bench::normalization(&sizes_big);
        }
        "cosmo" => {
            hfav::bench::cosmo(&sizes_small, 8);
        }
        "hydro2d" => {
            hfav::bench::hydro2d(&[64, 128, 256], 5);
        }
        "advect3d" => {
            hfav::bench::advect3d(&[64, 128, 256], 8);
        }
        "footprint" => {
            hfav::bench::footprint();
        }
        "serving" => {
            let (_, rows) = hfav::bench::serving(4, 6, vlen_of(rest)?.resolve(), threads);
            if json {
                write_json("BENCH_serving.json", hfav::bench::report::serving_json(&rows))?;
            }
        }
        "vectorization" => {
            let v = vlen_of(rest)?.resolve().unwrap_or_else(hfav::analysis::auto_vector_len);
            let (_, rows) = hfav::bench::vectorization(v, tcount);
            if json {
                write_json(
                    "BENCH_vectorization.json",
                    hfav::bench::report::vectorization_json(&rows),
                )?;
            }
        }
        "time-tiling" => {
            let (_, rows) = hfav::bench::time_tiling(tcount);
            if json {
                write_json(
                    "BENCH_time_tiling.json",
                    hfav::bench::report::time_tiling_json(&rows),
                )?;
            }
        }
        "pjrt" => {
            hfav::bench::pjrt(&hfav::runtime::default_artifacts_dir())?;
        }
        "all" => {
            hfav::bench::footprint();
            hfav::bench::normalization(&sizes_big);
            hfav::bench::cosmo(&sizes_small, 8);
            hfav::bench::hydro2d(&[64, 128, 256], 5);
            hfav::bench::advect3d(&[64, 128, 256], 8);
            let (_, srows) = hfav::bench::serving(4, 6, vlen_of(rest)?.resolve(), threads);
            let v = vlen_of(rest)?.resolve().unwrap_or_else(hfav::analysis::auto_vector_len);
            let (_, vrows) = hfav::bench::vectorization(v, tcount);
            let (_, trows) = hfav::bench::time_tiling(tcount);
            let _ = hfav::bench::pjrt(&hfav::runtime::default_artifacts_dir());
            if json {
                write_json("BENCH_serving.json", hfav::bench::report::serving_json(&srows))?;
                write_json(
                    "BENCH_vectorization.json",
                    hfav::bench::report::vectorization_json(&vrows),
                )?;
                write_json(
                    "BENCH_time_tiling.json",
                    hfav::bench::report::time_tiling_json(&trows),
                )?;
            }
        }
        other => return Err(format!("unknown bench `{other}`").into()),
    }
    Ok(())
}
