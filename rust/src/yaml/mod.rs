//! A minimal YAML-subset parser, sufficient for HFAV decks.
//!
//! Supported: block mappings (indentation-scoped), block sequences
//! (`- item`), inline flow sequences (`[a, b, c]`), plain scalars, quoted
//! scalars, literal block scalars (`|`), comments (`#`). This is a
//! deliberately small, dependency-free subset — the full YAML spec is not
//! needed by the deck format (paper §4 uses "a custom YAML format").

use std::collections::BTreeMap;
use std::fmt;

/// Parsed YAML node.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Scalar(String),
    Seq(Vec<Node>),
    /// Insertion-ordered mapping.
    Map(Vec<(String, Node)>),
}

impl Node {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Node::Scalar(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_map(&self) -> Option<&[(String, Node)]> {
        match self {
            Node::Map(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_seq(&self) -> Option<&[Node]> {
        match self {
            Node::Seq(s) => Some(s),
            _ => None,
        }
    }
    /// Mapping lookup.
    pub fn get(&self, key: &str) -> Option<&Node> {
        match self {
            Node::Map(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Flatten a map into a BTreeMap of scalar values (for small configs).
    pub fn scalar_map(&self) -> Option<BTreeMap<String, String>> {
        let m = self.as_map()?;
        let mut out = BTreeMap::new();
        for (k, v) in m {
            out.insert(k.clone(), v.as_str()?.to_string());
        }
        Some(out)
    }
}

impl fmt::Display for Node {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn go(n: &Node, indent: usize, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            let pad = "  ".repeat(indent);
            match n {
                Node::Scalar(s) => writeln!(f, "{pad}{s}"),
                Node::Seq(items) => {
                    for it in items {
                        match it {
                            Node::Scalar(s) => writeln!(f, "{pad}- {s}")?,
                            _ => {
                                writeln!(f, "{pad}-")?;
                                go(it, indent + 1, f)?;
                            }
                        }
                    }
                    Ok(())
                }
                Node::Map(m) => {
                    for (k, v) in m {
                        match v {
                            Node::Scalar(s) => writeln!(f, "{pad}{k}: {s}")?,
                            _ => {
                                writeln!(f, "{pad}{k}:")?;
                                go(v, indent + 1, f)?;
                            }
                        }
                    }
                    Ok(())
                }
            }
        }
        go(self, 0, f)
    }
}

#[derive(Debug)]
struct Line {
    indent: usize,
    /// Content with comments stripped (unless quoted / block scalar).
    text: String,
    /// 1-based source line for diagnostics.
    num: usize,
}

/// Parse a YAML document into a [`Node`].
pub fn parse(src: &str) -> Result<Node, String> {
    let lines = logical_lines(src);
    if lines.is_empty() {
        return Ok(Node::Map(vec![]));
    }
    let mut pos = 0usize;
    let node = parse_block(&lines, &mut pos, lines[0].indent, src)?;
    if pos < lines.len() {
        return Err(format!("line {}: trailing content", lines[pos].num));
    }
    Ok(node)
}

fn logical_lines(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let without_comment = strip_comment(raw);
        let trimmed = without_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line { indent, text: trimmed.trim_start().to_string(), num: idx + 1 });
    }
    out
}

/// Strip a `#` comment not inside quotes.
fn strip_comment(s: &str) -> String {
    let mut in_s = false;
    let mut in_d = false;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            '#' if !in_s && !in_d => {
                // Only treat as comment if at start or preceded by whitespace.
                if i == 0 || s[..i].ends_with(' ') || s[..i].ends_with('\t') {
                    return s[..i].to_string();
                }
            }
            _ => {}
        }
    }
    s.to_string()
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize, src: &str) -> Result<Node, String> {
    let line = &lines[*pos];
    if line.text.starts_with("- ") || line.text == "-" {
        parse_seq(lines, pos, indent, src)
    } else {
        parse_map(lines, pos, indent, src)
    }
}

fn parse_seq(lines: &[Line], pos: &mut usize, indent: usize, src: &str) -> Result<Node, String> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim().to_string();
        *pos += 1;
        if rest.is_empty() {
            // Nested block on following lines.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent, src)?);
            } else {
                items.push(Node::Scalar(String::new()));
            }
        } else {
            items.push(parse_value_inline(&rest)?);
        }
    }
    Ok(Node::Seq(items))
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize, src: &str) -> Result<Node, String> {
    let mut entries: Vec<(String, Node)> = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent {
            break;
        }
        let (key, rest) = split_key(&line.text)
            .ok_or_else(|| format!("line {}: expected `key:` got `{}`", line.num, line.text))?;
        if entries.iter().any(|(k, _)| k == &key) {
            return Err(format!("line {}: duplicate key `{key}`", line.num));
        }
        *pos += 1;
        let value = if rest.is_empty() {
            // Block value on following (more-indented) lines, or empty.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                parse_block(lines, pos, child_indent, src)?
            } else {
                Node::Scalar(String::new())
            }
        } else if rest == "|" || rest == "|-" {
            Node::Scalar(block_scalar(lines, pos, indent, src))
        } else {
            parse_value_inline(&rest)?
        };
        entries.push((key, value));
    }
    Ok(Node::Map(entries))
}

/// Collect a literal block scalar: all following lines indented deeper than
/// the key line, dedented to their common prefix, newlines preserved. The
/// block is recovered from the *original* source to keep `#` characters and
/// blank interior lines intact.
fn block_scalar(lines: &[Line], pos: &mut usize, key_indent: usize, src: &str) -> String {
    // We need the raw source lines between this logical line and the next
    // logical line at indent <= key_indent.
    let start_num = if *pos < lines.len() { lines[*pos].num } else { usize::MAX };
    // Find end: first logical line with indent <= key_indent at or after *pos.
    let mut end_logical = *pos;
    while end_logical < lines.len() && lines[end_logical].indent > key_indent {
        end_logical += 1;
    }
    let end_num = if end_logical < lines.len() { lines[end_logical].num } else { usize::MAX };
    *pos = end_logical;

    let raw: Vec<&str> = src
        .lines()
        .enumerate()
        .filter(|(i, _)| i + 1 >= start_num && i + 1 < end_num)
        .map(|(_, l)| l)
        .collect();
    // Common indent of non-empty lines.
    let common = raw
        .iter()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.len() - l.trim_start().len())
        .min()
        .unwrap_or(0);
    let mut out = String::new();
    for l in raw {
        if l.trim().is_empty() {
            out.push('\n');
        } else {
            out.push_str(&l[common.min(l.len())..]);
            out.push('\n');
        }
    }
    out
}

/// Split `key: value` / `key:`; keys may be quoted.
fn split_key(text: &str) -> Option<(String, String)> {
    let mut in_s = false;
    let mut in_d = false;
    for (i, c) in text.char_indices() {
        match c {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            ':' if !in_s && !in_d => {
                let after = &text[i + 1..];
                if after.is_empty() || after.starts_with(' ') {
                    let key = unquote(text[..i].trim());
                    return Some((key, after.trim().to_string()));
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

/// Inline value: flow sequence `[a, b]` or scalar.
fn parse_value_inline(s: &str) -> Result<Node, String> {
    let s = s.trim();
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(format!("unterminated flow sequence `{s}`"));
        }
        let inner = &s[1..s.len() - 1];
        let items = split_flow(inner)?;
        return Ok(Node::Seq(items.into_iter().map(|x| Node::Scalar(unquote(&x))).collect()));
    }
    Ok(Node::Scalar(unquote(s)))
}

/// Split a flow sequence body on commas, honoring brackets and quotes.
fn split_flow(s: &str) -> Result<Vec<String>, String> {
    let mut items = Vec::new();
    let mut depth = 0i32;
    let mut in_s = false;
    let mut in_d = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '\'' if !in_d => {
                in_s = !in_s;
                cur.push(c);
            }
            '"' if !in_s => {
                in_d = !in_d;
                cur.push(c);
            }
            '[' | '(' if !in_s && !in_d => {
                depth += 1;
                cur.push(c);
            }
            ']' | ')' if !in_s && !in_d => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_s && !in_d => {
                items.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if depth != 0 || in_s || in_d {
        return Err(format!("unbalanced flow sequence `{s}`"));
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_map() {
        let n = parse("a: 1\nb: hello\n").unwrap();
        assert_eq!(n.get("a").unwrap().as_str(), Some("1"));
        assert_eq!(n.get("b").unwrap().as_str(), Some("hello"));
    }

    #[test]
    fn nested_map() {
        let n = parse("outer:\n  inner:\n    x: 3\n  y: 4\nz: 5\n").unwrap();
        let outer = n.get("outer").unwrap();
        assert_eq!(outer.get("inner").unwrap().get("x").unwrap().as_str(), Some("3"));
        assert_eq!(outer.get("y").unwrap().as_str(), Some("4"));
        assert_eq!(n.get("z").unwrap().as_str(), Some("5"));
    }

    #[test]
    fn block_scalar_preserves_lines() {
        let src = "inputs: |\n  n : q?[j?-1][i?]\n  e : q?[j?][i?+1]\nnext: 1\n";
        let n = parse(src).unwrap();
        let block = n.get("inputs").unwrap().as_str().unwrap();
        assert_eq!(block, "n : q?[j?-1][i?]\ne : q?[j?][i?+1]\n");
        assert_eq!(n.get("next").unwrap().as_str(), Some("1"));
    }

    #[test]
    fn flow_seq() {
        let n = parse("order: [k, j, i]\n").unwrap();
        let seq = n.get("order").unwrap().as_seq().unwrap();
        let vals: Vec<_> = seq.iter().map(|x| x.as_str().unwrap()).collect();
        assert_eq!(vals, vec!["k", "j", "i"]);
    }

    #[test]
    fn block_seq() {
        let n = parse("items:\n  - one\n  - two\n").unwrap();
        let seq = n.get("items").unwrap().as_seq().unwrap();
        assert_eq!(seq.len(), 2);
        assert_eq!(seq[0].as_str(), Some("one"));
    }

    #[test]
    fn comments_stripped() {
        let n = parse("# header\na: 1 # trailing\nb: 2\n").unwrap();
        assert_eq!(n.get("a").unwrap().as_str(), Some("1"));
    }

    #[test]
    fn comment_inside_block_scalar_kept() {
        let src = "body: |\n  x # not a comment? actually stripped by line pass\nz: 1\n";
        // Block scalars are recovered from raw source, so `#` survives.
        let n = parse(src).unwrap();
        assert!(n.get("body").unwrap().as_str().unwrap().contains('#'));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn laplace_deck_shape() {
        let src = r#"
kernels:
  laplace:
    declaration: laplace5(float n, float e, float s, float w, float c, float &o);
    inputs: |
      n : q?[j?-1][i?]
      e : q?[j?][i?+1]
    outputs: |
      o : laplace(q?[j?][i?])
globals:
  inputs: |
    float g_cell[j?][i?] => cell[j?][i?]
  outputs: |
    laplace(cell[j][i]) => float g_cell[j][i]
"#;
        let n = parse(src).unwrap();
        let k = n.get("kernels").unwrap().get("laplace").unwrap();
        assert!(k.get("declaration").unwrap().as_str().unwrap().starts_with("laplace5"));
        assert!(k.get("inputs").unwrap().as_str().unwrap().contains("q?[j?-1][i?]"));
        assert!(n.get("globals").unwrap().get("outputs").is_some());
    }

    #[test]
    fn empty_doc() {
        assert_eq!(parse("").unwrap(), Node::Map(vec![]));
        assert_eq!(parse("\n\n# only comments\n").unwrap(), Node::Map(vec![]));
    }

    #[test]
    fn quoted_values() {
        let n = parse("a: \"x: y\"\n").unwrap();
        assert_eq!(n.get("a").unwrap().as_str(), Some("x: y"));
    }
}
