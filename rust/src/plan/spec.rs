//! `PlanSpec`: the single source of truth for *what gets compiled*.
//!
//! A spec names a deck (builtin app, external deck file, or inline
//! source), a paper [`Variant`], and the tuning knobs (vector length,
//! §5.3 tuning, input rolling). Everything downstream is derived from it:
//!
//! * [`PlanSpec::compile_options`] — the exact [`CompileOptions`] the
//!   pipeline runs under; no caller assembles options by hand.
//! * [`PlanSpec::fingerprint`] / [`PlanSpec::plan_key`] — the canonical
//!   plan-cache identity. Because the options are *derived from* the
//!   spec, fingerprinting the spec's fields covers every semantically
//!   relevant option by construction: there is no way to express a
//!   compile knob that escapes the fingerprint.
//! * [`PlanSpec::compile`] — deck resolution + the full pipeline.
//!
//! ```no_run
//! use hfav::apps::Variant;
//! use hfav::plan::{PlanSpec, Vlen};
//! let spec = PlanSpec::app("hydro2d").variant(Variant::Hfav).vlen(Vlen::Auto);
//! let prog = spec.compile().unwrap();
//! # let _ = prog;
//! ```

use crate::analysis::VecDim;
use crate::apps::Variant;
use crate::fusion::FusionOptions;
use crate::plan::cache::{Fnv64, PlanKey};
use crate::plan::{compile_src, CompileOptions, Program};
use std::borrow::Cow;
use std::path::Path;

/// Vector-length request for a spec. Parses from the CLI / trace spelling
/// (`deck` or `-` = deck default, `auto` = host SIMD width, `N` = forced).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vlen {
    /// Use the deck's declared `vector_len` (default).
    Deck,
    /// Runtime-detect the host's SIMD width ([`crate::analysis::auto_vector_len`]).
    Auto,
    /// Force `n` lanes (`Fixed(1)` forces scalar codegen).
    Fixed(usize),
}

impl Vlen {
    /// Resolve to the `Option` override the analysis layer takes. `Auto`
    /// resolves immediately (host detection is stable within a process),
    /// so fingerprints are always concrete.
    pub fn resolve(self) -> Option<usize> {
        match self {
            Vlen::Deck => None,
            Vlen::Auto => Some(crate::analysis::auto_vector_len()),
            Vlen::Fixed(n) => Some(n),
        }
    }
}

impl std::str::FromStr for Vlen {
    type Err = String;
    fn from_str(s: &str) -> Result<Vlen, String> {
        match s {
            "auto" => Ok(Vlen::Auto),
            "-" | "deck" => Ok(Vlen::Deck),
            v => {
                let n: usize = v.parse().map_err(|e| format!("vlen: {e}"))?;
                if n == 0 {
                    return Err("vlen must be >= 1 (1 = forced scalar)".to_string());
                }
                Ok(Vlen::Fixed(n))
            }
        }
    }
}

/// Where a spec's deck text comes from.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
enum Source {
    /// Built-in app name (resolved through [`crate::apps::deck_of`] at
    /// compile time, so unknown names fail at compile, not construction).
    App(String),
    /// External deck file; the text is captured eagerly at construction
    /// so the fingerprint covers the *content*, not just the path.
    File { path: String, src: String },
    /// Inline deck source (tests, generated decks).
    Inline { src: String },
}

/// A buildable description of one compiled plan. See the module docs.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanSpec {
    source: Source,
    variant: Variant,
    /// Resolved vector-length override (`None` = deck default).
    vlen: Option<usize>,
    /// Paper §5.3 "HFAV + Tuning": keep innermost windows full rows.
    tuned: bool,
    /// Roll *all* terminal inputs through buffers (§5.3 in-place variant).
    roll_all_inputs: bool,
    /// Which loop dim vector lanes run along (`Inner` default;
    /// `Outer(dim)` requires a k-independent outer loop; `Auto` picks).
    vec_dim: VecDim,
    /// Aligned-load specialization: aligned intermediate allocations +
    /// aligned strip heads (scalar head peel), unaligned general case.
    aligned: bool,
    /// Multi-dim lane tiling: outer-dim lanes × inner strips together
    /// (`vlen × vlen` tiles). Needs a k-independent outer dim.
    tiled: bool,
    /// Temporal blocking depth: run this many sweep passes per
    /// cache-resident block of the outer dim (1 = off). Gated by
    /// `analysis::time_tileable`; illegal decks fall back untiled.
    time_tile: usize,
}

impl PlanSpec {
    fn new(source: Source) -> PlanSpec {
        PlanSpec {
            source,
            variant: Variant::Hfav,
            vlen: None,
            tuned: false,
            roll_all_inputs: false,
            vec_dim: VecDim::Inner,
            aligned: false,
            tiled: false,
            time_tile: 1,
        }
    }

    /// Spec for a built-in app (`laplace` | `normalize` | `cosmo` |
    /// `hydro2d`). Unknown names are accepted here and fail at
    /// [`compile`](Self::compile) with the `unknown app` error, so jobs
    /// for bad app names surface as per-job failures, not panics.
    pub fn app(name: &str) -> PlanSpec {
        PlanSpec::new(Source::App(name.to_string()))
    }

    /// Spec for an external deck file. The file is read eagerly — a
    /// missing or unreadable deck fails here (fail fast), and the
    /// fingerprint covers the file *content*, so editing the deck yields
    /// a fresh plan-cache entry.
    pub fn deck_file(path: impl AsRef<Path>) -> Result<PlanSpec, String> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading deck `{}`: {e}", path.display()))?;
        Ok(PlanSpec::new(Source::File { path: path.display().to_string(), src }))
    }

    /// Spec for inline deck source text.
    pub fn deck_src(src: impl Into<String>) -> PlanSpec {
        PlanSpec::new(Source::Inline { src: src.into() })
    }

    /// Select the program shape (default [`Variant::Hfav`]).
    pub fn variant(mut self, v: Variant) -> PlanSpec {
        self.variant = v;
        self
    }

    /// Request a vector length (default [`Vlen::Deck`]).
    pub fn vlen(mut self, v: Vlen) -> PlanSpec {
        self.vlen = v.resolve();
        self
    }

    /// Set the already-resolved vector-length override directly (the
    /// plumbing form used by trace parsing and CLI overrides).
    pub fn vlen_resolved(mut self, vlen: Option<usize>) -> PlanSpec {
        self.vlen = vlen;
        self
    }

    /// Paper §5.3 "HFAV + Tuning": full fusion, but innermost-dim windows
    /// stay full rows so the steady state auto-vectorizes.
    pub fn tuned(mut self, on: bool) -> PlanSpec {
        self.tuned = on;
        self
    }

    /// Roll all terminal inputs through buffers (§5.3 in-place variant).
    pub fn roll_all_inputs(mut self, on: bool) -> PlanSpec {
        self.roll_all_inputs = on;
        self
    }

    /// Which loop dim vector lanes run along (default [`VecDim::Inner`]).
    /// `Outer(dim)` fails at [`compile`](Self::compile) when no fused
    /// nest has `dim` as a k-independent outer loop
    /// ([`crate::analysis::outer_vectorizable`]); `Auto` resolves to the
    /// outermost legal outer dim, else `Inner`.
    pub fn vec_dim(mut self, v: VecDim) -> PlanSpec {
        self.vec_dim = v;
        self
    }

    /// Aligned-load specialization (no effect at vector length 1): the
    /// C backend allocates intermediates 64-byte aligned and both
    /// backends peel a scalar head so strips start at multiples of the
    /// vector length; the unaligned shape stays the general case.
    pub fn aligned(mut self, on: bool) -> PlanSpec {
        self.aligned = on;
        self
    }

    /// Multi-dim lane tiling (no effect at vector length 1): strip-mine
    /// a k-independent outer dim *and* lane-fission the innermost loop,
    /// so the steady state runs `vlen × vlen` iteration tiles per
    /// kernel. With the default `vec_dim` the outer dim is auto-resolved
    /// (like [`VecDim::Auto`]); compilation fails when the deck has no
    /// legal outer dim — a tile request never silently degrades.
    pub fn tiled(mut self, on: bool) -> PlanSpec {
        self.tiled = on;
        self
    }

    /// Temporal blocking: run `t` sweep passes per cache-resident block
    /// of the outer dim before moving to the next block (1 = off, the
    /// default). Legality is decided by `analysis::time_tileable`
    /// during lowering: decks whose step dependence is not a bounded
    /// halo (outer reductions, aliased in-place steps) compile to the
    /// ordinary untiled schedule — the knob never changes results,
    /// only the walk order, and the coordinator divides the step count
    /// by the *effective* depth ([`Program::time_tile`]).
    pub fn time_tile(mut self, t: usize) -> PlanSpec {
        self.time_tile = t.max(1);
        self
    }

    // -- accessors ----------------------------------------------------------

    /// Built-in app name, if this spec targets one.
    pub fn app_name(&self) -> Option<&str> {
        match &self.source {
            Source::App(n) => Some(n),
            _ => None,
        }
    }

    /// Human-readable target: app name, deck file path, or `<inline>`.
    pub fn target(&self) -> &str {
        match &self.source {
            Source::App(n) => n,
            Source::File { path, .. } => path,
            Source::Inline { .. } => "<inline>",
        }
    }

    pub fn variant_kind(&self) -> Variant {
        self.variant
    }

    /// Resolved vector-length override (`None` = deck default).
    pub fn vlen_override(&self) -> Option<usize> {
        self.vlen
    }

    pub fn is_tuned(&self) -> bool {
        self.tuned
    }

    /// The requested vectorization dim (as built — `Auto` not yet
    /// resolved; resolution happens at compile).
    pub fn vec_dim_kind(&self) -> &VecDim {
        &self.vec_dim
    }

    pub fn is_aligned(&self) -> bool {
        self.aligned
    }

    pub fn is_tiled(&self) -> bool {
        self.tiled
    }

    /// Requested temporal-blocking depth (1 = off).
    pub fn time_tile_depth(&self) -> usize {
        self.time_tile
    }

    /// Variant label used in plan keys and traces (`hfav`, `autovec`,
    /// `hfav+tuned`, ...).
    pub fn variant_label(&self) -> String {
        if self.tuned {
            format!("{}+tuned", self.variant.label())
        } else {
            self.variant.label().to_string()
        }
    }

    // -- derivations --------------------------------------------------------

    /// The deck source this spec compiles.
    pub fn deck_source(&self) -> Result<Cow<'_, str>, String> {
        match &self.source {
            Source::App(n) => crate::apps::deck_of(n).map(Cow::Borrowed),
            Source::File { src, .. } | Source::Inline { src } => Ok(Cow::Borrowed(src)),
        }
    }

    /// The exact [`CompileOptions`] this spec compiles under — the only
    /// place in the tree that maps spec knobs to pipeline options.
    pub fn compile_options(&self) -> CompileOptions {
        let mut opts = match self.variant {
            Variant::Hfav => CompileOptions::default(),
            Variant::Autovec => CompileOptions {
                fusion: FusionOptions { enabled: false },
                analysis: crate::analysis::AnalysisOptions {
                    contraction: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        };
        if self.tuned {
            opts.analysis.contract_innermost = false;
        }
        opts.analysis.vector_len = self.vlen;
        opts.analysis.vec_dim = self.vec_dim.clone();
        opts.analysis.tile = self.tiled;
        opts.analysis.time_tile = self.time_tile;
        opts.roll_all_inputs = self.roll_all_inputs;
        opts.aligned = self.aligned;
        opts
    }

    /// Canonical fingerprint: a deterministic FNV-1a over every field
    /// that influences compilation (deck identity *and content* for
    /// file/inline sources, variant, tuning, vector length, rolling).
    /// [`plan_key`](Self::plan_key) is derived from this, so the cache
    /// can never conflate two differently-configured compiles.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        match &self.source {
            Source::App(n) => {
                h.write_str("app");
                h.write_str(n);
            }
            Source::File { path, src } => {
                h.write_str("file");
                h.write_str(path);
                h.write_str(src);
            }
            Source::Inline { src } => {
                h.write_str("src");
                h.write_str(src);
            }
        }
        h.write_str(self.variant.label());
        h.write_bool(self.tuned);
        h.write_bool(self.roll_all_inputs);
        // `None` (deck default) must not collide with any forced value.
        h.write_bool(self.vlen.is_some());
        h.write_u64(self.vlen.unwrap_or(0) as u64);
        // Vectorization strategy knobs. `Auto` is fingerprinted as-is:
        // its resolution depends only on the deck, which the fingerprint
        // already covers, so equal fingerprints resolve identically.
        h.write_str(&self.vec_dim.to_string());
        h.write_bool(self.aligned);
        h.write_bool(self.tiled);
        h.write_u64(self.time_tile as u64);
        h.finish()
    }

    /// The plan-cache key this spec compiles under.
    pub fn plan_key(&self) -> PlanKey {
        PlanKey {
            app: self.target().to_string(),
            variant: self.variant_label(),
            fingerprint: self.fingerprint(),
        }
    }

    /// Resolve the deck and run the full pipeline.
    pub fn compile(&self) -> Result<Program, String> {
        compile_src(&self.deck_source()?, self.compile_options())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_accessors() {
        let s = PlanSpec::app("laplace");
        assert_eq!(s.app_name(), Some("laplace"));
        assert_eq!(s.target(), "laplace");
        assert_eq!(s.variant_kind(), Variant::Hfav);
        assert_eq!(s.vlen_override(), None);
        assert!(!s.is_tuned());
        assert_eq!(s.variant_label(), "hfav");
        assert_eq!(s.clone().tuned(true).variant_label(), "hfav+tuned");
    }

    #[test]
    fn fingerprint_is_stable_and_distinct() {
        let base = PlanSpec::app("laplace");
        assert_eq!(base.fingerprint(), PlanSpec::app("laplace").fingerprint());
        let knobs = [
            base.clone().variant(Variant::Autovec),
            base.clone().vlen(Vlen::Fixed(1)),
            base.clone().vlen(Vlen::Fixed(4)),
            base.clone().vlen(Vlen::Fixed(8)),
            base.clone().tuned(true),
            base.clone().roll_all_inputs(true),
            base.clone().vec_dim(VecDim::Auto),
            base.clone().vec_dim(VecDim::Outer("j".to_string())),
            base.clone().aligned(true),
            base.clone().tiled(true),
            base.clone().tiled(true).vlen(Vlen::Fixed(4)),
            base.clone().time_tile(2),
            base.clone().time_tile(4),
            PlanSpec::app("normalize"),
            PlanSpec::deck_src("name: laplace\n"),
        ];
        let mut fps = vec![base.fingerprint()];
        for k in &knobs {
            fps.push(k.fingerprint());
        }
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "specs {i} and {j} collide");
            }
        }
    }

    #[test]
    fn options_derived_from_spec() {
        let hfav = PlanSpec::app("laplace").compile_options();
        assert!(hfav.fusion.enabled);
        assert!(hfav.analysis.contraction);
        let auto = PlanSpec::app("laplace").variant(Variant::Autovec).compile_options();
        assert!(!auto.fusion.enabled);
        assert!(!auto.analysis.contraction);
        let tuned = PlanSpec::app("cosmo").tuned(true).compile_options();
        assert!(!tuned.analysis.contract_innermost);
        assert!(tuned.fusion.enabled);
        let v = PlanSpec::app("laplace").vlen(Vlen::Fixed(4)).compile_options();
        assert_eq!(v.analysis.vector_len, Some(4));
        let r = PlanSpec::app("laplace").roll_all_inputs(true).compile_options();
        assert!(r.roll_all_inputs);
        let o = PlanSpec::app("cosmo")
            .vec_dim(VecDim::Outer("k".to_string()))
            .aligned(true)
            .compile_options();
        assert_eq!(o.analysis.vec_dim, VecDim::Outer("k".to_string()));
        assert!(o.aligned);
        assert_eq!(PlanSpec::app("cosmo").compile_options().analysis.vec_dim, VecDim::Inner);
        let t = PlanSpec::app("cosmo").vlen(Vlen::Fixed(4)).tiled(true).compile_options();
        assert!(t.analysis.tile);
        assert!(!PlanSpec::app("cosmo").compile_options().analysis.tile);
        let tt = PlanSpec::app("cosmo").time_tile(4).compile_options();
        assert_eq!(tt.analysis.time_tile, 4);
        assert_eq!(PlanSpec::app("cosmo").compile_options().analysis.time_tile, 1);
        // 0 clamps to 1 (off) and is fingerprint-identical to the default.
        let z = PlanSpec::app("cosmo").time_tile(0);
        assert_eq!(z.time_tile_depth(), 1);
        assert_eq!(z.fingerprint(), PlanSpec::app("cosmo").fingerprint());
    }

    #[test]
    fn time_tile_applies_or_falls_back_at_compile() {
        // chain1d's step dependence is a bounded halo: the knob takes.
        let prog = PlanSpec::deck_src(crate::frontend::testdecks::CHAIN1D)
            .time_tile(4)
            .compile()
            .unwrap();
        assert_eq!(prog.time_tile(), 4);
        // Cross-step aliasing (in-place decks) falls back untiled — same
        // results, ordinary walk — rather than erroring.
        let aliased = format!(
            "{}aliases:\n  - [g_u, g_d]\n",
            crate::frontend::testdecks::CHAIN1D
        );
        let inplace = PlanSpec::deck_src(aliased).time_tile(4).compile().unwrap();
        assert_eq!(inplace.time_tile(), 1);
    }

    #[test]
    fn tiled_resolves_or_fails_at_compile() {
        // cosmo: tile auto-resolves the outer dim (k) and the compiled
        // program reports itself tiled.
        let prog = PlanSpec::app("cosmo").vlen(Vlen::Fixed(4)).tiled(true).compile().unwrap();
        assert!(prog.tiled());
        assert_eq!(prog.outer_lane_dim(), Some("k"));
        // A 1-D deck has no outer dim: the tile request is a hard error.
        let e = PlanSpec::deck_src(crate::frontend::testdecks::CHAIN1D)
            .vlen(Vlen::Fixed(4))
            .tiled(true)
            .compile()
            .unwrap_err();
        assert!(e.contains("tile"), "{e}");
        // At vector length 1 tiling degrades to scalar, like every other
        // vectorization knob.
        let scalar =
            PlanSpec::app("cosmo").vlen(Vlen::Fixed(1)).tiled(true).compile().unwrap();
        assert!(!scalar.tiled());
    }

    #[test]
    fn unknown_app_fails_at_compile() {
        let e = PlanSpec::app("nope").compile().unwrap_err();
        assert!(e.contains("unknown app"), "{e}");
    }

    #[test]
    fn missing_deck_file_fails_fast() {
        let e = PlanSpec::deck_file("/no/such/deck.yaml").unwrap_err();
        assert!(e.contains("reading deck"), "{e}");
    }

    #[test]
    fn vlen_parsing() {
        assert_eq!("auto".parse::<Vlen>().unwrap(), Vlen::Auto);
        assert_eq!("deck".parse::<Vlen>().unwrap(), Vlen::Deck);
        assert_eq!("-".parse::<Vlen>().unwrap(), Vlen::Deck);
        assert_eq!("4".parse::<Vlen>().unwrap(), Vlen::Fixed(4));
        assert!("0".parse::<Vlen>().unwrap_err().contains(">= 1"));
        assert!("x".parse::<Vlen>().is_err());
        assert_eq!(Vlen::Deck.resolve(), None);
        assert_eq!(Vlen::Fixed(8).resolve(), Some(8));
        assert!(Vlen::Auto.resolve().unwrap_or(0) >= 1);
    }

    #[test]
    fn plan_key_derivation() {
        let s = PlanSpec::app("laplace").vlen(Vlen::Fixed(4));
        let k = s.plan_key();
        assert_eq!(k.app, "laplace");
        assert_eq!(k.variant, "hfav");
        assert_eq!(k.fingerprint, s.fingerprint());
    }

    #[test]
    fn compiles_builtin_decks() {
        for app in crate::apps::APP_NAMES {
            for v in [Variant::Hfav, Variant::Autovec] {
                let prog = PlanSpec::app(app).variant(v).compile().unwrap();
                assert!(!prog.fd.nests.is_empty(), "{app} {v:?}");
            }
        }
    }
}
