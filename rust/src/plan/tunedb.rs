//! The tuned-plans database: persisted winners of the `hfav tune`
//! empirical search, consulted by serving when a job says
//! `variant=tuned`.
//!
//! Entries are keyed by **(deck digest, shape class)**:
//!
//! * the deck digest ([`deck_digest`]) hashes the
//!   deck *content*, so a built-in app and an external deck file with
//!   identical text share tuning, and editing a deck invalidates its
//!   entries;
//! * the [`ShapeClass`] buckets concrete extents by dimensionality,
//!   magnitude (nearest power of two of the total cell count) and
//!   squareness — one tuning run generalizes to nearby shapes instead
//!   of demanding an exact-extent match.
//!
//! The DB is a JSON file beside the plan cache's other on-disk artifacts
//! (default [`DEFAULT_DB_PATH`]), written with [`crate::json::escape`]
//! and read back with [`crate::json::parse`] — hostile deck paths
//! round-trip. Lookups resolve to a concrete knob set
//! ([`TunedEntry::apply`]) **outside** `PlanKey` construction: the
//! resolved [`PlanSpec`] fingerprints like any hand-written spec, so one
//! tuned entry maps onto the existing compiled-plan cache and a miss
//! falls back to the heuristic `+tuned` options without error.

use crate::json::{self, Value};
use crate::plan::cache::Fnv64;
use crate::plan::PlanSpec;
use std::fmt::Write as _;
use std::path::Path;

/// Default on-disk location of the tuned-plans DB (CLI `--db` overrides).
pub const DEFAULT_DB_PATH: &str = "tuned_plans.json";

/// Schema tag of the DB file.
pub const TUNED_SCHEMA: &str = "hfav-tuned-plans/v1";

/// Shape bucket of a concrete extents vector. Two shapes in the same
/// class are served by the same tuned entry.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// Number of extents (grid dimensionality).
    pub dims: usize,
    /// `log2(total cells)` rounded to the nearest integer.
    pub magnitude: u32,
    /// All extents within 2x of each other.
    pub square: bool,
}

impl ShapeClass {
    /// Classify a concrete extents vector. Empty or degenerate extents
    /// clamp to 1, so classification never fails.
    pub fn of(extents: &[i64]) -> ShapeClass {
        let vals: Vec<i64> = extents.iter().map(|&v| v.max(1)).collect();
        let cells: f64 = vals.iter().map(|&v| v as f64).product::<f64>().max(1.0);
        let magnitude = cells.log2().round().max(0.0) as u32;
        let (min, max) = vals.iter().fold((i64::MAX, 1i64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        ShapeClass {
            dims: vals.len().max(1),
            magnitude,
            square: !vals.is_empty() && max <= 2 * min.max(1),
        }
    }

    /// Stable label used as the persisted key (`d3/m15/square`).
    pub fn label(&self) -> String {
        format!(
            "d{}/m{}/{}",
            self.dims,
            self.magnitude,
            if self.square { "square" } else { "rect" }
        )
    }
}

impl std::fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// One persisted tuning winner: the knob set plus its measurement
/// provenance (throughput, how many candidates were enumerated/timed,
/// timing reps of the winner).
#[derive(Debug, Clone, PartialEq)]
pub struct TunedEntry {
    /// [`deck_digest`] of the deck the entry was tuned on.
    pub deck_digest: u64,
    /// Human-readable target label (app name or deck path) — display
    /// only, never part of the lookup key.
    pub target: String,
    /// [`ShapeClass::label`] the entry covers.
    pub shape_class: String,
    /// The concrete extents the tuner actually timed (`32x32x32`).
    pub extents: String,
    /// Winning knob set.
    pub tuned: bool,
    pub vec_dim: String,
    pub vlen: usize,
    pub aligned: bool,
    pub tiled: bool,
    /// Winning temporal-blocking depth (1 = off). Optional in the
    /// persisted record: pre-knob DBs decode as 1, so old tunings keep
    /// resolving (never silently dropped by a schema addition).
    pub time_tile: usize,
    /// Winning runtime worker count (1 = serial).
    pub threads: usize,
    /// Measured throughput of the winner at tune time.
    pub mcells_per_s: f64,
    /// Legal candidates enumerated / candidates actually timed.
    pub candidates: usize,
    pub timed: usize,
    /// Timing reps the winner's median came from.
    pub reps: usize,
    /// Where the cost model ranked the measured winner among the legal
    /// candidates (1 = the model's top pick) — calibration provenance
    /// for `hfav tune --report`. Optional: older records carry none.
    pub predicted_rank: Option<usize>,
}

impl TunedEntry {
    /// Apply the recorded knob set to a base spec (the deck/variant
    /// identity is the caller's; this overwrites only the vectorization
    /// and §5.3 tuning knobs). The result fingerprints like any
    /// hand-written spec — resolution stays outside `PlanKey`.
    pub fn apply(&self, base: PlanSpec) -> Result<PlanSpec, String> {
        let vec_dim: crate::analysis::VecDim =
            self.vec_dim.parse().map_err(|e| format!("tuned entry vec_dim: {e}"))?;
        Ok(base
            .tuned(self.tuned)
            .vlen_resolved(Some(self.vlen.max(1)))
            .vec_dim(vec_dim)
            .aligned(self.aligned)
            .tiled(self.tiled)
            .time_tile(self.time_tile.max(1)))
    }

    /// One-line human-readable knob set (serve reports, tune output).
    pub fn knob_label(&self) -> String {
        format!(
            "vec_dim={} vlen={} aligned={} tiled={} time_tile={} tuned={} threads={}",
            self.vec_dim,
            self.vlen,
            self.aligned,
            self.tiled,
            self.time_tile,
            self.tuned,
            self.threads
        )
    }
}

/// The tuned-plans database: a flat entry list with (digest, class)
/// replace-on-insert semantics, persisted as versioned JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TunedDb {
    pub entries: Vec<TunedEntry>,
}

/// Decode one DB record. Any missing or mistyped field is an `Err` —
/// [`TunedDb::parse`] turns that into "skip this record".
fn decode_entry(e: &Value) -> Result<TunedEntry, String> {
    let err = |what: &str| format!("bad or missing `{what}`");
    let s = |k: &str| e.get(k).and_then(Value::as_str).map(str::to_string).ok_or_else(|| err(k));
    let n = |k: &str| e.get(k).and_then(Value::as_f64).ok_or_else(|| err(k));
    let b = |k: &str| e.get(k).and_then(Value::as_bool).ok_or_else(|| err(k));
    let digest_hex = s("deck_digest")?;
    let deck_digest = u64::from_str_radix(&digest_hex, 16)
        .map_err(|e| format!("bad deck_digest `{digest_hex}`: {e}"))?;
    Ok(TunedEntry {
        deck_digest,
        target: s("target")?,
        shape_class: s("shape_class")?,
        extents: s("extents")?,
        tuned: b("tuned")?,
        vec_dim: s("vec_dim")?,
        vlen: n("vlen")? as usize,
        aligned: b("aligned")?,
        tiled: b("tiled")?,
        // Optional: absent in pre-time-tiling records, which must keep
        // decoding (a required field here would drop every old tuning).
        time_tile: e
            .get("time_tile")
            .and_then(Value::as_f64)
            .map(|v| (v as usize).max(1))
            .unwrap_or(1),
        threads: n("threads")? as usize,
        mcells_per_s: n("mcells_per_s")?,
        candidates: n("candidates")? as usize,
        timed: n("timed")? as usize,
        reps: n("reps")? as usize,
        predicted_rank: e.get("predicted_rank").and_then(Value::as_f64).map(|v| v as usize),
    })
}

impl TunedDb {
    /// Load from `path`. A missing file is an empty DB (tuning is
    /// always optional); a present-but-malformed file is an error, so a
    /// corrupted DB never silently drops tunings.
    pub fn load(path: impl AsRef<Path>) -> Result<TunedDb, String> {
        let path = path.as_ref();
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(TunedDb::default())
            }
            Err(e) => return Err(format!("reading tuned DB `{}`: {e}", path.display())),
        };
        TunedDb::parse(&text).map_err(|e| format!("tuned DB `{}`: {e}", path.display()))
    }

    /// Parse the JSON document [`TunedDb::render`] writes.
    ///
    /// Forward compatibility: the top-level document must be this
    /// schema's (a damaged file never silently drops tunings), but a
    /// *record* that fails to decode — missing or mistyped fields
    /// written by some other version — is skipped rather than failing
    /// the whole DB, and unknown extra keys on a record are ignored by
    /// construction (lookup-by-key decoding). Future versions can add
    /// provenance keys without breaking older readers.
    pub fn parse(text: &str) -> Result<TunedDb, String> {
        let doc = json::parse(text)?;
        let schema = doc.get("schema").and_then(Value::as_str).unwrap_or("?");
        if schema != TUNED_SCHEMA {
            return Err(format!("schema `{schema}` (want `{TUNED_SCHEMA}`)"));
        }
        let raw = doc.get("entries").and_then(Value::as_arr).ok_or("missing `entries` array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            if let Ok(entry) = decode_entry(e) {
                entries.push(entry);
            }
        }
        Ok(TunedDb { entries })
    }

    /// Render the versioned JSON document (deterministic: ordered keys,
    /// fixed float precision — identical DBs produce identical bytes).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"schema\": \"{TUNED_SCHEMA}\",");
        let _ = writeln!(out, "  \"entries\": [");
        for (k, e) in self.entries.iter().enumerate() {
            let comma = if k + 1 < self.entries.len() { "," } else { "" };
            let rate = if e.mcells_per_s.is_finite() { e.mcells_per_s } else { 0.0 };
            let rank = e
                .predicted_rank
                .map(|r| format!(", \"predicted_rank\": {r}"))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "    {{ \"deck_digest\": \"{:016x}\", \"target\": \"{}\", \
                 \"shape_class\": \"{}\", \"extents\": \"{}\", \"tuned\": {}, \
                 \"vec_dim\": \"{}\", \"vlen\": {}, \"aligned\": {}, \"tiled\": {}, \
                 \"time_tile\": {}, \"threads\": {}, \"mcells_per_s\": {:.3}, \
                 \"candidates\": {}, \"timed\": {}, \"reps\": {}{rank} }}{comma}",
                e.deck_digest,
                json::escape(&e.target),
                json::escape(&e.shape_class),
                json::escape(&e.extents),
                e.tuned,
                json::escape(&e.vec_dim),
                e.vlen,
                e.aligned,
                e.tiled,
                e.time_tile,
                e.threads,
                rate,
                e.candidates,
                e.timed,
                e.reps
            );
        }
        let _ = writeln!(out, "  ]");
        let _ = writeln!(out, "}}");
        out
    }

    /// Write the DB to `path` (whole-file rewrite; the DB is small).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        std::fs::write(path, self.render())
            .map_err(|e| format!("writing tuned DB `{}`: {e}", path.display()))
    }

    /// Insert `entry`, replacing any existing entry with the same
    /// (deck digest, shape class) key.
    pub fn insert(&mut self, entry: TunedEntry) {
        self.entries
            .retain(|e| e.deck_digest != entry.deck_digest || e.shape_class != entry.shape_class);
        self.entries.push(entry);
    }

    /// Look up the entry for (deck digest, shape-class label).
    pub fn lookup(&self, deck_digest: u64, class: &str) -> Option<&TunedEntry> {
        self.entries.iter().find(|e| e.deck_digest == deck_digest && e.shape_class == class)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Content digest of a spec's deck source (knob-independent — two specs
/// over the same deck text share tuning entries regardless of variant
/// or vectorization knobs). Defined here rather than on [`PlanSpec`]
/// itself to keep the spec module free of tuning concerns.
pub fn deck_digest(spec: &PlanSpec) -> Result<u64, String> {
    let mut h = Fnv64::new();
    h.write_str(&spec.deck_source()?);
    Ok(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(digest: u64, class: &str) -> TunedEntry {
        TunedEntry {
            deck_digest: digest,
            target: "cosmo".to_string(),
            shape_class: class.to_string(),
            extents: "32x32x32".to_string(),
            tuned: true,
            vec_dim: "outer:k".to_string(),
            vlen: 8,
            aligned: true,
            tiled: false,
            time_tile: 2,
            threads: 2,
            mcells_per_s: 123.456,
            candidates: 18,
            timed: 4,
            reps: 37,
            predicted_rank: None,
        }
    }

    #[test]
    fn shape_class_buckets_by_magnitude_and_squareness() {
        let a = ShapeClass::of(&[32, 32, 32]);
        assert_eq!(a.label(), "d3/m15/square");
        // Nearby shapes land in the same bucket...
        assert_eq!(ShapeClass::of(&[30, 31, 33]), a);
        assert_eq!(ShapeClass::of(&[32, 28, 36]), a);
        // ...a much bigger grid does not...
        assert_ne!(ShapeClass::of(&[128, 128, 128]), a);
        // ...and skew moves the squareness half of the key.
        let skew = ShapeClass::of(&[512, 8, 8]);
        assert!(!skew.square);
        assert_ne!(skew, ShapeClass::of(&[32, 32, 32]));
        // Dimensionality is part of the class.
        assert_ne!(ShapeClass::of(&[64, 64]).label(), ShapeClass::of(&[64, 64, 1]).label());
        // 2x aspect still counts as square; beyond does not.
        assert!(ShapeClass::of(&[64, 32]).square);
        assert!(!ShapeClass::of(&[65, 32]).square);
        // Degenerate inputs clamp instead of panicking.
        assert_eq!(ShapeClass::of(&[]).dims, 1);
        assert_eq!(ShapeClass::of(&[0, -3]).magnitude, 0);
    }

    #[test]
    fn db_round_trips_through_json() {
        let mut db = TunedDb::default();
        db.insert(entry(0xdead_beef_0123_4567, "d3/m15/square"));
        let mut hostile = entry(7, "d2/m10/rect");
        hostile.target = "decks/my \"deck\"\\with\nnewline.yaml".to_string();
        db.insert(hostile);
        let text = db.render();
        // The writer's output is valid JSON by our own parser...
        crate::json::parse(&text).unwrap();
        // ...and loads back to an identical DB.
        let back = TunedDb::parse(&text).unwrap();
        assert_eq!(back, db);
        // Render is deterministic.
        assert_eq!(text, back.render());
    }

    #[test]
    fn db_load_save_round_trip_on_disk() {
        let dir = std::env::temp_dir().join(format!("hfav-tunedb-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tuned_plans.json");
        let mut db = TunedDb::default();
        db.insert(entry(42, "d3/m12/square"));
        db.save(&path).unwrap();
        assert_eq!(TunedDb::load(&path).unwrap(), db);
        // Missing file = empty DB; malformed file = hard error.
        assert!(TunedDb::load(dir.join("nope.json")).unwrap().is_empty());
        std::fs::write(dir.join("bad.json"), "{ not json").unwrap();
        assert!(TunedDb::load(dir.join("bad.json")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_skips_undecodable_records_for_forward_compat() {
        let mut db = TunedDb::default();
        db.insert(entry(1, "d3/m15/square"));
        let text = db.render();
        // A record with only future/unknown fields is skipped, not fatal.
        let spliced = text.replace(
            "  \"entries\": [",
            "  \"entries\": [\n    { \"deck_digest\": \"0000000000000002\", \"provenance\": \"v2\" },",
        );
        assert_ne!(spliced, text, "splice target must match the rendered document");
        let back = TunedDb::parse(&spliced).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.lookup(1, "d3/m15/square"), db.lookup(1, "d3/m15/square"));
        // Unknown extra keys on an otherwise-good record are ignored: the
        // DB round-trips to exactly the known fields.
        let extra = text.replace("\"reps\": 37 }", "\"reps\": 37, \"provenance\": \"v2\" }");
        assert_ne!(extra, text);
        assert_eq!(TunedDb::parse(&extra).unwrap(), db);
        // A mistyped field (string where a number belongs) skips too.
        let mistyped = text.replace("\"vlen\": 8", "\"vlen\": \"eight\"");
        assert_ne!(mistyped, text);
        assert!(TunedDb::parse(&mistyped).unwrap().is_empty());
        // Top-level damage stays a hard error.
        assert!(TunedDb::parse("{ \"schema\": \"nope\", \"entries\": [] }").is_err());
        assert!(TunedDb::parse(&format!("{{ \"schema\": \"{TUNED_SCHEMA}\" }}")).is_err());
    }

    #[test]
    fn pre_time_tile_records_decode_and_apply_cleanly() {
        // A DB written before the time_tile knob existed has records
        // without the field: they must decode (time_tile = 1, no
        // predicted rank) and apply without error — a `variant=tuned`
        // trace against an old DB keeps resolving.
        let mut db = TunedDb::default();
        db.insert(entry(1, "d3/m15/square"));
        let text = db.render();
        let pre_knob = text.replace("\"time_tile\": 2, ", "");
        assert_ne!(pre_knob, text, "strip target must match the rendered document");
        let back = TunedDb::parse(&pre_knob).unwrap();
        assert_eq!(back.len(), 1);
        let e = back.lookup(1, "d3/m15/square").unwrap();
        assert_eq!(e.time_tile, 1);
        assert_eq!(e.predicted_rank, None);
        let spec = e.apply(PlanSpec::app("cosmo")).unwrap();
        assert_eq!(spec.time_tile_depth(), 1);
        // And the pre-knob entry fingerprints exactly like an untiled
        // hand-written spec — the plan cache sees nothing new.
        let hand = e.apply(PlanSpec::app("cosmo")).unwrap();
        assert_eq!(spec.fingerprint(), hand.fingerprint());
        // predicted_rank round-trips when present.
        let mut ranked = entry(2, "d3/m15/square");
        ranked.predicted_rank = Some(3);
        let mut db2 = TunedDb::default();
        db2.insert(ranked.clone());
        let back2 = TunedDb::parse(&db2.render()).unwrap();
        assert_eq!(back2.lookup(2, "d3/m15/square").unwrap().predicted_rank, Some(3));
    }

    #[test]
    fn insert_replaces_same_key_and_lookup_finds_it() {
        let mut db = TunedDb::default();
        db.insert(entry(1, "d3/m15/square"));
        let mut better = entry(1, "d3/m15/square");
        better.vlen = 4;
        better.mcells_per_s = 999.0;
        db.insert(better.clone());
        assert_eq!(db.len(), 1, "same (digest, class) must replace");
        assert_eq!(db.lookup(1, "d3/m15/square"), Some(&better));
        assert_eq!(db.lookup(1, "d3/m9/square"), None);
        assert_eq!(db.lookup(2, "d3/m15/square"), None);
        db.insert(entry(1, "d2/m9/rect"));
        assert_eq!(db.len(), 2, "distinct class is a distinct key");
    }

    #[test]
    fn entry_applies_concrete_knobs() {
        let e = entry(1, "d3/m15/square");
        let spec = e.apply(PlanSpec::app("cosmo")).unwrap();
        assert!(spec.is_tuned());
        assert_eq!(spec.vlen_override(), Some(8));
        assert!(spec.is_aligned());
        assert!(!spec.is_tiled());
        assert_eq!(spec.time_tile_depth(), 2);
        assert_eq!(spec.vec_dim_kind(), &crate::analysis::VecDim::Outer("k".to_string()));
        // The applied spec fingerprints differently from the heuristic
        // fallback — resolution really changes the knob set...
        let fallback = PlanSpec::app("cosmo").tuned(true);
        assert_ne!(spec.fingerprint(), fallback.fingerprint());
        // ...while staying an ordinary spec (same plan-key machinery).
        assert_eq!(spec.plan_key().app, "cosmo");
        // A corrupt vec_dim fails loudly.
        let mut bad = e.clone();
        bad.vec_dim = "sideways".to_string();
        assert!(bad.apply(PlanSpec::app("cosmo")).is_err());
    }

    #[test]
    fn deck_digest_is_content_keyed() {
        let app = deck_digest(&PlanSpec::app("cosmo")).unwrap();
        // Knobs never move the digest...
        let knobbed = PlanSpec::app("cosmo").tuned(true).aligned(true).vlen_resolved(Some(8));
        assert_eq!(deck_digest(&knobbed).unwrap(), app);
        // ...an inline deck with identical content shares it...
        let inline = PlanSpec::deck_src(crate::apps::cosmo::DECK);
        assert_eq!(deck_digest(&inline).unwrap(), app);
        // ...and different decks differ.
        assert_ne!(deck_digest(&PlanSpec::app("laplace")).unwrap(), app);
        // Unknown apps fail like deck resolution does.
        assert!(deck_digest(&PlanSpec::app("nope")).is_err());
    }
}
