//! Shared compiled-plan cache.
//!
//! Deck→schedule compilation (inference, fusion, storage contraction,
//! vectorization analysis) is expensive, but its output — a [`Program`] —
//! is immutable and reusable. This module provides the compile-once /
//! run-many substrate the serving layer is built on:
//!
//! * [`OnceMap`] — a generic sharded concurrent map whose values are
//!   computed exactly once per key, even under racing lookups (other
//!   threads block on the in-flight computation instead of duplicating
//!   it). Hit/miss/compute counters are threaded through [`CacheStats`].
//! * [`PlanKey`] — `(app, variant, fingerprint)`: the identity of a
//!   compiled plan. The fingerprint comes from
//!   [`PlanSpec::fingerprint`](crate::plan::PlanSpec::fingerprint) — the
//!   spec is the *only* source of compile options, so fingerprinting its
//!   fields covers every semantically relevant option by construction.
//! * [`PlanCache`] — an `OnceMap<PlanKey, Program>` with compile helpers;
//!   the coordinator shares one instance across its whole worker pool.

use crate::plan::{PlanSpec, Program};
use std::collections::hash_map::RandomState;
use std::collections::HashMap;
use std::hash::{BuildHasher, Hash};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Fingerprinting
// ---------------------------------------------------------------------------

/// Deterministic FNV-1a 64-bit hasher for option fingerprints. Unlike
/// `DefaultHasher`, the result is stable across processes, so fingerprints
/// can be logged, compared across runs, and used in artifact file names.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf29ce484222325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write(&[v as u8]);
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

// ---------------------------------------------------------------------------
// Cache key
// ---------------------------------------------------------------------------

/// Identity of a compiled plan: which deck (`app` — builtin name or deck
/// file path), which variant label (`hfav` / `autovec` / `hfav+tuned` /
/// ...), and the canonical [`PlanSpec`] fingerprint covering every
/// option that influences the compile. Built by
/// [`PlanSpec::plan_key`](crate::plan::PlanSpec::plan_key).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey {
    pub app: String,
    pub variant: String,
    pub fingerprint: u64,
}

impl PlanKey {
    /// Derive a sibling key with an extra tag folded into the
    /// fingerprint (e.g. a backend name for prepared executables keyed
    /// off the same plan).
    pub fn tagged(&self, tag: &str) -> PlanKey {
        let mut h = Fnv64(self.fingerprint);
        h.write_str(tag);
        PlanKey { app: self.app.clone(), variant: self.variant.clone(), fingerprint: h.finish() }
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}#{:016x}", self.app, self.variant, self.fingerprint)
    }
}

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Atomic hit/miss/compute counters shared by all users of a cache.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub computes: AtomicU64,
    pub compute_ns: AtomicU64,
}

impl CacheStats {
    pub fn snapshot(&self) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            computes: self.computes.load(Ordering::Relaxed),
            compute_time: Duration::from_nanos(self.compute_ns.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time view of a cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStatsSnapshot {
    /// Lookups answered from an already-computed entry.
    pub hits: u64,
    /// Lookups that found no computed entry (includes racers that then
    /// blocked on another thread's in-flight compute).
    pub misses: u64,
    /// Times the compute closure actually ran — for a plan cache this is
    /// the number of pipeline compilations performed.
    pub computes: u64,
    /// Total wall time spent inside the compute closure.
    pub compute_time: Duration,
}

impl CacheStatsSnapshot {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

impl std::fmt::Display for CacheStatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} computes={} hit_rate={:.1}% compute_time={:?}",
            self.hits,
            self.misses,
            self.computes,
            100.0 * self.hit_rate(),
            self.compute_time,
        )
    }
}

// ---------------------------------------------------------------------------
// OnceMap
// ---------------------------------------------------------------------------

type Slot<V> = Arc<OnceLock<Result<Arc<V>, String>>>;

/// Sharded concurrent compute-once map.
///
/// Each key's value is produced by the first caller's closure; concurrent
/// callers for the same key block until that computation finishes and then
/// share the `Arc`'d result. Failed computations are cached too (negative
/// caching), so a deck that fails to compile does not trigger a recompile
/// storm under load.
pub struct OnceMap<K, V> {
    shards: Vec<RwLock<HashMap<K, Slot<V>>>>,
    hasher: RandomState,
    stats: CacheStats,
}

impl<K: Hash + Eq + Clone, V> OnceMap<K, V> {
    pub fn new() -> OnceMap<K, V> {
        OnceMap::with_shards(8)
    }

    pub fn with_shards(n: usize) -> OnceMap<K, V> {
        OnceMap {
            shards: (0..n.max(1)).map(|_| RwLock::new(HashMap::new())).collect(),
            hasher: RandomState::new(),
            stats: CacheStats::default(),
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        (self.hasher.hash_one(key) as usize) % self.shards.len()
    }

    /// Get the value for `key`, computing it with `f` if absent. `f` runs
    /// at most once per key across all threads.
    pub fn get_or_compute<F>(&self, key: &K, f: F) -> Result<Arc<V>, String>
    where
        F: FnOnce() -> Result<V, String>,
    {
        let shard = &self.shards[self.shard_of(key)];
        let slot = {
            let map = shard.read().unwrap();
            map.get(key).cloned()
        };
        let slot = match slot {
            Some(s) => s,
            None => {
                let mut map = shard.write().unwrap();
                map.entry(key.clone()).or_insert_with(|| Arc::new(OnceLock::new())).clone()
            }
        };
        if let Some(done) = slot.get() {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return done.clone();
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        slot.get_or_init(|| {
            let t0 = Instant::now();
            let out = f().map(Arc::new);
            self.stats.compute_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.stats.computes.fetch_add(1, Ordering::Relaxed);
            out
        })
        .clone()
    }

    /// Like [`get_or_compute`](Self::get_or_compute), but a failed
    /// computation is evicted instead of negatively cached, so a later
    /// caller retries. Use for I/O-dependent computations (e.g. invoking
    /// the system C compiler) where a failure may be transient; plan
    /// compilation is deterministic and keeps negative caching.
    pub fn get_or_compute_retrying<F>(&self, key: &K, f: F) -> Result<Arc<V>, String>
    where
        F: FnOnce() -> Result<V, String>,
    {
        let out = self.get_or_compute(key, f);
        if out.is_err() {
            let shard = &self.shards[self.shard_of(key)];
            let mut map = shard.write().unwrap();
            if let Some(slot) = map.get(key) {
                if matches!(slot.get(), Some(Err(_))) {
                    map.remove(key);
                }
            }
        }
        out
    }

    /// Peek without computing.
    pub fn get(&self, key: &K) -> Option<Result<Arc<V>, String>> {
        let shard = &self.shards[self.shard_of(key)];
        let map = shard.read().unwrap();
        map.get(key).and_then(|s| s.get().cloned())
    }

    /// Number of cached entries (computed or in flight).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry. Counters are preserved.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }

    pub fn stats(&self) -> CacheStatsSnapshot {
        self.stats.snapshot()
    }
}

impl<K: Hash + Eq + Clone, V> Default for OnceMap<K, V> {
    fn default() -> Self {
        OnceMap::new()
    }
}

// ---------------------------------------------------------------------------
// PlanCache
// ---------------------------------------------------------------------------

/// The shared compiled-plan cache: `PlanKey -> Arc<Program>`.
#[derive(Default)]
pub struct PlanCache {
    map: OnceMap<PlanKey, Program>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache { map: OnceMap::new() }
    }

    /// Fetch the plan for `key`, compiling with `f` on first use.
    pub fn get_or_compile<F>(&self, key: &PlanKey, f: F) -> Result<Arc<Program>, String>
    where
        F: FnOnce() -> Result<Program, String>,
    {
        self.map.get_or_compute(key, f)
    }

    /// Convenience: compile a [`PlanSpec`], keyed by its canonical
    /// [`PlanKey`].
    pub fn compile_spec(&self, spec: &PlanSpec) -> Result<Arc<Program>, String> {
        self.map.get_or_compute(&spec.plan_key(), || spec.compile())
    }

    pub fn get(&self, key: &PlanKey) -> Option<Result<Arc<Program>, String>> {
        self.map.get(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&self) {
        self.map.clear()
    }

    pub fn stats(&self) -> CacheStatsSnapshot {
        self.map.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Variant;
    use crate::frontend::testdecks;

    #[test]
    fn plan_cache_compiles_once_per_key() {
        let cache = PlanCache::new();
        let spec = PlanSpec::deck_src(testdecks::LAPLACE);
        for _ in 0..5 {
            let p = cache.compile_spec(&spec).unwrap();
            assert!(!p.fd.nests.is_empty());
        }
        let s = cache.stats();
        assert_eq!(s.computes, 1, "{s}");
        assert_eq!(s.hits, 4, "{s}");
        assert_eq!(s.misses, 1, "{s}");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_variants_get_distinct_entries() {
        let cache = PlanCache::new();
        let fused = PlanSpec::deck_src(testdecks::LAPLACE);
        let unfused = PlanSpec::deck_src(testdecks::LAPLACE).variant(Variant::Autovec);
        assert_ne!(fused.fingerprint(), unfused.fingerprint());
        let a = cache.compile_spec(&fused).unwrap();
        let b = cache.compile_spec(&unfused).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().computes, 2);
        // The two plans really differ: fusion produces fewer nests.
        assert!(a.fd.nests.len() <= b.fd.nests.len());
    }

    #[test]
    fn distinct_vlens_get_distinct_entries() {
        use crate::plan::Vlen;
        let cache = PlanCache::new();
        for vlen in [Vlen::Deck, Vlen::Fixed(1), Vlen::Fixed(4), Vlen::Fixed(8)] {
            cache.compile_spec(&PlanSpec::deck_src(testdecks::LAPLACE).vlen(vlen)).unwrap();
        }
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats().computes, 4);
    }

    #[test]
    fn concurrent_lookups_compute_once() {
        let cache = Arc::new(OnceMap::<String, u64>::new());
        let key = "k".to_string();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let key = key.clone();
            handles.push(std::thread::spawn(move || {
                let v = cache
                    .get_or_compute(&key, || {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(7)
                    })
                    .unwrap();
                assert_eq!(*v, 7);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.stats().computes, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn errors_are_cached() {
        let cache = PlanCache::new();
        let bad = PlanSpec::deck_src("not a deck");
        let e1 = cache.compile_spec(&bad).unwrap_err();
        let e2 = cache.compile_spec(&bad).unwrap_err();
        assert_eq!(e1, e2);
        let s = cache.stats();
        assert_eq!(s.computes, 1, "{s}");
        assert_eq!(s.hits, 1, "{s}");
    }

    #[test]
    fn retrying_evicts_errors() {
        let cache = OnceMap::<String, u64>::new();
        let key = "k".to_string();
        let e = cache.get_or_compute_retrying(&key, || Err("boom".to_string())).unwrap_err();
        assert_eq!(e, "boom");
        assert_eq!(cache.len(), 0, "failed entry must be evicted");
        let v = cache.get_or_compute_retrying(&key, || Ok(5)).unwrap();
        assert_eq!(*v, 5);
        assert_eq!(cache.stats().computes, 2);
    }

    #[test]
    fn tagged_keys_differ() {
        let k = PlanSpec::app("laplace").plan_key();
        let n = k.tagged("native");
        assert_eq!(k.app, n.app);
        assert_ne!(k.fingerprint, n.fingerprint);
        assert_ne!(n.fingerprint, k.tagged("exec").fingerprint);
        assert!(!format!("{k}").is_empty());
    }
}
