//! Shared compiled-plan cache.
//!
//! Deck→schedule compilation (inference, fusion, storage contraction,
//! vectorization analysis) is expensive, but its output — a [`Program`] —
//! is immutable and reusable. This module provides the compile-once /
//! run-many substrate the serving layer is built on:
//!
//! * [`OnceMap`] — a generic sharded concurrent map whose values are
//!   computed exactly once per key, even under racing lookups (other
//!   threads block on the in-flight computation instead of duplicating
//!   it). Hit/miss/compute counters are threaded through [`CacheStats`].
//! * [`PlanKey`] — `(app, variant, options fingerprint)`: the identity of
//!   a compiled plan. The fingerprint folds every semantically relevant
//!   field of [`CompileOptions`] (fusion + analysis + input rolling) and,
//!   optionally, [`ExecOptions`], through a deterministic FNV-1a hash.
//! * [`PlanCache`] — an `OnceMap<PlanKey, Program>` with compile helpers;
//!   the coordinator shares one instance across its whole worker pool.

use crate::exec::ExecOptions;
use crate::plan::{compile_src, CompileOptions, Program};
use std::collections::hash_map::RandomState;
use std::collections::HashMap;
use std::hash::{BuildHasher, Hash};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Fingerprinting
// ---------------------------------------------------------------------------

/// Deterministic FNV-1a 64-bit hasher for option fingerprints. Unlike
/// `DefaultHasher`, the result is stable across processes, so fingerprints
/// can be logged, compared across runs, and used in artifact file names.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf29ce484222325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_i64(&mut self, v: i64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write(&[v as u8]);
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// Fold every semantically relevant compile option into `h`. Any new
/// option that changes the produced schedule MUST be added here, or two
/// differently-configured compiles would collide in the cache.
pub fn feed_compile_options(h: &mut Fnv64, o: &CompileOptions) {
    h.write_bool(o.fusion.enabled);
    h.write_bool(o.analysis.contraction);
    // The vector-length override is an Option: `None` (deck default) must
    // not collide with any forced value, and distinct forced vlens must
    // get distinct compiled-plan cache entries.
    h.write_bool(o.analysis.vector_len.is_some());
    h.write_u64(o.analysis.vector_len.unwrap_or(0) as u64);
    h.write_i64(o.analysis.rotation_slack);
    h.write_bool(o.analysis.pow2_windows);
    h.write_bool(o.analysis.contract_innermost);
    h.write_bool(o.roll_all_inputs);
}

/// Fingerprint of a [`CompileOptions`].
pub fn compile_fingerprint(o: &CompileOptions) -> u64 {
    let mut h = Fnv64::new();
    feed_compile_options(&mut h, o);
    h.finish()
}

// ---------------------------------------------------------------------------
// Cache key
// ---------------------------------------------------------------------------

/// Identity of a compiled plan: which deck (`app`), which paper variant
/// (`hfav` / `autovec` / ...), and the fingerprint of every option that
/// influences the compile.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanKey {
    pub app: String,
    pub variant: String,
    pub fingerprint: u64,
}

impl PlanKey {
    /// Key for a compile of `app` under `opts`, labeled with a variant.
    pub fn new(app: &str, variant: &str, opts: &CompileOptions) -> PlanKey {
        PlanKey {
            app: app.to_string(),
            variant: variant.to_string(),
            fingerprint: compile_fingerprint(opts),
        }
    }

    /// Derive a sibling key with an extra tag folded into the
    /// fingerprint (e.g. `"native"` for compiled-C modules keyed off the
    /// same plan).
    pub fn tagged(&self, tag: &str) -> PlanKey {
        let mut h = Fnv64(self.fingerprint);
        h.write_str(tag);
        PlanKey { app: self.app.clone(), variant: self.variant.clone(), fingerprint: h.finish() }
    }

    /// Derive a sibling key for caches whose values also depend on the
    /// execution mode (e.g. per-worker interpreter sweepers).
    pub fn with_exec(&self, e: &ExecOptions) -> PlanKey {
        let mut h = Fnv64(self.fingerprint);
        h.write_str("exec");
        h.write_u64(e.mode as u64);
        h.write_bool(e.strip.is_some());
        h.write_u64(e.strip.unwrap_or(0) as u64);
        PlanKey { app: self.app.clone(), variant: self.variant.clone(), fingerprint: h.finish() }
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}#{:016x}", self.app, self.variant, self.fingerprint)
    }
}

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Atomic hit/miss/compute counters shared by all users of a cache.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub computes: AtomicU64,
    pub compute_ns: AtomicU64,
}

impl CacheStats {
    pub fn snapshot(&self) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            computes: self.computes.load(Ordering::Relaxed),
            compute_time: Duration::from_nanos(self.compute_ns.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time view of a cache's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStatsSnapshot {
    /// Lookups answered from an already-computed entry.
    pub hits: u64,
    /// Lookups that found no computed entry (includes racers that then
    /// blocked on another thread's in-flight compute).
    pub misses: u64,
    /// Times the compute closure actually ran — for a plan cache this is
    /// the number of pipeline compilations performed.
    pub computes: u64,
    /// Total wall time spent inside the compute closure.
    pub compute_time: Duration,
}

impl CacheStatsSnapshot {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

impl std::fmt::Display for CacheStatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} computes={} hit_rate={:.1}% compute_time={:?}",
            self.hits,
            self.misses,
            self.computes,
            100.0 * self.hit_rate(),
            self.compute_time,
        )
    }
}

// ---------------------------------------------------------------------------
// OnceMap
// ---------------------------------------------------------------------------

type Slot<V> = Arc<OnceLock<Result<Arc<V>, String>>>;

/// Sharded concurrent compute-once map.
///
/// Each key's value is produced by the first caller's closure; concurrent
/// callers for the same key block until that computation finishes and then
/// share the `Arc`'d result. Failed computations are cached too (negative
/// caching), so a deck that fails to compile does not trigger a recompile
/// storm under load.
pub struct OnceMap<K, V> {
    shards: Vec<RwLock<HashMap<K, Slot<V>>>>,
    hasher: RandomState,
    stats: CacheStats,
}

impl<K: Hash + Eq + Clone, V> OnceMap<K, V> {
    pub fn new() -> OnceMap<K, V> {
        OnceMap::with_shards(8)
    }

    pub fn with_shards(n: usize) -> OnceMap<K, V> {
        OnceMap {
            shards: (0..n.max(1)).map(|_| RwLock::new(HashMap::new())).collect(),
            hasher: RandomState::new(),
            stats: CacheStats::default(),
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        (self.hasher.hash_one(key) as usize) % self.shards.len()
    }

    /// Get the value for `key`, computing it with `f` if absent. `f` runs
    /// at most once per key across all threads.
    pub fn get_or_compute<F>(&self, key: &K, f: F) -> Result<Arc<V>, String>
    where
        F: FnOnce() -> Result<V, String>,
    {
        let shard = &self.shards[self.shard_of(key)];
        let slot = {
            let map = shard.read().unwrap();
            map.get(key).cloned()
        };
        let slot = match slot {
            Some(s) => s,
            None => {
                let mut map = shard.write().unwrap();
                map.entry(key.clone()).or_insert_with(|| Arc::new(OnceLock::new())).clone()
            }
        };
        if let Some(done) = slot.get() {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            return done.clone();
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        slot.get_or_init(|| {
            let t0 = Instant::now();
            let out = f().map(Arc::new);
            self.stats.compute_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.stats.computes.fetch_add(1, Ordering::Relaxed);
            out
        })
        .clone()
    }

    /// Like [`get_or_compute`](Self::get_or_compute), but a failed
    /// computation is evicted instead of negatively cached, so a later
    /// caller retries. Use for I/O-dependent computations (e.g. invoking
    /// the system C compiler) where a failure may be transient; plan
    /// compilation is deterministic and keeps negative caching.
    pub fn get_or_compute_retrying<F>(&self, key: &K, f: F) -> Result<Arc<V>, String>
    where
        F: FnOnce() -> Result<V, String>,
    {
        let out = self.get_or_compute(key, f);
        if out.is_err() {
            let shard = &self.shards[self.shard_of(key)];
            let mut map = shard.write().unwrap();
            if let Some(slot) = map.get(key) {
                if matches!(slot.get(), Some(Err(_))) {
                    map.remove(key);
                }
            }
        }
        out
    }

    /// Peek without computing.
    pub fn get(&self, key: &K) -> Option<Result<Arc<V>, String>> {
        let shard = &self.shards[self.shard_of(key)];
        let map = shard.read().unwrap();
        map.get(key).and_then(|s| s.get().cloned())
    }

    /// Number of cached entries (computed or in flight).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry. Counters are preserved.
    pub fn clear(&self) {
        for s in &self.shards {
            s.write().unwrap().clear();
        }
    }

    pub fn stats(&self) -> CacheStatsSnapshot {
        self.stats.snapshot()
    }
}

impl<K: Hash + Eq + Clone, V> Default for OnceMap<K, V> {
    fn default() -> Self {
        OnceMap::new()
    }
}

// ---------------------------------------------------------------------------
// PlanCache
// ---------------------------------------------------------------------------

/// The shared compiled-plan cache: `PlanKey -> Arc<Program>`.
#[derive(Default)]
pub struct PlanCache {
    map: OnceMap<PlanKey, Program>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache { map: OnceMap::new() }
    }

    /// Fetch the plan for `key`, compiling with `f` on first use.
    pub fn get_or_compile<F>(&self, key: &PlanKey, f: F) -> Result<Arc<Program>, String>
    where
        F: FnOnce() -> Result<Program, String>,
    {
        self.map.get_or_compute(key, f)
    }

    /// Convenience: compile `src` under `opts`, keyed by
    /// `(app, variant, fingerprint(opts))`.
    pub fn compile_src_cached(
        &self,
        app: &str,
        variant: &str,
        src: &str,
        opts: &CompileOptions,
    ) -> Result<Arc<Program>, String> {
        let key = PlanKey::new(app, variant, opts);
        self.map.get_or_compute(&key, || compile_src(src, opts.clone()))
    }

    pub fn get(&self, key: &PlanKey) -> Option<Result<Arc<Program>, String>> {
        self.map.get(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&self) {
        self.map.clear()
    }

    pub fn stats(&self) -> CacheStatsSnapshot {
        self.map.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;

    #[test]
    fn fingerprint_distinguishes_options() {
        let a = CompileOptions::default();
        let b = CompileOptions {
            fusion: crate::fusion::FusionOptions { enabled: false },
            ..Default::default()
        };
        let c = CompileOptions {
            analysis: crate::analysis::AnalysisOptions {
                contract_innermost: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let d = CompileOptions { roll_all_inputs: true, ..Default::default() };
        let fps = [
            compile_fingerprint(&a),
            compile_fingerprint(&b),
            compile_fingerprint(&c),
            compile_fingerprint(&d),
        ];
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "options {i} and {j} collide");
            }
        }
        // Same options → same fingerprint (determinism).
        assert_eq!(compile_fingerprint(&a), compile_fingerprint(&CompileOptions::default()));
    }

    #[test]
    fn exec_keys_distinguish_modes() {
        use crate::exec::Mode;
        let k = PlanKey::new("laplace", "hfav", &CompileOptions::default());
        let a = k.with_exec(&ExecOptions { mode: Mode::Peeled, strip: None });
        let b = k.with_exec(&ExecOptions { mode: Mode::Guarded, strip: None });
        let c = k.with_exec(&ExecOptions { mode: Mode::Peeled, strip: Some(4) });
        assert_ne!(a.fingerprint, b.fingerprint);
        assert_ne!(a.fingerprint, k.fingerprint);
        assert_ne!(a.fingerprint, c.fingerprint);
    }

    #[test]
    fn fingerprint_distinguishes_vector_lens() {
        let mk = |vl: Option<usize>| CompileOptions {
            analysis: crate::analysis::AnalysisOptions { vector_len: vl, ..Default::default() },
            ..Default::default()
        };
        let fps: Vec<u64> = [None, Some(1), Some(4), Some(8)]
            .into_iter()
            .map(|vl| compile_fingerprint(&mk(vl)))
            .collect();
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "vlen options {i} and {j} collide");
            }
        }
    }

    #[test]
    fn plan_cache_compiles_once_per_key() {
        let cache = PlanCache::new();
        let opts = CompileOptions::default();
        for _ in 0..5 {
            let p = cache
                .compile_src_cached("laplace", "hfav", testdecks::LAPLACE, &opts)
                .unwrap();
            assert!(!p.fd.nests.is_empty());
        }
        let s = cache.stats();
        assert_eq!(s.computes, 1, "{s}");
        assert_eq!(s.hits, 4, "{s}");
        assert_eq!(s.misses, 1, "{s}");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_fusion_options_get_distinct_entries() {
        let cache = PlanCache::new();
        let fused = CompileOptions::default();
        let unfused = CompileOptions {
            fusion: crate::fusion::FusionOptions { enabled: false },
            ..Default::default()
        };
        let a = cache
            .compile_src_cached("laplace", "hfav", testdecks::LAPLACE, &fused)
            .unwrap();
        let b = cache
            .compile_src_cached("laplace", "autovec", testdecks::LAPLACE, &unfused)
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().computes, 2);
        // The two plans really differ: fusion produces fewer nests.
        assert!(a.fd.nests.len() <= b.fd.nests.len());
        assert_ne!(
            PlanKey::new("laplace", "x", &fused).fingerprint,
            PlanKey::new("laplace", "x", &unfused).fingerprint,
        );
    }

    #[test]
    fn concurrent_lookups_compute_once() {
        let cache = Arc::new(OnceMap::<String, u64>::new());
        let key = "k".to_string();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let key = key.clone();
            handles.push(std::thread::spawn(move || {
                let v = cache
                    .get_or_compute(&key, || {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(7)
                    })
                    .unwrap();
                assert_eq!(*v, 7);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.stats().computes, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn errors_are_cached() {
        let cache = PlanCache::new();
        let opts = CompileOptions::default();
        let e1 = cache.compile_src_cached("bad", "hfav", "not a deck", &opts).unwrap_err();
        let e2 = cache.compile_src_cached("bad", "hfav", "not a deck", &opts).unwrap_err();
        assert_eq!(e1, e2);
        let s = cache.stats();
        assert_eq!(s.computes, 1, "{s}");
        assert_eq!(s.hits, 1, "{s}");
    }

    #[test]
    fn retrying_evicts_errors() {
        let cache = OnceMap::<String, u64>::new();
        let key = "k".to_string();
        let e = cache.get_or_compute_retrying(&key, || Err("boom".to_string())).unwrap_err();
        assert_eq!(e, "boom");
        assert_eq!(cache.len(), 0, "failed entry must be evicted");
        let v = cache.get_or_compute_retrying(&key, || Ok(5)).unwrap();
        assert_eq!(*v, 5);
        assert_eq!(cache.stats().computes, 2);
    }

    #[test]
    fn tagged_keys_differ() {
        let k = PlanKey::new("laplace", "hfav", &CompileOptions::default());
        let n = k.tagged("native");
        assert_eq!(k.app, n.app);
        assert_ne!(k.fingerprint, n.fingerprint);
        assert_ne!(n.fingerprint, k.tagged("exec").fingerprint);
        assert!(!format!("{k}").is_empty());
    }
}
