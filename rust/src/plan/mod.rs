//! Pipeline driver: deck → inference → fusion → analysis, bundled into a
//! [`Program`] — the compiled schedule consumed by the executor
//! ([`crate::exec`]) and the code emitters ([`crate::codegen`]).
//!
//! What to compile is described by a [`PlanSpec`] ([`spec`]): deck
//! target + variant + tuning knobs, with a canonical fingerprint that
//! doubles as the cache identity. Compilation is expensive but its
//! output is immutable: [`cache`] provides the shared
//! compile-once/serve-many plan cache ([`cache::PlanCache`], keyed by
//! [`cache::PlanKey`] = the spec fingerprint) that the coordinator's
//! worker pool is built on.

pub mod cache;
pub mod spec;
pub mod tunedb;

pub use self::spec::{PlanSpec, Vlen};

use crate::analysis::{self, AnalysisOptions, StoragePlan};
use crate::dataflow::{Dataflow, Terminal};
use crate::fusion::{self, FusedDag, FusionOptions, Role};
use crate::ir::Deck;
use std::collections::BTreeMap;

/// All options controlling compilation.
#[derive(Debug, Clone, Default)]
pub struct CompileOptions {
    pub fusion: FusionOptions,
    pub analysis: AnalysisOptions,
    /// Roll *all* terminal inputs through buffers (the paper's §5.3
    /// "additional rolling buffer for the input values" in-place variant).
    /// Inputs named in deck alias pairs are always rolled (in/out
    /// chaining, §3.5).
    pub roll_all_inputs: bool,
    /// Aligned-load specialization: intermediates get 64-byte-aligned
    /// allocations with `assume_aligned` hints (C backend), and every
    /// strip loop peels a scalar head so the steady-state strips start
    /// at indices that are multiples of the vector length ("aligned
    /// strip heads"). The unaligned shape remains the general case —
    /// peel analysis cannot prove most segment bounds are multiples of
    /// the vector length, so the head peel establishes alignment at run
    /// time. No effect at vector length 1.
    pub aligned: bool,
}

/// A fully-compiled program.
#[derive(Debug, Clone)]
pub struct Program {
    pub deck: Deck,
    pub df: Dataflow,
    pub fd: FusedDag,
    pub sp: StoragePlan,
    /// The lowered schedule IR ([`crate::schedule`]): one loop tree per
    /// fused nest, computed exactly once here. Both code emitters print
    /// it and the interpreter executes it — no consumer re-derives loop
    /// shapes.
    pub sched: crate::schedule::Schedule,
    pub opts: CompileOptions,
}

/// Compile a deck.
///
/// `opts.analysis.vector_len` is an `Option` override: `None` uses the
/// deck's declared `vector_len`, `Some(n)` forces `n` lanes (so
/// `Some(1)` explicitly forces scalar codegen on a vectorized deck). The
/// resolved value is reported by [`Program::vector_len`].
pub fn compile(deck: Deck, opts: CompileOptions) -> Result<Program, String> {
    let mut opts = opts;
    let mut df = crate::dataflow::build(&deck)?;
    // In/out chaining before fusion (inserts synthetic roll callsites).
    analysis::chain_inouts(&deck, &mut df)?;
    if opts.roll_all_inputs {
        let inputs: Vec<_> = df
            .vars
            .iter()
            .filter(|v| {
                matches!(v.terminal, Terminal::Input { .. }) && !df.reads_of[v.id].is_empty()
            })
            .map(|v| v.id)
            .collect();
        for v in inputs {
            // Skip if chain_inouts already buffered it.
            if df.var_by_ident.contains_key(&format!("__buf({})", df.vars[v].ident)) {
                continue;
            }
            analysis::insert_input_buffer(&mut df, v)?;
        }
    }
    let fd = fusion::fuse(&df, &opts.fusion)?;
    // Resolve the vectorization dimension against the fused schedule, so
    // the program carries a concrete `Inner`/`Outer(dim)` that storage
    // analysis, both code emitters and the executor all read. An
    // explicitly requested illegal outer dim fails here.
    opts.analysis.vec_dim = analysis::resolve_vec_dim(&deck, &df, &fd, &opts.analysis)?;
    let sp = analysis::analyze(&deck, &df, &fd, &opts.analysis)?;
    // Lower the loop-schedule tree exactly once, now that the strategy
    // (vec dim, vector length, tiling, alignment) and the storage plan
    // are final. Everything downstream walks this tree.
    let sched = crate::schedule::lower(&deck, &df, &fd, &sp, &opts)?;
    let prog = Program { deck, df, fd, sp, sched, opts };
    // Independent safety net behind the `HFAV_VERIFY` env knob (on by
    // default under `cfg(test)`): re-prove the lowered schedule
    // in-bounds, race-free and def-before-use clean before any backend
    // sees it. See [`crate::verify`].
    if crate::verify::gate_enabled() {
        crate::verify::gate_check(&prog)?;
    }
    Ok(prog)
}

/// Convenience: compile from deck source text.
pub fn compile_src(src: &str, opts: CompileOptions) -> Result<Program, String> {
    let deck = crate::frontend::parse_deck(src)?;
    compile(deck, opts)
}

impl Program {
    /// Effective vector length this program was analyzed (and must be
    /// emitted/executed) with: the caller's override if one was given,
    /// else the deck's declared `vector_len`. Storage windows were padded
    /// for exactly this many lanes, so the code generators and the strip
    /// executor must use the same value.
    pub fn vector_len(&self) -> usize {
        crate::analysis::resolve_vector_len(&self.deck, &self.opts.analysis)
    }

    /// The resolved vectorization dimension: always a concrete
    /// `Inner` / `Outer(dim)` after [`compile`] (never `Auto`).
    pub fn vec_dim(&self) -> &crate::analysis::VecDim {
        &self.opts.analysis.vec_dim
    }

    /// The outer lane dim, when this program vectorizes an outer loop:
    /// `Some(dim)` iff the resolved strategy is `Outer(dim)` and the
    /// effective vector length is > 1. Storage was lane-expanded along
    /// this dim, so the emitters and the executor strip-mine it (and
    /// must not strip-mine the innermost dim — its windows carry no
    /// vector padding under this strategy).
    pub fn outer_lane_dim(&self) -> Option<&str> {
        match &self.opts.analysis.vec_dim {
            crate::analysis::VecDim::Outer(d) if self.vector_len() > 1 => Some(d.as_str()),
            _ => None,
        }
    }

    /// Whether this program runs multi-dim lane tiles (outer lanes ×
    /// inner strips): the `tile` knob was set and an outer lane dim
    /// resolved at an effective vector length > 1.
    pub fn tiled(&self) -> bool {
        self.opts.analysis.tile && self.outer_lane_dim().is_some()
    }

    /// Effective temporal-blocking depth: the `t_block` of the lowered
    /// [`crate::schedule::TimeTileNode`] when the legality gate admitted
    /// time tiling (possibly wrapped in a [`crate::schedule::Node::Parallel`]
    /// level), else 1. Requesting `time_tile > 1` on an ineligible deck
    /// falls back silently — this accessor reports what actually lowered.
    pub fn time_tile(&self) -> usize {
        for np in &self.sched.nests {
            for node in &np.body {
                match node {
                    crate::schedule::Node::TimeTile(t) => return t.t_block,
                    crate::schedule::Node::Parallel(p) => {
                        if let Some(crate::schedule::Node::TimeTile(t)) = p.body.first() {
                            return t.t_block;
                        }
                    }
                    _ => {}
                }
            }
        }
        1
    }

    /// Stable fingerprint of the lowered schedule tree
    /// ([`crate::schedule::Schedule::digest`]): two programs with equal
    /// digests run exactly the same loops. Both code emitters print it
    /// into their output header, so backend agreement is checkable by
    /// string comparison.
    pub fn schedule_digest(&self) -> u64 {
        self.sched.digest
    }

    /// Names and spans of required external input arrays:
    /// (storage name, dims, per-dim half-open bounds).
    pub fn external_inputs(&self) -> Vec<(String, Vec<String>, Vec<crate::ir::Domain>)> {
        self.externals(true)
    }

    /// Names and spans of produced external output arrays.
    pub fn external_outputs(&self) -> Vec<(String, Vec<String>, Vec<crate::ir::Domain>)> {
        self.externals(false)
    }

    fn externals(&self, inputs: bool) -> Vec<(String, Vec<String>, Vec<crate::ir::Domain>)> {
        let mut out = Vec::new();
        for v in &self.df.vars {
            let name = match (&v.terminal, inputs) {
                (Terminal::Input { storage, .. }, true) => storage.clone(),
                (Terminal::Output { storage, .. }, false) => storage.clone(),
                _ => continue,
            };
            let doms: Vec<_> = v.dims.iter().map(|d| v.span[d].clone()).collect();
            out.push((name, v.dims.clone(), doms));
        }
        out
    }

    /// Pretty-print the fused schedule (loop structure with phases) — the
    /// human-readable view of the paper's Fig. 6.
    pub fn schedule_text(&self) -> String {
        let mut s = String::new();
        for nest in &self.fd.nests {
            s.push_str(&format!("nest {} over ({}):\n", nest.id, nest.dims.join(",")));
            self.fmt_level(nest, &nest.members.iter().collect::<Vec<_>>(), 0, 1, &mut s);
        }
        s
    }

    fn fmt_level(
        &self,
        nest: &crate::fusion::FusedNest,
        members: &[&crate::fusion::Member],
        level: usize,
        indent: usize,
        s: &mut String,
    ) {
        let pad = "  ".repeat(indent);
        if level == nest.dims.len() {
            for m in members {
                let cs = &self.df.callsites[m.callsite];
                let shifts: Vec<String> = nest
                    .dims
                    .iter()
                    .zip(m.shifts.iter())
                    .filter(|(d, _)| cs.dims.contains(d))
                    .map(|(d, sh)| format!("{d}+{sh}"))
                    .collect();
                s.push_str(&format!("{pad}{}({})\n", cs.name, shifts.join(",")));
            }
            return;
        }
        let pre: Vec<&crate::fusion::Member> =
            members.iter().filter(|m| m.roles[level] == Role::Pre).copied().collect();
        let inl: Vec<&crate::fusion::Member> =
            members.iter().filter(|m| m.roles[level] == Role::Loop).copied().collect();
        let post: Vec<&crate::fusion::Member> =
            members.iter().filter(|m| m.roles[level] == Role::Post).copied().collect();
        if !pre.is_empty() {
            s.push_str(&format!("{pad}prologue[{}]:\n", nest.dims[level]));
            self.fmt_level(nest, &pre, level + 1, indent + 1, s);
        }
        if !inl.is_empty() {
            s.push_str(&format!("{pad}for {}:\n", nest.dims[level]));
            self.fmt_level(nest, &inl, level + 1, indent + 1, s);
        }
        if !post.is_empty() {
            s.push_str(&format!("{pad}epilogue[{}]:\n", nest.dims[level]));
            self.fmt_level(nest, &post, level + 1, indent + 1, s);
        }
    }

    /// Intermediate footprint in words for given extents (paper §5.3/§5.4
    /// footprint claims).
    pub fn footprint_words(&self, extents: &BTreeMap<String, i64>) -> Result<i64, String> {
        self.sp.intermediate_words(&self.df, extents)
    }

    /// Walk-derived schedule counters ([`crate::schedule::Schedule::stats`])
    /// with the per-invocation load/store cost bound to this program's
    /// dataflow: each member invocation costs its callsite's read count in
    /// loads and write count in stores. `threads` sets the chunk-worker
    /// count the parallel levels are decomposed at (1 = serial).
    pub fn schedule_stats(
        &self,
        extents: &BTreeMap<String, i64>,
        threads: usize,
    ) -> Result<crate::schedule::ScheduleStats, String> {
        let cost = |np: usize, mi: usize| -> (u64, u64) {
            let m = &self.fd.nests[self.sched.nests[np].nest].members[mi];
            let cs = &self.df.callsites[m.callsite];
            (cs.reads.len() as u64, cs.writes.len() as u64)
        };
        self.sched.stats(extents, threads, &cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;

    #[test]
    fn compile_all_testdecks() {
        for src in [testdecks::LAPLACE, testdecks::NORMALIZE, testdecks::CHAIN1D] {
            let prog = compile_src(src, CompileOptions::default()).unwrap();
            assert!(!prog.fd.nests.is_empty());
        }
    }

    #[test]
    fn schedule_text_shows_phases() {
        let prog = compile_src(testdecks::NORMALIZE, CompileOptions::default()).unwrap();
        let txt = prog.schedule_text();
        assert!(txt.contains("prologue[i]"), "{txt}");
        assert!(txt.contains("epilogue[i]"), "{txt}");
        assert!(txt.contains("norm_acc"), "{txt}");
    }

    #[test]
    fn externals_reported() {
        let prog = compile_src(testdecks::LAPLACE, CompileOptions::default()).unwrap();
        let ins = prog.external_inputs();
        assert_eq!(ins.len(), 1);
        assert_eq!(ins[0].0, "g_cell");
        let outs = prog.external_outputs();
        assert_eq!(outs[0].0, "g_out");
    }

    #[test]
    fn vector_len_override_is_explicit() {
        // Deck declares vector_len 8; no override → 8 lanes.
        let src = format!("{}vector_len: 8\n", testdecks::CHAIN1D);
        let deck_default = compile_src(&src, CompileOptions::default()).unwrap();
        assert_eq!(deck_default.vector_len(), 8);
        // Some(1) forces scalar even though the deck asks for 8.
        let forced_scalar = compile_src(
            &src,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(1),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(forced_scalar.vector_len(), 1);
        // Forced-scalar storage matches a plain scalar compile.
        let plain = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        let dbl = |p: &Program| {
            let v = p.df.var("dbl(u)").unwrap().id;
            p.sp.storage_of(v).sizes.clone()
        };
        assert_eq!(dbl(&forced_scalar), dbl(&plain));
        assert_ne!(dbl(&deck_default), dbl(&plain));
        // Some(4) overrides the deck default in the other direction.
        let forced4 = compile_src(
            &src,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(forced4.vector_len(), 4);
    }

    #[test]
    fn vec_dim_resolves_at_compile() {
        use crate::analysis::VecDim;
        // Default: Inner, no outer lane dim.
        let plain = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        assert_eq!(plain.vec_dim(), &VecDim::Inner);
        assert_eq!(plain.outer_lane_dim(), None);
        // cosmo + Auto at vlen 4 resolves to the k-independent outer dim.
        let opts = |vd: VecDim| CompileOptions {
            analysis: crate::analysis::AnalysisOptions {
                vector_len: Some(4),
                vec_dim: vd,
                ..Default::default()
            },
            ..Default::default()
        };
        let auto = compile_src(crate::apps::cosmo::DECK, opts(VecDim::Auto)).unwrap();
        assert_eq!(auto.vec_dim(), &VecDim::Outer("k".to_string()));
        assert_eq!(auto.outer_lane_dim(), Some("k"));
        // An explicitly requested illegal dim fails the compile.
        let e = compile_src(crate::apps::cosmo::DECK, opts(VecDim::Outer("j".into())))
            .unwrap_err();
        assert!(e.contains("not legal"), "{e}");
        // Outer resolution at vlen 1 degrades to Inner (scalar).
        let scalar = compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(1),
                    vec_dim: VecDim::Outer("k".into()),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(scalar.vec_dim(), &VecDim::Inner);
        assert_eq!(scalar.outer_lane_dim(), None);
    }

    #[test]
    fn schedule_stats_counts_work_and_chunks() {
        let prog = compile_src(crate::apps::cosmo::DECK, CompileOptions::default()).unwrap();
        let mut ext = BTreeMap::new();
        for d in ["Nk", "Nj", "Ni"] {
            ext.insert(d.to_string(), 12i64);
        }
        let serial = prog.schedule_stats(&ext, 1).unwrap();
        let par = prog.schedule_stats(&ext, 4).unwrap();
        // Worker count changes chunking only, never the work.
        assert_eq!(serial.invocations, par.invocations);
        assert_eq!(serial.loads, par.loads);
        assert_eq!(serial.stores, par.stores);
        assert!(serial.invocations > 0);
        assert!(serial.loads > serial.stores);
        // cosmo carries one parallel level along k.
        assert_eq!(par.parallel.len(), 1);
        assert_eq!(par.parallel[0].dim, "k");
        assert_eq!(serial.parallel[0].chunks, 1);
        assert_eq!(par.parallel[0].chunks, 4);
        assert!(par.summary().contains("chunks"), "{}", par.summary());
    }

    #[test]
    fn roll_all_inputs_buffers_terminals() {
        let opts = CompileOptions { roll_all_inputs: true, ..Default::default() };
        let prog = compile_src(testdecks::LAPLACE, opts).unwrap();
        assert!(prog.df.var("__buf(cell)").is_some());
        // Still a single fused nest.
        assert_eq!(prog.fd.nests.len(), 1);
    }
}
