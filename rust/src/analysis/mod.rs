//! Variable & storage analysis (paper §3.5): enclosing regions, reuse
//! patterns, storage contraction, accumulator chaining, in/out alias
//! chaining and vector expansion.
//!
//! # Vectorization legality gates
//!
//! The code generators and the interpreter executor never vectorize on
//! their own judgement — every strip shape is justified by one of two
//! legality checks owned by this module:
//!
//! * [`lane_fission_safe`] gates **innermost-dimension** strips
//!   (`VecDim::Inner`, the paper's Fig. 9c vector expansion): running
//!   each steady-state kernel over `vlen` consecutive innermost
//!   iterations before the next kernel starts is legal only when no
//!   kernel reads another kernel's per-iteration value out of storage
//!   without per-lane slots (a *scan observed mid-loop*). The matching
//!   storage invariant is established here: innermost windows are padded
//!   to `w + vlen − 1` and loop-carried scalars get `vlen` lane slots,
//!   so a whole strip fits in the buffer without wraparound.
//! * [`outer_vectorizable`] gates **outer-dimension** strips
//!   (`VecDim::Outer(dim)`): a nest may run `vlen` lanes of an outer
//!   loop concurrently only when the loop is *k-independent* — every
//!   member iterates the dim with offset-0 accesses and zero pipeline
//!   shift, nothing reduces over it, and every written variable is
//!   indexed by it (so lanes write disjoint slots). The storage
//!   invariant is the *outer-lane expansion* applied by [`analyze`]:
//!   single-slot (`DimSize::One`) intermediates gain `vlen` slots along
//!   the lane dim, and [`layout_order`] moves that dim innermost in the
//!   intermediate layouts so lane loops touch contiguous memory. Inner
//!   windows keep their scalar sizes — in-register window rotation
//!   disappears entirely under this strategy.
//!
//! [`resolve_vec_dim`] turns the requested [`VecDim`] (including `Auto`)
//! into a concrete strategy against the fused schedule, failing fast
//! when an explicitly requested outer dim is illegal.

use crate::dataflow::{CallsiteId, Dataflow, Terminal, VarId};
use crate::fusion::{FusedDag, FusedNest, Role};
use crate::ir::Deck;
use std::collections::{BTreeMap, BTreeSet};

/// Size class of one dimension of a variable's storage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DimSize {
    /// Live window of one: the value never outlives an iteration of this
    /// dim (stored as a single slot).
    One,
    /// Rolling window of `w` iterations (circular buffer / rotation —
    /// paper Fig. 9). `alloc` is the actual allocated window: `w` padded
    /// for vector expansion (Fig. 9c) and rounded to a power of two for
    /// cheap modular indexing.
    Window { w: i64, alloc: i64 },
    /// Full required span of the dim.
    Full,
}

/// Storage assigned to one variable (or one alias class of variables).
#[derive(Debug, Clone)]
pub struct Storage {
    pub id: usize,
    /// Representative identifier (e.g. `laplace(cell)` or `g_cell`).
    pub name: String,
    /// Variables sharing this storage (accumulator chains).
    pub vars: Vec<VarId>,
    /// External terminal array name, if terminal.
    pub external: Option<String>,
    /// Dims of the representative var, outermost-first.
    pub dims: Vec<String>,
    /// Size class per dim.
    pub sizes: Vec<DimSize>,
    /// Enclosing region: [first nest index, last nest index] where this
    /// variable is live (paper §3.5 "Enclosing").
    pub enclosing: (usize, usize),
}

/// Reuse pattern of one variable (paper Fig. 8): read offsets ordered along
/// the Hamiltonian path of reuse (first visit → last use), per the global
/// iteration order.
#[derive(Debug, Clone)]
pub struct ReusePattern {
    pub var: VarId,
    /// Offsets sorted from first-visited to last (descending lexicographic
    /// by dim, outermost first).
    pub path: Vec<Vec<i64>>,
}

/// Analysis output consumed by planning/codegen.
#[derive(Debug, Clone)]
pub struct StoragePlan {
    pub storages: Vec<Storage>,
    /// var -> storage id
    pub of_var: Vec<usize>,
    pub reuse: Vec<ReusePattern>,
    /// Human-readable notes (contraction decisions, alias copies) for
    /// debugging output and EXPERIMENTS.md accounting.
    pub notes: Vec<String>,
}

impl StoragePlan {
    pub fn storage_of(&self, v: VarId) -> &Storage {
        &self.storages[self.of_var[v]]
    }

    /// Total words of *intermediate* storage (excludes external terminals),
    /// given concrete extents — reproduces the paper's footprint claims
    /// (§5.3 COSMO, §5.4 Hydro2D).
    pub fn intermediate_words(
        &self,
        df: &Dataflow,
        extents: &BTreeMap<String, i64>,
    ) -> Result<i64, String> {
        let mut total = 0i64;
        for s in &self.storages {
            if s.external.is_some() {
                continue;
            }
            total += storage_words(s, df, extents)?;
        }
        Ok(total)
    }
}

/// Words allocated for one storage under concrete extents.
pub fn storage_words(
    s: &Storage,
    df: &Dataflow,
    extents: &BTreeMap<String, i64>,
) -> Result<i64, String> {
    let rep = &df.vars[s.vars[0]];
    let mut words = 1i64;
    for (k, d) in s.dims.iter().enumerate() {
        let n = match &s.sizes[k] {
            DimSize::One => 1,
            DimSize::Window { alloc, .. } => *alloc,
            DimSize::Full => {
                let span = rep
                    .span
                    .get(d)
                    .ok_or_else(|| format!("no span for `{d}` of `{}`", rep.ident))?;
                (span.hi.eval(extents)? - span.lo.eval(extents)?).max(0)
            }
        };
        words *= n;
    }
    Ok(words)
}

/// Words of the buffer backing an *external* storage: the product of the
/// representative variable's span per dim — the executor's allocation
/// rule for terminal arrays, shared here so the static verifier
/// ([`crate::verify`]) sizes external buffers exactly like a run does.
pub fn external_storage_words(
    s: &Storage,
    df: &Dataflow,
    extents: &BTreeMap<String, i64>,
) -> Result<i64, String> {
    let rep = &df.vars[s.vars[0]];
    let mut words = 1i64;
    for d in &rep.dims {
        let span = rep
            .span
            .get(d)
            .ok_or_else(|| format!("no span for `{d}` of `{}`", rep.ident))?;
        words *= (span.hi.eval(extents)? - span.lo.eval(extents)?).max(0);
    }
    Ok(words)
}

/// Which loop dimension vector lanes run along.
///
/// `Inner` is the paper's Fig. 9c scheme: strip-mine the innermost loop
/// and rotate windows in-register. `Outer(dim)` strip-mines a
/// k-independent outer loop instead (legal per [`outer_vectorizable`]):
/// every kernel invocation is expanded across `vlen` lanes of that dim,
/// window rotation machinery disappears, and intermediates store the
/// lane dim contiguously ([`layout_order`]). `Auto` resolves at compile
/// time ([`resolve_vec_dim`]) to the outermost legal outer dim, else
/// `Inner`.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum VecDim {
    /// Strip-mine the innermost loop (vector expansion + in-register
    /// rotation, Fig. 9c). The default.
    #[default]
    Inner,
    /// Pick automatically: the outermost legal outer dim, else `Inner`.
    Auto,
    /// Strip-mine the named outer loop dim (must be k-independent in at
    /// least one fused nest, or compilation fails).
    Outer(String),
}

impl std::fmt::Display for VecDim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VecDim::Inner => write!(f, "inner"),
            VecDim::Auto => write!(f, "auto"),
            VecDim::Outer(d) => write!(f, "outer:{d}"),
        }
    }
}

impl std::str::FromStr for VecDim {
    type Err = String;
    fn from_str(s: &str) -> Result<VecDim, String> {
        match s {
            "inner" => Ok(VecDim::Inner),
            "auto" => Ok(VecDim::Auto),
            _ => match s.strip_prefix("outer:") {
                Some(d) if !d.is_empty() => Ok(VecDim::Outer(d.to_string())),
                _ => Err(format!("vec-dim `{s}` (want inner|auto|outer:<dim>)")),
            },
        }
    }
}

/// Options for the analysis stage.
#[derive(Debug, Clone)]
pub struct AnalysisOptions {
    /// Contract intermediate storage into rolling windows (paper §3.5
    /// "Contraction"). Off = every intermediate gets its full span (the
    /// shape of the unfused/naive code).
    pub contraction: bool,
    /// Vector length override for vector-expanded rotation (Fig. 9c).
    /// `None` defers to the deck's declared `vector_len`; `Some(n)` forces
    /// `n` lanes — including `Some(1)`, which forces scalar codegen even
    /// on a deck that declares `vector_len > 1`.
    pub vector_len: Option<usize>,
    /// Extra slack rows on rolling windows. The paper notes it is
    /// "generally most practical to simply allocate 3 times the storage
    /// needed for a single row" for a 2-row reuse distance — i.e. one
    /// slack row for pointer-rotation convenience. 0 reproduces exact
    /// reuse-distance contraction; 1 reproduces the paper's buffer sizes.
    pub rotation_slack: i64,
    /// Round allocated windows up to a power of two (cheap wraparound).
    pub pow2_windows: bool,
    /// Contract windows in the *innermost* loop dim. Scalar circular
    /// buffers there carry a distance-1 dependency that defeats
    /// auto-vectorization (the problem Fig. 9c's vector-expanded rotation
    /// addresses); turning this off keeps a full row instead — the
    /// "HFAV + Tuning" trade of a cache-resident row for a vectorizable
    /// steady state (§5.3).
    pub contract_innermost: bool,
    /// Which loop dim vector lanes run along ([`VecDim`]). `Auto` must be
    /// resolved against the fused schedule ([`resolve_vec_dim`]) before
    /// [`analyze`] runs; [`crate::plan::compile`] does this, so a
    /// compiled program always carries a concrete `Inner`/`Outer` here.
    pub vec_dim: VecDim,
    /// Multi-dim lane tiling: combine outer-dim lanes with innermost
    /// lane-fission strips (`vlen × vlen` tiles). Requires a resolved
    /// outer lane dim ([`resolve_vec_dim`] upgrades `Inner` to `Auto`
    /// resolution and fails when no dim is k-independent). Storage gets
    /// *both* expansions: innermost windows padded by `vlen − 1` (inner
    /// strips stay legal) and outer lane slots along the lane dim.
    pub tile: bool,
    /// Temporal blocking depth: execute this many sweep-steps per
    /// cache-resident block of the outermost loop dim before advancing
    /// (`schedule::lower` wraps each nest in a time-tile node when
    /// [`time_tileable`] holds, else the nest falls back to untiled).
    /// 1 = off.
    pub time_tile: usize,
}

impl Default for AnalysisOptions {
    fn default() -> Self {
        AnalysisOptions {
            contraction: true,
            vector_len: None,
            rotation_slack: 0,
            pow2_windows: true,
            contract_innermost: true,
            vec_dim: VecDim::Inner,
            tile: false,
            time_tile: 1,
        }
    }
}

/// Effective vector length of a compile: the caller's override if present,
/// else the deck's declared `vector_len`, clamped to at least 1.
pub fn resolve_vector_len(deck: &Deck, opts: &AnalysisOptions) -> usize {
    opts.vector_len.unwrap_or(deck.vector_len).max(1)
}

/// Vector length suggested by the host's SIMD features (f64 lanes):
/// AVX-512 → 8, AVX → 4, SSE2/NEON → 2, else scalar. This is the CLI's
/// `--vlen auto` default. On x86-64 the width is detected at *runtime*
/// (CPUID): the native backends compile the emitted code with
/// `-march=native` / `-C target-cpu=native`, so the host's best width is
/// the right answer even when this crate itself was built for baseline
/// x86-64.
pub fn auto_vector_len() -> usize {
    auto_vector_len_impl()
}

#[cfg(target_arch = "x86_64")]
fn auto_vector_len_impl() -> usize {
    if std::is_x86_feature_detected!("avx512f") {
        8
    } else if std::is_x86_feature_detected!("avx") {
        4
    } else {
        2 // SSE2 is baseline on x86-64
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn auto_vector_len_impl() -> usize {
    if cfg!(target_feature = "neon") {
        2
    } else {
        1
    }
}

/// Is `dim` a legal *outer* vectorization dim for this nest — i.e. is
/// the loop k-independent, so `vlen` consecutive iterations of it can
/// run as concurrent lanes?
///
/// Required for every member of the nest:
/// * the member iterates `dim` in the loop body ([`Role::Loop`]) with
///   zero pipeline shift;
/// * no reduction over `dim`;
/// * no read of an *in-nest-produced* value at a nonzero `dim` offset
///   (that would be cross-lane dataflow; offset reads of values
///   materialized before the nest — terminal inputs, upstream nests —
///   are read-only and safe);
/// * every *written* variable is indexed by `dim` at offset 0 (lanes
///   must land in disjoint slots; the outer-lane expansion in
///   [`analyze`] gives single-slot intermediates `vlen` slots along
///   `dim`).
///
/// Read-only variables that lack `dim` (broadcast inputs such as a
/// scalar `dtdx`) are fine: their loads are lane-invariant.
pub fn outer_vectorizable(df: &Dataflow, nest: &FusedNest, dim: &str) -> bool {
    let level = match nest.dim_index(dim) {
        Some(l) => l,
        None => return false,
    };
    if level + 1 == nest.dims.len() {
        return false; // innermost: use VecDim::Inner instead
    }
    for m in &nest.members {
        if m.roles[level] != Role::Loop || m.shifts[level] != 0 {
            return false;
        }
        let cs = &df.callsites[m.callsite];
        if cs.reduce_dims.contains(dim) {
            return false;
        }
        for (_, vid, offsets) in &cs.reads {
            let var = &df.vars[*vid];
            if let Some(k) = var.dims.iter().position(|d| d == dim) {
                let produced_here =
                    var.producer.is_some_and(|p| nest.member(p).is_some());
                if offsets[k] != 0 && produced_here {
                    return false;
                }
            }
        }
        for (_, vid, offsets) in &cs.writes {
            let var = &df.vars[*vid];
            match var.dims.iter().position(|d| d == dim) {
                Some(k) => {
                    if offsets[k] != 0 {
                        return false;
                    }
                }
                None => return false,
            }
        }
    }
    true
}

/// Is `dim` a legal *chunk-parallel* dim for this nest — i.e. may
/// disjoint ranges of it run concurrently on worker threads?
///
/// Builds on [`outer_vectorizable`] (k-independence: offset-0 accesses,
/// no reduction, no pipeline shift, every write indexed by `dim`), then
/// adds the storage-sharing obligation chunking introduces: lanes of an
/// outer strip execute in lockstep inside one thread, but chunks run on
/// *different* threads, so any written storage that is **contracted**
/// along `dim` (a [`DimSize::One`] slot or rolling [`DimSize::Window`])
/// would be overlapped by concurrent chunks. Such storages are legal
/// only when private to the nest (enclosing region is this nest alone),
/// in which case each chunk gets its own replica — k-independence
/// guarantees no value flows across `dim` iterations through them, so
/// replication is bitwise-invisible. Writes that are [`DimSize::Full`]
/// along `dim` land in disjoint slabs and stay shared.
///
/// Returns the storage ids to replicate per chunk, or `None` when the
/// nest must stay serial. Backends never call this: the decision is
/// baked into the schedule tree by `schedule::lower`.
pub fn parallel_safe(
    df: &Dataflow,
    sp: &StoragePlan,
    nest: &FusedNest,
    nest_index: usize,
    dim: &str,
) -> Option<Vec<usize>> {
    if !outer_vectorizable(df, nest, dim) {
        return None;
    }
    let mut private: BTreeSet<usize> = BTreeSet::new();
    for m in &nest.members {
        let cs = &df.callsites[m.callsite];
        for (_, vid, _) in &cs.writes {
            let sid = sp.of_var[*vid];
            let st = &sp.storages[sid];
            let full_along = st
                .dims
                .iter()
                .position(|d| d == dim)
                .map(|k| matches!(st.sizes[k], DimSize::Full))
                .unwrap_or(false);
            if full_along {
                continue; // chunks write disjoint slabs: share
            }
            if st.external.is_some() {
                return None; // contracted external: cannot replicate ABI arrays
            }
            if st.enclosing != (nest_index, nest_index) {
                return None; // window escapes the nest: later nests read one copy
            }
            private.insert(sid);
        }
    }
    Some(private.into_iter().collect())
}

/// Cap on per-member warm-up replay depth for time tiling. A fixpoint
/// that climbs past this (e.g. a scan reading its own past output, whose
/// self-edge diverges) means step-to-step dependence is not a bounded
/// halo, so the nest falls back to untiled.
const MAX_WARM_DEPTH: i64 = 64;

/// Per-member warm-up depths for temporal blocking along the nest's
/// outermost loop dim.
///
/// A time-tiled walk re-executes a block of the outer dim `t_block`
/// times before advancing. Re-execution pass `s > 0` restarts at the
/// block base `b` after pass `s − 1` marched rolling windows forward to
/// the block end, so window cells behind `b` hold *newer* coordinates
/// than the restarted reads expect. The fix is a per-member warm-up
/// replay: before each re-execution pass, member `m` is replayed over
/// loop coords `[b − D_m, b)` (clamped to its activity interval),
/// rebuilding exactly the cells reads at the block base reach back to.
/// Replays are idempotent — every invocation recomputes the same value
/// at the same coordinate — so results stay bitwise identical.
///
/// Depths come from a fixpoint over read edges. When consumer `m`
/// (replayed from depth `D_m`) reads a storage contracted along the dim
/// at add `A_r = shift_m + offset`, and in-nest producer `p` rewrites
/// that storage at add `A_w = shift_p + write_offset`, covering the
/// read requires `D_p ≥ D_m + (A_w − A_r)`. All depths start at 0 and
/// the constraints iterate to fixpoint.
///
/// Returns `Some(depths)` (one per nest member, in member order) when
/// the nest is time-tileable, `None` when it must stay untiled:
/// * a member runs a prologue/epilogue phase ([`Role::Pre`]/[`Role::Post`])
///   at the outer level, or anything reduces over the outer dim —
///   cross-step state with no bounded-halo form;
/// * a storage contracted along the dim has no in-nest writer to replay;
/// * the fixpoint exceeds [`MAX_WARM_DEPTH`] (scan-like self edges);
/// * a replay deeper than a window's allocation would wrap and clobber
///   cells the consumer still needs (`D_m + delta > alloc`).
///
/// Reads of storages kept [`DimSize::Full`] along the dim need no
/// warm-up: their cells are coordinate-distinct slabs that persist
/// across passes, and idempotent re-execution leaves them correct.
pub fn time_tile_depths(
    df: &Dataflow,
    sp: &StoragePlan,
    nest: &FusedNest,
) -> Option<Vec<i64>> {
    let dim = nest.dims.first()?;
    for m in &nest.members {
        if m.roles[0] != Role::Loop {
            return None;
        }
        if df.callsites[m.callsite].reduce_dims.contains(dim) {
            return None;
        }
    }
    struct Edge {
        consumer: usize,
        producer: usize,
        delta: i64,
        alloc: i64,
    }
    let member_index =
        |cs: CallsiteId| nest.members.iter().position(|m| m.callsite == cs);
    let mut edges: Vec<Edge> = Vec::new();
    for (mi, m) in nest.members.iter().enumerate() {
        let cs = &df.callsites[m.callsite];
        for (_, vid, offsets) in &cs.reads {
            let var = &df.vars[*vid];
            let k = match var.dims.iter().position(|d| d == dim) {
                Some(k) => k,
                None => continue, // dim-invariant: never rewritten along the dim
            };
            let st = &sp.storages[sp.of_var[*vid]];
            let alloc = match &st.sizes[k] {
                DimSize::Full => continue,
                DimSize::One => 1,
                DimSize::Window { alloc, .. } => *alloc,
            };
            let a_r = m.shifts[0] + offsets[k];
            let mut found_writer = false;
            for &wv in &st.vars {
                let wvar = &df.vars[wv];
                let Some(pcs) = wvar.producer else { continue };
                let Some(pi) = member_index(pcs) else { continue };
                found_writer = true;
                let a_w = nest.members[pi].shifts[0] + wvar.write_offset[k];
                edges.push(Edge { consumer: mi, producer: pi, delta: a_w - a_r, alloc });
            }
            if !found_writer {
                return None;
            }
        }
    }
    let mut depth = vec![0i64; nest.members.len()];
    loop {
        let mut changed = false;
        for e in &edges {
            let need = depth[e.consumer] + e.delta;
            if need > depth[e.producer] {
                if need > MAX_WARM_DEPTH {
                    return None;
                }
                depth[e.producer] = need;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    for e in &edges {
        if depth[e.consumer] + e.delta > e.alloc {
            return None;
        }
    }
    Some(depth)
}

/// Is the nest legal to wrap in a time-tile node — i.e. is every
/// step-to-step dependence along its outermost loop dim a bounded halo
/// that warm-up replay can rebuild? See [`time_tile_depths`].
pub fn time_tileable(df: &Dataflow, sp: &StoragePlan, nest: &FusedNest) -> bool {
    time_tile_depths(df, sp, nest).is_some()
}

/// Resolve the requested [`VecDim`] against the fused schedule into the
/// concrete strategy a program compiles (and is fingerprinted) with:
///
/// * vector length 1 → `Inner` (nothing to vectorize — a `tile` request
///   degrades to scalar the same way an explicit `Outer` does);
/// * `Outer(dim)` → itself when some nest passes [`outer_vectorizable`],
///   else a hard error (an explicitly requested illegal dim must fail
///   the compile, not silently degrade);
/// * `Auto` → the outermost legal outer dim of any nest, else `Inner`;
/// * with `tile` set, an unrequested `Inner` is upgraded to `Auto`
///   resolution (tiling needs an outer lane dim), and failure to find
///   one is a hard error — a tile request must not silently become
///   plain inner strips.
pub fn resolve_vec_dim(
    deck: &Deck,
    df: &Dataflow,
    fd: &FusedDag,
    opts: &AnalysisOptions,
) -> Result<VecDim, String> {
    if resolve_vector_len(deck, opts) <= 1 {
        return Ok(VecDim::Inner);
    }
    let requested = if opts.tile && opts.vec_dim == VecDim::Inner {
        VecDim::Auto
    } else {
        opts.vec_dim.clone()
    };
    match &requested {
        VecDim::Inner => Ok(VecDim::Inner),
        VecDim::Outer(d) => {
            if fd.nests.iter().any(|n| outer_vectorizable(df, n, d)) {
                Ok(VecDim::Outer(d.clone()))
            } else {
                Err(format!(
                    "vec-dim outer:{d} is not legal for deck `{}`: no fused nest has `{d}` as \
                     a k-independent outer loop (every member must iterate it with offset-0 \
                     accesses and no pipeline shift, nothing may reduce over it, and every \
                     written variable must be indexed by it)",
                    deck.name
                ))
            }
        }
        VecDim::Auto => {
            for n in &fd.nests {
                for d in n.dims.iter().take(n.dims.len().saturating_sub(1)) {
                    if outer_vectorizable(df, n, d) {
                        return Ok(VecDim::Outer(d.clone()));
                    }
                }
            }
            if opts.tile {
                return Err(format!(
                    "tile requested but deck `{}` has no k-independent outer loop dim to \
                     lane-tile (multi-dim tiling = outer lanes x inner strips)",
                    deck.name
                ));
            }
            Ok(VecDim::Inner)
        }
    }
}

/// Layout order of a storage's dims (indices into `Storage::dims`,
/// outermost-first). For intermediates of an outer-vectorized program
/// the lane dim moves innermost (stride 1), so per-member lane loops
/// touch contiguous slots; externals keep their declared row-major ABI
/// layout. All consumers of a storage plan — both code emitters and the
/// interpreter — derive strides through this one helper.
pub fn layout_order(s: &Storage, lane_dim: Option<&str>) -> Vec<usize> {
    let mut order: Vec<usize> = (0..s.dims.len()).collect();
    if s.external.is_none() {
        if let Some(d) = lane_dim {
            if let Some(k) = s.dims.iter().position(|x| x == d) {
                order.retain(|&x| x != k);
                order.push(k);
            }
        }
    }
    order
}

/// Run the full variable/storage analysis.
pub fn analyze(
    deck: &Deck,
    df: &Dataflow,
    fd: &FusedDag,
    opts: &AnalysisOptions,
) -> Result<StoragePlan, String> {
    let mut notes = Vec::new();
    let vlen = resolve_vector_len(deck, opts);
    // Outer-dim vectorization moves the lane expansion to the chosen
    // outer dim: the innermost dim keeps its scalar window sizes —
    // unless multi-dim tiling is on, which needs *both* expansions
    // (outer lane slots and inner window padding) so outer lanes and
    // inner lane-fission strips can run together.
    let outer_lane: Option<&str> = match &opts.vec_dim {
        VecDim::Outer(d) if vlen > 1 => Some(d.as_str()),
        _ => None,
    };
    let inner_vlen = if outer_lane.is_some() && !opts.tile { 1 } else { vlen };

    // ---- accumulator chaining -------------------------------------------
    // A reduction callsite that reads X and writes Y with the same base,
    // dims and offsets accumulates in place: X and Y must share storage
    // (paper §3.4 — the associative kernel's "many writes to the same
    // data").
    let mut alias_parent: Vec<usize> = (0..df.vars.len()).collect();
    fn find(p: &mut Vec<usize>, x: usize) -> usize {
        if p[x] != x {
            let r = find(p, p[x]);
            p[x] = r;
            r
        } else {
            x
        }
    }
    for cs in &df.callsites {
        if cs.reduce_dims.is_empty() {
            continue;
        }
        for (_, vin, oin) in &cs.reads {
            for (_, vout, oout) in &cs.writes {
                let a = &df.vars[*vin];
                let b = &df.vars[*vout];
                if base_of(&a.ident) == base_of(&b.ident) && a.dims == b.dims && oin == oout {
                    let (ra, rb) = (find(&mut alias_parent, *vin), find(&mut alias_parent, *vout));
                    if ra != rb {
                        alias_parent[rb] = ra;
                        notes.push(format!(
                            "accumulator chain: `{}` and `{}` share storage (reduction `{}`)",
                            a.ident, b.ident, cs.name
                        ));
                    }
                }
            }
        }
    }

    // ---- liveness / enclosing regions -----------------------------------
    // For each var: nest of producer and nests of consumers.
    let nest_of_cs = |c: CallsiteId| fd.nest_of(c);
    let mut enclosing: Vec<(usize, usize)> = Vec::with_capacity(df.vars.len());
    for v in &df.vars {
        let mut first = usize::MAX;
        let mut last = 0usize;
        if let Some(p) = v.producer {
            let n = nest_of_cs(p);
            first = first.min(n);
            last = last.max(n);
        }
        for r in &df.reads_of[v.id] {
            let n = nest_of_cs(r.consumer);
            first = first.min(n);
            last = last.max(n);
        }
        if first == usize::MAX {
            first = 0;
        }
        enclosing.push((first, last));
    }

    // ---- reuse patterns (Fig. 8) -----------------------------------------
    let mut reuse = Vec::new();
    for v in &df.vars {
        let mut offs: BTreeSet<Vec<i64>> =
            df.reads_of[v.id].iter().map(|r| r.offsets.clone()).collect();
        if offs.len() > 1 {
            // First-visited = lexicographically greatest (the iteration
            // reaches high offsets first relative to a moving point).
            let mut path: Vec<Vec<i64>> = offs.iter().cloned().collect();
            path.sort();
            path.reverse();
            reuse.push(ReusePattern { var: v.id, path });
        }
        offs.clear();
    }

    // ---- storage assignment ----------------------------------------------
    let mut storages: Vec<Storage> = Vec::new();
    let mut of_var: Vec<usize> = vec![usize::MAX; df.vars.len()];
    // Group vars by alias root.
    let mut groups: BTreeMap<usize, Vec<VarId>> = BTreeMap::new();
    for v in 0..df.vars.len() {
        let r = find(&mut alias_parent, v);
        groups.entry(r).or_default().push(v);
    }

    for (_, vars) in groups {
        let rep = vars[0];
        let v = &df.vars[rep];
        // Terminal handling: any terminal in the class makes it external.
        let mut external = None;
        for &x in &vars {
            match &df.vars[x].terminal {
                Terminal::Input { storage, .. } | Terminal::Output { storage, .. } => {
                    if external.is_some() {
                        return Err(format!(
                            "alias class of `{}` has multiple terminals",
                            v.ident
                        ));
                    }
                    external = Some(storage.clone());
                }
                Terminal::No => {}
            }
        }

        let (first, last) = vars
            .iter()
            .map(|&x| enclosing[x])
            .fold((usize::MAX, 0usize), |(f, l), (a, b)| (f.min(a), l.max(b)));
        let first = if first == usize::MAX { 0 } else { first };

        let sizes = if external.is_some() || !opts.contraction {
            vec![DimSize::Full; v.dims.len()]
        } else {
            contract_sizes(df, fd, &vars, opts, inner_vlen, &mut notes)?
        };

        let id = storages.len();
        for &x in &vars {
            of_var[x] = id;
        }
        storages.push(Storage {
            id,
            name: external.clone().unwrap_or_else(|| v.ident.clone()),
            vars,
            external,
            dims: v.dims.clone(),
            sizes,
            enclosing: (first, last),
        });
    }

    // Outer-lane expansion: under `VecDim::Outer(d)` every single-slot
    // intermediate indexed by `d` gains `vlen` slots, so `vlen` lanes of
    // the outer loop can be in flight without clobbering each other.
    // (Windows wider than 1 along `d` mean cross-lane dataflow; such
    // nests fail `outer_vectorizable` and run scalar, so their sizes
    // stay untouched.)
    if let Some(d) = outer_lane {
        for s in storages.iter_mut() {
            if s.external.is_some() {
                continue;
            }
            let k = match s.dims.iter().position(|x| x == d) {
                Some(k) => k,
                None => continue,
            };
            if s.sizes[k] != DimSize::One {
                continue;
            }
            let logical = vlen as i64;
            let alloc = if opts.pow2_windows {
                (logical as u64).next_power_of_two() as i64
            } else {
                logical
            };
            s.sizes[k] = DimSize::Window { w: logical, alloc };
            notes.push(format!(
                "outer-lane expand `{}` dim `{d}`: {logical} lanes (alloc {alloc})",
                s.name
            ));
        }
    }

    Ok(StoragePlan { storages, of_var, reuse, notes })
}

/// Base identifier of a family ident: `sum(acc)` → `acc`.
fn base_of(ident: &str) -> &str {
    match ident.rfind('(') {
        Some(p) => ident[p + 1..].trim_end_matches(')'),
        None => ident,
    }
}

/// Contraction: per-dim rolling-window computation for one alias class
/// (paper §3.5 "Contraction" + Fig. 9).
///
/// For each dim (outermost first) we compute the pipeline-aware reuse
/// distance `W = (s_P + wo) − min_over_reads(s_C + o) + 1`. The outermost
/// dim with `W > 1` becomes a rolling window; dims inside it must stay at
/// their full span (a window of rows); dims outside it with `W == 1`
/// collapse to a single slot. If every producer/consumer is not in one
/// nest, the class must keep its full span (it crosses a split — paper
/// §5.2: "the split ... prevents HFAV from performing array contraction").
fn contract_sizes(
    df: &Dataflow,
    fd: &FusedDag,
    vars: &[VarId],
    opts: &AnalysisOptions,
    vlen: usize,
    notes: &mut Vec<String>,
) -> Result<Vec<DimSize>, String> {
    let rep = &df.vars[vars[0]];
    let ndims = rep.dims.len();

    // All producers and consumers of the class must live in one nest.
    let mut nest: Option<usize> = None;
    for &x in vars {
        let v = &df.vars[x];
        if let Some(p) = v.producer {
            let n = fd.nest_of(p);
            if *nest.get_or_insert(n) != n {
                return Ok(vec![DimSize::Full; ndims]);
            }
        }
        for r in &df.reads_of[x] {
            let n = fd.nest_of(r.consumer);
            if *nest.get_or_insert(n) != n {
                return Ok(vec![DimSize::Full; ndims]);
            }
        }
    }
    let nest = match nest {
        Some(n) => &fd.nests[n],
        None => return Ok(vec![DimSize::Full; ndims]),
    };

    // Per-dim window across all vars in the class. `iterated[k]` records
    // whether any producer actually iterates the dim (Role::Loop) — the
    // condition under which a per-iteration value needs per-lane slots
    // when the schedule is vector-expanded.
    let mut w = vec![1i64; ndims];
    let mut iterated = vec![false; ndims];
    for &x in vars {
        let v = &df.vars[x];
        let producer = match v.producer {
            Some(p) => p,
            None => return Ok(vec![DimSize::Full; ndims]),
        };
        let pm = nest.member(producer).ok_or("producer not in nest")?;
        for (k, d) in v.dims.iter().enumerate() {
            let nd = match nest.dim_index(d) {
                Some(nd) => nd,
                None => continue,
            };
            // Skip dims the producer doesn't iterate (Pre/Post roles write
            // once per outer iteration — window 1).
            if pm.roles[nd] != Role::Loop {
                continue;
            }
            iterated[k] = true;
            let head = pm.shifts[nd] + v.write_offset[k];
            let mut oldest = head;
            for r in &df.reads_of[x] {
                let cm = nest.member(r.consumer).ok_or("consumer not in nest")?;
                let sc = if cm.roles[nd] == Role::Loop { cm.shifts[nd] } else { 0 };
                oldest = oldest.min(sc + r.offsets[k]);
            }
            w[k] = w[k].max(head - oldest + 1);
        }
    }

    // Assemble size classes: One* Window Full*.
    let mut sizes = Vec::with_capacity(ndims);
    let mut windowed = false;
    let pow2 = |logical: i64| -> i64 {
        if opts.pow2_windows {
            (logical.max(1) as u64).next_power_of_two() as i64
        } else {
            logical
        }
    };
    for k in 0..ndims {
        let innermost = rep.dims[k] == *nest.dims.last().unwrap();
        if windowed {
            sizes.push(DimSize::Full);
        } else if w[k] <= 1 {
            if innermost && iterated[k] && vlen > 1 {
                // Vector expansion of a loop-carried scalar (Fig. 9c): a
                // value produced and consumed within one iteration becomes
                // a vector of `vlen` lanes, so a lane-fissioned strip can
                // run each kernel across all lanes before the next kernel
                // reads any of them.
                let logical = vlen as i64;
                let alloc = pow2(logical);
                sizes.push(DimSize::Window { w: logical, alloc });
                windowed = true;
                notes.push(format!(
                    "vector-expand `{}` dim `{}`: {} lanes (alloc {})",
                    rep.ident, rep.dims[k], logical, alloc
                ));
            } else {
                sizes.push(DimSize::One);
            }
        } else if !opts.contract_innermost && innermost {
            // Tuning variant: keep the innermost dim at full span so the
            // steady state vectorizes (no circular-buffer dependency).
            sizes.push(DimSize::Full);
            windowed = true;
            notes.push(format!(
                "keep `{}` dim `{}` full (innermost; vectorization over contraction)",
                rep.ident, rep.dims[k]
            ));
        } else {
            let mut logical = w[k] + opts.rotation_slack;
            // Vector expansion applies to the innermost loop dim only
            // (Fig. 9c): rotation happens in-register across lanes.
            if innermost && vlen > 1 {
                logical += vlen as i64 - 1;
            }
            let alloc = pow2(logical);
            sizes.push(DimSize::Window { w: logical, alloc });
            windowed = true;
            notes.push(format!(
                "contract `{}` dim `{}`: window {} (alloc {})",
                rep.ident, rep.dims[k], logical, alloc
            ));
        }
    }
    Ok(sizes)
}

/// Is a lane-fissioned strip (run each member over `vlen` consecutive
/// innermost iterations before the next member — the execution order of
/// vector-expanded code, Fig. 9c) semantically equivalent to the scalar
/// interleaving for these members?
///
/// The one unsafe shape is a *scan observed mid-loop*: member A writes a
/// per-iteration value into storage without per-lane slots (its variable
/// lacks the innermost dim, or keeps `DimSize::One` there), and a
/// different member B reads that storage inside the same innermost loop —
/// after fission B would see only A's last-lane value. Accumulator chains
/// reading their *own* storage (reductions) stay safe: their lanes run
/// sequentially in iteration order.
pub fn lane_fission_safe(
    df: &Dataflow,
    sp: &StoragePlan,
    nest: &crate::fusion::FusedNest,
    members: &[&crate::fusion::Member],
) -> bool {
    let inner = match nest.dims.last() {
        Some(d) => d,
        None => return true,
    };
    let reads_storage = |m: &crate::fusion::Member, sid: usize| {
        df.callsites[m.callsite].reads.iter().any(|(_, vid, _)| sp.of_var[*vid] == sid)
    };
    for m in members {
        let cs = &df.callsites[m.callsite];
        for (_, vid, _) in &cs.writes {
            let var = &df.vars[*vid];
            let sid = sp.of_var[*vid];
            let lane_slotted = match var.dims.iter().position(|d| d == inner) {
                Some(k) => !matches!(sp.storages[sid].sizes[k], DimSize::One),
                None => false,
            };
            if lane_slotted {
                continue;
            }
            if members.iter().any(|o| o.callsite != m.callsite && reads_storage(o, sid)) {
                return false;
            }
        }
    }
    true
}

/// Insert a rolling input buffer for a terminal input variable: a
/// synthetic copy callsite (`__roll_<name>`) reads the terminal at offset
/// 0 and produces `__buf(<name>)`, and every consumer read is rewritten to
/// the buffered variable. Used for in/out alias chaining (paper §3.5) and
/// the in-place COSMO variant (§5.3). Must run *before* fusion.
pub fn insert_input_buffer(df: &mut Dataflow, var: VarId) -> Result<VarId, String> {
    let v = df.vars[var].clone();
    if !matches!(v.terminal, Terminal::Input { .. }) {
        return Err(format!("`{}` is not a terminal input", v.ident));
    }
    let buf_ident = format!("__buf({})", v.ident);
    if df.var_by_ident.contains_key(&buf_ident) {
        return Err(format!("`{}` already buffered", v.ident));
    }
    let buf = df.vars.len();
    df.vars.push(crate::dataflow::VarInfo {
        id: buf,
        ident: buf_ident.clone(),
        dims: v.dims.clone(),
        producer: None, // set below
        write_offset: vec![0; v.dims.len()],
        terminal: Terminal::No,
        span: v.span.clone(),
        ty: v.ty,
    });
    df.reads_of.push(Vec::new());
    df.var_by_ident.insert(buf_ident, buf);

    // Move existing reads to the buffer.
    let moved = std::mem::take(&mut df.reads_of[var]);
    df.reads_of[buf] = moved;
    for cs in df.callsites.iter_mut() {
        for (_, vid, _) in cs.reads.iter_mut() {
            if *vid == var {
                *vid = buf;
            }
        }
    }

    // Synthetic copy callsite.
    let id = df.callsites.len();
    let mut domain = BTreeMap::new();
    for d in &v.dims {
        let span = v
            .span
            .get(d)
            .ok_or_else(|| format!("no span on `{}` for `{d}`", v.ident))?;
        domain.insert(d.clone(), span.clone());
    }
    df.callsites.push(crate::dataflow::Callsite {
        id,
        rule: usize::MAX,
        name: format!("__roll_{}", v.ident),
        base_binding: BTreeMap::new(),
        dims: v.dims.clone(),
        domain,
        reads: vec![("x".into(), var, vec![0; v.dims.len()])],
        writes: vec![("y".into(), buf, vec![0; v.dims.len()])],
        reduce_dims: BTreeSet::new(),
    });
    df.vars[buf].producer = Some(id);
    df.reads_of[var].push(crate::dataflow::Read {
        consumer: id,
        param: "x".into(),
        offsets: vec![0; v.dims.len()],
    });
    Ok(buf)
}

/// In/out chaining (paper §3.5): for each declared terminal alias pair,
/// check whether the scheduled writes can overwrite positions still to be
/// read; if so, roll the input through a buffer. Call *before* fusion;
/// conservative: any aliased input with consumers is buffered.
pub fn chain_inouts(deck: &Deck, df: &mut Dataflow) -> Result<Vec<VarId>, String> {
    let mut buffered = Vec::new();
    for (in_store, out_store) in &deck.aliases {
        let vin = df
            .vars
            .iter()
            .find(|v| matches!(&v.terminal, Terminal::Input { storage, .. } if storage == in_store))
            .map(|v| v.id);
        let vout = df
            .vars
            .iter()
            .find(|v| {
                matches!(&v.terminal, Terminal::Output { storage, .. } if storage == out_store)
            })
            .map(|v| v.id);
        let (vin, _vout) = match (vin, vout) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                return Err(format!(
                    "alias pair ({in_store}, {out_store}) does not name terminal input/output"
                ))
            }
        };
        if !df.reads_of[vin].is_empty() {
            buffered.push(insert_input_buffer(df, vin)?);
        }
    }
    Ok(buffered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{parse_deck, testdecks};
    use crate::fusion::{fuse, FusionOptions};

    fn pipeline(src: &str) -> (crate::ir::Deck, Dataflow, FusedDag, StoragePlan) {
        let deck = parse_deck(src).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let sp = analyze(&deck, &df, &fd, &AnalysisOptions::default()).unwrap();
        (deck, df, fd, sp)
    }

    #[test]
    fn laplace_reuse_path_matches_paper() {
        let (_, df, _, sp) = pipeline(testdecks::LAPLACE);
        let cell = df.var("cell").unwrap().id;
        let r = sp.reuse.iter().find(|r| r.var == cell).unwrap();
        // Paper Fig. 8 (order j,i): first visit (j+1,i) ... our offsets are
        // [j_off, i_off]: path from greatest to least.
        assert_eq!(
            r.path,
            vec![vec![1, 0], vec![0, 1], vec![0, 0], vec![0, -1], vec![-1, 0]]
        );
    }

    #[test]
    fn chain1d_contracts_to_window3() {
        let (_, df, _, sp) = pipeline(testdecks::CHAIN1D);
        let dbl = df.var("dbl(u)").unwrap().id;
        let s = sp.storage_of(dbl);
        assert!(s.external.is_none());
        // dbl produced with shift 1, read at i±1 with shift 0:
        // head = 1, oldest = -1 → window 3.
        assert_eq!(s.sizes, vec![DimSize::Window { w: 3, alloc: 4 }]);
    }

    #[test]
    fn normalize_flux_not_contracted_across_split() {
        let (_, df, _, sp) = pipeline(testdecks::NORMALIZE);
        let f = df.var("flux(q)").unwrap().id;
        let s = sp.storage_of(f);
        // flux is consumed by normalize in the second nest → full storage
        // (paper §5.2: the split prevents contraction).
        assert_eq!(s.sizes, vec![DimSize::Full, DimSize::Full]);
    }

    #[test]
    fn normalize_accumulator_chains_to_scalar() {
        let (_, df, _, sp) = pipeline(testdecks::NORMALIZE);
        let z = df.var("zero(acc)").unwrap().id;
        let su = df.var("sum(acc)").unwrap().id;
        assert_eq!(sp.of_var[z], sp.of_var[su], "accumulator chain shares storage");
        let s = sp.storage_of(z);
        assert_eq!(s.sizes, vec![DimSize::One]);
    }

    #[test]
    fn footprint_counts_windows() {
        let (_, df, _, sp) = pipeline(testdecks::CHAIN1D);
        let mut ext = BTreeMap::new();
        ext.insert("N".to_string(), 1000i64);
        // Only intermediate is dbl(u): window alloc 4 words.
        assert_eq!(sp.intermediate_words(&df, &ext).unwrap(), 4);
    }

    #[test]
    fn no_contraction_option_gives_full() {
        let deck = parse_deck(testdecks::CHAIN1D).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let sp = analyze(
            &deck,
            &df,
            &fd,
            &AnalysisOptions { contraction: false, ..Default::default() },
        )
        .unwrap();
        let dbl = df.var("dbl(u)").unwrap().id;
        assert_eq!(sp.storage_of(dbl).sizes, vec![DimSize::Full]);
        let mut ext = BTreeMap::new();
        ext.insert("N".to_string(), 1000i64);
        // full span of dbl(u) = [0, N) = 1000 words.
        assert_eq!(sp.intermediate_words(&df, &ext).unwrap(), 1000);
    }

    #[test]
    fn input_buffer_insertion() {
        let deck = parse_deck(testdecks::LAPLACE).unwrap();
        let mut df = crate::dataflow::build(&deck).unwrap();
        let cell = df.var("cell").unwrap().id;
        let buf = insert_input_buffer(&mut df, cell).unwrap();
        assert_eq!(df.vars[buf].ident, "__buf(cell)");
        // All 5 stencil reads moved to the buffer; terminal keeps 1 copy read.
        assert_eq!(df.reads_of[buf].len(), 5);
        assert_eq!(df.reads_of[cell].len(), 1);
        // Re-fuse: single nest, buffer contracts to a 3-row window.
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        assert_eq!(fd.nests.len(), 1);
        let sp = analyze(&deck, &df, &fd, &AnalysisOptions::default()).unwrap();
        let s = sp.storage_of(buf);
        assert_eq!(s.sizes[0], DimSize::Window { w: 3, alloc: 4 });
        assert_eq!(s.sizes[1], DimSize::Full);
    }

    #[test]
    fn vector_expansion_grows_innermost_window() {
        let deck = parse_deck(testdecks::CHAIN1D).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let sp = analyze(
            &deck,
            &df,
            &fd,
            &AnalysisOptions { vector_len: Some(8), ..Default::default() },
        )
        .unwrap();
        let dbl = df.var("dbl(u)").unwrap().id;
        match &sp.storage_of(dbl).sizes[0] {
            DimSize::Window { w, alloc } => {
                assert_eq!(*w, 3 + 7);
                assert_eq!(*alloc, 16);
            }
            other => panic!("expected window, got {other:?}"),
        }
    }

    #[test]
    fn vector_expansion_gives_scalars_lane_slots() {
        // In a vector-expanded plan, a per-iteration scalar (window 1)
        // becomes a vector of vlen lanes so lane-fissioned strips can run
        // kernel-by-kernel (Fig. 9c); scalar plans keep the single slot.
        let src = r#"
name: passthru
iteration:
  order: [i]
  domains:
    i: [0, N]
kernels:
  a:
    declaration: a(double x, double &y);
    inputs: |
      x : u?[i?]
    outputs: |
      y : mid(u?[i?])
    body: "y = 2.0*x;"
  b:
    declaration: b(double y, double &z);
    inputs: |
      y : mid(u?[i?])
    outputs: |
      z : fin(u?[i?])
    body: "z = y + 1.0;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    fin(u[i]) => double g_o[i]
"#;
        let deck = parse_deck(src).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let scalar = analyze(&deck, &df, &fd, &AnalysisOptions::default()).unwrap();
        let mid = df.var("mid(u)").unwrap().id;
        assert_eq!(scalar.storage_of(mid).sizes, vec![DimSize::One]);
        let vec8 = analyze(
            &deck,
            &df,
            &fd,
            &AnalysisOptions { vector_len: Some(8), ..Default::default() },
        )
        .unwrap();
        assert_eq!(vec8.storage_of(mid).sizes, vec![DimSize::Window { w: 8, alloc: 8 }]);
    }

    #[test]
    fn outer_vectorizable_gates() {
        // cosmo: k carries no offsets, shifts or reductions → legal; j
        // carries the ±1 stencil offsets → illegal; i is innermost.
        let deck = parse_deck(crate::apps::cosmo::DECK).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let nest = &fd.nests[0];
        assert!(outer_vectorizable(&df, nest, "k"));
        assert!(!outer_vectorizable(&df, nest, "j"), "j carries stencil offsets");
        assert!(!outer_vectorizable(&df, nest, "i"), "i is the innermost dim");
        assert!(!outer_vectorizable(&df, nest, "nope"));
        // normalize: rows are independent, so j is legal in both nests —
        // even around the i-reduction (per-lane accumulator slots).
        let deck = parse_deck(testdecks::NORMALIZE).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        for nest in &fd.nests {
            assert!(outer_vectorizable(&df, nest, "j"), "nest {}", nest.id);
        }
        // laplace reads `cell` at j±1, but `cell` is a terminal input
        // (read-only), so j lanes are still independent → legal.
        let deck = parse_deck(testdecks::LAPLACE).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        assert!(outer_vectorizable(&df, &fd.nests[0], "j"));
    }

    #[test]
    fn outer_expansion_gives_lane_slots_and_skips_inner_padding() {
        let deck = parse_deck(crate::apps::cosmo::DECK).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let opts = AnalysisOptions {
            vector_len: Some(4),
            vec_dim: VecDim::Outer("k".to_string()),
            ..Default::default()
        };
        let sp = analyze(&deck, &df, &fd, &opts).unwrap();
        let lap = df.var("lap(u)").unwrap().id;
        let s = sp.storage_of(lap);
        // k: 4 lane slots; j: scalar-sized window (no vlen padding —
        // outer lanes replace in-register rotation); i: full row.
        assert_eq!(s.sizes[0], DimSize::Window { w: 4, alloc: 4 });
        assert!(matches!(s.sizes[1], DimSize::Window { w: 2, .. }), "{:?}", s.sizes);
        assert_eq!(s.sizes[2], DimSize::Full);
        // The lane dim moves innermost in intermediate layouts only.
        assert_eq!(layout_order(s, Some("k")), vec![1, 2, 0]);
        let su = sp.storage_of(df.var("u").unwrap().id);
        assert!(su.external.is_some());
        assert_eq!(layout_order(su, Some("k")), vec![0, 1, 2]);
        assert_eq!(layout_order(s, None), vec![0, 1, 2]);
    }

    #[test]
    fn tiled_expansion_gives_both_lane_slots_and_inner_padding() {
        let deck = parse_deck(crate::apps::cosmo::DECK).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let opts = AnalysisOptions {
            vector_len: Some(4),
            vec_dim: VecDim::Outer("k".to_string()),
            tile: true,
            ..Default::default()
        };
        let sp = analyze(&deck, &df, &fd, &opts).unwrap();
        let lap = df.var("lap(u)").unwrap().id;
        let s = sp.storage_of(lap);
        // k: 4 outer-lane slots (as under plain outer vectorization)...
        assert_eq!(s.sizes[0], DimSize::Window { w: 4, alloc: 4 });
        // ...AND the j window keeps its scalar size (j is not innermost)
        // while innermost-dim storage carries inner-strip padding: lap's
        // i dim is Full (a row), so check a per-iteration scalar instead.
        assert!(matches!(s.sizes[1], DimSize::Window { w: 2, .. }), "{:?}", s.sizes);
        assert_eq!(s.sizes[2], DimSize::Full);
        // fx(u) is read at i−1 and i (reuse window 2): under tiling its
        // i window gains inner-strip padding (w + vlen − 1) — the
        // invariant that makes inner fission legal inside outer strips.
        let flx = df.var("fx(u)").unwrap().id;
        let fs = sp.storage_of(flx);
        let ki = fs.dims.iter().position(|d| d == "i").unwrap();
        assert!(
            matches!(fs.sizes[ki], DimSize::Window { w, .. } if w >= 4),
            "fx i-dim must carry strip padding under tile: {:?}",
            fs.sizes
        );
        // Plain outer (no tile) keeps flx's i dim unexpanded.
        let plain = analyze(
            &deck,
            &df,
            &fd,
            &AnalysisOptions { tile: false, ..opts.clone() },
        )
        .unwrap();
        let ps = plain.storage_of(flx);
        assert!(
            !matches!(ps.sizes[ki], DimSize::Window { w, .. } if w >= 4),
            "no inner padding without tile: {:?}",
            ps.sizes
        );
    }

    #[test]
    fn resolve_vec_dim_tile_upgrades_and_errors() {
        // tile + Inner upgrades to Auto resolution (cosmo → outer:k)...
        let deck = parse_deck(crate::apps::cosmo::DECK).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let opts = |tile: bool, vd: VecDim| AnalysisOptions {
            vector_len: Some(4),
            vec_dim: vd,
            tile,
            ..Default::default()
        };
        assert_eq!(
            resolve_vec_dim(&deck, &df, &fd, &opts(true, VecDim::Inner)).unwrap(),
            VecDim::Outer("k".to_string())
        );
        // ...an explicit legal outer dim is kept...
        assert_eq!(
            resolve_vec_dim(&deck, &df, &fd, &opts(true, VecDim::Outer("k".into()))).unwrap(),
            VecDim::Outer("k".to_string())
        );
        // ...a 1-D deck has no outer dim: tile is a hard error...
        let deck1 = parse_deck(testdecks::CHAIN1D).unwrap();
        let df1 = crate::dataflow::build(&deck1).unwrap();
        let fd1 = fuse(&df1, &FusionOptions::default()).unwrap();
        let e = resolve_vec_dim(&deck1, &df1, &fd1, &opts(true, VecDim::Inner)).unwrap_err();
        assert!(e.contains("tile"), "{e}");
        // ...and at vlen 1 tile degrades to scalar like everything else.
        let scalar = AnalysisOptions {
            vector_len: Some(1),
            tile: true,
            ..Default::default()
        };
        assert_eq!(resolve_vec_dim(&deck1, &df1, &fd1, &scalar).unwrap(), VecDim::Inner);
    }

    #[test]
    fn resolve_vec_dim_auto_explicit_and_errors() {
        let deck = parse_deck(crate::apps::cosmo::DECK).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let opts = |vlen: usize, vd: VecDim| AnalysisOptions {
            vector_len: Some(vlen),
            vec_dim: vd,
            ..Default::default()
        };
        assert_eq!(
            resolve_vec_dim(&deck, &df, &fd, &opts(4, VecDim::Auto)).unwrap(),
            VecDim::Outer("k".to_string())
        );
        // vlen 1 degrades any request to Inner (nothing to vectorize).
        assert_eq!(
            resolve_vec_dim(&deck, &df, &fd, &opts(1, VecDim::Outer("k".into()))).unwrap(),
            VecDim::Inner
        );
        // An explicitly requested illegal dim is a hard error.
        let e = resolve_vec_dim(&deck, &df, &fd, &opts(4, VecDim::Outer("j".into()))).unwrap_err();
        assert!(e.contains("not legal"), "{e}");
        // 1-D decks have no outer dim: Auto falls back to Inner.
        let deck = parse_deck(testdecks::CHAIN1D).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let r = resolve_vec_dim(&deck, &df, &fd, &opts(8, VecDim::Auto)).unwrap();
        assert_eq!(r, VecDim::Inner);
    }

    #[test]
    fn time_tile_depths_chain1d_fixpoint() {
        let (_, df, fd, sp) = pipeline(testdecks::CHAIN1D);
        let nest = &fd.nests[0];
        let depths = time_tile_depths(&df, &sp, nest).expect("chain1d is time-tileable");
        // diff at the block base b reads dbl[b−1], which dbl (pipeline
        // shift +1) produced at loop coord b−2: the fixpoint must replay
        // dbl from depth 2. diff itself has no downstream reader → 0.
        let by_name = |n: &str| {
            nest.members
                .iter()
                .position(|m| df.callsites[m.callsite].name == n)
                .unwrap()
        };
        assert_eq!(depths[by_name("dbl")], 2);
        assert_eq!(depths[by_name("diff")], 0);
    }

    #[test]
    fn time_tileable_permits_inner_reductions_and_external_stencils() {
        // laplace reads only a terminal input → no warm-up edges at all.
        let (_, df, fd, sp) = pipeline(testdecks::LAPLACE);
        assert_eq!(time_tile_depths(&df, &sp, &fd.nests[0]), Some(vec![0]));
        // normalize reduces over i at the *inner* level: outer-level roles
        // are all Loop, so both nests stay tileable with zero depths (the
        // accumulator is rebuilt per row by the pass itself).
        let (_, df, fd, sp) = pipeline(testdecks::NORMALIZE);
        for nest in &fd.nests {
            let d = time_tile_depths(&df, &sp, nest)
                .unwrap_or_else(|| panic!("nest {} tileable", nest.id));
            assert!(d.iter().all(|&x| x == 0), "nest {}: {d:?}", nest.id);
        }
    }

    #[test]
    fn time_tileable_rejects_outer_reductions() {
        // Flip normalize's iteration order so the i-reduction runs over
        // the outermost dim: the accumulator carries cross-step state no
        // bounded halo expresses, so the gate must refuse that nest.
        let src = testdecks::NORMALIZE.replace("order: [j, i]", "order: [i, j]");
        let deck = parse_deck(&src).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        let sp = analyze(&deck, &df, &fd, &AnalysisOptions::default()).unwrap();
        let acc_cs = df.callsites.iter().find(|c| c.name == "norm_acc").unwrap().id;
        let nest = fd.nests.iter().find(|n| n.member(acc_cs).is_some()).unwrap();
        assert!(!time_tileable(&df, &sp, nest));
    }

    #[test]
    fn time_tileable_rejects_replay_deeper_than_window() {
        // Shrink dbl's rolling window below the depth-2 replay: a warm-up
        // pass would wrap the circular buffer and clobber cells the
        // consumer still needs, so the gate must fall back.
        let (_, df, fd, mut sp) = pipeline(testdecks::CHAIN1D);
        let nest = &fd.nests[0];
        assert!(time_tileable(&df, &sp, nest));
        let dbl = df.var("dbl(u)").unwrap().id;
        let sid = sp.of_var[dbl];
        sp.storages[sid].sizes[0] = DimSize::Window { w: 1, alloc: 1 };
        assert!(!time_tileable(&df, &sp, nest));
    }

    #[test]
    fn vec_dim_parse_round_trip() {
        assert_eq!("inner".parse::<VecDim>().unwrap(), VecDim::Inner);
        assert_eq!("auto".parse::<VecDim>().unwrap(), VecDim::Auto);
        assert_eq!("outer:k".parse::<VecDim>().unwrap(), VecDim::Outer("k".to_string()));
        assert!("outer:".parse::<VecDim>().is_err());
        assert!("sideways".parse::<VecDim>().is_err());
        assert_eq!(VecDim::Outer("k".to_string()).to_string(), "outer:k");
        assert_eq!(VecDim::default(), VecDim::Inner);
    }

    #[test]
    fn lane_fission_gate_blocks_scan_reads() {
        // normalize nest 0's innermost loop holds flux + the accumulator
        // chain: the accumulator reads only its own storage, so fission of
        // the loop members is safe. (Callers gate over the innermost
        // Loop-role members — Pre/Post members run outside strips.)
        let (_, df, fd, sp) = pipeline(testdecks::NORMALIZE);
        for nest in &fd.nests {
            let members: Vec<&crate::fusion::Member> = nest
                .members
                .iter()
                .filter(|m| m.roles.last() == Some(&Role::Loop))
                .collect();
            assert!(lane_fission_safe(&df, &sp, nest, &members), "nest {}", nest.id);
        }
        // Synthetic unsafe shape: pretend a member reads the accumulator
        // storage mid-loop by checking the gate against a member set where
        // one callsite writes acc and a different one reads it.
        let acc_writer = df
            .callsites
            .iter()
            .find(|c| c.name == "norm_acc")
            .expect("norm_acc callsite");
        let sum_reader = df
            .callsites
            .iter()
            .find(|c| c.name == "norm_root")
            .expect("norm_root callsite");
        let nest = fd
            .nests
            .iter()
            .find(|n| n.member(acc_writer.id).is_some())
            .expect("nest with norm_acc");
        // norm_root is Post-phase in reality; force-checking it as if it
        // were a strip member must trip the gate.
        if let Some(root_m) = nest.member(sum_reader.id) {
            let acc_m = nest.member(acc_writer.id).unwrap();
            assert!(!lane_fission_safe(&df, &sp, nest, &[acc_m, root_m]));
        }
    }
}
