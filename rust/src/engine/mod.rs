//! Unified execution-backend API: one open trait surface over every way
//! a compiled plan can run — the interpreter executor, generated C
//! (`cc` + dlopen), generated Rust (`rustc` + dlopen), and the PJRT
//! runtime — registered in a name-keyed [`BackendRegistry`].
//!
//! The contract mirrors the paper's §3.1 pipeline shape: one compile
//! path ([`crate::plan::PlanSpec`] → [`Program`]) feeding many execution
//! targets. A [`Backend`] turns a compiled plan into a prepared
//! [`Executable`] (compile the emitted C, load a module, resolve an AOT
//! artifact); an `Executable` runs the plan over named extents and
//! external arrays. Adding an engine is *additive*: implement the two
//! traits and register the backend in [`BackendRegistry::builtin`] —
//! `--engine` parsing, coordinator dispatch, availability probing, and
//! the prepared-executable cache all go through the registry, so there
//! is no per-engine dispatch anywhere else in the tree.

use crate::codegen::native::{self, CcOptions, NativeModule, RustcOptions};
use crate::exec::{self, registry::Registry, ExecOptions, Workspace};
use crate::plan::{PlanSpec, Program};
use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

/// Can a backend run on this host right now?
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Availability {
    Ready,
    /// Unavailable, with the reason (missing toolchain, unbuilt runtime).
    Missing(String),
}

impl Availability {
    pub fn is_ready(&self) -> bool {
        matches!(self, Availability::Ready)
    }
}

/// Everything a backend may need besides the compiled plan.
#[derive(Debug, Clone, Default)]
pub struct PrepareCtx {
    /// AOT artifacts directory (PJRT); `None` for in-process backends.
    pub artifacts: Option<PathBuf>,
}

/// Intra-job worker-thread count: a **runtime** knob, deliberately not
/// part of [`PlanSpec`] or `PlanKey` identity — one compiled plan (one
/// schedule tree, one loaded module) serves any core count, because the
/// schedule's `Parallel` levels defer chunking to run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Threads {
    /// One chunk: bitwise- and order-identical to the pre-parallel
    /// engine, and the default everywhere (paper figures are serial).
    #[default]
    Serial,
    /// Exactly `n` chunk workers.
    Fixed(usize),
    /// One chunk worker per available core.
    Auto,
}

impl Threads {
    /// Concrete worker count (>= 1).
    pub fn resolve(self) -> usize {
        match self {
            Threads::Serial => 1,
            Threads::Fixed(n) => n.max(1),
            Threads::Auto => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
        }
    }

    /// The `--threads` spelling (`serial` | `auto` | a positive count).
    pub fn label(self) -> String {
        match self {
            Threads::Serial => "serial".to_string(),
            Threads::Fixed(n) => n.to_string(),
            Threads::Auto => "auto".to_string(),
        }
    }
}

impl std::str::FromStr for Threads {
    type Err = String;
    fn from_str(s: &str) -> Result<Threads, String> {
        match s.trim() {
            "serial" | "1" => Ok(Threads::Serial),
            "auto" => Ok(Threads::Auto),
            t => match t.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(Threads::Fixed(n)),
                _ => Err(format!("bad --threads `{s}` (serial | auto | N >= 1)")),
            },
        }
    }
}

/// Per-run execution knobs, passed through [`Executable::run_with`].
/// Everything here is excluded from plan fingerprints by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RunConfig {
    pub threads: Threads,
}

impl RunConfig {
    pub fn with_threads(threads: Threads) -> RunConfig {
        RunConfig { threads }
    }
}

/// A prepared, runnable form of one compiled plan. Implementations are
/// shared pool-wide behind the coordinator's prepared-executable cache,
/// so they must be stateless across runs (per-run scratch lives in the
/// caller's [`Workspace`], per-run knobs in the [`RunConfig`]).
pub trait Executable: Send + Sync {
    /// Run the plan once over `extents` and the named external `arrays`
    /// (inputs seeded by the caller, outputs zero-filled; results are
    /// written back into `arrays`), under the given runtime knobs.
    /// Engines without a parallel path ignore `cfg`.
    fn run_with(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        ws: &mut Workspace,
        cfg: &RunConfig,
    ) -> Result<(), String>;

    /// [`Executable::run_with`] at the default (serial) knobs.
    fn run(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        ws: &mut Workspace,
    ) -> Result<(), String> {
        self.run_with(extents, arrays, ws, &RunConfig::default())
    }
}

/// An execution engine: knows its registry name, whether the host can
/// run it, and how to turn a compiled plan into an [`Executable`].
pub trait Backend: Send + Sync {
    /// Registry name (`exec` | `native` | `rust` | `pjrt`): the spelling
    /// used by `--engine`, job traces, and prepared-cache key tags.
    fn name(&self) -> &str;

    /// Probe host support (toolchains, runtimes). Serving degrades
    /// per-job on unavailable backends; the CLI fails fast with this
    /// message before spawning a coordinator.
    fn available(&self) -> Availability;

    /// Does this backend execute the compiled plan itself (true for all
    /// in-process engines)? PJRT runs fixed pre-built artifacts, so the
    /// plan's vector length says nothing about what it executes and the
    /// serving metrics skip it.
    fn executes_plan(&self) -> bool {
        true
    }

    /// Prepare `prog` for execution (emit + compile + load for the
    /// native backends). Expensive; the coordinator caches the result
    /// per `(plan key, backend name)` pool-wide.
    fn prepare(
        &self,
        spec: &PlanSpec,
        prog: &Arc<Program>,
        ctx: &PrepareCtx,
    ) -> Result<Box<dyn Executable>, String>;
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Name-keyed set of the known engines. All engine lookup — `--engine`
/// parsing, trace parsing, coordinator dispatch, CI smoke — goes through
/// here, so an engine exists exactly when it is registered.
pub struct BackendRegistry {
    backends: Vec<Box<dyn Backend>>,
}

impl BackendRegistry {
    /// The built-in engines, in documentation order.
    pub fn builtin() -> BackendRegistry {
        BackendRegistry {
            backends: vec![
                Box::new(InterpBackend),
                Box::new(NativeCBackend),
                Box::new(GenRustBackend),
                Box::new(PjrtBackend),
            ],
        }
    }

    /// Look up a backend by registry name.
    pub fn get(&self, name: &str) -> Result<&dyn Backend, String> {
        self.backends
            .iter()
            .map(|b| b.as_ref())
            .find(|b| b.name() == name)
            .ok_or_else(|| format!("unknown engine `{name}` ({})", self.names().join("|")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.backends.iter().map(|b| b.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Backend> {
        self.backends.iter().map(|b| b.as_ref())
    }
}

/// The process-wide backend registry.
pub fn registry() -> &'static BackendRegistry {
    static REG: OnceLock<BackendRegistry> = OnceLock::new();
    REG.get_or_init(BackendRegistry::builtin)
}

// ---------------------------------------------------------------------------
// Interpreter backend (`exec`)
// ---------------------------------------------------------------------------

/// The in-process schedule interpreter ([`crate::exec`]).
struct InterpBackend;

struct InterpExecutable {
    prog: Arc<Program>,
    reg: Registry,
    opts: ExecOptions,
    /// Declared external-input names: the executor is handed exactly
    /// these (output buffers in `arrays` must not pre-fill externals).
    input_names: BTreeSet<String>,
}

impl Executable for InterpExecutable {
    fn run_with(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        ws: &mut Workspace,
        cfg: &RunConfig,
    ) -> Result<(), String> {
        // Move (not clone) the declared inputs into the executor's input
        // map; everything is restored afterwards so callers see inputs
        // and outputs side by side, like the module backends.
        let mut inputs = BTreeMap::new();
        for name in &self.input_names {
            if let Some(v) = arrays.remove(name) {
                inputs.insert(name.clone(), v);
            }
        }
        let opts = ExecOptions { threads: cfg.threads.resolve(), ..self.opts };
        let result = exec::run_with(&self.prog, &self.reg, extents, &inputs, opts, ws);
        arrays.append(&mut inputs);
        for (k, v) in result? {
            arrays.insert(k, v);
        }
        Ok(())
    }
}

impl Backend for InterpBackend {
    fn name(&self) -> &str {
        "exec"
    }

    fn available(&self) -> Availability {
        Availability::Ready
    }

    fn prepare(
        &self,
        _spec: &PlanSpec,
        prog: &Arc<Program>,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn Executable>, String> {
        Ok(Box::new(InterpExecutable {
            prog: prog.clone(),
            reg: crate::apps::builtin_registry(),
            opts: ExecOptions::default(),
            input_names: prog.external_inputs().into_iter().map(|(n, _, _)| n).collect(),
        }))
    }
}

// ---------------------------------------------------------------------------
// Native module backends (`native`, `rust`)
// ---------------------------------------------------------------------------

impl Executable for NativeModule {
    fn run_with(
        &self,
        extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        _ws: &mut Workspace,
        cfg: &RunConfig,
    ) -> Result<(), String> {
        NativeModule::run_with(self, extents, arrays, cfg.threads)
    }
}

/// Generated C compiled with the system compiler and dlopen'd.
struct NativeCBackend;

impl Backend for NativeCBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn available(&self) -> Availability {
        static PROBE: OnceLock<Availability> = OnceLock::new();
        PROBE.get_or_init(|| probe_compiler(&CcOptions::default().cc, "C compiler")).clone()
    }

    fn prepare(
        &self,
        _spec: &PlanSpec,
        prog: &Arc<Program>,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn Executable>, String> {
        Ok(Box::new(native::build(prog, &CcOptions::default())?))
    }
}

/// The Rust emitter's output compiled with `rustc --crate-type cdylib`
/// and loaded through the same dlopen harness as the C backend.
struct GenRustBackend;

impl Backend for GenRustBackend {
    fn name(&self) -> &str {
        "rust"
    }

    fn available(&self) -> Availability {
        static PROBE: OnceLock<Availability> = OnceLock::new();
        PROBE
            .get_or_init(|| probe_compiler(&RustcOptions::default().rustc, "Rust compiler"))
            .clone()
    }

    fn prepare(
        &self,
        _spec: &PlanSpec,
        prog: &Arc<Program>,
        _ctx: &PrepareCtx,
    ) -> Result<Box<dyn Executable>, String> {
        Ok(Box::new(native::build_rust(prog, &RustcOptions::default())?))
    }
}

/// Shared `<compiler> --version` probe.
fn probe_compiler(cmd: &str, what: &str) -> Availability {
    match std::process::Command::new(cmd).arg("--version").output() {
        Ok(out) if out.status.success() => Availability::Ready,
        Ok(_) => Availability::Missing(format!("{what} `{cmd}` failed its --version probe")),
        Err(e) => Availability::Missing(format!("{what} `{cmd}` not found: {e}")),
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (`pjrt`)
// ---------------------------------------------------------------------------

/// AOT JAX/Pallas artifacts on the PJRT CPU client. The native XLA
/// toolchain is not vendored in this build ([`crate::runtime`]), so runs
/// degrade to a clear per-job error until it returns.
struct PjrtBackend;

struct PjrtExecutable {
    artifacts: PathBuf,
    artifact: String,
    /// Plan-declared external input/output names, in declaration order —
    /// the positional binding to the artifact's buffer signature.
    inputs: Vec<String>,
    outputs: Vec<String>,
    /// Latched "client not linked" failure, replayed so a trace full of
    /// PJRT jobs fails each one cheaply instead of re-reading the
    /// manifest per job. Only that build-constant error is latched.
    runtime_err: OnceLock<String>,
}

impl Executable for PjrtExecutable {
    // PJRT artifacts are fixed programs: the threads knob does not apply.
    fn run_with(
        &self,
        _extents: &BTreeMap<String, i64>,
        arrays: &mut BTreeMap<String, Vec<f64>>,
        _ws: &mut Workspace,
        _cfg: &RunConfig,
    ) -> Result<(), String> {
        // PJRT clients are not Send; when the real client is re-vendored
        // this must hold a per-thread runtime cache instead.
        if let Some(e) = self.runtime_err.get() {
            return Err(e.clone());
        }
        let rt = match crate::runtime::Runtime::cpu(&self.artifacts) {
            Ok(rt) => rt,
            Err(e) => {
                // Latch only the build-constant "client not linked"
                // error; environment errors (missing dir, bad manifest)
                // stay retryable so a fixed setup is picked up by later
                // jobs instead of poisoning the pool-wide cache entry.
                if e == crate::runtime::PJRT_UNAVAILABLE {
                    let _ = self.runtime_err.set(e.clone());
                }
                return Err(e);
            }
        };
        let exe = rt.load(&self.artifact)?;
        // Artifacts are fixed-shape: the positional binding below is
        // only sound when both arity and element counts line up, so a
        // job whose grid does not match the AOT shapes fails closed
        // instead of feeding out-of-shape buffers to the client.
        if exe.meta.inputs.len() != self.inputs.len()
            || exe.meta.outputs.len() != self.outputs.len()
        {
            return Err(format!(
                "artifact `{}` has {} inputs/{} outputs; plan declares {}/{}",
                self.artifact,
                exe.meta.inputs.len(),
                exe.meta.outputs.len(),
                self.inputs.len(),
                self.outputs.len()
            ));
        }
        let refs: Vec<&[f64]> = self
            .inputs
            .iter()
            .zip(&exe.meta.inputs)
            .map(|(n, shape)| {
                let v = arrays.get(n).ok_or_else(|| format!("missing input `{n}`"))?;
                let want: usize = shape.iter().product();
                if v.len() != want {
                    return Err(format!(
                        "input `{n}`: artifact `{}` expects {want} elements, job has {}",
                        self.artifact,
                        v.len()
                    ));
                }
                Ok(v.as_slice())
            })
            .collect::<Result<_, _>>()?;
        let out = exe.run(&refs)?;
        for (name, vals) in self.outputs.iter().zip(out) {
            arrays.insert(name.clone(), vals);
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn available(&self) -> Availability {
        Availability::Missing(crate::runtime::PJRT_UNAVAILABLE.to_string())
    }

    fn executes_plan(&self) -> bool {
        false
    }

    fn prepare(
        &self,
        spec: &PlanSpec,
        prog: &Arc<Program>,
        ctx: &PrepareCtx,
    ) -> Result<Box<dyn Executable>, String> {
        let artifacts = ctx
            .artifacts
            .clone()
            .ok_or_else(|| "no artifacts dir — PJRT unavailable".to_string())?;
        let app = spec
            .app_name()
            .ok_or_else(|| "PJRT serves only built-in apps (fixed AOT artifacts)".to_string())?;
        let base = if app == "hydro2d" { "hydro" } else { app };
        let suffix = match spec.variant_kind() {
            crate::apps::Variant::Hfav => "fused",
            crate::apps::Variant::Autovec => "unfused",
        };
        Ok(Box::new(PjrtExecutable {
            artifacts,
            artifact: format!("{base}_{suffix}"),
            inputs: prog.external_inputs().into_iter().map(|(n, _, _)| n).collect(),
            outputs: prog.external_outputs().into_iter().map(|(n, _, _)| n).collect(),
            runtime_err: OnceLock::new(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn registry_names_round_trip() {
        let reg = registry();
        let names = reg.names();
        assert_eq!(names, vec!["exec", "native", "rust", "pjrt"]);
        for name in names {
            assert_eq!(reg.get(name).unwrap().name(), name);
        }
    }

    #[test]
    fn unknown_engine_lists_known_names() {
        let e = registry().get("tpu").unwrap_err();
        assert!(e.contains("unknown engine `tpu`"), "{e}");
        for name in registry().names() {
            assert!(e.contains(name), "`{name}` missing from: {e}");
        }
    }

    #[test]
    fn exec_backend_runs_a_plan() {
        let spec = crate::plan::PlanSpec::app("laplace");
        let prog = Arc::new(spec.compile().unwrap());
        let backend = registry().get("exec").unwrap();
        assert!(backend.available().is_ready());
        assert!(backend.executes_plan());
        let exe = backend.prepare(&spec, &prog, &PrepareCtx::default()).unwrap();
        let n = 12usize;
        let ext: BTreeMap<String, i64> =
            [("Nj".to_string(), n as i64), ("Ni".to_string(), n as i64)].into();
        let u = apps::seeded(n * n, 3);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_cell".to_string(), u.clone());
        // Pre-filled output must not perturb the executor.
        arrays.insert("g_out".to_string(), vec![7.0; n * n]);
        let mut ws = Workspace::new();
        exe.run(&ext, &mut arrays, &mut ws).unwrap();
        let want = apps::laplace::reference(&u, n, n);
        assert!(apps::max_err(&arrays["g_out"], &want) < 1e-12);
        // Inputs survive the run (module-backend parity).
        assert_eq!(arrays["g_cell"], u);
    }

    #[test]
    fn threads_knob_parses_and_resolves() {
        assert_eq!("serial".parse::<Threads>().unwrap(), Threads::Serial);
        assert_eq!("1".parse::<Threads>().unwrap(), Threads::Serial);
        assert_eq!("4".parse::<Threads>().unwrap(), Threads::Fixed(4));
        assert_eq!("auto".parse::<Threads>().unwrap(), Threads::Auto);
        assert!("0".parse::<Threads>().is_err());
        assert!("fast".parse::<Threads>().is_err());
        assert_eq!(Threads::Serial.resolve(), 1);
        assert_eq!(Threads::Fixed(3).resolve(), 3);
        assert!(Threads::Auto.resolve() >= 1);
        assert_eq!(Threads::default(), Threads::Serial);
        assert_eq!(RunConfig::default().threads, Threads::Serial);
        assert_eq!(Threads::Fixed(2).label(), "2");
    }

    #[test]
    fn exec_backend_threads_are_bitwise_identical() {
        // Same prepared executable, different RunConfig: one plan serves
        // any core count and results never move.
        let spec = crate::plan::PlanSpec::app("laplace");
        let prog = Arc::new(spec.compile().unwrap());
        let exe = registry()
            .get("exec")
            .unwrap()
            .prepare(&spec, &prog, &PrepareCtx::default())
            .unwrap();
        let (nj, ni) = (10usize, 17usize);
        let ext: BTreeMap<String, i64> =
            [("Nj".to_string(), nj as i64), ("Ni".to_string(), ni as i64)].into();
        let u = apps::seeded(nj * ni, 3);
        let mut run = |threads: Threads| {
            let mut arrays = BTreeMap::new();
            arrays.insert("g_cell".to_string(), u.clone());
            let mut ws = Workspace::new();
            exe.run_with(&ext, &mut arrays, &mut ws, &RunConfig::with_threads(threads)).unwrap();
            arrays.remove("g_out").unwrap()
        };
        let serial = run(Threads::Serial);
        for t in [Threads::Fixed(2), Threads::Fixed(3), Threads::Auto] {
            assert_eq!(run(t), serial, "{t:?} must be bitwise identical");
        }
    }

    #[test]
    fn pjrt_backend_reports_unavailable() {
        let backend = registry().get("pjrt").unwrap();
        assert!(!backend.executes_plan());
        match backend.available() {
            Availability::Missing(why) => assert!(why.contains("PJRT"), "{why}"),
            Availability::Ready => panic!("stub build must report PJRT missing"),
        }
        let spec = crate::plan::PlanSpec::app("laplace");
        let prog = Arc::new(spec.compile().unwrap());
        let e = backend.prepare(&spec, &prog, &PrepareCtx::default()).unwrap_err();
        assert!(e.contains("artifacts"), "{e}");
    }

    #[test]
    fn pjrt_rejects_non_builtin_decks() {
        let spec = crate::plan::PlanSpec::deck_src(crate::frontend::testdecks::LAPLACE);
        let prog = Arc::new(spec.compile().unwrap());
        let ctx = PrepareCtx { artifacts: Some(PathBuf::from("artifacts")) };
        let e = registry().get("pjrt").unwrap().prepare(&spec, &prog, &ctx).unwrap_err();
        assert!(e.contains("built-in"), "{e}");
    }
}
