//! Greedy reproducer minimization: repeatedly try structural shrinks of
//! a failing [`GenDeck`], keeping a mutation only if the caller's
//! failure oracle still fires, until no candidate helps.
//!
//! Every mutation can only *shrink* the deck — drop stages, drop the
//! outermost dim, remove reads, move offsets toward zero, simplify
//! bodies — so a deck that was legal by construction stays legal
//! (the transitive input reach never grows), and the loop terminates:
//! each accepted candidate strictly decreases a finite size measure.

use super::gen::{Expr, GenDeck, GenRead, GenStage};

/// Shrink `deck` while `fails` keeps returning true. Returns the
/// minimized deck and the number of accepted shrink steps.
pub fn minimize<F: Fn(&GenDeck) -> bool>(deck: &GenDeck, fails: F) -> (GenDeck, usize) {
    let mut cur = deck.clone();
    let mut steps = 0usize;
    loop {
        let mut improved = false;
        for cand in candidates(&cur) {
            if fails(&cand) {
                cur = cand;
                steps += 1;
                improved = true;
                break;
            }
        }
        if !improved {
            return (cur, steps);
        }
    }
}

/// All single-step shrinks of `deck`, most aggressive first (bigger cuts
/// earlier means fewer oracle invocations on the way down).
fn candidates(deck: &GenDeck) -> Vec<GenDeck> {
    let mut out = Vec::new();

    // 1. Retarget the goal at an earlier grid value and garbage-collect
    //    everything that no longer feeds it.
    for v in 0..deck.goal {
        if !deck.values[v].reduced {
            if let Some(d) = retarget(deck, v) {
                out.push(d);
            }
        }
    }

    // 2. Drop the outermost dim (only when no reduced value would end up
    //    zero-dimensional).
    if deck.ndims() >= 2 && !(deck.ndims() == 2 && deck.values.iter().any(|v| v.reduced)) {
        let mut d = deck.clone();
        d.dims.remove(0);
        d.lo.remove(0);
        d.hi_back.remove(0);
        for st in &mut d.stages {
            for r in &mut st.reads {
                r.offsets.remove(0);
            }
        }
        out.push(d);
    }

    // 3. Remove one non-spine read (keep each stage's first read so the
    //    chain stays connected), re-pointing the expression at a plain
    //    sum of the surviving params.
    for (si, st) in deck.stages.iter().enumerate() {
        for ri in (1..st.reads.len()).rev() {
            let mut d = deck.clone();
            d.stages[si].reads.remove(ri);
            d.stages[si].expr = param_sum(d.stages[si].reads.len());
            out.push(d);
        }
    }

    // 4. Zero one read's offsets.
    for (si, st) in deck.stages.iter().enumerate() {
        for (ri, r) in st.reads.iter().enumerate() {
            if r.offsets.iter().any(|&o| o != 0) {
                let mut d = deck.clone();
                d.stages[si].reads[ri].offsets = vec![0; deck.ndims()];
                out.push(d);
            }
        }
    }

    // 5. Halve one nonzero offset toward zero.
    for (si, st) in deck.stages.iter().enumerate() {
        for (ri, r) in st.reads.iter().enumerate() {
            for (di, &o) in r.offsets.iter().enumerate() {
                if o.abs() > 1 {
                    let mut d = deck.clone();
                    d.stages[si].reads[ri].offsets[di] = o.signum();
                    out.push(d);
                }
            }
        }
    }

    // 6. Replace one compound body with the plain sum of its params.
    for (si, st) in deck.stages.iter().enumerate() {
        if !st.reads.is_empty() && st.expr != param_sum(st.reads.len()) {
            let mut d = deck.clone();
            d.stages[si].expr = param_sum(st.reads.len());
            out.push(d);
        }
    }

    // 7. Tighten domain slack: lower bounds down to the exact input
    //    reach, upper back-off to zero.
    {
        let (neg, _) = deck.input_reach();
        let mut d = deck.clone();
        let mut changed = false;
        for dim in 0..deck.ndims() {
            if d.lo[dim] > neg[dim] {
                d.lo[dim] = neg[dim];
                changed = true;
            }
            if d.hi_back[dim] != 0 {
                d.hi_back[dim] = 0;
                changed = true;
            }
        }
        if changed {
            out.push(d);
        }
    }

    out
}

/// `p0 + p1 + ...` — the simplest body that still uses every param.
fn param_sum(n: usize) -> Expr {
    let mut e = Expr::Param(0);
    for i in 1..n {
        e = Expr::Add(Box::new(e), Box::new(Expr::Param(i)));
    }
    e
}

/// New deck whose goal is grid value `new_goal`, with all stages and
/// values that don't transitively feed it removed and indices remapped.
fn retarget(deck: &GenDeck, new_goal: usize) -> Option<GenDeck> {
    let nv = deck.values.len();
    let mut live = vec![false; nv];
    live[new_goal] = true;
    // Stages are in producer order; a reverse sweep marks producers of
    // every live consumer.
    for st in deck.stages.iter().rev() {
        if live[st.out] {
            for r in &st.reads {
                if r.value >= 0 {
                    live[r.value as usize] = true;
                }
            }
        }
    }
    let mut remap = vec![usize::MAX; nv];
    let mut values = Vec::new();
    for (i, v) in deck.values.iter().enumerate() {
        if live[i] {
            remap[i] = values.len();
            values.push(v.clone());
        }
    }
    if values.len() == nv {
        return None; // nothing died — not a shrink
    }
    let stages: Vec<GenStage> = deck
        .stages
        .iter()
        .filter(|st| live[st.out])
        .map(|st| GenStage {
            kernel: st.kernel.clone(),
            reads: st
                .reads
                .iter()
                .map(|r| GenRead {
                    value: if r.value < 0 { -1 } else { remap[r.value as usize] as isize },
                    offsets: r.offsets.clone(),
                })
                .collect(),
            expr: st.expr.clone(),
            out: remap[st.out],
        })
        .collect();
    let mut d = deck.clone();
    d.values = values;
    d.stages = stages;
    d.goal = remap[new_goal];
    Some(d)
}

#[cfg(test)]
mod tests {
    use super::super::gen::generate;
    use super::*;

    /// Find a seed whose deck has >= 2 stencil stages and multi-read
    /// stages, so shrinks have room to act.
    fn rich_deck() -> GenDeck {
        (0..512u64)
            .map(generate)
            .find(|d| d.stages.len() >= 3 && d.stages.iter().any(|s| s.reads.len() >= 2))
            .expect("no rich deck in seed range")
    }

    #[test]
    fn always_failing_oracle_reaches_a_fixpoint_minimum() {
        let deck = rich_deck();
        let (min, steps) = minimize(&deck, |_| true);
        assert!(steps > 0, "rich deck should shrink at least once");
        // Fixpoint under "always fails": single dim, single stage,
        // single zero-offset read, trivial body, tight domain.
        assert_eq!(min.ndims(), 1);
        assert_eq!(min.stages.len(), 1);
        assert_eq!(min.stages[0].reads.len(), 1);
        assert!(min.stages[0].reads[0].offsets.iter().all(|&o| o == 0));
        assert_eq!(min.goal, 0);
        // Still legal: parses and validates.
        crate::frontend::parse_deck(&min.yaml()).expect("minimized deck must stay parseable");
    }

    #[test]
    fn oracle_constraints_are_respected() {
        let deck = rich_deck();
        let nd = deck.ndims();
        // Oracle: "fails" only while the dim count is intact and `f1`
        // survives — minimization must never accept a shrink past that.
        let (min, _) =
            minimize(&deck, |d| d.ndims() == nd && d.stages.iter().any(|s| s.kernel == "f1"));
        assert_eq!(min.ndims(), nd);
        assert!(min.stages.iter().any(|s| s.kernel == "f1"));
    }

    #[test]
    fn shrinks_never_grow_input_reach() {
        let deck = rich_deck();
        let (neg0, pos0) = deck.input_reach();
        for cand in candidates(&deck) {
            // Dim count may change; compare only when it matches.
            if cand.ndims() == deck.ndims() {
                let (neg, pos) = cand.input_reach();
                for d in 0..deck.ndims() {
                    assert!(neg[d] <= neg0[d] && pos[d] <= pos0[d]);
                }
                crate::frontend::parse_deck(&cand.yaml())
                    .expect("every shrink candidate must stay parseable");
            }
        }
    }
}
