//! Random-deck differential fuzzer with verifier-backed triage.
//!
//! Three pieces, surfaced through `hfav fuzz`:
//!
//! * [`gen`] — a seeded, legal-by-construction random deck generator:
//!   DAGs of 1–3-dim stencil chains and normalization-shaped reductions
//!   with random window depths, offsets and extents-relative bounds,
//!   whose kernel bodies are expression trees rendered identically for
//!   the C backend, the Rust backend, and the interpreter registry.
//! * [`driver`] — the two-stage campaign loop: stage 1 compiles each
//!   deck at random knob settings with the schedule verifier as a
//!   static oracle (and panics contained); stage 2 runs every surviving
//!   plan on each available engine against the interpreted unfused
//!   scalar baseline at 1e-12.
//! * [`minimize`] — greedy structural shrinking of failing decks, so
//!   every finding lands as a small self-contained reproducer deck
//!   (`traces/fuzz-regress-*.yaml`) with its exact knob line.
//!
//! The split keeps the oracle honest: the generator promises legality,
//! the verifier and the differential promise correctness, and anything
//! in between — a panic, a verifier rejection, a cross-engine mismatch
//! — is a pipeline bug with a replayable witness.

pub mod driver;
pub mod gen;
pub mod minimize;

pub use driver::{run, Finding, FuzzConfig, FuzzEngine, FuzzReport, Knobs, TOL};
pub use gen::{generate, GenDeck};
pub use minimize::minimize;
