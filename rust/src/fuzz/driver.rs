//! Two-stage fuzz driver over generated decks.
//!
//! For every seed, [`gen::generate`] produces a legal-by-construction
//! deck, and a handful of random knob settings (vlen × vec_dim × aligned
//! × tiled × time_tile × threads) push it through the full pipeline:
//!
//! * **Stage 1 (cheap, always on)** — compile the fused variant at each
//!   knob set and run [`crate::verify::check_program`] as the static
//!   oracle. A compile `Err` that is not a verifier rejection is a
//!   *legality skip* (illegal knob corner, e.g. tiling a deck with
//!   loop-carried reuse on every dim); a panic, a verifier-gate
//!   rejection, or verifier errors on a compiled plan are findings.
//! * **Stage 2 (differential)** — run each surviving plan on every
//!   requested engine (interpreter / native C / generated Rust) and
//!   compare against the interpreted unfused scalar baseline at 1e-12
//!   relative tolerance.
//!
//! The first finding per seed is greedily minimized
//! ([`super::minimize`]) against an oracle that replays the same
//! failure kind, and — when an output directory is set — written as a
//! self-contained reproducer deck (`fuzz-regress-s<seed>.yaml`) whose
//! header comments carry the exact knob line.

use super::gen::{self, GenDeck, Rng};
use super::minimize;
use crate::analysis::VecDim;
use crate::apps::{self, Variant};
use crate::codegen::native::{self, CcOptions, RustcOptions};
use crate::engine::Threads;
use crate::exec::{self, ExecOptions, Outputs};
use crate::plan::{PlanSpec, Program, Vlen};
use crate::verify;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Differential tolerance (max relative-ish error, [`apps::max_err`]).
pub const TOL: f64 = 1e-12;

/// Execution backends the differential stage can drive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuzzEngine {
    /// In-process schedule interpreter (always available).
    Exec,
    /// Emitted C99, built with the system C compiler.
    Native,
    /// Emitted Rust, built with `rustc`.
    Rust,
}

impl FuzzEngine {
    pub const ALL: [FuzzEngine; 3] = [FuzzEngine::Exec, FuzzEngine::Native, FuzzEngine::Rust];

    pub fn label(self) -> &'static str {
        match self {
            FuzzEngine::Exec => "exec",
            FuzzEngine::Native => "native",
            FuzzEngine::Rust => "rust",
        }
    }

    /// Can this engine run here? (Toolchain probes, so the driver can
    /// degrade to interpreter-only in bare environments.)
    pub fn available(self) -> bool {
        match self {
            FuzzEngine::Exec => true,
            FuzzEngine::Native => native::cc_available(),
            FuzzEngine::Rust => native::rustc_available(),
        }
    }
}

impl std::str::FromStr for FuzzEngine {
    type Err = String;
    fn from_str(s: &str) -> Result<FuzzEngine, String> {
        match s {
            "exec" => Ok(FuzzEngine::Exec),
            "native" => Ok(FuzzEngine::Native),
            "rust" => Ok(FuzzEngine::Rust),
            other => Err(format!("unknown fuzz engine `{other}` (exec|native|rust)")),
        }
    }
}

/// One sampled knob setting for the fused variant.
#[derive(Debug, Clone, PartialEq)]
pub struct Knobs {
    pub vlen: usize,
    pub vec_dim: VecDim,
    pub aligned: bool,
    pub tiled: bool,
    /// Temporal blocking depth; 1 = off. Decks whose dependence shape
    /// rejects the transform silently compile untiled (that fallback is
    /// itself under test), so every value here is a legal request.
    pub time_tile: usize,
    /// Runtime worker count (stage 2 only; stage 1 proves race freedom
    /// at several counts regardless).
    pub threads: usize,
}

impl Knobs {
    /// The always-tested baseline corner.
    pub fn scalar() -> Knobs {
        Knobs {
            vlen: 1,
            vec_dim: VecDim::Inner,
            aligned: false,
            tiled: false,
            time_tile: 1,
            threads: 1,
        }
    }

    pub fn sample(rng: &mut Rng) -> Knobs {
        let vlen = *rng.pick(&[1usize, 2, 4, 8]);
        Knobs {
            vlen,
            vec_dim: if rng.chance(1, 3) { VecDim::Auto } else { VecDim::Inner },
            aligned: vlen > 1 && rng.chance(1, 2),
            tiled: rng.chance(1, 4),
            time_tile: if rng.chance(1, 3) { 2 } else { 1 },
            threads: 1 + rng.below(3) as usize,
        }
    }

    /// The exact knob line reproducer headers carry.
    pub fn label(&self) -> String {
        format!(
            "vlen={} vec_dim={} aligned={} tiled={} time_tile={} threads={}",
            self.vlen, self.vec_dim, self.aligned, self.tiled, self.time_tile, self.threads
        )
    }

    pub fn apply(&self, spec: PlanSpec) -> PlanSpec {
        spec.vlen(Vlen::Fixed(self.vlen))
            .vec_dim(self.vec_dim.clone())
            .aligned(self.aligned)
            .tiled(self.tiled)
            .time_tile(self.time_tile)
    }
}

/// Fuzz campaign configuration.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Number of consecutive seeds to run.
    pub seeds: u64,
    /// First seed.
    pub seed0: u64,
    /// Engines for the differential stage; `None` = all available.
    pub engines: Option<Vec<FuzzEngine>>,
    /// Run the stage-2 differential (stage 1 always runs).
    pub stage2: bool,
    /// Directory for minimized reproducer decks (`None` = don't write).
    pub out_dir: Option<PathBuf>,
    /// Print per-finding lines to stderr as they happen.
    pub verbose: bool,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            seeds: 100,
            seed0: 0,
            engines: None,
            stage2: true,
            out_dir: None,
            verbose: false,
        }
    }
}

/// One triaged failure.
#[derive(Debug, Clone)]
pub struct Finding {
    pub seed: u64,
    /// `panic` | `baseline` | `verify-gate` | `verify` | `run` |
    /// `differential`
    pub kind: String,
    /// Exact knob line of the failing plan.
    pub knobs: String,
    pub engine: Option<FuzzEngine>,
    pub detail: String,
    /// Minimized reproducer deck YAML.
    pub deck: String,
    /// Shrink steps the minimizer accepted.
    pub shrunk: usize,
    /// Reproducer file, when an out dir was configured.
    pub path: Option<PathBuf>,
}

/// Campaign totals.
#[derive(Debug, Default)]
pub struct FuzzReport {
    pub seeds_run: u64,
    pub plans_compiled: usize,
    /// Compile `Err`s from illegal knob corners (expected, not findings).
    pub legality_skips: usize,
    /// Plans that passed the stage-1 verifier oracle.
    pub plans_verified: usize,
    /// Engine runs compared in stage 2.
    pub diff_runs: usize,
    pub findings: Vec<Finding>,
}

impl FuzzReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        writeln!(
            s,
            "fuzz: {} seeds | {} plans compiled, {} legality skips, {} verified, {} differential runs",
            self.seeds_run, self.plans_compiled, self.legality_skips, self.plans_verified,
            self.diff_runs
        )
        .unwrap();
        if self.findings.is_empty() {
            writeln!(s, "fuzz: clean — no findings").unwrap();
        } else {
            writeln!(s, "fuzz: {} finding(s)", self.findings.len()).unwrap();
            for f in &self.findings {
                let eng = f.engine.map(|e| format!(" engine={}", e.label())).unwrap_or_default();
                let head = f.detail.lines().next().unwrap_or("");
                writeln!(
                    s,
                    "  seed 0x{:x}: {} [{}{eng}] (shrunk {} steps) — {head}",
                    f.seed, f.kind, f.knobs, f.shrunk
                )
                .unwrap();
                if let Some(p) = &f.path {
                    writeln!(s, "    reproducer: {}", p.display()).unwrap();
                }
            }
        }
        s
    }
}

/// Run a fuzz campaign.
pub fn run(cfg: &FuzzConfig) -> Result<FuzzReport, String> {
    let engines: Vec<FuzzEngine> = match &cfg.engines {
        Some(list) => {
            for e in list {
                if !e.available() {
                    return Err(format!(
                        "fuzz engine `{}` requested but its toolchain is unavailable",
                        e.label()
                    ));
                }
            }
            list.clone()
        }
        None => FuzzEngine::ALL.into_iter().filter(|e| e.available()).collect(),
    };
    let mut report = FuzzReport::default();
    for seed in cfg.seed0..cfg.seed0.saturating_add(cfg.seeds) {
        fuzz_one(seed, &engines, cfg, &mut report);
        report.seeds_run += 1;
    }
    Ok(report)
}

/// What compiling one spec did, with panics contained.
enum Compiled {
    Ok(Box<Program>),
    /// Clean rejection of an illegal knob corner.
    Illegal(String),
    /// The `HFAV_VERIFY` gate inside compile fired — the schedule was
    /// built but failed its own proof. Always a finding.
    VerifierReject(String),
    Panicked(String),
}

fn compile_catching(spec: &PlanSpec) -> Compiled {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| spec.compile())) {
        Ok(Ok(p)) => Compiled::Ok(Box::new(p)),
        Ok(Err(e)) if e.contains("schedule verification failed") => Compiled::VerifierReject(e),
        Ok(Err(e)) => Compiled::Illegal(e),
        Err(payload) => Compiled::Panicked(panic_text(payload)),
    }
}

fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Concrete extents for stage 2: odd, unequal, and per-dim distinct, so
/// strips, remainders and alignment heads are all exercised; floored so
/// every domain stays non-empty.
fn extents_of(deck: &GenDeck) -> BTreeMap<String, i64> {
    (0..deck.ndims())
        .map(|d| {
            let min = deck.lo[d] + deck.hi_back[d] + 3;
            (deck.extent_name(d), (17 + 2 * d as i64).max(min))
        })
        .collect()
}

fn autovec_scalar_spec(yaml: &str) -> PlanSpec {
    PlanSpec::deck_src(yaml).variant(Variant::Autovec).vlen(Vlen::Fixed(1))
}

/// Run one engine with panics contained. `Err` carries (kind, detail).
fn run_caught(
    prog: &Program,
    reg: &crate::exec::registry::Registry,
    eng: FuzzEngine,
    ext: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    threads: usize,
) -> Result<Outputs, (String, String)> {
    let run = || -> Result<Outputs, String> {
        match eng {
            FuzzEngine::Exec => {
                let opts = ExecOptions { threads, ..Default::default() };
                exec::run(prog, reg, ext, inputs, opts)
            }
            FuzzEngine::Native | FuzzEngine::Rust => {
                let module = match eng {
                    FuzzEngine::Native => native::build(prog, &CcOptions::default())?,
                    _ => native::build_rust(prog, &RustcOptions::default())?,
                };
                let mut arrays = inputs.clone();
                for name in &module.externals {
                    if !arrays.contains_key(name) {
                        arrays.insert(name.clone(), vec![0.0; exec::external_len(prog, name, ext)?]);
                    }
                }
                let th = if threads <= 1 { Threads::Serial } else { Threads::Fixed(threads) };
                module.run_with(ext, &mut arrays, th)?;
                let outs: Vec<String> =
                    prog.external_outputs().into_iter().map(|(n, _, _)| n).collect();
                Ok(arrays.into_iter().filter(|(k, _)| outs.contains(k)).collect())
            }
        }
    };
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(run)) {
        Ok(Ok(o)) => Ok(o),
        Ok(Err(e)) => Err(("run".to_string(), e)),
        Err(payload) => Err(("panic".to_string(), panic_text(payload))),
    }
}

/// Worst relative-ish error across all shared outputs; infinite on
/// missing or mis-sized outputs.
fn diff(want: &Outputs, got: &Outputs) -> f64 {
    let mut worst = 0.0f64;
    for (name, a) in want {
        match got.get(name) {
            Some(b) if b.len() == a.len() => worst = worst.max(apps::max_err(a, b)),
            _ => return f64::INFINITY,
        }
    }
    worst
}

/// Replay one (knob set, engine) check on a candidate deck and name the
/// first failure kind, or `None` if it checks out (or became an illegal
/// knob corner — a shrink that breaks legality is not a reproducer).
fn first_failure(
    deck: &GenDeck,
    seed: u64,
    knobs: &Knobs,
    engine: Option<FuzzEngine>,
) -> Option<String> {
    let yaml = deck.yaml();
    let baseline = match compile_catching(&autovec_scalar_spec(&yaml)) {
        Compiled::Ok(p) => p,
        Compiled::Panicked(_) => return Some("panic".to_string()),
        Compiled::Illegal(_) | Compiled::VerifierReject(_) => return Some("baseline".to_string()),
    };
    let spec = knobs.apply(PlanSpec::deck_src(yaml.as_str()).variant(Variant::Hfav));
    let prog = match compile_catching(&spec) {
        Compiled::Ok(p) => p,
        Compiled::Panicked(_) => return Some("panic".to_string()),
        Compiled::VerifierReject(_) => return Some("verify-gate".to_string()),
        Compiled::Illegal(_) => return None,
    };
    match verify::check_program(&prog) {
        Ok(rep) if !rep.has_errors() => {}
        _ => return Some("verify".to_string()),
    }
    let eng = engine?;
    let reg = deck.registry();
    let ext = extents_of(deck);
    let len = match exec::external_len(&baseline, "g_u", &ext) {
        Ok(l) => l,
        Err(_) => return Some("run".to_string()),
    };
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(len, seed ^ 0xDA7A_F111));
    let want = match run_caught(&baseline, &reg, FuzzEngine::Exec, &ext, &inputs, 1) {
        Ok(o) => o,
        Err((kind, _)) => return Some(kind),
    };
    match run_caught(&prog, &reg, eng, &ext, &inputs, knobs.threads) {
        Ok(got) if diff(&want, &got) <= TOL => None,
        Ok(_) => Some("differential".to_string()),
        Err((kind, _)) => Some(kind),
    }
}

/// Minimize, persist and log one finding.
#[allow(clippy::too_many_arguments)]
fn record(
    report: &mut FuzzReport,
    cfg: &FuzzConfig,
    deck: &GenDeck,
    seed: u64,
    kind: &str,
    knobs: Knobs,
    engine: Option<FuzzEngine>,
    detail: String,
) {
    let (min_deck, shrunk) =
        minimize::minimize(deck, |d| first_failure(d, seed, &knobs, engine).as_deref() == Some(kind));
    let path = cfg.out_dir.as_ref().and_then(|dir| {
        match write_reproducer(dir, seed, &min_deck, kind, &knobs, engine, &detail) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("fuzz: cannot write reproducer for seed 0x{seed:x}: {e}");
                None
            }
        }
    });
    let finding = Finding {
        seed,
        kind: kind.to_string(),
        knobs: knobs.label(),
        engine,
        detail,
        deck: min_deck.yaml(),
        shrunk,
        path,
    };
    if cfg.verbose {
        let eng = engine.map(|e| format!(" engine={}", e.label())).unwrap_or_default();
        eprintln!(
            "fuzz: FINDING seed 0x{seed:x} kind={kind} [{}{eng}] shrunk {shrunk} steps",
            finding.knobs
        );
    }
    report.findings.push(finding);
}

fn write_reproducer(
    dir: &Path,
    seed: u64,
    deck: &GenDeck,
    kind: &str,
    knobs: &Knobs,
    engine: Option<FuzzEngine>,
    detail: &str,
) -> Result<PathBuf, String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let path = dir.join(format!("fuzz-regress-s{seed:x}.yaml"));
    let mut text = String::new();
    writeln!(text, "# hfav fuzz reproducer (minimized)").unwrap();
    writeln!(text, "# seed: 0x{seed:x}").unwrap();
    writeln!(text, "# kind: {kind}").unwrap();
    writeln!(text, "# knobs: variant=hfav {}", knobs.label()).unwrap();
    if let Some(e) = engine {
        writeln!(text, "# engine: {} (vs interpreted autovec scalar baseline)", e.label()).unwrap();
    }
    for line in detail.lines().take(4) {
        writeln!(text, "# detail: {line}").unwrap();
    }
    text.push_str(&deck.yaml());
    std::fs::write(&path, text).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(path)
}

/// Fuzz one seed; records at most one (the first) finding per seed.
fn fuzz_one(seed: u64, engines: &[FuzzEngine], cfg: &FuzzConfig, report: &mut FuzzReport) {
    let deck = gen::generate(seed);
    let yaml = deck.yaml();

    let mut rng = Rng::new(seed ^ 0x6B0B_5EED_0000_0002);
    let mut knob_sets = vec![Knobs::scalar()];
    for _ in 0..3 {
        let k = Knobs::sample(&mut rng);
        if !knob_sets.contains(&k) {
            knob_sets.push(k);
        }
    }

    // The unfused scalar plan is both the stage-2 oracle and a stage-1
    // canary: a legal-by-construction deck must always compile there.
    let baseline = match compile_catching(&autovec_scalar_spec(&yaml)) {
        Compiled::Ok(p) => {
            report.plans_compiled += 1;
            p
        }
        Compiled::Panicked(e) => {
            return record(report, cfg, &deck, seed, "panic", Knobs::scalar(), None, e);
        }
        Compiled::Illegal(e) | Compiled::VerifierReject(e) => {
            return record(report, cfg, &deck, seed, "baseline", Knobs::scalar(), None, e);
        }
    };

    // Stage 1: compile the fused variant at each knob set, then hold it
    // to the independent schedule verifier.
    let mut plans: Vec<(Knobs, Box<Program>)> = Vec::new();
    for knobs in &knob_sets {
        let spec = knobs.apply(PlanSpec::deck_src(yaml.as_str()).variant(Variant::Hfav));
        match compile_catching(&spec) {
            Compiled::Ok(p) => {
                report.plans_compiled += 1;
                match verify::check_program(&p) {
                    Ok(rep) if !rep.has_errors() => {
                        report.plans_verified += 1;
                        plans.push((knobs.clone(), p));
                    }
                    Ok(rep) => {
                        return record(
                            report,
                            cfg,
                            &deck,
                            seed,
                            "verify",
                            knobs.clone(),
                            None,
                            rep.render_errors(),
                        );
                    }
                    Err(e) => {
                        return record(report, cfg, &deck, seed, "verify", knobs.clone(), None, e);
                    }
                }
            }
            Compiled::Illegal(_) => report.legality_skips += 1,
            Compiled::VerifierReject(e) => {
                return record(report, cfg, &deck, seed, "verify-gate", knobs.clone(), None, e);
            }
            Compiled::Panicked(e) => {
                return record(report, cfg, &deck, seed, "panic", knobs.clone(), None, e);
            }
        }
    }

    if !cfg.stage2 {
        return;
    }

    // Stage 2: every surviving plan × engine against the interpreted
    // unfused scalar baseline.
    let reg = deck.registry();
    let ext = extents_of(&deck);
    let len = match exec::external_len(&baseline, "g_u", &ext) {
        Ok(l) => l,
        Err(e) => {
            return record(report, cfg, &deck, seed, "run", Knobs::scalar(), None, e);
        }
    };
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(len, seed ^ 0xDA7A_F111));
    let want = match run_caught(&baseline, &reg, FuzzEngine::Exec, &ext, &inputs, 1) {
        Ok(o) => o,
        Err((kind, e)) => {
            return record(
                report,
                cfg,
                &deck,
                seed,
                &kind,
                Knobs::scalar(),
                Some(FuzzEngine::Exec),
                e,
            );
        }
    };
    for (knobs, prog) in &plans {
        for &eng in engines {
            report.diff_runs += 1;
            match run_caught(prog, &reg, eng, &ext, &inputs, knobs.threads) {
                Ok(got) => {
                    let err = diff(&want, &got);
                    if !(err <= TOL) {
                        return record(
                            report,
                            cfg,
                            &deck,
                            seed,
                            "differential",
                            knobs.clone(),
                            Some(eng),
                            format!("max rel err {err:.3e} vs interpreted autovec scalar baseline"),
                        );
                    }
                }
                Err((kind, e)) => {
                    return record(report, cfg, &deck, seed, &kind, knobs.clone(), Some(eng), e);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_sampling_is_deterministic() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..16 {
            assert_eq!(Knobs::sample(&mut a), Knobs::sample(&mut b));
        }
    }

    #[test]
    fn scalar_knobs_label_is_stable() {
        assert_eq!(
            Knobs::scalar().label(),
            "vlen=1 vec_dim=inner aligned=false tiled=false time_tile=1 threads=1"
        );
    }

    #[test]
    fn sampled_time_tile_stays_in_pool() {
        let mut rng = Rng::new(42);
        for _ in 0..64 {
            let k = Knobs::sample(&mut rng);
            assert!(k.time_tile == 1 || k.time_tile == 2, "time_tile {}", k.time_tile);
        }
    }

    #[test]
    fn engine_parse_round_trip() {
        for e in FuzzEngine::ALL {
            assert_eq!(e.label().parse::<FuzzEngine>().unwrap(), e);
        }
        assert!("pjrt".parse::<FuzzEngine>().is_err());
    }

    #[test]
    fn unavailable_engine_request_is_an_error_or_runs() {
        // `exec` is always available; an explicit request must succeed.
        let cfg = FuzzConfig {
            seeds: 1,
            stage2: false,
            engines: Some(vec![FuzzEngine::Exec]),
            ..Default::default()
        };
        let rep = run(&cfg).expect("exec engine always available");
        assert_eq!(rep.seeds_run, 1);
    }

    #[test]
    fn report_summary_mentions_clean_when_empty() {
        let rep = FuzzReport { seeds_run: 3, ..Default::default() };
        assert!(rep.summary().contains("clean"));
    }
}
