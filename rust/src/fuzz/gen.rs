//! Random deck generator: seeded, self-contained DAGs of 1–3-dim
//! stencil / reduction chains rendered as deck YAML.
//!
//! The generator's contract is **legal by construction**: every deck it
//! emits must parse, pass `Deck::validate`, and describe a well-defined
//! computation (no reads before the input span, no division / sqrt in
//! bodies so results stay finite, domain lower bounds cover the
//! transitive negative reach of every read chain). Anything the pipeline
//! then does wrong with such a deck — a compile panic, a verifier error
//! on a compiled plan, or an engine disagreeing with the scalar
//! interpreter — is a *finding*, not generator noise. Vectorization
//! legality is deliberately **not** part of the contract: illegal knob
//! corners (e.g. `--tile` on a deck with loop-carried reuse along every
//! dim) must be rejected with a clean `Err`, and the driver counts those
//! as legality skips.
//!
//! Structure of a generated deck (mirroring the builtin apps' idioms):
//!
//! * 1–3 loop dims drawn from `[k, j, i]` (outermost first), half-open
//!   domains `[lo, Nd-hi]` per dim.
//! * a chain of 1–3 stencil stages `t1, t2, ...` over grid base `u`;
//!   each stage's spine reads the previous value (stage 1 reads the
//!   terminal input `u?`) plus 0–2 extra reads of earlier values or the
//!   input. Terminal-input reads draw offsets from `[-3, 3]` on every
//!   dim (window depths past 2, so windowed-reuse buffers deeper than
//!   the builtin apps' get exercised); intermediate reads keep
//!   non-innermost offsets in `[-3, 0]`
//!   (producer-runs-behind shapes — the windowed-reuse direction this
//!   grammar is here to stress; positive outer offsets on intermediates
//!   are covered separately by `tests/property.rs` at magnitude 1 and
//!   are future grammar here).
//! * optionally (2-dim decks) a normalization-shaped reduction block:
//!   `z(acc[..])` init, `s(acc[..])` accumulate over the innermost dim,
//!   a `w(acc[..])` post stage (the once-written value a broadcast may
//!   legally read, mirroring `norm_root`), and a `fin(u[..])` grid
//!   stage consuming it.
//! * kernel bodies are expression trees over `+ - *` and a small
//!   constant pool — the C subset that is also literal Rust, so `body`
//!   and `body_rs` are the same string and all three engines (interp
//!   closure, emitted C, emitted Rust) evaluate the identical tree.

use crate::exec::registry::Registry;
use std::fmt::Write as _;

/// Deterministic xorshift64* RNG (same core as [`crate::apps::seeded`]).
/// Fuzz reproducibility only needs stability within this crate, not any
/// external stream compatibility.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
    /// Uniform-ish in `[0, n)` (modulo bias is irrelevant at fuzz scale).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
    /// True with probability `num`/`den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }
}

/// Kernel-body expression over input params `p0..pN`. The rendered form
/// is simultaneously valid C99 and Rust (fully parenthesized, `f64`
/// literals with a decimal point, no calls), and [`Expr::eval`] is the
/// interpreter-registry semantics of the same tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Param(usize),
    Const(f64),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn eval(&self, p: &[f64]) -> f64 {
        match self {
            Expr::Param(i) => p[*i],
            Expr::Const(c) => *c,
            Expr::Add(a, b) => a.eval(p) + b.eval(p),
            Expr::Sub(a, b) => a.eval(p) - b.eval(p),
            Expr::Mul(a, b) => a.eval(p) * b.eval(p),
        }
    }

    /// Render as a C-and-Rust expression over the given param names.
    pub fn code(&self, params: &[String]) -> String {
        match self {
            Expr::Param(i) => params[*i].clone(),
            // `{:?}` prints f64 with a decimal point (`2.0`, `0.25`), which
            // both C and Rust read back as the same double literal.
            Expr::Const(c) if *c < 0.0 => format!("({:?})", c),
            Expr::Const(c) => format!("{:?}", c),
            Expr::Add(a, b) => format!("({} + {})", a.code(params), b.code(params)),
            Expr::Sub(a, b) => format!("({} - {})", a.code(params), b.code(params)),
            Expr::Mul(a, b) => format!("({} * {})", a.code(params), b.code(params)),
        }
    }

    /// Highest param index referenced, or None for constant exprs.
    pub fn max_param(&self) -> Option<usize> {
        match self {
            Expr::Param(i) => Some(*i),
            Expr::Const(_) => None,
            Expr::Add(a, b) | Expr::Sub(a, b) | Expr::Mul(a, b) => match (a.max_param(), b.max_param()) {
                (Some(x), Some(y)) => Some(x.max(y)),
                (x, None) => x,
                (None, y) => y,
            },
        }
    }
}

/// Magnitude-bounded constant pool: no value can blow past ~1e15 over a
/// handful of chained stages, keeping the 1e-12 relative tolerance
/// meaningful, and there is no division or sqrt so nothing can produce
/// inf/NaN from in-range inputs.
const CONSTS: [f64; 7] = [0.125, 0.25, 0.5, 0.75, 1.5, 2.0, 3.0];

/// One named intermediate value in the deck's dataflow.
#[derive(Debug, Clone, PartialEq)]
pub struct GenValue {
    /// Tag (`t1`, `z`, `s`, `fin`).
    pub tag: String,
    /// Base term family: `u` for grid values, `acc` for reduced ones.
    pub base: String,
    /// Reduced values drop the innermost dim (normalization idiom).
    pub reduced: bool,
}

/// One read in a stage: a producer value (or the terminal input) at a
/// per-dim offset.
#[derive(Debug, Clone, PartialEq)]
pub struct GenRead {
    /// Index into `GenDeck::values`, or -1 for the terminal input `u`.
    pub value: isize,
    /// One offset per deck dim, outermost first. Ignored entries (the
    /// innermost slot of a reduced read) are kept at 0.
    pub offsets: Vec<i64>,
}

/// One kernel + callsite: reads, an expression over them, one output.
#[derive(Debug, Clone, PartialEq)]
pub struct GenStage {
    pub kernel: String,
    pub reads: Vec<GenRead>,
    pub expr: Expr,
    /// Index into `GenDeck::values`.
    pub out: usize,
}

/// A generated deck: structured form first, YAML via [`GenDeck::yaml`].
/// Keeping the structure (not just text) is what makes greedy
/// minimization tractable — mutations edit this and re-render.
#[derive(Debug, Clone, PartialEq)]
pub struct GenDeck {
    pub name: String,
    /// Loop dims, outermost first (suffix of `[k, j, i]`).
    pub dims: Vec<String>,
    /// Domain lower bounds per dim (covers the negative input reach).
    pub lo: Vec<i64>,
    /// Domain upper offsets per dim: domain hi is `Nd - hi_back`, so
    /// entries are >= 0.
    pub hi_back: Vec<i64>,
    pub values: Vec<GenValue>,
    pub stages: Vec<GenStage>,
    /// Index of the value exported through `globals.outputs`.
    pub goal: usize,
}

impl GenDeck {
    pub fn ndims(&self) -> usize {
        self.dims.len()
    }

    /// Extent parameter name for dim `d` (`k` -> `Nk`).
    pub fn extent_name(&self, d: usize) -> String {
        format!("N{}", self.dims[d])
    }

    /// Per-dim (negative, positive) transitive reach of the terminal
    /// input from the goal — how far outside the goal's domain the
    /// chain reads `u`. Stages are in producer order, so one reverse
    /// sweep propagates consumer reach back through every read.
    pub fn input_reach(&self) -> (Vec<i64>, Vec<i64>) {
        let nd = self.ndims();
        // Slot 0 is the terminal input; slot v+1 is values[v].
        let mut neg = vec![vec![0i64; nd]; self.values.len() + 1];
        let mut pos = vec![vec![0i64; nd]; self.values.len() + 1];
        for st in self.stages.iter().rev() {
            let (oneg, opos) = (neg[st.out + 1].clone(), pos[st.out + 1].clone());
            for r in &st.reads {
                let vi = (r.value + 1) as usize;
                for d in 0..nd {
                    neg[vi][d] = neg[vi][d].max(oneg[d] + (-r.offsets[d]).max(0));
                    pos[vi][d] = pos[vi][d].max(opos[d] + r.offsets[d].max(0));
                }
            }
        }
        (neg[0].clone(), pos[0].clone())
    }

    /// Subscript list for a value (or the input) at given offsets, in
    /// deck pattern (`j?`) or concrete (`j`) spelling.
    fn subscripts(&self, reduced: bool, offsets: Option<&[i64]>, pattern: bool) -> String {
        let nd = if reduced { self.ndims() - 1 } else { self.ndims() };
        let mut s = String::new();
        for d in 0..nd {
            let var = &self.dims[d];
            let q = if pattern { "?" } else { "" };
            let off = offsets.map_or(0, |o| o[d]);
            match off.cmp(&0) {
                std::cmp::Ordering::Equal => write!(s, "[{var}{q}]").unwrap(),
                std::cmp::Ordering::Greater => write!(s, "[{var}{q}+{off}]").unwrap(),
                std::cmp::Ordering::Less => write!(s, "[{var}{q}-{}]", -off).unwrap(),
            }
        }
        s
    }

    /// Term text for one read, in kernel-inputs position.
    fn read_term(&self, r: &GenRead) -> String {
        if r.value < 0 {
            // Terminal input: pattern base.
            format!("u?{}", self.subscripts(false, Some(&r.offsets), true))
        } else {
            let v = &self.values[r.value as usize];
            // Produced values: tagged concrete base.
            format!("{}({}{})", v.tag, v.base, self.subscripts(v.reduced, Some(&r.offsets), true))
        }
    }

    /// Render the deck as YAML in the house style.
    pub fn yaml(&self) -> String {
        let mut y = String::new();
        writeln!(y, "name: {}", self.name).unwrap();
        writeln!(y, "iteration:").unwrap();
        let order = self.dims.join(", ");
        writeln!(y, "  order: [{order}]").unwrap();
        writeln!(y, "  domains:").unwrap();
        for d in 0..self.ndims() {
            let hi = if self.hi_back[d] == 0 {
                self.extent_name(d)
            } else {
                format!("{}-{}", self.extent_name(d), self.hi_back[d])
            };
            writeln!(y, "    {}: [{}, {}]", self.dims[d], self.lo[d], hi).unwrap();
        }
        writeln!(y, "kernels:").unwrap();
        for st in &self.stages {
            let out = &self.values[st.out];
            let params: Vec<String> = (0..st.reads.len()).map(|i| format!("p{i}")).collect();
            let decl_params: Vec<String> = params
                .iter()
                .map(|p| format!("double {p}"))
                .chain(std::iter::once("double &o".to_string()))
                .collect();
            writeln!(y, "  {}:", st.kernel).unwrap();
            writeln!(y, "    declaration: {}({});", st.kernel, decl_params.join(", ")).unwrap();
            if !st.reads.is_empty() {
                writeln!(y, "    inputs: |").unwrap();
                for (p, r) in params.iter().zip(&st.reads) {
                    writeln!(y, "      {p} : {}", self.read_term(r)).unwrap();
                }
            }
            writeln!(y, "    outputs: |").unwrap();
            // Outputs of grid stages are patterns over `u?`; reduced
            // outputs use the concrete `acc` base (normalization idiom).
            let out_term = if out.reduced {
                format!("{}({}{})", out.tag, out.base, self.subscripts(true, None, true))
            } else {
                format!("{}({}?{})", out.tag, out.base, self.subscripts(false, None, true))
            };
            writeln!(y, "      o : {out_term}").unwrap();
            let body = format!("o = {};", st.expr.code(&params));
            writeln!(y, "    body: \"{body}\"").unwrap();
            writeln!(y, "    body_rs: \"{body}\"").unwrap();
        }
        writeln!(y, "globals:").unwrap();
        writeln!(y, "  inputs: |").unwrap();
        let pat = self.subscripts(false, None, true);
        writeln!(y, "    double g_u{pat} => u{pat}").unwrap();
        writeln!(y, "  outputs: |").unwrap();
        let goal = &self.values[self.goal];
        let conc = self.subscripts(goal.reduced, None, false);
        writeln!(y, "    {}({}{conc}) => double g_out{conc}", goal.tag, goal.base).unwrap();
        y
    }

    /// Interpreter registry for this deck's kernels: each closure is the
    /// stage's expression tree evaluated over the input slice.
    pub fn registry(&self) -> Registry {
        let mut r = Registry::new();
        for st in &self.stages {
            let e = st.expr.clone();
            r.register(&st.kernel, move |i, o| o[0] = e.eval(i));
        }
        r
    }
}

/// Random per-dim offsets, weighted toward small magnitudes. When
/// `intermediate`, non-innermost dims are clamped non-positive (see the
/// module docs on the supported fusion envelope).
fn rand_offsets(rng: &mut Rng, nd: usize, intermediate: bool) -> Vec<i64> {
    (0..nd)
        .map(|d| {
            let o: i64 = match rng.below(12) {
                0..=4 => 0,
                5 | 6 => -1,
                7 => 1,
                8 => -2,
                9 => 2,
                10 => -3,
                _ => 3,
            };
            if intermediate && d + 1 < nd {
                -o.abs()
            } else {
                o
            }
        })
        .collect()
}

/// Random expression using **all** of `n` params exactly once as leaves
/// (plus optional constants), so every declared kernel param is live.
fn rand_expr(rng: &mut Rng, n: usize) -> Expr {
    assert!(n > 0);
    let mut e = Expr::Param(0);
    for i in 1..n {
        let p = Expr::Param(i);
        let term = if rng.chance(1, 2) {
            Expr::Mul(Box::new(Expr::Const(*rng.pick(&CONSTS))), Box::new(p))
        } else {
            p
        };
        e = if rng.chance(1, 3) {
            Expr::Sub(Box::new(e), Box::new(term))
        } else {
            Expr::Add(Box::new(e), Box::new(term))
        };
    }
    if rng.chance(1, 4) {
        e = Expr::Mul(Box::new(Expr::Const(*rng.pick(&CONSTS))), Box::new(e));
    }
    e
}

/// The verifier probes extents as small as 7 (`probe_extents` scale 2 at
/// vlen 1), so a generated domain must be non-empty there:
/// `lo + hi_back <= 7 - 1` keeps at least one iteration at the probe.
const MAX_EDGE: i64 = 6;
/// Cap on per-dim total input reach (`neg + pos`); chains that exceed it
/// get their offsets clamped until they fit.
const MAX_REACH: i64 = 5;

/// Generate the deck for one fuzz seed. Pure function of the seed.
pub fn generate(seed: u64) -> GenDeck {
    let mut rng = Rng::new(seed ^ 0xF022_5EED_CAFE_0001);
    let all = ["k", "j", "i"];
    let ndims = 1 + rng.below(3) as usize;
    let dims: Vec<String> = all[3 - ndims..].iter().map(|s| s.to_string()).collect();

    let mut values = Vec::new();
    let mut stages = Vec::new();

    // Stencil chain t1 -> t2 -> ... over grid base `u`.
    let n_sten = 1 + rng.below(3) as usize;
    for s in 0..n_sten {
        values.push(GenValue { tag: format!("t{}", s + 1), base: "u".into(), reduced: false });
        let mut reads = vec![GenRead {
            value: s as isize - 1,
            offsets: rand_offsets(&mut rng, ndims, s > 0),
        }];
        for _ in 0..rng.below(3) {
            // Any earlier value or the input.
            let v = rng.below(s as u64 + 1) as isize - 1;
            reads.push(GenRead { value: v, offsets: rand_offsets(&mut rng, ndims, v >= 0) });
        }
        let expr = rand_expr(&mut rng, reads.len());
        stages.push(GenStage { kernel: format!("f{}", s + 1), reads, expr, out: s });
    }
    let mut goal = n_sten - 1;

    // Optional reduction block, 2D decks only for now: the shape is
    // exactly normalization's (init / accumulate / post / broadcast),
    // which the repo's own differential suite proves end to end. 3D
    // reductions are future grammar.
    if ndims == 2 && rng.chance(2, 5) {
        let zi = values.len();
        values.push(GenValue { tag: "z".into(), base: "acc".into(), reduced: true });
        stages.push(GenStage {
            kernel: "r_init".into(),
            reads: vec![],
            expr: Expr::Const(0.0),
            out: zi,
        });
        let si = values.len();
        values.push(GenValue { tag: "s".into(), base: "acc".into(), reduced: true });
        let acc_expr = if rng.chance(1, 2) {
            // p0 + p1*p1 (sum of squares, like normalization)
            Expr::Add(
                Box::new(Expr::Param(0)),
                Box::new(Expr::Mul(Box::new(Expr::Param(1)), Box::new(Expr::Param(1)))),
            )
        } else {
            // p0 + c*p1 (weighted sum)
            Expr::Add(
                Box::new(Expr::Param(0)),
                Box::new(Expr::Mul(
                    Box::new(Expr::Const(*rng.pick(&CONSTS))),
                    Box::new(Expr::Param(1)),
                )),
            )
        };
        stages.push(GenStage {
            kernel: "r_acc".into(),
            reads: vec![
                GenRead { value: zi as isize, offsets: vec![0; ndims] },
                GenRead { value: (n_sten - 1) as isize, offsets: vec![0; ndims] },
            ],
            expr: acc_expr,
            out: si,
        });
        // Post stage (norm_root's slot): the accumulator tag is written
        // once per inner-loop step, so broadcasts read this once-written
        // value instead.
        let wi = values.len();
        values.push(GenValue { tag: "w".into(), base: "acc".into(), reduced: true });
        stages.push(GenStage {
            kernel: "r_post".into(),
            reads: vec![GenRead { value: si as isize, offsets: vec![0; ndims] }],
            expr: Expr::Mul(
                Box::new(Expr::Const(*rng.pick(&CONSTS))),
                Box::new(Expr::Param(0)),
            ),
            out: wi,
        });
        let fi = values.len();
        values.push(GenValue { tag: "fin".into(), base: "u".into(), reduced: false });
        stages.push(GenStage {
            kernel: "r_fin".into(),
            reads: vec![
                GenRead { value: (n_sten - 1) as isize, offsets: vec![0; ndims] },
                GenRead { value: wi as isize, offsets: vec![0; ndims] },
            ],
            expr: rand_expr(&mut rng, 2),
            out: fi,
        });
        goal = fi;
    }

    let mut deck = GenDeck {
        name: format!("fuzz_s{seed:x}"),
        dims,
        lo: vec![0; ndims],
        hi_back: vec![0; ndims],
        values,
        stages,
        goal,
    };

    // Clamp runaway reach: squeeze offsets to |2|, then |1|, then 0, on
    // any dim whose total transitive reach exceeds the budget.
    for max_mag in [2i64, 1, 0] {
        let (neg, pos) = deck.input_reach();
        let over: Vec<bool> = (0..ndims).map(|d| neg[d] + pos[d] > MAX_REACH).collect();
        if !over.iter().any(|&b| b) {
            break;
        }
        for st in &mut deck.stages {
            for r in &mut st.reads {
                for d in 0..ndims {
                    if over[d] {
                        r.offsets[d] = r.offsets[d].clamp(-max_mag, max_mag);
                    }
                }
            }
        }
    }

    // Domains: lower bound covers the negative input reach (plus random
    // slack), upper bound backs off 0-2 from the extent, all within the
    // verifier's smallest probe extent.
    let (neg, _pos) = deck.input_reach();
    for d in 0..ndims {
        let extra = if rng.chance(1, 3) { 1 } else { 0 };
        deck.lo[d] = (neg[d] + extra).min(MAX_EDGE);
        let room = MAX_EDGE - deck.lo[d];
        deck.hi_back[d] = (rng.below(3) as i64).min(room.max(0));
    }

    deck
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        for s in [0u64, 1, 7, 0xC0FFEE] {
            assert_eq!(generate(s), generate(s), "seed {s}");
        }
    }

    #[test]
    fn decks_parse_and_validate() {
        for s in 0..64u64 {
            let deck = generate(s);
            let y = deck.yaml();
            let parsed = crate::frontend::parse_deck(&y)
                .unwrap_or_else(|e| panic!("seed {s}: generated deck does not parse: {e}\n{y}"));
            assert_eq!(parsed.name, deck.name);
            assert_eq!(parsed.iteration.order, deck.dims);
        }
    }

    #[test]
    fn domains_fit_probe_extents() {
        for s in 0..256u64 {
            let deck = generate(s);
            let (neg, _) = deck.input_reach();
            for d in 0..deck.ndims() {
                assert!(deck.lo[d] >= neg[d], "seed {s} dim {d}: lo below input reach");
                assert!(
                    deck.lo[d] + deck.hi_back[d] <= MAX_EDGE,
                    "seed {s} dim {d}: domain empty at the verifier's probe extent"
                );
            }
        }
    }

    #[test]
    fn grammar_reaches_window_depths_past_two() {
        // The deep-window arm of the grammar must actually fire: some
        // seed in a modest range keeps a magnitude-3 offset after the
        // reach clamp, and the clamp still holds every deck within the
        // probe-extent budget.
        let mut saw_deep = false;
        for s in 0..512u64 {
            let deck = generate(s);
            let (neg, pos) = deck.input_reach();
            for d in 0..deck.ndims() {
                assert!(neg[d] + pos[d] <= MAX_REACH, "seed {s} dim {d}: reach over budget");
            }
            if deck
                .stages
                .iter()
                .any(|st| st.reads.iter().any(|r| r.offsets.iter().any(|o| o.abs() >= 3)))
            {
                saw_deep = true;
            }
        }
        assert!(saw_deep, "no deck in 512 seeds used a window deeper than 2");
    }

    #[test]
    fn expr_code_matches_eval() {
        let e = Expr::Sub(
            Box::new(Expr::Add(Box::new(Expr::Param(0)), Box::new(Expr::Const(0.5)))),
            Box::new(Expr::Mul(Box::new(Expr::Const(2.0)), Box::new(Expr::Param(1)))),
        );
        assert_eq!(e.code(&["a".into(), "b".into()]), "((a + 0.5) - (2.0 * b))");
        assert_eq!(e.eval(&[1.0, 3.0]), (1.0 + 0.5) - 2.0 * 3.0);
        assert_eq!(e.max_param(), Some(1));
    }

    #[test]
    fn registry_covers_all_stages() {
        let deck = generate(3);
        let reg = deck.registry();
        for st in &deck.stages {
            assert!(reg.get(&st.kernel).is_some(), "kernel {}", st.kernel);
        }
    }

    #[test]
    fn every_param_is_used() {
        for s in 0..128u64 {
            let deck = generate(s);
            for st in &deck.stages {
                if st.reads.is_empty() {
                    assert_eq!(st.expr.max_param(), None);
                } else {
                    assert_eq!(
                        st.expr.max_param(),
                        Some(st.reads.len() - 1),
                        "seed {s} kernel {}: unused tail params",
                        st.kernel
                    );
                }
            }
        }
    }
}
