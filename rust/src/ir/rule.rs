//! Kernel production rules.

use super::term::Term;
use super::Scalar;
use std::fmt;

/// Direction of a kernel parameter (C-style `&` marks outputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamDir {
    In,
    Out,
}

/// One kernel parameter from the C-like declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Scalar,
    pub dir: ParamDir,
}

/// A production rule: a kernel with a declaration, input term patterns
/// (one per `In` parameter) and output term patterns (one per `Out`
/// parameter). Patterns share unification variables, e.g. the Laplace rule
/// consumes `q?[j?±1][i?±1]` and produces `laplace(q?[j?][i?])`.
#[derive(Debug, Clone)]
pub struct Rule {
    pub name: String,
    pub params: Vec<Param>,
    /// (param name, term pattern) for inputs, in declaration order.
    pub inputs: Vec<(String, Term)>,
    /// (param name, term pattern) for outputs, in declaration order.
    pub outputs: Vec<(String, Term)>,
    /// Optional inline body (an expression / statement list in the backend
    /// language) used by code generators to inline the kernel. Purely
    /// substitution-based, as in the paper's front-end.
    pub body: Option<String>,
    /// Optional Rust-specific body for the Rust backend. When absent the
    /// Rust emitter falls back to `body`, which works for bodies written
    /// in the expression-level C-that-is-also-Rust subset; kernels using
    /// C-only syntax (ternaries, `double` declarations, C `for` loops)
    /// carry an explicit translation here.
    pub body_rs: Option<String>,
}

impl Rule {
    /// All dimension variable names mentioned by this rule's patterns.
    pub fn pattern_dims(&self) -> Vec<String> {
        let mut dims = Vec::new();
        for (_, t) in self.inputs.iter().chain(self.outputs.iter()) {
            for s in &t.subs {
                if !dims.contains(&s.var) {
                    dims.push(s.var.clone());
                }
            }
        }
        dims
    }

    /// Parse a C-like declaration: `name(double a, double b, double &out)`.
    /// A trailing `;` is tolerated.
    pub fn parse_declaration(src: &str) -> Result<(String, Vec<Param>), String> {
        let src = src.trim().trim_end_matches(';').trim();
        let lp = src.find('(').ok_or_else(|| format!("missing `(` in declaration `{src}`"))?;
        if !src.ends_with(')') {
            return Err(format!("missing `)` in declaration `{src}`"));
        }
        let name = src[..lp].trim();
        // Tolerate an optional leading return type (e.g. `void laplace5(...)`).
        let name = name.split_whitespace().last().unwrap_or("");
        if name.is_empty() {
            return Err(format!("missing kernel name in `{src}`"));
        }
        let inner = src[lp + 1..src.len() - 1].trim();
        let mut params = Vec::new();
        if !inner.is_empty() {
            for part in inner.split(',') {
                let part = part.trim();
                let toks: Vec<&str> = part.split_whitespace().collect();
                if toks.len() < 2 {
                    return Err(format!("bad parameter `{part}` in `{src}`"));
                }
                let ty = Scalar::parse(toks[0])
                    .ok_or_else(|| format!("unknown type `{}` in `{src}`", toks[0]))?;
                let mut pname = toks[1..].join("");
                let mut dir = ParamDir::In;
                if let Some(stripped) = pname.strip_prefix('&') {
                    dir = ParamDir::Out;
                    pname = stripped.to_string();
                }
                if let Some(stripped) = pname.strip_prefix('*') {
                    dir = ParamDir::Out;
                    pname = stripped.to_string();
                }
                params.push(Param { name: pname, ty, dir });
            }
        }
        Ok((name.to_string(), params))
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}(", self.name)?;
        let mut first = true;
        for p in &self.params {
            if !first {
                write!(f, ", ")?;
            }
            first = false;
            write!(
                f,
                "{} {}{}",
                p.ty.c_name(),
                if p.dir == ParamDir::Out { "&" } else { "" },
                p.name
            )?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decl_basic() {
        let decl = "laplace5(float n, float e, float s, float w, float c, float &o);";
        let (name, ps) = Rule::parse_declaration(decl).unwrap();
        assert_eq!(name, "laplace5");
        assert_eq!(ps.len(), 6);
        assert_eq!(ps[0].dir, ParamDir::In);
        assert_eq!(ps[5].dir, ParamDir::Out);
        assert_eq!(ps[5].name, "o");
    }

    #[test]
    fn decl_return_type_and_star() {
        let (name, ps) = Rule::parse_declaration("void f(double x, double *y)").unwrap();
        assert_eq!(name, "f");
        assert_eq!(ps[1].dir, ParamDir::Out);
    }

    #[test]
    fn decl_amp_space() {
        let (_, ps) = Rule::parse_declaration("f(double & y)").unwrap();
        assert_eq!(ps[0].dir, ParamDir::Out);
        assert_eq!(ps[0].name, "y");
    }

    #[test]
    fn decl_errors() {
        assert!(Rule::parse_declaration("nope").is_err());
        assert!(Rule::parse_declaration("f(badtype x)").is_err());
        assert!(Rule::parse_declaration("f(double)").is_err());
    }
}
