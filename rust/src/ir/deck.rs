//! Decks: the full declarative input to the generator.

use super::rule::Rule;
use super::term::Term;
use super::Scalar;
use std::collections::BTreeMap;
use std::fmt;

/// A symbolic loop bound: `base + offset` where `base` is the name of a
/// runtime extent parameter (e.g. `Ni`) or absent for a constant.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Bound {
    pub base: Option<String>,
    pub offset: i64,
}

impl Bound {
    pub fn constant(v: i64) -> Bound {
        Bound { base: None, offset: v }
    }
    pub fn of(base: &str, offset: i64) -> Bound {
        Bound { base: Some(base.to_string()), offset }
    }

    /// Evaluate against runtime extent bindings.
    pub fn eval(&self, extents: &BTreeMap<String, i64>) -> Result<i64, String> {
        match &self.base {
            None => Ok(self.offset),
            Some(b) => extents
                .get(b)
                .map(|v| v + self.offset)
                .ok_or_else(|| format!("unbound extent `{b}`")),
        }
    }

    /// Add a constant.
    pub fn plus(&self, d: i64) -> Bound {
        Bound { base: self.base.clone(), offset: self.offset + d }
    }

    /// Parse `0`, `Ni`, `Ni-1`, `Ni+2`.
    pub fn parse(s: &str) -> Result<Bound, String> {
        let s = s.trim();
        if let Ok(v) = s.parse::<i64>() {
            return Ok(Bound::constant(v));
        }
        let split = s.find(['+', '-']);
        match split {
            Some(p) if p > 0 => {
                let off: i64 = s[p..]
                    .replace(' ', "")
                    .parse()
                    .map_err(|_| format!("bad bound offset in `{s}`"))?;
                Ok(Bound::of(s[..p].trim(), off))
            }
            _ => Ok(Bound::of(s, 0)),
        }
    }
}

impl fmt::Display for Bound {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.base {
            None => write!(f, "{}", self.offset),
            Some(b) => match self.offset.cmp(&0) {
                std::cmp::Ordering::Equal => write!(f, "{b}"),
                std::cmp::Ordering::Greater => write!(f, "{b}+{}", self.offset),
                std::cmp::Ordering::Less => write!(f, "{b}{}", self.offset),
            },
        }
    }
}

/// Half-open iteration domain `[lo, hi)` for one loop variable.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Domain {
    pub lo: Bound,
    pub hi: Bound,
}

impl Domain {
    pub fn new(lo: Bound, hi: Bound) -> Domain {
        Domain { lo, hi }
    }
}

impl fmt::Display for Domain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}, {})", self.lo, self.hi)
    }
}

/// Iteration configuration: global loop order (outermost first) and the
/// default domain of each loop variable.
#[derive(Debug, Clone, Default)]
pub struct IterationCfg {
    /// Outermost-first, e.g. `["k", "j", "i"]`.
    pub order: Vec<String>,
    pub domains: BTreeMap<String, Domain>,
}

impl IterationCfg {
    /// Rank of a loop variable: 0 = innermost. Unknown vars error at deck
    /// validation, so this may panic on unvalidated input.
    pub fn rank(&self, var: &str) -> usize {
        let pos = self
            .order
            .iter()
            .position(|v| v == var)
            .unwrap_or_else(|| panic!("unknown loop var `{var}`"));
        self.order.len() - 1 - pos
    }

    /// Sort dimension variables outermost-first according to the global
    /// order.
    pub fn sort_outer_first(&self, dims: &mut Vec<String>) {
        let order = &self.order;
        dims.sort_by_key(|d| order.iter().position(|v| v == d).unwrap_or(usize::MAX));
        dims.dedup();
    }
}

/// An axiom: a terminal input array that provides a family of terms.
/// `float g_cell[j?][i?] => cell[j?][i?]`.
#[derive(Debug, Clone)]
pub struct Axiom {
    pub storage: Term,
    pub ty: Scalar,
    pub provides: Term,
}

/// A goal: a requested terminal output. `laplace(cell[j][i]) => float
/// g_out[j][i]`. The left side is a *concrete* term family over the deck
/// domains of its loop vars.
#[derive(Debug, Clone)]
pub struct Goal {
    pub requires: Term,
    pub ty: Scalar,
    pub storage: Term,
}

/// A full deck.
#[derive(Debug, Clone, Default)]
pub struct Deck {
    pub name: String,
    pub rules: Vec<Rule>,
    pub axioms: Vec<Axiom>,
    pub goals: Vec<Goal>,
    pub iteration: IterationCfg,
    /// Terminal inputs that alias terminal outputs (pairs of storage base
    /// names), e.g. an in-place stencil update. Paper §3.5 "In/out chaining".
    pub aliases: Vec<(String, String)>,
    /// Target vector length for vector-expanded rotation (paper Fig. 9c).
    /// 1 disables vector expansion.
    pub vector_len: usize,
}

impl Deck {
    /// Validate internal consistency; returns a list of problems (empty =
    /// valid).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.iteration.order.is_empty() {
            errs.push("iteration.order is empty".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for v in &self.iteration.order {
            if !seen.insert(v.clone()) {
                errs.push(format!("duplicate loop var `{v}` in iteration.order"));
            }
            if !self.iteration.domains.contains_key(v) {
                errs.push(format!("loop var `{v}` has no domain"));
            }
        }
        for r in &self.rules {
            for (pname, _) in r.inputs.iter() {
                if !r.params.iter().any(|p| &p.name == pname) {
                    errs.push(format!("rule `{}`: input `{pname}` not in declaration", r.name));
                }
            }
            for (pname, t) in r.outputs.iter() {
                if !r.params.iter().any(|p| &p.name == pname) {
                    errs.push(format!("rule `{}`: output `{pname}` not in declaration", r.name));
                }
                if t.tags.is_empty() && t.base_pattern {
                    // outputs like `q?[...]` with no tag would collide with the
                    // input variable family; the paper always tags derived terms.
                    errs.push(format!(
                        "rule `{}`: output `{t}` is an untagged pattern base",
                        r.name
                    ));
                }
            }
            for s in r
                .inputs
                .iter()
                .chain(r.outputs.iter())
                .flat_map(|(_, t)| t.subs.iter())
            {
                if !s.pattern && !self.iteration.order.contains(&s.var) {
                    errs.push(format!(
                        "rule `{}`: concrete subscript var `{}` is not a loop var",
                        r.name, s.var
                    ));
                }
            }
        }
        for g in &self.goals {
            if g.requires.is_pattern() {
                errs.push(format!("goal `{}` must be concrete", g.requires));
            }
            for s in &g.requires.subs {
                if !self.iteration.order.contains(&s.var) {
                    errs.push(format!("goal `{}`: `{}` is not a loop var", g.requires, s.var));
                }
            }
        }
        for a in &self.axioms {
            for s in &a.provides.subs {
                if !s.pattern && !self.iteration.order.contains(&s.var) {
                    errs.push(format!("axiom `{}`: `{}` is not a loop var", a.provides, s.var));
                }
            }
        }
        errs
    }

    pub fn rule(&self, name: &str) -> Option<&Rule> {
        self.rules.iter().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_parse_eval() {
        let b = Bound::parse("Ni-1").unwrap();
        assert_eq!(b, Bound::of("Ni", -1));
        let mut ext = BTreeMap::new();
        ext.insert("Ni".to_string(), 100i64);
        assert_eq!(b.eval(&ext).unwrap(), 99);
        assert_eq!(Bound::parse("7").unwrap().eval(&ext).unwrap(), 7);
        assert!(Bound::parse("Nq").unwrap().eval(&ext).is_err());
        assert_eq!(Bound::parse("Ni+2").unwrap().to_string(), "Ni+2");
    }

    #[test]
    fn rank_order() {
        let cfg = IterationCfg {
            order: vec!["k".into(), "j".into(), "i".into()],
            domains: BTreeMap::new(),
        };
        assert_eq!(cfg.rank("i"), 0);
        assert_eq!(cfg.rank("k"), 2);
        let mut dims = vec!["i".to_string(), "k".to_string()];
        cfg.sort_outer_first(&mut dims);
        assert_eq!(dims, vec!["k".to_string(), "i".to_string()]);
    }

    #[test]
    fn validate_catches_missing_domain() {
        let deck = Deck {
            iteration: IterationCfg { order: vec!["i".into()], domains: BTreeMap::new() },
            ..Default::default()
        };
        let errs = deck.validate();
        assert!(errs.iter().any(|e| e.contains("no domain")));
    }
}
