//! Terms: tagged array references with symbolic subscripts.

use std::collections::BTreeMap;
use std::fmt;

/// One subscript: `var ± offset`. `var` may be a unification variable
/// (spelled `i?` in deck source; stored here with the trailing `?` stripped
/// and `pattern = true`).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Subscript {
    pub var: String,
    pub offset: i64,
    pub pattern: bool,
}

impl Subscript {
    pub fn new(var: &str, offset: i64) -> Self {
        Subscript { var: var.to_string(), offset, pattern: false }
    }
    pub fn pat(var: &str, offset: i64) -> Self {
        Subscript { var: var.to_string(), offset, pattern: true }
    }
}

impl fmt::Display for Subscript {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.var, if self.pattern { "?" } else { "" })?;
        match self.offset.cmp(&0) {
            std::cmp::Ordering::Greater => write!(f, "+{}", self.offset),
            std::cmp::Ordering::Less => write!(f, "{}", self.offset),
            std::cmp::Ordering::Equal => Ok(()),
        }
    }
}

/// A term: `tag(base[sub]...[sub])` with the tag optional and possibly
/// nested (`tags` is outermost-first). The base identifier may itself be a
/// unification variable (`q?`).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Term {
    pub tags: Vec<String>,
    pub base: String,
    pub base_pattern: bool,
    pub subs: Vec<Subscript>,
}

impl Term {
    pub fn new(base: &str, subs: Vec<Subscript>) -> Self {
        Term { tags: vec![], base: base.to_string(), base_pattern: false, subs }
    }

    pub fn tagged(tag: &str, base: &str, subs: Vec<Subscript>) -> Self {
        Term { tags: vec![tag.to_string()], base: base.to_string(), base_pattern: false, subs }
    }

    /// The "identifier" of a term for storage purposes: tags + base joined.
    /// `laplace(q[j][i])` and `q[j][i]` are distinct variables.
    pub fn ident(&self) -> String {
        if self.tags.is_empty() {
            self.base.clone()
        } else {
            format!("{}({})", self.tags.join("("), self.base)
        }
    }

    /// True if this term contains any unification variables.
    pub fn is_pattern(&self) -> bool {
        self.base_pattern || self.subs.iter().any(|s| s.pattern)
    }

    /// Dimension variables used, in subscript order.
    pub fn dims(&self) -> Vec<String> {
        self.subs.iter().map(|s| s.var.clone()).collect()
    }

    /// Apply a shift to all subscripts: `shift[var]` is added to the offset
    /// of every subscript over `var`.
    pub fn shifted(&self, shift: &BTreeMap<String, i64>) -> Term {
        let mut t = self.clone();
        for s in &mut t.subs {
            if let Some(d) = shift.get(&s.var) {
                s.offset += d;
            }
        }
        t
    }

    /// Parse a term from deck source, e.g. `laplace(q?[j?][i?+1])` or
    /// `cell[j][i-2]`.
    pub fn parse(src: &str) -> Result<Term, String> {
        let src = src.trim();
        // Peel nested tags: ident '(' ... ')'.
        let mut tags = Vec::new();
        let mut rest = src;
        loop {
            // Find the first of '(' or '['. A '(' before any '[' means a tag.
            let lparen = rest.find('(');
            let lbrack = rest.find('[');
            match (lparen, lbrack) {
                (Some(p), b) if b.map_or(true, |b| p < b) => {
                    let tag = rest[..p].trim();
                    if tag.is_empty() {
                        return Err(format!("empty tag in term `{src}`"));
                    }
                    if !rest.ends_with(')') {
                        return Err(format!("unbalanced parens in term `{src}`"));
                    }
                    tags.push(tag.to_string());
                    rest = rest[p + 1..rest.len() - 1].trim();
                }
                _ => break,
            }
        }
        // Now rest = base[sub][sub]...
        let (base_raw, subs_raw) = match rest.find('[') {
            Some(b) => (&rest[..b], &rest[b..]),
            None => (rest, ""),
        };
        let base_raw = base_raw.trim();
        if base_raw.is_empty() {
            return Err(format!("empty base in term `{src}`"));
        }
        let (base, base_pattern) = strip_pattern(base_raw);
        if !ident_ok(&base) {
            return Err(format!("bad identifier `{base_raw}` in term `{src}`"));
        }
        let mut subs = Vec::new();
        let mut s = subs_raw.trim();
        while !s.is_empty() {
            if !s.starts_with('[') {
                return Err(format!("expected `[` in subscripts of `{src}`"));
            }
            let close = s.find(']').ok_or_else(|| format!("missing `]` in `{src}`"))?;
            let inner = s[1..close].trim();
            subs.push(parse_subscript(inner).map_err(|e| format!("{e} in term `{src}`"))?);
            s = s[close + 1..].trim_start();
        }
        Ok(Term { tags, base, base_pattern, subs })
    }
}

impl fmt::Display for Term {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for t in &self.tags {
            write!(f, "{t}(")?;
        }
        write!(f, "{}{}", self.base, if self.base_pattern { "?" } else { "" })?;
        for s in &self.subs {
            write!(f, "[{s}]")?;
        }
        for _ in &self.tags {
            write!(f, ")")?;
        }
        Ok(())
    }
}

fn strip_pattern(s: &str) -> (String, bool) {
    if let Some(stripped) = s.strip_suffix('?') {
        (stripped.to_string(), true)
    } else {
        (s.to_string(), false)
    }
}

fn ident_ok(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().unwrap().is_ascii_alphabetic()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse `i`, `i?`, `i+1`, `i?-2`.
fn parse_subscript(s: &str) -> Result<Subscript, String> {
    let s = s.trim();
    let split = s.find(['+', '-']);
    let (var_raw, offset) = match split {
        Some(p) if p > 0 => {
            let off: i64 = s[p..]
                .replace(' ', "")
                .parse()
                .map_err(|_| format!("bad offset `{}`", &s[p..]))?;
            (s[..p].trim(), off)
        }
        _ => (s, 0),
    };
    let (var, pattern) = strip_pattern(var_raw);
    if !ident_ok(&var) {
        return Err(format!("bad subscript var `{var_raw}`"));
    }
    Ok(Subscript { var, offset, pattern })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain() {
        let t = Term::parse("cell[j][i]").unwrap();
        assert_eq!(t.base, "cell");
        assert!(!t.base_pattern);
        assert_eq!(t.subs, vec![Subscript::new("j", 0), Subscript::new("i", 0)]);
        assert_eq!(t.to_string(), "cell[j][i]");
    }

    #[test]
    fn parse_offsets() {
        let t = Term::parse("q?[j?-1][i?+2]").unwrap();
        assert!(t.base_pattern);
        assert_eq!(t.subs, vec![Subscript::pat("j", -1), Subscript::pat("i", 2)]);
        assert_eq!(t.to_string(), "q?[j?-1][i?+2]");
    }

    #[test]
    fn parse_tagged() {
        let t = Term::parse("laplace(q?[j?][i?])").unwrap();
        assert_eq!(t.tags, vec!["laplace"]);
        assert_eq!(t.ident(), "laplace(q)");
        assert_eq!(t.to_string(), "laplace(q?[j?][i?])");
    }

    #[test]
    fn parse_nested_tags() {
        let t = Term::parse("sum(sq(f[j][i]))").unwrap();
        assert_eq!(t.tags, vec!["sum", "sq"]);
        assert_eq!(t.ident(), "sum(sq(f)");
    }

    #[test]
    fn parse_scalar_term() {
        let t = Term::parse("nsteps").unwrap();
        assert!(t.subs.is_empty());
        assert_eq!(t.ident(), "nsteps");
    }

    #[test]
    fn parse_errors() {
        assert!(Term::parse("").is_err());
        assert!(Term::parse("a[").is_err());
        assert!(Term::parse("f(x[i]").is_err());
        assert!(Term::parse("[i]").is_err());
        assert!(Term::parse("a[1b]").is_err());
    }

    #[test]
    fn shift_applies_per_var() {
        let t = Term::parse("f[j-1][i+1]").unwrap();
        let mut sh = BTreeMap::new();
        sh.insert("j".to_string(), 2i64);
        let s = t.shifted(&sh);
        assert_eq!(s.subs[0].offset, 1);
        assert_eq!(s.subs[1].offset, 1);
    }

    #[test]
    fn spaces_tolerated() {
        let t = Term::parse("  f [ j - 1 ][ i ]  ");
        // spaces inside subscripts are tolerated; base with space is not split
        assert!(t.is_ok());
        let t = t.unwrap();
        assert_eq!(t.subs[0].offset, -1);
    }
}
