//! Core intermediate representation for HFAV decks.
//!
//! A *deck* is the declarative input to the generator (paper §4, Fig. 10):
//! kernel production rules, terminal axioms (available inputs), terminal
//! goals (requested outputs), and the iteration configuration (global loop
//! order and per-variable domains).
//!
//! Terms follow the paper's grammar: an optional *tag* (a function symbol
//! such as `laplace(...)` used to distinguish stages of a value), a base
//! identifier, and a subscript list of `var ± offset` displacements, e.g.
//! `q?[j?-1][i?+1]`. Identifiers ending in `?` are unification variables.

pub mod term;
pub mod rule;
pub mod deck;

pub use deck::{Axiom, Bound, Deck, Domain, Goal, IterationCfg};
pub use rule::{Param, ParamDir, Rule};
pub use term::{Subscript, Term};

/// Scalar element types supported by the backends.
///
/// The paper's applications all use `double`; `float` is carried through for
/// completeness of the front-end (declarations in decks may use either).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scalar {
    F32,
    F64,
    I32,
    I64,
}

impl Scalar {
    /// Parse a C-like type name.
    pub fn parse(s: &str) -> Option<Scalar> {
        match s {
            "float" => Some(Scalar::F32),
            "double" => Some(Scalar::F64),
            "int" | "int32_t" => Some(Scalar::I32),
            "long" | "int64_t" => Some(Scalar::I64),
            _ => None,
        }
    }

    /// C99 spelling.
    pub fn c_name(&self) -> &'static str {
        match self {
            Scalar::F32 => "float",
            Scalar::F64 => "double",
            Scalar::I32 => "int32_t",
            Scalar::I64 => "int64_t",
        }
    }

    /// Rust spelling.
    pub fn rust_name(&self) -> &'static str {
        match self {
            Scalar::F32 => "f32",
            Scalar::F64 => "f64",
            Scalar::I32 => "i32",
            Scalar::I64 => "i64",
        }
    }

    /// Size in bytes (used by footprint accounting).
    pub fn size_bytes(&self) -> usize {
        match self {
            Scalar::F32 | Scalar::I32 => 4,
            Scalar::F64 | Scalar::I64 => 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_parse_roundtrip() {
        assert_eq!(Scalar::parse("double"), Some(Scalar::F64));
        assert_eq!(Scalar::parse("float"), Some(Scalar::F32));
        assert_eq!(Scalar::parse("void"), None);
        assert_eq!(Scalar::F64.c_name(), "double");
        assert_eq!(Scalar::F32.rust_name(), "f32");
        assert_eq!(Scalar::F64.size_bytes(), 8);
    }
}
