//! Dataflow graph: the RAP-dual DAG of grouped kernel callsites (paper
//! §3.2, Fig. 2/3), plus interval domain propagation.
//!
//! Vertices are *grouped* callsites (rule instances canonicalized modulo
//! spatial displacement — the paper's "Grouping" step falls out of this
//! canonicalization), and edges are variables (term families) annotated
//! with the read offsets of each consumer.
//!
//! Everything downstream is a query over this graph: fusion feasibility
//! is cycle/concavity analysis over [`Dataflow::edges`] (with
//! [`Dataflow::reduced_dims_upstream`] marking where a reduction's
//! result is re-broadcast), pipeline shifts are longest paths over the
//! same edges, storage reuse distances come from the per-consumer
//! [`Read::offsets`], and the vectorization legality gates in
//! [`crate::analysis`] are offset checks: inner lane fission looks for
//! per-iteration values observed by *other* callsites, and outer-dim
//! vectorization ([`crate::analysis::outer_vectorizable`]) demands that
//! no in-nest-produced variable is read at a nonzero offset along the
//! candidate dim and that every written variable is indexed by it.
//! Domain propagation (the symbolic [`crate::ir::Domain`] spans carried
//! on [`VarInfo::span`]) is what lets the emitters peel loops
//! statically and the executor bind concrete extents at run time.

use crate::ir::{Bound, Deck, Domain, Scalar};
use std::collections::{BTreeMap, BTreeSet};

/// Identifier of a callsite vertex in the dataflow graph.
pub type CallsiteId = usize;
/// Identifier of a variable (term family).
pub type VarId = usize;

/// How a variable reaches the outside world (terminal behaviour).
#[derive(Debug, Clone, PartialEq)]
pub enum Terminal {
    /// Not terminal: a pure intermediate.
    No,
    /// Terminal input (axiom): backed by external storage of this name.
    Input { storage: String, ty: Scalar },
    /// Terminal output (goal): must be stored to external storage.
    Output { storage: String, ty: Scalar },
}

/// A variable: one term family, e.g. `laplace(cell)` over dims `[j, i]`.
#[derive(Debug, Clone)]
pub struct VarInfo {
    pub id: VarId,
    /// Unique identifier, e.g. `laplace(cell)`.
    pub ident: String,
    /// Dimension vars, outermost-first (global loop order).
    pub dims: Vec<String>,
    /// Producing callsite (None for axiom terminals).
    pub producer: Option<CallsiteId>,
    /// Offset (per dim of `dims`) at which the producer writes, relative to
    /// its iteration point. Canonically zero for the first output.
    pub write_offset: Vec<i64>,
    pub terminal: Terminal,
    /// Required span per dim (half-open), derived by domain propagation.
    pub span: BTreeMap<String, Domain>,
    pub ty: Scalar,
}

/// One consumer read: `callsite` reads the variable at `offsets` (aligned
/// with `VarInfo::dims`) through kernel parameter `param`.
#[derive(Debug, Clone, PartialEq)]
pub struct Read {
    pub consumer: CallsiteId,
    pub param: String,
    pub offsets: Vec<i64>,
}

/// A grouped callsite: one rule instance (modulo displacement).
#[derive(Debug, Clone)]
pub struct Callsite {
    pub id: CallsiteId,
    /// Index into `Deck::rules`.
    pub rule: usize,
    /// Rule name (copied for convenience/diagnostics).
    pub name: String,
    /// Binding of base pattern vars, e.g. `q -> cell`.
    pub base_binding: BTreeMap<String, String>,
    /// Iteration-space dims, outermost-first.
    pub dims: Vec<String>,
    /// Iteration domain per dim (half-open), from domain propagation.
    pub domain: BTreeMap<String, Domain>,
    /// For each input param (in rule order): (var id, offsets per var dim).
    pub reads: Vec<(String, VarId, Vec<i64>)>,
    /// For each output param: (var id, offsets per var dim).
    pub writes: Vec<(String, VarId, Vec<i64>)>,
    /// Dims present in the iteration space but absent from some output —
    /// i.e. dims over which this callsite reduces that output.
    pub reduce_dims: BTreeSet<String>,
}

/// The dataflow graph.
#[derive(Debug, Clone, Default)]
pub struct Dataflow {
    pub callsites: Vec<Callsite>,
    pub vars: Vec<VarInfo>,
    pub reads_of: Vec<Vec<Read>>, // indexed by VarId
    /// ident -> VarId
    pub var_by_ident: BTreeMap<String, VarId>,
    /// Global loop order (outermost first), copied from the deck.
    pub loop_order: Vec<String>,
}

impl Dataflow {
    pub fn var(&self, ident: &str) -> Option<&VarInfo> {
        self.var_by_ident.get(ident).map(|&v| &self.vars[v])
    }

    /// Producer→consumer edges between callsites (deduped), with the vars
    /// carried on each edge.
    pub fn edges(&self) -> Vec<(CallsiteId, CallsiteId, Vec<VarId>)> {
        let mut map: BTreeMap<(CallsiteId, CallsiteId), Vec<VarId>> = BTreeMap::new();
        for v in &self.vars {
            if let Some(p) = v.producer {
                for r in &self.reads_of[v.id] {
                    let e = map.entry((p, r.consumer)).or_default();
                    if !e.contains(&v.id) {
                        e.push(v.id);
                    }
                }
            }
        }
        map.into_iter().map(|((a, b), vs)| (a, b, vs)).collect()
    }

    /// Topological order of callsites (producers first). Errors on a cycle
    /// (should be impossible by construction — one producer per term).
    pub fn topo_order(&self) -> Result<Vec<CallsiteId>, String> {
        let n = self.callsites.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<CallsiteId>> = vec![Vec::new(); n];
        for (a, b, _) in self.edges() {
            if a != b {
                adj[a].push(b);
                indeg[b] += 1;
            }
        }
        let mut queue: Vec<CallsiteId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop() {
            order.push(u);
            for &w in &adj[u] {
                indeg[w] -= 1;
                if indeg[w] == 0 {
                    queue.push(w);
                }
            }
        }
        if order.len() != n {
            return Err("cycle in dataflow graph".into());
        }
        Ok(order)
    }

    /// Can every callsite in `r` be topologically ordered no later than
    /// every callsite in `s`? (paper §3.3.2 `dataflow_le`). Equivalently:
    /// there is no path from any element of `s` to any element of `r`
    /// through the graph (excluding trivial identity).
    pub fn dataflow_le(&self, r: &BTreeSet<CallsiteId>, s: &BTreeSet<CallsiteId>) -> bool {
        if r.is_empty() || s.is_empty() {
            return true;
        }
        // Reachability from s.
        let reach = self.reachable_from(s);
        // If any r-node is strictly reachable from s (and not also in s via
        // identity), ordering r <= s fails.
        for &x in r {
            if reach.contains(&x) && !s.contains(&x) {
                return false;
            }
        }
        true
    }

    /// All callsites reachable from `from` (excluding the start set unless
    /// revisited).
    pub fn reachable_from(&self, from: &BTreeSet<CallsiteId>) -> BTreeSet<CallsiteId> {
        let mut adj: Vec<Vec<CallsiteId>> = vec![Vec::new(); self.callsites.len()];
        for (a, b, _) in self.edges() {
            adj[a].push(b);
        }
        let mut seen = BTreeSet::new();
        let mut stack: Vec<CallsiteId> = from.iter().copied().collect();
        while let Some(u) = stack.pop() {
            for &w in &adj[u] {
                if seen.insert(w) {
                    stack.push(w);
                }
            }
        }
        seen
    }

    /// Dims "reduced away" somewhere upstream of each variable — used for
    /// concave-dataflow (split) detection (paper §3.4).
    pub fn reduced_dims_upstream(&self) -> Vec<BTreeSet<String>> {
        let mut out: Vec<BTreeSet<String>> = vec![BTreeSet::new(); self.vars.len()];
        let order = self.topo_order().expect("acyclic");
        // Walk callsites in topo order; each output var accumulates the
        // producer's reduce_dims plus everything upstream of its inputs.
        for &cs_id in &order {
            let cs = &self.callsites[cs_id];
            let mut acc: BTreeSet<String> = cs.reduce_dims.iter().cloned().collect();
            for (_, v, _) in &cs.reads {
                acc.extend(out[*v].iter().cloned());
            }
            for (_, v, _) in &cs.writes {
                out[*v].extend(acc.iter().cloned());
            }
        }
        out
    }
}

/// Union two symbolic half-open domains (interval hull). Errors if bounds
/// mix different extent bases (not meaningful for stencil spans).
pub fn domain_union(a: &Domain, b: &Domain) -> Result<Domain, String> {
    Ok(Domain::new(bound_min(&a.lo, &b.lo)?, bound_max(&a.hi, &b.hi)?))
}

pub fn bound_min(a: &Bound, b: &Bound) -> Result<Bound, String> {
    if a.base == b.base {
        Ok(Bound { base: a.base.clone(), offset: a.offset.min(b.offset) })
    } else {
        Err(format!("cannot compare bounds `{a}` and `{b}`"))
    }
}

pub fn bound_max(a: &Bound, b: &Bound) -> Result<Bound, String> {
    if a.base == b.base {
        Ok(Bound { base: a.base.clone(), offset: a.offset.max(b.offset) })
    } else {
        Err(format!("cannot compare bounds `{a}` and `{b}`"))
    }
}

/// Shift a domain by an offset range `[min_o, max_o]` (consumer-driven
/// producer span: values read at `t + o` for `t` in `dom`).
pub fn domain_shift(dom: &Domain, min_o: i64, max_o: i64) -> Domain {
    Domain::new(dom.lo.plus(min_o), dom.hi.plus(max_o))
}

/// Allocation extents of a terminal array given its required span and the
/// deck's declared domain for each dim — used for halo accounting.
pub fn span_words(
    span: &BTreeMap<String, Domain>,
    extents: &BTreeMap<String, i64>,
) -> Result<i64, String> {
    let mut words = 1i64;
    for d in span.values() {
        let lo = d.lo.eval(extents)?;
        let hi = d.hi.eval(extents)?;
        words *= (hi - lo).max(0);
    }
    Ok(words)
}

/// Build the dataflow graph from a deck: run the inference engine.
pub fn build(deck: &Deck) -> Result<Dataflow, String> {
    crate::inference::infer(deck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;

    fn laplace_df() -> Dataflow {
        let deck = crate::frontend::parse_deck(testdecks::LAPLACE).unwrap();
        build(&deck).unwrap()
    }

    #[test]
    fn laplace_graph_shape() {
        let df = laplace_df();
        // One callsite (laplace5); vars: cell (terminal in), laplace(cell)
        // (terminal out).
        assert_eq!(df.callsites.len(), 1);
        assert_eq!(df.vars.len(), 2);
        let lap = df.var("laplace(cell)").unwrap();
        assert!(matches!(lap.terminal, Terminal::Output { .. }));
        let cell = df.var("cell").unwrap();
        assert!(matches!(cell.terminal, Terminal::Input { .. }));
        // 5 reads of cell with the stencil offsets.
        let offs: BTreeSet<Vec<i64>> =
            df.reads_of[cell.id].iter().map(|r| r.offsets.clone()).collect();
        let expect: BTreeSet<Vec<i64>> = [
            vec![-1, 0],
            vec![0, 1],
            vec![1, 0],
            vec![0, -1],
            vec![0, 0],
        ]
        .into_iter()
        .collect();
        assert_eq!(offs, expect);
    }

    #[test]
    fn laplace_halo_span() {
        let df = laplace_df();
        let cell = df.var("cell").unwrap();
        // Goal domain is [1, N-1); reads at ±1 → span [0, N).
        let sj = &cell.span["j"];
        assert_eq!(sj.lo, Bound::constant(0));
        assert_eq!(sj.hi, Bound::of("Nj", 0));
    }

    #[test]
    fn normalize_graph_shape() {
        let deck = crate::frontend::parse_deck(testdecks::NORMALIZE).unwrap();
        let df = build(&deck).unwrap();
        // Callsites: flux, norm_init, norm_acc, norm_root, normalize.
        assert_eq!(df.callsites.len(), 5);
        let acc = df.callsites.iter().find(|c| c.name == "norm_acc").unwrap();
        assert_eq!(acc.dims, vec!["j".to_string(), "i".to_string()]);
        assert!(acc.reduce_dims.contains("i"));
        let init = df.callsites.iter().find(|c| c.name == "norm_init").unwrap();
        assert_eq!(init.dims, vec!["j".to_string()]);
        // Concavity: rsqrt(acc) has i reduced upstream.
        let rd = df.reduced_dims_upstream();
        let rs = df.var("rsqrt(acc)").unwrap();
        assert!(rd[rs.id].contains("i"));
        let fx = df.var("flux(q)").unwrap();
        assert!(rd[fx.id].is_empty());
    }

    #[test]
    fn topo_and_le() {
        let deck = crate::frontend::parse_deck(testdecks::NORMALIZE).unwrap();
        let df = build(&deck).unwrap();
        let order = df.topo_order().unwrap();
        let pos = |name: &str| {
            let id = df.callsites.iter().find(|c| c.name == name).unwrap().id;
            order.iter().position(|&x| x == id).unwrap()
        };
        assert!(pos("flux") < pos("norm_acc"));
        assert!(pos("norm_acc") < pos("norm_root"));
        assert!(pos("norm_root") < pos("normalize"));

        let id = |name: &str| df.callsites.iter().find(|c| c.name == name).unwrap().id;
        let r: BTreeSet<_> = [id("flux")].into_iter().collect();
        let s: BTreeSet<_> = [id("normalize")].into_iter().collect();
        assert!(df.dataflow_le(&r, &s));
        assert!(!df.dataflow_le(&s, &r));
    }

    #[test]
    fn domain_helpers() {
        let a = Domain::new(Bound::constant(1), Bound::of("N", -1));
        let b = Domain::new(Bound::constant(0), Bound::of("N", 0));
        let u = domain_union(&a, &b).unwrap();
        assert_eq!(u.lo, Bound::constant(0));
        assert_eq!(u.hi, Bound::of("N", 0));
        let s = domain_shift(&a, -1, 2);
        assert_eq!(s.lo, Bound::constant(0));
        assert_eq!(s.hi, Bound::of("N", 1));
        assert!(bound_min(&Bound::of("N", 0), &Bound::of("M", 0)).is_err());
    }
}
