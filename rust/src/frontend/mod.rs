//! Front-end: parse a YAML deck (paper §4, Fig. 10) into an [`ir::Deck`].
//!
//! Deck format (a superset of the paper's, with the iteration section made
//! explicit so decks are self-contained):
//!
//! ```yaml
//! name: laplace
//! iteration:
//!   order: [j, i]            # outermost first
//!   domains:
//!     j: [1, Nj-1]           # half-open [lo, hi)
//!     i: [1, Ni-1]
//! kernels:
//!   laplace:
//!     declaration: laplace5(double n, double e, double s, double w, double c, double &o);
//!     inputs: |
//!       n : q?[j?-1][i?]
//!       ...
//!     outputs: |
//!       o : laplace(q?[j?][i?])
//!     body: "o = 0.25*(n + e + s + w) - c;"   # optional, for inlining emitters
//!     body_rs: "o = 0.25*(n + e + s + w) - c;" # optional Rust-specific body
//!                                              # (falls back to `body`)
//! globals:
//!   inputs: |
//!     double g_cell[j?][i?] => cell[j?][i?]
//!   outputs: |
//!     laplace(cell[j][i]) => double g_cell[j][i]
//! aliases:                    # optional: in-place updates (paper §3.5)
//!   - [g_cell, g_out]
//! vector_len: 8               # optional: vector-expanded rotation (Fig. 9c)
//! ```

use crate::ir::{Axiom, Bound, Deck, Domain, Goal, IterationCfg, ParamDir, Rule, Scalar, Term};
use crate::yaml::{self, Node};
use std::collections::BTreeMap;

/// Parse deck source text.
pub fn parse_deck(src: &str) -> Result<Deck, String> {
    let root = yaml::parse(src)?;
    deck_from_node(&root)
}

/// Parse a deck from a file path.
pub fn load_deck(path: &str) -> Result<Deck, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_deck(&src)
}

fn deck_from_node(root: &Node) -> Result<Deck, String> {
    let mut deck = Deck {
        name: root.get("name").and_then(|n| n.as_str()).unwrap_or("deck").to_string(),
        vector_len: 1,
        ..Default::default()
    };

    // iteration
    let iter = root.get("iteration").ok_or("missing `iteration` section")?;
    let order_node = iter.get("order").ok_or("missing `iteration.order`")?;
    let order: Vec<String> = order_node
        .as_seq()
        .ok_or("`iteration.order` must be a sequence")?
        .iter()
        .map(|n| n.as_str().map(str::to_string).ok_or("non-scalar in order".to_string()))
        .collect::<Result<_, _>>()?;
    let mut domains = BTreeMap::new();
    let dom_node = iter.get("domains").ok_or("missing `iteration.domains`")?;
    for (var, v) in dom_node.as_map().ok_or("`iteration.domains` must be a map")? {
        let seq = v.as_seq().ok_or_else(|| format!("domain of `{var}` must be [lo, hi]"))?;
        if seq.len() != 2 {
            return Err(format!("domain of `{var}` must have exactly [lo, hi]"));
        }
        let lo = Bound::parse(seq[0].as_str().ok_or("bad lo bound")?)?;
        let hi = Bound::parse(seq[1].as_str().ok_or("bad hi bound")?)?;
        domains.insert(var.clone(), Domain::new(lo, hi));
    }
    deck.iteration = IterationCfg { order, domains };

    // kernels
    if let Some(kernels) = root.get("kernels") {
        for (kname, knode) in kernels.as_map().ok_or("`kernels` must be a map")? {
            deck.rules.push(parse_kernel(kname, knode)?);
        }
    }

    // globals
    let globals = root.get("globals").ok_or("missing `globals` section")?;
    if let Some(inputs) = globals.get("inputs").and_then(|n| n.as_str()) {
        for line in nonempty_lines(inputs) {
            deck.axioms.push(parse_axiom(line)?);
        }
    }
    if let Some(outputs) = globals.get("outputs").and_then(|n| n.as_str()) {
        for line in nonempty_lines(outputs) {
            deck.goals.push(parse_goal(line)?);
        }
    }

    // aliases
    if let Some(aliases) = root.get("aliases") {
        for a in aliases.as_seq().ok_or("`aliases` must be a sequence")? {
            let pair = a.as_seq().ok_or("alias entries must be [in, out]")?;
            if pair.len() != 2 {
                return Err("alias entries must be [in, out]".into());
            }
            deck.aliases.push((
                pair[0].as_str().unwrap_or("").to_string(),
                pair[1].as_str().unwrap_or("").to_string(),
            ));
        }
    }

    if let Some(vl) = root.get("vector_len").and_then(|n| n.as_str()) {
        deck.vector_len = vl.parse::<usize>().map_err(|_| format!("bad vector_len `{vl}`"))?;
        if deck.vector_len == 0 {
            return Err("vector_len must be >= 1".into());
        }
    }

    let errs = deck.validate();
    if !errs.is_empty() {
        return Err(format!("invalid deck `{}`:\n  {}", deck.name, errs.join("\n  ")));
    }
    Ok(deck)
}

fn parse_kernel(name: &str, node: &Node) -> Result<Rule, String> {
    let decl = node
        .get("declaration")
        .and_then(|n| n.as_str())
        .ok_or_else(|| format!("kernel `{name}`: missing declaration"))?;
    let (decl_name, params) = Rule::parse_declaration(decl)?;

    let mut inputs = Vec::new();
    if let Some(block) = node.get("inputs").and_then(|n| n.as_str()) {
        for line in nonempty_lines(block) {
            let (pname, term) = parse_binding(line)?;
            inputs.push((pname, term));
        }
    }
    let mut outputs = Vec::new();
    if let Some(block) = node.get("outputs").and_then(|n| n.as_str()) {
        for line in nonempty_lines(block) {
            let (pname, term) = parse_binding(line)?;
            outputs.push((pname, term));
        }
    }
    let body = node.get("body").and_then(|n| n.as_str()).map(str::to_string);
    let body_rs = node.get("body_rs").and_then(|n| n.as_str()).map(str::to_string);

    // Check coverage: every In param bound in inputs, every Out in outputs.
    for p in &params {
        let list = if p.dir == ParamDir::In { &inputs } else { &outputs };
        if !list.iter().any(|(n, _)| n == &p.name) {
            return Err(format!(
                "kernel `{name}`: parameter `{}` ({:?}) has no term binding",
                p.name, p.dir
            ));
        }
    }
    for (pname, _) in inputs.iter() {
        match params.iter().find(|p| &p.name == pname) {
            Some(p) if p.dir == ParamDir::In => {}
            Some(_) => {
                return Err(format!(
                    "kernel `{name}`: `{pname}` bound as input but declared output"
                ))
            }
            None => return Err(format!("kernel `{name}`: unknown input param `{pname}`")),
        }
    }
    for (pname, _) in outputs.iter() {
        match params.iter().find(|p| &p.name == pname) {
            Some(p) if p.dir == ParamDir::Out => {}
            Some(_) => {
                return Err(format!(
                    "kernel `{name}`: `{pname}` bound as output but declared input"
                ))
            }
            None => return Err(format!("kernel `{name}`: unknown output param `{pname}`")),
        }
    }

    Ok(Rule { name: decl_name, params, inputs, outputs, body, body_rs })
}

/// `n : q?[j?-1][i?]`
fn parse_binding(line: &str) -> Result<(String, Term), String> {
    let (pname, rest) = line
        .split_once(':')
        .ok_or_else(|| format!("expected `param : term` in `{line}`"))?;
    let term = Term::parse(rest)?;
    Ok((pname.trim().to_string(), term))
}

/// `double g_cell[j?][i?] => cell[j?][i?]`
fn parse_axiom(line: &str) -> Result<Axiom, String> {
    let (lhs, rhs) = line
        .split_once("=>")
        .ok_or_else(|| format!("expected `storage => term` in axiom `{line}`"))?;
    let (ty, storage) = parse_typed_storage(lhs)?;
    let provides = Term::parse(rhs)?;
    Ok(Axiom { storage, ty, provides })
}

/// `laplace(cell[j][i]) => double g_cell[j][i]`
fn parse_goal(line: &str) -> Result<Goal, String> {
    let (lhs, rhs) = line
        .split_once("=>")
        .ok_or_else(|| format!("expected `term => storage` in goal `{line}`"))?;
    let requires = Term::parse(lhs)?;
    let (ty, storage) = parse_typed_storage(rhs)?;
    Ok(Goal { requires, ty, storage })
}

fn parse_typed_storage(s: &str) -> Result<(Scalar, Term), String> {
    let s = s.trim();
    let (ty_raw, rest) = s
        .split_once(char::is_whitespace)
        .ok_or_else(|| format!("expected `type storage[...]` in `{s}`"))?;
    let ty = Scalar::parse(ty_raw).ok_or_else(|| format!("unknown type `{ty_raw}`"))?;
    let storage = Term::parse(rest)?;
    if !storage.tags.is_empty() {
        return Err(format!("storage `{rest}` must be untagged"));
    }
    Ok((ty, storage))
}

fn nonempty_lines(block: &str) -> impl Iterator<Item = &str> {
    block.lines().map(str::trim).filter(|l| !l.is_empty())
}

#[cfg(test)]
pub mod testdecks;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_laplace_deck() {
        let deck = parse_deck(testdecks::LAPLACE).unwrap();
        assert_eq!(deck.name, "laplace");
        assert_eq!(deck.rules.len(), 1);
        let r = &deck.rules[0];
        assert_eq!(r.name, "laplace5");
        assert_eq!(r.inputs.len(), 5);
        assert_eq!(r.outputs.len(), 1);
        assert_eq!(deck.axioms.len(), 1);
        assert_eq!(deck.goals.len(), 1);
        assert_eq!(deck.iteration.order, vec!["j", "i"]);
        assert_eq!(deck.iteration.rank("i"), 0);
    }

    #[test]
    fn missing_iteration_rejected() {
        assert!(parse_deck("kernels:\n").is_err());
    }

    #[test]
    fn unbound_param_rejected() {
        let src = r#"
name: bad
iteration:
  order: [i]
  domains:
    i: [0, N]
kernels:
  k:
    declaration: k(double a, double &b);
    inputs: |
      a : u?[i?]
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    k(u[i]) => double g_o[i]
"#;
        let err = parse_deck(src).unwrap_err();
        assert!(err.contains("has no term binding"), "{err}");
    }

    #[test]
    fn goal_must_be_concrete() {
        let src = r#"
name: bad
iteration:
  order: [i]
  domains:
    i: [0, N]
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    f(u[i?]) => double g_o[i]
"#;
        assert!(parse_deck(src).is_err());
    }

    #[test]
    fn aliases_and_vector_len() {
        let src = r#"
name: t
iteration:
  order: [i]
  domains:
    i: [1, N-1]
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    u[i] => double g_u2[i]
aliases:
  - [g_u, g_u2]
vector_len: 8
"#;
        let deck = parse_deck(src).unwrap();
        assert_eq!(deck.aliases, vec![("g_u".to_string(), "g_u2".to_string())]);
        assert_eq!(deck.vector_len, 8);
    }
}
