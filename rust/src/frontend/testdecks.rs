//! Shared deck fixtures for unit tests across engine modules.

/// The paper's running example (Listing 1 / Fig. 10): 5-point Laplace.
pub const LAPLACE: &str = r#"
name: laplace
iteration:
  order: [j, i]
  domains:
    j: [1, Nj-1]
    i: [1, Ni-1]
kernels:
  laplace:
    declaration: laplace5(double n, double e, double s, double w, double c, double &o);
    inputs: |
      n : q?[j?-1][i?]
      e : q?[j?][i?+1]
      s : q?[j?+1][i?]
      w : q?[j?][i?-1]
      c : q?[j?][i?]
    outputs: |
      o : laplace(q?[j?][i?])
    body: "o = 0.25*(n + e + s + w) - c;"
globals:
  inputs: |
    double g_cell[j?][i?] => cell[j?][i?]
  outputs: |
    laplace(cell[j][i]) => double g_out[j][i]
"#;

/// The paper's normalization example (§3, Figs. 3/4/6, §5.2): per-row flux
/// differences, an L2-norm reduction over `i`, and a normalize broadcast.
/// Unfused this visits the (j,i) space five times; fused it is two nests
/// split at the reduction→broadcast concavity.
pub const NORMALIZE: &str = r#"
name: normalize
iteration:
  order: [j, i]
  domains:
    j: [0, Nj]
    i: [0, Ni]
kernels:
  flux:
    declaration: flux(double l, double r, double &f);
    inputs: |
      l : q?[j?][i?]
      r : q?[j?][i?+1]
    outputs: |
      f : flux(q?[j?][i?])
    body: "f = r - l;"
  norm_init:
    declaration: norm_init(double &a);
    outputs: |
      a : zero(acc[j?])
    body: "a = 0.0;"
  norm_acc:
    declaration: norm_acc(double a0, double f, double &a);
    inputs: |
      a0 : zero(acc[j?])
      f : flux(q[j?][i?])
    outputs: |
      a : sum(acc[j?])
    body: "a = a0 + f*f;"
  norm_root:
    declaration: norm_root(double a, double &r);
    inputs: |
      a : sum(acc[j?])
    outputs: |
      r : rsqrt(acc[j?])
    body: "r = 1.0/sqrt(a + 1e-30);"
  normalize:
    declaration: normalize(double f, double r, double &o);
    inputs: |
      f : flux(q[j?][i?])
      r : rsqrt(acc[j?])
    outputs: |
      o : normed(q[j?][i?])
    body: "o = f*r;"
globals:
  inputs: |
    double g_q[j?][i?] => q[j?][i?]
  outputs: |
    normed(q[j][i]) => double g_out[j][i]
"#;

/// A 1D 3-point stencil chain used to exercise pipelining/contraction:
/// d[i] = b[i+1]-b[i-1] where b = a*2 — producer must run ahead of consumer.
pub const CHAIN1D: &str = r#"
name: chain1d
iteration:
  order: [i]
  domains:
    i: [1, N-1]
kernels:
  dbl:
    declaration: dbl(double a, double &b);
    inputs: |
      a : u?[i?]
    outputs: |
      b : dbl(u?[i?])
    body: "b = 2.0*a;"
  diff:
    declaration: diff(double l, double r, double &d);
    inputs: |
      l : dbl(u?[i?-1])
      r : dbl(u?[i?+1])
    outputs: |
      d : diff(u?[i?])
    body: "d = r - l;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    diff(u[i]) => double g_d[i]
"#;
