//! Iteration-nest fusion (paper §3.3–§3.4, Figs. 5 & 7).
//!
//! The iteration-nest DAG starts with one (perfect) nest per grouped
//! callsite; fusion merges nests along dataflow edges as long as:
//!
//! * no *concave dataflow* crosses the merge — a broadcast consuming the
//!   (transitive) result of a reduction forces a **split** (paper §3.4);
//! * the merge keeps the group schedulable — every member missing a loop
//!   dim must have a consistent placement (prologue or epilogue) relative
//!   to that loop, derived from dataflow (this is the rank-difference case
//!   of `fuse_inest`, Fig. 7: the lower-ranked nest fuses into the
//!   higher-ranked nest's prologue/epilogue);
//! * no dataflow path leaves the group and re-enters it (cycle check —
//!   the `dataflow_le` conditions of Fig. 7).
//!
//! Within a fused group, *software-pipeline shifts* are assigned per dim by
//! longest-path over the group's dataflow edges, so every producer runs
//! just far enough ahead of its consumers (this realizes the paper's
//! prologue/steady-state/epilogue phases; see [`crate::plan`]).
//!
//! # What downstream stages read off a [`FusedNest`]
//!
//! * **Storage contraction** ([`crate::analysis`]) requires every
//!   producer and consumer of a variable to sit in *one* nest — a split
//!   (recorded in [`FusedDag::splits`]) forces full-span storage, which
//!   is the measurable cost of a fusion barrier (paper §5.2).
//! * **Vectorization legality** is judged against the nest's
//!   [`Member`] roles and shifts: inner-strip lane fission
//!   ([`crate::analysis::lane_fission_safe`]) inspects the innermost
//!   [`Role::Loop`] members, and outer-dim vectorization
//!   ([`crate::analysis::outer_vectorizable`]) demands `Role::Loop`
//!   with zero shift for every member at the candidate level —
//!   prologue/epilogue placement or a nonzero pipeline shift along a
//!   dim is exactly what makes lanes along it unsafe.
//! * **Code emission** walks `dims` outermost-first, partitioning
//!   members by role at each level; `shifts` become the static peeling
//!   offsets of the emitted prologue/steady-state/epilogue segments.

use crate::dataflow::{CallsiteId, Dataflow, VarId};
use std::collections::{BTreeMap, BTreeSet};

/// Placement of a callsite relative to a loop dim it does not iterate
/// (paper: which *phase* of the enclosing nest it lands in).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Iterates this dim in the loop body (steady state).
    Loop,
    /// Runs before the loop (prologue) at each outer iteration.
    Pre,
    /// Runs after the loop (epilogue) at each outer iteration.
    Post,
}

/// One member of a fused nest.
#[derive(Debug, Clone)]
pub struct Member {
    pub callsite: CallsiteId,
    /// Role per nest dim (aligned with `FusedNest::dims`).
    pub roles: Vec<Role>,
    /// Pipeline shift per nest dim (0 for dims the member doesn't iterate).
    pub shifts: Vec<i64>,
}

/// A fused iteration nest: a set of callsites scheduled under one loop
/// tree over `dims`.
#[derive(Debug, Clone)]
pub struct FusedNest {
    pub id: usize,
    /// Union of member dims, outermost-first.
    pub dims: Vec<String>,
    /// Members in dataflow-topological order (the emission order).
    pub members: Vec<Member>,
}

impl FusedNest {
    pub fn member(&self, cs: CallsiteId) -> Option<&Member> {
        self.members.iter().find(|m| m.callsite == cs)
    }
    pub fn dim_index(&self, d: &str) -> Option<usize> {
        self.dims.iter().position(|x| x == d)
    }
}

/// The fused iteration-nest DAG: nests in execution order (edges always go
/// from earlier to later nests by construction).
#[derive(Debug, Clone)]
pub struct FusedDag {
    pub nests: Vec<FusedNest>,
    /// Why each split happened, for diagnostics/DOT: (producer callsite,
    /// consumer callsite, variable, reason).
    pub splits: Vec<SplitInfo>,
}

#[derive(Debug, Clone)]
pub struct SplitInfo {
    pub producer: CallsiteId,
    pub consumer: CallsiteId,
    pub var: VarId,
    pub reason: String,
}

impl FusedDag {
    /// Which nest a callsite landed in.
    pub fn nest_of(&self, cs: CallsiteId) -> usize {
        self.nests
            .iter()
            .position(|n| n.member(cs).is_some())
            .expect("callsite not in any nest")
    }
}

/// Options controlling fusion.
#[derive(Debug, Clone)]
pub struct FusionOptions {
    /// Disable fusion entirely (one nest per callsite) — the "autovec"
    /// baseline shape used in the paper's performance comparisons.
    pub enabled: bool,
}

impl Default for FusionOptions {
    fn default() -> Self {
        FusionOptions { enabled: true }
    }
}

/// Fuse the iteration-nest DAG (paper Fig. 5 `fuse_inest_dag`).
pub fn fuse(df: &Dataflow, opts: &FusionOptions) -> Result<FusedDag, String> {
    let order = df.topo_order()?;
    let reduced_upstream = df.reduced_dims_upstream();

    let mut splits = Vec::new();

    // Precompute adjacency for descendant queries.
    let edges = df.edges();
    let mut adj: Vec<Vec<CallsiteId>> = vec![Vec::new(); df.callsites.len()];
    for (a, b, _) in &edges {
        adj[*a].push(*b);
    }
    let descendants = |v: CallsiteId| -> BTreeSet<CallsiteId> {
        let mut seen = BTreeSet::new();
        let mut stack = vec![v];
        while let Some(u) = stack.pop() {
            for &w in &adj[u] {
                if seen.insert(w) {
                    stack.push(w);
                }
            }
        }
        seen
    };

    // Record concave edges once (they are properties of the dataflow, not
    // of the grouping state).
    let mut concave: BTreeSet<(CallsiteId, CallsiteId)> = BTreeSet::new();
    for v in &df.vars {
        if let Some(p) = v.producer {
            for r in &df.reads_of[v.id] {
                let c = &df.callsites[r.consumer];
                // Broadcast: consumer iterates dims the variable lacks.
                let extra: Vec<&String> =
                    c.dims.iter().filter(|d| !v.dims.contains(d)).collect();
                if extra.is_empty() {
                    continue;
                }
                // Concave iff any such dim was reduced away upstream.
                if extra.iter().any(|d| reduced_upstream[v.id].contains(*d)) {
                    if concave.insert((p, r.consumer)) {
                        splits.push(SplitInfo {
                            producer: p,
                            consumer: r.consumer,
                            var: v.id,
                            reason: format!(
                                "concave dataflow: `{}` re-expands reduced dim(s) {:?}",
                                v.ident,
                                extra.iter().map(|d| d.as_str()).collect::<Vec<_>>()
                            ),
                        });
                    }
                }
            }
        }
    }

    let mut remaining: Vec<CallsiteId> = order.clone();
    let mut nests: Vec<FusedNest> = Vec::new();

    while !remaining.is_empty() {
        let mut group: Vec<CallsiteId> = vec![remaining[0]];
        let mut blocked: BTreeSet<CallsiteId> = BTreeSet::new();

        if opts.enabled {
            for &v in remaining.iter().skip(1) {
                if blocked.contains(&v) {
                    continue;
                }
                let mut candidate = group.clone();
                candidate.push(v);
                match group_feasible(df, &candidate, &concave) {
                    Ok(()) => group.push(v),
                    Err(_) => {
                        blocked.insert(v);
                        blocked.extend(descendants(v));
                    }
                }
            }
        }

        let nest = build_nest(df, nests.len(), &group)?;
        nests.push(nest);
        let in_group: BTreeSet<CallsiteId> = group.into_iter().collect();
        remaining.retain(|c| !in_group.contains(c));
    }

    Ok(FusedDag { nests, splits })
}

/// Check that a candidate member set forms a valid fused nest.
fn group_feasible(
    df: &Dataflow,
    members: &[CallsiteId],
    concave: &BTreeSet<(CallsiteId, CallsiteId)>,
) -> Result<(), String> {
    let set: BTreeSet<CallsiteId> = members.iter().copied().collect();

    // 1. No concave edge inside the group.
    for &(p, c) in concave {
        if set.contains(&p) && set.contains(&c) {
            return Err(format!("concave edge {p}->{c} inside group"));
        }
    }

    // 2. No path from a member to a member through a non-member (merging
    //    would create a cycle in the nest DAG).
    //    Find everything reachable from the group through non-members; if a
    //    member is reached via a non-member, reject.
    let edges = df.edges();
    let mut adj: Vec<Vec<CallsiteId>> = vec![Vec::new(); df.callsites.len()];
    for (a, b, _) in &edges {
        adj[*a].push(*b);
    }
    let mut outside_reached: BTreeSet<CallsiteId> = BTreeSet::new();
    let mut stack: Vec<CallsiteId> = Vec::new();
    for &m in members {
        for &w in &adj[m] {
            if !set.contains(&w) && outside_reached.insert(w) {
                stack.push(w);
            }
        }
    }
    while let Some(u) = stack.pop() {
        for &w in &adj[u] {
            if set.contains(&w) {
                return Err(format!("path re-enters group at callsite {w}"));
            }
            if outside_reached.insert(w) {
                stack.push(w);
            }
        }
    }

    // 3. Placement consistency for members missing dims.
    compute_roles(df, members).map(|_| ())
}

/// Union of member dims, outermost-first (uses the order carried on the
/// callsites, which inference sorted by the deck's global loop order).
fn union_dims(df: &Dataflow, members: &[CallsiteId]) -> Vec<String> {
    let mut dims: Vec<String> = Vec::new();
    for &m in members {
        for d in &df.callsites[m].dims {
            if !dims.contains(d) {
                dims.push(d.clone());
            }
        }
    }
    dims.sort_by_key(|d| df.loop_order.iter().position(|v| v == d).unwrap_or(usize::MAX));
    dims
}

/// Derive the Pre/Post role of every member for every dim it lacks.
/// Errors if any member would need to be both before and after the loop
/// over some dim.
fn compute_roles(df: &Dataflow, members: &[CallsiteId]) -> Result<Vec<Vec<Role>>, String> {
    let set: BTreeSet<CallsiteId> = members.iter().copied().collect();
    let dims = union_dims(df, members);
    let edges: Vec<(CallsiteId, CallsiteId)> = df
        .edges()
        .into_iter()
        .filter(|(a, b, _)| set.contains(a) && set.contains(b) && a != b)
        .map(|(a, b, _)| (a, b))
        .collect();

    let idx: BTreeMap<CallsiteId, usize> =
        members.iter().enumerate().map(|(i, &c)| (c, i)).collect();

    let mut roles: Vec<Vec<Role>> = members
        .iter()
        .map(|&m| {
            dims.iter()
                .map(|d| {
                    if df.callsites[m].dims.contains(d) {
                        Role::Loop
                    } else {
                        Role::Pre // provisional
                    }
                })
                .collect()
        })
        .collect();

    for (k, d) in dims.iter().enumerate() {
        // Constraint lattice per member: {Pre, Post}; start unknown (None).
        let mut need_pre = vec![false; members.len()];
        let mut need_post = vec![false; members.len()];
        // Direct constraints from edges touching d-having members.
        // Fixed-point: propagate along edges among d-missing members.
        loop {
            let mut changed = false;
            for &(a, b) in &edges {
                let (ia, ib) = (idx[&a], idx[&b]);
                let a_has = roles[ia][k] == Role::Loop;
                let b_has = roles[ib][k] == Role::Loop;
                match (a_has, b_has) {
                    (true, false) => {
                        // d-having producer feeds d-missing consumer: the
                        // consumer must run after the loop completes.
                        if !need_post[ib] {
                            need_post[ib] = true;
                            changed = true;
                        }
                    }
                    (false, true) => {
                        // d-missing producer feeds d-having consumer: run
                        // before the loop (prologue).
                        if !need_pre[ia] {
                            need_pre[ia] = true;
                            changed = true;
                        }
                    }
                    (false, false) => {
                        // order within the missing set: b >= a.
                        if need_post[ia] && !need_post[ib] {
                            need_post[ib] = true;
                            changed = true;
                        }
                        if need_pre[ib] && !need_pre[ia] {
                            need_pre[ia] = true;
                            changed = true;
                        }
                    }
                    (true, true) => {}
                }
            }
            if !changed {
                break;
            }
        }
        for (m, r) in roles.iter_mut().enumerate() {
            if r[k] == Role::Loop {
                continue;
            }
            match (need_pre[m], need_post[m]) {
                (true, true) => {
                    return Err(format!(
                        "callsite `{}` needs both prologue and epilogue placement for dim `{d}`",
                        df.callsites[members[m]].name
                    ));
                }
                (false, true) => r[k] = Role::Post,
                _ => r[k] = Role::Pre,
            }
        }
    }
    Ok(roles)
}

/// Assemble a fused nest: roles, member order, pipeline shifts.
fn build_nest(df: &Dataflow, id: usize, group: &[CallsiteId]) -> Result<FusedNest, String> {
    let dims = union_dims(df, group);
    let roles = compute_roles(df, group)?;

    // Member order: topological within the group.
    let set: BTreeSet<CallsiteId> = group.iter().copied().collect();
    let order = df.topo_order()?;
    let sorted: Vec<CallsiteId> = order.into_iter().filter(|c| set.contains(c)).collect();
    // Map group position -> roles index (roles computed in `group` order).
    let role_of: BTreeMap<CallsiteId, Vec<Role>> = group
        .iter()
        .zip(roles.into_iter())
        .map(|(&c, r)| (c, r))
        .collect();

    // Pipeline shifts per dim: longest path over in-group edges,
    // s_p >= s_c + max_read_offset - write_offset, in reverse topo order.
    let mut shifts: BTreeMap<CallsiteId, Vec<i64>> =
        sorted.iter().map(|&c| (c, vec![0i64; dims.len()])).collect();
    for &c in sorted.iter().rev() {
        // For each input var of c produced inside the group:
        for (_, vid, offsets) in &df.callsites[c].reads {
            let var = &df.vars[*vid];
            if let Some(p) = var.producer {
                if !set.contains(&p) || p == c {
                    continue;
                }
                for (vk, d) in var.dims.iter().enumerate() {
                    let nd = match dims.iter().position(|x| x == d) {
                        Some(nd) => nd,
                        None => continue,
                    };
                    let o = offsets[vk];
                    let wo = var.write_offset[vk];
                    let sc = shifts[&c][nd];
                    let req = sc + o - wo;
                    let sp = shifts.get_mut(&p).unwrap();
                    if req > sp[nd] {
                        sp[nd] = req;
                    }
                }
            }
        }
    }

    // Aggregate all reads of a var: the producer shift must satisfy the
    // *maximum* over every consumer read; the loop above processes each
    // read, and reverse-topo order guarantees consumer shifts are final
    // before the producer's is read... except chains where producer==consumer
    // order ties; the DAG has no such ties (p != c enforced).

    let members = sorted
        .iter()
        .map(|&c| Member {
            callsite: c,
            roles: role_of[&c].clone(),
            shifts: shifts[&c].clone(),
        })
        .collect();

    Ok(FusedNest { id, dims, members })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{parse_deck, testdecks};

    fn fused(src: &str) -> (crate::ir::Deck, Dataflow, FusedDag) {
        let deck = parse_deck(src).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions::default()).unwrap();
        (deck, df, fd)
    }

    #[test]
    fn laplace_single_nest() {
        let (_, _, fd) = fused(testdecks::LAPLACE);
        assert_eq!(fd.nests.len(), 1);
        assert_eq!(fd.nests[0].dims, vec!["j".to_string(), "i".to_string()]);
        assert!(fd.splits.is_empty());
    }

    #[test]
    fn chain1d_fuses_with_shift() {
        let (_, df, fd) = fused(testdecks::CHAIN1D);
        assert_eq!(fd.nests.len(), 1);
        let nest = &fd.nests[0];
        let dbl = df.callsites.iter().find(|c| c.name == "dbl").unwrap().id;
        let diff = df.callsites.iter().find(|c| c.name == "diff").unwrap().id;
        // diff reads dbl(u) at i+1 → dbl runs 1 ahead.
        assert_eq!(nest.member(dbl).unwrap().shifts, vec![1]);
        assert_eq!(nest.member(diff).unwrap().shifts, vec![0]);
        // dbl before diff in emission order.
        let pos = |c| nest.members.iter().position(|m| m.callsite == c).unwrap();
        assert!(pos(dbl) < pos(diff));
    }

    #[test]
    fn normalize_splits_at_concavity() {
        let (_, df, fd) = fused(testdecks::NORMALIZE);
        // Two nests: {flux, norm_init, norm_acc, norm_root} and {normalize}.
        assert_eq!(fd.nests.len(), 2, "splits: {:?}", fd.splits);
        assert!(!fd.splits.is_empty());
        let name = |c: CallsiteId| df.callsites[c].name.clone();
        let n0: Vec<String> = fd.nests[0].members.iter().map(|m| name(m.callsite)).collect();
        let n1: Vec<String> = fd.nests[1].members.iter().map(|m| name(m.callsite)).collect();
        assert!(n0.contains(&"flux".to_string()));
        assert!(n0.contains(&"norm_acc".to_string()));
        assert!(n0.contains(&"norm_root".to_string()));
        assert_eq!(n1, vec!["normalize".to_string()]);
    }

    #[test]
    fn normalize_roles() {
        let (_, df, fd) = fused(testdecks::NORMALIZE);
        let nest = &fd.nests[0];
        assert_eq!(nest.dims, vec!["j".to_string(), "i".to_string()]);
        let by_name = |n: &str| {
            let id = df.callsites.iter().find(|c| c.name == n).unwrap().id;
            nest.member(id).unwrap().clone()
        };
        // i is dim index 1.
        assert_eq!(by_name("norm_init").roles[1], Role::Pre);
        assert_eq!(by_name("norm_acc").roles[1], Role::Loop);
        assert_eq!(by_name("norm_root").roles[1], Role::Post);
        assert_eq!(by_name("flux").roles[1], Role::Loop);
        // All iterate j.
        assert_eq!(by_name("norm_init").roles[0], Role::Loop);
    }

    #[test]
    fn fusion_disabled_gives_one_nest_per_callsite() {
        let deck = parse_deck(testdecks::NORMALIZE).unwrap();
        let df = crate::dataflow::build(&deck).unwrap();
        let fd = fuse(&df, &FusionOptions { enabled: false }).unwrap();
        assert_eq!(fd.nests.len(), df.callsites.len());
        // Nest order must respect dataflow: flux before norm_acc.
        let nest_of_name = |n: &str| {
            let id = df.callsites.iter().find(|c| c.name == n).unwrap().id;
            fd.nest_of(id)
        };
        assert!(nest_of_name("flux") < nest_of_name("norm_acc"));
        assert!(nest_of_name("norm_root") < nest_of_name("normalize"));
    }
}
