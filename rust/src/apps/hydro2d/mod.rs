//! Hydro2D (paper §5.4, Fig. 13): the CEA 2D shock-hydrodynamics
//! benchmark [5] — a dimensionally-split Godunov scheme with nine kernels.
//!
//! The deck below carries eight of them (`constoprim`,
//! `equation_of_state`, `slope`, `trace`, `qleftright`, `riemann`,
//! `cmpflx`, `update_cons_vars`); `make_boundary` only touches the four
//! ghost cells per row and is handled by the driver (see
//! [`solver`]) — fusing it is meaningless for footprint or bandwidth and
//! our engine's terms cannot express its reflective index arithmetic
//! (documented substitution, DESIGN.md §Substitutions).
//!
//! Each kernel depends only in the sweep dimension `i`; the `j` dimension
//! indexes independent rows. The y-pass reuses the same deck on transposed
//! data with the velocity components swapped, exactly like the original
//! CEA code. HFAV fuses all kernels into a single (j,i) nest and contracts
//! every intermediate to rolling scalar windows — the paper's
//! `O(31·Ni·Nj)` → `O(4·Ni·Nj + 112)` claim.

pub mod solver;

use crate::exec::registry::Registry;

/// Ratio of specific heats (ideal gas), as in CEA Hydro2D.
pub const GAMMA: f64 = 1.4;

/// The sweep deck. Interior cells are `i ∈ [2, Ni+2)` of arrays padded
/// with two ghost cells per side (the engine derives the `[0, Ni+4)`
/// terminal spans from the dependency chain).
pub const DECK: &str = r#"
name: hydro2d_sweep
iteration:
  order: [j, i]
  domains:
    j: [0, Nj]
    i: [2, Ni+2]
kernels:
  constoprim:
    declaration: constoprim(double rho, double rhou, double rhov, double E, double &r, double &u, double &v, double &eint);
    inputs: |
      rho  : grho[j?][i?]
      rhou : grhou[j?][i?]
      rhov : grhov[j?][i?]
      E    : gE[j?][i?]
    outputs: |
      r    : prim_r(grho[j?][i?])
      u    : prim_u(grho[j?][i?])
      v    : prim_v(grho[j?][i?])
      eint : prim_e(grho[j?][i?])
    body: |
      r = rho;
      u = rhou / rho;
      v = rhov / rho;
      eint = E / rho - 0.5 * (u*u + v*v);
  equation_of_state:
    declaration: equation_of_state(double r, double eint, double &p);
    inputs: |
      r    : prim_r(grho[j?][i?])
      eint : prim_e(grho[j?][i?])
    outputs: |
      p : prim_p(grho[j?][i?])
    body: "p = 0.4 * r * eint; if (p < 1e-10) { p = 1e-10; }"
  slope:
    declaration: slope(double rm, double rc, double rp, double um, double uc, double up, double vm, double vc, double vp, double pm, double pc, double pp, double &dr, double &du, double &dv, double &dp);
    inputs: |
      rm : prim_r(grho[j?][i?-1])
      rc : prim_r(grho[j?][i?])
      rp : prim_r(grho[j?][i?+1])
      um : prim_u(grho[j?][i?-1])
      uc : prim_u(grho[j?][i?])
      up : prim_u(grho[j?][i?+1])
      vm : prim_v(grho[j?][i?-1])
      vc : prim_v(grho[j?][i?])
      vp : prim_v(grho[j?][i?+1])
      pm : prim_p(grho[j?][i?-1])
      pc : prim_p(grho[j?][i?])
      pp : prim_p(grho[j?][i?+1])
    outputs: |
      dr : slope_r(grho[j?][i?])
      du : slope_u(grho[j?][i?])
      dv : slope_v(grho[j?][i?])
      dp : slope_p(grho[j?][i?])
    body: |
      { double dl = rc - rm, dg = rp - rc, dc = 0.5*(dl+dg), s = dc >= 0.0 ? 1.0 : -1.0;
        double lim = (dl*dg <= 0.0) ? 0.0 : 2.0*fmin(fabs(dl), fabs(dg));
        dr = s * fmin(lim, fabs(dc)); }
      { double dl = uc - um, dg = up - uc, dc = 0.5*(dl+dg), s = dc >= 0.0 ? 1.0 : -1.0;
        double lim = (dl*dg <= 0.0) ? 0.0 : 2.0*fmin(fabs(dl), fabs(dg));
        du = s * fmin(lim, fabs(dc)); }
      { double dl = vc - vm, dg = vp - vc, dc = 0.5*(dl+dg), s = dc >= 0.0 ? 1.0 : -1.0;
        double lim = (dl*dg <= 0.0) ? 0.0 : 2.0*fmin(fabs(dl), fabs(dg));
        dv = s * fmin(lim, fabs(dc)); }
      { double dl = pc - pm, dg = pp - pc, dc = 0.5*(dl+dg), s = dc >= 0.0 ? 1.0 : -1.0;
        double lim = (dl*dg <= 0.0) ? 0.0 : 2.0*fmin(fabs(dl), fabs(dg));
        dp = s * fmin(lim, fabs(dc)); }
    body_rs: |
      { let dl = rc - rm; let dg = rp - rc; let dc = 0.5*(dl+dg);
        let s = if dc >= 0.0 { 1.0 } else { -1.0 };
        let lim = if dl*dg <= 0.0 { 0.0 } else { 2.0*fmin(fabs(dl), fabs(dg)) };
        dr = s * fmin(lim, fabs(dc)); }
      { let dl = uc - um; let dg = up - uc; let dc = 0.5*(dl+dg);
        let s = if dc >= 0.0 { 1.0 } else { -1.0 };
        let lim = if dl*dg <= 0.0 { 0.0 } else { 2.0*fmin(fabs(dl), fabs(dg)) };
        du = s * fmin(lim, fabs(dc)); }
      { let dl = vc - vm; let dg = vp - vc; let dc = 0.5*(dl+dg);
        let s = if dc >= 0.0 { 1.0 } else { -1.0 };
        let lim = if dl*dg <= 0.0 { 0.0 } else { 2.0*fmin(fabs(dl), fabs(dg)) };
        dv = s * fmin(lim, fabs(dc)); }
      { let dl = pc - pm; let dg = pp - pc; let dc = 0.5*(dl+dg);
        let s = if dc >= 0.0 { 1.0 } else { -1.0 };
        let lim = if dl*dg <= 0.0 { 0.0 } else { 2.0*fmin(fabs(dl), fabs(dg)) };
        dp = s * fmin(lim, fabs(dc)); }
  trace:
    declaration: trace(double r, double u, double v, double p, double dr, double du, double dv, double dp, double dtdx, double &rm, double &um, double &vm, double &pm, double &rp, double &up, double &vp, double &pp);
    inputs: |
      r : prim_r(grho[j?][i?])
      u : prim_u(grho[j?][i?])
      v : prim_v(grho[j?][i?])
      p : prim_p(grho[j?][i?])
      dr : slope_r(grho[j?][i?])
      du : slope_u(grho[j?][i?])
      dv : slope_v(grho[j?][i?])
      dp : slope_p(grho[j?][i?])
      dtdx : dtdx
    outputs: |
      rm : trace_rm(grho[j?][i?])
      um : trace_um(grho[j?][i?])
      vm : trace_vm(grho[j?][i?])
      pm : trace_pm(grho[j?][i?])
      rp : trace_rp(grho[j?][i?])
      up : trace_up(grho[j?][i?])
      vp : trace_vp(grho[j?][i?])
      pp : trace_pp(grho[j?][i?])
    body: |
      { double h = 0.5 * dtdx;
        double r2 = r - h*(u*dr + r*du);
        double u2 = u - h*(u*du + dp/r);
        double v2 = v - h*(u*dv);
        double p2 = p - h*(1.4*p*du + u*dp);
        if (r2 < 1e-10) { r2 = 1e-10; }
        if (p2 < 1e-10) { p2 = 1e-10; }
        rm = r2 - 0.5*dr; um = u2 - 0.5*du; vm = v2 - 0.5*dv; pm = p2 - 0.5*dp;
        rp = r2 + 0.5*dr; up = u2 + 0.5*du; vp = v2 + 0.5*dv; pp = p2 + 0.5*dp;
        if (rm < 1e-10) { rm = 1e-10; }
        if (rp < 1e-10) { rp = 1e-10; }
        if (pm < 1e-10) { pm = 1e-10; }
        if (pp < 1e-10) { pp = 1e-10; } }
    body_rs: |
      { let h = 0.5 * dtdx;
        let mut r2 = r - h*(u*dr + r*du);
        let u2 = u - h*(u*du + dp/r);
        let v2 = v - h*(u*dv);
        let mut p2 = p - h*(1.4*p*du + u*dp);
        if r2 < 1e-10 { r2 = 1e-10; }
        if p2 < 1e-10 { p2 = 1e-10; }
        rm = r2 - 0.5*dr; um = u2 - 0.5*du; vm = v2 - 0.5*dv; pm = p2 - 0.5*dp;
        rp = r2 + 0.5*dr; up = u2 + 0.5*du; vp = v2 + 0.5*dv; pp = p2 + 0.5*dp;
        if rm < 1e-10 { rm = 1e-10; }
        if rp < 1e-10 { rp = 1e-10; }
        if pm < 1e-10 { pm = 1e-10; }
        if pp < 1e-10 { pp = 1e-10; } }
  qleftright:
    declaration: qleftright(double rl, double ul, double vl, double pl, double rr, double ur, double vr, double pr, double &orl, double &oul, double &ovl, double &opl, double &orr, double &our, double &ovr, double &opr);
    inputs: |
      rl : trace_rp(grho[j?][i?])
      ul : trace_up(grho[j?][i?])
      vl : trace_vp(grho[j?][i?])
      pl : trace_pp(grho[j?][i?])
      rr : trace_rm(grho[j?][i?+1])
      ur : trace_um(grho[j?][i?+1])
      vr : trace_vm(grho[j?][i?+1])
      pr : trace_pm(grho[j?][i?+1])
    outputs: |
      orl : qlr_rl(grho[j?][i?])
      oul : qlr_ul(grho[j?][i?])
      ovl : qlr_vl(grho[j?][i?])
      opl : qlr_pl(grho[j?][i?])
      orr : qlr_rr(grho[j?][i?])
      our : qlr_ur(grho[j?][i?])
      ovr : qlr_vr(grho[j?][i?])
      opr : qlr_pr(grho[j?][i?])
    body: |
      orl = rl; oul = ul; ovl = vl; opl = pl;
      orr = rr; our = ur; ovr = vr; opr = pr;
  riemann:
    declaration: riemann(double rl, double ul, double vl, double pl, double rr, double ur, double vr, double pr, double &gr, double &gu, double &gv, double &gp);
    inputs: |
      rl : qlr_rl(grho[j?][i?])
      ul : qlr_ul(grho[j?][i?])
      vl : qlr_vl(grho[j?][i?])
      pl : qlr_pl(grho[j?][i?])
      rr : qlr_rr(grho[j?][i?])
      ur : qlr_ur(grho[j?][i?])
      vr : qlr_vr(grho[j?][i?])
      pr : qlr_pr(grho[j?][i?])
    outputs: |
      gr : gdnv_r(grho[j?][i?])
      gu : gdnv_u(grho[j?][i?])
      gv : gdnv_v(grho[j?][i?])
      gp : gdnv_p(grho[j?][i?])
    body: |
      { double cl = sqrt(1.4*pl/rl), cr = sqrt(1.4*pr/rr);
        double pst = 0.5*(pl+pr) - 0.125*(ur-ul)*(rl+rr)*(cl+cr);
        if (pst < 1e-10) { pst = 1e-10; }
        for (int it = 0; it < 8; ++it) {
          double al = 0.8333333333333333/rl, bl = 0.16666666666666666*pl;
          double ar = 0.8333333333333333/rr, br = 0.16666666666666666*pr;
          double sl = sqrt(al/(pst+bl)), sr = sqrt(ar/(pst+br));
          double fl = (pst-pl)*sl, fr = (pst-pr)*sr;
          double dl = sl*(1.0 - (pst-pl)/(2.0*(pst+bl)));
          double dr_ = sr*(1.0 - (pst-pr)/(2.0*(pst+br)));
          double f = fl + fr + (ur - ul);
          pst = pst - f/(dl + dr_);
          if (pst < 1e-10) { pst = 1e-10; }
        }
        double sl0 = sqrt((0.8333333333333333/rl)/(pst+0.16666666666666666*pl));
        double sr0 = sqrt((0.8333333333333333/rr)/(pst+0.16666666666666666*pr));
        double ustar = 0.5*(ul+ur) + 0.5*((pst-pr)*sr0 - (pst-pl)*sl0);
        double sgn, r0, u0, p0, v0;
        if (ustar >= 0.0) { sgn = 1.0; r0 = rl; u0 = ul; p0 = pl; v0 = vl; }
        else { sgn = -1.0; r0 = rr; u0 = ur; p0 = pr; v0 = vr; }
        double c0 = sqrt(1.4*p0/r0);
        double ro, uo, po;
        if (pst > p0) {
          double S = u0 - sgn*c0*sqrt(0.8571428571428571*(pst/p0) + 0.14285714285714285);
          if (sgn*S >= 0.0) { ro = r0; uo = u0; po = p0; }
          else { double q = pst/p0; ro = r0*((q + 0.16666666666666666)/(0.16666666666666666*q + 1.0)); uo = ustar; po = pst; }
        } else {
          double cst = c0*pow(pst/p0, 0.14285714285714285);
          double SH = u0 - sgn*c0;
          double ST = ustar - sgn*cst;
          if (sgn*SH >= 0.0) { ro = r0; uo = u0; po = p0; }
          else if (sgn*ST <= 0.0) { ro = r0*pow(pst/p0, 0.7142857142857143); uo = ustar; po = pst; }
          else {
            uo = 0.8333333333333333*(sgn*c0 + 0.2*u0);
            double cf = sgn*uo; if (cf < 1e-12) { cf = 1e-12; }
            ro = r0*pow(cf/c0, 5.0); po = p0*pow(cf/c0, 7.0);
          }
        }
        gr = ro; gu = uo; gv = v0; gp = po; }
    body_rs: |
      { let cl = sqrt(1.4*pl/rl); let cr = sqrt(1.4*pr/rr);
        let mut pst = 0.5*(pl+pr) - 0.125*(ur-ul)*(rl+rr)*(cl+cr);
        if pst < 1e-10 { pst = 1e-10; }
        let mut it = 0;
        while it < 8 {
          let al = 0.8333333333333333/rl; let bl = 0.16666666666666666*pl;
          let ar = 0.8333333333333333/rr; let br = 0.16666666666666666*pr;
          let sl = sqrt(al/(pst+bl)); let sr = sqrt(ar/(pst+br));
          let fl = (pst-pl)*sl; let fr = (pst-pr)*sr;
          let dl = sl*(1.0 - (pst-pl)/(2.0*(pst+bl)));
          let dr_ = sr*(1.0 - (pst-pr)/(2.0*(pst+br)));
          let f = fl + fr + (ur - ul);
          pst = pst - f/(dl + dr_);
          if pst < 1e-10 { pst = 1e-10; }
          it += 1;
        }
        let sl0 = sqrt((0.8333333333333333/rl)/(pst+0.16666666666666666*pl));
        let sr0 = sqrt((0.8333333333333333/rr)/(pst+0.16666666666666666*pr));
        let ustar = 0.5*(ul+ur) + 0.5*((pst-pr)*sr0 - (pst-pl)*sl0);
        let (sgn, r0, u0, p0, v0) = if ustar >= 0.0 { (1.0, rl, ul, pl, vl) }
          else { (-1.0, rr, ur, pr, vr) };
        let c0 = sqrt(1.4*p0/r0);
        let ro; let uo; let po;
        if pst > p0 {
          let s = u0 - sgn*c0*sqrt(0.8571428571428571*(pst/p0) + 0.14285714285714285);
          if sgn*s >= 0.0 { ro = r0; uo = u0; po = p0; }
          else { let q = pst/p0; ro = r0*((q + 0.16666666666666666)/(0.16666666666666666*q + 1.0)); uo = ustar; po = pst; }
        } else {
          let cst = c0*pow(pst/p0, 0.14285714285714285);
          let sh = u0 - sgn*c0;
          let st = ustar - sgn*cst;
          if sgn*sh >= 0.0 { ro = r0; uo = u0; po = p0; }
          else if sgn*st <= 0.0 { ro = r0*pow(pst/p0, 0.7142857142857143); uo = ustar; po = pst; }
          else {
            uo = 0.8333333333333333*(sgn*c0 + 0.2*u0);
            let mut cf = sgn*uo; if cf < 1e-12 { cf = 1e-12; }
            ro = r0*pow(cf/c0, 5.0); po = p0*pow(cf/c0, 7.0);
          }
        }
        gr = ro; gu = uo; gv = v0; gp = po; }
  cmpflx:
    declaration: cmpflx(double gr, double gu, double gv, double gp, double &frho, double &frhou, double &frhov, double &fE);
    inputs: |
      gr : gdnv_r(grho[j?][i?])
      gu : gdnv_u(grho[j?][i?])
      gv : gdnv_v(grho[j?][i?])
      gp : gdnv_p(grho[j?][i?])
    outputs: |
      frho  : flux_rho(grho[j?][i?])
      frhou : flux_rhou(grho[j?][i?])
      frhov : flux_rhov(grho[j?][i?])
      fE    : flux_E(grho[j?][i?])
    body: |
      { double e = gp/0.4 + 0.5*gr*(gu*gu + gv*gv);
        frho = gr*gu;
        frhou = gr*gu*gu + gp;
        frhov = gr*gu*gv;
        fE = gu*(e + gp); }
    body_rs: |
      { let e = gp/0.4 + 0.5*gr*(gu*gu + gv*gv);
        frho = gr*gu;
        frhou = gr*gu*gu + gp;
        frhov = gr*gu*gv;
        fE = gu*(e + gp); }
  update_cons_vars:
    declaration: update_cons_vars(double rho, double rhou, double rhov, double E, double fm_rho, double fm_rhou, double fm_rhov, double fm_E, double fc_rho, double fc_rhou, double fc_rhov, double fc_E, double dtdx, double &nrho, double &nrhou, double &nrhov, double &nE);
    inputs: |
      rho  : grho[j?][i?]
      rhou : grhou[j?][i?]
      rhov : grhov[j?][i?]
      E    : gE[j?][i?]
      fm_rho  : flux_rho(grho[j?][i?-1])
      fm_rhou : flux_rhou(grho[j?][i?-1])
      fm_rhov : flux_rhov(grho[j?][i?-1])
      fm_E    : flux_E(grho[j?][i?-1])
      fc_rho  : flux_rho(grho[j?][i?])
      fc_rhou : flux_rhou(grho[j?][i?])
      fc_rhov : flux_rhov(grho[j?][i?])
      fc_E    : flux_E(grho[j?][i?])
      dtdx : dtdx
    outputs: |
      nrho  : new_rho(grho[j?][i?])
      nrhou : new_rhou(grho[j?][i?])
      nrhov : new_rhov(grho[j?][i?])
      nE    : new_E(grho[j?][i?])
    body: |
      nrho  = rho  + dtdx*(fm_rho  - fc_rho);
      nrhou = rhou + dtdx*(fm_rhou - fc_rhou);
      nrhov = rhov + dtdx*(fm_rhov - fc_rhov);
      nE    = E    + dtdx*(fm_E    - fc_E);
globals:
  inputs: |
    double g_rho[j?][i?] => grho[j?][i?]
    double g_rhou[j?][i?] => grhou[j?][i?]
    double g_rhov[j?][i?] => grhov[j?][i?]
    double g_E[j?][i?] => gE[j?][i?]
    double g_dtdx => dtdx
  outputs: |
    new_rho(grho[j][i]) => double g_nrho[j][i]
    new_rhou(grho[j][i]) => double g_nrhou[j][i]
    new_rhov(grho[j][i]) => double g_nrhov[j][i]
    new_E(grho[j][i]) => double g_nE[j][i]
"#;

/// Slope limiter (van-Leer-style, slope_type 2 as in CEA Hydro2D).
#[inline]
pub fn limited_slope(qm: f64, qc: f64, qp: f64) -> f64 {
    let dl = qc - qm;
    let dg = qp - qc;
    let dc = 0.5 * (dl + dg);
    let s = if dc >= 0.0 { 1.0 } else { -1.0 };
    let lim = if dl * dg <= 0.0 { 0.0 } else { 2.0 * dl.abs().min(dg.abs()) };
    s * lim.min(dc.abs())
}

/// Two-shock approximate Riemann solver with Toro-style sampling at
/// x/t = 0. Returns the Godunov state (r, u, v, p).
#[inline]
pub fn riemann_solve(
    rl: f64,
    ul: f64,
    vl: f64,
    pl: f64,
    rr: f64,
    ur: f64,
    vr: f64,
    pr: f64,
) -> (f64, f64, f64, f64) {
    let cl = (GAMMA * pl / rl).sqrt();
    let cr = (GAMMA * pr / rr).sqrt();
    let mut pst = 0.5 * (pl + pr) - 0.125 * (ur - ul) * (rl + rr) * (cl + cr);
    if pst < 1e-10 {
        pst = 1e-10;
    }
    for _ in 0..8 {
        let al = 0.8333333333333333 / rl;
        let bl = 0.16666666666666666 * pl;
        let ar = 0.8333333333333333 / rr;
        let br = 0.16666666666666666 * pr;
        let sl = (al / (pst + bl)).sqrt();
        let sr = (ar / (pst + br)).sqrt();
        let fl = (pst - pl) * sl;
        let fr = (pst - pr) * sr;
        let dl = sl * (1.0 - (pst - pl) / (2.0 * (pst + bl)));
        let dr = sr * (1.0 - (pst - pr) / (2.0 * (pst + br)));
        let f = fl + fr + (ur - ul);
        pst -= f / (dl + dr);
        if pst < 1e-10 {
            pst = 1e-10;
        }
    }
    let sl0 = ((0.8333333333333333 / rl) / (pst + 0.16666666666666666 * pl)).sqrt();
    let sr0 = ((0.8333333333333333 / rr) / (pst + 0.16666666666666666 * pr)).sqrt();
    let ustar = 0.5 * (ul + ur) + 0.5 * ((pst - pr) * sr0 - (pst - pl) * sl0);
    let (sgn, r0, u0, p0, v0) = if ustar >= 0.0 {
        (1.0, rl, ul, pl, vl)
    } else {
        (-1.0, rr, ur, pr, vr)
    };
    let c0 = (GAMMA * p0 / r0).sqrt();
    let (ro, uo, po);
    if pst > p0 {
        let s = u0 - sgn * c0 * (0.8571428571428571 * (pst / p0) + 0.14285714285714285).sqrt();
        if sgn * s >= 0.0 {
            ro = r0;
            uo = u0;
            po = p0;
        } else {
            let q = pst / p0;
            ro = r0 * ((q + 0.16666666666666666) / (0.16666666666666666 * q + 1.0));
            uo = ustar;
            po = pst;
        }
    } else {
        let cst = c0 * (pst / p0).powf(0.14285714285714285);
        let sh = u0 - sgn * c0;
        let st = ustar - sgn * cst;
        if sgn * sh >= 0.0 {
            ro = r0;
            uo = u0;
            po = p0;
        } else if sgn * st <= 0.0 {
            ro = r0 * (pst / p0).powf(0.7142857142857143);
            uo = ustar;
            po = pst;
        } else {
            uo = 0.8333333333333333 * (sgn * c0 + 0.2 * u0);
            let mut cf = sgn * uo;
            if cf < 1e-12 {
                cf = 1e-12;
            }
            ro = r0 * (cf / c0).powf(5.0);
            po = p0 * (cf / c0).powf(7.0);
        }
    }
    (ro, uo, v0, po)
}

/// MUSCL-Hancock predictor half step + edge extrapolation.
/// Returns (rm, um, vm, pm, rp, up, vp, pp).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn trace_cell(
    r: f64,
    u: f64,
    v: f64,
    p: f64,
    dr: f64,
    du: f64,
    dv: f64,
    dp: f64,
    dtdx: f64,
) -> (f64, f64, f64, f64, f64, f64, f64, f64) {
    let h = 0.5 * dtdx;
    let mut r2 = r - h * (u * dr + r * du);
    let u2 = u - h * (u * du + dp / r);
    let v2 = v - h * (u * dv);
    let mut p2 = p - h * (GAMMA * p * du + u * dp);
    if r2 < 1e-10 {
        r2 = 1e-10;
    }
    if p2 < 1e-10 {
        p2 = 1e-10;
    }
    let clamp = |x: f64| if x < 1e-10 { 1e-10 } else { x };
    (
        clamp(r2 - 0.5 * dr),
        u2 - 0.5 * du,
        v2 - 0.5 * dv,
        clamp(p2 - 0.5 * dp),
        clamp(r2 + 0.5 * dr),
        u2 + 0.5 * du,
        v2 + 0.5 * dv,
        clamp(p2 + 0.5 * dp),
    )
}

/// Interface flux from a Godunov state.
#[inline]
pub fn flux_from_gdnv(gr: f64, gu: f64, gv: f64, gp: f64) -> (f64, f64, f64, f64) {
    let e = gp / (GAMMA - 1.0) + 0.5 * gr * (gu * gu + gv * gv);
    (gr * gu, gr * gu * gu + gp, gr * gu * gv, gu * (e + gp))
}

/// Kernel registry (must match the C bodies in [`DECK`] exactly).
pub fn registry() -> Registry {
    let mut r = Registry::new();
    r.register("constoprim", |i, o| {
        let (rho, rhou, rhov, e) = (i[0], i[1], i[2], i[3]);
        o[0] = rho;
        o[1] = rhou / rho;
        o[2] = rhov / rho;
        o[3] = e / rho - 0.5 * (o[1] * o[1] + o[2] * o[2]);
    });
    r.register("equation_of_state", |i, o| {
        let p = 0.4 * i[0] * i[1];
        o[0] = if p < 1e-10 { 1e-10 } else { p };
    });
    r.register("slope", |i, o| {
        o[0] = limited_slope(i[0], i[1], i[2]);
        o[1] = limited_slope(i[3], i[4], i[5]);
        o[2] = limited_slope(i[6], i[7], i[8]);
        o[3] = limited_slope(i[9], i[10], i[11]);
    });
    r.register("trace", |i, o| {
        let t = trace_cell(i[0], i[1], i[2], i[3], i[4], i[5], i[6], i[7], i[8]);
        o[0] = t.0;
        o[1] = t.1;
        o[2] = t.2;
        o[3] = t.3;
        o[4] = t.4;
        o[5] = t.5;
        o[6] = t.6;
        o[7] = t.7;
    });
    r.register("qleftright", |i, o| o.copy_from_slice(&i[..8]));
    r.register("riemann", |i, o| {
        let g = riemann_solve(i[0], i[1], i[2], i[3], i[4], i[5], i[6], i[7]);
        o[0] = g.0;
        o[1] = g.1;
        o[2] = g.2;
        o[3] = g.3;
    });
    r.register("cmpflx", |i, o| {
        let f = flux_from_gdnv(i[0], i[1], i[2], i[3]);
        o[0] = f.0;
        o[1] = f.1;
        o[2] = f.2;
        o[3] = f.3;
    });
    r.register("update_cons_vars", |i, o| {
        let dtdx = i[12];
        o[0] = i[0] + dtdx * (i[4] - i[8]);
        o[1] = i[1] + dtdx * (i[5] - i[9]);
        o[2] = i[2] + dtdx * (i[6] - i[10]);
        o[3] = i[3] + dtdx * (i[7] - i[11]);
    });
    r
}
