//! Hydro2D solver driver: Sod shock-tube setup, CFL control, reflective
//! boundaries, dimensional splitting (x-pass, then y-pass on transposed
//! data) — plus the paper's comparison sweep implementations:
//!
//! * [`RefSweeper`] — the original unfused code: one full-grid pass
//!   per kernel, every intermediate materialized (`autovec`);
//! * [`HandvecSweeper`] — the hand-fused expert version (row-buffered
//!   single pass, the role of the paper's intrinsics `handvec`);
//! * [`ExecSweeper`] / [`NativeSweeper`] — the HFAV-generated schedule run
//!   by the interpreter executor or as compiled C via dlopen.

use super::{flux_from_gdnv, limited_slope, riemann_solve, trace_cell, GAMMA};
use crate::exec::{self, registry::Registry, ExecOptions};
use crate::plan::Program;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Number of ghost cells per side in the sweep dimension.
pub const NG: usize = 2;

/// Interior state, row-major `ny × nx`.
#[derive(Debug, Clone)]
pub struct State {
    pub nx: usize,
    pub ny: usize,
    pub rho: Vec<f64>,
    pub rhou: Vec<f64>,
    pub rhov: Vec<f64>,
    pub e: Vec<f64>,
    pub t: f64,
}

/// Sod shock tube: left state (ρ=1, p=1), right state (ρ=0.125, p=0.1),
/// discontinuity at x = 0.5 (per-column in x).
pub fn sod(nx: usize, ny: usize) -> State {
    let mut s = State {
        nx,
        ny,
        rho: vec![0.0; nx * ny],
        rhou: vec![0.0; nx * ny],
        rhov: vec![0.0; nx * ny],
        e: vec![0.0; nx * ny],
        t: 0.0,
    };
    for j in 0..ny {
        for i in 0..nx {
            let x = (i as f64 + 0.5) / nx as f64;
            let (r, p) = if x < 0.5 { (1.0, 1.0) } else { (0.125, 0.1) };
            s.rho[j * nx + i] = r;
            s.e[j * nx + i] = p / (GAMMA - 1.0);
        }
    }
    s
}

/// CFL-limited timestep.
pub fn cfl_dt(s: &State, dx: f64, cfl: f64) -> f64 {
    let mut wmax = 1e-10f64;
    for k in 0..s.rho.len() {
        let r = s.rho[k].max(1e-10);
        let u = s.rhou[k] / r;
        let v = s.rhov[k] / r;
        let eint = (s.e[k] / r - 0.5 * (u * u + v * v)).max(1e-10);
        let p = (GAMMA - 1.0) * r * eint;
        let c = (GAMMA * p / r).sqrt();
        wmax = wmax.max(u.abs() + c).max(v.abs() + c);
    }
    cfl * dx / wmax
}

/// Pad one field with reflective ghosts in the sweep dim: row-major
/// `rows × (n + 4)`; `flip` negates the ghost values (normal momentum).
pub fn pad(field: &[f64], rows: usize, n: usize, flip: bool) -> Vec<f64> {
    let w = n + 2 * NG;
    let mut out = vec![0.0; rows * w];
    let s = if flip { -1.0 } else { 1.0 };
    for j in 0..rows {
        let src = &field[j * n..(j + 1) * n];
        let dst = &mut out[j * w..(j + 1) * w];
        dst[NG..NG + n].copy_from_slice(src);
        dst[1] = s * src[0];
        dst[0] = s * src[1];
        dst[NG + n] = s * src[n - 1];
        dst[NG + n + 1] = s * src[n - 2];
    }
    out
}

/// Transpose a row-major `rows × cols` array.
pub fn transpose(a: &[f64], rows: usize, cols: usize) -> Vec<f64> {
    let mut out = vec![0.0; a.len()];
    for j in 0..rows {
        for i in 0..cols {
            out[i * rows + j] = a[j * cols + i];
        }
    }
    out
}

/// One directional sweep: padded conservative inputs (`rows × (n+4)`) →
/// updated interior (`rows × n`). The "normal" velocity component is
/// `rhou`; callers swap components for the y-pass.
pub trait Sweeper {
    #[allow(clippy::too_many_arguments)]
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String>;

    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// autovec reference: one pass per kernel, everything materialized.
// ---------------------------------------------------------------------------

/// The original unfused Hydro2D sweep (paper `autovec`): eight full-grid
/// passes with ~33 materialized intermediate arrays.
pub struct RefSweeper;

impl Sweeper for RefSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let w = n + 2 * NG;
        let sz = rows * w;
        // constoprim
        let mut pr = vec![0.0; sz];
        let mut pu = vec![0.0; sz];
        let mut pv = vec![0.0; sz];
        let mut pe = vec![0.0; sz];
        for k in 0..sz {
            pr[k] = rho[k];
            pu[k] = rhou[k] / rho[k];
            pv[k] = rhov[k] / rho[k];
            pe[k] = e[k] / rho[k] - 0.5 * (pu[k] * pu[k] + pv[k] * pv[k]);
        }
        // equation_of_state
        let mut pp = vec![0.0; sz];
        for k in 0..sz {
            pp[k] = (0.4 * pr[k] * pe[k]).max(1e-10);
        }
        // slope
        let mut dr = vec![0.0; sz];
        let mut du = vec![0.0; sz];
        let mut dv = vec![0.0; sz];
        let mut dp = vec![0.0; sz];
        for j in 0..rows {
            for i in 1..w - 1 {
                let k = j * w + i;
                dr[k] = limited_slope(pr[k - 1], pr[k], pr[k + 1]);
                du[k] = limited_slope(pu[k - 1], pu[k], pu[k + 1]);
                dv[k] = limited_slope(pv[k - 1], pv[k], pv[k + 1]);
                dp[k] = limited_slope(pp[k - 1], pp[k], pp[k + 1]);
            }
        }
        // trace
        let mut trm = vec![0.0; sz];
        let mut tum = vec![0.0; sz];
        let mut tvm = vec![0.0; sz];
        let mut tpm = vec![0.0; sz];
        let mut trp = vec![0.0; sz];
        let mut tup = vec![0.0; sz];
        let mut tvp = vec![0.0; sz];
        let mut tpp = vec![0.0; sz];
        for j in 0..rows {
            for i in 1..w - 1 {
                let k = j * w + i;
                let t =
                    trace_cell(pr[k], pu[k], pv[k], pp[k], dr[k], du[k], dv[k], dp[k], dtdx);
                trm[k] = t.0;
                tum[k] = t.1;
                tvm[k] = t.2;
                tpm[k] = t.3;
                trp[k] = t.4;
                tup[k] = t.5;
                tvp[k] = t.6;
                tpp[k] = t.7;
            }
        }
        // qleftright + riemann + cmpflx (interfaces 1..n+2)
        let mut frho = vec![0.0; sz];
        let mut frhou = vec![0.0; sz];
        let mut frhov = vec![0.0; sz];
        let mut fe = vec![0.0; sz];
        // qleftright (materialized, as in the original code)
        let mut qrl = vec![0.0; sz];
        let mut qul = vec![0.0; sz];
        let mut qvl = vec![0.0; sz];
        let mut qpl = vec![0.0; sz];
        let mut qrr = vec![0.0; sz];
        let mut qur = vec![0.0; sz];
        let mut qvr = vec![0.0; sz];
        let mut qpr = vec![0.0; sz];
        for j in 0..rows {
            for i in 1..w - 2 {
                let k = j * w + i;
                qrl[k] = trp[k];
                qul[k] = tup[k];
                qvl[k] = tvp[k];
                qpl[k] = tpp[k];
                qrr[k] = trm[k + 1];
                qur[k] = tum[k + 1];
                qvr[k] = tvm[k + 1];
                qpr[k] = tpm[k + 1];
            }
        }
        let mut grs = vec![0.0; sz];
        let mut gus = vec![0.0; sz];
        let mut gvs = vec![0.0; sz];
        let mut gps = vec![0.0; sz];
        for j in 0..rows {
            for i in 1..w - 2 {
                let k = j * w + i;
                let g = riemann_solve(
                    qrl[k], qul[k], qvl[k], qpl[k], qrr[k], qur[k], qvr[k], qpr[k],
                );
                grs[k] = g.0;
                gus[k] = g.1;
                gvs[k] = g.2;
                gps[k] = g.3;
            }
        }
        for j in 0..rows {
            for i in 1..w - 2 {
                let k = j * w + i;
                let f = flux_from_gdnv(grs[k], gus[k], gvs[k], gps[k]);
                frho[k] = f.0;
                frhou[k] = f.1;
                frhov[k] = f.2;
                fe[k] = f.3;
            }
        }
        // update
        let mut nrho = vec![0.0; rows * n];
        let mut nrhou = vec![0.0; rows * n];
        let mut nrhov = vec![0.0; rows * n];
        let mut ne = vec![0.0; rows * n];
        for j in 0..rows {
            for i in NG..n + NG {
                let k = j * w + i;
                let o = j * n + (i - NG);
                nrho[o] = rho[k] + dtdx * (frho[k - 1] - frho[k]);
                nrhou[o] = rhou[k] + dtdx * (frhou[k - 1] - frhou[k]);
                nrhov[o] = rhov[k] + dtdx * (frhov[k - 1] - frhov[k]);
                ne[o] = e[k] + dtdx * (fe[k - 1] - fe[k]);
            }
        }
        Ok([nrho, nrhou, nrhov, ne])
    }

    fn name(&self) -> &'static str {
        "autovec"
    }
}

// ---------------------------------------------------------------------------
// handvec: hand-fused single pass with row-local buffers.
// ---------------------------------------------------------------------------

/// Expert hand-fused sweep: one pass over the grid per step, all
/// intermediates in row-length scratch (the role the paper's `handvec`
/// intrinsics code plays in Fig. 13).
pub struct HandvecSweeper {
    scratch: Vec<f64>,
}

impl HandvecSweeper {
    pub fn new() -> Self {
        HandvecSweeper { scratch: Vec::new() }
    }
}

impl Default for HandvecSweeper {
    fn default() -> Self {
        Self::new()
    }
}

impl Sweeper for HandvecSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let w = n + 2 * NG;
        // Row scratch: prims (5), slopes (4), traces (8), flux (4) = 21 rows.
        let nbuf = 21;
        self.scratch.resize(nbuf * w, 0.0);
        let mut nrho = vec![0.0; rows * n];
        let mut nrhou = vec![0.0; rows * n];
        let mut nrhov = vec![0.0; rows * n];
        let mut ne = vec![0.0; rows * n];
        for j in 0..rows {
            let b = j * w;
            let (pr, rest) = self.scratch.split_at_mut(w);
            let (pu, rest) = rest.split_at_mut(w);
            let (pv, rest) = rest.split_at_mut(w);
            let (pe, rest) = rest.split_at_mut(w);
            let (pp, rest) = rest.split_at_mut(w);
            let (dr, rest) = rest.split_at_mut(w);
            let (du, rest) = rest.split_at_mut(w);
            let (dv, rest) = rest.split_at_mut(w);
            let (dp, rest) = rest.split_at_mut(w);
            let (trm, rest) = rest.split_at_mut(w);
            let (tum, rest) = rest.split_at_mut(w);
            let (tvm, rest) = rest.split_at_mut(w);
            let (tpm, rest) = rest.split_at_mut(w);
            let (trp, rest) = rest.split_at_mut(w);
            let (tup, rest) = rest.split_at_mut(w);
            let (tvp, rest) = rest.split_at_mut(w);
            let (tpp, rest) = rest.split_at_mut(w);
            let (frho, rest) = rest.split_at_mut(w);
            let (frhou, rest) = rest.split_at_mut(w);
            let (frhov, rest) = rest.split_at_mut(w);
            let (fe, _) = rest.split_at_mut(w);
            for i in 0..w {
                let k = b + i;
                pr[i] = rho[k];
                pu[i] = rhou[k] / rho[k];
                pv[i] = rhov[k] / rho[k];
                pe[i] = e[k] / rho[k] - 0.5 * (pu[i] * pu[i] + pv[i] * pv[i]);
                pp[i] = (0.4 * pr[i] * pe[i]).max(1e-10);
            }
            for i in 1..w - 1 {
                dr[i] = limited_slope(pr[i - 1], pr[i], pr[i + 1]);
                du[i] = limited_slope(pu[i - 1], pu[i], pu[i + 1]);
                dv[i] = limited_slope(pv[i - 1], pv[i], pv[i + 1]);
                dp[i] = limited_slope(pp[i - 1], pp[i], pp[i + 1]);
                let t = trace_cell(pr[i], pu[i], pv[i], pp[i], dr[i], du[i], dv[i], dp[i], dtdx);
                trm[i] = t.0;
                tum[i] = t.1;
                tvm[i] = t.2;
                tpm[i] = t.3;
                trp[i] = t.4;
                tup[i] = t.5;
                tvp[i] = t.6;
                tpp[i] = t.7;
            }
            for i in 1..w - 2 {
                let g = riemann_solve(
                    trp[i], tup[i], tvp[i], tpp[i], trm[i + 1], tum[i + 1], tvm[i + 1],
                    tpm[i + 1],
                );
                let f = flux_from_gdnv(g.0, g.1, g.2, g.3);
                frho[i] = f.0;
                frhou[i] = f.1;
                frhov[i] = f.2;
                fe[i] = f.3;
            }
            for i in NG..n + NG {
                let k = b + i;
                let o = j * n + (i - NG);
                nrho[o] = rho[k] + dtdx * (frho[i - 1] - frho[i]);
                nrhou[o] = rhou[k] + dtdx * (frhou[i - 1] - frhou[i]);
                nrhov[o] = rhov[k] + dtdx * (frhov[i - 1] - frhov[i]);
                ne[o] = e[k] + dtdx * (fe[i - 1] - fe[i]);
            }
        }
        Ok([nrho, nrhou, nrhov, ne])
    }

    fn name(&self) -> &'static str {
        "handvec"
    }
}

// ---------------------------------------------------------------------------
// HFAV sweepers: interpreter executor and compiled-C module.
// ---------------------------------------------------------------------------

/// HFAV schedule run by the interpreter executor. Holds the plan behind
/// an `Arc` so cached plans (coordinator plan cache) are shared, not
/// cloned; a reusable [`exec::Workspace`] recycles buffers across sweeps.
pub struct ExecSweeper {
    pub prog: Arc<Program>,
    pub reg: Registry,
    pub opts: ExecOptions,
    pub ws: exec::Workspace,
}

impl ExecSweeper {
    pub fn new(prog: impl Into<Arc<Program>>) -> Self {
        ExecSweeper {
            prog: prog.into(),
            reg: super::registry(),
            opts: ExecOptions::default(),
            ws: exec::Workspace::new(),
        }
    }
}

fn sweep_inputs(
    rho: &[f64],
    rhou: &[f64],
    rhov: &[f64],
    e: &[f64],
    dtdx: f64,
) -> BTreeMap<String, Vec<f64>> {
    let mut m = BTreeMap::new();
    m.insert("g_rho".to_string(), rho.to_vec());
    m.insert("g_rhou".to_string(), rhou.to_vec());
    m.insert("g_rhov".to_string(), rhov.to_vec());
    m.insert("g_E".to_string(), e.to_vec());
    m.insert("g_dtdx".to_string(), vec![dtdx]);
    m
}

fn sweep_extents(rows: usize, n: usize) -> BTreeMap<String, i64> {
    let mut m = BTreeMap::new();
    m.insert("Nj".to_string(), rows as i64);
    m.insert("Ni".to_string(), n as i64);
    m
}

impl Sweeper for ExecSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let inputs = sweep_inputs(rho, rhou, rhov, e, dtdx);
        let ext = sweep_extents(rows, n);
        let mut out =
            exec::run_with(&self.prog, &self.reg, &ext, &inputs, self.opts, &mut self.ws)?;
        Ok([
            out.remove("g_nrho").ok_or("missing g_nrho")?,
            out.remove("g_nrhou").ok_or("missing g_nrhou")?,
            out.remove("g_nrhov").ok_or("missing g_nrhov")?,
            out.remove("g_nE").ok_or("missing g_nE")?,
        ])
    }

    fn name(&self) -> &'static str {
        "hfav-exec"
    }
}

/// HFAV schedule compiled to C (`cc -O3 -march=native`) and dlopen'd.
pub struct NativeSweeper {
    pub module: crate::codegen::native::NativeModule,
}

impl NativeSweeper {
    pub fn new(prog: &Program) -> Result<Self, String> {
        let module = crate::codegen::native::build(prog, &Default::default())?;
        Ok(NativeSweeper { module })
    }
}

impl Sweeper for NativeSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let ext = sweep_extents(rows, n);
        let mut arrays = sweep_inputs(rho, rhou, rhov, e, dtdx);
        for name in ["g_nrho", "g_nrhou", "g_nrhov", "g_nE"] {
            arrays.insert(name.to_string(), vec![0.0; rows * n]);
        }
        self.module.run(&ext, &mut arrays)?;
        Ok([
            arrays.remove("g_nrho").unwrap(),
            arrays.remove("g_nrhou").unwrap(),
            arrays.remove("g_nrhov").unwrap(),
            arrays.remove("g_nE").unwrap(),
        ])
    }

    fn name(&self) -> &'static str {
        "hfav-native"
    }
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

/// Advance one dimensionally-split step (x-pass then y-pass), returning dt.
pub fn step(s: &mut State, dx: f64, cfl: f64, sweeper: &mut dyn Sweeper) -> Result<f64, String> {
    let dt = cfl_dt(s, dx, cfl);
    let dtdx = dt / dx;
    let (nx, ny) = (s.nx, s.ny);

    // x-pass: rows are y, sweep dim is x; rhou is normal.
    {
        let rho = pad(&s.rho, ny, nx, false);
        let rhou = pad(&s.rhou, ny, nx, true);
        let rhov = pad(&s.rhov, ny, nx, false);
        let e = pad(&s.e, ny, nx, false);
        let [a, b, c, d] = sweeper.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx)?;
        s.rho = a;
        s.rhou = b;
        s.rhov = c;
        s.e = d;
    }

    // y-pass: transpose; rhov becomes the normal component.
    {
        let rho_t = transpose(&s.rho, ny, nx);
        let rhou_t = transpose(&s.rhou, ny, nx);
        let rhov_t = transpose(&s.rhov, ny, nx);
        let e_t = transpose(&s.e, ny, nx);
        let rho = pad(&rho_t, nx, ny, false);
        let rhov = pad(&rhov_t, nx, ny, true); // normal: flip in ghosts
        let rhou = pad(&rhou_t, nx, ny, false);
        let e = pad(&e_t, nx, ny, false);
        // swap: sweeper's "rhou" slot carries the normal component (rhov).
        let [a, b, c, d] = sweeper.sweep(&rho, &rhov, &rhou, &e, dtdx, nx, ny)?;
        s.rho = transpose(&a, nx, ny);
        s.rhov = transpose(&b, nx, ny);
        s.rhou = transpose(&c, nx, ny);
        s.e = transpose(&d, nx, ny);
    }
    s.t += dt;
    Ok(dt)
}

/// Total mass and energy (conservation diagnostics).
pub fn totals(s: &State) -> (f64, f64) {
    (s.rho.iter().sum(), s.e.iter().sum())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{max_err, Variant};
    use crate::plan::PlanSpec;

    fn compile_variant(deck: &str, v: Variant) -> Result<Program, String> {
        PlanSpec::deck_src(deck).variant(v).compile()
    }

    #[test]
    fn sweepers_agree_one_pass() {
        let (nx, ny) = (40usize, 6usize);
        let s = sod(nx, ny);
        let rho = pad(&s.rho, ny, nx, false);
        let rhou = pad(&s.rhou, ny, nx, true);
        let rhov = pad(&s.rhov, ny, nx, false);
        let e = pad(&s.e, ny, nx, false);
        let dtdx = 0.1;

        let mut rs = RefSweeper;
        let want = rs.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx).unwrap();

        let mut hv = HandvecSweeper::new();
        let got = hv.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx).unwrap();
        for k in 0..4 {
            assert!(max_err(&want[k], &got[k]) < 1e-13, "handvec field {k}");
        }

        for variant in [Variant::Hfav, Variant::Autovec] {
            let prog = compile_variant(super::super::DECK, variant).unwrap();
            let mut ex = ExecSweeper::new(prog);
            let got = ex.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx).unwrap();
            for k in 0..4 {
                assert!(
                    max_err(&want[k], &got[k]) < 1e-12,
                    "exec {variant:?} field {k}: err {}",
                    max_err(&want[k], &got[k])
                );
            }
        }
    }

    #[test]
    fn native_sweeper_matches() {
        let (nx, ny) = (32usize, 4usize);
        let s = sod(nx, ny);
        let rho = pad(&s.rho, ny, nx, false);
        let rhou = pad(&s.rhou, ny, nx, true);
        let rhov = pad(&s.rhov, ny, nx, false);
        let e = pad(&s.e, ny, nx, false);
        let dtdx = 0.08;
        let mut rs = RefSweeper;
        let want = rs.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx).unwrap();
        let prog = compile_variant(super::super::DECK, Variant::Hfav).unwrap();
        let mut ns = NativeSweeper::new(&prog).unwrap();
        let got = ns.sweep(&rho, &rhou, &rhov, &e, dtdx, ny, nx).unwrap();
        for k in 0..4 {
            assert!(
                max_err(&want[k], &got[k]) < 1e-12,
                "native field {k}: err {}",
                max_err(&want[k], &got[k])
            );
        }
    }

    #[test]
    fn sod_conserves_and_stays_physical() {
        let (nx, ny) = (64usize, 8usize);
        let mut s = sod(nx, ny);
        let (m0, e0) = totals(&s);
        let mut sw = HandvecSweeper::new();
        for _ in 0..25 {
            step(&mut s, 1.0 / nx as f64, 0.4, &mut sw).unwrap();
        }
        let (m1, e1) = totals(&s);
        assert!(((m1 - m0) / m0).abs() < 1e-10, "mass drift {}", (m1 - m0) / m0);
        assert!(((e1 - e0) / e0).abs() < 1e-10, "energy drift {}", (e1 - e0) / e0);
        assert!(s.rho.iter().all(|&r| r > 0.0 && r < 1.5));
        // Shock moved right: density right of the midpoint increased.
        let j = ny / 2;
        let right = s.rho[j * nx + 3 * nx / 4];
        assert!(right > 0.125, "shock should have raised density: {right}");
    }

    #[test]
    fn hfav_contracts_hydro_to_scalars() {
        let prog = compile_variant(super::super::DECK, Variant::Hfav).unwrap();
        assert_eq!(prog.fd.nests.len(), 1, "all eight kernels fuse into one nest");
        // Footprint: O(1) per row (scalar windows), vs O(Ni*Nj) unfused.
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), 1024i64);
        ext.insert("Ni".to_string(), 1024i64);
        let fused = prog.footprint_words(&ext).unwrap();
        let naive = compile_variant(super::super::DECK, Variant::Autovec).unwrap();
        let naive_words = naive.footprint_words(&ext).unwrap();
        assert!(fused < 512, "fused intermediate footprint is O(1): {fused} words");
        assert!(
            naive_words > 25 * 1024 * 1024,
            "naive footprint is O(~30 N²): {naive_words} words"
        );
    }
}
