//! COSMO micro-kernels (paper §5.3, Fig. 11): the two-dimensional
//! fourth-order diffusion stencil of Gysi et al. [8], applied over 3D data
//! with no dependence in `k`. Four kernels: `ulapstage` (5-point Laplace),
//! `flux_x`, `flux_y` (limited flux differences of the Laplacian) and
//! `ustage` (integration).
//!
//! Comparison variants reproduced from the paper:
//! * `reference` — four separate sweeps, everything materialized
//!   ("autovec" shape);
//! * `stella` — the STELLA-style variant: Laplacian materialized, the
//!   final three kernels fused *with the fluxes computed redundantly for
//!   each cell*;
//! * the HFAV deck — all four kernels fused, Laplacians/fluxes in rolling
//!   buffers (§5.3: "rolling buffers of sizes 2 and 3 for the fluxes and
//!   Laplacians").

use crate::exec::registry::Registry;

/// Diffusion coefficient baked into `ustage` (the paper's kernels carry
/// their constants the same way).
pub const ALPHA: f64 = 0.1;

pub const DECK: &str = r#"
name: cosmo
iteration:
  order: [k, j, i]
  domains:
    k: [0, Nk]
    j: [2, Nj-2]
    i: [2, Ni-2]
kernels:
  ulapstage:
    declaration: ulapstage(double n, double e, double s, double w, double c, double &lap);
    inputs: |
      n : u?[k?][j?-1][i?]
      e : u?[k?][j?][i?+1]
      s : u?[k?][j?+1][i?]
      w : u?[k?][j?][i?-1]
      c : u?[k?][j?][i?]
    outputs: |
      lap : lap(u?[k?][j?][i?])
    body: "lap = n + e + s + w - 4.0*c;"
  flux_x:
    declaration: flux_x(double lc, double le, double uc, double ue, double &fx);
    inputs: |
      lc : lap(u[k?][j?][i?])
      le : lap(u[k?][j?][i?+1])
      uc : u?[k?][j?][i?]
      ue : u?[k?][j?][i?+1]
    outputs: |
      fx : fx(u?[k?][j?][i?])
    body: "fx = le - lc; if (fx * (ue - uc) > 0.0) { fx = 0.0; }"
  flux_y:
    declaration: flux_y(double lc, double ls, double uc, double us, double &fy);
    inputs: |
      lc : lap(u[k?][j?][i?])
      ls : lap(u[k?][j?+1][i?])
      uc : u?[k?][j?][i?]
      us : u?[k?][j?+1][i?]
    outputs: |
      fy : fy(u?[k?][j?][i?])
    body: "fy = ls - lc; if (fy * (us - uc) > 0.0) { fy = 0.0; }"
  ustage:
    declaration: ustage(double c, double fxm, double fxc, double fym, double fyc, double &o);
    inputs: |
      c : u?[k?][j?][i?]
      fxm : fx(u[k?][j?][i?-1])
      fxc : fx(u[k?][j?][i?])
      fym : fy(u[k?][j?-1][i?])
      fyc : fy(u[k?][j?][i?])
    outputs: |
      o : unew(u?[k?][j?][i?])
    body: "o = c - 0.1*(fxc - fxm + fyc - fym);"
globals:
  inputs: |
    double g_u[k?][j?][i?] => u[k?][j?][i?]
  outputs: |
    unew(u[k][j][i]) => double g_out[k][j][i]
"#;

pub fn registry() -> Registry {
    let mut r = Registry::new();
    r.register("ulapstage", |i, o| o[0] = i[0] + i[1] + i[2] + i[3] - 4.0 * i[4]);
    r.register("flux_x", |i, o| {
        let mut fx = i[1] - i[0];
        if fx * (i[3] - i[2]) > 0.0 {
            fx = 0.0;
        }
        o[0] = fx;
    });
    r.register("flux_y", |i, o| {
        let mut fy = i[1] - i[0];
        if fy * (i[3] - i[2]) > 0.0 {
            fy = 0.0;
        }
        o[0] = fy;
    });
    r.register("ustage", |i, o| o[0] = i[0] - ALPHA * (i[2] - i[1] + i[4] - i[3]));
    r
}

#[inline]
fn lap_at(u: &[f64], _nj: usize, ni: usize, j: usize, i: usize) -> f64 {
    u[(j - 1) * ni + i] + u[j * ni + i + 1] + u[(j + 1) * ni + i] + u[j * ni + i - 1]
        - 4.0 * u[j * ni + i]
}

#[inline]
fn limited(f: f64, du: f64) -> f64 {
    if f * du > 0.0 {
        0.0
    } else {
        f
    }
}

/// "autovec" shape: four separate sweeps per k-slice, Laplacian and both
/// flux arrays fully materialized.
pub fn reference(u: &[f64], nk: usize, nj: usize, ni: usize, out: &mut [f64]) {
    let slice = nj * ni;
    let onj = nj - 4;
    let oni = ni - 4;
    let mut lap = vec![0.0; slice];
    let mut fx = vec![0.0; slice];
    let mut fy = vec![0.0; slice];
    for k in 0..nk {
        let us = &u[k * slice..(k + 1) * slice];
        // sweep 1: laplacian over [1, N-1)
        for j in 1..nj - 1 {
            for i in 1..ni - 1 {
                lap[j * ni + i] = lap_at(us, nj, ni, j, i);
            }
        }
        // sweep 2: flux_x over j in [2, Nj-2), i in [1, Ni-2)
        for j in 2..nj - 2 {
            for i in 1..ni - 2 {
                let f = lap[j * ni + i + 1] - lap[j * ni + i];
                fx[j * ni + i] = limited(f, us[j * ni + i + 1] - us[j * ni + i]);
            }
        }
        // sweep 3: flux_y over j in [1, Nj-2), i in [2, Ni-2)
        for j in 1..nj - 2 {
            for i in 2..ni - 2 {
                let f = lap[(j + 1) * ni + i] - lap[j * ni + i];
                fy[j * ni + i] = limited(f, us[(j + 1) * ni + i] - us[j * ni + i]);
            }
        }
        // sweep 4: ustage over interior [2, N-2)
        for j in 2..nj - 2 {
            for i in 2..ni - 2 {
                let o = us[j * ni + i]
                    - ALPHA
                        * (fx[j * ni + i] - fx[j * ni + i - 1] + fy[j * ni + i]
                            - fy[(j - 1) * ni + i]);
                out[k * onj * oni + (j - 2) * oni + (i - 2)] = o;
            }
        }
    }
}

/// STELLA-style variant (paper Fig. 11): the Laplacian pass is kept
/// separate and materialized; the final three kernels are fused with the
/// fluxes computed redundantly for each cell.
pub fn stella(u: &[f64], nk: usize, nj: usize, ni: usize, out: &mut [f64]) {
    let slice = nj * ni;
    let onj = nj - 4;
    let oni = ni - 4;
    let mut lap = vec![0.0; slice];
    for k in 0..nk {
        let us = &u[k * slice..(k + 1) * slice];
        for j in 1..nj - 1 {
            for i in 1..ni - 1 {
                lap[j * ni + i] = lap_at(us, nj, ni, j, i);
            }
        }
        for j in 2..nj - 2 {
            for i in 2..ni - 2 {
                // redundant flux computation per cell (4 fluxes each)
                let fxc = limited(
                    lap[j * ni + i + 1] - lap[j * ni + i],
                    us[j * ni + i + 1] - us[j * ni + i],
                );
                let fxm = limited(
                    lap[j * ni + i] - lap[j * ni + i - 1],
                    us[j * ni + i] - us[j * ni + i - 1],
                );
                let fyc = limited(
                    lap[(j + 1) * ni + i] - lap[j * ni + i],
                    us[(j + 1) * ni + i] - us[j * ni + i],
                );
                let fym = limited(
                    lap[j * ni + i] - lap[(j - 1) * ni + i],
                    us[j * ni + i] - us[(j - 1) * ni + i],
                );
                out[k * onj * oni + (j - 2) * oni + (i - 2)] =
                    us[j * ni + i] - ALPHA * (fxc - fxm + fyc - fym);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{max_err, seeded, Variant};
    use crate::exec::{self, ExecOptions};
    use crate::plan::{PlanSpec, Program};
    use std::collections::BTreeMap;

    fn compile_variant(deck: &str, v: Variant) -> Result<Program, String> {
        PlanSpec::deck_src(deck).variant(v).compile()
    }

    fn ext(nk: usize, nj: usize, ni: usize) -> BTreeMap<String, i64> {
        [("Nk", nk), ("Nj", nj), ("Ni", ni)]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v as i64))
            .collect()
    }

    #[test]
    fn stella_matches_reference() {
        let (nk, nj, ni) = (3usize, 12usize, 14usize);
        let u = seeded(nk * nj * ni, 4);
        let mut a = vec![0.0; nk * (nj - 4) * (ni - 4)];
        let mut b = a.clone();
        reference(&u, nk, nj, ni, &mut a);
        stella(&u, nk, nj, ni, &mut b);
        assert!(max_err(&a, &b) < 1e-13);
    }

    #[test]
    fn hfav_matches_reference() {
        let (nk, nj, ni) = (2usize, 13usize, 11usize);
        let e = ext(nk, nj, ni);
        let u = seeded(nk * nj * ni, 5);
        let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
        reference(&u, nk, nj, ni, &mut want);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        for v in [Variant::Hfav, Variant::Autovec] {
            let prog = compile_variant(DECK, v).unwrap();
            // The engine's u span may exceed [0,N): check and adapt.
            let shape = exec::external_shape(&prog, "g_u", &e).unwrap();
            assert_eq!(shape, vec![(0, nk as i64), (0, nj as i64), (0, ni as i64)], "{v:?}");
            let out =
                exec::run(&prog, &registry(), &e, &inputs, ExecOptions::default()).unwrap();
            assert!(max_err(&out["g_out"], &want) < 1e-13, "variant {v:?}");
        }
    }

    #[test]
    fn hfav_buffer_sizes_match_paper() {
        // §5.3: Laplacians and fluxes contract to rolling j-rows; fx
        // contracts further to an i-window. Memory footprint
        // O(5Ni + 2)-ish per k-slice instead of O(3NjNi).
        let prog = compile_variant(DECK, Variant::Hfav).unwrap();
        assert_eq!(prog.fd.nests.len(), 1, "all four kernels fuse");
        let sizes = |ident: &str| {
            let v = prog.df.var(ident).unwrap().id;
            prog.sp.storage_of(v).sizes.clone()
        };
        use crate::analysis::DimSize::*;
        // lap: one k-slice at a time, rolling j-rows, full i-rows.
        let lap = sizes("lap(u)");
        assert_eq!(lap[0], One, "lap k");
        assert!(matches!(lap[1], Window { w: 2, .. }), "lap j window: {lap:?}");
        assert_eq!(lap[2], Full, "lap i");
        // fy: rolling j window of 2 rows.
        let fy = sizes("fy(u)");
        assert!(matches!(fy[1], Window { w: 2, .. }), "fy j window: {fy:?}");
        // fx: scalar window in i.
        let fx = sizes("fx(u)");
        assert_eq!(fx[1], One, "fx j");
        assert!(matches!(fx[2], Window { w: 2, .. }), "fx i window: {fx:?}");

        // Footprint: O(Ni) rows, not O(Nj*Ni) slices (paper's
        // O(5NkNjNi) → O(2NkNjNi + 5Ni + 2) claim, per-slice part).
        let mut e = BTreeMap::new();
        e.insert("Nk".to_string(), 8i64);
        e.insert("Nj".to_string(), 512i64);
        e.insert("Ni".to_string(), 512i64);
        let fused_words = prog.footprint_words(&e).unwrap();
        let naive = compile_variant(DECK, Variant::Autovec).unwrap();
        let naive_words = naive.footprint_words(&e).unwrap();
        assert!(
            fused_words < 16 * 512 + 64,
            "fused footprint should be O(Ni): {fused_words}"
        );
        assert!(
            naive_words > 3 * 8 * 500 * 500,
            "naive footprint should be O(NkNjNi): {naive_words}"
        );
    }
}
