//! The paper's normalization example (§3 Figs. 3/4/6, §5.2 Fig. 12):
//! per-row flux differences over a 2D grid, an L2-norm reduction over each
//! row, and a normalization broadcast. Unfused this visits the (j,i) space
//! five times; HFAV fuses it into two nests split at the
//! reduction→broadcast concavity.

use crate::exec::registry::Registry;

pub const DECK: &str = r#"
name: normalize
iteration:
  order: [j, i]
  domains:
    j: [0, Nj]
    i: [0, Ni]
kernels:
  flux:
    declaration: flux(double l, double r, double &f);
    inputs: |
      l : q?[j?][i?]
      r : q?[j?][i?+1]
    outputs: |
      f : flux(q?[j?][i?])
    body: "f = r - l;"
  norm_init:
    declaration: norm_init(double &a);
    outputs: |
      a : zero(acc[j?])
    body: "a = 0.0;"
  norm_acc:
    declaration: norm_acc(double a0, double f, double &a);
    inputs: |
      a0 : zero(acc[j?])
      f : flux(q[j?][i?])
    outputs: |
      a : sum(acc[j?])
    body: "a = a0 + f*f;"
  norm_root:
    declaration: norm_root(double a, double &r);
    inputs: |
      a : sum(acc[j?])
    outputs: |
      r : rsqrt(acc[j?])
    body: "r = 1.0/sqrt(a + 1e-30);"
  normalize:
    declaration: normalize(double f, double r, double &o);
    inputs: |
      f : flux(q[j?][i?])
      r : rsqrt(acc[j?])
    outputs: |
      o : normed(q[j?][i?])
    body: "o = f*r;"
globals:
  inputs: |
    double g_q[j?][i?] => q[j?][i?]
  outputs: |
    normed(q[j][i]) => double g_out[j][i]
"#;

pub fn registry() -> Registry {
    let mut r = Registry::new();
    r.register("flux", |i, o| o[0] = i[1] - i[0]);
    r.register("norm_init", |_i, o| o[0] = 0.0);
    r.register("norm_acc", |i, o| o[0] = i[0] + i[1] * i[1]);
    r.register("norm_root", |i, o| o[0] = 1.0 / (i[0] + 1e-30).sqrt());
    r.register("normalize", |i, o| o[0] = i[0] * i[1]);
    r
}

/// Hand-written "autovec" baseline: the original five separate sweeps over
/// the (j,i) space, all intermediates materialized — what the compiler
/// auto-vectorizes in the paper's Fig. 12 comparison.
pub fn reference(q: &[f64], nj: usize, ni: usize, out: &mut [f64]) {
    assert_eq!(q.len(), nj * (ni + 1));
    assert_eq!(out.len(), nj * ni);
    let mut f = vec![0.0; nj * ni];
    let mut acc = vec![0.0; nj];
    let mut rnorm = vec![0.0; nj];
    // sweep 1: flux
    for j in 0..nj {
        for i in 0..ni {
            f[j * ni + i] = q[j * (ni + 1) + i + 1] - q[j * (ni + 1) + i];
        }
    }
    // sweep 2: init
    for a in acc.iter_mut() {
        *a = 0.0;
    }
    // sweep 3: accumulate
    for j in 0..nj {
        for i in 0..ni {
            let x = f[j * ni + i];
            acc[j] += x * x;
        }
    }
    // sweep 4: root
    for j in 0..nj {
        rnorm[j] = 1.0 / (acc[j] + 1e-30).sqrt();
    }
    // sweep 5: normalize
    for j in 0..nj {
        for i in 0..ni {
            out[j * ni + i] = f[j * ni + i] * rnorm[j];
        }
    }
}

/// Hand-fused upper bound: two sweeps (flux+accumulate, then normalize),
/// flux kept per-row — the shape HFAV generates.
pub fn fused_by_hand(q: &[f64], nj: usize, ni: usize, out: &mut [f64]) {
    let mut f = vec![0.0; nj * ni];
    for j in 0..nj {
        let mut acc = 0.0;
        let base = j * (ni + 1);
        for i in 0..ni {
            let x = q[base + i + 1] - q[base + i];
            f[j * ni + i] = x;
            acc += x * x;
        }
        let r = 1.0 / (acc + 1e-30).sqrt();
        for i in 0..ni {
            out[j * ni + i] = f[j * ni + i] * r;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{max_err, seeded, Variant};
    use crate::exec::{self, ExecOptions};
    use crate::plan::{PlanSpec, Program};
    use std::collections::BTreeMap;

    fn compile_variant(deck: &str, v: Variant) -> Result<Program, String> {
        PlanSpec::deck_src(deck).variant(v).compile()
    }

    #[test]
    fn all_variants_agree() {
        let (nj, ni) = (9usize, 31usize);
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), nj as i64);
        ext.insert("Ni".to_string(), ni as i64);
        let q = seeded(nj * (ni + 1), 2);
        let mut want = vec![0.0; nj * ni];
        reference(&q, nj, ni, &mut want);
        let mut hand = vec![0.0; nj * ni];
        fused_by_hand(&q, nj, ni, &mut hand);
        assert!(max_err(&want, &hand) < 1e-13);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_q".to_string(), q);
        for v in [Variant::Hfav, Variant::Autovec] {
            let prog = compile_variant(DECK, v).unwrap();
            let out =
                exec::run(&prog, &registry(), &ext, &inputs, ExecOptions::default()).unwrap();
            assert!(max_err(&out["g_out"], &want) < 1e-13, "variant {v:?}");
        }
    }

    #[test]
    fn hfav_nests_match_paper() {
        // §5.2: two loop nests; flux kept at full span (no contraction
        // across the split).
        let prog = compile_variant(DECK, Variant::Hfav).unwrap();
        assert_eq!(prog.fd.nests.len(), 2);
        let f = prog.df.var("flux(q)").unwrap().id;
        let st = prog.sp.storage_of(f);
        assert!(st.sizes.iter().all(|s| matches!(s, crate::analysis::DimSize::Full)));
    }
}
