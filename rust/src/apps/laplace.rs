//! The paper's running example (Listing 1, Fig. 10): the 5-point Laplace
//! stencil as used in an SOR-style sweep.

use crate::exec::registry::Registry;

/// HFAV deck (Fig. 10, with the iteration section made explicit).
pub const DECK: &str = r#"
name: laplace
iteration:
  order: [j, i]
  domains:
    j: [1, Nj-1]
    i: [1, Ni-1]
kernels:
  laplace:
    declaration: laplace5(double n, double e, double s, double w, double c, double &o);
    inputs: |
      n : q?[j?-1][i?]
      e : q?[j?][i?+1]
      s : q?[j?+1][i?]
      w : q?[j?][i?-1]
      c : q?[j?][i?]
    outputs: |
      o : laplace(q?[j?][i?])
    body: "o = 0.25*(n + e + s + w) - c;"
globals:
  inputs: |
    double g_cell[j?][i?] => cell[j?][i?]
  outputs: |
    laplace(cell[j][i]) => double g_out[j][i]
"#;

/// Kernel registry for the executor.
pub fn registry() -> Registry {
    let mut r = Registry::new();
    r.register("laplace5", |i, o| o[0] = 0.25 * (i[0] + i[1] + i[2] + i[3]) - i[4]);
    r
}

/// Hand-written reference: interior Laplace over a (nj × ni) grid,
/// output over the (nj-2)×(ni-2) interior.
pub fn reference(u: &[f64], nj: usize, ni: usize) -> Vec<f64> {
    let mut out = vec![0.0; (nj - 2) * (ni - 2)];
    for j in 1..nj - 1 {
        for i in 1..ni - 1 {
            let n = u[(j - 1) * ni + i];
            let e = u[j * ni + i + 1];
            let s = u[(j + 1) * ni + i];
            let w = u[j * ni + i - 1];
            let c = u[j * ni + i];
            out[(j - 1) * (ni - 2) + (i - 1)] = 0.25 * (n + e + s + w) - c;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{max_err, seeded, Variant};
    use crate::exec::{self, ExecOptions};
    use crate::plan::PlanSpec;
    use std::collections::BTreeMap;

    #[test]
    fn hfav_and_autovec_match_reference() {
        let (nj, ni) = (21usize, 17usize);
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), nj as i64);
        ext.insert("Ni".to_string(), ni as i64);
        let u = seeded(nj * ni, 1);
        let want = reference(&u, nj, ni);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_cell".to_string(), u);
        for v in [Variant::Hfav, Variant::Autovec] {
            let prog = PlanSpec::app("laplace").variant(v).compile().unwrap();
            let out =
                exec::run(&prog, &registry(), &ext, &inputs, ExecOptions::default()).unwrap();
            assert!(max_err(&out["g_out"], &want) < 1e-13);
        }
    }
}
