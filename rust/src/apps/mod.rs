//! The paper's application codes (§3, §5): Laplace, the normalization
//! example, the COSMO fourth-order-diffusion micro-kernels, and the
//! Hydro2D shock-hydrodynamics benchmark — each with its HFAV deck, a
//! kernel registry for the executor, hand-written baselines
//! (`autovec`-shaped unfused loops, plus the paper's comparison variants),
//! and workload generators.

pub mod cosmo;
pub mod hydro2d;
pub mod laplace;
pub mod normalization;

use crate::analysis::AnalysisOptions;
use crate::fusion::FusionOptions;
use crate::plan::{compile_src, CompileOptions, Program};

/// The two program shapes the paper compares everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Variant {
    /// Fully fused + contracted + pipelined (the HFAV output).
    Hfav,
    /// One loop nest per kernel, all intermediates materialized — the
    /// shape of the original code (paper: "autovec").
    Autovec,
}

impl Variant {
    /// Stable label used in traces, CSV output and plan-cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Hfav => "hfav",
            Variant::Autovec => "autovec",
        }
    }
}

/// The [`CompileOptions`] each standard variant compiles under — exposed
/// so callers (coordinator, plan cache) can fingerprint them.
pub fn variant_options(v: Variant) -> CompileOptions {
    match v {
        Variant::Hfav => CompileOptions::default(),
        Variant::Autovec => CompileOptions {
            fusion: FusionOptions { enabled: false },
            analysis: AnalysisOptions { contraction: false, ..Default::default() },
            ..Default::default()
        },
    }
}

/// [`variant_options`] with an explicit vector-length override: `None`
/// keeps the deck default, `Some(n)` forces `n` lanes (including
/// `Some(1)` for forced-scalar). This is the options path the
/// coordinator's plan cache fingerprints, so distinct vlens get distinct
/// compiled-plan entries.
pub fn variant_options_vlen(v: Variant, vlen: Option<usize>) -> CompileOptions {
    let mut opts = variant_options(v);
    opts.analysis.vector_len = vlen;
    opts
}

/// Compile a deck source in a standard shape at an explicit vector length.
pub fn compile_variant_vlen(
    src: &str,
    v: Variant,
    vlen: Option<usize>,
) -> Result<Program, String> {
    compile_src(src, variant_options_vlen(v, vlen))
}

/// Compile with the "HFAV + Tuning" options (paper §5.3): full fusion,
/// but innermost-dim windows stay full rows so the steady state
/// auto-vectorizes (the manual-tuning step the paper applied to COSMO).
pub fn compile_tuned(src: &str) -> Result<Program, String> {
    compile_src(
        src,
        CompileOptions {
            analysis: AnalysisOptions { contract_innermost: false, ..Default::default() },
            ..Default::default()
        },
    )
}

/// Compile a deck source in one of the two standard shapes.
pub fn compile_variant(src: &str, v: Variant) -> Result<Program, String> {
    compile_src(src, variant_options(v))
}

/// Deterministic pseudo-random fill in [0, 1) (xorshift64*).
pub fn seeded(n: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(2685821657736338717).max(1);
    (0..n)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64) / ((1u64 << 53) as f64)
        })
        .collect()
}

/// Max relative-ish error between two slices.
pub fn max_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0.0, f64::max)
}
