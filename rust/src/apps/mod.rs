//! The paper's application codes (§3, §5): Laplace, the normalization
//! example, the COSMO fourth-order-diffusion micro-kernels, and the
//! Hydro2D shock-hydrodynamics benchmark — plus a 3D upwind advection
//! sweep ([`advect3d`]) covering the stencil shape the paper's codes
//! never reach (offset reads along the outermost dim). Each app carries
//! its HFAV deck, a kernel registry for the executor, hand-written
//! baselines (`autovec`-shaped unfused loops, plus the paper's
//! comparison variants), and workload generators.
//!
//! Compilation goes through [`crate::plan::PlanSpec`]: a spec names a
//! deck (builtin app, file, or inline source), a [`Variant`], and the
//! tuning knobs, and its canonical fingerprint is the plan-cache key.

pub mod advect3d;
pub mod cosmo;
pub mod hydro2d;
pub mod laplace;
pub mod normalization;

use crate::exec::registry::Registry;

/// The two program shapes the paper compares everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Variant {
    /// Fully fused + contracted + pipelined (the HFAV output).
    Hfav,
    /// One loop nest per kernel, all intermediates materialized — the
    /// shape of the original code (paper: "autovec").
    Autovec,
}

impl Variant {
    /// Stable label used in traces, CSV output and plan-cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Hfav => "hfav",
            Variant::Autovec => "autovec",
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "hfav" => Ok(Variant::Hfav),
            "autovec" => Ok(Variant::Autovec),
            other => Err(format!("unknown variant `{other}` (hfav|autovec)")),
        }
    }
}

/// Deck lookup for the built-in apps.
pub fn deck_of(app: &str) -> Result<&'static str, String> {
    match app {
        "laplace" => Ok(laplace::DECK),
        "normalize" => Ok(normalization::DECK),
        "cosmo" => Ok(cosmo::DECK),
        "hydro2d" => Ok(hydro2d::DECK),
        "advect3d" => Ok(advect3d::DECK),
        _ => Err(format!("unknown app `{app}` (laplace|normalize|cosmo|hydro2d|advect3d)")),
    }
}

/// Names of the built-in apps, in `deck_of` order.
pub const APP_NAMES: [&str; 5] = ["laplace", "normalize", "cosmo", "hydro2d", "advect3d"];

/// One registry holding every built-in app's kernels (the names are
/// globally unique across apps), so the interpreter backend can execute
/// any builtin deck — and any external deck file whose kernels reuse
/// these names. Unknown kernels still fail at execution time with the
/// kernel's name in the error.
pub fn builtin_registry() -> Registry {
    let mut r = laplace::registry();
    r.extend(normalization::registry());
    r.extend(cosmo::registry());
    r.extend(hydro2d::registry());
    r.extend(advect3d::registry());
    r
}

/// Deterministic pseudo-random fill in [0, 1) (xorshift64*).
pub fn seeded(n: usize, seed: u64) -> Vec<f64> {
    let mut s = seed.wrapping_mul(2685821657736338717).max(1);
    (0..n)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64) / ((1u64 << 53) as f64)
        })
        .collect()
}

/// Max relative-ish error between two slices.
pub fn max_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deck_lookup_and_names() {
        for app in APP_NAMES {
            assert!(deck_of(app).is_ok(), "{app}");
        }
        let e = deck_of("nope").unwrap_err();
        assert!(e.contains("unknown app"), "{e}");
    }

    #[test]
    fn builtin_registry_covers_all_apps() {
        let reg = builtin_registry();
        for name in
            ["laplace5", "flux", "norm_acc", "ustage", "flux_x", "riemann", "trace", "adv_update"]
        {
            assert!(reg.get(name).is_some(), "missing kernel `{name}`");
        }
    }

    #[test]
    fn variant_parse_round_trip() {
        for v in [Variant::Hfav, Variant::Autovec] {
            assert_eq!(v.label().parse::<Variant>().unwrap(), v);
        }
        assert!("x".parse::<Variant>().unwrap_err().contains("unknown variant"));
    }
}
