//! 3D first-order upwind advection sweep (flux form): a fourth builtin
//! app whose shape none of the paper's codes reach — a stencil with
//! in-nest-produced values read at nonzero offsets along **all three**
//! loop dims, including the outermost.
//!
//! One sweep of `u_t + v·∇u = 0` with a constant positive velocity
//! `(VZ, VY, VX)` at unit CFL numbers per component:
//!
//! ```text
//! F_d = v_d * u                       (one flux kernel per dim)
//! o   = u - Σ_d (F_d[x_d] - F_d[x_d - 1])
//! ```
//!
//! The update kernel reads `afx` at `i-1`, `afy` at `j-1` **and `afz` at
//! `k-1`** — so contraction has to carry a rolling window along the
//! *outermost* dim (`afz` contracts to a 2-deep window of full (j,i)
//! slices), and no loop dim is k-independent: `outer:<dim>` lanes and
//! `--tile` are illegal on this deck, `parallel_safe` finds no chunkable
//! level, and `vec_dim auto` must fall back to inner strips. That makes
//! advect3d the differential/verify suites' probe for the "every outer
//! knob is an illegal corner" quadrant, with per-dim extents
//! (`Nk`/`Nj`/`Ni`) exercising non-cubic grids end to end.

use crate::exec::registry::Registry;

/// Per-component CFL numbers baked into the flux kernels (positive, so
/// the upwind direction is statically the low side of each dim).
pub const VX: f64 = 0.3;
pub const VY: f64 = 0.2;
pub const VZ: f64 = 0.1;

pub const DECK: &str = r#"
name: advect3d
iteration:
  order: [k, j, i]
  domains:
    k: [1, Nk]
    j: [1, Nj]
    i: [1, Ni]
kernels:
  adv_flux_x:
    declaration: adv_flux_x(double c, double &f);
    inputs: |
      c : u?[k?][j?][i?]
    outputs: |
      f : afx(u?[k?][j?][i?])
    body: "f = 0.3*c;"
  adv_flux_y:
    declaration: adv_flux_y(double c, double &f);
    inputs: |
      c : u?[k?][j?][i?]
    outputs: |
      f : afy(u?[k?][j?][i?])
    body: "f = 0.2*c;"
  adv_flux_z:
    declaration: adv_flux_z(double c, double &f);
    inputs: |
      c : u?[k?][j?][i?]
    outputs: |
      f : afz(u?[k?][j?][i?])
    body: "f = 0.1*c;"
  adv_update:
    declaration: adv_update(double c, double fxm, double fxc, double fym, double fyc, double fzm, double fzc, double &o);
    inputs: |
      c : u?[k?][j?][i?]
      fxm : afx(u[k?][j?][i?-1])
      fxc : afx(u[k?][j?][i?])
      fym : afy(u[k?][j?-1][i?])
      fyc : afy(u[k?][j?][i?])
      fzm : afz(u[k?-1][j?][i?])
      fzc : afz(u[k?][j?][i?])
    outputs: |
      o : adv(u?[k?][j?][i?])
    body: "o = c - (fxc - fxm) - (fyc - fym) - (fzc - fzm);"
globals:
  inputs: |
    double g_u[k?][j?][i?] => u[k?][j?][i?]
  outputs: |
    adv(u[k][j][i]) => double g_out[k][j][i]
"#;

pub fn registry() -> Registry {
    let mut r = Registry::new();
    r.register("adv_flux_x", |i, o| o[0] = VX * i[0]);
    r.register("adv_flux_y", |i, o| o[0] = VY * i[0]);
    r.register("adv_flux_z", |i, o| o[0] = VZ * i[0]);
    r.register("adv_update", |i, o| {
        o[0] = i[0] - (i[2] - i[1]) - (i[4] - i[3]) - (i[6] - i[5]);
    });
    r
}

/// Hand-written "autovec" baseline: four separate materialized sweeps
/// (three flux grids plus the update), in the same flux-difference
/// arithmetic order as the kernels so errors stay at rounding level.
pub fn reference(u: &[f64], nk: usize, nj: usize, ni: usize, out: &mut [f64]) {
    assert_eq!(u.len(), nk * nj * ni);
    let (onk, onj, oni) = (nk - 1, nj - 1, ni - 1);
    assert_eq!(out.len(), onk * onj * oni);
    let at = |k: usize, j: usize, i: usize| u[(k * nj + j) * ni + i];
    let mut fx = vec![0.0; nk * nj * ni];
    let mut fy = vec![0.0; nk * nj * ni];
    let mut fz = vec![0.0; nk * nj * ni];
    for k in 0..nk {
        for j in 0..nj {
            for i in 0..ni {
                let idx = (k * nj + j) * ni + i;
                fx[idx] = VX * u[idx];
                fy[idx] = VY * u[idx];
                fz[idx] = VZ * u[idx];
            }
        }
    }
    for k in 1..nk {
        for j in 1..nj {
            for i in 1..ni {
                let idx = (k * nj + j) * ni + i;
                let o = at(k, j, i)
                    - (fx[idx] - fx[idx - 1])
                    - (fy[idx] - fy[idx - ni])
                    - (fz[idx] - fz[idx - nj * ni]);
                out[((k - 1) * onj + (j - 1)) * oni + (i - 1)] = o;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{max_err, seeded, Variant};
    use crate::exec::{self, ExecOptions};
    use crate::plan::PlanSpec;
    use std::collections::BTreeMap;

    fn ext(nk: usize, nj: usize, ni: usize) -> BTreeMap<String, i64> {
        [("Nk", nk), ("Nj", nj), ("Ni", ni)]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v as i64))
            .collect()
    }

    #[test]
    fn hfav_matches_reference() {
        let (nk, nj, ni) = (5usize, 9usize, 12usize);
        let e = ext(nk, nj, ni);
        let u = seeded(nk * nj * ni, 13);
        let mut want = vec![0.0; (nk - 1) * (nj - 1) * (ni - 1)];
        reference(&u, nk, nj, ni, &mut want);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u);
        for v in [Variant::Hfav, Variant::Autovec] {
            let prog = PlanSpec::deck_src(DECK).variant(v).compile().unwrap();
            let shape = exec::external_shape(&prog, "g_u", &e).unwrap();
            assert_eq!(shape, vec![(0, nk as i64), (0, nj as i64), (0, ni as i64)], "{v:?}");
            let out =
                exec::run(&prog, &registry(), &e, &inputs, ExecOptions::default()).unwrap();
            assert!(max_err(&out["g_out"], &want) < 1e-13, "variant {v:?}");
        }
    }

    #[test]
    fn outermost_dim_carries_a_rolling_window() {
        // The shape the other builtins never reach: afz is read at k-1
        // and k, so contraction keeps a 2-deep rolling window of full
        // (j,i) slices along the *outermost* dim.
        let prog = PlanSpec::deck_src(DECK).compile().unwrap();
        assert_eq!(prog.fd.nests.len(), 1, "all four kernels fuse");
        use crate::analysis::DimSize::*;
        let sizes = |ident: &str| {
            let v = prog.df.var(ident).unwrap().id;
            prog.sp.storage_of(v).sizes.clone()
        };
        let fz = sizes("afz(u)");
        assert!(matches!(fz[0], Window { w: 2, .. }), "afz k window: {fz:?}");
        let fy = sizes("afy(u)");
        assert!(matches!(fy[1], Window { w: 2, .. }), "afy j window: {fy:?}");
        let fx = sizes("afx(u)");
        assert!(matches!(fx[2], Window { w: 2, .. }), "afx i window: {fx:?}");
    }

    #[test]
    fn no_outer_dim_is_legal() {
        // Every dim carries an offset read of an in-nest value, so outer
        // lanes and tiling must fail compilation (the legality gates are
        // this deck's whole point) while `auto` falls back to inner.
        use crate::analysis::VecDim;
        use crate::plan::Vlen;
        for dim in ["k", "j", "i"] {
            let r = PlanSpec::deck_src(DECK)
                .vlen(Vlen::Fixed(4))
                .vec_dim(VecDim::Outer(dim.to_string()))
                .compile();
            assert!(r.is_err(), "outer:{dim} must be illegal");
        }
        assert!(PlanSpec::deck_src(DECK).vlen(Vlen::Fixed(4)).tiled(true).compile().is_err());
        let auto = PlanSpec::deck_src(DECK)
            .vlen(Vlen::Fixed(4))
            .vec_dim(VecDim::Auto)
            .compile()
            .unwrap();
        assert_eq!(auto.vector_len(), 4);
    }
}
