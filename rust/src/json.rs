//! Minimal JSON support: a strict escaper and a small recursive-descent
//! parser — no serde, keeping the crate dependency-free.
//!
//! Two consumers:
//!
//! * The hand-rolled report writers ([`crate::bench::report`], the
//!   tuned-plans DB [`crate::plan::tunedb`]) escape every embedded
//!   string through [`escape`], so hostile inputs (deck paths with
//!   quotes, backslashes, control characters) still produce valid JSON.
//! * [`parse`] reads those files back (DB loads, tests that round-trip
//!   writer output), accepting any spec-conforming document.
//!
//! The parser covers the full value grammar (objects, arrays, strings
//! with `\uXXXX` escapes and surrogate pairs, numbers, booleans, null);
//! numbers are held as `f64`, which is exact for every integer the
//! writers emit (counts and fixed-precision rates, all far below 2^53).

/// Escape `s` for embedding inside a JSON string literal (no
/// surrounding quotes). Handles the two mandatory printables (`"`,
/// `\`), the short control escapes, and `\u00XX` for the remaining
/// control characters — everything RFC 8259 requires.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object member lookup (first match; writers never duplicate keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

/// Parse a complete JSON document (one value plus trailing whitespace).
pub fn parse(text: &str) -> Result<Value, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            members.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape `{s}`"))?;
        self.pos = end;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("truncated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: the low half must follow.
                                if self.peek() != Some(b'\\') {
                                    return Err("lone high surrogate".to_string());
                                }
                                self.pos += 1;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad low surrogate".to_string());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("bad code point {cp:#x}"))?,
                            );
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8 by construction).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number `{s}`: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_covers_mandatory_characters() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("a\nb\tc\rd"), "a\\nb\\tc\\rd");
        assert_eq!(escape("\u{0008}\u{000C}"), "\\b\\f");
        assert_eq!(escape("\u{0000}\u{001f}"), "\\u0000\\u001f");
        // Non-ASCII passes through unescaped (valid in JSON strings).
        assert_eq!(escape("héllo"), "héllo");
    }

    #[test]
    fn parse_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
        let v = parse("{ \"a\": [1, 2, {\"b\": false}], \"c\": \"d\" }").unwrap();
        assert_eq!(v.get("c").and_then(Value::as_str), Some("d"));
        let arr = v.get("a").and_then(Value::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").and_then(Value::as_bool), Some(false));
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{\"a\":}"] {
            assert!(parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn escape_parse_round_trip_hostile_strings() {
        let hostile = [
            "path with spaces/deck.yaml",
            "quote\" backslash\\ done",
            "newline\n tab\t cr\r",
            "control\u{0001}\u{001f} bell\u{0007}",
            "unicode é 本 \u{1F600}",
            "\\\"nested\\\" \\u0041",
        ];
        for s in hostile {
            let doc = format!("{{ \"k\": \"{}\" }}", escape(s));
            let v = parse(&doc).unwrap_or_else(|e| panic!("`{s}`: {e}"));
            assert_eq!(v.get("k").and_then(Value::as_str), Some(s), "round trip of `{s}`");
        }
    }

    #[test]
    fn parse_unicode_escapes_and_surrogates() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
        // 😀 U+1F600 as a surrogate pair.
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap(), Value::Str("\u{1F600}".into()));
        assert!(parse("\"\\ud83d\"").is_err(), "lone surrogate must fail");
    }
}
