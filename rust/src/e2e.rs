//! End-to-end demo: Sod shock tube through the full stack — HFAV deck →
//! fusion/contraction → generated C → cc -O3 → dlopen → time loop — with
//! a comparison against the autovec baseline and a printed density
//! profile. This is the run recorded in EXPERIMENTS.md §E2E.

use crate::apps::hydro2d::solver::*;
use crate::apps::Variant;
use crate::plan::PlanSpec;

/// Run the Sod demo and print throughput + the final mid-row density
/// profile (coarse ASCII) for both engines.
pub fn sod_demo(size: usize, steps: usize) -> Result<(), String> {
    println!("Hydro2D Sod shock tube: {size}x{size}, {steps} split steps");
    let prog = PlanSpec::app("hydro2d").compile()?;
    println!(
        "HFAV schedule: {} nest(s); intermediate footprint {} words @1024^2 (autovec: {})",
        prog.fd.nests.len(),
        prog.footprint_words(
            &[("Nj".to_string(), 1024i64), ("Ni".to_string(), 1024i64)].into_iter().collect()
        )?,
        PlanSpec::app("hydro2d").variant(Variant::Autovec).compile()?.footprint_words(
            &[("Nj".to_string(), 1024i64), ("Ni".to_string(), 1024i64)].into_iter().collect()
        )?,
    );

    let mut results = Vec::new();
    for engine in ["autovec", "hfav-native"] {
        let mut sweeper: Box<dyn Sweeper> = match engine {
            "autovec" => Box::new(RefSweeper),
            _ => Box::new(NativeSweeper::new(&prog)?),
        };
        let mut s = sod(size, size);
        let (m0, e0) = totals(&s);
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            step(&mut s, 1.0 / size as f64, 0.4, sweeper.as_mut())?;
        }
        let wall = t0.elapsed();
        let (m1, e1) = totals(&s);
        let cups = (size * size * steps) as f64 / wall.as_secs_f64();
        println!(
            "  {engine:<12} t={:.4}  {:.1} Mcells/s  wall={wall:?}  mass_drift={:.2e} energy_drift={:.2e}",
            s.t,
            cups / 1e6,
            (m1 - m0) / m0,
            (e1 - e0) / e0
        );
        results.push((engine, s, cups));
    }
    // Cross-check final states.
    let a = &results[0].1;
    let b = &results[1].1;
    let err = crate::apps::max_err(&a.rho, &b.rho);
    println!("  final-density max err autovec vs hfav: {err:.2e}");
    if err > 1e-10 {
        return Err(format!("engines diverged: {err}"));
    }
    // ASCII mid-row density profile.
    let j = size / 2;
    let cols = 64.min(size);
    println!("  density profile (mid row):");
    let mut line = String::from("  ");
    for c in 0..cols {
        let i = c * size / cols;
        let r = a.rho[j * size + i];
        let ch = match (r * 10.0) as i64 {
            0..=2 => '.',
            3..=4 => ':',
            5..=6 => '+',
            7..=8 => '#',
            _ => '@',
        };
        line.push(ch);
    }
    println!("{line}");
    println!("  speedup hfav/autovec: {:.2}x", results[1].2 / results[0].2);
    Ok(())
}
