//! Persistent chunk-worker pool shared by every parallel schedule level
//! in the process — all coordinator job workers scatter into this one
//! pool, so intra-job chunk parallelism and across-job parallelism draw
//! from the same set of cores instead of multiplying thread counts.
//!
//! [`scatter`] is synchronous: it enqueues one task per chunk and
//! blocks until every chunk has signalled the completion latch. That
//! single property carries the two guarantees the executor relies on:
//! borrowed captures in the chunk closure are sound (the lifetime
//! erasure below never outlives the call), and there is never an
//! in-flight chunk after a caller returns — workers park idle between
//! scatters, so dropping a coordinator (whose own `Drop` joins its job
//! workers) leaves no detached thread holding work. Chunk closures must
//! not scatter recursively (the schedule has one parallel level).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

struct Task {
    /// Chunk closure, lifetime-erased in [`scatter`]; the pool never
    /// holds a task beyond its execution.
    f: &'static (dyn Fn(usize) + Sync),
    chunk: usize,
    done: Arc<Latch>,
}

// SAFETY: the closure is Sync (shared calls from any thread are fine)
// and `scatter` blocks on the latch until every task has run, so the
// erased reference outlives all worker accesses.
unsafe impl Send for Task {}

struct Latch {
    left: Mutex<usize>,
    panicked: Mutex<usize>,
    cv: Condvar,
}

struct Pool {
    // `Sender` is cheaply clonable but historically !Sync; serialize
    // enqueues through a mutex instead of assuming a newer std.
    tx: Mutex<Sender<Task>>,
    workers: usize,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        for w in 0..workers {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("hfav-chunk-{w}"))
                .spawn(move || loop {
                    let task = {
                        let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                        match guard.recv() {
                            Ok(t) => t,
                            Err(_) => return, // sender gone: process teardown
                        }
                    };
                    // A panicking kernel must not wedge the latch (or
                    // kill the pool thread): count it and move on.
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        (task.f)(task.chunk)
                    }))
                    .is_ok();
                    if !ok {
                        *task.done.panicked.lock().unwrap_or_else(|e| e.into_inner()) += 1;
                    }
                    let mut left = task.done.left.lock().unwrap_or_else(|e| e.into_inner());
                    *left -= 1;
                    if *left == 0 {
                        task.done.cv.notify_all();
                    }
                })
                .expect("spawn chunk worker");
        }
        Pool { tx: Mutex::new(tx), workers }
    })
}

/// Worker count of the shared pool (effective-thread reporting).
pub fn workers() -> usize {
    pool().workers
}

/// Run `f(c)` for every chunk `c in 0..chunks` across the pool,
/// blocking until all complete. Returns an error if any chunk panicked
/// (the chunks that ran are *not* rolled back — callers treat the run
/// as failed).
pub fn scatter(chunks: usize, f: &(dyn Fn(usize) + Sync)) -> Result<(), String> {
    if chunks == 0 {
        return Ok(());
    }
    let p = pool();
    let done =
        Arc::new(Latch { left: Mutex::new(chunks), panicked: Mutex::new(0), cv: Condvar::new() });
    // SAFETY (lifetime erasure): the wait below does not return until
    // every enqueued task has finished executing `f`, so the 'static
    // reference can never be used after this frame unwinds.
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    let mut unsent = 0usize;
    {
        let tx = p.tx.lock().unwrap_or_else(|e| e.into_inner());
        for c in 0..chunks {
            if tx.send(Task { f: f_static, chunk: c, done: done.clone() }).is_err() {
                unsent = chunks - c;
                break;
            }
        }
    }
    if unsent > 0 {
        // Receiver gone (should not happen: workers never exit while the
        // sender lives) — account for the tasks that never enqueued,
        // then still drain the ones that did before touching `f`'s frame.
        *done.left.lock().unwrap_or_else(|e| e.into_inner()) -= unsent;
    }
    let mut left = done.left.lock().unwrap_or_else(|e| e.into_inner());
    while *left > 0 {
        left = done.cv.wait(left).unwrap_or_else(|e| e.into_inner());
    }
    drop(left);
    if unsent > 0 {
        return Err("chunk pool is gone".to_string());
    }
    let panicked = *done.panicked.lock().unwrap_or_else(|e| e.into_inner());
    if panicked > 0 {
        return Err(format!("{panicked} chunk task(s) panicked"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_runs_every_chunk_and_drains() {
        let hits = AtomicUsize::new(0);
        let mask = Mutex::new(vec![false; 23]);
        scatter(23, &|c| {
            hits.fetch_add(1, Ordering::SeqCst);
            mask.lock().unwrap()[c] = true;
        })
        .unwrap();
        // Synchronous: by the time scatter returns, every chunk ran.
        assert_eq!(hits.load(Ordering::SeqCst), 23);
        assert!(mask.into_inner().unwrap().iter().all(|&b| b));
        assert!(workers() >= 1);
    }

    #[test]
    fn scatter_reports_panicked_chunks() {
        let e = scatter(4, &|c| {
            if c == 2 {
                panic!("boom");
            }
        })
        .unwrap_err();
        assert!(e.contains("panicked"), "{e}");
        // The pool survives a panicking task.
        scatter(2, &|_| {}).unwrap();
    }
}
