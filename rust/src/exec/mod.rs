//! In-process schedule executor.
//!
//! Runs a compiled [`Program`] over concrete grids with kernels registered
//! as Rust functions. This substitutes for "compile the emitted C and run
//! it": in [`Mode::Peeled`] the executor *interprets the same lowered
//! schedule tree* ([`crate::schedule`]) that both code emitters print —
//! peeled segments, inner lane-fission strips, outer-dim lane loops,
//! alignment heads, multi-dim tiles — so it visits kernel invocations in
//! exactly the order the emitted code executes them and stays the
//! differential oracle. No loop shape is decided here; the executor only
//! walks nodes (the old hand-mirrored strip selection is gone).
//!
//! [`Mode::Guarded`] is the other execution shape: one uniform loop per
//! level with per-callsite masking (the paper's "HFAV + Tuning"
//! fold-into-steady-state variant). It is strip-free by construction.
//!
//! [`run_traced`] records the `(kernel, index)` sequence of a peeled run
//! — the instrumented trace the property suite compares against
//! [`crate::schedule::Schedule::visit`].

pub mod pool;
pub mod registry;

use crate::analysis::DimSize;
use crate::dataflow::Terminal;
use crate::fusion::{FusedNest, Member, Role};
use crate::plan::Program;
use crate::schedule::Node;
use registry::Registry;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Peeled,
    Guarded,
}

/// Executor options. The loop shapes themselves (strips, lanes, peels,
/// parallel levels) are carried by the compiled plan's schedule tree —
/// there is nothing shape-related to configure here; `threads` only
/// sets how many chunk workers a `Parallel` level may use at run time.
#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    pub mode: Mode,
    /// Resolved chunk-worker count for parallel levels (>= 1). At 1
    /// (the default) every parallel level runs its single chunk inline,
    /// identically to the pre-parallel executor.
    pub threads: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { mode: Mode::Peeled, threads: 1 }
    }
}

/// Concrete per-dim bounds.
#[derive(Debug, Clone, Copy)]
struct Range {
    lo: i64,
    hi: i64,
}

/// Resolved access path for one argument: storage buffer + per-dim
/// (dim level in nest, shift+offset, size class data).
#[derive(Debug, Clone)]
struct Access {
    storage: usize,
    /// per var-dim: (nest level, added offset = shift + read offset)
    dims: Vec<(usize, i64)>,
    /// per var-dim: index rule
    rules: Vec<IndexRule>,
    /// per var-dim stride
    strides: Vec<i64>,
}

#[derive(Debug, Clone, Copy)]
enum IndexRule {
    /// Full span: subtract `lo`.
    Full { lo: i64 },
    /// Window: wrap modulo `alloc` (power of two → mask).
    Window { alloc: i64 },
    /// Single slot.
    One,
}

/// A compiled callsite: kernel fn + resolved argument accesses.
struct Compiled {
    kernel: registry::Kernel,
    reads: Vec<Access>,
    writes: Vec<Access>,
    /// Concrete iteration domain per nest level (None = member lacks dim).
    domain: Vec<Option<Range>>,
    /// shifts per nest level.
    shifts: Vec<i64>,
    /// phase per nest level (from fusion roles).
    phases: Vec<Phase>,
    name: String,
}

/// The result of a run: named external outputs (row-major over their span).
pub type Outputs = BTreeMap<String, Vec<f64>>;

/// The invocation sequence of a traced run: (kernel name, loop indices
/// by nest level) per kernel call, in execution order.
pub type InvocationTrace = Vec<(String, Vec<i64>)>;

/// Shape of an external array: per-dim concrete half-open bounds.
pub fn external_shape(
    prog: &Program,
    name: &str,
    extents: &BTreeMap<String, i64>,
) -> Result<Vec<(i64, i64)>, String> {
    for v in &prog.df.vars {
        let store = match &v.terminal {
            Terminal::Input { storage, .. } | Terminal::Output { storage, .. } => storage,
            Terminal::No => continue,
        };
        if store == name {
            return v
                .dims
                .iter()
                .map(|d| {
                    let s = &v.span[d];
                    Ok((s.lo.eval(extents)?, s.hi.eval(extents)?))
                })
                .collect();
        }
    }
    Err(format!("no external array `{name}`"))
}

/// Number of elements of an external array.
pub fn external_len(
    prog: &Program,
    name: &str,
    extents: &BTreeMap<String, i64>,
) -> Result<usize, String> {
    Ok(external_shape(prog, name, extents)?
        .iter()
        .map(|(lo, hi)| (hi - lo).max(0) as usize)
        .product())
}

/// Reusable executor workspace: storage buffers allocated by one run are
/// recycled by the next instead of being freed and re-malloc'd. Recycled
/// buffers are zero-filled before reuse, so results are identical to a
/// fresh run; for batches of same-shape jobs the resize is a pure memset
/// with no allocator traffic. The coordinator keeps one workspace per
/// worker and batches same-key jobs so consecutive runs share it.
#[derive(Default)]
pub struct Workspace {
    pool: Vec<Vec<f64>>,
    /// Buffers recycled from the pool.
    pub reused: u64,
    /// Buffers freshly allocated because the pool was empty.
    pub allocated: u64,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zeroed buffer of `len` words, recycled if possible.
    fn take(&mut self, len: usize) -> Vec<f64> {
        match self.pool.pop() {
            Some(mut buf) => {
                self.reused += 1;
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.allocated += 1;
                vec![0f64; len]
            }
        }
    }

    fn recycle(&mut self, bufs: Vec<Vec<f64>>) {
        self.pool.extend(bufs);
    }
}

/// Run a program.
///
/// `inputs` maps terminal-input storage names to row-major arrays over
/// their required span (see [`external_shape`]). Returns terminal outputs.
/// Deck alias pairs share one underlying buffer (in-place execution).
pub fn run(
    prog: &Program,
    reg: &Registry,
    extents: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    opts: ExecOptions,
) -> Result<Outputs, String> {
    let mut ws = Workspace::default();
    run_with(prog, reg, extents, inputs, opts, &mut ws)
}

/// [`run`] with an explicit [`Workspace`] so buffer allocations are reused
/// across consecutive runs (the serving hot path).
pub fn run_with(
    prog: &Program,
    reg: &Registry,
    extents: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    opts: ExecOptions,
    ws: &mut Workspace,
) -> Result<Outputs, String> {
    // Buffers live outside the fallible body so every path — success or
    // error — recycles them into the workspace.
    let mut buffers: Vec<Vec<f64>> = Vec::new();
    let result = run_inner(prog, reg, extents, inputs, opts, ws, &mut buffers, None);
    ws.recycle(std::mem::take(&mut buffers));
    result
}

/// [`run`] (peeled mode) that additionally records the kernel-invocation
/// sequence — the executor's side of the "schedule walk order equals
/// emitted order" property.
pub fn run_traced(
    prog: &Program,
    reg: &Registry,
    extents: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
) -> Result<(Outputs, InvocationTrace), String> {
    run_traced_with(prog, reg, extents, inputs, 1)
}

/// [`run_traced`] at an explicit chunk-worker count. Chunks of a
/// parallel level interleave in the trace, but each chunk's invocation
/// subsequence stays in schedule order — the partition property pinned
/// by the property suite against
/// [`crate::schedule::Schedule::visit_threads`].
pub fn run_traced_with(
    prog: &Program,
    reg: &Registry,
    extents: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    threads: usize,
) -> Result<(Outputs, InvocationTrace), String> {
    let mut ws = Workspace::default();
    let mut buffers: Vec<Vec<f64>> = Vec::new();
    let mut trace = InvocationTrace::new();
    let result = run_inner(
        prog,
        reg,
        extents,
        inputs,
        ExecOptions { mode: Mode::Peeled, threads: threads.max(1) },
        &mut ws,
        &mut buffers,
        Some(&mut trace),
    );
    ws.recycle(std::mem::take(&mut buffers));
    result.map(|out| (out, trace))
}

#[allow(clippy::too_many_arguments)]
fn run_inner(
    prog: &Program,
    reg: &Registry,
    extents: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    opts: ExecOptions,
    ws: &mut Workspace,
    buffers: &mut Vec<Vec<f64>>,
    mut trace: Option<&mut InvocationTrace>,
) -> Result<Outputs, String> {
    // ---- allocate storage -------------------------------------------------
    // external name -> workspace buffer index (aliases share).
    let mut ext_buf: BTreeMap<String, usize> = BTreeMap::new();
    let mut storage_buf: Vec<usize> = vec![usize::MAX; prog.sp.storages.len()];

    // Pre-size externals from their var spans.
    for s in &prog.sp.storages {
        if let Some(name) = &s.external {
            // Alias resolution: find canonical name.
            let canon = canonical_alias(prog, name);
            let idx = match ext_buf.get(&canon) {
                Some(&i) => i,
                None => {
                    let len = external_len_by_storage(prog, s, extents)?;
                    let mut buf = ws.take(len);
                    // Fill from inputs if provided under any aliased name.
                    if let Some(src) = inputs.get(name).or_else(|| inputs.get(&canon)) {
                        if src.len() != len {
                            buffers.push(buf);
                            return Err(format!(
                                "input `{name}`: expected {len} elements, got {}",
                                src.len()
                            ));
                        }
                        buf.copy_from_slice(src);
                    }
                    buffers.push(buf);
                    let i = buffers.len() - 1;
                    ext_buf.insert(canon.clone(), i);
                    i
                }
            };
            // If an aliased input arrives under this name, copy it in.
            if let Some(src) = inputs.get(name) {
                if src.len() == buffers[idx].len() && buffers[idx].iter().all(|&x| x == 0.0) {
                    buffers[idx].copy_from_slice(src);
                }
            }
            storage_buf[s.id] = idx;
        } else {
            let words = crate::analysis::storage_words(s, &prog.df, extents)?;
            buffers.push(ws.take(words.max(0) as usize));
            storage_buf[s.id] = buffers.len() - 1;
        }
    }

    // ---- execute the schedule ---------------------------------------------
    let mut scratch_in: Vec<f64> = Vec::with_capacity(32);
    let mut scratch_out: Vec<f64> = Vec::with_capacity(16);

    // All buffer pushes are done: raw views over them are stable from
    // here, and chunk workers of a parallel level may share them (their
    // writes are disjoint by the legality gate; contracted intermediates
    // are replaced per chunk via `BufView::with_private`).
    let bufs = BufView::of(&mut buffers[..]);
    // The trace goes behind a mutex so parallel chunks can append; with
    // one thread the lock is uncontended and the order is the serial one.
    let sink: Option<TraceSink> = trace.as_ref().map(|_| Mutex::new(InvocationTrace::new()));

    for (nest, np) in prog.fd.nests.iter().zip(&prog.sched.nests) {
        let compiled: Vec<Compiled> = nest
            .members
            .iter()
            .map(|m| compile_member(prog, reg, nest, m, extents, &storage_buf))
            .collect::<Result<_, _>>()?;
        let mut idx = vec![0i64; nest.dims.len()];
        match opts.mode {
            Mode::Peeled => {
                // Interpret the lowered schedule tree — the same nodes
                // the code emitters print.
                exec_nodes(
                    &compiled,
                    &np.body,
                    extents,
                    &mut idx,
                    &bufs,
                    &storage_buf,
                    opts.threads.max(1),
                    &mut scratch_in,
                    &mut scratch_out,
                    sink.as_ref(),
                )?;
            }
            Mode::Guarded => {
                let all: Vec<usize> = (0..compiled.len()).collect();
                exec_guarded(
                    &compiled,
                    &all,
                    0,
                    nest.dims.len(),
                    &mut idx,
                    &bufs,
                    &mut scratch_in,
                    &mut scratch_out,
                )?;
            }
        }
    }

    if let (Some(t), Some(s)) = (trace.as_mut(), sink) {
        t.extend(s.into_inner().unwrap_or_else(|e| e.into_inner()));
    }

    // ---- collect outputs ----------------------------------------------------
    let mut outputs = Outputs::new();
    for s in &prog.sp.storages {
        if let Some(name) = &s.external {
            let is_output = s.vars.iter().any(|&v| {
                matches!(prog.df.vars[v].terminal, Terminal::Output { .. })
            });
            if is_output {
                outputs.insert(name.clone(), buffers[storage_buf[s.id]].clone());
            }
        }
    }
    Ok(outputs)
}

/// Canonical name for aliased externals (first element of the alias pair).
fn canonical_alias(prog: &Program, name: &str) -> String {
    for (a, b) in &prog.deck.aliases {
        if name == b {
            return a.clone();
        }
    }
    name.to_string()
}

fn external_len_by_storage(
    prog: &Program,
    s: &crate::analysis::Storage,
    extents: &BTreeMap<String, i64>,
) -> Result<usize, String> {
    let rep = &prog.df.vars[s.vars[0]];
    let mut len = 1usize;
    for d in &rep.dims {
        let span = &rep.span[d];
        len *= (span.hi.eval(extents)? - span.lo.eval(extents)?).max(0) as usize;
    }
    Ok(len)
}

fn compile_member(
    prog: &Program,
    reg: &Registry,
    nest: &FusedNest,
    m: &Member,
    extents: &BTreeMap<String, i64>,
    storage_buf: &[usize],
) -> Result<Compiled, String> {
    let cs = &prog.df.callsites[m.callsite];
    let kernel = reg
        .get(&cs.name)
        .ok_or_else(|| format!("no kernel registered for `{}`", cs.name))?;

    let access = |vid: usize, offsets: &[i64]| -> Result<Access, String> {
        let var = &prog.df.vars[vid];
        let sid = prog.sp.of_var[vid];
        let st = &prog.sp.storages[sid];
        let mut dims = Vec::with_capacity(var.dims.len());
        let mut rules = Vec::with_capacity(var.dims.len());
        let mut sizes = Vec::with_capacity(var.dims.len());
        for (k, d) in var.dims.iter().enumerate() {
            let level = nest
                .dim_index(d)
                .ok_or_else(|| format!("dim `{d}` of `{}` not in nest", var.ident))?;
            let shift = if m.roles[level] == Role::Loop { m.shifts[level] } else { 0 };
            dims.push((level, shift + offsets[k]));
            let (rule, size) = match &st.sizes[k] {
                DimSize::One => (IndexRule::One, 1i64),
                DimSize::Window { alloc, .. } => (IndexRule::Window { alloc: *alloc }, *alloc),
                DimSize::Full => {
                    let span = &var.span[d];
                    let lo = span.lo.eval(extents)?;
                    let hi = span.hi.eval(extents)?;
                    (IndexRule::Full { lo }, (hi - lo).max(0))
                }
            };
            rules.push(rule);
            sizes.push(size);
        }
        // Strides per the storage's layout order (shared with both code
        // emitters): row-major, except the outer lane dim of an
        // outer-vectorized program moves innermost for intermediates.
        let order = crate::analysis::layout_order(st, prog.outer_lane_dim());
        let mut strides = vec![1i64; sizes.len()];
        for k in 0..sizes.len() {
            let pos = order.iter().position(|&x| x == k).unwrap();
            strides[k] = order[pos + 1..].iter().map(|&x| sizes[x]).product();
        }
        Ok(Access { storage: storage_buf[sid], dims, rules, strides })
    };

    let mut reads = Vec::new();
    for (_, vid, offsets) in &cs.reads {
        reads.push(access(*vid, offsets)?);
    }
    let mut writes = Vec::new();
    for (_, vid, offsets) in &cs.writes {
        writes.push(access(*vid, offsets)?);
    }

    let mut domain = Vec::with_capacity(nest.dims.len());
    let mut shifts = Vec::with_capacity(nest.dims.len());
    let mut phases = Vec::with_capacity(nest.dims.len());
    for (lvl, d) in nest.dims.iter().enumerate() {
        if m.roles[lvl] == Role::Loop {
            let dom = &cs.domain[d];
            domain.push(Some(Range { lo: dom.lo.eval(extents)?, hi: dom.hi.eval(extents)? }));
            shifts.push(m.shifts[lvl]);
            phases.push(Phase::Loop);
        } else {
            domain.push(None);
            shifts.push(0);
            phases.push(if m.roles[lvl] == Role::Pre { Phase::Pre } else { Phase::Post });
        }
    }

    Ok(Compiled {
        kernel: kernel.clone(),
        reads,
        writes,
        domain,
        shifts,
        phases,
        name: cs.name.clone(),
    })
}

/// Shared trace accumulator: parallel chunks append under the lock,
/// serial runs pay one uncontended lock per invocation (test-only path).
type TraceSink = Mutex<InvocationTrace>;

/// Raw views of the storage buffers, shareable across chunk workers.
///
/// SAFETY argument: concurrent access is only reachable through a
/// `Node::Parallel` level, whose legality gate
/// ([`crate::analysis::parallel_safe`]) guarantees (a) chunk writes to
/// shared storages hit disjoint slabs along the parallel dim (every
/// write is `DimSize::Full` and offset-0 along it), and (b) every
/// storage *not* full along that dim is replaced per chunk via
/// [`BufView::with_private`] — so no two workers ever touch the same
/// element with a write involved. Bounds are still checked on every
/// access (the same safety net indexing `Vec` gave).
struct BufView {
    ptrs: Vec<*mut f64>,
    lens: Vec<usize>,
}

unsafe impl Send for BufView {}
unsafe impl Sync for BufView {}

impl BufView {
    fn of(buffers: &mut [Vec<f64>]) -> BufView {
        let (ptrs, lens) = buffers.iter_mut().map(|b| (b.as_mut_ptr(), b.len())).unzip();
        BufView { ptrs, lens }
    }

    fn len_of(&self, b: usize) -> usize {
        self.lens[b]
    }

    /// This view with the given buffer indices re-pointed at the
    /// chunk-private replicas (parallel workers' windowed intermediates).
    fn with_private(&self, replace: &[usize], replicas: &mut [Vec<f64>]) -> BufView {
        let mut v = BufView { ptrs: self.ptrs.clone(), lens: self.lens.clone() };
        for (k, &b) in replace.iter().enumerate() {
            v.ptrs[b] = replicas[k].as_mut_ptr();
            v.lens[b] = replicas[k].len();
        }
        v
    }

    #[inline]
    fn load(&self, b: usize, off: usize) -> f64 {
        assert!(off < self.lens[b], "read OOB: buffer {b} len {} offset {off}", self.lens[b]);
        unsafe { *self.ptrs[b].add(off) }
    }

    #[inline]
    fn store(&self, b: usize, off: usize, v: f64) {
        assert!(off < self.lens[b], "write OOB: buffer {b} len {} offset {off}", self.lens[b]);
        unsafe { *self.ptrs[b].add(off) = v }
    }
}

/// One kernel call: record it in the trace (if any), then invoke.
fn call(
    c: &Compiled,
    idx: &[i64],
    bufs: &BufView,
    scratch_in: &mut Vec<f64>,
    scratch_out: &mut Vec<f64>,
    trace: Option<&TraceSink>,
) -> Result<(), String> {
    if let Some(tr) = trace {
        tr.lock().unwrap_or_else(|e| e.into_inner()).push((c.name.clone(), idx.to_vec()));
    }
    invoke(c, idx, bufs, scratch_in, scratch_out)
}

/// Interpret a sequence of schedule nodes ([`Mode::Peeled`]): the
/// executor's walk is node-for-node the structure both emitters print,
/// evaluated over concrete extents.
#[allow(clippy::too_many_arguments)]
fn exec_nodes(
    compiled: &[Compiled],
    nodes: &[Node],
    extents: &BTreeMap<String, i64>,
    idx: &mut Vec<i64>,
    bufs: &BufView,
    storage_buf: &[usize],
    threads: usize,
    scratch_in: &mut Vec<f64>,
    scratch_out: &mut Vec<f64>,
    trace: Option<&TraceSink>,
) -> Result<(), String> {
    for node in nodes {
        match node {
            Node::Parallel(p) => {
                let (lo, hi) = (p.lo.eval(extents)?, p.hi.eval(extents)?);
                let spans = crate::schedule::chunk_spans(lo, hi, p.unit, threads);
                if spans.len() <= 1 {
                    // Single chunk: run inline on this thread — byte- and
                    // order-identical to the pre-parallel executor.
                    for (clo, chi) in spans {
                        let mut ext = extents.clone();
                        ext.insert(p.lo_sym(), clo);
                        ext.insert(p.hi_sym(), chi);
                        exec_nodes(
                            compiled, &p.body, &ext, idx, bufs, storage_buf, threads,
                            scratch_in, scratch_out, trace,
                        )?;
                    }
                } else {
                    let err: Mutex<Option<String>> = Mutex::new(None);
                    let base_idx: Vec<i64> = idx.clone();
                    let job = |c: usize| {
                        let (clo, chi) = spans[c];
                        let mut ext = extents.clone();
                        ext.insert(p.lo_sym(), clo);
                        ext.insert(p.hi_sym(), chi);
                        // Per-chunk replicas of the nest-local windowed
                        // intermediates (the "workspace slices"): zeroed
                        // like a fresh serial buffer, and no value flows
                        // across the parallel dim through them, so the
                        // chunk computes bitwise what the serial run does.
                        let mut replicas: Vec<Vec<f64>> = p
                            .private_storages
                            .iter()
                            .map(|&sid| vec![0.0f64; bufs.len_of(storage_buf[sid])])
                            .collect();
                        let slots: Vec<usize> =
                            p.private_storages.iter().map(|&sid| storage_buf[sid]).collect();
                        let view = bufs.with_private(&slots, &mut replicas);
                        let mut idx2 = base_idx.clone();
                        let mut sin: Vec<f64> = Vec::with_capacity(32);
                        let mut sout: Vec<f64> = Vec::with_capacity(16);
                        if let Err(e) = exec_nodes(
                            compiled, &p.body, &ext, &mut idx2, &view, storage_buf, threads,
                            &mut sin, &mut sout, trace,
                        ) {
                            let mut g = err.lock().unwrap_or_else(|p| p.into_inner());
                            if g.is_none() {
                                *g = Some(e);
                            }
                        }
                    };
                    pool::scatter(spans.len(), &job)?;
                    if let Some(e) = err.into_inner().unwrap_or_else(|p| p.into_inner()) {
                        return Err(e);
                    }
                }
            }
            Node::Loop(l) => {
                let (lo, hi) = (l.lo.eval(extents)?, l.hi.eval(extents)?);
                let mut t = lo;
                while t < hi {
                    idx[l.level] = t;
                    exec_nodes(
                        compiled, &l.body, extents, idx, bufs, storage_buf, threads,
                        scratch_in, scratch_out, trace,
                    )?;
                    t += 1;
                }
            }
            Node::Strip(s) => {
                let (lo, hi) = (s.lo.eval(extents)?, s.hi.eval(extents)?);
                let lanes = s.lanes as i64;
                let mut t = lo;
                if let Some(head) = &s.head {
                    // Scalar alignment head: advance to a multiple of the
                    // lane count (clamped), exactly like the emitted code.
                    let he = (t + ((lanes - t.rem_euclid(lanes)) % lanes)).min(hi);
                    while t < he {
                        idx[s.level] = t;
                        exec_nodes(
                            compiled, head, extents, idx, bufs, storage_buf, threads,
                            scratch_in, scratch_out, trace,
                        )?;
                        t += 1;
                    }
                }
                let steady = t + ((hi - t) / lanes) * lanes;
                while t < steady {
                    idx[s.level] = t;
                    exec_nodes(
                        compiled, &s.steady, extents, idx, bufs, storage_buf, threads,
                        scratch_in, scratch_out, trace,
                    )?;
                    t += lanes;
                }
                while t < hi {
                    idx[s.level] = t;
                    exec_nodes(
                        compiled, &s.remainder, extents, idx, bufs, storage_buf, threads,
                        scratch_in, scratch_out, trace,
                    )?;
                    t += 1;
                }
            }
            Node::Guarded(g) => {
                let (lo, hi) = (g.lo.eval(extents)?, g.hi.eval(extents)?);
                let mut arms = Vec::with_capacity(g.arms.len());
                for a in &g.arms {
                    arms.push((a.lo.eval(extents)?, a.hi.eval(extents)?));
                }
                let mut t = lo;
                while t < hi {
                    idx[g.level] = t;
                    for (a, &(alo, ahi)) in g.arms.iter().zip(&arms) {
                        if t >= alo && t < ahi {
                            exec_nodes(
                                compiled, &a.body, extents, idx, bufs, storage_buf, threads,
                                scratch_in, scratch_out, trace,
                            )?;
                        }
                    }
                    t += 1;
                }
            }
            Node::TimeTile(t) => {
                // Temporal blocking, interpreted as pure syntax: per block
                // of the outer dim, run the body `t_block` times. Clamp
                // symbols restrict each pass to the block; warm-up symbols
                // (bound only for passes after the first) replay the halo
                // below the block base. The arithmetic here mirrors
                // `schedule::visit_nodes` exactly.
                let (lo, hi) = (t.lo.eval(extents)?, t.hi.eval(extents)?);
                let block = t.block as i64;
                let mut b = lo;
                while b < hi {
                    let bh = (b + block).min(hi);
                    for s in 0..t.t_block {
                        let mut ext = extents.clone();
                        for (g, (olo, ohi)) in t.clamps.iter().enumerate() {
                            let cl = olo.eval(extents)?.max(b);
                            let ch = ohi.eval(extents)?.min(bh).max(cl);
                            ext.insert(crate::schedule::tt_lo_sym(t.level, g), cl);
                            ext.insert(crate::schedule::tt_hi_sym(t.level, g), ch);
                        }
                        if s > 0 {
                            for (g, w) in t.warmup.iter().enumerate() {
                                let wl = w.lo.eval(extents)?.max(b - w.depth);
                                let wh = w.hi.eval(extents)?.min(b).max(wl);
                                ext.insert(crate::schedule::tt_warm_lo_sym(t.level, g), wl);
                                ext.insert(crate::schedule::tt_warm_hi_sym(t.level, g), wh);
                            }
                            for w in &t.warmup {
                                exec_nodes(
                                    compiled, &w.body, &ext, idx, bufs, storage_buf, threads,
                                    scratch_in, scratch_out, trace,
                                )?;
                            }
                        }
                        exec_nodes(
                            compiled, &t.body, &ext, idx, bufs, storage_buf, threads,
                            scratch_in, scratch_out, trace,
                        )?;
                    }
                    b = bh;
                }
            }
            Node::Invoke(inv) => {
                let c = &compiled[inv.member];
                match &inv.lanes {
                    None => call(c, idx, bufs, scratch_in, scratch_out, trace)?,
                    Some(l) => {
                        let base = idx[l.level];
                        for k in 0..l.lanes as i64 {
                            idx[l.level] = base + k;
                            call(c, idx, bufs, scratch_in, scratch_out, trace)?;
                        }
                        idx[l.level] = base;
                    }
                }
            }
            Node::MemberStrip(ms) => {
                let c = &compiled[ms.member];
                let base = idx[ms.level];
                for il in 0..ms.lanes as i64 {
                    idx[ms.level] = base + il;
                    match &ms.outer {
                        None => call(c, idx, bufs, scratch_in, scratch_out, trace)?,
                        Some(l) => {
                            let ob = idx[l.level];
                            for ol in 0..l.lanes as i64 {
                                idx[l.level] = ob + ol;
                                call(c, idx, bufs, scratch_in, scratch_out, trace)?;
                            }
                            idx[l.level] = ob;
                        }
                    }
                }
                idx[ms.level] = base;
            }
        }
    }
    Ok(())
}

/// [`Mode::Guarded`]: one uniform loop per level with per-callsite
/// masking at the leaf (strip-free by construction).
#[allow(clippy::too_many_arguments)]
fn exec_guarded(
    compiled: &[Compiled],
    members: &[usize],
    level: usize,
    nlevels: usize,
    idx: &mut Vec<i64>,
    bufs: &BufView,
    scratch_in: &mut Vec<f64>,
    scratch_out: &mut Vec<f64>,
) -> Result<(), String> {
    if members.is_empty() {
        return Ok(());
    }
    if level == nlevels {
        for &mi in members {
            let c = &compiled[mi];
            if !active(c, idx, nlevels) {
                continue;
            }
            invoke(c, idx, bufs, scratch_in, scratch_out)?;
        }
        return Ok(());
    }
    let pre: Vec<usize> =
        members.iter().copied().filter(|&m| compiled[m].phase_at(level) == Phase::Pre).collect();
    let inl: Vec<usize> =
        members.iter().copied().filter(|&m| compiled[m].phase_at(level) == Phase::Loop).collect();
    let post: Vec<usize> =
        members.iter().copied().filter(|&m| compiled[m].phase_at(level) == Phase::Post).collect();

    exec_guarded(compiled, &pre, level + 1, nlevels, idx, bufs, scratch_in, scratch_out)?;

    if !inl.is_empty() {
        // Loop range: union of member ranges at this level.
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for &mi in &inl {
            if let Some(r) = compiled[mi].domain[level] {
                lo = lo.min(r.lo - compiled[mi].shifts[level]);
                hi = hi.max(r.hi - compiled[mi].shifts[level]);
            }
        }
        for t in lo..hi {
            idx[level] = t;
            exec_guarded(compiled, &inl, level + 1, nlevels, idx, bufs, scratch_in, scratch_out)?;
        }
    }

    exec_guarded(compiled, &post, level + 1, nlevels, idx, bufs, scratch_in, scratch_out)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Pre,
    Loop,
    Post,
}

impl Compiled {
    fn phase_at(&self, level: usize) -> Phase {
        match self.phases.get(level) {
            Some(p) => *p,
            None => Phase::Loop,
        }
    }
}

/// Is the member active at the current index (guarded mode)?
fn active(c: &Compiled, idx: &[i64], nlevels: usize) -> bool {
    for lvl in 0..nlevels {
        if let Some(r) = c.domain[lvl] {
            if c.phase_at(lvl) == Phase::Loop {
                let p = idx[lvl] + c.shifts[lvl];
                if p < r.lo || p >= r.hi {
                    return false;
                }
            }
        }
    }
    true
}

fn invoke(
    c: &Compiled,
    idx: &[i64],
    bufs: &BufView,
    scratch_in: &mut Vec<f64>,
    scratch_out: &mut Vec<f64>,
) -> Result<(), String> {
    scratch_in.clear();
    for a in &c.reads {
        scratch_in.push(bufs.load(a.storage, resolve(a, idx)));
    }
    scratch_out.clear();
    scratch_out.resize(c.writes.len(), 0.0);
    (c.kernel)(scratch_in, scratch_out);
    for (k, a) in c.writes.iter().enumerate() {
        let off = resolve(a, idx);
        bufs.store(a.storage, off, scratch_out[k]);
    }
    Ok(())
}

#[inline]
fn resolve(a: &Access, idx: &[i64]) -> usize {
    let mut off = 0i64;
    for k in 0..a.dims.len() {
        let (level, add) = a.dims[k];
        let pos = idx[level] + add;
        let x = match a.rules[k] {
            IndexRule::One => 0,
            IndexRule::Window { alloc } => pos.rem_euclid(alloc),
            IndexRule::Full { lo } => pos - lo,
        };
        off += x * a.strides[k];
    }
    off as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;
    use crate::plan::{compile_src, CompileOptions};

    fn laplace_registry() -> Registry {
        let mut r = Registry::new();
        r.register("laplace5", |i, o| o[0] = 0.25 * (i[0] + i[1] + i[2] + i[3]) - i[4]);
        r
    }

    fn norm_registry() -> Registry {
        let mut r = Registry::new();
        r.register("flux", |i, o| o[0] = i[1] - i[0]);
        r.register("norm_init", |_i, o| o[0] = 0.0);
        r.register("norm_acc", |i, o| o[0] = i[0] + i[1] * i[1]);
        r.register("norm_root", |i, o| o[0] = 1.0 / (i[0] + 1e-30).sqrt());
        r.register("normalize", |i, o| o[0] = i[0] * i[1]);
        r
    }

    fn chain_registry() -> Registry {
        let mut r = Registry::new();
        r.register("dbl", |i, o| o[0] = 2.0 * i[0]);
        r.register("diff", |i, o| o[0] = i[1] - i[0]);
        r
    }

    fn extents(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn seeded(n: usize, seed: u64) -> Vec<f64> {
        // xorshift64* deterministic fill in [0,1)
        let mut s = seed.wrapping_mul(2685821657736338717).max(1);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64) / ((1u64 << 53) as f64)
            })
            .collect()
    }

    fn laplace_ref(u: &[f64], nj: usize, ni: usize) -> Vec<f64> {
        // output over interior span [1, N-1) per dim → (nj-2)x(ni-2)
        let mut out = vec![0.0; (nj - 2) * (ni - 2)];
        for j in 1..nj - 1 {
            for i in 1..ni - 1 {
                let n = u[(j - 1) * ni + i];
                let e = u[j * ni + i + 1];
                let s = u[(j + 1) * ni + i];
                let w = u[j * ni + i - 1];
                let c = u[j * ni + i];
                out[(j - 1) * (ni - 2) + (i - 1)] = 0.25 * (n + e + s + w) - c;
            }
        }
        out
    }

    fn assert_close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "elem {k}: {x} vs {y}");
        }
    }

    #[test]
    fn laplace_matches_reference_both_modes() {
        let prog = compile_src(testdecks::LAPLACE, CompileOptions::default()).unwrap();
        let reg = laplace_registry();
        let (nj, ni) = (13usize, 17usize);
        let ext = extents(&[("Nj", nj as i64), ("Ni", ni as i64)]);
        // g_cell span: [0, Nj) x [0, Ni).
        assert_eq!(
            external_shape(&prog, "g_cell", &ext).unwrap(),
            vec![(0, nj as i64), (0, ni as i64)]
        );
        let u = seeded(nj * ni, 42);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_cell".to_string(), u.clone());
        let want = laplace_ref(&u, nj, ni);
        for mode in [Mode::Peeled, Mode::Guarded] {
            let out =
                run(&prog, &reg, &ext, &inputs, ExecOptions { mode, ..Default::default() })
                    .unwrap();
            assert_close(&out["g_out"], &want, 1e-12);
        }
    }

    #[test]
    fn laplace_rolled_inputs_match() {
        let opts = CompileOptions { roll_all_inputs: true, ..Default::default() };
        let prog = compile_src(testdecks::LAPLACE, opts).unwrap();
        let reg = laplace_registry();
        let (nj, ni) = (9usize, 11usize);
        let ext = extents(&[("Nj", nj as i64), ("Ni", ni as i64)]);
        let u = seeded(nj * ni, 7);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_cell".to_string(), u.clone());
        let want = laplace_ref(&u, nj, ni);
        let out = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&out["g_out"], &want, 1e-12);
    }

    #[test]
    fn chain1d_matches_reference() {
        let prog = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        let reg = chain_registry();
        let n = 23usize;
        let ext = extents(&[("N", n as i64)]);
        let u = seeded(n, 3);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        let mut want = vec![0.0; n - 2];
        for i in 1..n - 1 {
            want[i - 1] = 2.0 * u[i + 1] - 2.0 * u[i - 1];
        }
        for mode in [Mode::Peeled, Mode::Guarded] {
            let out =
                run(&prog, &reg, &ext, &inputs, ExecOptions { mode, ..Default::default() })
                    .unwrap();
            assert_close(&out["g_d"], &want, 1e-12);
        }
    }

    #[test]
    fn normalize_matches_reference() {
        let prog = compile_src(testdecks::NORMALIZE, CompileOptions::default()).unwrap();
        let reg = norm_registry();
        let (nj, ni) = (6usize, 10usize);
        let ext = extents(&[("Nj", nj as i64), ("Ni", ni as i64)]);
        // q span: [0,Nj) x [0,Ni+1) (flux reads i+1).
        assert_eq!(
            external_shape(&prog, "g_q", &ext).unwrap(),
            vec![(0, nj as i64), (0, ni as i64 + 1)]
        );
        let q = seeded(nj * (ni + 1), 11);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_q".to_string(), q.clone());
        let mut want = vec![0.0; nj * ni];
        for j in 0..nj {
            let mut acc = 0.0;
            let f: Vec<f64> =
                (0..ni).map(|i| q[j * (ni + 1) + i + 1] - q[j * (ni + 1) + i]).collect();
            for i in 0..ni {
                acc += f[i] * f[i];
            }
            let r = 1.0 / (acc + 1e-30).sqrt();
            for i in 0..ni {
                want[j * ni + i] = f[i] * r;
            }
        }
        for mode in [Mode::Peeled, Mode::Guarded] {
            let out =
                run(&prog, &reg, &ext, &inputs, ExecOptions { mode, ..Default::default() })
                    .unwrap();
            assert_close(&out["g_out"], &want, 1e-12);
        }
    }

    #[test]
    fn unfused_uncontracted_matches_fused() {
        // The "autovec baseline" plan must agree numerically with the fully
        // fused + contracted plan.
        let baseline_opts = CompileOptions {
            fusion: crate::fusion::FusionOptions { enabled: false },
            analysis: crate::analysis::AnalysisOptions {
                contraction: false,
                ..Default::default()
            },
            ..Default::default()
        };
        for (src, reg) in [
            (testdecks::LAPLACE, laplace_registry()),
            (testdecks::NORMALIZE, norm_registry()),
            (testdecks::CHAIN1D, chain_registry()),
        ] {
            let fused = compile_src(src, CompileOptions::default()).unwrap();
            let naive = compile_src(src, baseline_opts.clone()).unwrap();
            let ext = extents(&[("Nj", 8), ("Ni", 9), ("N", 16)]);
            let mut inputs = BTreeMap::new();
            for (name, _, _) in fused.external_inputs() {
                let len = external_len(&fused, &name, &ext).unwrap();
                inputs.insert(name, seeded(len, 99));
            }
            let a = run(&fused, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
            let b = run(&naive, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
            for (k, v) in &a {
                assert_close(v, &b[k], 1e-12);
            }
        }
    }

    #[test]
    fn strip_execution_matches_scalar_plan_bitwise() {
        // A vector-expanded plan runs lane-fissioned strips (from its
        // schedule tree); per-element math is unchanged, so it must agree
        // bit-for-bit with a forced-scalar plan — and the reference.
        let mk = |vlen: usize| {
            compile_src(
                testdecks::CHAIN1D,
                CompileOptions {
                    analysis: crate::analysis::AnalysisOptions {
                        vector_len: Some(vlen),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let vec4 = mk(4);
        assert_eq!(vec4.vector_len(), 4);
        let scalar = mk(1);
        let reg = chain_registry();
        let n = 27usize;
        let ext = extents(&[("N", n as i64)]);
        let u = seeded(n, 3);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        let a = run(&scalar, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let b = run(&vec4, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&b["g_d"], &a["g_d"], 0.0);
        let mut want = vec![0.0; n - 2];
        for i in 1..n - 1 {
            want[i - 1] = 2.0 * u[i + 1] - 2.0 * u[i - 1];
        }
        assert_close(&a["g_d"], &want, 1e-12);
    }

    #[test]
    fn outer_strip_execution_matches_scalar_bitwise() {
        // cosmo with outer-k lanes at vlen 4 on Nk=6 (strip + remainder):
        // outer lanes are independent, so the strip order must reproduce
        // the plain scalar compile bit-for-bit — and the reference.
        let outer_opts = CompileOptions {
            analysis: crate::analysis::AnalysisOptions {
                vector_len: Some(4),
                vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                ..Default::default()
            },
            ..Default::default()
        };
        let prog = compile_src(crate::apps::cosmo::DECK, outer_opts).unwrap();
        assert_eq!(prog.outer_lane_dim(), Some("k"));
        let scalar = compile_src(crate::apps::cosmo::DECK, CompileOptions::default()).unwrap();
        let (nk, nj, ni) = (6usize, 9usize, 11usize);
        let ext = extents(&[("Nk", nk as i64), ("Nj", nj as i64), ("Ni", ni as i64)]);
        let reg = crate::apps::cosmo::registry();
        let u = seeded(nk * nj * ni, 8);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        let a = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let b = run(&scalar, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&a["g_out"], &b["g_out"], 0.0);
        let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
        crate::apps::cosmo::reference(&u, nk, nj, ni, &mut want);
        assert_close(&a["g_out"], &want, 1e-12);
    }

    #[test]
    fn tiled_execution_matches_scalar_bitwise() {
        // Multi-dim lane tiling (outer k lanes × inner i strips) on a
        // non-square grid: pure per-element kernels in a new order, so
        // the tile walk must agree bit-for-bit with the scalar plan.
        let tiled_opts = CompileOptions {
            analysis: crate::analysis::AnalysisOptions {
                vector_len: Some(4),
                vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                tile: true,
                ..Default::default()
            },
            ..Default::default()
        };
        let prog = compile_src(crate::apps::cosmo::DECK, tiled_opts).unwrap();
        assert!(prog.tiled());
        let scalar = compile_src(crate::apps::cosmo::DECK, CompileOptions::default()).unwrap();
        let (nk, nj, ni) = (6usize, 9usize, 11usize);
        let ext = extents(&[("Nk", nk as i64), ("Nj", nj as i64), ("Ni", ni as i64)]);
        let reg = crate::apps::cosmo::registry();
        let u = seeded(nk * nj * ni, 21);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        let a = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let b = run(&scalar, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&a["g_out"], &b["g_out"], 0.0);
        let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
        crate::apps::cosmo::reference(&u, nk, nj, ni, &mut want);
        assert_close(&a["g_out"], &want, 1e-12);
    }

    #[test]
    fn aligned_strip_execution_matches_unaligned_bitwise() {
        // chain1d at vlen 4 with aligned strip heads: the head peel
        // shifts strip boundaries, which must not change any value.
        let mk = |aligned: bool| CompileOptions {
            analysis: crate::analysis::AnalysisOptions {
                vector_len: Some(4),
                ..Default::default()
            },
            aligned,
            ..Default::default()
        };
        let plain = compile_src(testdecks::CHAIN1D, mk(false)).unwrap();
        let aligned = compile_src(testdecks::CHAIN1D, mk(true)).unwrap();
        let reg = chain_registry();
        let n = 27usize;
        let ext = extents(&[("N", n as i64)]);
        let u = seeded(n, 3);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), u.clone());
        let a = run(&plain, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let b = run(&aligned, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&a["g_d"], &b["g_d"], 0.0);
        let mut want = vec![0.0; n - 2];
        for i in 1..n - 1 {
            want[i - 1] = 2.0 * u[i + 1] - 2.0 * u[i - 1];
        }
        assert_close(&b["g_d"], &want, 1e-12);
    }

    #[test]
    fn traced_run_reports_invocations_in_schedule_order() {
        let prog = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        let reg = chain_registry();
        let n = 8usize;
        let ext = extents(&[("N", n as i64)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), seeded(n, 5));
        let (out, trace) = run_traced(&prog, &reg, &ext, &inputs).unwrap();
        assert!(out.contains_key("g_d"));
        // dbl over [0, 6), diff over [1, 7): 12 invocations total, and
        // the first is dbl@0 (pipeline prologue).
        assert_eq!(trace.len(), 12, "{trace:?}");
        assert_eq!(trace[0].0, "dbl");
        assert_eq!(trace[0].1, vec![0]);
        // The traced outputs are the normal outputs.
        let plain = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        assert_close(&out["g_d"], &plain["g_d"], 0.0);
    }

    #[test]
    fn workspace_reuse_matches_fresh_runs() {
        let prog = compile_src(testdecks::LAPLACE, CompileOptions::default()).unwrap();
        let reg = laplace_registry();
        let ext = extents(&[("Nj", 11), ("Ni", 9)]);
        let u = seeded(11 * 9, 4);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_cell".to_string(), u);
        let fresh = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let got =
                run_with(&prog, &reg, &ext, &inputs, ExecOptions::default(), &mut ws).unwrap();
            assert_close(&got["g_out"], &fresh["g_out"], 0.0);
        }
        assert!(ws.reused > 0, "expected recycling (allocated={})", ws.allocated);
        // From the second run on, the pool covers every buffer.
        let allocated = ws.allocated;
        let _ = run_with(&prog, &reg, &ext, &inputs, ExecOptions::default(), &mut ws).unwrap();
        assert_eq!(ws.allocated, allocated);
    }

    #[test]
    fn missing_kernel_reported() {
        let prog = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        let reg = Registry::new();
        let ext = extents(&[("N", 8)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), vec![0.0; 8]);
        let err = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap_err();
        assert!(err.contains("no kernel registered"), "{err}");
    }

    #[test]
    fn wrong_input_size_reported() {
        let prog = compile_src(testdecks::CHAIN1D, CompileOptions::default()).unwrap();
        let reg = chain_registry();
        let ext = extents(&[("N", 8)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), vec![0.0; 3]);
        let err = run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap_err();
        assert!(err.contains("expected"), "{err}");
    }

    fn cosmo_at(vlen: usize, tile: bool) -> Program {
        compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(vlen),
                    vec_dim: if vlen > 1 {
                        crate::analysis::VecDim::Auto
                    } else {
                        crate::analysis::VecDim::Inner
                    },
                    tile,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn parallel_chunks_are_bitwise_identical_to_serial() {
        // The tentpole invariant at the interpreter: a parallel level run
        // at any worker count produces the exact bytes the serial walk
        // does — chunk-private replicas make the windowed intermediates
        // invisible, and shared writes land in disjoint slabs.
        let (nk, nj, ni) = (7usize, 10usize, 13usize); // non-square
        let ext = extents(&[("Nk", nk as i64), ("Nj", nj as i64), ("Ni", ni as i64)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), seeded(nk * nj * ni, 17));
        let reg = crate::apps::cosmo::registry();
        for (vlen, tile) in [(1usize, false), (4, false), (4, true)] {
            let prog = cosmo_at(vlen, tile);
            let serial =
                run(&prog, &reg, &ext, &inputs, ExecOptions { mode: Mode::Peeled, threads: 1 })
                    .unwrap();
            let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            for threads in [2usize, 3, auto] {
                let got =
                    run(&prog, &reg, &ext, &inputs, ExecOptions { mode: Mode::Peeled, threads })
                        .unwrap();
                // Bitwise: exact equality, not tolerance.
                assert_eq!(
                    got["g_out"],
                    serial["g_out"],
                    "vlen={vlen} tile={tile} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn time_tiled_execution_matches_untiled_bitwise() {
        // Temporal blocking re-invokes idempotent sweep passes per block;
        // every write lands the same value at the same coordinate, so the
        // result must be byte-identical to the untiled plan — at any
        // worker count (TimeTile under Parallel) and with lane tiling on.
        let mk = |vlen: usize, tile: bool, tt: usize| {
            compile_src(
                crate::apps::cosmo::DECK,
                CompileOptions {
                    analysis: crate::analysis::AnalysisOptions {
                        vector_len: Some(vlen),
                        vec_dim: if vlen > 1 {
                            crate::analysis::VecDim::Auto
                        } else {
                            crate::analysis::VecDim::Inner
                        },
                        tile,
                        time_tile: tt,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let (nk, nj, ni) = (7usize, 10usize, 13usize); // non-square
        let ext = extents(&[("Nk", nk as i64), ("Nj", nj as i64), ("Ni", ni as i64)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), seeded(nk * nj * ni, 29));
        let reg = crate::apps::cosmo::registry();
        let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
        crate::apps::cosmo::reference(&inputs["g_u"], nk, nj, ni, &mut want);
        for (vlen, tile) in [(1usize, false), (4, false), (4, true)] {
            let base = run(
                &mk(vlen, tile, 1),
                &reg,
                &ext,
                &inputs,
                ExecOptions::default(),
            )
            .unwrap();
            assert_close(&base["g_out"], &want, 1e-12);
            for tt in [2usize, 4] {
                let prog = mk(vlen, tile, tt);
                for threads in [1usize, 3] {
                    let got = run(
                        &prog,
                        &reg,
                        &ext,
                        &inputs,
                        ExecOptions { mode: Mode::Peeled, threads },
                    )
                    .unwrap();
                    assert_eq!(
                        got["g_out"], base["g_out"],
                        "vlen={vlen} tile={tile} tt={tt} threads={threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn traced_parallel_run_matches_serial_multiset() {
        // Chunks interleave in the shared trace, but nothing is lost or
        // duplicated: the multiset of invocations equals the serial one
        // (exact per-chunk partition order is pinned in tests/property.rs).
        let prog = cosmo_at(1, false);
        let reg = crate::apps::cosmo::registry();
        let (nk, nj, ni) = (6usize, 9, 11);
        let ext = extents(&[("Nk", nk as i64), ("Nj", nj as i64), ("Ni", ni as i64)]);
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), seeded(nk * nj * ni, 23));
        let (out1, t1) = run_traced_with(&prog, &reg, &ext, &inputs, 1).unwrap();
        let (out3, t3) = run_traced_with(&prog, &reg, &ext, &inputs, 3).unwrap();
        assert_eq!(out1["g_out"], out3["g_out"]);
        assert_eq!(t1.len(), t3.len());
        let mut s1 = t1.clone();
        let mut s3 = t3.clone();
        s1.sort();
        s3.sort();
        assert_eq!(s1, s3);
    }
}
