//! Kernel registry: maps rule names to Rust implementations.
//!
//! Kernels follow the paper's model: pure functions of their scalar
//! arguments (no side effects, no iteration-order dependence) — inputs in
//! declaration order, outputs in declaration order.

use std::collections::BTreeMap;
use std::sync::Arc;

/// A kernel implementation: reads `inputs` (rule input params, in order),
/// writes `outputs` (rule output params, in order).
pub type Kernel = Arc<dyn Fn(&[f64], &mut [f64]) + Send + Sync>;

/// Registry of kernel implementations.
#[derive(Clone, Default)]
pub struct Registry {
    map: BTreeMap<String, Kernel>,
    identity: Option<Kernel>,
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            map: BTreeMap::new(),
            identity: Some(Arc::new(|i: &[f64], o: &mut [f64]| {
                o.copy_from_slice(&i[..o.len()]);
            })),
        }
    }

    /// Register a kernel under a rule name.
    pub fn register<F>(&mut self, name: &str, f: F) -> &mut Self
    where
        F: Fn(&[f64], &mut [f64]) + Send + Sync + 'static,
    {
        self.map.insert(name.to_string(), Arc::new(f));
        self
    }

    /// Look up a kernel. Synthetic `__roll_*` copy callsites (inserted by
    /// in/out chaining) resolve to the identity kernel.
    pub fn get(&self, name: &str) -> Option<&Kernel> {
        if let Some(k) = self.map.get(name) {
            return Some(k);
        }
        if name.starts_with("__roll_") {
            return self.identity.as_ref();
        }
        None
    }

    /// Absorb every kernel from `other` (later registrations win). Used to
    /// merge the per-app registries into one builtin registry.
    pub fn extend(&mut self, other: Registry) {
        self.map.extend(other.map);
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_get() {
        let mut r = Registry::new();
        r.register("add", |i, o| o[0] = i[0] + i[1]);
        let k = r.get("add").unwrap();
        let mut out = [0.0];
        k(&[2.0, 3.0], &mut out);
        assert_eq!(out[0], 5.0);
        assert!(r.get("missing").is_none());
    }

    #[test]
    fn roll_resolves_to_identity() {
        let r = Registry::new();
        let k = r.get("__roll_cell").unwrap();
        let mut out = [0.0];
        k(&[7.5], &mut out);
        assert_eq!(out[0], 7.5);
    }
}
