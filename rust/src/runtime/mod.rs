//! PJRT runtime: load AOT-compiled HLO text artifacts (produced once by
//! `python/compile/aot.py`) and execute them from Rust. Python is never on
//! the request path — the interchange format is HLO *text* because the
//! xla crate's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Shape signature of one artifact from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Input shapes (empty vec = scalar).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.txt` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut artifacts = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                return Err(anyhow!("bad manifest line: `{line}`"));
            }
            let parse_shapes = |s: &str| -> Result<Vec<Vec<usize>>> {
                if s.trim().is_empty() {
                    return Ok(vec![]);
                }
                s.split(',')
                    .map(|sh| {
                        if sh == "scalar" {
                            Ok(vec![])
                        } else {
                            sh.split('x')
                                .map(|d| d.parse::<usize>().map_err(|e| anyhow!("{e}")))
                                .collect()
                        }
                    })
                    .collect()
            };
            artifacts.push(ArtifactMeta {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                inputs: parse_shapes(parts[2])?,
                outputs: parse_shapes(parts[3])?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled PJRT executable plus its metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on f64 buffers. Inputs must match the manifest shapes.
    pub fn run(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "artifact `{}`: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (k, (buf, shape)) in inputs.iter().zip(self.meta.inputs.iter()).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(anyhow!(
                    "artifact `{}` input {k}: expected {want} elements, got {}",
                    self.meta.name,
                    buf.len()
                ));
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(if dims.is_empty() { lit } else { lit.reshape(&dims)? });
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True.
        let tuple = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f64>()?);
        }
        Ok(out)
    }
}

/// The PJRT client + executable cache (compile once per artifact).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: std::sync::Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// CPU-backed runtime over an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            manifest: Manifest::load(artifacts_dir)?,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile (cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact `{name}` in manifest"))?
            .clone();
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let arc = Arc::new(Executable { meta, exe });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }
}

/// Smoke helper used by the CLI: run the matmul demo from /opt/xla-example.
pub fn smoke(path: &str) -> Result<Vec<f64>> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = xla::Literal::vec1(&[1f32, 2f32, 3f32, 4f32]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1f32, 1f32, 1f32]).reshape(&[2, 2])?;
    let result = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    Ok(out.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect())
}

/// Locate the artifacts directory (./artifacts or $HFAV_ARTIFACTS).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("HFAV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.txt").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("laplace_fused").is_some());
        assert!(m.get("hydro_unfused").is_some());
        let h = m.get("hydro_fused").unwrap();
        assert_eq!(h.inputs.len(), 5);
        assert_eq!(h.outputs.len(), 4);
        assert!(m.get("nonexistent").is_none());
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("hfav-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "not|enough|parts\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pjrt_laplace_artifacts_match_native() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = Runtime::cpu(&dir).unwrap();
        let (nj, ni) = (512usize, 512usize);
        let u = crate::apps::seeded(nj * ni, 9);
        let fused = rt.load("laplace_fused").unwrap();
        let unfused = rt.load("laplace_unfused").unwrap();
        let a = fused.run(&[&u]).unwrap();
        let b = unfused.run(&[&u]).unwrap();
        let want = crate::apps::laplace::reference(&u, nj, ni);
        assert_eq!(a[0].len(), want.len());
        assert!(crate::apps::max_err(&a[0], &want) < 1e-12, "pallas vs rust ref");
        assert!(crate::apps::max_err(&b[0], &want) < 1e-12, "jnp vs rust ref");
        // cache hit path
        let again = rt.load("laplace_fused").unwrap();
        assert_eq!(again.meta.name, "laplace_fused");
    }

    #[test]
    fn pjrt_hydro_artifact_matches_rust() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        use crate::apps::hydro2d::solver::{pad, sod, RefSweeper, Sweeper};
        let rt = Runtime::cpu(&dir).unwrap();
        let exe = rt.load("hydro_unfused").unwrap();
        let (rows, n) = (exe.meta.inputs[0][0], exe.meta.inputs[0][1] - 4);
        let s = sod(n, rows);
        let rho = pad(&s.rho, rows, n, false);
        let rhou = pad(&s.rhou, rows, n, true);
        let rhov = pad(&s.rhov, rows, n, false);
        let e = pad(&s.e, rows, n, false);
        let dtdx = [0.1f64];
        let out = exe.run(&[&rho, &rhou, &rhov, &e, &dtdx]).unwrap();
        let mut rs = RefSweeper;
        let want = rs.sweep(&rho, &rhou, &rhov, &e, 0.1, rows, n).unwrap();
        for k in 0..4 {
            assert!(
                crate::apps::max_err(&out[k], &want[k]) < 1e-11,
                "field {k}: {}",
                crate::apps::max_err(&out[k], &want[k])
            );
        }
    }
}
