//! PJRT runtime surface: manifest + artifact metadata for AOT-compiled
//! HLO text artifacts (produced once by `python/compile/aot.py`), and the
//! runtime/executable API the coordinator's PJRT engine drives.
//!
//! The actual XLA/PJRT client requires the external `xla_extension`
//! native toolchain (the `xla` crate), which is not part of this
//! hermetic, dependency-free build. The manifest layer — the stable
//! interchange contract — is fully implemented and tested here; the
//! execution entry points ([`Runtime::cpu`], [`smoke`]) return a clear
//! "backend unavailable" error until the toolchain is vendored back in
//! (tracked in README §PJRT). Callers (coordinator, bench) are written to
//! degrade gracefully on that error, so serving traffic on the exec and
//! native engines is unaffected.

use std::path::{Path, PathBuf};

/// The error every execution entry point returns in this build.
pub const PJRT_UNAVAILABLE: &str =
    "PJRT backend unavailable: built without the external `xla` toolchain (see README §PJRT)";

/// Shape signature of one artifact from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Input shapes (empty vec = scalar).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.txt` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let mut artifacts = Vec::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                return Err(format!("bad manifest line: `{line}`"));
            }
            let parse_shapes = |s: &str| -> Result<Vec<Vec<usize>>, String> {
                if s.trim().is_empty() {
                    return Ok(vec![]);
                }
                s.split(',')
                    .map(|sh| {
                        if sh == "scalar" {
                            Ok(vec![])
                        } else {
                            sh.split('x')
                                .map(|d| d.parse::<usize>().map_err(|e| format!("{e}")))
                                .collect()
                        }
                    })
                    .collect()
            };
            artifacts.push(ArtifactMeta {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                inputs: parse_shapes(parts[2])?,
                outputs: parse_shapes(parts[3])?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled PJRT executable plus its metadata (stub: metadata only).
pub struct Executable {
    pub meta: ArtifactMeta,
}

impl Executable {
    /// Execute on f64 buffers. Inputs must match the manifest shapes.
    pub fn run(&self, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>, String> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(format!(
                "artifact `{}`: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            ));
        }
        Err(PJRT_UNAVAILABLE.to_string())
    }
}

/// The PJRT client + executable cache (compile once per artifact).
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// CPU-backed runtime over an artifacts directory. Fails in this
    /// build: the XLA client is not linked (see [`PJRT_UNAVAILABLE`]).
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime, String> {
        // Validate the manifest first so configuration errors surface as
        // themselves, not as the generic backend error.
        let manifest = Manifest::load(artifacts_dir)?;
        let _ = Runtime { manifest };
        Err(PJRT_UNAVAILABLE.to_string())
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile (cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>, String> {
        self.manifest
            .get(name)
            .ok_or_else(|| format!("no artifact `{name}` in manifest"))?;
        Err(PJRT_UNAVAILABLE.to_string())
    }
}

/// Smoke helper used by the CLI: run an HLO-text module. Stubbed.
pub fn smoke(path: &str) -> Result<Vec<f64>, String> {
    if !Path::new(path).exists() {
        return Err(format!("no HLO file at `{path}`"));
    }
    Err(PJRT_UNAVAILABLE.to_string())
}

/// Locate the artifacts directory (./artifacts or $HFAV_ARTIFACTS).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("HFAV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("manifest.txt").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("laplace_fused").is_some());
        assert!(m.get("hydro_unfused").is_some());
        let h = m.get("hydro_fused").unwrap();
        assert_eq!(h.inputs.len(), 5);
        assert_eq!(h.outputs.len(), 4);
        assert!(m.get("nonexistent").is_none());
    }

    #[test]
    fn manifest_roundtrip_from_text() {
        let dir = std::env::temp_dir().join(format!("hfav-man-ok-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "laplace_fused|laplace_fused.hlo.txt|512x512|510x510\n\
             hydro_fused|hydro_fused.hlo.txt|8x36,8x36,8x36,8x36,scalar|8x32,8x32,8x32,8x32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.get("laplace_fused").unwrap().inputs, vec![vec![512, 512]]);
        let h = m.get("hydro_fused").unwrap();
        assert_eq!(h.inputs.len(), 5);
        assert_eq!(h.inputs[4], Vec::<usize>::new());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("hfav-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "not|enough|parts\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn runtime_reports_unavailable_backend() {
        let dir = std::env::temp_dir().join(format!("hfav-rt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "a|a.hlo.txt|2x2|2x2\n").unwrap();
        let err = Runtime::cpu(&dir).unwrap_err();
        assert!(err.contains("PJRT backend unavailable"), "{err}");
        // A missing manifest is reported as such, not as the backend error.
        let err = Runtime::cpu(dir.join("nope")).unwrap_err();
        assert!(err.contains("reading"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
