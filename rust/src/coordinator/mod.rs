//! Job coordinator: the serving substrate. A leader/worker runtime that
//! dispatches grid-update jobs to the available engines (interpreter
//! executor, compiled-C native modules, PJRT executables) on top of a
//! **shared compiled-plan cache** ([`crate::plan::cache`]): each distinct
//! `(app, variant, options)` key is compiled exactly once for the whole
//! pool, and the resulting `Arc<Program>` (and `Arc<NativeModule>`) is
//! shared across workers. `run_batch` groups same-key jobs so consecutive
//! runs on a worker reuse its executor buffer workspace, and
//! [`metrics`] aggregates latency, throughput and cache counters.
//!
//! The paper's contribution is the *generator*; the coordinator is the
//! driver that makes the generated artifacts deployable: compile once,
//! serve many requests, never touch Python.

pub mod metrics;

pub use self::metrics::{Metrics, ServeReport};

use crate::apps::{self, Variant};
use crate::codegen::native::NativeModule;
use crate::exec;
use crate::plan::cache::{OnceMap, PlanCache, PlanKey};
use crate::plan::Program;
use crate::runtime::Runtime;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine executes a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Engine {
    /// Interpreter executor over the HFAV schedule.
    Exec,
    /// Generated C compiled with the system compiler, dlopen'd.
    Native,
    /// AOT JAX/Pallas artifact on the PJRT CPU client.
    Pjrt,
}

impl std::str::FromStr for Engine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "exec" => Ok(Engine::Exec),
            "native" => Ok(Engine::Native),
            "pjrt" => Ok(Engine::Pjrt),
            _ => Err(format!("unknown engine `{s}` (exec|native|pjrt)")),
        }
    }
}

/// A grid-update job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    /// `laplace` | `normalize` | `cosmo` | `hydro2d`
    pub app: String,
    pub variant: Variant,
    pub engine: Engine,
    /// Problem size (per side).
    pub size: usize,
    /// Number of repeated applications (time steps / sweeps).
    pub steps: usize,
    /// Vector-length override: `None` = deck default, `Some(n)` forces
    /// `n` lanes (`Some(1)` forces scalar). Folded into the plan-cache
    /// fingerprint, so distinct vlens compile (and cache) separately.
    pub vlen: Option<usize>,
}

impl Job {
    /// The plan-cache key this job compiles under.
    pub fn plan_key(&self) -> PlanKey {
        plan_key(&self.app, self.variant, self.vlen)
    }
}

/// Result of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub ok: bool,
    pub detail: String,
    pub latency: Duration,
    /// Cell-updates per second achieved.
    pub cups: f64,
    pub checksum: f64,
}

/// Key for the plan cache: app + variant label + options fingerprint
/// (which folds in the vector-length override).
fn plan_key(app: &str, variant: Variant, vlen: Option<usize>) -> PlanKey {
    PlanKey::new(app, variant.label(), &apps::variant_options_vlen(variant, vlen))
}

/// Depth of the cosmo 3-D grid served by the coordinator (the `Nk`
/// extent `Worker::run_stencil` passes and `cells_per_step` accounts).
const COSMO_NK: i64 = 4;

/// Grid cells one application of `job` updates. cosmo runs a 3-D grid
/// ([`COSMO_NK`] planes); the others are 2-D.
fn cells_per_step(job: &Job) -> u64 {
    let planes = if job.app == "cosmo" { COSMO_NK as u64 } else { 1 };
    planes * (job.size * job.size) as u64
}

/// Same-key batching: jobs agreeing on this tuple run back-to-back on one
/// worker, so its plan lookup is hot and its executor workspace buffers
/// fit without reallocation.
type BatchKey = (String, Variant, Engine, usize, Option<usize>);

fn batch_key(job: &Job) -> BatchKey {
    (job.app.clone(), job.variant, job.engine, job.size, job.vlen)
}

enum Msg {
    Run(Job, mpsc::Sender<JobResult>),
    RunBatch(Vec<(usize, Job)>, mpsc::Sender<(usize, JobResult)>),
    Stop,
}

/// The coordinator: owns the worker pool and the shared caches.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    workers: Vec<std::thread::JoinHandle<()>>,
    nworkers: usize,
    pub metrics: Arc<Metrics>,
    /// Shared compiled-plan cache: one compile per distinct key, pool-wide.
    pub plans: Arc<PlanCache>,
    /// Shared native-module cache (generated C → cc → dlopen, once).
    pub natives: Arc<OnceMap<PlanKey, NativeModule>>,
}

impl Coordinator {
    /// Start `nworkers` workers with a fresh plan cache. `artifacts_dir`
    /// may be None (PJRT jobs will then fail gracefully).
    pub fn start(nworkers: usize, artifacts_dir: Option<std::path::PathBuf>) -> Coordinator {
        Coordinator::start_with_cache(nworkers, artifacts_dir, Arc::new(PlanCache::new()))
    }

    /// Start with an externally shared plan cache (e.g. kept warm across
    /// coordinator restarts or shared with an embedding process).
    pub fn start_with_cache(
        nworkers: usize,
        artifacts_dir: Option<std::path::PathBuf>,
        plans: Arc<PlanCache>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let natives: Arc<OnceMap<PlanKey, NativeModule>> = Arc::new(OnceMap::new());
        let mut workers = Vec::new();
        let nworkers = nworkers.max(1);
        for wid in 0..nworkers {
            let rx = rx.clone();
            // PJRT clients are not Send: each worker owns its own runtime,
            // created lazily (inside its thread) on the first PJRT job.
            let artifacts = artifacts_dir.clone();
            let plans = plans.clone();
            let natives = natives.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                let mut worker = Worker::new(wid, artifacts, plans, natives, metrics);
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job, reply)) => {
                            let res = worker.process(&job);
                            let _ = reply.send(res);
                        }
                        Ok(Msg::RunBatch(batch, reply)) => {
                            for (slot, job) in batch {
                                let res = worker.process(&job);
                                let _ = reply.send((slot, res));
                            }
                        }
                        Ok(Msg::Stop) | Err(_) => break,
                    }
                }
            }));
        }
        Coordinator { tx, workers, nworkers, metrics, plans, natives }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit(&self, job: Job) -> mpsc::Receiver<JobResult> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Run(job, rtx)).expect("coordinator stopped");
        rrx
    }

    /// Submit a batch and wait for all results (in input order).
    ///
    /// Dynamic batching: jobs sharing a [`BatchKey`] are grouped so one
    /// worker runs them consecutively against its warm workspace; groups
    /// larger than `len/nworkers` are chunked so a single hot key still
    /// spreads across the pool. Distinct plans are compiled exactly once
    /// regardless of grouping (the plan cache is pool-wide).
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let mut groups: BTreeMap<BatchKey, Vec<(usize, Job)>> = BTreeMap::new();
        for (slot, job) in jobs.into_iter().enumerate() {
            groups.entry(batch_key(&job)).or_default().push((slot, job));
        }
        let (rtx, rrx) = mpsc::channel::<(usize, JobResult)>();
        for mut group in groups.into_values() {
            let chunk = group.len().div_ceil(self.nworkers).max(1);
            while !group.is_empty() {
                let rest = group.split_off(chunk.min(group.len()));
                let batch = std::mem::replace(&mut group, rest);
                self.tx.send(Msg::RunBatch(batch, rtx.clone())).expect("coordinator stopped");
            }
        }
        drop(rtx);
        let mut out: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (slot, res) = rrx.recv().expect("worker died");
            out[slot] = Some(res);
        }
        out.into_iter().map(|r| r.expect("missing result")).collect()
    }

    /// Snapshot job metrics + cache counters over a measured wall time.
    ///
    /// All counters are cumulative over the coordinator's lifetime, so
    /// `wall` must cover everything served so far (time the coordinator,
    /// not the last batch) or the throughput figure will be inflated.
    pub fn report(&self, wall: Duration) -> ServeReport {
        ServeReport {
            completed: self.metrics.completed.load(Ordering::Relaxed),
            failed: self.metrics.failed.load(Ordering::Relaxed),
            p50: self.metrics.percentile(0.5),
            p95: self.metrics.percentile(0.95),
            total_cells: self.metrics.total_cells.load(Ordering::Relaxed),
            wall,
            plans: self.plans.stats(),
            natives: self.natives.stats(),
            buffers_reused: self.metrics.buffers_reused.load(Ordering::Relaxed),
            buffers_allocated: self.metrics.buffers_allocated.load(Ordering::Relaxed),
            vlen_min: self.metrics.vlen_min.load(Ordering::Relaxed),
            vlen_max: self.metrics.vlen_max.load(Ordering::Relaxed),
        }
    }

    pub fn shutdown(mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-worker state. Plans and native modules live in the pool-shared
/// caches; the worker owns only its (non-Send) PJRT runtime and its
/// executor buffer workspace.
struct Worker {
    #[allow(dead_code)]
    id: usize,
    artifacts: Option<std::path::PathBuf>,
    runtime: Option<Runtime>,
    /// First runtime-creation failure, replayed for later PJRT jobs.
    runtime_err: Option<String>,
    plans: Arc<PlanCache>,
    natives: Arc<OnceMap<PlanKey, NativeModule>>,
    metrics: Arc<Metrics>,
    ws: exec::Workspace,
    /// Cached hydro2d interpreter sweepers (plan Arc + warm workspace),
    /// one per variant, so batched hydro Exec jobs reuse buffers too.
    exec_sweepers: BTreeMap<PlanKey, crate::apps::hydro2d::solver::ExecSweeper>,
    flushed_reused: u64,
    flushed_allocated: u64,
}

impl Worker {
    fn new(
        id: usize,
        artifacts: Option<std::path::PathBuf>,
        plans: Arc<PlanCache>,
        natives: Arc<OnceMap<PlanKey, NativeModule>>,
        metrics: Arc<Metrics>,
    ) -> Worker {
        Worker {
            id,
            artifacts,
            runtime: None,
            runtime_err: None,
            plans,
            natives,
            metrics,
            ws: exec::Workspace::new(),
            exec_sweepers: BTreeMap::new(),
            flushed_reused: 0,
            flushed_allocated: 0,
        }
    }

    /// Monotonic buffer counters across this worker's workspaces (the
    /// stencil workspace plus every cached hydro sweeper's).
    fn ws_totals(&self) -> (u64, u64) {
        let mut reused = self.ws.reused;
        let mut allocated = self.ws.allocated;
        for s in self.exec_sweepers.values() {
            reused += s.ws.reused;
            allocated += s.ws.allocated;
        }
        (reused, allocated)
    }

    /// Lazily create this worker's PJRT runtime (clients are not Send).
    /// Failures are remembered so a trace full of PJRT jobs fails each one
    /// cheaply instead of re-reading the manifest per job.
    fn runtime(&mut self) -> Result<&Runtime, String> {
        if let Some(e) = &self.runtime_err {
            return Err(e.clone());
        }
        if self.runtime.is_none() {
            let made = self
                .artifacts
                .clone()
                .ok_or_else(|| "no artifacts dir — PJRT unavailable".to_string())
                .and_then(Runtime::cpu);
            match made {
                Ok(rt) => self.runtime = Some(rt),
                Err(e) => {
                    self.runtime_err = Some(e.clone());
                    return Err(e);
                }
            }
        }
        Ok(self.runtime.as_ref().unwrap())
    }

    fn prog(
        &self,
        app: &str,
        variant: Variant,
        vlen: Option<usize>,
    ) -> Result<Arc<Program>, String> {
        let deck = deck_of(app)?;
        let key = plan_key(app, variant, vlen);
        self.plans.get_or_compile(&key, || apps::compile_variant_vlen(deck, variant, vlen))
    }

    fn native(
        &self,
        app: &str,
        variant: Variant,
        vlen: Option<usize>,
    ) -> Result<Arc<NativeModule>, String> {
        let prog = self.prog(app, variant, vlen)?;
        let key = plan_key(app, variant, vlen).tagged("native");
        // Retrying variant: a cc/dlopen failure may be transient (tmpdir
        // full, compiler hiccup) and must not poison the key pool-wide.
        self.natives
            .get_or_compute_retrying(&key, || {
                crate::codegen::native::build(&prog, &Default::default())
            })
    }

    /// Run one job: execute, record metrics, flush workspace counters.
    fn process(&mut self, job: &Job) -> JobResult {
        let cells = cells_per_step(job) * job.steps.max(1) as u64;
        let res = self.run(job);
        self.metrics.record(&res, cells);
        let (reused, allocated) = self.ws_totals();
        let dr = reused - self.flushed_reused;
        let da = allocated - self.flushed_allocated;
        self.flushed_reused = reused;
        self.flushed_allocated = allocated;
        self.metrics.buffers_reused.fetch_add(dr, Ordering::Relaxed);
        self.metrics.buffers_allocated.fetch_add(da, Ordering::Relaxed);
        res
    }

    fn run(&mut self, job: &Job) -> JobResult {
        let start = Instant::now();
        let out = self.dispatch(job);
        let latency = start.elapsed();
        match out {
            Ok(checksum) => {
                let cells = (cells_per_step(job) * job.steps.max(1) as u64) as f64;
                JobResult {
                    id: job.id,
                    ok: true,
                    detail: String::new(),
                    latency,
                    cups: cells / latency.as_secs_f64(),
                    checksum,
                }
            }
            Err(e) => JobResult {
                id: job.id,
                ok: false,
                detail: e,
                latency,
                cups: 0.0,
                checksum: 0.0,
            },
        }
    }

    fn dispatch(&mut self, job: &Job) -> Result<f64, String> {
        match job.app.as_str() {
            "hydro2d" => self.run_hydro(job),
            "laplace" | "normalize" | "cosmo" => self.run_stencil(job),
            other => Err(format!("unknown app `{other}`")),
        }
    }

    fn run_hydro(&mut self, job: &Job) -> Result<f64, String> {
        use crate::apps::hydro2d::solver::*;
        let n = job.size;
        let mut state = sod(n, n);
        if job.engine != Engine::Pjrt {
            let vl = self.prog("hydro2d", job.variant, job.vlen)?.vector_len();
            self.metrics.record_vlen(vl);
        }
        let mut native_sweeper;
        let sweeper: &mut dyn Sweeper = match job.engine {
            Engine::Exec => {
                // Per-worker cached sweeper: shared plan Arc + a workspace
                // that stays warm across batched same-key jobs.
                let key = plan_key("hydro2d", job.variant, job.vlen)
                    .with_exec(&crate::exec::ExecOptions::default());
                if !self.exec_sweepers.contains_key(&key) {
                    let s = ExecSweeper::new(self.prog("hydro2d", job.variant, job.vlen)?);
                    self.exec_sweepers.insert(key.clone(), s);
                }
                self.exec_sweepers.get_mut(&key).unwrap()
            }
            Engine::Native => {
                let m = self.native("hydro2d", job.variant, job.vlen)?;
                native_sweeper = SharedNativeSweeper { module: m };
                &mut native_sweeper
            }
            Engine::Pjrt => {
                return Err("hydro2d PJRT path requires fixed artifact shape; use bench pjrt".into())
            }
        };
        for _ in 0..job.steps {
            step(&mut state, 1.0 / n as f64, 0.4, sweeper)?;
        }
        Ok(state.rho.iter().sum())
    }

    fn run_stencil(&mut self, job: &Job) -> Result<f64, String> {
        let n = job.size;
        let (reg, extents, input_name): (_, Vec<(&str, i64)>, &str) = match job.app.as_str() {
            "laplace" => (
                crate::apps::laplace::registry(),
                vec![("Nj", n as i64), ("Ni", n as i64)],
                "g_cell",
            ),
            "normalize" => (
                crate::apps::normalization::registry(),
                vec![("Nj", n as i64), ("Ni", n as i64)],
                "g_q",
            ),
            "cosmo" => (
                crate::apps::cosmo::registry(),
                vec![("Nk", COSMO_NK), ("Nj", n as i64), ("Ni", n as i64)],
                "g_u",
            ),
            _ => unreachable!(),
        };
        let prog = self.prog(&job.app, job.variant, job.vlen)?;
        if job.engine != Engine::Pjrt {
            // PJRT runs fixed pre-built artifacts; the compiled plan's
            // vector length says nothing about what it executes.
            self.metrics.record_vlen(prog.vector_len());
        }
        let ext: BTreeMap<String, i64> =
            extents.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        let len = crate::exec::external_len(&prog, input_name, &ext)?;
        let mut inputs = BTreeMap::new();
        inputs.insert(input_name.to_string(), apps::seeded(len, job.id));
        let mut checksum = 0.0;
        match job.engine {
            Engine::Exec => {
                for _ in 0..job.steps.max(1) {
                    let out = crate::exec::run_with(
                        &prog,
                        &reg,
                        &ext,
                        &inputs,
                        Default::default(),
                        &mut self.ws,
                    )?;
                    checksum = out.values().next().map(|v| v.iter().sum()).unwrap_or(0.0);
                }
            }
            Engine::Native => {
                let m = self.native(&job.app, job.variant, job.vlen)?;
                let mut arrays = inputs.clone();
                for name in &m.externals {
                    arrays.entry(name.clone()).or_insert_with(|| {
                        vec![0.0; crate::exec::external_len(&prog, name, &ext).unwrap_or(0)]
                    });
                }
                for _ in 0..job.steps.max(1) {
                    m.run(&ext, &mut arrays)?;
                }
                checksum = arrays
                    .iter()
                    .filter(|(k, _)| !inputs.contains_key(*k))
                    .map(|(_, v)| v.iter().sum::<f64>())
                    .sum();
            }
            Engine::Pjrt => {
                let rt = self.runtime()?;
                let variant = if job.variant == Variant::Hfav { "fused" } else { "unfused" };
                let name = format!(
                    "{}_{}",
                    if job.app == "normalize" { "normalize" } else { job.app.as_str() },
                    variant
                );
                let exe = rt.load(&name)?;
                // PJRT artifacts are fixed-shape; synthesize matching input.
                let shapes = exe.meta.inputs.clone();
                let bufs: Vec<Vec<f64>> = shapes
                    .iter()
                    .map(|s| apps::seeded(s.iter().product(), job.id))
                    .collect();
                let refs: Vec<&[f64]> = bufs.iter().map(|b| b.as_slice()).collect();
                for _ in 0..job.steps.max(1) {
                    let out = exe.run(&refs)?;
                    checksum = out[0].iter().sum();
                }
            }
        }
        Ok(checksum)
    }
}

/// Native sweeper over a shared module (coordinator cache).
struct SharedNativeSweeper {
    module: Arc<NativeModule>,
}

impl crate::apps::hydro2d::solver::Sweeper for SharedNativeSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), rows as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_rho".to_string(), rho.to_vec());
        arrays.insert("g_rhou".to_string(), rhou.to_vec());
        arrays.insert("g_rhov".to_string(), rhov.to_vec());
        arrays.insert("g_E".to_string(), e.to_vec());
        arrays.insert("g_dtdx".to_string(), vec![dtdx]);
        for name in ["g_nrho", "g_nrhou", "g_nrhov", "g_nE"] {
            arrays.insert(name.to_string(), vec![0.0; rows * n]);
        }
        self.module.run(&ext, &mut arrays)?;
        Ok([
            arrays.remove("g_nrho").unwrap(),
            arrays.remove("g_nrhou").unwrap(),
            arrays.remove("g_nrhov").unwrap(),
            arrays.remove("g_nE").unwrap(),
        ])
    }

    fn name(&self) -> &'static str {
        "hfav-native-shared"
    }
}

/// Deck lookup for the built-in apps.
pub fn deck_of(app: &str) -> Result<&'static str, String> {
    match app {
        "laplace" => Ok(crate::apps::laplace::DECK),
        "normalize" => Ok(crate::apps::normalization::DECK),
        "cosmo" => Ok(crate::apps::cosmo::DECK),
        "hydro2d" => Ok(crate::apps::hydro2d::DECK),
        _ => Err(format!("unknown app `{app}` (laplace|normalize|cosmo|hydro2d)")),
    }
}

/// Expand a job template `repeat` times, assigning fresh sequential ids
/// (the id seeds each job's synthetic input, so repeats stay distinct).
pub fn repeat_jobs(template: &[Job], repeat: usize) -> Vec<Job> {
    let mut out = Vec::with_capacity(template.len() * repeat.max(1));
    for r in 0..repeat.max(1) {
        for (i, j) in template.iter().enumerate() {
            let mut job = j.clone();
            job.id = (r * template.len() + i) as u64;
            out.push(job);
        }
    }
    out
}

/// Number of distinct plan-cache keys a job list compiles under — the
/// expected pipeline-compilation count for a cold cache.
pub fn distinct_plan_keys(jobs: &[Job]) -> usize {
    jobs.iter().map(|j| j.plan_key()).collect::<std::collections::BTreeSet<_>>().len()
}

/// Parse a job-trace line: `app,variant,engine,size,steps[,vlen]`. The
/// optional sixth field forces a vector length for that job (`-` or
/// `deck` keeps the deck default, like omitting it).
pub fn parse_trace_line(id: u64, line: &str) -> Result<Job, String> {
    let f: Vec<&str> = line.split(',').map(str::trim).collect();
    if f.len() != 5 && f.len() != 6 {
        return Err(format!("bad trace line `{line}` (app,variant,engine,size,steps[,vlen])"));
    }
    let variant = match f[1] {
        "hfav" => Variant::Hfav,
        "autovec" => Variant::Autovec,
        other => return Err(format!("unknown variant `{other}`")),
    };
    let vlen = match f.get(5) {
        None => None,
        Some(&"-") | Some(&"deck") => None,
        Some(v) => {
            let n: usize = v.parse().map_err(|e| format!("vlen: {e}"))?;
            if n == 0 {
                return Err("vlen must be >= 1".to_string());
            }
            Some(n)
        }
    };
    Ok(Job {
        id,
        app: f[0].to_string(),
        variant,
        engine: f[2].parse()?,
        size: f[3].parse().map_err(|e| format!("size: {e}"))?,
        steps: f[4].parse().map_err(|e| format!("steps: {e}"))?,
        vlen,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_runs_mixed_batch() {
        let c = Coordinator::start(2, None);
        let mk = |id: u64, app: &str, variant: Variant, engine: Engine, size: usize, steps| Job {
            id,
            app: app.to_string(),
            variant,
            engine,
            size,
            steps,
            vlen: None,
        };
        let jobs = vec![
            mk(1, "laplace", Variant::Hfav, Engine::Exec, 64, 1),
            mk(2, "normalize", Variant::Autovec, Engine::Exec, 48, 1),
            mk(3, "hydro2d", Variant::Hfav, Engine::Exec, 16, 2),
            mk(4, "laplace", Variant::Hfav, Engine::Native, 64, 2),
        ];
        let results = c.run_batch(jobs);
        assert_eq!(results.len(), 4);
        for (k, r) in results.iter().enumerate() {
            assert_eq!(r.id, k as u64 + 1, "results must preserve input order");
            assert!(r.ok, "job {} failed: {}", r.id, r.detail);
            assert!(r.cups > 0.0);
        }
        assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(c.metrics.percentile(0.5) > Duration::ZERO);
        // 3 distinct plan keys: laplace/hfav (shared by exec+native),
        // normalize/autovec, hydro2d/hfav.
        assert_eq!(c.plans.stats().computes, 3, "{}", c.plans.stats());
        assert_eq!(c.natives.stats().computes, 1, "{}", c.natives.stats());
        c.shutdown();
    }

    #[test]
    fn coordinator_reports_failures() {
        let c = Coordinator::start(1, None);
        let r = c
            .submit(Job {
                id: 9,
                app: "nope".into(),
                variant: Variant::Hfav,
                engine: Engine::Exec,
                size: 8,
                steps: 1,
                vlen: None,
            })
            .recv()
            .unwrap();
        assert!(!r.ok);
        assert!(r.detail.contains("unknown app"));
        c.shutdown();
    }

    #[test]
    fn repeated_jobs_hit_the_plan_cache() {
        let c = Coordinator::start(4, None);
        let jobs: Vec<Job> = (0..12)
            .map(|i| Job {
                id: i,
                app: "laplace".into(),
                variant: Variant::Hfav,
                engine: Engine::Exec,
                size: 32,
                steps: 1,
                vlen: None,
            })
            .collect();
        let results = c.run_batch(jobs);
        assert!(results.iter().all(|r| r.ok));
        let s = c.plans.stats();
        assert_eq!(s.computes, 1, "one key → one compile: {s}");
        assert!(s.hits >= 11 - 3, "most lookups must hit: {s}");
        let rep = c.report(Duration::from_secs(1));
        assert_eq!(rep.completed, 12);
        assert!(rep.buffers_reused > 0, "{rep}");
        c.shutdown();
    }

    #[test]
    fn trace_parsing() {
        let j = parse_trace_line(5, "hydro2d, hfav, native, 128, 10").unwrap();
        assert_eq!(j.app, "hydro2d");
        assert_eq!(j.engine, Engine::Native);
        assert_eq!(j.size, 128);
        assert_eq!(j.vlen, None);
        let v = parse_trace_line(6, "hydro2d, hfav, native, 128, 10, 8").unwrap();
        assert_eq!(v.vlen, Some(8));
        let d = parse_trace_line(7, "laplace, hfav, exec, 64, 1, -").unwrap();
        assert_eq!(d.vlen, None);
        assert!(parse_trace_line(0, "bad line").is_err());
        assert!(parse_trace_line(0, "a,b,c,d,e").is_err());
        assert!(parse_trace_line(0, "laplace, hfav, exec, 64, 1, 0").is_err());
    }

    #[test]
    fn distinct_vlens_get_distinct_plan_entries() {
        // Same id → same seeded input, so checksums are comparable.
        let mk = |vlen: Option<usize>| Job {
            id: 7,
            app: "laplace".into(),
            variant: Variant::Hfav,
            engine: Engine::Exec,
            size: 32,
            steps: 1,
            vlen,
        };
        let jobs = vec![mk(None), mk(Some(1)), mk(Some(4)), mk(Some(8)), mk(Some(4))];
        assert_eq!(distinct_plan_keys(&jobs), 4, "None, 1, 4, 8");
        let c = Coordinator::start(2, None);
        let results = c.run_batch(jobs);
        assert!(results.iter().all(|r| r.ok), "{results:?}");
        // Same inputs, same math → identical checksums across vlens.
        for r in &results[1..] {
            assert_eq!(r.checksum, results[0].checksum, "vlen changed results");
        }
        assert_eq!(c.plans.stats().computes, 4, "{}", c.plans.stats());
        let rep = c.report(Duration::from_millis(1));
        assert_eq!(rep.vlen_min, 1);
        assert_eq!(rep.vlen_max, 8);
        c.shutdown();
    }
}
