//! Job coordinator: the serving substrate. A leader/worker runtime that
//! dispatches grid-update jobs to the registered execution backends
//! ([`crate::engine`]) on top of a **shared compiled-plan cache**
//! ([`crate::plan::cache`]) and a **shared prepared-executable cache**:
//! each distinct [`PlanSpec`] fingerprint is compiled exactly once for
//! the whole pool, each `(plan, backend)` pair is prepared (cc/rustc +
//! dlopen, artifact resolution) exactly once, and the resulting
//! `Arc`-shared plans/executables serve every worker. `run_batch` groups
//! same-key jobs so consecutive runs on a worker reuse its executor
//! buffer workspace, and [`metrics`] aggregates latency, throughput and
//! cache counters.
//!
//! There is no per-engine dispatch here: jobs carry a backend *name*,
//! the [`engine::registry`] resolves it, and every engine — interpreter,
//! native C, generated Rust, PJRT — runs through the same
//! `Backend::prepare` / `Executable::run` path. Jobs may target built-in
//! apps or external deck files ([`target_spec`]).
//!
//! The paper's contribution is the *generator*; the coordinator is the
//! driver that makes the generated artifacts deployable: compile once,
//! serve many requests, never touch Python.

pub mod metrics;

pub use self::metrics::{Metrics, ServeReport};

use crate::apps::Variant;
use crate::engine::{self, Executable, PrepareCtx};
use crate::exec;
use crate::plan::cache::{OnceMap, PlanCache, PlanKey};
use crate::plan::{PlanSpec, Program, Vlen};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A grid-update job: *what* to compile ([`PlanSpec`]) plus *where* to
/// run it (a backend registry name) and the workload shape. Every
/// compile-relevant option lives inside the spec — the job cannot
/// express an option the plan-cache fingerprint does not cover.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    /// What to compile: deck target, variant, vector length, tuning.
    pub spec: PlanSpec,
    /// Execution backend, by [`engine::registry`] name
    /// (`exec` | `native` | `rust` | `pjrt`).
    pub backend: String,
    /// Problem size (per side).
    pub size: usize,
    /// Number of repeated applications (time steps / sweeps).
    pub steps: usize,
    /// Per-job extents override (trace v3 `extents=NxM[xK]` / CLI
    /// `--extents`): concrete values for the deck's extent parameters in
    /// sorted-name order (the generated code's `hfav_extents` order),
    /// replacing the square `size`-per-extent default. Compiled plans
    /// are shape-generic, so this affects *execution* (and the batch
    /// identity), never the plan-cache key.
    pub extents: Option<Vec<i64>>,
    /// Intra-job worker count for the plan's parallel chunk levels — a
    /// *runtime* knob ([`engine::RunConfig`]), deliberately outside both
    /// the [`PlanSpec`] and the plan/batch cache identities: one compiled
    /// plan serves every core count.
    pub threads: engine::Threads,
    /// The trace line said `variant=tuned`: serving should consult the
    /// tuned-plans DB ([`resolve_tuned`]) before dispatch. The spec
    /// already carries the heuristic `hfav+tuned` fallback knobs, so an
    /// unresolved request (no DB, no matching entry) serves correctly
    /// with no further handling — a miss is never an error.
    pub tuned_request: bool,
}

impl Job {
    pub fn new(id: u64, spec: PlanSpec, backend: &str, size: usize, steps: usize) -> Job {
        Job {
            id,
            spec,
            backend: backend.to_string(),
            size,
            steps,
            extents: None,
            threads: engine::Threads::Serial,
            tuned_request: false,
        }
    }

    /// Attach a per-job extents override (see [`Job::extents`]).
    pub fn with_extents(mut self, extents: Vec<i64>) -> Job {
        self.extents = Some(extents);
        self
    }

    /// Set the intra-job worker count (see [`Job::threads`]).
    pub fn with_threads(mut self, threads: engine::Threads) -> Job {
        self.threads = threads;
        self
    }

    /// The plan-cache key this job compiles under.
    pub fn plan_key(&self) -> PlanKey {
        self.spec.plan_key()
    }
}

/// Parse a trace/CLI extents override: `128x64x4` → `[128, 64, 4]`. The
/// values bind to the deck's extent parameters in sorted-name order —
/// e.g. cosmo's `Ni x Nj x Nk` — matching the `hfav_extents` string of
/// the generated code.
pub fn parse_extents(s: &str) -> Result<Vec<i64>, String> {
    let vals = s
        .split('x')
        .map(|p| {
            let v: i64 = p.trim().parse().map_err(|e| format!("extents `{s}`: {e}"))?;
            if v < 1 {
                return Err(format!("extents `{s}`: values must be >= 1"));
            }
            Ok(v)
        })
        .collect::<Result<Vec<i64>, String>>()?;
    if vals.is_empty() {
        return Err("empty extents override".to_string());
    }
    Ok(vals)
}

/// Result of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub ok: bool,
    pub detail: String,
    pub latency: Duration,
    /// Cell-updates per second achieved.
    pub cups: f64,
    pub checksum: f64,
}

/// Resolve a trace/CLI target string into a [`PlanSpec`]: a built-in
/// app name, or an external deck file — anything with a path separator
/// or a `.yaml`/`.yml` suffix (read eagerly, so missing files fail
/// here), plus any other name that exists as a file on disk.
pub fn target_spec(target: &str) -> Result<PlanSpec, String> {
    if crate::apps::deck_of(target).is_ok() {
        return Ok(PlanSpec::app(target));
    }
    if target.contains('/') || target.ends_with(".yaml") || target.ends_with(".yml") {
        return PlanSpec::deck_file(target);
    }
    if std::path::Path::new(target).is_file() {
        return PlanSpec::deck_file(target);
    }
    // Unknown bare name that is not a file: keep it as an app spec so it
    // fails at compile time with the canonical `unknown app` error.
    Ok(PlanSpec::app(target))
}

/// Depth of the cosmo 3-D grid served by the coordinator (the `Nk`
/// extent the grid driver passes for decks named `cosmo`).
const COSMO_NK: i64 = 4;

/// The concrete extent values a job runs at for a compiled program, in
/// sorted-name order (the generated code's `hfav_extents` order): the
/// trace-v3 override when present, else the square default the grid
/// driver applies (every extent = `job.size`, cosmo's `Nk` =
/// [`COSMO_NK`]). This is the single source of the default-shape rule —
/// the grid driver and tuned-plan shape classification both use it, so
/// the shape class a serve resolves against is exactly the shape the
/// job executes.
pub fn job_extents(job: &Job, prog: &Program) -> Result<Vec<i64>, String> {
    let names = crate::codegen::c99::extent_names(prog);
    match &job.extents {
        Some(vals) => {
            if vals.len() != names.len() {
                return Err(format!(
                    "extents override has {} values but deck `{}` takes {} ({})",
                    vals.len(),
                    prog.deck.name,
                    names.len(),
                    names.join("x")
                ));
            }
            Ok(vals.clone())
        }
        None => Ok(names
            .iter()
            .map(|name| {
                if prog.deck.name == "cosmo" && name == "Nk" {
                    COSMO_NK
                } else {
                    job.size as i64
                }
            })
            .collect()),
    }
}

/// Resolve a `variant=tuned` job against the tuned-plans DB, in place.
///
/// Returns `Ok(Some(label))` — a human-readable description of the
/// chosen knob set — when a DB entry matched the job's (deck digest,
/// shape class) and its knobs were applied to the job's spec (plus the
/// entry's worker count, unless the job already carries an explicit
/// [`engine::Threads`] request). Returns `Ok(None)` when the job is not
/// a tuned request or no entry matched — the spec keeps its heuristic
/// `hfav+tuned` fallback knobs, so a miss is never an error.
///
/// Resolution deliberately happens *outside* `PlanKey` construction, at
/// prepare time: the resolved spec fingerprints like any hand-written
/// spec, so one tuned entry maps onto the existing compiled-plan cache.
/// The fallback spec is compiled through the caller's shared `plans`
/// cache to learn the deck's extent names — on a miss, serving proceeds
/// on exactly that plan, so the compile is never wasted.
pub fn resolve_tuned(
    job: &mut Job,
    db: &crate::plan::tunedb::TunedDb,
    plans: &PlanCache,
) -> Result<Option<String>, String> {
    if !job.tuned_request {
        return Ok(None);
    }
    let key = job.spec.plan_key();
    let prog = plans.get_or_compile(&key, || job.spec.compile())?;
    let digest = crate::plan::tunedb::deck_digest(&job.spec)?;
    let vals = job_extents(job, &prog)?;
    let class = crate::plan::tunedb::ShapeClass::of(&vals);
    let entry = match db.lookup(digest, &class.label()) {
        Some(e) => e,
        None => return Ok(None),
    };
    job.spec = entry.apply(job.spec.clone())?;
    if matches!(job.threads, engine::Threads::Serial) && entry.threads > 1 {
        job.threads = engine::Threads::Fixed(entry.threads);
    }
    Ok(Some(format!("{} [{}]", entry.knob_label(), class.label())))
}

/// Same-key batching: jobs agreeing on this tuple run back-to-back on one
/// worker, so its plan lookup is hot and its executor workspace buffers
/// fit without reallocation. Extents are part of the identity — a
/// non-square job runs a different grid than a square job of the same
/// `size`, so grouping them would defeat the buffer-fit heuristic (the
/// *plan* key, by contrast, is shape-generic and shared).
pub type BatchKey = (PlanKey, String, usize, Vec<i64>);

/// The batching identity of a job (public so tests can pin the
/// fails-closed property: distinct extents → distinct batch identity,
/// same plan key).
pub fn batch_key(job: &Job) -> BatchKey {
    (job.plan_key(), job.backend.clone(), job.size, job.extents.clone().unwrap_or_default())
}

enum Msg {
    Run(Job, mpsc::Sender<JobResult>),
    RunBatch(Vec<(usize, Job)>, mpsc::Sender<(usize, JobResult)>),
    Stop,
}

/// The coordinator: owns the worker pool and the shared caches.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    workers: Vec<std::thread::JoinHandle<()>>,
    nworkers: usize,
    pub metrics: Arc<Metrics>,
    /// Shared compiled-plan cache: one compile per distinct key, pool-wide.
    pub plans: Arc<PlanCache>,
    /// Shared prepared-executable cache: one `Backend::prepare` per
    /// distinct `(plan key, backend)` pair, pool-wide — interpreter
    /// setups, compiled C/Rust modules, and PJRT artifact bindings all
    /// live here.
    pub prepared: Arc<OnceMap<PlanKey, Box<dyn Executable>>>,
}

impl Coordinator {
    /// Start `nworkers` workers with a fresh plan cache. `artifacts_dir`
    /// may be None (PJRT jobs will then fail gracefully).
    pub fn start(nworkers: usize, artifacts_dir: Option<std::path::PathBuf>) -> Coordinator {
        Coordinator::start_with_cache(nworkers, artifacts_dir, Arc::new(PlanCache::new()))
    }

    /// Start with an externally shared plan cache (e.g. kept warm across
    /// coordinator restarts or shared with an embedding process).
    pub fn start_with_cache(
        nworkers: usize,
        artifacts_dir: Option<std::path::PathBuf>,
        plans: Arc<PlanCache>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let prepared: Arc<OnceMap<PlanKey, Box<dyn Executable>>> = Arc::new(OnceMap::new());
        let mut workers = Vec::new();
        let nworkers = nworkers.max(1);
        for wid in 0..nworkers {
            let rx = rx.clone();
            let artifacts = artifacts_dir.clone();
            let plans = plans.clone();
            let prepared = prepared.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                let mut worker = Worker::new(wid, artifacts, plans, prepared, metrics);
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job, reply)) => {
                            let res = worker.process(&job);
                            let _ = reply.send(res);
                        }
                        Ok(Msg::RunBatch(batch, reply)) => {
                            for (slot, job) in batch {
                                let res = worker.process(&job);
                                let _ = reply.send((slot, res));
                            }
                        }
                        Ok(Msg::Stop) | Err(_) => break,
                    }
                }
            }));
        }
        Coordinator { tx, workers, nworkers, metrics, plans, prepared }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit(&self, job: Job) -> mpsc::Receiver<JobResult> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Run(job, rtx)).expect("coordinator stopped");
        rrx
    }

    /// Submit a batch and wait for all results (in input order).
    ///
    /// Dynamic batching: jobs sharing a [`BatchKey`] are grouped so one
    /// worker runs them consecutively against its warm workspace; groups
    /// larger than `len/nworkers` are chunked so a single hot key still
    /// spreads across the pool. Distinct plans are compiled exactly once
    /// regardless of grouping (the plan cache is pool-wide).
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let batch_start = Instant::now();
        let mut groups: BTreeMap<BatchKey, Vec<(usize, Job)>> = BTreeMap::new();
        for (slot, job) in jobs.into_iter().enumerate() {
            groups.entry(batch_key(&job)).or_default().push((slot, job));
        }
        let (rtx, rrx) = mpsc::channel::<(usize, JobResult)>();
        for mut group in groups.into_values() {
            let chunk = group.len().div_ceil(self.nworkers).max(1);
            while !group.is_empty() {
                let rest = group.split_off(chunk.min(group.len()));
                let batch = std::mem::replace(&mut group, rest);
                self.tx.send(Msg::RunBatch(batch, rtx.clone())).expect("coordinator stopped");
            }
        }
        drop(rtx);
        let mut out: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (slot, res) = rrx.recv().expect("worker died");
            out[slot] = Some(res);
        }
        self.metrics.record_batch(batch_start.elapsed());
        out.into_iter().map(|r| r.expect("missing result")).collect()
    }

    /// Snapshot job metrics + cache counters over a measured wall time.
    ///
    /// All counters are cumulative over the coordinator's lifetime, so
    /// `wall` must cover everything served so far (time the coordinator,
    /// not the last batch) or the throughput figure will be inflated.
    pub fn report(&self, wall: Duration) -> ServeReport {
        let pcts = self.metrics.percentiles(&[0.5, 0.95]);
        ServeReport {
            completed: self.metrics.completed.load(Ordering::Relaxed),
            failed: self.metrics.failed.load(Ordering::Relaxed),
            p50: pcts[0],
            p95: pcts[1],
            total_cells: self.metrics.total_cells.load(Ordering::Relaxed),
            wall,
            plans: self.plans.stats(),
            prepared: self.prepared.stats(),
            buffers_reused: self.metrics.buffers_reused.load(Ordering::Relaxed),
            buffers_allocated: self.metrics.buffers_allocated.load(Ordering::Relaxed),
            vlen_min: self.metrics.vlen_min.load(Ordering::Relaxed),
            vlen_max: self.metrics.vlen_max.load(Ordering::Relaxed),
            batches: self.metrics.batches.load(Ordering::Relaxed),
            batch_wall: Duration::from_micros(self.metrics.batch_wall_us.load(Ordering::Relaxed)),
            threads_effective: self.metrics.threads_max.load(Ordering::Relaxed),
        }
    }

    /// Stop the pool, draining in-flight work: each worker finishes its
    /// current job (and any intra-job parallel chunks — [`exec::pool`]
    /// scatter is synchronous, so chunks never outlive their job) before
    /// seeing the stop message, and every thread is joined.
    pub fn shutdown(self) {
        // Drop runs `stop()`; taking `self` by value keeps the explicit
        // call sites and makes "shut down" a move, not a method you can
        // call twice.
    }

    fn stop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    /// A dropped coordinator shuts down cleanly even without an explicit
    /// [`Coordinator::shutdown`] — no detached workers, no lost chunks.
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-worker state. Plans and prepared executables live in the
/// pool-shared caches; the worker owns only its executor buffer
/// workspace (and, transitively, any per-thread backend state).
struct Worker {
    #[allow(dead_code)]
    id: usize,
    artifacts: Option<std::path::PathBuf>,
    plans: Arc<PlanCache>,
    prepared: Arc<OnceMap<PlanKey, Box<dyn Executable>>>,
    metrics: Arc<Metrics>,
    ws: exec::Workspace,
    flushed_reused: u64,
    flushed_allocated: u64,
}

impl Worker {
    fn new(
        id: usize,
        artifacts: Option<std::path::PathBuf>,
        plans: Arc<PlanCache>,
        prepared: Arc<OnceMap<PlanKey, Box<dyn Executable>>>,
        metrics: Arc<Metrics>,
    ) -> Worker {
        Worker {
            id,
            artifacts,
            plans,
            prepared,
            metrics,
            ws: exec::Workspace::new(),
            flushed_reused: 0,
            flushed_allocated: 0,
        }
    }

    /// Run one job: execute, record metrics, flush workspace counters.
    fn process(&mut self, job: &Job) -> JobResult {
        let (res, cells) = self.run(job);
        self.metrics.record(&res, cells);
        let dr = self.ws.reused - self.flushed_reused;
        let da = self.ws.allocated - self.flushed_allocated;
        self.flushed_reused = self.ws.reused;
        self.flushed_allocated = self.ws.allocated;
        self.metrics.buffers_reused.fetch_add(dr, Ordering::Relaxed);
        self.metrics.buffers_allocated.fetch_add(da, Ordering::Relaxed);
        res
    }

    fn run(&mut self, job: &Job) -> (JobResult, u64) {
        let start = Instant::now();
        let out = self.dispatch(job);
        let latency = start.elapsed();
        match out {
            Ok((checksum, cells_per_step)) => {
                let cells = cells_per_step * job.steps.max(1) as u64;
                let res = JobResult {
                    id: job.id,
                    ok: true,
                    detail: String::new(),
                    latency,
                    cups: cells as f64 / latency.as_secs_f64(),
                    checksum,
                };
                (res, cells)
            }
            Err(e) => {
                let res = JobResult {
                    id: job.id,
                    ok: false,
                    detail: e,
                    latency,
                    cups: 0.0,
                    checksum: 0.0,
                };
                // Failed jobs contribute no cells to the throughput
                // counters ([`Metrics::record`] ignores them).
                (res, 0)
            }
        }
    }

    /// The single execution path every engine goes through: resolve the
    /// backend by name, compile the spec (plan cache), prepare the
    /// executable (prepared cache), then drive the app loop against the
    /// uniform [`Executable`] surface. Returns the checksum and the
    /// cells one application updated (from the grid the driver actually
    /// ran, so throughput metering is exact for any deck shape).
    fn dispatch(&mut self, job: &Job) -> Result<(f64, u64), String> {
        let backend = engine::registry().get(&job.backend)?;
        let key = job.plan_key();
        let prog = self.plans.get_or_compile(&key, || job.spec.compile())?;
        if backend.executes_plan() {
            // PJRT runs fixed pre-built artifacts; the compiled plan's
            // vector length says nothing about what it executes.
            self.metrics.record_vlen(prog.vector_len());
            self.metrics.record_threads(job.threads.resolve() as u64);
        }
        let ctx = PrepareCtx { artifacts: self.artifacts.clone() };
        // Retrying cache: a cc/rustc/dlopen failure may be transient
        // (tmpdir full, compiler hiccup) and must not poison the key
        // pool-wide.
        let exe = self
            .prepared
            .get_or_compute_retrying(&key.tagged(backend.name()), || {
                backend.prepare(&job.spec, &prog, &ctx)
            })?;
        // Driver selection keys on the *compiled deck's* name, so an
        // external deck file with the same content as a builtin serves
        // through the same driver (and produces the same results and
        // throughput accounting).
        if prog.deck.name == "hydro2d_sweep" {
            self.run_hydro(job, &**exe)
        } else {
            self.run_grid(job, &prog, &**exe)
        }
    }

    /// Hydro2D driver: Sod setup + dimensionally-split time loop, with
    /// the prepared executable as the sweep implementation. A trace-v3
    /// extents override (`Ni x Nj` in sorted-name order) makes the tube
    /// rectangular; cells are metered from the grid actually run.
    fn run_hydro(&mut self, job: &Job, exe: &dyn Executable) -> Result<(f64, u64), String> {
        use crate::apps::hydro2d::solver::{sod, step};
        let (nx, ny) = match &job.extents {
            None => (job.size, job.size),
            Some(v) if v.len() == 2 => (v[0] as usize, v[1] as usize),
            Some(v) => {
                return Err(format!(
                    "hydro2d extents override takes 2 values (NixNj), got {}",
                    v.len()
                ))
            }
        };
        let mut state = sod(nx, ny);
        let cfg = engine::RunConfig::with_threads(job.threads);
        let mut sweeper = ExecutableSweeper { exe, ws: &mut self.ws, cfg };
        for _ in 0..job.steps {
            step(&mut state, 1.0 / nx as f64, 0.4, &mut sweeper)?;
        }
        Ok((state.rho.iter().sum(), (nx * ny) as u64))
    }

    /// Generic grid driver (built-in stencil apps *and* external deck
    /// files): every extent is set to the job size (cosmo's `Nk` to the
    /// served plane count) unless the job carries a trace-v3 extents
    /// override, which binds its values to the extent names in sorted
    /// order — non-square external workloads. External inputs are seeded
    /// from the job id, outputs zero-filled, and the checksum sums the
    /// pure outputs. Returns `(checksum, cells per application)` — the
    /// product of the extents actually executed, so 3-D and non-square
    /// grids are metered exactly.
    fn run_grid(
        &mut self,
        job: &Job,
        prog: &Program,
        exe: &dyn Executable,
    ) -> Result<(f64, u64), String> {
        let names = crate::codegen::c99::extent_names(prog);
        let vals = job_extents(job, prog)?;
        let ext: BTreeMap<String, i64> = names.iter().cloned().zip(vals.iter().copied()).collect();
        let cells_per_step: u64 = ext.values().map(|&v| v.max(1) as u64).product();
        let input_names: BTreeSet<String> =
            prog.external_inputs().into_iter().map(|(n, _, _)| n).collect();
        let output_names: BTreeSet<String> =
            prog.external_outputs().into_iter().map(|(n, _, _)| n).collect();
        let mut arrays = BTreeMap::new();
        for name in &input_names {
            let len = exec::external_len(prog, name, &ext)?;
            arrays.insert(name.clone(), crate::apps::seeded(len, job.id));
        }
        for name in &output_names {
            if !arrays.contains_key(name) {
                let len = exec::external_len(prog, name, &ext)?;
                arrays.insert(name.clone(), vec![0.0; len]);
            }
        }
        let cfg = engine::RunConfig::with_threads(job.threads);
        // A time-tiled plan applies `time_tile` fused sweep passes per
        // invocation, so the step loop divides: one call serves t steps
        // (the last call may overshoot — sweeps are idempotent, so extra
        // passes rewrite identical values).
        let t_eff = prog.time_tile().max(1);
        for _ in 0..job.steps.max(1).div_ceil(t_eff) {
            exe.run_with(&ext, &mut arrays, &mut self.ws, &cfg)?;
        }
        let mut checksum = 0.0;
        for name in output_names.difference(&input_names) {
            checksum += arrays
                .get(name)
                .map(|v| v.iter().sum::<f64>())
                .ok_or_else(|| format!("backend produced no output `{name}`"))?;
        }
        Ok((checksum, cells_per_step))
    }
}

/// Hydro2D sweep over any prepared [`Executable`] — the one adapter
/// between the solver's `Sweeper` interface and the engine API.
struct ExecutableSweeper<'a> {
    exe: &'a dyn Executable,
    ws: &'a mut exec::Workspace,
    cfg: engine::RunConfig,
}

impl crate::apps::hydro2d::solver::Sweeper for ExecutableSweeper<'_> {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), rows as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_rho".to_string(), rho.to_vec());
        arrays.insert("g_rhou".to_string(), rhou.to_vec());
        arrays.insert("g_rhov".to_string(), rhov.to_vec());
        arrays.insert("g_E".to_string(), e.to_vec());
        arrays.insert("g_dtdx".to_string(), vec![dtdx]);
        for name in ["g_nrho", "g_nrhou", "g_nrhov", "g_nE"] {
            arrays.insert(name.to_string(), vec![0.0; rows * n]);
        }
        self.exe.run_with(&ext, &mut arrays, self.ws, &self.cfg)?;
        let mut take = |name: &str| arrays.remove(name).ok_or_else(|| format!("missing `{name}`"));
        Ok([take("g_nrho")?, take("g_nrhou")?, take("g_nrhov")?, take("g_nE")?])
    }

    fn name(&self) -> &'static str {
        "hfav-backend"
    }
}

/// Expand a job template `repeat` times, assigning fresh sequential ids
/// (the id seeds each job's synthetic input, so repeats stay distinct).
pub fn repeat_jobs(template: &[Job], repeat: usize) -> Vec<Job> {
    let mut out = Vec::with_capacity(template.len() * repeat.max(1));
    for r in 0..repeat.max(1) {
        for (i, j) in template.iter().enumerate() {
            let mut job = j.clone();
            job.id = (r * template.len() + i) as u64;
            out.push(job);
        }
    }
    out
}

/// Number of distinct plan-cache keys a job list compiles under — the
/// expected pipeline-compilation count for a cold cache.
pub fn distinct_plan_keys(jobs: &[Job]) -> usize {
    jobs.iter().map(|j| j.plan_key()).collect::<std::collections::BTreeSet<_>>().len()
}

/// Parse a job-trace line (format v4):
/// `app|deck.yaml, variant, engine, size, steps[, vlen][, extents=NxM[xK]][, tt=N]`.
///
/// The target may be a built-in app or a deck-file path; the engine is
/// any [`engine::registry`] name; the optional `vlen` field forces a
/// vector length for that job (`-` or `deck` keeps the deck default);
/// the optional `extents=` field overrides the grid shape per job
/// (values bind to the deck's extents in sorted-name order — see
/// [`parse_extents`]), opening non-square workloads through the generic
/// grid driver; the optional v4 `tt=N` field requests temporal blocking
/// depth N for that job (part of the plan fingerprint — the legality
/// gate may still fall back to 1 at compile time). v2/v3 lines parse
/// unchanged.
///
/// The variant field additionally accepts `tuned`: the job is marked a
/// tuned request ([`Job::tuned_request`]) and its spec defaults to the
/// heuristic `hfav+tuned` knobs, so it serves correctly even when no
/// tuned-plans DB is consulted ([`resolve_tuned`] upgrades it on a hit).
pub fn parse_trace_line(id: u64, line: &str) -> Result<Job, String> {
    let f: Vec<&str> = line.split(',').map(str::trim).collect();
    if !(5..=8).contains(&f.len()) {
        return Err(format!(
            "bad trace line `{line}` \
             (app|deck.yaml, variant, engine, size, steps[, vlen][, extents=NxM][, tt=N])"
        ));
    }
    let tuned_request = f[1] == "tuned";
    let variant: Variant = if tuned_request { Variant::Hfav } else { f[1].parse()? };
    let mut vlen: Option<Vlen> = None;
    let mut extents: Option<Vec<i64>> = None;
    let mut time_tile: Option<usize> = None;
    for field in &f[5..] {
        if let Some(spec) = field.strip_prefix("extents=") {
            if extents.is_some() {
                return Err(format!("bad trace line `{line}`: duplicate extents field"));
            }
            extents = Some(parse_extents(spec)?);
        } else if let Some(n) = field.strip_prefix("tt=") {
            if time_tile.is_some() {
                return Err(format!("bad trace line `{line}`: duplicate tt field"));
            }
            let t: usize = n.parse().map_err(|e| format!("bad trace line `{line}`: tt: {e}"))?;
            if t < 1 {
                return Err(format!("bad trace line `{line}`: tt must be >= 1"));
            }
            time_tile = Some(t);
        } else {
            if vlen.is_some() {
                return Err(format!("bad trace line `{line}`: duplicate vlen field"));
            }
            vlen = Some(field.parse()?);
        }
    }
    let vlen = vlen.unwrap_or(Vlen::Deck);
    let backend = engine::registry().get(f[2])?.name().to_string();
    let spec = target_spec(f[0])?
        .variant(variant)
        .vlen(vlen)
        .time_tile(time_tile.unwrap_or(1))
        .tuned(tuned_request);
    Ok(Job {
        id,
        spec,
        backend,
        size: f[3].parse().map_err(|e| format!("size: {e}"))?,
        steps: f[4].parse().map_err(|e| format!("steps: {e}"))?,
        extents,
        threads: engine::Threads::Serial,
        tuned_request,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(id: u64, app: &str, variant: Variant, backend: &str, size: usize, steps: usize) -> Job {
        Job::new(id, PlanSpec::app(app).variant(variant), backend, size, steps)
    }

    #[test]
    fn coordinator_runs_mixed_batch() {
        let c = Coordinator::start(2, None);
        let jobs = vec![
            mk(1, "laplace", Variant::Hfav, "exec", 64, 1),
            mk(2, "normalize", Variant::Autovec, "exec", 48, 1),
            mk(3, "hydro2d", Variant::Hfav, "exec", 16, 2),
            mk(4, "laplace", Variant::Hfav, "native", 64, 2),
        ];
        let results = c.run_batch(jobs);
        assert_eq!(results.len(), 4);
        for (k, r) in results.iter().enumerate() {
            assert_eq!(r.id, k as u64 + 1, "results must preserve input order");
            assert!(r.ok, "job {} failed: {}", r.id, r.detail);
            assert!(r.cups > 0.0);
        }
        assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(c.metrics.percentile(0.5) > Duration::ZERO);
        // 3 distinct plan keys: laplace/hfav (shared by exec+native),
        // normalize/autovec, hydro2d/hfav.
        assert_eq!(c.plans.stats().computes, 3, "{}", c.plans.stats());
        // 4 prepared executables: the three interpreter setups plus one
        // compiled-C module (laplace/hfav on `native`).
        assert_eq!(c.prepared.stats().computes, 4, "{}", c.prepared.stats());
        c.shutdown();
    }

    #[test]
    fn coordinator_reports_failures() {
        let c = Coordinator::start(1, None);
        let r = c.submit(mk(9, "nope", Variant::Hfav, "exec", 8, 1)).recv().unwrap();
        assert!(!r.ok);
        assert!(r.detail.contains("unknown app"));
        c.shutdown();
    }

    #[test]
    fn repeated_jobs_hit_the_plan_cache() {
        let c = Coordinator::start(4, None);
        let jobs: Vec<Job> =
            (0..12).map(|i| mk(i, "laplace", Variant::Hfav, "exec", 32, 1)).collect();
        let results = c.run_batch(jobs);
        assert!(results.iter().all(|r| r.ok));
        let s = c.plans.stats();
        assert_eq!(s.computes, 1, "one key → one compile: {s}");
        assert!(s.hits >= 11 - 3, "most lookups must hit: {s}");
        assert_eq!(c.prepared.stats().computes, 1, "{}", c.prepared.stats());
        let rep = c.report(Duration::from_secs(1));
        assert_eq!(rep.completed, 12);
        assert!(rep.buffers_reused > 0, "{rep}");
        c.shutdown();
    }

    #[test]
    fn trace_parsing() {
        let j = parse_trace_line(5, "hydro2d, hfav, native, 128, 10").unwrap();
        assert_eq!(j.spec.app_name(), Some("hydro2d"));
        assert_eq!(j.backend, "native");
        assert_eq!(j.size, 128);
        assert_eq!(j.spec.vlen_override(), None);
        // The generated-Rust engine parses like any registry name.
        let v = parse_trace_line(6, "hydro2d, hfav, rust, 128, 10, 8").unwrap();
        assert_eq!(v.backend, "rust");
        assert_eq!(v.spec.vlen_override(), Some(8));
        let d = parse_trace_line(7, "laplace, hfav, exec, 64, 1, -").unwrap();
        assert_eq!(d.spec.vlen_override(), None);
        assert!(parse_trace_line(0, "bad line").is_err());
        assert!(parse_trace_line(0, "a,b,c,d,e").is_err());
        assert!(parse_trace_line(0, "laplace, hfav, exec, 64, 1, 0").is_err());
        let e = parse_trace_line(0, "laplace, hfav, tpu, 64, 1").unwrap_err();
        assert!(e.contains("unknown engine"), "{e}");
    }

    #[test]
    fn trace_v3_extents_parsing() {
        // v3: extents override with and without a per-job vlen.
        let j = parse_trace_line(1, "cosmo, hfav, exec, 32, 2, -, extents=13x11x3").unwrap();
        assert_eq!(j.extents, Some(vec![13, 11, 3]));
        assert_eq!(j.spec.vlen_override(), None);
        let j = parse_trace_line(2, "cosmo, hfav, exec, 32, 2, 8, extents=13x11x3").unwrap();
        assert_eq!(j.extents, Some(vec![13, 11, 3]));
        assert_eq!(j.spec.vlen_override(), Some(8));
        // extents directly in the sixth position (no vlen field).
        let j = parse_trace_line(3, "hydro2d, hfav, exec, 24, 1, extents=48x12").unwrap();
        assert_eq!(j.extents, Some(vec![48, 12]));
        // v2 lines parse unchanged.
        let j = parse_trace_line(4, "laplace, hfav, exec, 64, 1").unwrap();
        assert_eq!(j.extents, None);
        // Malformed overrides fail.
        assert!(parse_trace_line(0, "laplace, hfav, exec, 64, 1, extents=").is_err());
        assert!(parse_trace_line(0, "laplace, hfav, exec, 64, 1, extents=0x4").is_err());
        assert!(parse_trace_line(0, "laplace, hfav, exec, 64, 1, extents=axb").is_err());
        // Duplicate optional fields are rejected, not last-one-wins.
        let e = parse_trace_line(0, "laplace, hfav, exec, 64, 1, 8, 4").unwrap_err();
        assert!(e.contains("duplicate vlen"), "{e}");
        let e = parse_trace_line(0, "cosmo, hfav, exec, 32, 1, extents=4x4x4, extents=8x8x8")
            .unwrap_err();
        assert!(e.contains("duplicate extents"), "{e}");
        assert_eq!(parse_extents("128x64x4").unwrap(), vec![128, 64, 4]);
    }

    #[test]
    fn extents_move_batch_identity_not_plan_key() {
        let square = mk(1, "laplace", Variant::Hfav, "exec", 32, 1);
        let wide = mk(2, "laplace", Variant::Hfav, "exec", 32, 1).with_extents(vec![64, 16]);
        let tall = mk(3, "laplace", Variant::Hfav, "exec", 32, 1).with_extents(vec![16, 64]);
        // Plans are shape-generic: one compile serves every shape...
        assert_eq!(square.plan_key(), wide.plan_key());
        assert_eq!(distinct_plan_keys(&[square.clone(), wide.clone(), tall.clone()]), 1);
        // ...but the batch identity separates shapes (warm-buffer fit).
        assert_ne!(batch_key(&square), batch_key(&wide));
        assert_ne!(batch_key(&wide), batch_key(&tall));
    }

    #[test]
    fn non_square_extents_serve_with_exact_cell_metering() {
        // laplace on a 24x10 grid (extent names sorted: Ni=24, Nj=10),
        // 3 steps: total cells must be 24*10*3, not size^2 * steps.
        let c = Coordinator::start(1, None);
        let job = mk(5, "laplace", Variant::Hfav, "exec", 32, 3).with_extents(vec![24, 10]);
        let r = c.submit(job).recv().unwrap();
        assert!(r.ok, "{}", r.detail);
        let rep = c.report(Duration::from_millis(1));
        assert_eq!(rep.total_cells, 24 * 10 * 3);
        // A mismatched override fails the job with a clear error.
        let bad = mk(6, "laplace", Variant::Hfav, "exec", 32, 1).with_extents(vec![24, 10, 4]);
        let r = c.submit(bad).recv().unwrap();
        assert!(!r.ok);
        assert!(r.detail.contains("extents override"), "{}", r.detail);
        c.shutdown();
    }

    #[test]
    fn distinct_vlens_get_distinct_plan_entries() {
        // Same id → same seeded input, so checksums are comparable.
        let mk_v = |vlen: Option<usize>| {
            Job::new(7, PlanSpec::app("laplace").vlen_resolved(vlen), "exec", 32, 1)
        };
        let jobs = vec![mk_v(None), mk_v(Some(1)), mk_v(Some(4)), mk_v(Some(8)), mk_v(Some(4))];
        assert_eq!(distinct_plan_keys(&jobs), 4, "None, 1, 4, 8");
        let c = Coordinator::start(2, None);
        let results = c.run_batch(jobs);
        assert!(results.iter().all(|r| r.ok), "{results:?}");
        // Same inputs, same math → identical checksums across vlens.
        for r in &results[1..] {
            assert_eq!(r.checksum, results[0].checksum, "vlen changed results");
        }
        assert_eq!(c.plans.stats().computes, 4, "{}", c.plans.stats());
        let rep = c.report(Duration::from_millis(1));
        assert_eq!(rep.vlen_min, 1);
        assert_eq!(rep.vlen_max, 8);
        c.shutdown();
    }

    #[test]
    fn threads_are_runtime_only_and_bitwise_stable() {
        // The knob changes neither the plan key nor the batch identity —
        // one compiled plan, one warm-workspace group, any core count.
        let base = mk(11, "cosmo", Variant::Hfav, "exec", 16, 1);
        let threaded = base.clone().with_threads(engine::Threads::Fixed(3));
        assert_eq!(base.plan_key(), threaded.plan_key());
        assert_eq!(batch_key(&base), batch_key(&threaded));
        let c = Coordinator::start(2, None);
        let r1 = c.submit(base).recv().unwrap();
        let r2 = c.submit(threaded).recv().unwrap();
        assert!(r1.ok, "{}", r1.detail);
        assert!(r2.ok, "{}", r2.detail);
        assert_eq!(r1.checksum, r2.checksum, "threads changed results");
        let rep = c.report(Duration::from_millis(1));
        assert_eq!(rep.threads_effective, 3, "{rep}");
        c.shutdown();
    }

    #[test]
    fn batches_are_metered() {
        let c = Coordinator::start(2, None);
        let jobs: Vec<Job> =
            (0..4).map(|i| mk(i, "laplace", Variant::Hfav, "exec", 24, 1)).collect();
        let results = c.run_batch(jobs);
        assert!(results.iter().all(|r| r.ok));
        let rep = c.report(Duration::from_millis(1));
        assert_eq!(rep.batches, 1);
        assert!(rep.batch_wall > Duration::ZERO, "{rep}");
        assert!(rep.batch_wall_mean() > Duration::ZERO);
        // Dropping without an explicit shutdown still drains the pool.
        drop(c);
    }

    #[test]
    fn trace_v4_time_tile_parsing() {
        // tt= in any optional position, alone or with vlen/extents.
        let j = parse_trace_line(1, "cosmo, hfav, exec, 16, 2, tt=4").unwrap();
        assert_eq!(j.spec.time_tile_depth(), 4);
        let j = parse_trace_line(2, "cosmo, hfav, exec, 16, 2, 8, extents=12x10x3, tt=2").unwrap();
        assert_eq!(j.spec.time_tile_depth(), 2);
        assert_eq!(j.spec.vlen_override(), Some(8));
        assert_eq!(j.extents, Some(vec![12, 10, 3]));
        // v2/v3 lines default to 1 (and fingerprint like pre-v4 specs).
        let j = parse_trace_line(3, "cosmo, hfav, exec, 16, 2").unwrap();
        assert_eq!(j.spec.time_tile_depth(), 1);
        assert_eq!(
            j.spec.fingerprint(),
            parse_trace_line(4, "cosmo, hfav, exec, 16, 2, tt=1").unwrap().spec.fingerprint()
        );
        // Malformed/duplicate tt fields fail.
        assert!(parse_trace_line(0, "cosmo, hfav, exec, 16, 2, tt=").is_err());
        assert!(parse_trace_line(0, "cosmo, hfav, exec, 16, 2, tt=0").is_err());
        let e = parse_trace_line(0, "cosmo, hfav, exec, 16, 2, tt=2, tt=4").unwrap_err();
        assert!(e.contains("duplicate tt"), "{e}");
    }

    #[test]
    fn time_tiled_jobs_serve_bitwise_identically() {
        // Sweeps are idempotent, so a t-deep plan serving ceil(steps/t)
        // invocations must reproduce the untiled checksum exactly — and
        // the tt knob must split the plan cache (it is compile-relevant).
        let c = Coordinator::start(1, None);
        let plain = Job::new(3, PlanSpec::app("cosmo"), "exec", 12, 3);
        let tiled = Job::new(3, PlanSpec::app("cosmo").time_tile(2), "exec", 12, 3);
        assert_ne!(plain.plan_key(), tiled.plan_key());
        let r1 = c.submit(plain).recv().unwrap();
        let r2 = c.submit(tiled).recv().unwrap();
        assert!(r1.ok, "{}", r1.detail);
        assert!(r2.ok, "{}", r2.detail);
        assert_eq!(r1.checksum, r2.checksum, "time tiling changed results");
        c.shutdown();
    }

    #[test]
    fn trace_variant_tuned_marks_request_with_heuristic_fallback() {
        let j = parse_trace_line(1, "cosmo, tuned, exec, 16, 1").unwrap();
        assert!(j.tuned_request);
        assert!(j.spec.is_tuned(), "fallback must carry the heuristic +tuned knobs");
        assert_eq!(j.spec.variant_label(), "hfav+tuned");
        // Optional fields still parse after the tuned variant.
        let j = parse_trace_line(2, "cosmo, tuned, exec, 16, 1, 8, extents=12x10x3").unwrap();
        assert!(j.tuned_request);
        assert_eq!(j.spec.vlen_override(), Some(8));
        assert_eq!(j.extents, Some(vec![12, 10, 3]));
        // Plain variants leave the flag off.
        let j = parse_trace_line(3, "cosmo, hfav, exec, 16, 1").unwrap();
        assert!(!j.tuned_request);
        assert!(!j.spec.is_tuned());
    }

    #[test]
    fn job_extents_defaults_mirror_the_grid_driver() {
        let prog = PlanSpec::app("cosmo").compile().unwrap();
        let job = mk(1, "cosmo", Variant::Hfav, "exec", 16, 1);
        // Sorted extent names Ni, Nj, Nk — square default with Nk pinned.
        assert_eq!(job_extents(&job, &prog).unwrap(), vec![16, 16, COSMO_NK]);
        let over = job.clone().with_extents(vec![12, 10, 3]);
        assert_eq!(job_extents(&over, &prog).unwrap(), vec![12, 10, 3]);
        let bad = job.with_extents(vec![12, 10]);
        assert!(job_extents(&bad, &prog).unwrap_err().contains("extents override"));
    }

    #[test]
    fn resolve_tuned_hit_miss_and_non_request() {
        use crate::plan::tunedb::{deck_digest, ShapeClass, TunedDb, TunedEntry};
        let plans = PlanCache::new();
        let mut db = TunedDb::default();
        let mut job = parse_trace_line(1, "cosmo, tuned, exec, 16, 1").unwrap();
        let fallback_fp = job.spec.fingerprint();

        // Miss: no entry — spec keeps its fallback knobs, no error.
        assert_eq!(resolve_tuned(&mut job, &db, &plans).unwrap(), None);
        assert_eq!(job.spec.fingerprint(), fallback_fp);
        // The miss path compiled the fallback through the shared cache.
        assert_eq!(plans.stats().computes, 1, "{}", plans.stats());

        // Hit: entry keyed by the job's (deck digest, shape class).
        let digest = deck_digest(&job.spec).unwrap();
        let class = ShapeClass::of(&[16, 16, COSMO_NK]).label();
        db.insert(TunedEntry {
            deck_digest: digest,
            target: "cosmo".to_string(),
            shape_class: class.clone(),
            extents: "16x16x4".to_string(),
            tuned: true,
            vec_dim: "inner".to_string(),
            vlen: 4,
            aligned: true,
            tiled: false,
            time_tile: 1,
            threads: 2,
            mcells_per_s: 100.0,
            candidates: 10,
            timed: 3,
            reps: 20,
            predicted_rank: None,
        });
        let label = resolve_tuned(&mut job, &db, &plans).unwrap().expect("hit");
        assert!(label.contains("vlen=4"), "{label}");
        assert!(label.contains(&class), "{label}");
        assert_eq!(job.spec.vlen_override(), Some(4));
        assert!(job.spec.is_aligned());
        assert_ne!(job.spec.fingerprint(), fallback_fp, "resolution must change the plan");
        assert!(matches!(job.threads, engine::Threads::Fixed(2)));
        // Resolution itself compiles nothing new (the resolved plan
        // compiles lazily at dispatch, through the same cache).
        assert_eq!(plans.stats().computes, 1, "{}", plans.stats());

        // An explicit runtime threads request wins over the entry's.
        let mut pinned = parse_trace_line(2, "cosmo, tuned, exec, 16, 1")
            .unwrap()
            .with_threads(engine::Threads::Fixed(7));
        resolve_tuned(&mut pinned, &db, &plans).unwrap().expect("hit");
        assert!(matches!(pinned.threads, engine::Threads::Fixed(7)));

        // Non-tuned jobs pass through untouched.
        let mut plain = parse_trace_line(3, "cosmo, hfav, exec, 16, 1").unwrap();
        let fp = plain.spec.fingerprint();
        assert_eq!(resolve_tuned(&mut plain, &db, &plans).unwrap(), None);
        assert_eq!(plain.spec.fingerprint(), fp);

        // A different shape class misses cleanly.
        let mut big = parse_trace_line(4, "cosmo, tuned, exec, 64, 1").unwrap();
        assert_eq!(resolve_tuned(&mut big, &db, &plans).unwrap(), None);
    }

    #[test]
    fn target_spec_resolves_apps_and_rejects_missing_decks() {
        assert_eq!(target_spec("hydro2d").unwrap().app_name(), Some("hydro2d"));
        // Bare unknown names stay app specs (fail at compile)...
        assert_eq!(target_spec("nope").unwrap().app_name(), Some("nope"));
        // ...while path-shaped targets are deck files, read eagerly.
        let e = target_spec("/no/such/deck.yaml").unwrap_err();
        assert!(e.contains("reading deck"), "{e}");
    }
}
