//! Job coordinator: a leader/worker runtime that dispatches grid-update
//! jobs to the available engines (interpreter executor, compiled-C native
//! modules, PJRT executables) with per-worker executable caches, dynamic
//! batching of same-kind jobs, and latency/throughput metrics.
//!
//! The paper's contribution is the *generator*; the coordinator is the
//! thin L3 driver that makes the generated artifacts deployable: load
//! once, serve many requests, never touch Python.

use crate::apps::{self, Variant};
use crate::runtime::Runtime;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Which engine executes a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Interpreter executor over the HFAV schedule.
    Exec,
    /// Generated C compiled with the system compiler, dlopen'd.
    Native,
    /// AOT JAX/Pallas artifact on the PJRT CPU client.
    Pjrt,
}

impl std::str::FromStr for Engine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "exec" => Ok(Engine::Exec),
            "native" => Ok(Engine::Native),
            "pjrt" => Ok(Engine::Pjrt),
            _ => Err(format!("unknown engine `{s}` (exec|native|pjrt)")),
        }
    }
}

/// A grid-update job.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    /// `laplace` | `normalize` | `cosmo` | `hydro2d`
    pub app: String,
    pub variant: Variant,
    pub engine: Engine,
    /// Problem size (per side).
    pub size: usize,
    /// Number of repeated applications (time steps / sweeps).
    pub steps: usize,
}

/// Result of one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub ok: bool,
    pub detail: String,
    pub latency: Duration,
    /// Cell-updates per second achieved.
    pub cups: f64,
    pub checksum: f64,
}

/// Aggregated metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub latencies_us: Mutex<Vec<u64>>,
    pub total_cells: AtomicU64,
}

impl Metrics {
    pub fn record(&self, r: &JobResult, cells: u64) {
        if r.ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
            self.total_cells.fetch_add(cells, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.latencies_us.lock().unwrap().push(r.latency.as_micros() as u64);
    }

    pub fn percentile(&self, p: f64) -> Duration {
        let mut v = self.latencies_us.lock().unwrap().clone();
        if v.is_empty() {
            return Duration::ZERO;
        }
        v.sort_unstable();
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        Duration::from_micros(v[idx])
    }

    pub fn summary(&self) -> String {
        format!(
            "completed={} failed={} p50={:?} p95={:?} total_cells={}",
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.percentile(0.5),
            self.percentile(0.95),
            self.total_cells.load(Ordering::Relaxed),
        )
    }
}

enum Msg {
    Run(Job, mpsc::Sender<JobResult>),
    Stop,
}

/// The coordinator: owns the worker pool.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start `nworkers` workers. `artifacts_dir` may be None (PJRT jobs
    /// will then fail gracefully).
    pub fn start(nworkers: usize, artifacts_dir: Option<std::path::PathBuf>) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for wid in 0..nworkers.max(1) {
            let rx = rx.clone();
            let metrics = metrics.clone();
            // PJRT clients are not Send: each worker owns its own runtime,
            // created lazily on the first PJRT job.
            let artifacts = artifacts_dir.clone();
            workers.push(std::thread::spawn(move || {
                let mut worker = Worker::new(wid, artifacts);
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Msg::Run(job, reply)) => {
                            let cells =
                                (job.size * job.size) as u64 * job.steps.max(1) as u64;
                            let res = worker.run(&job);
                            metrics.record(&res, cells);
                            let _ = reply.send(res);
                        }
                        Ok(Msg::Stop) | Err(_) => break,
                    }
                }
            }));
        }
        Coordinator { tx, workers, metrics }
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit(&self, job: Job) -> mpsc::Receiver<JobResult> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Run(job, rtx)).expect("coordinator stopped");
        rrx
    }

    /// Submit a batch and wait for all results (dynamic batching: jobs of
    /// the same kind hit warm per-worker caches).
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let rxs: Vec<_> = jobs.into_iter().map(|j| self.submit(j)).collect();
        rxs.into_iter().map(|rx| rx.recv().expect("worker died")).collect()
    }

    pub fn shutdown(mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-worker state: compiled program / native-module caches.
struct Worker {
    #[allow(dead_code)]
    id: usize,
    artifacts: Option<std::path::PathBuf>,
    runtime: Option<Runtime>,
    progs: BTreeMap<(String, bool), Arc<crate::plan::Program>>,
    natives: BTreeMap<(String, bool), Arc<crate::codegen::native::NativeModule>>,
}

impl Worker {
    fn new(id: usize, artifacts: Option<std::path::PathBuf>) -> Worker {
        Worker { id, artifacts, runtime: None, progs: BTreeMap::new(), natives: BTreeMap::new() }
    }

    /// Lazily create this worker's PJRT runtime (clients are not Send).
    fn runtime(&mut self) -> Result<&Runtime, String> {
        if self.runtime.is_none() {
            let dir = self.artifacts.clone().ok_or("no artifacts dir — PJRT unavailable")?;
            self.runtime = Some(Runtime::cpu(dir).map_err(|e| e.to_string())?);
        }
        Ok(self.runtime.as_ref().unwrap())
    }

    fn prog(&mut self, app: &str, variant: Variant) -> Result<Arc<crate::plan::Program>, String> {
        let key = (app.to_string(), variant == Variant::Hfav);
        if let Some(p) = self.progs.get(&key) {
            return Ok(p.clone());
        }
        let deck = deck_of(app)?;
        let p = Arc::new(apps::compile_variant(deck, variant)?);
        self.progs.insert(key, p.clone());
        Ok(p)
    }

    fn native(
        &mut self,
        app: &str,
        variant: Variant,
    ) -> Result<Arc<crate::codegen::native::NativeModule>, String> {
        let key = (app.to_string(), variant == Variant::Hfav);
        if let Some(m) = self.natives.get(&key) {
            return Ok(m.clone());
        }
        let prog = self.prog(app, variant)?;
        let m = Arc::new(crate::codegen::native::build(&prog, &Default::default())?);
        self.natives.insert(key, m.clone());
        Ok(m)
    }

    fn run(&mut self, job: &Job) -> JobResult {
        let start = Instant::now();
        let out = self.dispatch(job);
        let latency = start.elapsed();
        match out {
            Ok(checksum) => {
                let cells = (job.size * job.size) as f64 * job.steps.max(1) as f64;
                JobResult {
                    id: job.id,
                    ok: true,
                    detail: String::new(),
                    latency,
                    cups: cells / latency.as_secs_f64(),
                    checksum,
                }
            }
            Err(e) => JobResult {
                id: job.id,
                ok: false,
                detail: e,
                latency,
                cups: 0.0,
                checksum: 0.0,
            },
        }
    }

    fn dispatch(&mut self, job: &Job) -> Result<f64, String> {
        match job.app.as_str() {
            "hydro2d" => self.run_hydro(job),
            "laplace" | "normalize" | "cosmo" => self.run_stencil(job),
            other => Err(format!("unknown app `{other}`")),
        }
    }

    fn run_hydro(&mut self, job: &Job) -> Result<f64, String> {
        use crate::apps::hydro2d::solver::*;
        let n = job.size;
        let mut state = sod(n, n);
        let mut sweeper: Box<dyn Sweeper> = match job.engine {
            Engine::Exec => Box::new(ExecSweeper::new(apps::compile_variant(
                crate::apps::hydro2d::DECK,
                job.variant,
            )?)),
            Engine::Native => {
                let m = self.native("hydro2d", job.variant)?;
                // NativeModule isn't cloneable into the Box; rebuild a thin
                // wrapper around the shared Arc.
                Box::new(SharedNativeSweeper { module: m })
            }
            Engine::Pjrt => {
                return Err("hydro2d PJRT path requires fixed artifact shape; use bench pjrt".into())
            }
        };
        for _ in 0..job.steps {
            step(&mut state, 1.0 / n as f64, 0.4, sweeper.as_mut())?;
        }
        Ok(state.rho.iter().sum())
    }

    fn run_stencil(&mut self, job: &Job) -> Result<f64, String> {
        let n = job.size;
        let (_deck, reg, extents, input_name): (&str, _, Vec<(&str, i64)>, &str) =
            match job.app.as_str() {
                "laplace" => (
                    crate::apps::laplace::DECK,
                    crate::apps::laplace::registry(),
                    vec![("Nj", n as i64), ("Ni", n as i64)],
                    "g_cell",
                ),
                "normalize" => (
                    crate::apps::normalization::DECK,
                    crate::apps::normalization::registry(),
                    vec![("Nj", n as i64), ("Ni", n as i64)],
                    "g_q",
                ),
                "cosmo" => (
                    crate::apps::cosmo::DECK,
                    crate::apps::cosmo::registry(),
                    vec![("Nk", 4), ("Nj", n as i64), ("Ni", n as i64)],
                    "g_u",
                ),
                _ => unreachable!(),
            };
        let prog = self.prog(&job.app, job.variant)?;
        let ext: BTreeMap<String, i64> =
            extents.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        let len = crate::exec::external_len(&prog, input_name, &ext)?;
        let mut inputs = BTreeMap::new();
        inputs.insert(input_name.to_string(), apps::seeded(len, job.id));
        let mut checksum = 0.0;
        match job.engine {
            Engine::Exec => {
                for _ in 0..job.steps.max(1) {
                    let out = crate::exec::run(&prog, &reg, &ext, &inputs, Default::default())?;
                    checksum = out.values().next().map(|v| v.iter().sum()).unwrap_or(0.0);
                }
            }
            Engine::Native => {
                let m = self.native(&job.app, job.variant)?;
                let mut arrays = inputs.clone();
                for name in &m.externals {
                    arrays
                        .entry(name.clone())
                        .or_insert_with(|| vec![0.0; crate::exec::external_len(&prog, name, &ext).unwrap_or(0)]);
                }
                for _ in 0..job.steps.max(1) {
                    m.run(&ext, &mut arrays)?;
                }
                checksum = arrays
                    .iter()
                    .filter(|(k, _)| !inputs.contains_key(*k))
                    .map(|(_, v)| v.iter().sum::<f64>())
                    .sum();
            }
            Engine::Pjrt => {
                let rt = self.runtime()?;
                let variant = if job.variant == Variant::Hfav { "fused" } else { "unfused" };
                let name = format!(
                    "{}_{}",
                    if job.app == "normalize" { "normalize" } else { job.app.as_str() },
                    variant
                );
                let exe = rt.load(&name).map_err(|e| e.to_string())?;
                // PJRT artifacts are fixed-shape; synthesize matching input.
                let shapes = exe.meta.inputs.clone();
                let bufs: Vec<Vec<f64>> = shapes
                    .iter()
                    .map(|s| apps::seeded(s.iter().product(), job.id))
                    .collect();
                let refs: Vec<&[f64]> = bufs.iter().map(|b| b.as_slice()).collect();
                for _ in 0..job.steps.max(1) {
                    let out = exe.run(&refs).map_err(|e| e.to_string())?;
                    checksum = out[0].iter().sum();
                }
            }
        }
        Ok(checksum)
    }
}

/// Native sweeper over a shared module (coordinator cache).
struct SharedNativeSweeper {
    module: Arc<crate::codegen::native::NativeModule>,
}

impl crate::apps::hydro2d::solver::Sweeper for SharedNativeSweeper {
    fn sweep(
        &mut self,
        rho: &[f64],
        rhou: &[f64],
        rhov: &[f64],
        e: &[f64],
        dtdx: f64,
        rows: usize,
        n: usize,
    ) -> Result<[Vec<f64>; 4], String> {
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), rows as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_rho".to_string(), rho.to_vec());
        arrays.insert("g_rhou".to_string(), rhou.to_vec());
        arrays.insert("g_rhov".to_string(), rhov.to_vec());
        arrays.insert("g_E".to_string(), e.to_vec());
        arrays.insert("g_dtdx".to_string(), vec![dtdx]);
        for name in ["g_nrho", "g_nrhou", "g_nrhov", "g_nE"] {
            arrays.insert(name.to_string(), vec![0.0; rows * n]);
        }
        self.module.run(&ext, &mut arrays)?;
        Ok([
            arrays.remove("g_nrho").unwrap(),
            arrays.remove("g_nrhou").unwrap(),
            arrays.remove("g_nrhov").unwrap(),
            arrays.remove("g_nE").unwrap(),
        ])
    }

    fn name(&self) -> &'static str {
        "hfav-native-shared"
    }
}

/// Deck lookup for the built-in apps.
pub fn deck_of(app: &str) -> Result<&'static str, String> {
    match app {
        "laplace" => Ok(crate::apps::laplace::DECK),
        "normalize" => Ok(crate::apps::normalization::DECK),
        "cosmo" => Ok(crate::apps::cosmo::DECK),
        "hydro2d" => Ok(crate::apps::hydro2d::DECK),
        _ => Err(format!("unknown app `{app}` (laplace|normalize|cosmo|hydro2d)")),
    }
}

/// Parse a job-trace line: `app,variant,engine,size,steps`.
pub fn parse_trace_line(id: u64, line: &str) -> Result<Job, String> {
    let f: Vec<&str> = line.split(',').map(str::trim).collect();
    if f.len() != 5 {
        return Err(format!("bad trace line `{line}` (app,variant,engine,size,steps)"));
    }
    let variant = match f[1] {
        "hfav" => Variant::Hfav,
        "autovec" => Variant::Autovec,
        other => return Err(format!("unknown variant `{other}`")),
    };
    Ok(Job {
        id,
        app: f[0].to_string(),
        variant,
        engine: f[2].parse()?,
        size: f[3].parse().map_err(|e| format!("size: {e}"))?,
        steps: f[4].parse().map_err(|e| format!("steps: {e}"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_runs_mixed_batch() {
        let c = Coordinator::start(2, None);
        let jobs = vec![
            Job { id: 1, app: "laplace".into(), variant: Variant::Hfav, engine: Engine::Exec, size: 64, steps: 1 },
            Job { id: 2, app: "normalize".into(), variant: Variant::Autovec, engine: Engine::Exec, size: 48, steps: 1 },
            Job { id: 3, app: "hydro2d".into(), variant: Variant::Hfav, engine: Engine::Exec, size: 16, steps: 2 },
            Job { id: 4, app: "laplace".into(), variant: Variant::Hfav, engine: Engine::Native, size: 64, steps: 2 },
        ];
        let results = c.run_batch(jobs);
        for r in &results {
            assert!(r.ok, "job {} failed: {}", r.id, r.detail);
            assert!(r.cups > 0.0);
        }
        assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert!(c.metrics.percentile(0.5) > Duration::ZERO);
        c.shutdown();
    }

    #[test]
    fn coordinator_reports_failures() {
        let c = Coordinator::start(1, None);
        let r = c
            .submit(Job {
                id: 9,
                app: "nope".into(),
                variant: Variant::Hfav,
                engine: Engine::Exec,
                size: 8,
                steps: 1,
            })
            .recv()
            .unwrap();
        assert!(!r.ok);
        assert!(r.detail.contains("unknown app"));
        c.shutdown();
    }

    #[test]
    fn trace_parsing() {
        let j = parse_trace_line(5, "hydro2d, hfav, native, 128, 10").unwrap();
        assert_eq!(j.app, "hydro2d");
        assert_eq!(j.engine, Engine::Native);
        assert_eq!(j.size, 128);
        assert!(parse_trace_line(0, "bad line").is_err());
        assert!(parse_trace_line(0, "a,b,c,d,e").is_err());
    }
}
