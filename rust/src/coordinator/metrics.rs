//! Serving metrics: per-job latency/throughput aggregation plus the
//! cache counters (plan compiles, native builds, executor buffer reuse)
//! that quantify the compile-once/run-many amortization claim.

use super::JobResult;
use crate::plan::cache::CacheStatsSnapshot;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Fixed-size latency reservoir (Vitter's Algorithm R). The first
/// [`LatencyReservoir::CAP`] samples are kept exactly; after that each
/// new sample replaces a uniformly random slot with probability
/// `CAP/seen`, so the buffer remains a uniform sample of the *whole*
/// run and a serve of any length uses bounded memory. (The previous
/// unbounded `Vec` grew by 8 bytes per job forever, and every
/// percentile call cloned and sorted all of it.) The RNG is a small
/// deterministic xorshift — percentile estimates need statistical
/// fairness, not cryptographic randomness, and determinism keeps tests
/// exact.
#[derive(Debug)]
pub struct LatencyReservoir {
    samples: Vec<u64>,
    /// Samples ever recorded (`>= samples.len()`).
    seen: u64,
    rng: u64,
}

impl Default for LatencyReservoir {
    fn default() -> LatencyReservoir {
        LatencyReservoir { samples: Vec::new(), seen: 0, rng: 0x9e37_79b9_7f4a_7c15 }
    }
}

impl LatencyReservoir {
    /// Reservoir capacity: large enough for stable p50/p95 estimates
    /// (sampling error well under 1% at this size), small enough that a
    /// million-job serve holds 32 KiB of latencies, not 8 MB.
    pub const CAP: usize = 4096;

    fn next_rand(&mut self) -> u64 {
        // xorshift64*: fast, full-period, deterministic.
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Record one latency sample.
    pub fn record(&mut self, us: u64) {
        self.seen += 1;
        if self.samples.len() < Self::CAP {
            self.samples.push(us);
        } else {
            // Algorithm R: keep the new sample with probability CAP/seen.
            let j = (self.next_rand() % self.seen) as usize;
            if j < Self::CAP {
                self.samples[j] = us;
            }
        }
    }

    /// Samples currently held (bounded by [`Self::CAP`]).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples ever recorded (the unbounded count the reservoir summarizes).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Percentile estimates, one per requested fraction — a single sort
    /// of the bounded buffer serves all of them.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        if self.samples.is_empty() {
            return vec![Duration::ZERO; ps.len()];
        }
        let mut v = self.samples.clone();
        v.sort_unstable();
        ps.iter()
            .map(|p| {
                let idx = ((v.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
                Duration::from_micros(v[idx])
            })
            .collect()
    }
}

/// Aggregated job metrics, updated by every worker.
#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub latencies: Mutex<LatencyReservoir>,
    pub total_cells: AtomicU64,
    /// Executor buffers recycled from worker workspaces.
    pub buffers_reused: AtomicU64,
    /// Executor buffers freshly allocated by worker workspaces.
    pub buffers_allocated: AtomicU64,
    /// Smallest effective vector length served so far (0 = none yet).
    pub vlen_min: AtomicU64,
    /// Largest effective vector length served so far (0 = none yet).
    pub vlen_max: AtomicU64,
    /// `run_batch` calls completed.
    pub batches: AtomicU64,
    /// Aggregate wall time spent inside `run_batch` (microseconds) —
    /// submit-to-last-result per batch, summed.
    pub batch_wall_us: AtomicU64,
    /// Largest effective intra-job worker count served so far (resolved
    /// from the job's [`crate::engine::Threads`] knob; 0 = none yet).
    pub threads_max: AtomicU64,
}

impl Metrics {
    pub fn record(&self, r: &JobResult, cells: u64) {
        if r.ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
            self.total_cells.fetch_add(cells, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        self.latencies.lock().unwrap().record(r.latency.as_micros() as u64);
    }

    /// Record the effective vector length of a served job's plan.
    pub fn record_vlen(&self, vlen: usize) {
        let v = vlen.max(1) as u64;
        self.vlen_max.fetch_max(v, Ordering::Relaxed);
        // min over a 0-initialized atomic: treat 0 as "unset".
        let mut cur = self.vlen_min.load(Ordering::Relaxed);
        loop {
            if cur != 0 && cur <= v {
                break;
            }
            match self.vlen_min.compare_exchange_weak(
                cur,
                v,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
    }

    /// Record one completed batch and its wall time.
    pub fn record_batch(&self, wall: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_wall_us.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
    }

    /// Record the effective intra-job worker count of a served job.
    pub fn record_threads(&self, threads: u64) {
        self.threads_max.fetch_max(threads.max(1), Ordering::Relaxed);
    }

    /// One latency percentile estimate. For several percentiles at
    /// once, [`percentiles`](Self::percentiles) sorts only once.
    pub fn percentile(&self, p: f64) -> Duration {
        self.percentiles(&[p])[0]
    }

    /// Latency percentile estimates from the bounded reservoir, one
    /// sort for all requested fractions.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<Duration> {
        self.latencies.lock().unwrap().percentiles(ps)
    }

    pub fn summary(&self) -> String {
        let pcts = self.percentiles(&[0.5, 0.95]);
        format!(
            "completed={} failed={} p50={:?} p95={:?} total_cells={}",
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            pcts[0],
            pcts[1],
            self.total_cells.load(Ordering::Relaxed),
        )
    }
}

/// One coherent view of a serve run: job counts, latency percentiles,
/// throughput over the measured wall time, and the cache counters.
#[derive(Debug, Clone, Copy)]
pub struct ServeReport {
    pub completed: u64,
    pub failed: u64,
    pub p50: Duration,
    pub p95: Duration,
    pub total_cells: u64,
    pub wall: Duration,
    /// Plan cache: `computes` is the number of pipeline compilations.
    pub plans: CacheStatsSnapshot,
    /// Prepared-executable cache: `computes` is the number of
    /// `Backend::prepare` calls (cc/rustc builds, interpreter setups,
    /// artifact bindings).
    pub prepared: CacheStatsSnapshot,
    pub buffers_reused: u64,
    pub buffers_allocated: u64,
    /// Smallest effective vector length among served plans (0 = none).
    pub vlen_min: u64,
    /// Largest effective vector length among served plans (0 = none).
    pub vlen_max: u64,
    /// `run_batch` calls this report covers.
    pub batches: u64,
    /// Aggregate wall time spent inside `run_batch` (all batches).
    pub batch_wall: Duration,
    /// Largest effective intra-job worker count served (0 = none —
    /// e.g. only artifact-executing backends ran).
    pub threads_effective: u64,
}

impl ServeReport {
    /// Cell updates per second over the wall time.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.total_cells as f64 / self.wall.as_secs_f64()
        }
    }

    /// Mean wall time per batch (zero when no batch ran).
    pub fn batch_wall_mean(&self) -> Duration {
        if self.batches == 0 {
            Duration::ZERO
        } else {
            self.batch_wall / self.batches as u32
        }
    }

    /// Human-readable effective intra-job worker count: `-` when none
    /// was recorded, otherwise the maximum served.
    pub fn threads_label(&self) -> String {
        match self.threads_effective {
            0 => "-".to_string(),
            n => n.to_string(),
        }
    }

    /// Human-readable effective vector length: `-` (none), `8`, or `1..8`.
    pub fn vlen_label(&self) -> String {
        match (self.vlen_min, self.vlen_max) {
            (0, _) | (_, 0) => "-".to_string(),
            (a, b) if a == b => a.to_string(),
            (a, b) => format!("{a}..{b}"),
        }
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "jobs: completed={} failed={} p50={:?} p95={:?}",
            self.completed, self.failed, self.p50, self.p95
        )?;
        writeln!(
            f,
            "throughput: {:.1} Mcells/s over wall={:?} (effective vlen {}, threads {})",
            self.throughput() / 1e6,
            self.wall,
            self.vlen_label(),
            self.threads_label()
        )?;
        writeln!(
            f,
            "batches: {} (mean wall {:?}/batch)",
            self.batches,
            self.batch_wall_mean()
        )?;
        writeln!(f, "plan cache:     {}", self.plans)?;
        writeln!(f, "prepared execs: {}", self.prepared)?;
        write!(
            f,
            "exec buffers: reused={} allocated={}",
            self.buffers_reused, self.buffers_allocated
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(ok: bool, us: u64) -> JobResult {
        JobResult {
            id: 0,
            ok,
            detail: String::new(),
            latency: Duration::from_micros(us),
            cups: 0.0,
            checksum: 0.0,
        }
    }

    #[test]
    fn record_and_percentiles() {
        let m = Metrics::default();
        for us in [100, 200, 300, 400, 1000] {
            m.record(&result(true, us), 10);
        }
        m.record(&result(false, 50), 10);
        assert_eq!(m.completed.load(Ordering::Relaxed), 5);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.total_cells.load(Ordering::Relaxed), 50);
        assert!(m.percentile(0.5) >= Duration::from_micros(200));
        assert!(m.percentile(1.0) == Duration::from_micros(1000));
        assert!(m.summary().contains("completed=5"));
    }

    #[test]
    fn reservoir_bounds_memory_over_100k_records() {
        let m = Metrics::default();
        // Latencies 1..=100_000 us, uniformly — known true percentiles.
        for us in 1..=100_000u64 {
            m.record(&result(true, us), 1);
        }
        {
            let res = m.latencies.lock().unwrap();
            assert_eq!(res.seen(), 100_000);
            assert_eq!(res.len(), LatencyReservoir::CAP, "reservoir must stay capped");
            assert!(res.samples.capacity() <= 2 * LatencyReservoir::CAP);
        }
        // Percentile estimates from the uniform sample stay sane
        // (deterministic RNG, so these bounds are exact-reproducible;
        // they are ~10 sigma wide regardless).
        let pcts = m.percentiles(&[0.5, 0.95]);
        let (p50, p95) = (pcts[0].as_micros() as u64, pcts[1].as_micros() as u64);
        assert!((40_000..=60_000).contains(&p50), "p50 = {p50}us");
        assert!((88_000..=100_000).contains(&p95), "p95 = {p95}us");
        assert!(p50 < p95);
        assert_eq!(m.completed.load(Ordering::Relaxed), 100_000);
    }

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = LatencyReservoir::default();
        assert!(r.is_empty());
        assert_eq!(r.percentiles(&[0.5]), vec![Duration::ZERO]);
        for us in [100, 200, 300] {
            r.record(us);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.seen(), 3);
        let got: Vec<u64> =
            r.percentiles(&[0.0, 0.5, 1.0]).iter().map(|d| d.as_micros() as u64).collect();
        assert_eq!(got, vec![100, 200, 300]);
    }

    #[test]
    fn report_throughput() {
        let r = ServeReport {
            completed: 2,
            failed: 0,
            p50: Duration::from_millis(1),
            p95: Duration::from_millis(2),
            total_cells: 1_000_000,
            wall: Duration::from_secs(1),
            plans: CacheStatsSnapshot::default(),
            prepared: CacheStatsSnapshot::default(),
            buffers_reused: 3,
            buffers_allocated: 4,
            vlen_min: 1,
            vlen_max: 8,
            batches: 2,
            batch_wall: Duration::from_millis(10),
            threads_effective: 4,
        };
        assert!((r.throughput() - 1e6).abs() < 1e-6);
        assert_eq!(r.vlen_label(), "1..8");
        assert_eq!(r.batch_wall_mean(), Duration::from_millis(5));
        assert_eq!(r.threads_label(), "4");
        let text = format!("{r}");
        assert!(text.contains("plan cache"), "{text}");
        assert!(text.contains("reused=3"), "{text}");
        assert!(text.contains("effective vlen 1..8, threads 4"), "{text}");
        assert!(text.contains("batches: 2"), "{text}");
    }

    #[test]
    fn batch_and_thread_counters() {
        let m = Metrics::default();
        m.record_batch(Duration::from_micros(1500));
        m.record_batch(Duration::from_micros(500));
        m.record_threads(1);
        m.record_threads(4);
        m.record_threads(2);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.batch_wall_us.load(Ordering::Relaxed), 2000);
        assert_eq!(m.threads_max.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn vlen_min_max_tracking() {
        let m = Metrics::default();
        assert_eq!(m.vlen_min.load(Ordering::Relaxed), 0);
        m.record_vlen(4);
        m.record_vlen(1);
        m.record_vlen(8);
        assert_eq!(m.vlen_min.load(Ordering::Relaxed), 1);
        assert_eq!(m.vlen_max.load(Ordering::Relaxed), 8);
    }
}
