//! The **schedule IR**: one explicit loop-schedule tree per fused nest,
//! lowered exactly once by [`crate::plan::compile`] after analysis has
//! resolved the vectorization strategy — and *walked*, never re-derived,
//! by every consumer (the C99 and Rust emitters print it, the
//! interpreter executor runs it).
//!
//! Before this module existed, the strip/lane/peel/remainder/alignment
//! shapes were re-decided three times — once per code emitter and once
//! in the executor, which had to hand-mirror the emitted loop structure.
//! Now every shape decision happens in [`lower`]:
//!
//! * **static peeling** — loop levels split into segments with fixed
//!   active member sets where the symbolic bounds are orderable
//!   ([`Node::Loop`]), with a guarded fallback ([`Node::Guarded`]);
//! * **inner lane-fission strips** ([`Node::Strip`] with
//!   `outer == false`, the paper's Fig. 9c vector expansion) where
//!   [`crate::analysis::lane_fission_safe`] allows, each steady member a
//!   [`Node::MemberStrip`];
//! * **outer-dim lane strips** (`outer == true`) on the resolved
//!   k-independent lane dim ([`crate::analysis::outer_vectorizable`]),
//!   every leaf invocation an [`Invoke`] expanded across a [`LaneLoop`];
//! * **alignment heads** — the aligned specialization's scalar head
//!   peel, *elided at compile time* when a strip's lower bound is
//!   statically a multiple of the lane count (`StripNode::head` is
//!   `None`, `static_aligned` records why);
//! * **multi-dim lane tiling** — outer lanes × inner strips together
//!   (`PlanSpec::tiled` / `--tile`): the steady×steady region runs each
//!   kernel over a `vlen × vlen` tile ([`MemberStrip::outer`]), with no
//!   new shape logic in any backend;
//! * **chunk parallelism** ([`Node::Parallel`]) — when the outermost dim
//!   is k-independent *and* no contracted intermediate window is shared
//!   across chunks ([`crate::analysis::parallel_safe`]), the level-0
//!   loop/strip is wrapped in a Parallel level that splits the iteration
//!   space into `len.div_ceil(threads)`-sized chunks ([`chunk_spans`]).
//!   The thread count is a *runtime* knob (`RunConfig`), never plan
//!   identity: the node carries only the chunk granule and the storage
//!   ids each chunk must privatize; each walker binds the chunk bounds
//!   to the [`ParallelNode::lo_sym`]/[`ParallelNode::hi_sym`] symbols at
//!   run time (OpenMP in C99, `std::thread::scope` in Rust, the shared
//!   worker pool in the interpreter). At one thread the single chunk is
//!   the whole range, so serial runs are bitwise- and order-identical
//!   to the unwrapped tree.
//!
//! The tree is symbolic (bounds are [`Bound`]s over extent names), so
//! one lowering serves every grid shape. [`Schedule::digest`] is a
//! stable fingerprint of the lowered structure — both emitters print it
//! into their output header, so "do all executors agree on the loops
//! that run" is checkable by string equality — and [`Schedule::visit`]
//! is the reference walker that enumerates kernel invocations in
//! exactly the order the emitted code executes them (the property suite
//! compares the executor's instrumented trace against it).

pub mod cost;

use crate::analysis::{self, DimSize, StoragePlan};
use crate::dataflow::Dataflow;
use crate::fusion::{FusedDag, FusedNest, Member, Role};
use crate::ir::{Bound, Deck};
use crate::plan::cache::Fnv64;
use crate::plan::CompileOptions;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write;

// ---------------------------------------------------------------------------
// Tree types
// ---------------------------------------------------------------------------

/// The fully lowered schedule of a compiled program: one loop tree per
/// fused nest, in nest execution order.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub nests: Vec<NestPlan>,
    /// Stable FNV-1a fingerprint of [`Schedule::render`] — the identity
    /// of "which loops actually run".
    pub digest: u64,
}

/// The lowered tree of one fused nest.
#[derive(Debug, Clone)]
pub struct NestPlan {
    /// Index into [`crate::fusion::FusedDag::nests`].
    pub nest: usize,
    /// Nest dims, outermost-first (copied from the fused nest).
    pub dims: Vec<String>,
    /// Top-level (level-0) schedule nodes.
    pub body: Vec<Node>,
}

/// One node of the loop-schedule tree.
#[derive(Debug, Clone)]
pub enum Node {
    /// A plain counting loop (step 1) over one nest level.
    Loop(LoopNode),
    /// A strip-mined loop: optional scalar alignment head, steady-state
    /// strips advancing `lanes` per iteration, scalar remainder.
    Strip(StripNode),
    /// Guarded fallback (bounds not statically orderable): one uniform
    /// loop with per-member activity guards.
    Guarded(GuardedNode),
    /// One kernel invocation, optionally expanded across outer lanes.
    Invoke(Invoke),
    /// One member of an innermost lane-fission strip: the kernel runs
    /// over all `lanes` consecutive innermost iterations before the next
    /// node starts (vector expansion, Fig. 9c).
    MemberStrip(MemberStrip),
    /// A chunk-parallel level over a k-independent outer dim: the range
    /// `[lo, hi)` splits into per-thread chunks at run time and the body
    /// runs once per chunk with its bounds bound to the chunk symbols.
    Parallel(ParallelNode),
    /// Temporal blocking over the outermost loop dim: the range `[lo,
    /// hi)` advances in cache-resident blocks of `block` iterations, and
    /// each block executes `t_block` sweep-steps back-to-back before the
    /// walk moves on — re-executions rebuild rolling-window halo cells
    /// through per-member warm-up replays first (see [`TimeTileNode`]).
    TimeTile(TimeTileNode),
}

/// See [`Node::Loop`].
#[derive(Debug, Clone)]
pub struct LoopNode {
    pub dim: String,
    pub level: usize,
    pub lo: Bound,
    pub hi: Bound,
    pub body: Vec<Node>,
}

/// See [`Node::Strip`]. The three phases share the strip variable: the
/// head (if any) runs scalar iterations up to the first multiple of
/// `lanes`, the steady loop advances `lanes` at a time, the remainder
/// finishes scalar.
#[derive(Debug, Clone)]
pub struct StripNode {
    pub dim: String,
    pub level: usize,
    pub lo: Bound,
    pub hi: Bound,
    pub lanes: usize,
    /// `true` = outer-dim strip (whole inner nest per strip, lane loops
    /// at each kernel invocation); `false` = innermost lane-fission
    /// strip (steady body is [`Node::MemberStrip`]s).
    pub outer: bool,
    /// Scalar alignment-head body (aligned specialization). `None` when
    /// the plan is unaligned — or when `static_aligned` proves the peel
    /// unnecessary.
    pub head: Option<Vec<Node>>,
    /// The aligned specialization was requested and `lo` is statically a
    /// multiple of `lanes` (constant bound, offset divisible), so the
    /// head peel was elided at compile time.
    pub static_aligned: bool,
    pub steady: Vec<Node>,
    pub remainder: Vec<Node>,
}

/// See [`Node::Parallel`]. The wrapped body's loop/strip bounds have
/// been rewritten to [`ParallelNode::lo_sym`]/[`ParallelNode::hi_sym`],
/// which every walker binds per chunk — the node itself keeps the full
/// range and the chunking parameters, so no backend re-derives shape.
#[derive(Debug, Clone)]
pub struct ParallelNode {
    pub dim: String,
    pub level: usize,
    /// Full range of the parallelized level.
    pub lo: Bound,
    pub hi: Bound,
    /// Chunk granule in iterations: 1 for a plain loop, `lanes` for a
    /// strip-mined level (chunk boundaries never split a steady strip).
    pub unit: usize,
    /// Storage ids each chunk must privatize (intermediates contracted
    /// along `dim`, proven nest-local by the legality gate); all other
    /// storages are shared — chunk writes land in disjoint slabs.
    pub private_storages: Vec<usize>,
    pub body: Vec<Node>,
}

impl ParallelNode {
    /// Extent symbol the body's lower bounds reference; bound per chunk.
    pub fn lo_sym(&self) -> String {
        par_lo_sym(self.level)
    }
    /// Extent symbol the body's upper bounds reference; bound per chunk.
    pub fn hi_sym(&self) -> String {
        par_hi_sym(self.level)
    }
}

/// Chunk lower-bound symbol for a parallel level (a valid C/Rust
/// identifier, so emitters declare a variable of the same name).
pub fn par_lo_sym(level: usize) -> String {
    format!("hfav_par_lo{level}")
}
/// Chunk upper-bound symbol for a parallel level.
pub fn par_hi_sym(level: usize) -> String {
    format!("hfav_par_hi{level}")
}

/// See [`Node::TimeTile`]. The node is pure syntax to every walker: the
/// legality proof (bounded halos, warm-up depths) lives in
/// `analysis::time_tile_depths`, and the lowering here froze its results
/// into clamp intervals and warm-up sub-schedules. The walk is:
///
/// ```text
/// for b in [lo, hi) step block:            # cache-resident block
///     b_hi = min(b + block, hi)
///     for s in 0..t_block:                 # sweep-steps per block
///         bind clamp syms: [max(seg_lo, b), min(seg_hi, b_hi))  per body node
///         if s > 0:
///             bind warm syms: [max(act_lo, b - depth), min(act_hi, b))  per warm entry
///             walk warm-up bodies in member order
///         walk body
/// ```
///
/// Pass `s = 0` of each block continues the previous block's window
/// state (blocks are contiguous); passes `s > 0` restart at `b` after
/// windows marched to `b_hi`, so each warm-up replays its member over
/// the trailing `depth` iterations, idempotently rebuilding exactly the
/// cells reads at the block base reach back to. Every re-executed
/// invocation rewrites the same value at the same coordinate, so
/// results stay bitwise identical to the untiled sweep while one call
/// serves `t_block` coordinator steps.
#[derive(Debug, Clone)]
pub struct TimeTileNode {
    pub dim: String,
    /// Nest level of the blocked dim (always 0: the outermost loop).
    pub level: usize,
    /// Full range of the blocked level (chunk symbols under a
    /// [`Node::Parallel`] wrapper).
    pub lo: Bound,
    pub hi: Bound,
    /// Sweep-steps executed per block (>= 2; 1 never lowers this node).
    pub t_block: usize,
    /// Spatial block length in iterations; a multiple of `unit`, sized
    /// so verifier probe extents still form several blocks.
    pub block: usize,
    /// Iteration granule of the wrapped segments: 1 for plain loops,
    /// `lanes` for outer strips (blocks never split a steady strip).
    pub unit: usize,
    /// Max warm-up depth over all members (the halo; render/debug).
    pub halo: i64,
    /// Per-member warm-up replays, in member (producer-before-consumer)
    /// order; empty when every depth is 0.
    pub warmup: Vec<TimeTileWarm>,
    /// Original `[lo, hi)` of each body node, index-aligned with `body`;
    /// each pass binds that node's clamp symbols to the intersection
    /// with the current block.
    pub clamps: Vec<(Bound, Bound)>,
    /// The wrapped level-0 segments, bounds rewritten to clamp symbols.
    pub body: Vec<Node>,
}

/// One member's warm-up replay inside a [`TimeTileNode`]: a loop over
/// the warm symbols (bound per pass to `[max(lo, b − depth), min(hi,
/// b))`) running the member's inner sub-schedule.
#[derive(Debug, Clone)]
pub struct TimeTileWarm {
    /// Index into the fused nest's members.
    pub member: usize,
    /// Replay depth behind the block base, from the analysis fixpoint.
    pub depth: i64,
    /// The member's activity interval at the blocked level (warm bounds
    /// clamp into it so replays never leave the member's domain).
    pub lo: Bound,
    pub hi: Bound,
    /// A single level-0 loop over the warm symbols.
    pub body: Vec<Node>,
}

/// Per-pass clamp lower-bound symbol of body node `g` of a time-tile
/// level (a valid C/Rust identifier, like the parallel chunk symbols).
pub fn tt_lo_sym(level: usize, g: usize) -> String {
    format!("hfav_tt{level}_s{g}_lo")
}
/// Per-pass clamp upper-bound symbol of body node `g`.
pub fn tt_hi_sym(level: usize, g: usize) -> String {
    format!("hfav_tt{level}_s{g}_hi")
}
/// Warm-up replay lower-bound symbol of warm entry `g`.
pub fn tt_warm_lo_sym(level: usize, g: usize) -> String {
    format!("hfav_tt{level}_w{g}_lo")
}
/// Warm-up replay upper-bound symbol of warm entry `g`.
pub fn tt_warm_hi_sym(level: usize, g: usize) -> String {
    format!("hfav_tt{level}_w{g}_hi")
}

/// The one chunk-decomposition formula every consumer shares: split
/// `[lo, hi)` into at most `threads` chunks of whole `unit`-granules,
/// `ceil(units/threads)` granules per chunk (`len.div_ceil(threads)`
/// when `unit == 1`). Empty chunks are dropped; at `threads <= 1` the
/// single chunk is the full range. The source emitters print this same
/// arithmetic symbolically — [`tests::chunk_spans_cover_exactly`] and
/// the differential suite pin the agreement.
pub fn chunk_spans(lo: i64, hi: i64, unit: usize, threads: usize) -> Vec<(i64, i64)> {
    let len = hi - lo;
    if len <= 0 {
        return Vec::new();
    }
    let unit = unit.max(1) as i64;
    let units = (len + unit - 1) / unit;
    let t = (threads.max(1) as i64).min(units);
    let per = ((units + t - 1) / t) * unit;
    (0..t)
        .map(|c| {
            let clo = lo + c * per;
            (clo, (clo + per).min(hi))
        })
        .filter(|(a, b)| a < b)
        .collect()
}

/// See [`Node::Guarded`].
#[derive(Debug, Clone)]
pub struct GuardedNode {
    pub dim: String,
    pub level: usize,
    pub lo: Bound,
    pub hi: Bound,
    pub arms: Vec<GuardedArm>,
}

/// One member's activity interval and sub-schedule inside a guarded loop.
#[derive(Debug, Clone)]
pub struct GuardedArm {
    pub lo: Bound,
    pub hi: Bound,
    pub body: Vec<Node>,
}

/// A lane loop along one nest dim: `lanes` consecutive values of the
/// strip variable run as concurrent vector lanes.
#[derive(Debug, Clone)]
pub struct LaneLoop {
    pub dim: String,
    pub level: usize,
    pub lanes: usize,
}

/// See [`Node::Invoke`].
#[derive(Debug, Clone)]
pub struct Invoke {
    /// Index into the fused nest's members.
    pub member: usize,
    /// The member's callsite id (into [`Dataflow::callsites`]).
    pub callsite: usize,
    /// Callsite name (for rendering and emitted comments).
    pub name: String,
    /// Outer-lane expansion: the invocation becomes a simd lane loop
    /// along this dim (legal per the outer k-independence gate).
    pub lanes: Option<LaneLoop>,
}

/// See [`Node::MemberStrip`].
#[derive(Debug, Clone)]
pub struct MemberStrip {
    /// Index into the fused nest's members.
    pub member: usize,
    /// The member's callsite id.
    pub callsite: usize,
    /// Callsite name.
    pub name: String,
    /// The innermost (strip) dim and its nest level.
    pub dim: String,
    pub level: usize,
    pub lanes: usize,
    /// Lane loop may carry a simd pragma with window accesses staged
    /// through lane-local arrays (in-register rotation); `false` =
    /// loop-carried member, lanes stay sequential.
    pub simd: bool,
    /// Multi-dim tiling: each inner lane additionally expands across
    /// these outer lanes (a `lanes × outer.lanes` tile per invocation).
    pub outer: Option<LaneLoop>,
}

// ---------------------------------------------------------------------------
// Shared symbolic-bound helpers
// ---------------------------------------------------------------------------

/// Partial order on symbolic bounds under the "extents are large"
/// assumption: constants sort below any extent-based bound; same-base
/// bounds compare by offset; distinct extent bases are incomparable.
pub fn cmp_bound(a: &Bound, b: &Bound) -> Option<std::cmp::Ordering> {
    match (&a.base, &b.base) {
        (None, None) => Some(a.offset.cmp(&b.offset)),
        (None, Some(_)) => Some(std::cmp::Ordering::Less),
        (Some(_), None) => Some(std::cmp::Ordering::Greater),
        (Some(x), Some(y)) if x == y => Some(a.offset.cmp(&b.offset)),
        _ => None,
    }
}

/// Is `b` statically a multiple of `lanes` (constant bound)? When true
/// under the aligned specialization, the scalar alignment head is a
/// compile-time no-op and the lowering elides it.
pub fn statically_aligned(b: &Bound, lanes: usize) -> bool {
    lanes > 0 && b.base.is_none() && b.offset.rem_euclid(lanes as i64) == 0
}

// ---------------------------------------------------------------------------
// Strip access decomposition (shared by both source emitters)
// ---------------------------------------------------------------------------

/// Innermost-dim contribution of one access inside a lane-fission strip.
pub enum StripInner {
    /// Rolling window (vector-expanded): wrap base+lane through the pow2
    /// mask. Staged into lane-local arrays by the emitters.
    Window {
        add: i64,
        mask: i64,
        stride: String,
    },
    /// Full span: linear in the lane index.
    Full {
        add: i64,
        lo: String,
        stride: String,
    },
}

/// One access split into a lane-invariant part and the innermost-dim
/// contribution.
pub struct StripAccess {
    pub sid: usize,
    /// Lane-invariant index terms (outer dims), `"0"` if none.
    pub outer: String,
    /// Innermost-dim contribution; `None` = the whole access is
    /// lane-invariant (variable lacks the dim, or single slot).
    pub inner: Option<StripInner>,
}

/// Decompose an access for strip emission. Index sub-expressions are
/// rendered in the C-compatible spelling both source emitters share
/// (stride names `st<sid>_<k>`, positions over the loop variables), so
/// the decomposition — like every other shape fact — exists once.
pub fn strip_access(
    df: &Dataflow,
    sp: &StoragePlan,
    nest: &FusedNest,
    m: &Member,
    vid: usize,
    offsets: &[i64],
) -> Result<StripAccess, String> {
    let var = &df.vars[vid];
    let sid = sp.of_var[vid];
    let st = &sp.storages[sid];
    let innermost = nest.dims.last().cloned().unwrap_or_default();
    let mut outer_terms = Vec::new();
    let mut inner = None;
    for (k, d) in var.dims.iter().enumerate() {
        let level = nest.dim_index(d).ok_or("dim not in nest")?;
        let shift = if m.roles[level] == Role::Loop { m.shifts[level] } else { 0 };
        let add = shift + offsets[k];
        let stride = format!("st{sid}_{k}");
        if *d == innermost {
            match &st.sizes[k] {
                DimSize::One => {}
                DimSize::Window { alloc, .. } => {
                    inner = Some(StripInner::Window { add, mask: alloc - 1, stride });
                }
                DimSize::Full => {
                    let lo = &var.span[d].lo;
                    let lo_expr = if lo.base.is_none() && lo.offset == 0 {
                        String::new()
                    } else {
                        bound_text(lo)
                    };
                    inner = Some(StripInner::Full { add, lo: lo_expr, stride });
                }
            }
        } else {
            let pos = pos_text(d, add);
            match &st.sizes[k] {
                DimSize::One => continue,
                DimSize::Window { alloc, .. } => {
                    outer_terms.push(format!("({pos} & {}) * {stride}", alloc - 1))
                }
                DimSize::Full => {
                    let lo = &var.span[d].lo;
                    let idx = if lo.base.is_none() && lo.offset == 0 {
                        pos
                    } else {
                        format!("({pos} - {})", bound_text(lo))
                    };
                    outer_terms.push(format!("{idx} * {stride}"));
                }
            }
        }
    }
    let outer = if outer_terms.is_empty() { "0".to_string() } else { outer_terms.join(" + ") };
    Ok(StripAccess { sid, outer, inner })
}

/// Render a symbolic bound as a C/Rust expression over extent variables
/// — the single spelling shared by [`strip_access`] and both source
/// emitters (which delegate here), so index strings and the loop
/// variables they reference can never desynchronize.
pub fn bound_text(b: &Bound) -> String {
    match &b.base {
        None => format!("{}", b.offset),
        Some(base) => match b.offset.cmp(&0) {
            std::cmp::Ordering::Equal => base.clone(),
            std::cmp::Ordering::Greater => format!("({base} + {})", b.offset),
            std::cmp::Ordering::Less => format!("({base} - {})", -b.offset),
        },
    }
}

/// Position expression `base + add` over a loop-variable expression —
/// shared with both emitters like [`bound_text`].
pub fn pos_text(base: &str, add: i64) -> String {
    match add.cmp(&0) {
        std::cmp::Ordering::Equal => base.to_string(),
        std::cmp::Ordering::Greater => format!("({base} + {add})"),
        std::cmp::Ordering::Less => format!("({base} - {})", -add),
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

/// Lower a compiled pipeline (fused DAG + storage plan + resolved
/// options) into the schedule tree. Called exactly once, by
/// [`crate::plan::compile`]; every shape decision the backends used to
/// make lives here.
pub fn lower(
    deck: &Deck,
    df: &Dataflow,
    fd: &FusedDag,
    sp: &StoragePlan,
    opts: &CompileOptions,
) -> Result<Schedule, String> {
    let vl = analysis::resolve_vector_len(deck, &opts.analysis);
    let outer: Option<String> = match &opts.analysis.vec_dim {
        analysis::VecDim::Outer(d) if vl > 1 => Some(d.clone()),
        _ => None,
    };
    let tiled = opts.analysis.tile && outer.is_some() && vl > 1;
    let mut nests = Vec::new();
    for (ni, nest) in fd.nests.iter().enumerate() {
        let cx = Lower {
            df,
            sp,
            nest,
            vl,
            outer: outer.as_deref(),
            tiled,
            aligned: opts.aligned,
        };
        let all: Vec<usize> = (0..nest.members.len()).collect();
        let mut body = cx.level(&all, 0, None)?;
        // Temporal blocking: wrap the level-0 segments in a time-tile
        // node when requested and legal. Decks with in/out aliases chain
        // state across steps (a sweep is not idempotent), so they — like
        // nests failing the bounded-halo gate — fall back to untiled.
        let tt = opts.analysis.time_tile.max(1);
        if tt > 1 && deck.aliases.is_empty() {
            if let Some(depths) = analysis::time_tile_depths(df, sp, nest) {
                body = cx.wrap_time_tile(body, &depths, tt)?;
            }
        }
        if let Some(d0) = nest.dims.first() {
            if nest.dims.len() > 1 {
                if let Some(private) = analysis::parallel_safe(df, sp, nest, ni, d0) {
                    body = wrap_parallel(body, d0, &private);
                }
            }
        }
        nests.push(NestPlan { nest: ni, dims: nest.dims.clone(), body });
    }
    let mut sched = Schedule { nests, digest: 0 };
    let mut h = Fnv64::new();
    h.write_str(&sched.render());
    sched.digest = h.finish();
    Ok(sched)
}

/// Wrap the qualifying level-0 segments of a legal nest in
/// [`Node::Parallel`] levels. A segment qualifies when chunk boundaries
/// cannot change what runs: a plain level-0 [`Node::Loop`] over the dim
/// (granule 1), or a head-less level-0 outer [`Node::Strip`] (granule
/// `lanes`, so chunks never split a steady strip; runtime alignment
/// heads would peel per chunk instead of once, so those stay serial).
/// Guarded fallbacks and pre/post sub-schedules stay serial too. The
/// wrapped node's bounds are rewritten to the chunk symbols.
fn wrap_parallel(body: Vec<Node>, dim: &str, private: &[usize]) -> Vec<Node> {
    body.into_iter()
        .map(|n| match n {
            Node::Loop(l) if l.level == 0 && l.dim == dim => {
                let (lo, hi) = (l.lo.clone(), l.hi.clone());
                let inner = Node::Loop(LoopNode {
                    lo: Bound::of(&par_lo_sym(0), 0),
                    hi: Bound::of(&par_hi_sym(0), 0),
                    ..l
                });
                Node::Parallel(ParallelNode {
                    dim: dim.to_string(),
                    level: 0,
                    lo,
                    hi,
                    unit: 1,
                    private_storages: private.to_vec(),
                    body: vec![inner],
                })
            }
            Node::Strip(s) if s.level == 0 && s.dim == dim && s.outer && s.head.is_none() => {
                let (lo, hi) = (s.lo.clone(), s.hi.clone());
                let unit = s.lanes;
                let inner = Node::Strip(StripNode {
                    lo: Bound::of(&par_lo_sym(0), 0),
                    hi: Bound::of(&par_hi_sym(0), 0),
                    ..s
                });
                Node::Parallel(ParallelNode {
                    dim: dim.to_string(),
                    level: 0,
                    lo,
                    hi,
                    unit,
                    private_storages: private.to_vec(),
                    body: vec![inner],
                })
            }
            // A time-tile level chunks by whole spatial blocks, so chunk
            // boundaries never split one. `parallel_safe` implies zero
            // warm-up depths (k-independence forces every halo edge to
            // delta 0), so the wrapped node carries no cross-chunk
            // replays and chunk writes stay disjoint per pass.
            Node::TimeTile(t) if t.level == 0 && t.dim == dim && t.warmup.is_empty() => {
                let (lo, hi) = (t.lo.clone(), t.hi.clone());
                let unit = t.block;
                let inner = Node::TimeTile(TimeTileNode {
                    lo: Bound::of(&par_lo_sym(0), 0),
                    hi: Bound::of(&par_hi_sym(0), 0),
                    ..t
                });
                Node::Parallel(ParallelNode {
                    dim: dim.to_string(),
                    level: 0,
                    lo,
                    hi,
                    unit,
                    private_storages: private.to_vec(),
                    body: vec![inner],
                })
            }
            other => other,
        })
        .collect()
}

/// Per-nest lowering context.
struct Lower<'a> {
    df: &'a Dataflow,
    sp: &'a StoragePlan,
    nest: &'a FusedNest,
    /// Effective vector length (>= 1).
    vl: usize,
    /// Resolved outer lane dim (only when `vl > 1`).
    outer: Option<&'a str>,
    tiled: bool,
    aligned: bool,
}

impl Lower<'_> {
    /// Inner lane-fission strips are shaped only when the storage plan
    /// carries the matching window padding: always under `VecDim::Inner`,
    /// and under an outer lane dim only when tiling re-enables it.
    fn inner_lanes(&self) -> bool {
        self.vl > 1 && (self.outer.is_none() || self.tiled)
    }

    fn invoke(&self, mi: usize, octx: Option<&LaneLoop>) -> Node {
        let cs = self.nest.members[mi].callsite;
        Node::Invoke(Invoke {
            member: mi,
            callsite: cs,
            name: self.df.callsites[cs].name.clone(),
            lanes: octx.cloned(),
        })
    }

    /// Activity interval of a member at a nest level, in loop coordinates.
    fn interval(&self, mi: usize, level: usize) -> (Bound, Bound) {
        let m = &self.nest.members[mi];
        let cs = &self.df.callsites[m.callsite];
        let dom = &cs.domain[&self.nest.dims[level]];
        (dom.lo.plus(-m.shifts[level]), dom.hi.plus(-m.shifts[level]))
    }

    /// Static peeling: split the level's range into segments with fixed
    /// active sets, if all interval endpoints are mutually orderable.
    #[allow(clippy::type_complexity)]
    fn segments(&self, inl: &[usize], level: usize) -> Option<Vec<(Bound, Bound, Vec<usize>)>> {
        let ivals: Vec<(Bound, Bound)> =
            inl.iter().map(|&mi| self.interval(mi, level)).collect();
        let mut cuts: Vec<Bound> = Vec::new();
        for (a, b) in &ivals {
            cuts.push(a.clone());
            cuts.push(b.clone());
        }
        let mut ok = true;
        cuts.sort_by(|a, b| match cmp_bound(a, b) {
            Some(o) => o,
            None => {
                ok = false;
                std::cmp::Ordering::Equal
            }
        });
        if !ok {
            return None;
        }
        cuts.dedup();
        let mut segs = Vec::new();
        for w in cuts.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            let mut active = Vec::new();
            for (k, (lo, hi)) in ivals.iter().enumerate() {
                let c1 = cmp_bound(lo, a)?;
                let c2 = cmp_bound(b, hi)?;
                if c1 != std::cmp::Ordering::Greater && c2 != std::cmp::Ordering::Greater {
                    active.push(inl[k]);
                }
            }
            if !active.is_empty() {
                segs.push((a.clone(), b.clone(), active));
            }
        }
        Some(segs)
    }

    /// Can this member's lane loop carry a simd hint (no loop-carried
    /// dependence across lanes)? Reductions, accumulator chains (read
    /// and write the same storage) and lane-invariant writes must stay
    /// sequential.
    fn member_simd_safe(&self, mi: usize) -> bool {
        let m = &self.nest.members[mi];
        let cs = &self.df.callsites[m.callsite];
        if !cs.reduce_dims.is_empty() {
            return false;
        }
        let wsids: BTreeSet<usize> =
            cs.writes.iter().map(|(_, vid, _)| self.sp.of_var[*vid]).collect();
        if cs.reads.iter().any(|(_, vid, _)| wsids.contains(&self.sp.of_var[*vid])) {
            return false;
        }
        let innermost = match self.nest.dims.last() {
            Some(d) => d,
            None => return false,
        };
        for (_, vid, _) in &cs.writes {
            let var = &self.df.vars[*vid];
            match var.dims.iter().position(|d| d == innermost) {
                Some(k) => {
                    if matches!(self.sp.storage_of(*vid).sizes[k], DimSize::One) {
                        return false;
                    }
                }
                None => return false,
            }
        }
        true
    }

    /// Lower one nest level for a member subset. `octx` carries an
    /// active outer lane loop (set inside an outer strip's steady body).
    fn level(
        &self,
        members: &[usize],
        level: usize,
        octx: Option<&LaneLoop>,
    ) -> Result<Vec<Node>, String> {
        let nest = self.nest;
        if level == nest.dims.len() {
            return Ok(members.iter().map(|&mi| self.invoke(mi, octx)).collect());
        }
        let role = |mi: usize| nest.members[mi].roles[level];
        let pre: Vec<usize> = members.iter().copied().filter(|&m| role(m) == Role::Pre).collect();
        let inl: Vec<usize> = members.iter().copied().filter(|&m| role(m) == Role::Loop).collect();
        let post: Vec<usize> =
            members.iter().copied().filter(|&m| role(m) == Role::Post).collect();

        let mut out = self.level(&pre, level + 1, octx)?;
        if !inl.is_empty() {
            let dim = nest.dims[level].clone();
            let innermost = level + 1 == nest.dims.len();
            let outer_here = octx.is_none()
                && !innermost
                && self.outer == Some(dim.as_str())
                && analysis::outer_vectorizable(self.df, nest, &dim);
            match self.segments(&inl, level) {
                Some(segs) => {
                    for (lo, hi, act) in segs {
                        if outer_here {
                            out.push(self.outer_strip(&act, level, lo, hi)?);
                        } else if innermost && self.inner_lanes() && self.fission_safe(&act) {
                            out.push(self.inner_strip(&act, level, lo, hi, octx)?);
                        } else {
                            out.push(Node::Loop(LoopNode {
                                dim: dim.clone(),
                                level,
                                lo,
                                hi,
                                body: self.level(&act, level + 1, octx)?,
                            }));
                        }
                    }
                }
                None => {
                    // Guarded fallback: one uniform loop, per-member guards.
                    let mut lo: Option<Bound> = None;
                    let mut hi: Option<Bound> = None;
                    for &mi in &inl {
                        let (a, b) = self.interval(mi, level);
                        lo = Some(match lo {
                            None => a,
                            Some(x) => crate::dataflow::bound_min(&x, &a)?,
                        });
                        hi = Some(match hi {
                            None => b,
                            Some(x) => crate::dataflow::bound_max(&x, &b)?,
                        });
                    }
                    let mut arms = Vec::with_capacity(inl.len());
                    for &mi in &inl {
                        let (a, b) = self.interval(mi, level);
                        arms.push(GuardedArm {
                            lo: a,
                            hi: b,
                            body: self.level(&[mi], level + 1, octx)?,
                        });
                    }
                    out.push(Node::Guarded(GuardedNode {
                        dim,
                        level,
                        lo: lo.unwrap(),
                        hi: hi.unwrap(),
                        arms,
                    }));
                }
            }
        }
        out.extend(self.level(&post, level + 1, octx)?);
        Ok(out)
    }

    fn fission_safe(&self, act: &[usize]) -> bool {
        let ms: Vec<&Member> = act.iter().map(|&mi| &self.nest.members[mi]).collect();
        analysis::lane_fission_safe(self.df, self.sp, self.nest, &ms)
    }

    /// One peeled segment of the outer lane dim, strip-mined by `vl`:
    /// the whole inner nest runs per strip with every kernel invocation
    /// expanded across the lanes; head (when not statically aligned
    /// under `--aligned`) and remainder reuse the scalar sub-schedule.
    fn outer_strip(
        &self,
        act: &[usize],
        level: usize,
        lo: Bound,
        hi: Bound,
    ) -> Result<Node, String> {
        let dim = self.nest.dims[level].clone();
        let lane = LaneLoop { dim: dim.clone(), level, lanes: self.vl };
        let provable = statically_aligned(&lo, self.vl);
        let head = if self.aligned && !provable {
            Some(self.level(act, level + 1, None)?)
        } else {
            None
        };
        let steady = self.level(act, level + 1, Some(&lane))?;
        let remainder = self.level(act, level + 1, None)?;
        Ok(Node::Strip(StripNode {
            dim,
            level,
            lo,
            hi,
            lanes: self.vl,
            outer: true,
            head,
            static_aligned: self.aligned && provable,
            steady,
            remainder,
        }))
    }

    /// One peeled innermost segment, lane-fissioned by `vl`: the steady
    /// body runs each member across the whole strip before the next
    /// ([`MemberStrip`]); head and remainder run the plain scalar
    /// invocations. Under tiling (`octx` set) every lane additionally
    /// expands across the outer lanes.
    fn inner_strip(
        &self,
        act: &[usize],
        level: usize,
        lo: Bound,
        hi: Bound,
        octx: Option<&LaneLoop>,
    ) -> Result<Node, String> {
        let dim = self.nest.dims[level].clone();
        let provable = statically_aligned(&lo, self.vl);
        let scalar: Vec<Node> = act.iter().map(|&mi| self.invoke(mi, octx)).collect();
        let head = if self.aligned && !provable { Some(scalar.clone()) } else { None };
        let steady = act
            .iter()
            .map(|&mi| {
                let cs = self.nest.members[mi].callsite;
                Node::MemberStrip(MemberStrip {
                    member: mi,
                    callsite: cs,
                    name: self.df.callsites[cs].name.clone(),
                    dim: dim.clone(),
                    level,
                    lanes: self.vl,
                    simd: self.member_simd_safe(mi),
                    outer: octx.cloned(),
                })
            })
            .collect();
        Ok(Node::Strip(StripNode {
            dim,
            level,
            lo,
            hi,
            lanes: self.vl,
            outer: false,
            head,
            static_aligned: self.aligned && provable,
            steady,
            remainder: scalar,
        }))
    }

    /// Wrap the lowered level-0 segments of this nest in a
    /// [`Node::TimeTile`]. `depths` are the per-member warm-up depths
    /// proven by `analysis::time_tile_depths`. Returns the body
    /// unchanged (untiled fallback) when any top node is not a plain
    /// level-0 loop/strip segment over the outermost dim — a guarded
    /// fallback has no statically orderable clamp intervals.
    fn wrap_time_tile(
        &self,
        body: Vec<Node>,
        depths: &[i64],
        t_block: usize,
    ) -> Result<Vec<Node>, String> {
        let dim = match self.nest.dims.first() {
            Some(d) => d.clone(),
            None => return Ok(body),
        };
        if body.is_empty() {
            return Ok(body);
        }
        let mut unit = 1usize;
        for n in &body {
            match n {
                Node::Loop(l) if l.level == 0 && l.dim == dim => {}
                Node::Strip(s) if s.level == 0 && s.dim == dim => unit = unit.max(s.lanes),
                _ => return Ok(body),
            }
        }
        let span_of = |n: &Node| -> (Bound, Bound) {
            match n {
                Node::Loop(l) => (l.lo.clone(), l.hi.clone()),
                Node::Strip(s) => (s.lo.clone(), s.hi.clone()),
                _ => unreachable!("checked above"),
            }
        };
        // Segments come out of static peeling in ascending cut order, so
        // the union span is [first lo, last hi).
        let lo = span_of(&body[0]).0;
        let hi = span_of(body.last().unwrap()).1;
        let halo = depths.iter().copied().max().unwrap_or(0);
        // Block sizing: a multiple of the segment granule, at least
        // halo + 1 (a warm-up must fit behind one block) and at least
        // two granules — and deliberately small, so the verifier's probe
        // extents still form several blocks and exercise the warm-up
        // path. Cache residency wants small blocks anyway: the working
        // set per pass is block × inner-dim slabs.
        let unit_i = unit as i64;
        let block = ((halo + 1).max(2 * unit_i) + unit_i - 1) / unit_i * unit_i;
        let level = 0usize;
        let mut clamps = Vec::with_capacity(body.len());
        let mut new_body = Vec::with_capacity(body.len());
        for (g, n) in body.into_iter().enumerate() {
            clamps.push(span_of(&n));
            let clo = Bound::of(&tt_lo_sym(level, g), 0);
            let chi = Bound::of(&tt_hi_sym(level, g), 0);
            new_body.push(match n {
                Node::Loop(l) => Node::Loop(LoopNode { lo: clo, hi: chi, ..l }),
                // Clamped strip bases are runtime values, so a
                // compile-time alignment proof no longer holds.
                Node::Strip(s) => {
                    Node::Strip(StripNode { lo: clo, hi: chi, static_aligned: false, ..s })
                }
                other => other,
            });
        }
        let mut warmup = Vec::new();
        for (mi, &d) in depths.iter().enumerate() {
            if d <= 0 {
                continue;
            }
            let g = warmup.len();
            let (ilo, ihi) = self.interval(mi, 0);
            let inner = self.level(&[mi], 1, None)?;
            warmup.push(TimeTileWarm {
                member: mi,
                depth: d,
                lo: ilo,
                hi: ihi,
                body: vec![Node::Loop(LoopNode {
                    dim: dim.clone(),
                    level,
                    lo: Bound::of(&tt_warm_lo_sym(level, g), 0),
                    hi: Bound::of(&tt_warm_hi_sym(level, g), 0),
                    body: inner,
                })],
            });
        }
        Ok(vec![Node::TimeTile(TimeTileNode {
            dim,
            level,
            lo,
            hi,
            t_block,
            block: block as usize,
            unit,
            halo,
            warmup,
            clamps,
            body: new_body,
        })])
    }
}

// ---------------------------------------------------------------------------
// Rendering (digest + human-readable dump)
// ---------------------------------------------------------------------------

impl Schedule {
    /// Human-readable dump of the lowered tree — the one place "which
    /// loops actually run" can be read off (CLI: `generate --backend
    /// schedule-ir`). [`Schedule::digest`] fingerprints this text.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for np in &self.nests {
            let _ = writeln!(s, "nest {} over ({}):", np.nest, np.dims.join(","));
            render_nodes(&np.body, 1, &mut s);
        }
        s
    }
}

fn render_nodes(nodes: &[Node], indent: usize, s: &mut String) {
    let pad = "  ".repeat(indent);
    for n in nodes {
        match n {
            Node::Loop(l) => {
                let _ = writeln!(s, "{pad}for {} in [{}, {}):", l.dim, l.lo, l.hi);
                render_nodes(&l.body, indent + 1, s);
            }
            Node::Strip(t) => {
                let kind = if t.outer { "outer-strip" } else { "strip" };
                let mut flags = String::new();
                if t.head.is_some() {
                    flags.push_str(" +aligned-head");
                }
                if t.static_aligned {
                    flags.push_str(" +static-aligned");
                }
                let _ = writeln!(
                    s,
                    "{pad}{kind} {} in [{}, {}) x{}{}:",
                    t.dim, t.lo, t.hi, t.lanes, flags
                );
                if let Some(h) = &t.head {
                    let _ = writeln!(s, "{pad}  head:");
                    render_nodes(h, indent + 2, s);
                }
                let _ = writeln!(s, "{pad}  steady:");
                render_nodes(&t.steady, indent + 2, s);
                let _ = writeln!(s, "{pad}  remainder:");
                render_nodes(&t.remainder, indent + 2, s);
            }
            Node::Guarded(g) => {
                let _ = writeln!(s, "{pad}guarded {} in [{}, {}):", g.dim, g.lo, g.hi);
                for a in &g.arms {
                    let _ = writeln!(s, "{pad}  when [{}, {}):", a.lo, a.hi);
                    render_nodes(&a.body, indent + 2, s);
                }
            }
            Node::Invoke(i) => match &i.lanes {
                Some(l) => {
                    let _ = writeln!(s, "{pad}{} x{} along {}", i.name, l.lanes, l.dim);
                }
                None => {
                    let _ = writeln!(s, "{pad}{}", i.name);
                }
            },
            Node::Parallel(p) => {
                let privs = if p.private_storages.is_empty() {
                    String::new()
                } else {
                    let ids: Vec<String> =
                        p.private_storages.iter().map(|s| format!("b{s}")).collect();
                    format!(" private[{}]", ids.join(","))
                };
                let _ = writeln!(
                    s,
                    "{pad}parallel {} in [{}, {}) chunk-unit {}{}:",
                    p.dim, p.lo, p.hi, p.unit, privs
                );
                render_nodes(&p.body, indent + 1, s);
            }
            Node::TimeTile(t) => {
                let _ = writeln!(
                    s,
                    "{pad}time-tile {} in [{}, {}) x{} block {} unit {} halo {}:",
                    t.dim, t.lo, t.hi, t.t_block, t.block, t.unit, t.halo
                );
                for w in &t.warmup {
                    let _ = writeln!(
                        s,
                        "{pad}  warmup m{} depth {} within [{}, {}):",
                        w.member, w.depth, w.lo, w.hi
                    );
                    render_nodes(&w.body, indent + 2, s);
                }
                for (g, (clo, chi)) in t.clamps.iter().enumerate() {
                    let _ = writeln!(s, "{pad}  clamp s{g} to [{clo}, {chi})");
                }
                let _ = writeln!(s, "{pad}  body:");
                render_nodes(&t.body, indent + 2, s);
            }
            Node::MemberStrip(m) => {
                let how = if m.simd { "simd" } else { "sequential" };
                match &m.outer {
                    Some(o) => {
                        let _ = writeln!(
                            s,
                            "{pad}{} tile {}x{} along {},{} ({how})",
                            m.name, m.lanes, o.lanes, m.dim, o.dim
                        );
                    }
                    None => {
                        let _ = writeln!(
                            s,
                            "{pad}{} strip x{} along {} ({how})",
                            m.name, m.lanes, m.dim
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reference walker
// ---------------------------------------------------------------------------

impl Schedule {
    /// Enumerate kernel invocations in exactly the order the emitted
    /// code executes them, calling `f(nest_plan_index, member_index,
    /// idx)` for each (idx holds the loop variables by nest level). This
    /// is the reference semantics of the tree: the interpreter executor
    /// must visit the same sequence (pinned by the property suite).
    pub fn visit<F>(&self, extents: &BTreeMap<String, i64>, f: &mut F) -> Result<(), String>
    where
        F: FnMut(usize, usize, &[i64]),
    {
        self.visit_threads(extents, 1, f)
    }

    /// [`Schedule::visit`] at an explicit chunk-worker count: parallel
    /// levels enumerate their [`chunk_spans`] in chunk order, each chunk
    /// sequentially — the reference partition a threaded executor's
    /// per-chunk invocation sets must match exactly. At `threads == 1`
    /// this is the plain serial order.
    pub fn visit_threads<F>(
        &self,
        extents: &BTreeMap<String, i64>,
        threads: usize,
        f: &mut F,
    ) -> Result<(), String>
    where
        F: FnMut(usize, usize, &[i64]),
    {
        for (k, np) in self.nests.iter().enumerate() {
            let mut idx = vec![0i64; np.dims.len()];
            visit_nodes(k, &np.body, extents, threads, &mut idx, f)?;
        }
        Ok(())
    }

    /// Walk-derived cost counters over concrete extents — the seed of
    /// the ROADMAP cost model. `cost(nest_plan_idx, member_idx)` supplies
    /// (loads, stores) per invocation (see
    /// [`crate::plan::Program::schedule_stats`] for the dataflow-backed
    /// binding); parallel chunk counts come from [`chunk_spans`] at the
    /// given worker count.
    pub fn stats(
        &self,
        extents: &BTreeMap<String, i64>,
        threads: usize,
        cost: &dyn Fn(usize, usize) -> (u64, u64),
    ) -> Result<ScheduleStats, String> {
        let mut st = ScheduleStats::default();
        self.visit_threads(extents, threads, &mut |np, mi, _| {
            let (l, s) = cost(np, mi);
            st.invocations += 1;
            st.loads += l;
            st.stores += s;
        })?;
        for (k, np) in self.nests.iter().enumerate() {
            for n in &np.body {
                if let Node::Parallel(p) = n {
                    let (lo, hi) = (p.lo.eval(extents)?, p.hi.eval(extents)?);
                    st.parallel.push(ParallelStats {
                        nest: k,
                        dim: p.dim.clone(),
                        unit: p.unit,
                        span: (hi - lo).max(0),
                        chunks: chunk_spans(lo, hi, p.unit, threads).len(),
                    });
                }
            }
        }
        Ok(st)
    }
}

/// Output of [`Schedule::stats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScheduleStats {
    /// Kernel invocations the walk enumerates (lanes count individually).
    pub invocations: u64,
    /// Scalar loads implied by the invocations' read accesses.
    pub loads: u64,
    /// Scalar stores implied by the invocations' write accesses.
    pub stores: u64,
    /// One entry per parallel level, in nest order.
    pub parallel: Vec<ParallelStats>,
}

/// Chunk decomposition of one parallel level at a concrete shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParallelStats {
    pub nest: usize,
    pub dim: String,
    pub unit: usize,
    /// Iterations of the parallelized level.
    pub span: i64,
    /// Chunks actually formed at the queried worker count.
    pub chunks: usize,
}

impl ScheduleStats {
    /// One-line summary (CLI `generate --backend schedule-ir`, bench JSON).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} invocations, {} loads, {} stores",
            self.invocations, self.loads, self.stores
        );
        for p in &self.parallel {
            let _ = write!(
                s,
                "; nest {} parallel {} span {} unit {} -> {} chunks",
                p.nest, p.dim, p.span, p.unit, p.chunks
            );
        }
        s
    }
}

/// Walk one node sequence with explicit extents and index state — the
/// same traversal [`Schedule::visit_threads`] performs per nest, exposed
/// so external analyses (the static verifier, [`crate::verify`]) can
/// walk a sub-tree such as a single parallel chunk's body under
/// chunk-bound extents. `f(nest, member, idx)` fires per invocation in
/// reference order.
pub fn visit_body<F>(
    nest: usize,
    nodes: &[Node],
    extents: &BTreeMap<String, i64>,
    threads: usize,
    idx: &mut Vec<i64>,
    f: &mut F,
) -> Result<(), String>
where
    F: FnMut(usize, usize, &[i64]),
{
    visit_nodes(nest, nodes, extents, threads, idx, f)
}

fn visit_nodes<F>(
    nest: usize,
    nodes: &[Node],
    extents: &BTreeMap<String, i64>,
    threads: usize,
    idx: &mut Vec<i64>,
    f: &mut F,
) -> Result<(), String>
where
    F: FnMut(usize, usize, &[i64]),
{
    for n in nodes {
        match n {
            Node::Parallel(p) => {
                let (lo, hi) = (p.lo.eval(extents)?, p.hi.eval(extents)?);
                for (clo, chi) in chunk_spans(lo, hi, p.unit, threads) {
                    let mut ext = extents.clone();
                    ext.insert(p.lo_sym(), clo);
                    ext.insert(p.hi_sym(), chi);
                    visit_nodes(nest, &p.body, &ext, threads, idx, f)?;
                }
            }
            Node::Loop(l) => {
                let (lo, hi) = (l.lo.eval(extents)?, l.hi.eval(extents)?);
                let mut t = lo;
                while t < hi {
                    idx[l.level] = t;
                    visit_nodes(nest, &l.body, extents, threads, idx, f)?;
                    t += 1;
                }
            }
            Node::Strip(s) => {
                let (lo, hi) = (s.lo.eval(extents)?, s.hi.eval(extents)?);
                let lanes = s.lanes as i64;
                let mut t = lo;
                if let Some(head) = &s.head {
                    let he = (t + ((lanes - t.rem_euclid(lanes)) % lanes)).min(hi);
                    while t < he {
                        idx[s.level] = t;
                        visit_nodes(nest, head, extents, threads, idx, f)?;
                        t += 1;
                    }
                }
                let steady = t + ((hi - t) / lanes) * lanes;
                while t < steady {
                    idx[s.level] = t;
                    visit_nodes(nest, &s.steady, extents, threads, idx, f)?;
                    t += lanes;
                }
                while t < hi {
                    idx[s.level] = t;
                    visit_nodes(nest, &s.remainder, extents, threads, idx, f)?;
                    t += 1;
                }
            }
            Node::Guarded(g) => {
                let (lo, hi) = (g.lo.eval(extents)?, g.hi.eval(extents)?);
                let mut arms = Vec::with_capacity(g.arms.len());
                for a in &g.arms {
                    arms.push((a.lo.eval(extents)?, a.hi.eval(extents)?));
                }
                let mut t = lo;
                while t < hi {
                    idx[g.level] = t;
                    for (a, &(alo, ahi)) in g.arms.iter().zip(&arms) {
                        if t >= alo && t < ahi {
                            visit_nodes(nest, &a.body, extents, threads, idx, f)?;
                        }
                    }
                    t += 1;
                }
            }
            Node::TimeTile(t) => {
                let (lo, hi) = (t.lo.eval(extents)?, t.hi.eval(extents)?);
                let block = t.block as i64;
                let mut b = lo;
                while b < hi {
                    let bh = (b + block).min(hi);
                    for s in 0..t.t_block {
                        let mut ext = extents.clone();
                        for (g, (olo, ohi)) in t.clamps.iter().enumerate() {
                            let cl = olo.eval(extents)?.max(b);
                            let ch = ohi.eval(extents)?.min(bh).max(cl);
                            ext.insert(tt_lo_sym(t.level, g), cl);
                            ext.insert(tt_hi_sym(t.level, g), ch);
                        }
                        if s > 0 {
                            for (g, w) in t.warmup.iter().enumerate() {
                                let wl = w.lo.eval(extents)?.max(b - w.depth);
                                let wh = w.hi.eval(extents)?.min(b).max(wl);
                                ext.insert(tt_warm_lo_sym(t.level, g), wl);
                                ext.insert(tt_warm_hi_sym(t.level, g), wh);
                            }
                            for w in &t.warmup {
                                visit_nodes(nest, &w.body, &ext, threads, idx, f)?;
                            }
                        }
                        visit_nodes(nest, &t.body, &ext, threads, idx, f)?;
                    }
                    b = bh;
                }
            }
            Node::Invoke(inv) => match &inv.lanes {
                None => f(nest, inv.member, idx),
                Some(l) => {
                    let base = idx[l.level];
                    for k in 0..l.lanes as i64 {
                        idx[l.level] = base + k;
                        f(nest, inv.member, idx);
                    }
                    idx[l.level] = base;
                }
            },
            Node::MemberStrip(ms) => {
                let base = idx[ms.level];
                for il in 0..ms.lanes as i64 {
                    idx[ms.level] = base + il;
                    match &ms.outer {
                        None => f(nest, ms.member, idx),
                        Some(l) => {
                            let ob = idx[l.level];
                            for ol in 0..l.lanes as i64 {
                                idx[l.level] = ob + ol;
                                f(nest, ms.member, idx);
                            }
                            idx[l.level] = ob;
                        }
                    }
                }
                idx[ms.level] = base;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;
    use crate::plan::{compile_src, CompileOptions, Program};

    fn compile(src: &str, vlen: usize) -> Program {
        compile_src(
            src,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(vlen),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    }

    fn count_nodes(nodes: &[Node], pred: &dyn Fn(&Node) -> bool) -> usize {
        let mut n = 0;
        for node in nodes {
            if pred(node) {
                n += 1;
            }
            match node {
                Node::Loop(l) => n += count_nodes(&l.body, pred),
                Node::Strip(s) => {
                    if let Some(h) = &s.head {
                        n += count_nodes(h, pred);
                    }
                    n += count_nodes(&s.steady, pred) + count_nodes(&s.remainder, pred);
                }
                Node::Guarded(g) => {
                    for a in &g.arms {
                        n += count_nodes(&a.body, pred);
                    }
                }
                Node::Parallel(p) => n += count_nodes(&p.body, pred),
                Node::TimeTile(t) => {
                    for w in &t.warmup {
                        n += count_nodes(&w.body, pred);
                    }
                    n += count_nodes(&t.body, pred);
                }
                _ => {}
            }
        }
        n
    }

    fn count(prog: &Program, pred: &dyn Fn(&Node) -> bool) -> usize {
        prog.sched.nests.iter().map(|np| count_nodes(&np.body, pred)).sum()
    }

    #[test]
    fn bound_ordering_and_static_alignment() {
        use std::cmp::Ordering;
        assert_eq!(cmp_bound(&Bound::constant(0), &Bound::of("N", -1)), Some(Ordering::Less));
        assert_eq!(cmp_bound(&Bound::of("N", -1), &Bound::of("N", 0)), Some(Ordering::Less));
        assert_eq!(cmp_bound(&Bound::of("N", 0), &Bound::of("M", 0)), None);
        assert!(statically_aligned(&Bound::constant(0), 4));
        assert!(statically_aligned(&Bound::constant(8), 4));
        assert!(!statically_aligned(&Bound::constant(1), 4));
        assert!(!statically_aligned(&Bound::of("N", 0), 4), "symbolic lo is never provable");
    }

    #[test]
    fn scalar_plan_has_no_strips() {
        let prog = compile(testdecks::CHAIN1D, 1);
        assert_eq!(count(&prog, &|n| matches!(n, Node::Strip(_))), 0);
        assert!(count(&prog, &|n| matches!(n, Node::Loop(_))) >= 2, "peeled segments");
        let txt = prog.sched.render();
        assert!(txt.contains("for i in"), "{txt}");
        assert!(txt.contains("dbl"), "{txt}");
    }

    #[test]
    fn vector_plan_lowers_member_strips() {
        let prog = compile(testdecks::CHAIN1D, 4);
        let strips = count(&prog, &|n| matches!(n, Node::Strip(s) if !s.outer && s.lanes == 4));
        assert!(strips >= 1, "{}", prog.sched.render());
        let members = count(&prog, &|n| matches!(n, Node::MemberStrip(m) if m.outer.is_none()));
        assert!(members >= 2, "{}", prog.sched.render());
        // No alignment heads without the aligned specialization.
        assert_eq!(count(&prog, &|n| matches!(n, Node::Strip(s) if s.head.is_some())), 0);
    }

    #[test]
    fn outer_plan_lowers_outer_strips_and_lane_invokes() {
        let prog = compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(count(&prog, &|n| matches!(n, Node::Strip(s) if s.outer)) >= 1);
        // Steady invocations expand across the k lanes; no inner strips
        // without tiling (inner windows carry no padding).
        assert!(count(&prog, &|n| matches!(n, Node::Invoke(i) if i.lanes.is_some())) >= 1);
        assert_eq!(count(&prog, &|n| matches!(n, Node::MemberStrip(_))), 0);
    }

    #[test]
    fn tiled_plan_lowers_tiles() {
        let prog = compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                    tile: true,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        assert!(prog.tiled());
        // The steady×steady region holds member tiles: inner strips whose
        // members also expand across the outer lanes.
        let tiles = count(&prog, &|n| matches!(n, Node::MemberStrip(m) if m.outer.is_some()));
        assert!(tiles >= 1, "{}", prog.sched.render());
        assert!(count(&prog, &|n| matches!(n, Node::Strip(s) if s.outer)) >= 1);
        let txt = prog.sched.render();
        assert!(txt.contains("tile 4x4"), "{txt}");
    }

    #[test]
    fn aligned_heads_present_only_when_not_provable() {
        // chain1d's steady segment starts at 1 (not a multiple of 4):
        // runtime head. Its prologue segment starts at 0: head elided.
        let prog = compile_src(
            testdecks::CHAIN1D,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    ..Default::default()
                },
                aligned: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(count(&prog, &|n| matches!(n, Node::Strip(s) if s.head.is_some())) >= 1);
        assert!(count(&prog, &|n| matches!(n, Node::Strip(s) if s.static_aligned)) >= 1);
    }

    #[test]
    fn digest_is_stable_and_strategy_sensitive() {
        let a = compile(testdecks::CHAIN1D, 4);
        let b = compile(testdecks::CHAIN1D, 4);
        assert_eq!(a.sched.digest, b.sched.digest);
        let c = compile(testdecks::CHAIN1D, 1);
        assert_ne!(a.sched.digest, c.sched.digest, "vlen must move the digest");
        let d = compile(testdecks::CHAIN1D, 8);
        assert_ne!(a.sched.digest, d.sched.digest);
    }

    #[test]
    fn visit_enumerates_scalar_order() {
        // chain1d N=6: dbl runs one ahead of diff over i in [1, N-1).
        let prog = compile(testdecks::CHAIN1D, 1);
        let ext: BTreeMap<String, i64> = [("N".to_string(), 6i64)].into();
        let mut got = Vec::new();
        prog.sched
            .visit(&ext, &mut |np, mi, idx| {
                let nest = &prog.fd.nests[prog.sched.nests[np].nest];
                let cs = nest.members[mi].callsite;
                got.push((prog.df.callsites[cs].name.clone(), idx[0]));
            })
            .unwrap();
        // dbl interval [0, 4), diff interval [1, 5): prologue t=0 (dbl),
        // steady t=1..4 (dbl, diff), epilogue t=4 (diff).
        let want: Vec<(String, i64)> = [
            ("dbl", 0),
            ("dbl", 1),
            ("diff", 1),
            ("dbl", 2),
            ("diff", 2),
            ("dbl", 3),
            ("diff", 3),
            ("diff", 4),
        ]
        .iter()
        .map(|(n, i)| (n.to_string(), *i))
        .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn chunk_spans_cover_exactly() {
        // Coverage, order, and granule alignment across shapes.
        let shapes = [
            (1i64, 23i64, 4usize, 3usize),
            (0, 17, 1, 4),
            (2, 30, 4, 8),
            (0, 3, 4, 4),
            (5, 5, 1, 2),
        ];
        for (lo, hi, unit, threads) in shapes {
            let spans = chunk_spans(lo, hi, unit, threads);
            let mut t = lo;
            for &(a, b) in &spans {
                assert_eq!(a, t, "chunks must tile the range in order");
                assert!(b > a);
                assert_eq!((a - lo).rem_euclid(unit as i64), 0, "chunk start off-granule");
                t = b;
            }
            assert_eq!(t, if hi > lo { hi } else { lo }, "chunks must cover [{lo}, {hi})");
            assert!(spans.len() <= threads.max(1));
        }
        // threads <= 1: the single chunk is the whole range.
        assert_eq!(chunk_spans(3, 11, 4, 1), vec![(3, 11)]);
        assert_eq!(chunk_spans(3, 11, 4, 0), vec![(3, 11)]);
        // div_ceil split at unit 1: 10 over 4 threads -> 3,3,3,1.
        assert_eq!(chunk_spans(0, 10, 1, 4), vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
    }

    #[test]
    fn parallel_levels_wrap_k_independent_outer_dims() {
        // Scalar cosmo: the k loop is k-independent, so the level-0 loop
        // gains a Parallel wrapper whose body reads the chunk symbols;
        // contracted intermediates are recorded for per-chunk replication.
        let prog = compile(crate::apps::cosmo::DECK, 1);
        let pars = count(&prog, &|n| matches!(n, Node::Parallel(_)));
        assert!(pars >= 1, "{}", prog.sched.render());
        for np in &prog.sched.nests {
            for n in &np.body {
                if let Node::Parallel(p) = n {
                    assert_eq!(p.dim, "k");
                    assert_eq!(p.unit, 1, "plain loop chunks by single iterations");
                    match &p.body[0] {
                        Node::Loop(l) => {
                            assert_eq!(l.lo, Bound::of(&p.lo_sym(), 0));
                            assert_eq!(l.hi, Bound::of(&p.hi_sym(), 0));
                        }
                        other => panic!("expected loop under parallel, got {other:?}"),
                    }
                    for &sid in &p.private_storages {
                        assert!(
                            prog.sp.storages[sid].external.is_none(),
                            "externals are never replicated"
                        );
                    }
                }
            }
        }
        assert!(prog.sched.render().contains("parallel k"), "{}", prog.sched.render());
        // 1-D chains have no non-innermost dim: nothing to chunk.
        let chain = compile(testdecks::CHAIN1D, 1);
        assert_eq!(count(&chain, &|n| matches!(n, Node::Parallel(_))), 0);
    }

    #[test]
    fn parallel_composes_with_outer_strips_and_tiles() {
        // Outer-vectorized cosmo: the level-0 outer strip is chunked by
        // whole strips (unit = lanes) so boundaries never split one.
        let prog = compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                    tile: true,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let mut seen = 0;
        for np in &prog.sched.nests {
            for n in &np.body {
                if let Node::Parallel(p) = n {
                    seen += 1;
                    assert_eq!(p.unit, 4, "strip-level chunks move by whole strips");
                    assert!(matches!(&p.body[0], Node::Strip(s) if s.outer));
                }
            }
        }
        assert!(seen >= 1, "{}", prog.sched.render());
        // Threads over k chunks, lanes inside: tiles survive under Parallel.
        assert!(count(&prog, &|n| matches!(n, Node::MemberStrip(m) if m.outer.is_some())) >= 1);
    }

    #[test]
    fn visit_threads_is_order_invariant_and_stats_count() {
        // Chunks enumerate in range order, sequential within, so the
        // visit_threads sequence is independent of the worker count —
        // which is exactly why serial and chunked runs stay bitwise equal.
        let prog = compile(crate::apps::cosmo::DECK, 1);
        let ext: BTreeMap<String, i64> =
            [("Nk".to_string(), 6i64), ("Nj".to_string(), 9), ("Ni".to_string(), 11)].into();
        let seq = |threads: usize| {
            let mut got = Vec::new();
            prog.sched
                .visit_threads(&ext, threads, &mut |np, mi, idx| {
                    got.push((np, mi, idx.to_vec()));
                })
                .unwrap();
            got
        };
        let one = seq(1);
        assert!(!one.is_empty());
        for t in [2, 3, 8] {
            assert_eq!(seq(t), one, "threads={t}");
        }
        let stats = prog
            .sched
            .stats(&ext, 3, &|_, _| (2, 1))
            .unwrap();
        assert_eq!(stats.invocations as usize, one.len());
        assert_eq!(stats.loads, 2 * stats.invocations);
        assert_eq!(stats.stores, stats.invocations);
        assert!(!stats.parallel.is_empty());
        for p in &stats.parallel {
            assert!(p.chunks >= 1 && p.chunks <= 3);
            assert_eq!(p.dim, "k");
        }
        assert!(stats.summary().contains("invocations"), "{}", stats.summary());
    }

    fn compile_tt(src: &str, vlen: usize, tt: usize) -> Program {
        compile_src(
            src,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(vlen),
                    time_tile: tt,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn time_tile_lowers_once_with_warmup_and_clamps() {
        let prog = compile_tt(testdecks::CHAIN1D, 1, 4);
        assert_eq!(count(&prog, &|n| matches!(n, Node::TimeTile(_))), 1);
        for np in &prog.sched.nests {
            for n in &np.body {
                if let Node::TimeTile(t) = n {
                    assert_eq!(t.t_block, 4);
                    assert_eq!(t.halo, 2, "dbl replays 2 behind the base");
                    assert_eq!(t.warmup.len(), 1, "only dbl needs warm-up");
                    assert_eq!(t.unit, 1);
                    assert!(t.block >= 3 && t.block % t.unit == 0);
                    assert_eq!(t.clamps.len(), t.body.len());
                    // Body bounds were rewritten to the clamp symbols.
                    match &t.body[0] {
                        Node::Loop(l) => {
                            assert_eq!(l.lo, Bound::of(&tt_lo_sym(0, 0), 0));
                            assert_eq!(l.hi, Bound::of(&tt_hi_sym(0, 0), 0));
                        }
                        other => panic!("expected loop, got {other:?}"),
                    }
                }
            }
        }
        let txt = prog.sched.render();
        assert!(txt.contains("time-tile i"), "{txt}");
        assert!(txt.contains("warmup m0 depth 2"), "{txt}");
        // The default (t = 1) lowers no time-tile node at all.
        let plain = compile(testdecks::CHAIN1D, 1);
        assert_eq!(count(&plain, &|n| matches!(n, Node::TimeTile(_))), 0);
        // And the knob moves the digest by construction.
        assert_ne!(prog.sched.digest, plain.sched.digest);
    }

    #[test]
    fn time_tile_composes_with_parallel_and_strips() {
        // cosmo is k-independent along its outer dim: depths are all 0,
        // so the time-tile node (no warm-up) nests *inside* the Parallel
        // wrapper, chunked by whole spatial blocks.
        let prog = compile_tt(crate::apps::cosmo::DECK, 1, 2);
        let mut seen = 0;
        for np in &prog.sched.nests {
            for n in &np.body {
                if let Node::Parallel(p) = n {
                    seen += 1;
                    match &p.body[0] {
                        Node::TimeTile(t) => {
                            assert_eq!(p.unit, t.block, "chunks move by whole blocks");
                            assert!(t.warmup.is_empty(), "k-independence => no halo");
                            assert_eq!(t.lo, Bound::of(&p.lo_sym(), 0));
                            assert_eq!(t.hi, Bound::of(&p.hi_sym(), 0));
                        }
                        other => panic!("expected time-tile under parallel, got {other:?}"),
                    }
                }
            }
        }
        assert!(seen >= 1, "{}", prog.sched.render());
        // Outer-vectorized: blocks are strip granules (unit = lanes) and
        // clamped strips drop any compile-time alignment claim.
        let prog = compile_src(
            crate::apps::cosmo::DECK,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(4),
                    vec_dim: crate::analysis::VecDim::Outer("k".to_string()),
                    time_tile: 4,
                    ..Default::default()
                },
                aligned: true,
                ..Default::default()
            },
        )
        .unwrap();
        let mut tiles = 0;
        for np in &prog.sched.nests {
            for n in &np.body {
                let t = match n {
                    Node::TimeTile(t) => t,
                    Node::Parallel(p) => match &p.body[0] {
                        Node::TimeTile(t) => t,
                        _ => continue,
                    },
                    _ => continue,
                };
                tiles += 1;
                assert_eq!(t.unit, 4);
                assert_eq!(t.block % 4, 0);
                for b in &t.body {
                    if let Node::Strip(s) = b {
                        assert!(!s.static_aligned, "clamped base is a runtime value");
                    }
                }
            }
        }
        assert!(tiles >= 1, "{}", prog.sched.render());
    }

    #[test]
    fn time_tile_walk_covers_every_coord_t_times_plus_warmup() {
        // Each (member, coord) runs once per pass — t_block times per
        // block — plus warm-up replays for coords within `depth` behind
        // a later block's base. The *set* of coords must match the
        // untiled walk exactly.
        let t_block = 3usize;
        let prog = compile_tt(testdecks::CHAIN1D, 1, t_block);
        let ext: BTreeMap<String, i64> = [("N".to_string(), 13i64)].into();
        let mut per: BTreeMap<(usize, i64), usize> = BTreeMap::new();
        prog.sched
            .visit(&ext, &mut |_, mi, idx| {
                *per.entry((mi, idx[0])).or_default() += 1;
            })
            .unwrap();
        let base = compile(testdecks::CHAIN1D, 1);
        let mut base_set: BTreeSet<(usize, i64)> = BTreeSet::new();
        base.sched
            .visit(&ext, &mut |_, mi, idx| {
                base_set.insert((mi, idx[0]));
            })
            .unwrap();
        let tiled_set: BTreeSet<(usize, i64)> = per.keys().copied().collect();
        assert_eq!(tiled_set, base_set, "tiling must not change the coord set");
        for (&(mi, c), &n) in &per {
            assert!(
                n >= t_block && n <= t_block + (t_block - 1),
                "member {mi} coord {c}: {n} visits"
            );
        }
        // Warm-up replays actually happen (some coord runs > t times).
        assert!(per.values().any(|&n| n > t_block), "{per:?}");
    }

    #[test]
    fn visit_strip_covers_every_iteration_once() {
        // At vlen 4 on N=13 the steady segment [1, 11) has strips + a
        // remainder; every (member, i) pair must appear exactly once and
        // member strips keep each kernel ahead of its consumer.
        let prog = compile(testdecks::CHAIN1D, 4);
        let ext: BTreeMap<String, i64> = [("N".to_string(), 13i64)].into();
        let mut per: BTreeMap<(String, i64), usize> = BTreeMap::new();
        prog.sched
            .visit(&ext, &mut |np, mi, idx| {
                let nest = &prog.fd.nests[prog.sched.nests[np].nest];
                let cs = nest.members[mi].callsite;
                *per.entry((prog.df.callsites[cs].name.clone(), idx[0])).or_default() += 1;
            })
            .unwrap();
        for t in 0..11 {
            assert_eq!(per.get(&("dbl".to_string(), t)).copied(), Some(1), "dbl@{t}");
        }
        for t in 1..12 {
            assert_eq!(per.get(&("diff".to_string(), t)).copied(), Some(1), "diff@{t}");
        }
        assert_eq!(per.len(), 11 + 11);
    }
}
