//! A cheap analytical cost model over [`ScheduleStats`] — the ranking
//! stage of the `hfav tune` pipeline (ROADMAP "shape-class autotuner +
//! schedule cost model").
//!
//! The model predicts *relative* runtime, not absolute seconds: the
//! tuner uses it only to order legal candidate plans so that the
//! expensive empirical timing stage measures the top few instead of the
//! whole knob cross-product. Inputs are exactly what the walk counters
//! expose — invocation / scalar load / scalar store counts plus the
//! chunk decomposition of each parallel level — combined with the
//! candidate's effective vector length and worker count:
//!
//! * memory traffic dominates: `loads + STORE_WEIGHT × stores`
//!   (stores carry writeback/ownership traffic);
//! * each kernel invocation adds `INVOKE_WEIGHT` of loop/call
//!   bookkeeping;
//! * vector lanes discount the total by `sqrt(vlen)`, not `vlen` —
//!   remainder strips, unaligned heads and gather-ish access keep real
//!   SIMD speedups sublinear;
//! * a parallel level divides by its usable speedup
//!   `min(chunks, threads)` and charges `CHUNK_OVERHEAD` per chunk for
//!   fork/join and replica merging — so tiny grids correctly prefer
//!   fewer threads.
//!
//! All weights are unit-free tuning constants calibrated against the
//! committed `BENCH_*.json` trajectories; they only need to get the
//! *ordering* of candidates roughly right.

use crate::schedule::ScheduleStats;

/// Relative cost of one scalar store vs. one scalar load.
pub const STORE_WEIGHT: f64 = 2.0;
/// Bookkeeping cost charged per kernel invocation.
pub const INVOKE_WEIGHT: f64 = 0.5;
/// Fork/join + replica-merge cost charged per parallel chunk.
pub const CHUNK_OVERHEAD: f64 = 256.0;

/// Predicted relative runtime (arbitrary units, lower is better) of a
/// candidate whose walk produced `stats`, running `vlen` lanes wide at
/// `threads` workers. Deterministic and total: degenerate inputs clamp
/// instead of returning NaN, so sorting by this value is always safe.
pub fn estimate(stats: &ScheduleStats, vlen: usize, threads: usize) -> f64 {
    let serial = stats.loads as f64
        + STORE_WEIGHT * stats.stores as f64
        + INVOKE_WEIGHT * stats.invocations as f64;
    let simd = serial / (vlen.max(1) as f64).sqrt();
    // One parallel region runs at a time, so speedup is bounded by the
    // *least* parallel level; chunk overhead accrues across all of them.
    let min_chunks = stats
        .parallel
        .iter()
        .filter(|p| p.chunks > 0)
        .map(|p| p.chunks.min(threads.max(1)) as f64)
        .fold(f64::INFINITY, f64::min);
    let speedup = if min_chunks.is_finite() { min_chunks.max(1.0) } else { 1.0 };
    let overhead: f64 = stats.parallel.iter().map(|p| CHUNK_OVERHEAD * p.chunks as f64).sum();
    simd / speedup + overhead
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ParallelStats;

    fn stats(invocations: u64, loads: u64, stores: u64) -> ScheduleStats {
        ScheduleStats { invocations, loads, stores, parallel: Vec::new() }
    }

    fn with_parallel(mut st: ScheduleStats, chunks: usize, span: i64) -> ScheduleStats {
        st.parallel.push(ParallelStats { nest: 0, dim: "k".to_string(), unit: 1, span, chunks });
        st
    }

    #[test]
    fn wider_vectors_rank_cheaper() {
        let st = stats(1000, 4000, 1000);
        let scalar = estimate(&st, 1, 1);
        let v4 = estimate(&st, 4, 1);
        let v8 = estimate(&st, 8, 1);
        assert!(v4 < scalar && v8 < v4, "{scalar} {v4} {v8}");
        // ...but sublinearly: 8 lanes are not 8x.
        assert!(v8 > scalar / 8.0);
    }

    #[test]
    fn stores_cost_more_than_loads() {
        let load_heavy = estimate(&stats(100, 1000, 0), 1, 1);
        let store_heavy = estimate(&stats(100, 0, 1000), 1, 1);
        assert!(store_heavy > load_heavy);
    }

    #[test]
    fn parallel_chunks_help_big_grids_only() {
        let big = stats(100_000, 400_000, 100_000);
        let serial = estimate(&big, 1, 1);
        let par = estimate(&with_parallel(big.clone(), 4, 1024), 1, 4);
        assert!(par < serial, "{par} vs {serial}");
        // A tiny grid's chunk overhead outweighs the division.
        let small = stats(64, 256, 64);
        let small_serial = estimate(&small, 1, 1);
        let small_par = estimate(&with_parallel(small.clone(), 4, 8), 1, 4);
        assert!(small_par > small_serial, "{small_par} vs {small_serial}");
    }

    #[test]
    fn speedup_capped_by_threads_and_chunks() {
        let st = stats(100_000, 400_000, 100_000);
        // 8 chunks but 2 workers: speedup bounded by threads...
        let two = estimate(&with_parallel(st.clone(), 8, 1024), 1, 2);
        let eight = estimate(&with_parallel(st.clone(), 8, 1024), 1, 8);
        assert!(eight < two);
        // ...and 2 chunks at 8 workers is no better than at 2.
        let c2_t8 = estimate(&with_parallel(st.clone(), 2, 1024), 1, 8);
        let c2_t2 = estimate(&with_parallel(st, 2, 1024), 1, 2);
        assert!((c2_t8 - c2_t2).abs() < 1e-9);
    }

    #[test]
    fn total_on_degenerate_inputs() {
        assert!(estimate(&stats(0, 0, 0), 0, 0).is_finite());
        let zero_chunks = with_parallel(stats(10, 10, 10), 0, 0);
        assert!(estimate(&zero_chunks, 1, 1).is_finite());
    }
}
