//! A cheap analytical cost model over [`ScheduleStats`] — the ranking
//! stage of the `hfav tune` pipeline (ROADMAP "shape-class autotuner +
//! schedule cost model").
//!
//! The model predicts *relative* runtime, not absolute seconds: the
//! tuner uses it only to order legal candidate plans so that the
//! expensive empirical timing stage measures the top few instead of the
//! whole knob cross-product. Inputs are exactly what the walk counters
//! expose — invocation / scalar load / scalar store counts plus the
//! chunk decomposition of each parallel level — combined with the
//! candidate's effective vector length and worker count:
//!
//! * memory traffic dominates: `loads + STORE_WEIGHT × stores`
//!   (stores carry writeback/ownership traffic);
//! * each kernel invocation adds `INVOKE_WEIGHT` of loop/call
//!   bookkeeping;
//! * vector lanes discount the total by `sqrt(vlen)`, not `vlen` —
//!   remainder strips, unaligned heads and gather-ish access keep real
//!   SIMD speedups sublinear;
//! * a parallel level divides by its usable speedup
//!   `min(chunks, threads)` and charges `CHUNK_OVERHEAD` per chunk for
//!   fork/join and replica merging — so tiny grids correctly prefer
//!   fewer threads.
//!
//! All weights are unit-free tuning constants calibrated against the
//! committed `BENCH_*.json` trajectories; they only need to get the
//! *ordering* of candidates roughly right.

use crate::plan::tunedb::{TunedDb, TunedEntry};
use crate::schedule::ScheduleStats;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Relative cost of one scalar store vs. one scalar load.
pub const STORE_WEIGHT: f64 = 2.0;
/// Bookkeeping cost charged per kernel invocation.
pub const INVOKE_WEIGHT: f64 = 0.5;
/// Fork/join + replica-merge cost charged per parallel chunk.
pub const CHUNK_OVERHEAD: f64 = 256.0;
/// Relative cost of a time-tiled pass after the first: its block is
/// cache-resident, so its memory traffic is cheaper than the counters
/// alone suggest (< 1.0 makes deeper tiles rank cheaper per step,
/// sublinearly — warmup-replay work still accrues in the counters).
pub const TIME_TILE_CACHE_DISCOUNT: f64 = 0.6;

/// Predicted relative runtime (arbitrary units, lower is better) of a
/// candidate whose walk produced `stats`, running `vlen` lanes wide at
/// `threads` workers. Deterministic and total: degenerate inputs clamp
/// instead of returning NaN, so sorting by this value is always safe.
pub fn estimate(stats: &ScheduleStats, vlen: usize, threads: usize) -> f64 {
    let serial = stats.loads as f64
        + STORE_WEIGHT * stats.stores as f64
        + INVOKE_WEIGHT * stats.invocations as f64;
    let simd = serial / (vlen.max(1) as f64).sqrt();
    // One parallel region runs at a time, so speedup is bounded by the
    // *least* parallel level; chunk overhead accrues across all of them.
    let min_chunks = stats
        .parallel
        .iter()
        .filter(|p| p.chunks > 0)
        .map(|p| p.chunks.min(threads.max(1)) as f64)
        .fold(f64::INFINITY, f64::min);
    let speedup = if min_chunks.is_finite() { min_chunks.max(1.0) } else { 1.0 };
    let overhead: f64 = stats.parallel.iter().map(|p| CHUNK_OVERHEAD * p.chunks as f64).sum();
    simd / speedup + overhead
}

/// Per-timestep cost of a candidate whose one invocation serves
/// `time_tile` steps. The walk counters already cover all `time_tile`
/// passes (plus halo-replay warmup), so the total divides by the steps
/// served; passes after the first additionally run on cache-resident
/// blocks and are discounted by [`TIME_TILE_CACHE_DISCOUNT`]. At
/// `time_tile <= 1` this is exactly [`estimate`] — untiled and tiled
/// candidates rank on the same per-step scale.
pub fn estimate_per_step(
    stats: &ScheduleStats,
    vlen: usize,
    threads: usize,
    time_tile: usize,
) -> f64 {
    let total = estimate(stats, vlen, threads);
    let t = time_tile.max(1) as f64;
    // Of the counted work, ~1/t ran cold (first pass) and (t-1)/t ran on
    // the cache-resident block.
    total * (1.0 + TIME_TILE_CACHE_DISCOUNT * (t - 1.0)) / (t * t)
}

/// Calibration report over a tuned-plans DB: per shape class, how the
/// cost model's pre-timing ranking compares with the measured winners —
/// top-pick hit counts, mean predicted rank of the winners, and (when a
/// class holds two or more ranked entries) the Spearman rank correlation
/// between predicted ordering and measured throughput ordering. Entries
/// recorded before ranks were persisted show as `rank=?` and are
/// excluded from the statistics, never an error.
pub fn calibration_report(db: &TunedDb) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "cost-model calibration over {} tuned entries", db.len());
    if db.is_empty() {
        let _ = writeln!(out, "  (empty DB — run `hfav tune <target> --extents ...` first)");
        return out;
    }
    let mut classes: BTreeMap<&str, Vec<&TunedEntry>> = BTreeMap::new();
    for e in &db.entries {
        classes.entry(e.shape_class.as_str()).or_default().push(e);
    }
    let mut total_ranked = 0usize;
    let mut total_top1 = 0usize;
    for (class, entries) in &classes {
        let ranked: Vec<&TunedEntry> =
            entries.iter().filter(|e| e.predicted_rank.is_some()).copied().collect();
        let top1 = ranked.iter().filter(|e| e.predicted_rank == Some(1)).count();
        total_ranked += ranked.len();
        total_top1 += top1;
        let mean_rank = if ranked.is_empty() {
            "?".to_string()
        } else {
            let m: f64 = ranked.iter().map(|e| e.predicted_rank.unwrap() as f64).sum::<f64>()
                / ranked.len() as f64;
            format!("{m:.1}")
        };
        let rho = match spearman(&ranked) {
            Some(r) => format!("{r:+.2}"),
            None => "n/a".to_string(),
        };
        let _ = writeln!(
            out,
            "  class {class}: {} entries, model top pick won {top1}/{}, \
             mean winner rank {mean_rank}, rank correlation {rho}",
            entries.len(),
            ranked.len()
        );
        for e in entries {
            let rank = e
                .predicted_rank
                .map(|r| r.to_string())
                .unwrap_or_else(|| "?".to_string());
            let _ = writeln!(
                out,
                "    {:<12} {:<48} rank={rank:<3} {:>9.1} Mcells/s",
                e.target,
                e.knob_label(),
                e.mcells_per_s
            );
        }
    }
    let _ = writeln!(
        out,
        "  overall: model's top pick won {total_top1}/{total_ranked} ranked tunings"
    );
    out
}

/// Spearman rank correlation between the model's predicted ordering and
/// the measured-throughput ordering of `entries` (all carrying a
/// predicted rank). `None` below two entries — a correlation over one
/// point is noise.
fn spearman(entries: &[&TunedEntry]) -> Option<f64> {
    let n = entries.len();
    if n < 2 {
        return None;
    }
    // Rank both ways over the same entry set: by predicted rank
    // (ascending — lower is better) and by measured throughput
    // (descending — faster is better).
    let rank_of = |order: &[usize]| {
        let mut r = vec![0usize; n];
        for (pos, &i) in order.iter().enumerate() {
            r[i] = pos + 1;
        }
        r
    };
    let mut by_pred: Vec<usize> = (0..n).collect();
    by_pred.sort_by_key(|&i| entries[i].predicted_rank.unwrap_or(usize::MAX));
    let mut by_meas: Vec<usize> = (0..n).collect();
    by_meas.sort_by(|&a, &b| entries[b].mcells_per_s.total_cmp(&entries[a].mcells_per_s));
    let (pr, mr) = (rank_of(&by_pred), rank_of(&by_meas));
    let d2: f64 = (0..n).map(|i| (pr[i] as f64 - mr[i] as f64).powi(2)).sum();
    let nf = n as f64;
    Some(1.0 - 6.0 * d2 / (nf * (nf * nf - 1.0)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ParallelStats;

    fn stats(invocations: u64, loads: u64, stores: u64) -> ScheduleStats {
        ScheduleStats { invocations, loads, stores, parallel: Vec::new() }
    }

    fn with_parallel(mut st: ScheduleStats, chunks: usize, span: i64) -> ScheduleStats {
        st.parallel.push(ParallelStats { nest: 0, dim: "k".to_string(), unit: 1, span, chunks });
        st
    }

    #[test]
    fn wider_vectors_rank_cheaper() {
        let st = stats(1000, 4000, 1000);
        let scalar = estimate(&st, 1, 1);
        let v4 = estimate(&st, 4, 1);
        let v8 = estimate(&st, 8, 1);
        assert!(v4 < scalar && v8 < v4, "{scalar} {v4} {v8}");
        // ...but sublinearly: 8 lanes are not 8x.
        assert!(v8 > scalar / 8.0);
    }

    #[test]
    fn stores_cost_more_than_loads() {
        let load_heavy = estimate(&stats(100, 1000, 0), 1, 1);
        let store_heavy = estimate(&stats(100, 0, 1000), 1, 1);
        assert!(store_heavy > load_heavy);
    }

    #[test]
    fn parallel_chunks_help_big_grids_only() {
        let big = stats(100_000, 400_000, 100_000);
        let serial = estimate(&big, 1, 1);
        let par = estimate(&with_parallel(big.clone(), 4, 1024), 1, 4);
        assert!(par < serial, "{par} vs {serial}");
        // A tiny grid's chunk overhead outweighs the division.
        let small = stats(64, 256, 64);
        let small_serial = estimate(&small, 1, 1);
        let small_par = estimate(&with_parallel(small.clone(), 4, 8), 1, 4);
        assert!(small_par > small_serial, "{small_par} vs {small_serial}");
    }

    #[test]
    fn speedup_capped_by_threads_and_chunks() {
        let st = stats(100_000, 400_000, 100_000);
        // 8 chunks but 2 workers: speedup bounded by threads...
        let two = estimate(&with_parallel(st.clone(), 8, 1024), 1, 2);
        let eight = estimate(&with_parallel(st.clone(), 8, 1024), 1, 8);
        assert!(eight < two);
        // ...and 2 chunks at 8 workers is no better than at 2.
        let c2_t8 = estimate(&with_parallel(st.clone(), 2, 1024), 1, 8);
        let c2_t2 = estimate(&with_parallel(st, 2, 1024), 1, 2);
        assert!((c2_t8 - c2_t2).abs() < 1e-9);
    }

    #[test]
    fn total_on_degenerate_inputs() {
        assert!(estimate(&stats(0, 0, 0), 0, 0).is_finite());
        let zero_chunks = with_parallel(stats(10, 10, 10), 0, 0);
        assert!(estimate(&zero_chunks, 1, 1).is_finite());
        assert!(estimate_per_step(&stats(0, 0, 0), 0, 0, 0).is_finite());
    }

    #[test]
    fn time_tiled_passes_rank_cheaper_per_step() {
        // An untiled sweep vs. the same sweep counted twice (t=2 covers
        // two steps): per-step the tiled plan must be cheaper (cache
        // discount) but not twice as cheap (the first pass is cold).
        let one = stats(1000, 4000, 1000);
        let two = stats(2000, 8000, 2000);
        let untiled = estimate_per_step(&one, 1, 1, 1);
        let tiled = estimate_per_step(&two, 1, 1, 2);
        assert!(tiled < untiled, "{tiled} vs {untiled}");
        assert!(tiled > untiled * TIME_TILE_CACHE_DISCOUNT, "{tiled} vs {untiled}");
        // t=1 is exactly the plain estimate.
        assert_eq!(estimate_per_step(&one, 4, 2, 1), estimate(&one, 4, 2));
        // Warmup replay counted on top of the t sweeps erodes the win.
        let mut with_warmup = two.clone();
        with_warmup.loads += 4000;
        with_warmup.invocations += 1000;
        assert!(estimate_per_step(&with_warmup, 1, 1, 2) > tiled);
    }

    #[test]
    fn calibration_report_on_synthetic_db() {
        use crate::plan::tunedb::{TunedDb, TunedEntry};
        let entry = |target: &str, class: &str, rank: Option<usize>, mcells: f64| TunedEntry {
            deck_digest: target.len() as u64,
            target: target.to_string(),
            shape_class: class.to_string(),
            extents: "32x32".to_string(),
            tuned: true,
            vec_dim: "inner".to_string(),
            vlen: 4,
            aligned: false,
            tiled: false,
            time_tile: 2,
            threads: 1,
            mcells_per_s: mcells,
            candidates: 8,
            timed: 4,
            reps: 5,
            predicted_rank: rank,
        };
        // Empty DB: a hint, not an error.
        let report = calibration_report(&TunedDb::default());
        assert!(report.contains("0 tuned entries"), "{report}");
        // Class `a`: model ordering matches measurement exactly (rho +1);
        // class `b`: perfectly inverted (rho -1) plus a pre-rank record.
        let mut db = TunedDb::default();
        db.insert(entry("d1", "d2/m10/square", Some(1), 300.0));
        db.insert(entry("d02", "d2/m10/square", Some(2), 200.0));
        db.insert(entry("d003", "d2/m10/square", Some(3), 100.0));
        db.insert(entry("e1", "d2/m12/rect", Some(1), 100.0));
        db.insert(entry("e02", "d2/m12/rect", Some(2), 200.0));
        db.insert(entry("e003", "d2/m12/rect", None, 250.0));
        let report = calibration_report(&db);
        assert!(report.contains("6 tuned entries"), "{report}");
        assert!(report.contains("class d2/m10/square"), "{report}");
        assert!(report.contains("rank correlation +1.00"), "{report}");
        assert!(report.contains("rank correlation -1.00"), "{report}");
        // Top-pick tallies: d1 and e1 won at predicted rank 1.
        assert!(report.contains("model top pick won 1/3"), "{report}");
        assert!(report.contains("model top pick won 1/2"), "{report}");
        assert!(report.contains("overall: model's top pick won 2/5"), "{report}");
        // The unranked (pre-knob) record shows but doesn't poison stats.
        assert!(report.contains("rank=?"), "{report}");
        // Singleton classes report n/a instead of a junk correlation.
        db.insert(entry("solo", "d3/m9/square", Some(1), 50.0));
        assert!(calibration_report(&db).contains("rank correlation n/a"));
    }
}
