//! Static schedule verification: independent proofs that a lowered
//! [`Program`] is memory-safe and deterministic.
//!
//! The compilation pipeline *constructs* legality: gates like
//! `outer_vectorizable`, `parallel_safe` and `lane_fission_safe` decide
//! what the schedule may do, and lowering encodes the result. Nothing
//! downstream re-checks that the encoded tree actually has the claimed
//! properties — and transformation code is exactly where silent
//! corruption bugs live. This module is the independent oracle: it
//! rebuilds the executor's address model from the storage plan alone and
//! walks the schedule tree symbolically over probe extents, proving
//! three properties:
//!
//! 1. **bounds** — every access of every invocation reachable via
//!    [`crate::schedule::Schedule::visit`] (window rotations, padded
//!    intermediates, outer-lane slots, aligned heads, tile members)
//!    stays inside its buffer at every probed shape;
//! 2. **races** — for every [`Node::Parallel`], per-chunk read/write
//!    footprints recomputed from [`chunk_spans`] are pairwise disjoint
//!    on shared storages (no chunk writes a cell another chunk touches),
//!    and chunk-private replicas are written in-chunk before they are
//!    read (replicas start zeroed, not carried over from other chunks);
//! 3. **def-before-use** — every read of an intermediate cell is
//!    preceded in walk order by a write of the *same logical
//!    coordinates* to that cell: an unwritten cell is an uninitialized
//!    read (`def-before-use`), a coordinate mismatch is a rotation
//!    clobber (`stale-read` — the window is too small for the schedule
//!    that reads it).
//!
//! The proofs are exhaustive over small staggered probe extents (chosen
//! so alignment heads, steady strips, scalar remainders and uneven
//! parallel chunks all execute), which is exactly the regime where
//! off-by-one peeling and padding bugs live — larger extents only repeat
//! steady-state iterations the probes already cover.
//!
//! Surfaced three ways: the `hfav check <app|deck.yaml>` CLI command
//! (deck lints + schedule proofs, nonzero exit on errors), the
//! `HFAV_VERIFY` gate inside [`crate::plan::compile`] (on by default
//! under `cfg(test)`, so every unit-test compile is verified), and
//! [`reject_reason`] as the tuner's pre-timing candidate filter.

use crate::analysis::{self, DimSize};
use crate::dataflow::Terminal;
use crate::fusion::Role;
use crate::plan::Program;
use crate::schedule::{chunk_spans, Node};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

/// Finding severity: errors fail `hfav check` (nonzero exit) and the
/// compile gate; warnings are advisory lints.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One verifier finding, tagged with the rule that produced it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable rule tag: `bounds`, `race`, `def-before-use`, `stale-read`,
    /// `chunk-uninit-read`, or a deck-lint tag (`dead-kernel`,
    /// `unused-input`, `dead-value`, `input-underrun`).
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    fn error(rule: &'static str, message: String) -> Diagnostic {
        Diagnostic { severity: Severity::Error, rule, message }
    }
    fn warning(rule: &'static str, message: String) -> Diagnostic {
        Diagnostic { severity: Severity::Warning, rule, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]: {}", self.severity, self.rule, self.message)
    }
}

/// Accumulated findings of one verification run. Findings are
/// deduplicated per (rule, site): a bug that fires on every iteration of
/// a walk is reported once, at its first occurrence.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    seen: BTreeSet<String>,
}

impl Report {
    fn push(&mut self, site: String, d: Diagnostic) {
        if self.seen.insert(format!("{}\u{1}{site}", d.rule)) {
            self.diagnostics.push(d);
        }
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.error_count()
    }

    /// All findings, one rendered line per diagnostic.
    pub fn render(&self) -> String {
        self.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
    }

    /// Error findings only (the compile-gate failure payload).
    pub fn render_errors(&self) -> String {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Whether the [`crate::plan::compile`] verification gate is on. The
/// `HFAV_VERIFY` env var wins (`0`/`off`/empty disable, anything else
/// enables); unset defaults to on under `cfg(test)` so every unit-test
/// compile is verified, and off otherwise (production compiles stay
/// cheap; `hfav check` runs the verifier explicitly).
pub fn gate_enabled() -> bool {
    gate_from(std::env::var("HFAV_VERIFY").ok().as_deref())
}

fn gate_from(v: Option<&str>) -> bool {
    match v {
        Some(s) => !(s.is_empty() || s == "0" || s.eq_ignore_ascii_case("off")),
        None => cfg!(test),
    }
}

/// The compile-gate body: one small probe shape (the gate runs on every
/// unit-test compile, so it stays cheap), serial walk plus a two-chunk
/// race walk. `Ok(())` or the rendered error findings.
pub fn gate_check(prog: &Program) -> Result<(), String> {
    let ext = probe_extents(prog, 1);
    let mut report = Report::default();
    check_at(prog, &ext, &[2], &mut report)?;
    if report.has_errors() {
        return Err(format!("schedule verification failed:\n{}", report.render_errors()));
    }
    Ok(())
}

/// The tuner's candidate filter: `Some(reason)` when the lowered
/// schedule fails verification (candidate must not be timed), `None`
/// when it proves clean.
pub fn reject_reason(prog: &Program) -> Option<String> {
    gate_check(prog).err()
}

/// Full verification: deck lints plus schedule proofs over two staggered
/// probe shapes, with race walks at 2 and 3 chunk workers each.
pub fn check_program(prog: &Program) -> Result<Report, String> {
    let mut report = Report::default();
    for d in lint_deck(prog) {
        let site = d.message.clone();
        report.push(site, d);
    }
    check_schedule_into(prog, &mut report)?;
    Ok(report)
}

/// Schedule proofs only (no deck lints): two probe shapes, serial walk
/// plus 2- and 3-worker race walks at each.
pub fn check_schedule(prog: &Program) -> Result<Report, String> {
    let mut report = Report::default();
    check_schedule_into(prog, &mut report)?;
    Ok(report)
}

fn check_schedule_into(prog: &Program, report: &mut Report) -> Result<(), String> {
    for scale in [4, 2] {
        let ext = probe_extents(prog, scale);
        check_at(prog, &ext, &[2, 3], report)?;
    }
    Ok(())
}

/// Schedule proofs at one explicit shape: a serial bounds/def walk, then
/// a race walk per worker count in `threads` (entries below 2 are
/// covered by the serial walk and skipped).
pub fn check_schedule_at(
    prog: &Program,
    extents: &BTreeMap<String, i64>,
    threads: &[usize],
) -> Result<Report, String> {
    let mut report = Report::default();
    check_at(prog, extents, threads, &mut report)?;
    Ok(report)
}

/// Staggered, deliberately unaligned probe extents: roughly `scale`
/// vector strips per dim plus a distinct odd offset per extent name, so
/// alignment heads, steady strips, scalar remainders and uneven parallel
/// chunks all execute during the walk.
pub fn probe_extents(prog: &Program, scale: i64) -> BTreeMap<String, i64> {
    let vl = prog.vector_len().max(1) as i64;
    let mut ext = BTreeMap::new();
    for (i, name) in crate::codegen::c99::extent_names(prog).into_iter().enumerate() {
        ext.insert(name, scale * vl + 5 + 2 * i as i64);
    }
    ext
}

fn check_at(
    prog: &Program,
    extents: &BTreeMap<String, i64>,
    threads: &[usize],
    report: &mut Report,
) -> Result<(), String> {
    let model = Model::build(prog, extents)?;
    model.check_serial(report)?;
    for &t in threads {
        if t >= 2 {
            model.check_races(t, report)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Deck lints
// ---------------------------------------------------------------------------

/// Deck-level lints, independent of any particular schedule: dead
/// kernels (rules the goal chain never instantiates), inputs nothing
/// consumes, computed values nothing reads, and input stencil spans that
/// reach below index 0 — an offset larger than the array the deck
/// declares (`input-underrun`, the only lint that is an error).
pub fn lint_deck(prog: &Program) -> Vec<Diagnostic> {
    let df = &prog.df;
    let mut out = Vec::new();

    // Rules never instantiated by inference. Synthetic roll callsites
    // carry `rule == usize::MAX` and don't count as uses.
    let used: BTreeSet<usize> =
        df.callsites.iter().map(|c| c.rule).filter(|&r| r != usize::MAX).collect();
    for (i, r) in prog.deck.rules.iter().enumerate() {
        if !used.contains(&i) {
            out.push(Diagnostic::warning(
                "dead-kernel",
                format!("kernel `{}` is never instantiated by the goal chain", r.name),
            ));
        }
    }

    // Input axioms nothing reads.
    for a in &prog.deck.axioms {
        let ident = a.provides.ident();
        let consumed = df
            .var_by_ident
            .get(&ident)
            .map(|&v| !df.reads_of[v].is_empty())
            .unwrap_or(false);
        if !consumed {
            out.push(Diagnostic::warning(
                "unused-input",
                format!("input `{ident}` is never consumed by any instantiated kernel"),
            ));
        }
    }

    // Computed values that are neither terminal nor read.
    for v in &df.vars {
        if v.producer.is_some()
            && matches!(v.terminal, Terminal::No)
            && df.reads_of[v.id].is_empty()
        {
            out.push(Diagnostic::warning(
                "dead-value",
                format!("value `{}` is computed but never read", v.ident),
            ));
        }
    }

    // Input spans reaching below index 0: a stencil offset exceeds the
    // declared array. The executor *allocates* the halo (spans size the
    // buffers), so bounds proofs pass — this is the deck-level check
    // that catches the mistake.
    for v in &df.vars {
        if !matches!(v.terminal, Terminal::Input { .. }) {
            continue;
        }
        for d in &v.dims {
            let lo = &v.span[d].lo;
            if lo.base.is_none() && lo.offset < 0 {
                out.push(Diagnostic::error(
                    "input-underrun",
                    format!(
                        "input `{}`: stencil reads reach index {} along `{d}`, below the \
                         array start — widen the domain or shrink the negative offset",
                        v.ident, lo.offset
                    ),
                ));
            }
        }
    }

    out
}

// ---------------------------------------------------------------------------
// The address model (mirrors the executor, rebuilt independently)
// ---------------------------------------------------------------------------

/// How one dim of an access resolves to a physical index — the
/// executor's three index rules, rebuilt from the storage plan.
#[derive(Debug, Clone, Copy)]
enum Rule {
    One,
    Window { alloc: i64 },
    Full { lo: i64 },
}

#[derive(Debug, Clone)]
struct DimPlan {
    dim: String,
    level: usize,
    /// Pipeline shift (loop roles only) plus the subscript offset.
    add: i64,
    size: i64,
    stride: i64,
    rule: Rule,
}

#[derive(Debug, Clone)]
struct AccessPlan {
    /// Accessed variable ident (diagnostics).
    ident: String,
    /// Buffer id (storage after external-alias dedup).
    buf: usize,
    dims: Vec<DimPlan>,
}

#[derive(Debug, Clone)]
struct MemberAccess {
    /// Callsite name (diagnostics).
    name: String,
    reads: Vec<AccessPlan>,
    writes: Vec<AccessPlan>,
}

/// The executor's whole address model at one concrete shape: buffer
/// identity (externals deduplicated through deck aliases), buffer sizes
/// in words, and per-member resolved access plans per nest.
struct Model<'a> {
    prog: &'a Program,
    extents: &'a BTreeMap<String, i64>,
    /// storage id -> buffer id.
    storage_buf: Vec<usize>,
    /// buffer id -> words allocated at these extents.
    buf_words: Vec<i64>,
    /// buffer id -> display name (external canon or storage name).
    buf_names: Vec<String>,
    /// buffer id -> true when backed by an external array (externals are
    /// always-defined: the host initializes them before a run).
    buf_external: Vec<bool>,
    /// per nest plan, per fused-nest member: resolved access plans.
    nests: Vec<Vec<MemberAccess>>,
}

impl<'a> Model<'a> {
    fn build(prog: &'a Program, extents: &'a BTreeMap<String, i64>) -> Result<Model<'a>, String> {
        // Buffer identity and sizing, exactly like the executor's
        // allocation pass: externals dedup through deck aliases and size
        // by the representative var's span; intermediates size by the
        // storage plan.
        let mut ext_buf: BTreeMap<String, usize> = BTreeMap::new();
        let mut storage_buf = vec![usize::MAX; prog.sp.storages.len()];
        let mut buf_words = Vec::new();
        let mut buf_names = Vec::new();
        let mut buf_external = Vec::new();
        for s in &prog.sp.storages {
            let b = if let Some(name) = &s.external {
                let canon = canonical_alias(prog, name);
                match ext_buf.get(&canon) {
                    Some(&b) => b,
                    None => {
                        buf_words.push(analysis::external_storage_words(s, &prog.df, extents)?);
                        buf_names.push(canon.clone());
                        buf_external.push(true);
                        ext_buf.insert(canon, buf_words.len() - 1);
                        buf_words.len() - 1
                    }
                }
            } else {
                buf_words.push(analysis::storage_words(s, &prog.df, extents)?);
                buf_names.push(s.name.clone());
                buf_external.push(false);
                buf_words.len() - 1
            };
            storage_buf[s.id] = b;
        }

        // Access plans per nest member, mirroring the executor's member
        // compilation: nest level per var dim, role-gated pipeline shift
        // plus subscript offset, index rule and size from the storage
        // plan, strides per the shared layout order.
        let mut nests = Vec::with_capacity(prog.sched.nests.len());
        for np in &prog.sched.nests {
            let nest = &prog.fd.nests[np.nest];
            let mut members = Vec::with_capacity(nest.members.len());
            for m in &nest.members {
                let cs = &prog.df.callsites[m.callsite];
                let access = |vid: usize, offsets: &[i64]| -> Result<AccessPlan, String> {
                    let var = &prog.df.vars[vid];
                    let sid = prog.sp.of_var[vid];
                    let st = &prog.sp.storages[sid];
                    let mut dims = Vec::with_capacity(var.dims.len());
                    let mut sizes = Vec::with_capacity(var.dims.len());
                    for (k, d) in var.dims.iter().enumerate() {
                        let level = nest
                            .dim_index(d)
                            .ok_or_else(|| format!("dim `{d}` of `{}` not in nest", var.ident))?;
                        let shift = if m.roles[level] == Role::Loop { m.shifts[level] } else { 0 };
                        let (rule, size) = match &st.sizes[k] {
                            DimSize::One => (Rule::One, 1i64),
                            DimSize::Window { alloc, .. } => {
                                (Rule::Window { alloc: *alloc }, *alloc)
                            }
                            DimSize::Full => {
                                let span = &var.span[d];
                                let lo = span.lo.eval(extents)?;
                                let hi = span.hi.eval(extents)?;
                                (Rule::Full { lo }, (hi - lo).max(0))
                            }
                        };
                        dims.push(DimPlan {
                            dim: d.clone(),
                            level,
                            add: shift + offsets[k],
                            size,
                            stride: 1,
                            rule,
                        });
                        sizes.push(size);
                    }
                    let order = analysis::layout_order(st, prog.outer_lane_dim());
                    for k in 0..sizes.len() {
                        let pos = order.iter().position(|&x| x == k).unwrap();
                        dims[k].stride = order[pos + 1..].iter().map(|&x| sizes[x]).product();
                    }
                    Ok(AccessPlan {
                        ident: var.ident.clone(),
                        buf: storage_buf[sid],
                        dims,
                    })
                };
                let mut reads = Vec::new();
                for (_, vid, offsets) in &cs.reads {
                    reads.push(access(*vid, offsets)?);
                }
                let mut writes = Vec::new();
                for (_, vid, offsets) in &cs.writes {
                    writes.push(access(*vid, offsets)?);
                }
                members.push(MemberAccess { name: cs.name.clone(), reads, writes });
            }
            nests.push(members);
        }

        Ok(Model { prog, extents, storage_buf, buf_words, buf_names, buf_external, nests })
    }

    /// Resolve one access at a loop index: per-dim bounds proof plus the
    /// flat cell and the logical coordinates (one per var dim). `Err` is
    /// a bounds violation message (without the kernel prefix).
    fn resolve(&self, a: &AccessPlan, idx: &[i64]) -> Result<(i64, Vec<i64>), String> {
        let mut flat = 0i64;
        let mut coords = Vec::with_capacity(a.dims.len());
        for d in &a.dims {
            let pos = idx[d.level] + d.add;
            let x = match d.rule {
                Rule::One => 0,
                Rule::Window { alloc } => pos.rem_euclid(alloc),
                Rule::Full { lo } => {
                    let x = pos - lo;
                    if x < 0 || x >= d.size {
                        return Err(format!(
                            "`{}`: index {pos} outside span [{lo}, {}) along `{}`",
                            a.ident,
                            lo + d.size,
                            d.dim
                        ));
                    }
                    x
                }
            };
            coords.push(pos);
            flat += x * d.stride;
        }
        let words = self.buf_words[a.buf];
        if flat < 0 || flat >= words {
            return Err(format!(
                "`{}`: flat word {flat} outside the {words}-word buffer `{}`",
                a.ident, self.buf_names[a.buf]
            ));
        }
        Ok((flat, coords))
    }

    /// Serial walk: bounds on every access, and def-before-use /
    /// stale-read on every intermediate read. Definition state persists
    /// across nests (earlier nests feed later ones); external buffers
    /// are always-defined.
    fn check_serial(&self, report: &mut Report) -> Result<(), String> {
        let mut defs: Vec<BTreeMap<i64, Vec<i64>>> = vec![BTreeMap::new(); self.buf_words.len()];
        let mut findings: Vec<(String, Diagnostic)> = Vec::new();
        self.prog.sched.visit(self.extents, &mut |np, mi, idx| {
            let ma = &self.nests[np][mi];
            for a in &ma.reads {
                match self.resolve(a, idx) {
                    Err(msg) => findings.push((
                        format!("{}/{}", ma.name, a.ident),
                        Diagnostic::error("bounds", format!("`{}` reads {msg}", ma.name)),
                    )),
                    Ok((flat, coords)) => {
                        if !self.buf_external[a.buf] {
                            match defs[a.buf].get(&flat) {
                                None => findings.push((
                                    format!("{}/{}", ma.name, a.ident),
                                    Diagnostic::error(
                                        "def-before-use",
                                        format!(
                                            "`{}` reads `{}` at {coords:?} before any write \
                                             defines that cell",
                                            ma.name, a.ident
                                        ),
                                    ),
                                )),
                                Some(held) if *held != coords => findings.push((
                                    format!("{}/{}", ma.name, a.ident),
                                    Diagnostic::error(
                                        "stale-read",
                                        format!(
                                            "`{}` reads `{}` expecting {coords:?} but the cell \
                                             last held {held:?} — window clobbered before use",
                                            ma.name, a.ident
                                        ),
                                    ),
                                )),
                                Some(_) => {}
                            }
                        }
                    }
                }
            }
            for a in &ma.writes {
                match self.resolve(a, idx) {
                    Err(msg) => findings.push((
                        format!("{}/{}", ma.name, a.ident),
                        Diagnostic::error("bounds", format!("`{}` writes {msg}", ma.name)),
                    )),
                    Ok((flat, coords)) => {
                        defs[a.buf].insert(flat, coords);
                    }
                }
            }
        })?;
        for (site, d) in findings {
            report.push(site, d);
        }
        Ok(())
    }

    /// Race walk at one worker count: for every parallel level, rebuild
    /// each chunk's read/write footprint on shared buffers and prove the
    /// chunks disjoint (no write overlaps another chunk's footprint);
    /// chunk-private buffers instead get a fresh per-chunk definition
    /// state (replicas start zeroed), proving every private read was
    /// written by the same chunk with matching coordinates.
    fn check_races(&self, threads: usize, report: &mut Report) -> Result<(), String> {
        for (np_i, np) in self.prog.sched.nests.iter().enumerate() {
            for node in &np.body {
                let Node::Parallel(p) = node else { continue };
                let lo = p.lo.eval(self.extents)?;
                let hi = p.hi.eval(self.extents)?;
                let spans = chunk_spans(lo, hi, p.unit, threads);
                if spans.len() <= 1 {
                    continue;
                }
                let private: BTreeSet<usize> =
                    p.private_storages.iter().map(|&sid| self.storage_buf[sid]).collect();
                let mut findings: Vec<(String, Diagnostic)> = Vec::new();
                // (shared reads, shared writes) per chunk, keyed by buffer.
                type Foot = BTreeMap<usize, BTreeSet<i64>>;
                let mut feet: Vec<(Foot, Foot)> = Vec::with_capacity(spans.len());
                for &(clo, chi) in &spans {
                    let mut ext = self.extents.clone();
                    ext.insert(p.lo_sym(), clo);
                    ext.insert(p.hi_sym(), chi);
                    let mut reads: Foot = BTreeMap::new();
                    let mut writes: Foot = BTreeMap::new();
                    let mut pdefs: BTreeMap<usize, BTreeMap<i64, Vec<i64>>> =
                        private.iter().map(|&b| (b, BTreeMap::new())).collect();
                    let mut idx = vec![0i64; np.dims.len()];
                    crate::schedule::visit_body(
                        np_i,
                        &p.body,
                        &ext,
                        1,
                        &mut idx,
                        &mut |_, mi, idx| {
                            let ma = &self.nests[np_i][mi];
                            for a in &ma.reads {
                                // Bounds violations are the serial
                                // walk's findings; here only footprints
                                // and private definedness matter.
                                let Ok((flat, coords)) = self.resolve(a, idx) else { continue };
                                if let Some(defs) = pdefs.get(&a.buf) {
                                    match defs.get(&flat) {
                                        None => findings.push((
                                            format!("{}/{}", ma.name, a.ident),
                                            Diagnostic::error(
                                                "chunk-uninit-read",
                                                format!(
                                                    "`{}` reads chunk-private `{}` at {coords:?} \
                                                     before the chunk writes it (replicas start \
                                                     zeroed, not carried over)",
                                                    ma.name, a.ident
                                                ),
                                            ),
                                        )),
                                        Some(held) if *held != coords => findings.push((
                                            format!("{}/{}", ma.name, a.ident),
                                            Diagnostic::error(
                                                "stale-read",
                                                format!(
                                                    "`{}` reads chunk-private `{}` expecting \
                                                     {coords:?} but the replica cell last held \
                                                     {held:?}",
                                                    ma.name, a.ident
                                                ),
                                            ),
                                        )),
                                        Some(_) => {}
                                    }
                                } else {
                                    reads.entry(a.buf).or_default().insert(flat);
                                }
                            }
                            for a in &ma.writes {
                                let Ok((flat, coords)) = self.resolve(a, idx) else { continue };
                                if let Some(defs) = pdefs.get_mut(&a.buf) {
                                    defs.insert(flat, coords);
                                } else {
                                    writes.entry(a.buf).or_default().insert(flat);
                                }
                            }
                        },
                    )?;
                    feet.push((reads, writes));
                }
                // Pairwise disjointness: a chunk's writes must not touch
                // any cell another chunk reads or writes.
                for i in 0..feet.len() {
                    for j in 0..feet.len() {
                        if i == j {
                            continue;
                        }
                        for (buf, w) in &feet[i].1 {
                            let mut overlap = |other: &BTreeSet<i64>, kind: &str| {
                                let common: Vec<i64> =
                                    w.intersection(other).take(4).copied().collect();
                                if !common.is_empty() {
                                    findings.push((
                                        format!("nest{np_i}/{}/{kind}", self.buf_names[*buf]),
                                        Diagnostic::error(
                                            "race",
                                            format!(
                                                "parallel `{}` at {threads} workers: chunk {i} \
                                                 writes cells of `{}` that chunk {j} {kind} \
                                                 (e.g. word {})",
                                                p.dim, self.buf_names[*buf], common[0]
                                            ),
                                        ),
                                    ));
                                }
                            };
                            if i < j {
                                if let Some(w2) = feet[j].1.get(buf) {
                                    overlap(w2, "writes");
                                }
                            }
                            if let Some(r2) = feet[j].0.get(buf) {
                                overlap(r2, "reads");
                            }
                        }
                    }
                }
                for (site, d) in findings {
                    report.push(site, d);
                }
            }
        }
        Ok(())
    }
}

/// Externals aliased in the deck share one buffer (in/out chaining); the
/// executor's canonicalization, mirrored.
fn canonical_alias(prog: &Program, name: &str) -> String {
    for (a, b) in &prog.deck.aliases {
        if name == b {
            return a.clone();
        }
    }
    name.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::testdecks;
    use crate::plan::{compile_src, CompileOptions, PlanSpec};

    fn compile(src: &str, vlen: usize) -> Program {
        compile_src(
            src,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    vector_len: Some(vlen),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn gate_env_semantics() {
        assert!(!gate_from(Some("0")));
        assert!(!gate_from(Some("")));
        assert!(!gate_from(Some("off")));
        assert!(!gate_from(Some("OFF")));
        assert!(gate_from(Some("1")));
        assert!(gate_from(Some("yes")));
        // Unset defaults to on in the test cfg.
        assert!(gate_from(None));
    }

    #[test]
    fn testdecks_verify_clean_at_all_vector_lengths() {
        for src in [testdecks::LAPLACE, testdecks::NORMALIZE, testdecks::CHAIN1D] {
            for vlen in [1, 4, 8] {
                let prog = compile(src, vlen);
                let report = check_program(&prog).unwrap();
                assert!(
                    !report.has_errors(),
                    "{} vlen {vlen}:\n{}",
                    prog.deck.name,
                    report.render()
                );
            }
        }
    }

    #[test]
    fn builtin_apps_verify_clean_and_lint_free() {
        for app in crate::apps::APP_NAMES {
            let prog = PlanSpec::app(app).compile().unwrap();
            // One probe here (debug builds): the integration matrix and
            // the CI `check` sweep run the full multi-probe pass.
            let ext = probe_extents(&prog, 2);
            let report = check_schedule_at(&prog, &ext, &[2]).unwrap();
            assert!(!report.has_errors(), "{app}:\n{}", report.render());
            assert!(
                lint_deck(&prog).iter().all(|d| d.severity != Severity::Error),
                "{app} has error-severity lints"
            );
        }
    }

    #[test]
    fn shrunk_window_is_reported_as_clobber() {
        // dbl(u)'s window along `i` holds the producer's run-ahead; halving
        // the allocation makes the i+1 write land on the cell the i-1 read
        // still needs.
        let mut prog = compile(testdecks::CHAIN1D, 1);
        let mut shrunk = false;
        for s in &mut prog.sp.storages {
            for sz in &mut s.sizes {
                if let DimSize::Window { alloc, .. } = sz {
                    if *alloc >= 2 {
                        *alloc /= 2;
                        shrunk = true;
                    }
                }
            }
        }
        assert!(shrunk, "chain1d must carry a windowed intermediate");
        let report = check_schedule(&prog).unwrap();
        assert!(
            report.diagnostics.iter().any(|d| d.rule == "stale-read"),
            "expected a stale-read finding:\n{}",
            report.render()
        );
    }

    #[test]
    fn shrunk_time_tile_halo_is_reported() {
        // Time tiling replays a `depth`-deep halo of each windowed
        // producer before every pass after the first. Shrinking that
        // halo by one leaves the first consumer reads of the pass on
        // cells still holding the previous pass's rotation — the serial
        // walk must catch it (the emitters and interpreter consume the
        // warmup bounds as pure syntax and would silently corrupt).
        let mut prog = compile_src(
            testdecks::CHAIN1D,
            CompileOptions {
                analysis: crate::analysis::AnalysisOptions {
                    time_tile: 4,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let clean = check_schedule(&prog).unwrap();
        assert!(!clean.has_errors(), "unmutated time-tiled plan must verify:\n{}", clean.render());
        let mut mutated = false;
        for np in &mut prog.sched.nests {
            for node in &mut np.body {
                if let Node::TimeTile(t) = node {
                    for w in &mut t.warmup {
                        if !mutated && w.depth > 0 {
                            w.depth -= 1;
                            mutated = true;
                        }
                    }
                }
            }
        }
        assert!(mutated, "chain1d at t=4 must lower a warmup halo to shrink");
        let report = check_schedule(&prog).unwrap();
        assert!(
            report
                .diagnostics
                .iter()
                .any(|d| d.rule == "stale-read" || d.rule == "def-before-use"),
            "expected the shrunk halo to be caught:\n{}",
            report.render()
        );
    }

    #[test]
    fn underrun_deck_is_a_lint_error() {
        // Widen laplace's stencil past the declared input: with `j`
        // starting at 0, the `j-1` read reaches index -1 of `g_cell`.
        let bad = testdecks::LAPLACE.replace("j: [1, Nj-1]", "j: [0, Nj-1]");
        let prog = compile(&bad, 1);
        let lints = lint_deck(&prog);
        assert!(
            lints
                .iter()
                .any(|d| d.severity == Severity::Error && d.rule == "input-underrun"),
            "expected input-underrun: {lints:?}"
        );
        // And the full report carries it as an error.
        let report = check_program(&prog).unwrap();
        assert!(report.has_errors());
    }

    #[test]
    fn report_dedups_by_rule_and_site() {
        let mut r = Report::default();
        r.push("a".into(), Diagnostic::error("bounds", "x".into()));
        r.push("a".into(), Diagnostic::error("bounds", "y".into()));
        r.push("b".into(), Diagnostic::warning("dead-kernel", "z".into()));
        assert_eq!(r.diagnostics.len(), 2);
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert!(r.has_errors());
        assert_eq!(r.render().lines().count(), 2);
        assert_eq!(r.render_errors(), "  error[bounds]: x");
    }
}
