//! Machine-readable bench reports: a stable, diffable JSON schema for
//! the vectorization and serving benchmarks (`hfav bench ... --json`).
//!
//! The schema is the contract: every row carries the same keys in the
//! same order, values are plain numbers/strings/bools, and the top-level
//! `schema` tag is versioned (`hfav-bench-vectorization/v1`,
//! `hfav-bench-serving/v1`, `hfav-bench-time-tiling/v1`). CI diffs the
//! *key structure* of a fresh run against the committed `BENCH_*.json`
//! baselines — values are advisory (they move with the host), the
//! schema is strict. Serialization is
//! hand-rolled (ordered keys, fixed float precision) so the crate needs
//! no JSON dependency and identical runs produce byte-identical files.

use std::fmt::Write;

/// Schema tag of [`vectorization_json`].
pub const VEC_SCHEMA: &str = "hfav-bench-vectorization/v1";
/// Schema tag of [`serving_json`].
pub const SERVE_SCHEMA: &str = "hfav-bench-serving/v1";
/// Schema tag of [`time_tiling_json`].
pub const TIME_TILE_SCHEMA: &str = "hfav-bench-time-tiling/v1";

/// One measured strategy of the vectorization benchmark.
#[derive(Debug, Clone)]
pub struct VecRow {
    pub app: String,
    /// Strategy label (`scalar`, `inner-vec`, `outer:k`, `parallel`,
    /// `parallel+tiled`, ...).
    pub strategy: String,
    /// Engine registry name the row ran on (`native`).
    pub engine: String,
    /// Effective vector length the plan compiled at.
    pub vlen: usize,
    /// Runtime worker count the row ran at (1 = serial).
    pub threads: usize,
    /// Grid shape, extent values in sorted-name order (`NixNjxNk`).
    pub extents: String,
    pub mcells_per_s: f64,
    pub speedup_vs_scalar: f64,
    /// Outputs bitwise-equal to the serial scalar baseline.
    pub bitwise_vs_scalar: bool,
    /// [`crate::schedule::ScheduleStats`] of the plan at this shape.
    pub invocations: u64,
    pub loads: u64,
    pub stores: u64,
    /// Chunks the plan's parallel levels decompose into at `threads`
    /// (0 = the plan has no parallel level).
    pub parallel_chunks: u64,
}

/// One measured point of the temporal-blocking sweep
/// (`hfav bench time-tiling`).
#[derive(Debug, Clone)]
pub struct TimeTileRow {
    pub app: String,
    /// Requested `--time-tile` depth.
    pub time_tile: usize,
    /// Depth the legality gate actually compiled (1 = fell back).
    pub effective: usize,
    /// Engine registry name the row ran on (`native`).
    pub engine: String,
    /// Runtime worker count the row ran at (1 = serial).
    pub threads: usize,
    /// Grid shape, extent values in sorted-name order.
    pub extents: String,
    /// Per-timestep throughput (one call serves `effective` steps).
    pub mcells_per_s: f64,
    pub speedup_vs_untiled: f64,
    /// Output bitwise-equal to the serial untiled run.
    pub bitwise_vs_untiled: bool,
}

/// One serving-benchmark scenario.
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub scenario: String,
    pub workers: usize,
    /// Intra-job worker count requested for every job (1 = serial).
    pub threads: usize,
    pub jobs: usize,
    pub distinct_plan_keys: usize,
    pub plan_compiles: u64,
    pub plan_hit_rate: f64,
    pub mcells_per_s: f64,
    pub batches: u64,
    pub batch_wall_ms: f64,
    /// Largest effective intra-job worker count the report recorded.
    pub threads_effective: u64,
}

/// JSON string escaping. An earlier hand-rolled version only handled
/// backslash and quote, so a deck path containing a newline or other
/// control character produced invalid JSON; [`crate::json::escape`]
/// covers the full mandatory set (quote, backslash, `\n\r\t\b\f`, and
/// `\u00XX` for remaining control characters).
fn esc(s: &str) -> String {
    crate::json::escape(s)
}

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0.000".to_string()
    }
}

fn header(out: &mut String, schema: &str) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"schema\": \"{schema}\",");
    let _ = writeln!(out, "  \"sysinfo\": {{ \"logical_cores\": {cores} }},");
    let _ = writeln!(out, "  \"rows\": [");
}

fn footer(out: &mut String) {
    let _ = writeln!(out, "  ]");
    let _ = writeln!(out, "}}");
}

/// Render the vectorization report (`BENCH_vectorization.json`).
pub fn vectorization_json(rows: &[VecRow]) -> String {
    let mut out = String::new();
    header(&mut out, VEC_SCHEMA);
    for (k, r) in rows.iter().enumerate() {
        let comma = if k + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{ \"app\": \"{}\", \"strategy\": \"{}\", \"engine\": \"{}\", \
             \"vlen\": {}, \"threads\": {}, \"extents\": \"{}\", \
             \"mcells_per_s\": {}, \"speedup_vs_scalar\": {}, \
             \"bitwise_vs_scalar\": {}, \"invocations\": {}, \"loads\": {}, \
             \"stores\": {}, \"parallel_chunks\": {} }}{comma}",
            esc(&r.app),
            esc(&r.strategy),
            esc(&r.engine),
            r.vlen,
            r.threads,
            esc(&r.extents),
            num(r.mcells_per_s),
            num(r.speedup_vs_scalar),
            r.bitwise_vs_scalar,
            r.invocations,
            r.loads,
            r.stores,
            r.parallel_chunks
        );
    }
    footer(&mut out);
    out
}

/// Render the temporal-blocking report (`BENCH_time_tiling.json`).
pub fn time_tiling_json(rows: &[TimeTileRow]) -> String {
    let mut out = String::new();
    header(&mut out, TIME_TILE_SCHEMA);
    for (k, r) in rows.iter().enumerate() {
        let comma = if k + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{ \"app\": \"{}\", \"time_tile\": {}, \"effective\": {}, \
             \"engine\": \"{}\", \"threads\": {}, \"extents\": \"{}\", \
             \"mcells_per_s\": {}, \"speedup_vs_untiled\": {}, \
             \"bitwise_vs_untiled\": {} }}{comma}",
            esc(&r.app),
            r.time_tile,
            r.effective,
            esc(&r.engine),
            r.threads,
            esc(&r.extents),
            num(r.mcells_per_s),
            num(r.speedup_vs_untiled),
            r.bitwise_vs_untiled
        );
    }
    footer(&mut out);
    out
}

/// Render the serving report (`BENCH_serving.json`).
pub fn serving_json(rows: &[ServeRow]) -> String {
    let mut out = String::new();
    header(&mut out, SERVE_SCHEMA);
    for (k, r) in rows.iter().enumerate() {
        let comma = if k + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{ \"scenario\": \"{}\", \"workers\": {}, \"threads\": {}, \
             \"jobs\": {}, \"distinct_plan_keys\": {}, \"plan_compiles\": {}, \
             \"plan_hit_rate\": {}, \"mcells_per_s\": {}, \"batches\": {}, \
             \"batch_wall_ms\": {}, \"threads_effective\": {} }}{comma}",
            esc(&r.scenario),
            r.workers,
            r.threads,
            r.jobs,
            r.distinct_plan_keys,
            r.plan_compiles,
            num(r.plan_hit_rate),
            num(r.mcells_per_s),
            r.batches,
            num(r.batch_wall_ms),
            r.threads_effective
        );
    }
    footer(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vec_row() -> VecRow {
        VecRow {
            app: "cosmo".into(),
            strategy: "parallel".into(),
            engine: "native".into(),
            vlen: 1,
            threads: 4,
            extents: "128x128x32".into(),
            mcells_per_s: 123.456789,
            speedup_vs_scalar: 1.75,
            bitwise_vs_scalar: true,
            invocations: 10,
            loads: 20,
            stores: 5,
            parallel_chunks: 4,
        }
    }

    #[test]
    fn vectorization_schema_is_stable() {
        let text = vectorization_json(&[vec_row(), vec_row()]);
        assert!(text.contains("\"schema\": \"hfav-bench-vectorization/v1\""), "{text}");
        assert!(text.contains("\"strategy\": \"parallel\""), "{text}");
        assert!(text.contains("\"mcells_per_s\": 123.457"), "{text}");
        assert!(text.contains("\"bitwise_vs_scalar\": true"), "{text}");
        assert!(text.contains("\"parallel_chunks\": 4"), "{text}");
        // Deterministic: two renders of the same rows are byte-identical.
        assert_eq!(text, vectorization_json(&[vec_row(), vec_row()]));
        // Exactly one trailing comma between the two rows, none after the
        // last — the output is real JSON.
        assert_eq!(text.matches("},").count(), 2, "{text}"); // sysinfo + row 1
    }

    #[test]
    fn time_tiling_schema_is_stable() {
        let r = TimeTileRow {
            app: "cosmo".into(),
            time_tile: 4,
            effective: 4,
            engine: "native".into(),
            threads: 1,
            extents: "128x128x32".into(),
            mcells_per_s: 321.98765,
            speedup_vs_untiled: 1.4,
            bitwise_vs_untiled: true,
        };
        let text = time_tiling_json(&[r.clone(), r]);
        assert!(text.contains("\"schema\": \"hfav-bench-time-tiling/v1\""), "{text}");
        assert!(text.contains("\"time_tile\": 4"), "{text}");
        assert!(text.contains("\"effective\": 4"), "{text}");
        assert!(text.contains("\"mcells_per_s\": 321.988"), "{text}");
        assert!(text.contains("\"bitwise_vs_untiled\": true"), "{text}");
        // Real JSON with deterministic rendering.
        let doc = crate::json::parse(&text).unwrap();
        assert_eq!(doc.get("rows").and_then(crate::json::Value::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn serving_schema_is_stable() {
        let r = ServeRow {
            scenario: "mixed-trace".into(),
            workers: 4,
            threads: 2,
            jobs: 30,
            distinct_plan_keys: 5,
            plan_compiles: 5,
            plan_hit_rate: 0.8333,
            mcells_per_s: 55.5,
            batches: 1,
            batch_wall_ms: 12.5,
            threads_effective: 2,
        };
        let text = serving_json(&[r]);
        assert!(text.contains("\"schema\": \"hfav-bench-serving/v1\""), "{text}");
        assert!(text.contains("\"plan_hit_rate\": 0.833"), "{text}");
        assert!(text.contains("\"threads_effective\": 2"), "{text}");
    }

    #[test]
    fn hostile_strings_round_trip_through_a_json_parser() {
        // Deck-file paths end up in `app`/`scenario`/`extents` fields;
        // quotes, backslashes, control characters and unicode must all
        // survive rendering and parse back to the original text.
        let hostile = [
            "decks/my deck.yaml",
            "decks/quo\"te.yaml",
            "C:\\decks\\win.yaml",
            "line\nbreak\tand\rcontrol\u{1}\u{1f}",
            "uni-ço∂é ☃",
        ];
        for s in hostile {
            let mut r = vec_row();
            r.app = s.to_string();
            r.strategy = s.to_string();
            let text = vectorization_json(&[r]);
            let doc = crate::json::parse(&text)
                .unwrap_or_else(|e| panic!("invalid JSON for {s:?}: {e}\n{text}"));
            let row = &doc.get("rows").and_then(crate::json::Value::as_arr).unwrap()[0];
            assert_eq!(row.get("app").and_then(crate::json::Value::as_str), Some(s));
            assert_eq!(row.get("strategy").and_then(crate::json::Value::as_str), Some(s));
        }
        let mut sr = ServeRow {
            scenario: "trace \"x\"\\\n".to_string(),
            workers: 1,
            threads: 1,
            jobs: 1,
            distinct_plan_keys: 1,
            plan_compiles: 1,
            plan_hit_rate: 0.0,
            mcells_per_s: 1.0,
            batches: 1,
            batch_wall_ms: 1.0,
            threads_effective: 1,
        };
        let text = serving_json(&[sr.clone()]);
        let doc = crate::json::parse(&text).unwrap();
        let row = &doc.get("rows").and_then(crate::json::Value::as_arr).unwrap()[0];
        assert_eq!(
            row.get("scenario").and_then(crate::json::Value::as_str),
            Some(sr.scenario.as_str())
        );
        sr.mcells_per_s = f64::NAN; // non-finite values render as 0.000
        assert!(crate::json::parse(&serving_json(&[sr])).is_ok());
    }
}
