//! The `hfav tune` driver: empirical plan selection over the knob
//! cross-product (ROADMAP "shape-class autotuner").
//!
//! The pipeline has three stages, cheapest first:
//!
//! 1. **Enumerate** ([`candidate_specs`]): the vectorization/tuning
//!    knob cross-product over the base spec — vector length (scalar and
//!    host SIMD width), lane dim (`inner` / `auto`-resolved outer),
//!    aligned heads, multi-dim tiling, §5.3 tuning — deduplicated by
//!    fingerprint. *Compilation is the legality gate*: the same
//!    `resolve_vec_dim` / `parallel_safe` analyses that protect serving
//!    reject illegal combinations here (e.g. tiling a deck with no
//!    k-independent outer dim), so an illegal knob set is filtered, not
//!    an error.
//! 2. **Rank** ([`legal_candidates`]): each surviving plan is costed
//!    with the analytical model ([`crate::schedule::cost::estimate`])
//!    over its walk counters ([`crate::plan::Program::schedule_stats`])
//!    at the tuning shape; plans with parallel levels are costed at
//!    every configured worker count. Ranking is cheap — no execution.
//! 3. **Time** ([`tune`]): only the `budget` best-ranked candidates are
//!    actually run ([`crate::bench::time_it`] medians on the configured
//!    engine), and the measured winner is returned as a
//!    [`TunedEntry`] ready for the tuned-plans DB
//!    ([`crate::plan::tunedb::TunedDb`]).
//!
//! The entry records the *resolved* knobs of the winning compiled plan
//! (concrete lane dim and vector length, never `auto`), so serving can
//! re-apply them without re-running any analysis.

use crate::analysis::VecDim;
use crate::bench::time_it;
use crate::engine::{self, PrepareCtx, RunConfig, Threads};
use crate::exec;
use crate::plan::tunedb::{deck_digest, ShapeClass, TunedEntry};
use crate::plan::{PlanSpec, Program};
use crate::schedule::cost;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Tuner configuration (CLI flags of `hfav tune`).
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Concrete extents to tune at, in sorted-name order (`--extents`).
    pub extents: Vec<i64>,
    /// Candidates to time after cost ranking (`--budget`).
    pub budget: usize,
    /// Engine registry name to time on (`--engine`).
    pub engine: String,
    /// Worker counts considered for plans with parallel levels.
    pub threads: Vec<usize>,
    /// Per-candidate timing: minimum reps and minimum measured seconds.
    pub min_reps: usize,
    pub min_time_s: f64,
}

impl TuneConfig {
    /// Defaults for a given tuning shape: time the 4 best candidates on
    /// the best available engine, considering serial and all-cores
    /// execution for parallel plans.
    pub fn for_extents(extents: Vec<i64>) -> TuneConfig {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        TuneConfig {
            extents,
            budget: 4,
            engine: default_engine().to_string(),
            threads: if cores > 1 { vec![1, cores] } else { vec![1] },
            min_reps: 3,
            min_time_s: 0.1,
        }
    }
}

/// The engine candidates are timed on by default: compiled C when a C
/// compiler is present (what production serves), else the interpreter.
pub fn default_engine() -> &'static str {
    match engine::registry().get("native") {
        Ok(b) if b.available().is_ready() => "native",
        _ => "exec",
    }
}

/// One ranked candidate: a legal (compiled) plan plus the worker count
/// it would run at and its predicted relative cost.
#[derive(Clone)]
pub struct Candidate {
    pub spec: PlanSpec,
    pub prog: Arc<Program>,
    pub threads: usize,
    pub cost: f64,
}

impl Candidate {
    /// Human-readable knob label (tune progress output).
    pub fn label(&self) -> String {
        format!(
            "vec_dim={} vlen={} aligned={} tiled={} tt={} tuned={} threads={}",
            self.prog.vec_dim(),
            self.prog.vector_len(),
            self.spec.is_aligned(),
            self.prog.tiled(),
            self.prog.time_tile(),
            self.spec.is_tuned(),
            self.threads
        )
    }
}

/// The knob cross-product over `base`, deduplicated by fingerprint (at
/// vector length 1 the lane-dim/aligned/tile knobs are no-ops, so the
/// scalar corner contributes only the §5.3-tuning toggle). Legality is
/// *not* checked here — [`legal_candidates`] compiles each spec and
/// drops the ones the analysis gates reject.
pub fn candidate_specs(base: &PlanSpec) -> Vec<PlanSpec> {
    let auto = crate::analysis::auto_vector_len();
    let mut vlens = vec![1usize];
    if auto > 1 {
        vlens.push(auto);
    }
    let mut out = Vec::new();
    for &vlen in &vlens {
        for tuned in [false, true] {
            // Temporal blocking is orthogonal to the vectorization knobs
            // (the gate falls ineligible decks back to 1, and the
            // fingerprint dedup below collapses nothing — tt is hashed).
            for tt in [1usize, 2] {
                let b = base.clone().vlen_resolved(Some(vlen)).tuned(tuned).time_tile(tt);
                if vlen == 1 {
                    out.push(b);
                    continue;
                }
                for vd in [VecDim::Inner, VecDim::Auto] {
                    for aligned in [false, true] {
                        for tiled in [false, true] {
                            out.push(b.clone().vec_dim(vd.clone()).aligned(aligned).tiled(tiled));
                        }
                    }
                }
            }
        }
    }
    let mut seen = BTreeSet::new();
    out.retain(|s| seen.insert(s.fingerprint()));
    out
}

/// Compile every candidate spec (the legality gate), cost the legal
/// ones at the tuning shape, and return them sorted best-first. Plans
/// without parallel levels are costed at one worker only; plans with
/// them get one candidate per configured worker count.
pub fn legal_candidates(base: &PlanSpec, cfg: &TuneConfig) -> Result<Vec<Candidate>, String> {
    let mut threads: Vec<usize> = cfg.threads.iter().map(|&t| t.max(1)).collect();
    threads.sort_unstable();
    threads.dedup();
    if threads.is_empty() {
        threads.push(1);
    }
    let mut out = Vec::new();
    for spec in candidate_specs(base) {
        let Ok(prog) = spec.compile() else {
            continue; // illegal knob set for this deck — filtered, not fatal
        };
        // Second gate behind compilation: a candidate whose lowered
        // schedule fails the static bounds/race/def-use proofs is
        // rejected with its reason rather than timed (see crate::verify).
        if let Some(reason) = crate::verify::reject_reason(&prog) {
            println!(
                "  candidate {} vlen={} rejected by verifier: {reason}",
                spec.variant_label(),
                prog.vector_len()
            );
            continue;
        }
        let prog = Arc::new(prog);
        let ext = extents_map(&prog, &cfg.extents)?;
        let base_stats = prog.schedule_stats(&ext, 1)?;
        let counts: &[usize] =
            if base_stats.parallel.is_empty() { &threads[..1] } else { &threads };
        for &t in counts {
            let stats = if t == 1 { base_stats.clone() } else { prog.schedule_stats(&ext, t)? };
            // Per-step cost: a time-tiled plan's walk counters cover all
            // its passes but its one invocation serves that many steps,
            // so candidates rank on a common per-step scale.
            out.push(Candidate {
                spec: spec.clone(),
                prog: prog.clone(),
                threads: t,
                cost: cost::estimate_per_step(&stats, prog.vector_len(), t, prog.time_tile()),
            });
        }
    }
    if out.is_empty() {
        return Err("no legal candidate plans for this deck".to_string());
    }
    out.sort_by(|a, b| a.cost.total_cmp(&b.cost));
    Ok(out)
}

/// Bind the tuning extents to the compiled deck's extent names (sorted
/// order, like trace-v3 overrides).
fn extents_map(prog: &Program, extents: &[i64]) -> Result<BTreeMap<String, i64>, String> {
    let names = crate::codegen::c99::extent_names(prog);
    if names.len() != extents.len() {
        return Err(format!(
            "--extents has {} values but deck `{}` takes {} ({})",
            extents.len(),
            prog.deck.name,
            names.len(),
            names.join("x")
        ));
    }
    Ok(names.iter().cloned().zip(extents.iter().copied()).collect())
}

/// Time one candidate on the configured engine: external inputs seeded,
/// outputs zero-filled (the coordinator's generic grid setup), one
/// validated run, then a [`time_it`] median. Returns (Mcells/s, reps).
fn time_candidate(c: &Candidate, cfg: &TuneConfig) -> Result<(f64, usize), String> {
    let backend = engine::registry().get(&cfg.engine)?;
    let exe = backend.prepare(&c.spec, &c.prog, &PrepareCtx { artifacts: None })?;
    let ext = extents_map(&c.prog, &cfg.extents)?;
    // One invocation of a time-tiled plan serves `time_tile` steps, so
    // its cell-updates count scales accordingly (same accounting as the
    // coordinator's step loop).
    let cells: f64 = ext.values().map(|&v| v.max(1) as f64).product::<f64>()
        * c.prog.time_tile().max(1) as f64;
    let input_names: BTreeSet<String> =
        c.prog.external_inputs().into_iter().map(|(n, _, _)| n).collect();
    let mut arrays = BTreeMap::new();
    for name in &input_names {
        let len = exec::external_len(&c.prog, name, &ext)?;
        arrays.insert(name.clone(), crate::apps::seeded(len, 42));
    }
    for (name, _, _) in c.prog.external_outputs() {
        if !arrays.contains_key(&name) {
            let len = exec::external_len(&c.prog, &name, &ext)?;
            arrays.insert(name, vec![0.0; len]);
        }
    }
    let run_cfg = RunConfig::with_threads(if c.threads > 1 {
        Threads::Fixed(c.threads)
    } else {
        Threads::Serial
    });
    let mut ws = exec::Workspace::new();
    exe.run_with(&ext, &mut arrays, &mut ws, &run_cfg)?;
    let mut err: Option<String> = None;
    let t = time_it(
        || {
            if err.is_none() {
                if let Err(e) = exe.run_with(&ext, &mut arrays, &mut ws, &run_cfg) {
                    err = Some(e);
                }
            }
        },
        cfg.min_reps,
        cfg.min_time_s,
    );
    if let Some(e) = err {
        return Err(format!("timing run failed: {e}"));
    }
    Ok((cells / t.secs / 1e6, t.reps))
}

/// Run the full tuning pipeline for `base` at the configured shape and
/// return the measured winner as a DB-ready [`TunedEntry`]. Progress is
/// printed (bench-style); persistence is the caller's (`hfav tune`
/// loads, inserts, and saves the DB around this).
pub fn tune(base: &PlanSpec, cfg: &TuneConfig) -> Result<TunedEntry, String> {
    let digest = deck_digest(base)?;
    let class = ShapeClass::of(&cfg.extents);
    let extents_label = cfg.extents.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("x");
    let ranked = legal_candidates(base, cfg)?;
    println!(
        "tune {} @ {extents_label} (class {}, engine {}): {} legal candidates, timing {}",
        base.target(),
        class.label(),
        cfg.engine,
        ranked.len(),
        cfg.budget.clamp(1, ranked.len()),
    );
    let mut best: Option<(TunedEntry, f64)> = None;
    let mut timed = 0usize;
    for (rank0, c) in ranked.iter().take(cfg.budget.max(1)).enumerate() {
        let (mcells, reps) = match time_candidate(c, cfg) {
            Ok(r) => r,
            Err(e) => {
                println!("  {:<58} FAILED: {e}", c.label());
                continue;
            }
        };
        timed += 1;
        println!("  {:<58} {mcells:>9.1} Mcells/s  ({reps} reps)", c.label());
        let entry = TunedEntry {
            deck_digest: digest,
            target: base.target().to_string(),
            shape_class: class.label(),
            extents: extents_label.clone(),
            tuned: c.spec.is_tuned(),
            vec_dim: c.prog.vec_dim().to_string(),
            vlen: c.prog.vector_len(),
            aligned: c.spec.is_aligned(),
            tiled: c.prog.tiled(),
            time_tile: c.prog.time_tile(),
            threads: c.threads,
            mcells_per_s: mcells,
            candidates: ranked.len(),
            timed: 0, // final count patched below
            reps,
            // Calibration provenance: where the cost model ranked the
            // winner (1 = the model's top pick) — `tune --report` reads
            // this back across the DB.
            predicted_rank: Some(rank0 + 1),
        };
        let better = match &best {
            None => true,
            Some((_, b)) => mcells > *b,
        };
        if better {
            best = Some((entry, mcells));
        }
    }
    let (mut entry, _) = best.ok_or("all timed candidates failed")?;
    entry.timed = timed;
    println!("  winner: {}  ({:.1} Mcells/s)", entry.knob_label(), entry.mcells_per_s);
    Ok(entry)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_specs_cover_the_knob_space_without_duplicates() {
        let specs = candidate_specs(&PlanSpec::app("cosmo"));
        let fps: BTreeSet<u64> = specs.iter().map(|s| s.fingerprint()).collect();
        assert_eq!(fps.len(), specs.len(), "duplicate fingerprints survived dedup");
        // At minimum the four scalar corners (tuned × time_tile) exist...
        assert!(specs.len() >= 4);
        assert!(specs.iter().any(|s| s.time_tile_depth() > 1), "time-tile axis missing");
        assert!(specs.iter().any(|s| s.time_tile_depth() == 1));
        // ...and when the host has SIMD lanes, the vector knob space too.
        if crate::analysis::auto_vector_len() > 1 {
            assert!(specs.len() >= 4 + 32, "vector cross-product missing: {}", specs.len());
            assert!(specs.iter().any(|s| s.is_tiled()));
            assert!(specs.iter().any(|s| s.is_aligned()));
            assert!(specs.iter().any(|s| s.is_tiled() && s.time_tile_depth() > 1));
        }
    }

    #[test]
    fn legal_candidates_rank_by_cost_and_respect_the_shape() {
        let cfg = TuneConfig {
            extents: vec![12, 12, 3],
            budget: 2,
            engine: "exec".to_string(),
            threads: vec![1, 2],
            min_reps: 1,
            min_time_s: 0.0,
        };
        let ranked = legal_candidates(&PlanSpec::app("cosmo"), &cfg).unwrap();
        assert!(!ranked.is_empty());
        for w in ranked.windows(2) {
            assert!(w[0].cost <= w[1].cost, "not sorted by cost");
        }
        for c in &ranked {
            assert!(c.cost.is_finite());
            assert!(c.threads >= 1);
        }
        // Wrong extent count is a hard error, not a silent mis-bind.
        let bad = TuneConfig { extents: vec![12, 12], ..cfg };
        assert!(legal_candidates(&PlanSpec::app("cosmo"), &bad).is_err());
    }

    #[test]
    fn tune_produces_a_db_ready_entry() {
        let cfg = TuneConfig {
            extents: vec![10, 10, 3],
            budget: 2,
            engine: "exec".to_string(),
            threads: vec![1],
            min_reps: 1,
            min_time_s: 0.0,
        };
        let base = PlanSpec::app("cosmo");
        let entry = tune(&base, &cfg).unwrap();
        assert_eq!(entry.deck_digest, deck_digest(&base).unwrap());
        assert_eq!(entry.shape_class, ShapeClass::of(&[10, 10, 3]).label());
        assert_eq!(entry.extents, "10x10x3");
        assert!(entry.mcells_per_s > 0.0);
        assert!(entry.vlen >= 1);
        assert_ne!(entry.vec_dim, "auto", "entry must record the resolved lane dim");
        assert!(entry.timed >= 1 && entry.timed <= 2);
        assert!(entry.candidates >= entry.timed);
        assert!(entry.reps >= 1);
        assert!(entry.time_tile >= 1);
        let rank = entry.predicted_rank.expect("tune must record the winner's predicted rank");
        assert!(rank >= 1 && rank <= cfg.budget, "rank {rank} outside the timed prefix");
        // The recorded knobs apply onto a fresh spec without error.
        entry.apply(PlanSpec::app("cosmo")).unwrap();
    }
}
