//! Benchmark harness: timing utilities and one driver per paper
//! table/figure. Each driver prints the same rows/series the paper
//! reports (throughput vs problem size per implementation variant) and a
//! CSV block for plotting.

pub mod report;
pub mod tune;

use crate::analysis::VecDim;
use crate::apps::{self, Variant};
use crate::engine::Threads;
use crate::plan::{PlanSpec, Vlen};
use std::collections::BTreeMap;
use std::time::Instant;

/// Safety cap on [`time_it`] reps — a backstop against pathological
/// spins, set far above what `min_time_s` needs on any real kernel.
pub const MAX_REPS: usize = 100_000;

/// One timing measurement: median seconds-per-call and the rep count
/// the median came from (recorded by the tuner's DB entries).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timing {
    /// Median seconds-per-call across the measured reps.
    pub secs: f64,
    /// Reps the median was taken over.
    pub reps: usize,
}

/// Run `f` repeatedly: a warmup call, then until *both* at least
/// `min_reps` reps have run *and* `min_time_s` has elapsed (an earlier
/// version broke at a hard 1000-rep cap before the time check, silently
/// under-measuring fast kernels on exactly the small shapes the tuner
/// times most). [`MAX_REPS`] remains as a generous safety cap. Always
/// measures at least one rep.
pub fn time_it<F: FnMut()>(mut f: F, min_reps: usize, min_time_s: f64) -> Timing {
    f(); // warmup
    let mut times = Vec::new();
    let start = Instant::now();
    while times.is_empty()
        || (times.len() < MAX_REPS
            && (times.len() < min_reps || start.elapsed().as_secs_f64() < min_time_s))
    {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing { secs: times[times.len() / 2], reps: times.len() }
}

/// Table-row printer: name, size, cell-updates/s.
pub fn row(label: &str, size: usize, secs: f64, cells: f64) {
    println!(
        "  {label:<14} n={size:<6} {:>10.1} Mcells/s   ({:.3} ms)",
        cells / secs / 1e6,
        secs * 1e3
    );
}

/// §T1: print the testbed description (the paper's Table 1 analogue).
pub fn sysinfo() -> String {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let model = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string())
        })
        .unwrap_or_else(|| "unknown".into());
    let mem_kb = std::fs::read_to_string("/proc/meminfo")
        .ok()
        .and_then(|s| {
            s.lines().find(|l| l.starts_with("MemTotal")).and_then(|l| {
                l.split_whitespace().nth(1).and_then(|x| x.parse::<u64>().ok())
            })
        })
        .unwrap_or(0);
    format!(
        "Table 1 (testbed): cpu=\"{model}\" logical_cores={cores} mem={:.1} GiB os=linux\n\
         (paper used SKX 2x24c / KNL 68c; shapes, not absolute numbers, are the claim)",
        mem_kb as f64 / 1024.0 / 1024.0
    )
}

/// Figure 12: normalization throughput, autovec vs HFAV (native-compiled
/// generated code), across problem sizes. Returns CSV lines.
pub fn normalization(sizes: &[usize]) -> Vec<String> {
    let mut csv = vec!["app,size,variant,mcells_per_s".to_string()];
    println!("Figure 12 — normalization example (cell updates/s):");
    for &n in sizes {
        let q = apps::seeded(n * (n + 1), 42);
        let mut out = vec![0.0; n * n];
        // autovec: hand-written unfused sweeps (what the compiler sees).
        let t_auto = time_it(
            || apps::normalization::reference(&q, n, n, &mut out),
            3,
            0.2,
        )
        .secs;
        row("autovec", n, t_auto, (n * n) as f64);
        csv.push(format!("normalize,{n},autovec,{:.3}", (n * n) as f64 / t_auto / 1e6));
        // HFAV: generated C, cc -O3, dlopen.
        let prog = PlanSpec::app("normalize").compile().unwrap();
        let module = crate::codegen::native::build(&prog, &Default::default()).unwrap();
        let mut ext = BTreeMap::new();
        ext.insert("Nj".to_string(), n as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_q".to_string(), q.clone());
        arrays.insert("g_out".to_string(), vec![0.0; n * n]);
        let t_hfav = time_it(|| module.run(&ext, &mut arrays).unwrap(), 3, 0.2).secs;
        row("HFAV", n, t_hfav, (n * n) as f64);
        csv.push(format!("normalize,{n},hfav,{:.3}", (n * n) as f64 / t_hfav / 1e6));
        println!("    speedup {:.2}x", t_auto / t_hfav);
    }
    csv
}

/// Figure 11: COSMO micro-kernels — STELLA-like vs HFAV vs HFAV+Tuning.
pub fn cosmo(sizes: &[usize], nk: usize) -> Vec<String> {
    let mut csv = vec!["app,size,variant,mcells_per_s".to_string()];
    println!("Figure 11 — COSMO micro-kernels (cell updates/s, nk={nk}):");
    for &n in sizes {
        let u = apps::seeded(nk * n * n, 7);
        let cells = (nk * (n - 4) * (n - 4)) as f64;
        let mut out = vec![0.0; nk * (n - 4) * (n - 4)];
        let t_ref = time_it(|| apps::cosmo::reference(&u, nk, n, n, &mut out), 3, 0.2).secs;
        row("autovec", n, t_ref, cells);
        csv.push(format!("cosmo,{n},autovec,{:.3}", cells / t_ref / 1e6));
        let t_st = time_it(|| apps::cosmo::stella(&u, nk, n, n, &mut out), 3, 0.2).secs;
        row("STELLA", n, t_st, cells);
        csv.push(format!("cosmo,{n},stella,{:.3}", cells / t_st / 1e6));

        let prog = PlanSpec::app("cosmo").compile().unwrap();
        let module = crate::codegen::native::build(&prog, &Default::default()).unwrap();
        let mut ext = BTreeMap::new();
        ext.insert("Nk".to_string(), nk as i64);
        ext.insert("Nj".to_string(), n as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_u".to_string(), u.clone());
        arrays.insert("g_out".to_string(), vec![0.0; nk * (n - 4) * (n - 4)]);
        let t_hfav = time_it(|| module.run(&ext, &mut arrays).unwrap(), 3, 0.2).secs;
        row("HFAV", n, t_hfav, cells);
        csv.push(format!("cosmo,{n},hfav,{:.3}", cells / t_hfav / 1e6));

        // HFAV + Tuning (paper §5.3): innermost windows kept as full
        // rows so the steady state vectorizes.
        let tuned = PlanSpec::app("cosmo").tuned(true).compile().unwrap();
        let module_t = crate::codegen::native::build(&tuned, &Default::default()).unwrap();
        let mut arrays_t = BTreeMap::new();
        arrays_t.insert("g_u".to_string(), u.clone());
        arrays_t.insert("g_out".to_string(), vec![0.0; nk * (n - 4) * (n - 4)]);
        let t_tuned = time_it(|| module_t.run(&ext, &mut arrays_t).unwrap(), 3, 0.2).secs;
        row("HFAV+Tuning", n, t_tuned, cells);
        csv.push(format!("cosmo,{n},hfav_tuned,{:.3}", cells / t_tuned / 1e6));
        println!(
            "    STELLA/HFAV+T {:.2}x   autovec/HFAV+T {:.2}x",
            t_st / t_tuned,
            t_ref / t_tuned
        );
    }
    csv
}

/// advect3d: 3D upwind advection (flux form) — autovec vs HFAV
/// (native-compiled generated code) on an `nk × n × n` slab. The deck
/// rolls a window along the *outermost* dim, so this is the bench row
/// for contraction's worst-covered shape.
pub fn advect3d(sizes: &[usize], nk: usize) -> Vec<String> {
    let mut csv = vec!["app,size,variant,mcells_per_s".to_string()];
    println!("advect3d — 3D upwind advection sweep (cell updates/s, nk={nk}):");
    for &n in sizes {
        let u = apps::seeded(nk * n * n, 19);
        let cells = ((nk - 1) * (n - 1) * (n - 1)) as f64;
        let mut out = vec![0.0; (nk - 1) * (n - 1) * (n - 1)];
        let t_ref = time_it(|| apps::advect3d::reference(&u, nk, n, n, &mut out), 3, 0.2).secs;
        row("autovec", n, t_ref, cells);
        csv.push(format!("advect3d,{n},autovec,{:.3}", cells / t_ref / 1e6));

        let prog = PlanSpec::app("advect3d").compile().unwrap();
        let module = crate::codegen::native::build(&prog, &Default::default()).unwrap();
        let mut ext = BTreeMap::new();
        ext.insert("Nk".to_string(), nk as i64);
        ext.insert("Nj".to_string(), n as i64);
        ext.insert("Ni".to_string(), n as i64);
        let mut arrays = BTreeMap::new();
        arrays.insert("g_u".to_string(), u.clone());
        arrays.insert("g_out".to_string(), vec![0.0; (nk - 1) * (n - 1) * (n - 1)]);
        let t_hfav = time_it(|| module.run(&ext, &mut arrays).unwrap(), 3, 0.2).secs;
        row("HFAV", n, t_hfav, cells);
        csv.push(format!("advect3d,{n},hfav,{:.3}", cells / t_hfav / 1e6));
        println!("    speedup {:.2}x", t_ref / t_hfav);
    }
    csv
}

/// Figure 13: Hydro2D — autovec vs handvec vs HFAV (native).
pub fn hydro2d(sizes: &[usize], steps: usize) -> Vec<String> {
    use crate::apps::hydro2d::solver::*;
    let mut csv = vec!["app,size,variant,mcells_per_s".to_string()];
    println!("Figure 13 — Hydro2D (cell updates/s over {steps} steps):");
    for &n in sizes {
        let cells = (n * n * steps) as f64;
        for (label, mk) in [
            ("autovec", 0usize),
            ("handvec", 1usize),
            ("HFAV", 2usize),
            ("HFAV+Tuning", 3usize),
        ] {
            let mut state = sod(n, n);
            let mut sweeper: Box<dyn Sweeper> = match mk {
                0 => Box::new(RefSweeper),
                1 => Box::new(HandvecSweeper::new()),
                2 => {
                    let prog = PlanSpec::app("hydro2d").compile().unwrap();
                    Box::new(NativeSweeper::new(&prog).unwrap())
                }
                _ => {
                    let prog = PlanSpec::app("hydro2d").tuned(true).compile().unwrap();
                    Box::new(NativeSweeper::new(&prog).unwrap())
                }
            };
            let t0 = Instant::now();
            for _ in 0..steps {
                step(&mut state, 1.0 / n as f64, 0.4, sweeper.as_mut()).unwrap();
            }
            let secs = t0.elapsed().as_secs_f64();
            row(label, n, secs, cells);
            csv.push(format!(
                "hydro2d,{n},{},{:.3}",
                label.to_lowercase(),
                cells / secs / 1e6
            ));
        }
    }
    csv
}

/// §M1/M2: footprint table — measured intermediate words, fused vs
/// autovec, with the paper's formulas for comparison.
pub fn footprint() -> Vec<String> {
    let mut lines = Vec::new();
    println!("Footprint (intermediate storage words):");
    let cases = [
        ("cosmo", apps::cosmo::DECK, vec![("Nk", 8i64), ("Nj", 512), ("Ni", 512)]),
        ("hydro2d", crate::apps::hydro2d::DECK, vec![("Nj", 1024), ("Ni", 1024)]),
        ("normalize", apps::normalization::DECK, vec![("Nj", 512), ("Ni", 512)]),
        ("laplace", apps::laplace::DECK, vec![("Nj", 512), ("Ni", 512)]),
    ];
    for (name, deck, ext) in cases {
        let extents: BTreeMap<String, i64> =
            ext.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        let fused = PlanSpec::deck_src(deck).compile().unwrap();
        let naive = PlanSpec::deck_src(deck).variant(Variant::Autovec).compile().unwrap();
        let fw = fused.footprint_words(&extents).unwrap();
        let nw = naive.footprint_words(&extents).unwrap();
        let line = format!(
            "  {name:<10} autovec={nw:>12} words   hfav={fw:>8} words   reduction {:.0}x",
            nw as f64 / fw.max(1) as f64
        );
        println!("{line}");
        lines.push(line);
    }
    lines
}

/// Serving scenario: a mixed job trace repeated `repeat` times through
/// the coordinator — the compile-once/run-many amortization claim in
/// numbers. Reports pipeline-compilation count (== distinct plan keys),
/// plan-cache hit rate, buffer reuse and end-to-end throughput.
///
/// With `vlen` (`bench serving --vlen 8`), a second phase serves a
/// hydro2d native-engine trace twice — forced scalar (`vlen 1`) and at
/// the requested vector length — and reports the scalar-vs-vector
/// throughput ratio; the cache shape (distinct keys, hit rate) is
/// identical in both runs, isolating the codegen effect.
pub fn serving(
    workers: usize,
    repeat: usize,
    vlen: Option<usize>,
    threads: Threads,
) -> (Vec<String>, Vec<report::ServeRow>) {
    use crate::coordinator::{distinct_plan_keys, repeat_jobs, Coordinator, Job};
    let template: Vec<Job> = [
        ("laplace", Variant::Hfav, 64, 1),
        ("laplace", Variant::Autovec, 64, 1),
        ("normalize", Variant::Hfav, 64, 1),
        ("cosmo", Variant::Hfav, 24, 1),
        ("hydro2d", Variant::Hfav, 16, 1),
    ]
    .iter()
    .map(|&(app, variant, size, steps)| {
        Job::new(0, PlanSpec::app(app).variant(variant), "exec", size, steps)
            .with_threads(threads)
    })
    .collect();
    let jobs = repeat_jobs(&template, repeat);
    let n = jobs.len();
    let distinct = distinct_plan_keys(&jobs);
    println!(
        "Serving — {n} jobs over {distinct} distinct plan keys, {workers} workers, \
         threads {}:",
        threads.label()
    );
    let c = Coordinator::start(workers, None);
    let t0 = Instant::now();
    let results = c.run_batch(jobs);
    let wall = t0.elapsed();
    let failed = results.iter().filter(|r| !r.ok).count();
    let rep = c.report(wall);
    for line in rep.to_string().lines() {
        println!("  {line}");
    }
    if failed > 0 {
        println!("  WARNING: {failed} jobs failed");
    }
    let mut csv = vec!["jobs,distinct_keys,compiles,hit_rate,mcells_per_s".to_string()];
    csv.push(format!(
        "{n},{distinct},{},{:.3},{:.3}",
        rep.plans.computes,
        rep.plans.hit_rate(),
        rep.throughput() / 1e6
    ));
    let mut rows = vec![report::ServeRow {
        scenario: "mixed-trace".to_string(),
        workers,
        threads: threads.resolve(),
        jobs: n,
        distinct_plan_keys: distinct,
        plan_compiles: rep.plans.computes,
        plan_hit_rate: rep.plans.hit_rate(),
        mcells_per_s: rep.throughput() / 1e6,
        batches: rep.batches,
        batch_wall_ms: rep.batch_wall.as_secs_f64() * 1e3,
        threads_effective: rep.threads_effective,
    }];
    c.shutdown();

    // Scalar-vs-vector phase (hydro2d, native engine) — only when a
    // vector length was explicitly requested (`bench serving --vlen N`).
    let v = vlen.unwrap_or(1);
    if v > 1 {
        println!("Serving, scalar vs vector — hydro2d native, vlen 1 vs {v}:");
        let mut serve_at = |force: usize| -> (f64, f64, u64) {
            let template: Vec<Job> = (0..2 * workers.max(1))
                .map(|i| {
                    Job::new(
                        i as u64,
                        PlanSpec::app("hydro2d").vlen_resolved(Some(force)),
                        "native",
                        128,
                        2,
                    )
                    .with_threads(threads)
                })
                .collect();
            let jobs = repeat_jobs(&template, repeat.max(2));
            let n = jobs.len();
            let distinct = distinct_plan_keys(&jobs);
            let c = Coordinator::start(workers, None);
            let t0 = Instant::now();
            let results = c.run_batch(jobs);
            let wall = t0.elapsed();
            let rep = c.report(wall);
            let bad = results.iter().filter(|r| !r.ok).count();
            if bad > 0 {
                println!("  WARNING: {bad} jobs failed at vlen {force}");
            }
            rows.push(report::ServeRow {
                scenario: format!("hydro2d-native-vlen{force}"),
                workers,
                threads: threads.resolve(),
                jobs: n,
                distinct_plan_keys: distinct,
                plan_compiles: rep.plans.computes,
                plan_hit_rate: rep.plans.hit_rate(),
                mcells_per_s: rep.throughput() / 1e6,
                batches: rep.batches,
                batch_wall_ms: rep.batch_wall.as_secs_f64() * 1e3,
                threads_effective: rep.threads_effective,
            });
            c.shutdown();
            (rep.throughput(), rep.plans.hit_rate(), rep.plans.computes)
        };
        let (t1, h1, c1) = serve_at(1);
        let (tv, hv, cv) = serve_at(v);
        let speedup = if t1 > 0.0 { tv / t1 } else { 0.0 };
        println!(
            "  vlen 1: {:.1} Mcells/s (hit_rate {:.1}%, compiles {c1})",
            t1 / 1e6,
            100.0 * h1
        );
        println!(
            "  vlen {v}: {:.1} Mcells/s (hit_rate {:.1}%, compiles {cv})",
            tv / 1e6,
            100.0 * hv
        );
        println!("  vector/scalar throughput ratio: {speedup:.2}x");
        csv.push("vlen,mcells_per_s,hit_rate,speedup_vs_scalar".to_string());
        csv.push(format!("1,{:.3},{h1:.3},1.00", t1 / 1e6));
        csv.push(format!("{v},{:.3},{hv:.3},{speedup:.2}", tv / 1e6));
    }
    (csv, rows)
}

/// Vectorization-strategy comparison: scalar vs inner-dim strips vs
/// outer-dim lanes vs the aligned specialization vs multi-dim lane
/// tiling (outer lanes × inner strips) vs temporal blocking
/// (`time-tiled:4`), measured on the native-C engine for cosmo (outer
/// dim `k`, 32×128×128) and hydro2d (outer dim `j`, 64 rows × 256
/// cells). All eight compiled variants are distinct `PlanSpec`
/// fingerprints, so a serving pool would cache and dispatch them as
/// distinct plans.
pub fn vectorization(vlen: usize, threads: usize) -> (Vec<String>, Vec<report::VecRow>) {
    let v = vlen.max(2);
    let t = threads.max(2);
    let mut csv =
        vec!["app,strategy,threads,mcells_per_s,speedup_vs_scalar,bitwise".to_string()];
    let mut rows = Vec::new();
    println!("Vectorization strategies (native C, vlen {v}, parallel rows at {t} threads):");

    // cosmo: 3-D fourth-order diffusion, outer dim k.
    {
        let (nk, n) = (32usize, 128usize);
        let ext: BTreeMap<String, i64> = [("Nk", nk), ("Nj", n), ("Ni", n)]
            .into_iter()
            .map(|(k, x)| (k.to_string(), x as i64))
            .collect();
        let cells = (nk * (n - 4) * (n - 4)) as f64;
        let mut inputs = BTreeMap::new();
        inputs.insert("g_u".to_string(), apps::seeded(nk * n * n, 7));
        let mut outputs = BTreeMap::new();
        outputs.insert("g_out".to_string(), vec![0.0; nk * (n - 4) * (n - 4)]);
        let case = Case { v, threads: t, app: "cosmo", outer: "k", n, cells };
        vectorization_case(&mut csv, &mut rows, &case, &ext, &inputs, &outputs);
    }

    // hydro2d sweep: independent rows, outer dim j; physically sane
    // seeded state (positive density/energy, small momenta).
    {
        let (nj, ni) = (64usize, 256usize);
        let ext: BTreeMap<String, i64> = [("Nj", nj), ("Ni", ni)]
            .into_iter()
            .map(|(k, x)| (k.to_string(), x as i64))
            .collect();
        let cells = (nj * ni) as f64;
        let prog = PlanSpec::app("hydro2d").compile().unwrap();
        let mut inputs = BTreeMap::new();
        for (name, _, _) in prog.external_inputs() {
            let len = crate::exec::external_len(&prog, &name, &ext).unwrap();
            let vals: Vec<f64> = match name.as_str() {
                "g_rho" => apps::seeded(len, 1).iter().map(|x| 0.5 + x).collect(),
                "g_E" => apps::seeded(len, 2).iter().map(|x| 2.0 + x).collect(),
                "g_dtdx" => vec![0.05],
                _ => apps::seeded(len, 3).iter().map(|x| 0.1 * x).collect(),
            };
            inputs.insert(name, vals);
        }
        let mut outputs = BTreeMap::new();
        for (name, _, _) in prog.external_outputs() {
            let len = crate::exec::external_len(&prog, &name, &ext).unwrap();
            outputs.insert(name, vec![0.0; len]);
        }
        let case = Case { v, threads: t, app: "hydro2d", outer: "j", n: ni, cells };
        vectorization_case(&mut csv, &mut rows, &case, &ext, &inputs, &outputs);
    }

    (csv, rows)
}

/// The strategy specs compared by [`vectorization`] for one app
/// (scalar baseline first; `tiled` = outer lanes × inner strips, the
/// schedule-IR multi-dim tiling). The third element is the *runtime*
/// worker count the strategy runs at — `parallel` rows reuse the scalar
/// and tiled *plans* and differ only in the [`Threads`] knob, which is
/// the whole point: thread count is outside the plan fingerprint.
fn vectorization_strategies(
    app: &str,
    outer: &str,
    v: usize,
    threads: usize,
) -> Vec<(String, PlanSpec, usize)> {
    let outer_spec =
        || PlanSpec::app(app).vlen(Vlen::Fixed(v)).vec_dim(VecDim::Outer(outer.to_string()));
    vec![
        ("scalar".to_string(), PlanSpec::app(app).vlen(Vlen::Fixed(1)), 1),
        ("inner-vec".to_string(), PlanSpec::app(app).vlen(Vlen::Fixed(v)), 1),
        ("inner+aligned".to_string(), PlanSpec::app(app).vlen(Vlen::Fixed(v)).aligned(true), 1),
        (format!("outer:{outer}"), outer_spec(), 1),
        (format!("outer:{outer}+aligned"), outer_spec().aligned(true), 1),
        (format!("tiled:{outer}"), outer_spec().tiled(true), 1),
        ("parallel".to_string(), PlanSpec::app(app).vlen(Vlen::Fixed(1)), threads),
        ("parallel+tiled".to_string(), outer_spec().tiled(true), threads),
        // Temporal blocking rows: one invocation serves `t` timesteps
        // (per-step accounting in `vectorization_case`), comparing
        // cache-resident multi-step sweeps against the one-sweep
        // strategies. Apps whose dependence shape fails the legality
        // gate fall back untiled (effective t = 1).
        (
            "time-tiled:4".to_string(),
            PlanSpec::app(app).vlen(Vlen::Fixed(1)).time_tile(4),
            1,
        ),
        (
            "parallel+tiled+time-tiled:4".to_string(),
            outer_spec().tiled(true).time_tile(4),
            threads,
        ),
    ]
}

/// One app of the vectorization comparison: fixed compile-time knobs
/// plus the worker count the `parallel` rows run at.
struct Case<'a> {
    v: usize,
    threads: usize,
    app: &'a str,
    outer: &'a str,
    n: usize,
    cells: f64,
}

/// Time every strategy of one app on the native-C engine and report
/// rows + CSV (first strategy is the scalar baseline). Every strategy's
/// output is compared bitwise against the serial scalar baseline before
/// timing, and each row carries the plan's walk-derived
/// [`crate::schedule::ScheduleStats`] at its worker count.
fn vectorization_case(
    csv: &mut Vec<String>,
    rows: &mut Vec<report::VecRow>,
    case: &Case<'_>,
    ext: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    outputs: &BTreeMap<String, Vec<f64>>,
) {
    let (app, outer) = (case.app, case.outer);
    let extents_label = ext.values().map(|v| v.to_string()).collect::<Vec<_>>().join("x");
    let mut t_scalar = 0.0;
    let mut baseline: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let strategies = vectorization_strategies(app, outer, case.v, case.threads);
    for (k, (label, spec, nthreads)) in strategies.into_iter().enumerate() {
        let prog = spec.compile().unwrap();
        let module = crate::codegen::native::build(&prog, &Default::default()).unwrap();
        let knob = if nthreads > 1 { Threads::Fixed(nthreads) } else { Threads::Serial };
        let mut arrays = inputs.clone();
        for (name, zeros) in outputs {
            arrays.insert(name.clone(), zeros.clone());
        }
        // Correctness first: one run, compared bitwise against the
        // serial scalar baseline (which row 0 establishes).
        module.run_with(ext, &mut arrays, knob).unwrap();
        let bitwise = if k == 0 {
            for name in outputs.keys() {
                baseline.insert(name.clone(), arrays[name].clone());
            }
            true
        } else {
            outputs.keys().all(|name| arrays[name] == baseline[name])
        };
        // Per-timestep accounting: a time-tiled plan's single call
        // serves `prog.time_tile()` steps, the rest serve exactly one.
        let eff_t = prog.time_tile().max(1) as f64;
        let t = time_it(|| module.run_with(ext, &mut arrays, knob).unwrap(), 3, 0.2).secs
            / eff_t;
        if k == 0 {
            t_scalar = t;
        }
        let stats = prog.schedule_stats(ext, nthreads.max(1)).unwrap();
        row(&format!("{app}/{label}"), case.n, t, case.cells);
        println!(
            "      {:.2}x vs scalar{}",
            t_scalar / t,
            if bitwise { "" } else { "  BITWISE MISMATCH" }
        );
        csv.push(format!(
            "{app},{label},{nthreads},{:.3},{:.2},{bitwise}",
            case.cells / t / 1e6,
            t_scalar / t
        ));
        rows.push(report::VecRow {
            app: app.to_string(),
            strategy: label,
            engine: "native".to_string(),
            vlen: prog.vector_len(),
            threads: nthreads,
            extents: extents_label.clone(),
            mcells_per_s: case.cells / t / 1e6,
            speedup_vs_scalar: t_scalar / t,
            bitwise_vs_scalar: bitwise,
            invocations: stats.invocations,
            loads: stats.loads,
            stores: stats.stores,
            parallel_chunks: stats.parallel.iter().map(|p| p.chunks as u64).sum(),
        });
    }
}

/// Temporal-blocking sweep: `t_block ∈ {1, 2, 4, 8}` on the two 3-D
/// window-rolling apps (cosmo 32×128×128 and advect3d on the same
/// slab), native-C engine, serial and threaded. One call of a plan
/// compiled at `--time-tile t` performs `t` sweep passes per
/// cache-resident block, and the coordinator serves `t` timesteps per
/// call — so throughput counts `cells × effective_t` per invocation.
/// `effective_t` is read back from the compiled plan: apps whose
/// dependence shape fails the legality gate fall back untiled and are
/// reported honestly at `effective=1`. Every row's output is compared
/// bitwise against the serial untiled run first (idempotent sweeps make
/// temporal blocking bit-exact, not just tolerance-close).
pub fn time_tiling(threads: usize) -> (Vec<String>, Vec<report::TimeTileRow>) {
    let t_par = threads.max(2);
    let mut csv = vec![
        "app,time_tile,effective,threads,mcells_per_s,speedup_vs_untiled,bitwise".to_string(),
    ];
    let mut rows = Vec::new();
    println!("Temporal blocking sweep (native C, parallel rows at {t_par} threads):");
    for app in ["cosmo", "advect3d"] {
        let (nk, n) = (32usize, 128usize);
        let ext: BTreeMap<String, i64> = [("Nk", nk), ("Nj", n), ("Ni", n)]
            .into_iter()
            .map(|(k, x)| (k.to_string(), x as i64))
            .collect();
        let out_len = match app {
            "cosmo" => nk * (n - 4) * (n - 4),
            _ => (nk - 1) * (n - 1) * (n - 1),
        };
        let cells = out_len as f64;
        let extents_label =
            ext.values().map(|v| v.to_string()).collect::<Vec<_>>().join("x");
        let u = apps::seeded(nk * n * n, 7);
        let mut baseline: Vec<f64> = Vec::new();
        // Serial untiled per-step time, the speedup denominator.
        let mut per_step_t1 = 0.0;
        for &tt in &[1usize, 2, 4, 8] {
            for nthreads in [1usize, t_par] {
                let prog = PlanSpec::app(app).time_tile(tt).compile().unwrap();
                let eff = prog.time_tile().max(1);
                let module =
                    crate::codegen::native::build(&prog, &Default::default()).unwrap();
                let knob =
                    if nthreads > 1 { Threads::Fixed(nthreads) } else { Threads::Serial };
                let mut arrays = BTreeMap::new();
                arrays.insert("g_u".to_string(), u.clone());
                arrays.insert("g_out".to_string(), vec![0.0; out_len]);
                module.run_with(&ext, &mut arrays, knob).unwrap();
                let bitwise = if baseline.is_empty() {
                    baseline = arrays["g_out"].clone();
                    true
                } else {
                    arrays["g_out"] == baseline
                };
                let secs =
                    time_it(|| module.run_with(&ext, &mut arrays, knob).unwrap(), 3, 0.2)
                        .secs;
                let per_step = secs / eff as f64;
                if tt == 1 && nthreads == 1 {
                    per_step_t1 = per_step;
                }
                let speedup = if per_step > 0.0 { per_step_t1 / per_step } else { 0.0 };
                let label = format!("t={tt}(eff {eff}) thr={nthreads}");
                row(&format!("{app}/{label}"), n, per_step, cells);
                println!(
                    "      {speedup:.2}x vs untiled serial{}",
                    if bitwise { "" } else { "  BITWISE MISMATCH" }
                );
                csv.push(format!(
                    "{app},{tt},{eff},{nthreads},{:.3},{speedup:.2},{bitwise}",
                    cells / per_step / 1e6
                ));
                rows.push(report::TimeTileRow {
                    app: app.to_string(),
                    time_tile: tt,
                    effective: eff,
                    engine: "native".to_string(),
                    threads: nthreads,
                    extents: extents_label.clone(),
                    mcells_per_s: cells / per_step / 1e6,
                    speedup_vs_untiled: speedup,
                    bitwise_vs_untiled: bitwise,
                });
            }
        }
    }
    (csv, rows)
}

/// P1: PJRT artifacts — fused (Pallas) vs unfused (jnp) on the CPU PJRT
/// client, loaded and driven from Rust.
pub fn pjrt(artifacts: &std::path::Path) -> Result<Vec<String>, String> {
    let rt = crate::runtime::Runtime::cpu(artifacts).map_err(|e| e.to_string())?;
    let mut csv = vec!["artifact,ms_per_call".to_string()];
    println!("PJRT artifacts (platform {}):", rt.platform());
    for name in [
        "laplace_unfused",
        "laplace_fused",
        "normalize_unfused",
        "normalize_fused",
        "hydro_unfused",
        "hydro_fused",
    ] {
        let exe = match rt.load(name) {
            Ok(e) => e,
            Err(e) => {
                println!("  {name:<18} unavailable: {e}");
                continue;
            }
        };
        let bufs: Vec<Vec<f64>> = exe
            .meta
            .inputs
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                apps::seeded(n, 3).iter().map(|x| 0.2 + 0.5 * x).collect()
            })
            .collect();
        let refs: Vec<&[f64]> = bufs.iter().map(|b| b.as_slice()).collect();
        let secs = time_it(
            || {
                exe.run(&refs).unwrap();
            },
            2,
            0.1,
        )
        .secs;
        println!("  {name:<18} {:.3} ms/call", secs * 1e3);
        csv.push(format!("{name},{:.4}", secs * 1e3));
    }
    Ok(csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_honors_min_time_past_old_rep_cap() {
        let start = Instant::now();
        let mut acc = 0u64;
        let t = time_it(|| acc = acc.wrapping_add(1), 3, 0.02);
        std::hint::black_box(acc);
        // A trivially fast closure must keep measuring until the time
        // budget (or the generous safety cap) — not stop at the old
        // hard 1000-rep cap.
        assert!(t.reps > 1000, "rep cap resurfaced: {} reps", t.reps);
        assert!(t.reps <= MAX_REPS);
        assert!(
            start.elapsed().as_secs_f64() >= 0.02 || t.reps == MAX_REPS,
            "stopped before min_time_s with only {} reps",
            t.reps
        );
        assert!(t.secs >= 0.0);
    }

    #[test]
    fn time_it_honors_min_reps_and_always_measures_once() {
        let mut calls = 0usize;
        let t = time_it(|| calls += 1, 5, 0.0);
        assert!(t.reps >= 5);
        assert_eq!(calls, t.reps + 1, "warmup call not counted in reps");
        // Degenerate request still measures one rep (no panic).
        let t0 = time_it(|| {}, 0, 0.0);
        assert_eq!(t0.reps, 1);
    }
}
