//! Autotuner integration tests: the full `tune -> persist -> serve`
//! loop. The tuner's winner round-trips through the on-disk DB, a
//! `variant=tuned` trace job resolves to the recorded knob set (hit) or
//! the heuristic fallback (miss, never an error), and resolution stays
//! outside `PlanKey` — one tuned entry maps onto the ordinary
//! compiled-plan cache.

use hfav::apps::Variant;
use hfav::bench::tune::{tune, TuneConfig};
use hfav::coordinator::{parse_trace_line, resolve_tuned, Coordinator};
use hfav::engine::Threads;
use hfav::plan::cache::PlanCache;
use hfav::plan::tunedb::{deck_digest, ShapeClass, TunedDb, TunedEntry};
use hfav::plan::PlanSpec;
use std::sync::Arc;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hfav-tuning-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fast_cfg(extents: Vec<i64>) -> TuneConfig {
    TuneConfig {
        extents,
        budget: 2,
        engine: "exec".to_string(),
        threads: vec![1],
        min_reps: 1,
        min_time_s: 0.0,
    }
}

/// Nearby shapes bucket together; different magnitudes and aspect
/// ratios do not. This is the stability contract that lets one tuned
/// entry serve a whole family of grids.
#[test]
fn shape_classes_bucket_nearby_shapes() {
    let canon = ShapeClass::of(&[32, 32, 32]);
    assert_eq!(canon.label(), "d3/m15/square");
    assert_eq!(ShapeClass::of(&[30, 31, 33]), canon);
    assert_eq!(ShapeClass::of(&[32, 28, 36]), canon);
    assert_ne!(ShapeClass::of(&[64, 64, 64]), canon, "magnitude must split");
    assert_ne!(ShapeClass::of(&[512, 16, 4]), canon, "aspect ratio must split");
    assert_ne!(ShapeClass::of(&[181, 181]), canon, "dimensionality must split");
}

/// The tuner's entry survives a disk round-trip byte-exactly and is
/// found again under its (deck digest, shape class) key; the file
/// itself is well-formed JSON.
#[test]
fn tuned_entry_round_trips_through_the_disk_db() {
    let base = PlanSpec::app("cosmo");
    let entry = tune(&base, &fast_cfg(vec![12, 12, 4])).unwrap();
    let dir = tmp_dir("roundtrip");
    let path = dir.join("tuned_plans.json");
    let mut db = TunedDb::load(&path).unwrap();
    assert!(db.is_empty(), "missing file must load as an empty DB");
    db.insert(entry.clone());
    db.save(&path).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    hfav::json::parse(&text).expect("tuned DB must be well-formed JSON");

    let back = TunedDb::load(&path).unwrap();
    assert_eq!(back.len(), 1);
    let digest = deck_digest(&base).unwrap();
    let found = back.lookup(digest, &entry.shape_class).expect("entry lost on reload");
    assert_eq!(found, &entry, "disk round-trip changed the entry");
    assert!(back.lookup(digest, "d3/m30/rect").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance loop end-to-end: tune cosmo at the exact shape the
/// grid driver gives a size-16 trace job, persist, then serve a
/// `variant=tuned` trace line against the DB — resolution reports the
/// recorded knob set, the job's spec carries it, and the job runs.
#[test]
fn serve_of_variant_tuned_consults_the_db() {
    // size=16 cosmo serves at [16, 16, 4] (Nk plane default).
    let entry = tune(&PlanSpec::app("cosmo"), &fast_cfg(vec![16, 16, 4])).unwrap();
    let dir = tmp_dir("serve");
    let path = dir.join("db.json");
    let mut db = TunedDb::default();
    db.insert(entry.clone());
    db.save(&path).unwrap();
    let db = TunedDb::load(&path).unwrap();

    let mut job = parse_trace_line(0, "cosmo, tuned, exec, 16, 1").unwrap();
    assert!(job.tuned_request);
    let fallback_fp = job.spec.fingerprint();
    let plans = Arc::new(PlanCache::new());
    let label = resolve_tuned(&mut job, &db, &plans)
        .unwrap()
        .expect("entry tuned at the serve shape must hit");
    assert!(label.contains(&format!("vlen={}", entry.vlen)), "{label}");
    assert!(label.contains(&entry.shape_class), "{label}");
    assert_eq!(job.spec.vlen_override(), Some(entry.vlen));
    assert_eq!(job.spec.is_tuned(), entry.tuned);
    if entry.threads > 1 {
        assert!(matches!(job.threads, Threads::Fixed(t) if t == entry.threads));
    }

    let c = Coordinator::start_with_cache(1, None, plans);
    let r = c.submit(job).recv().unwrap();
    assert!(r.ok, "resolved tuned job failed: {}", r.detail);
    assert!(r.checksum.is_finite());
    c.shutdown();
    let _ = fallback_fp; // may legitimately equal the winner's knobs
    let _ = std::fs::remove_dir_all(&dir);
}

/// A tuned request with no matching DB entry is *not* an error: the job
/// keeps the heuristic `hfav+tuned` fallback the trace parser installed
/// and serves normally.
#[test]
fn tuned_miss_falls_back_to_heuristic_and_serves() {
    let mut job = parse_trace_line(3, "cosmo, tuned, exec, 24, 1").unwrap();
    assert!(job.tuned_request);
    assert_eq!(job.spec.variant_kind(), Variant::Hfav);
    assert!(job.spec.is_tuned(), "fallback must be the +tuned heuristic");
    let before = job.spec.fingerprint();

    let plans = Arc::new(PlanCache::new());
    let empty = TunedDb::default();
    assert_eq!(resolve_tuned(&mut job, &empty, &plans).unwrap(), None);
    assert_eq!(job.spec.fingerprint(), before, "a miss must not touch the spec");

    // A populated DB whose only entry covers a *different* shape class
    // also misses — lookup is class-exact.
    let mut other = TunedDb::default();
    other.insert(TunedEntry {
        deck_digest: deck_digest(&job.spec).unwrap(),
        target: "cosmo".to_string(),
        shape_class: ShapeClass::of(&[512, 512, 512]).label(),
        extents: "512x512x512".to_string(),
        tuned: false,
        vec_dim: "inner".to_string(),
        vlen: 4,
        aligned: false,
        tiled: false,
        time_tile: 1,
        threads: 1,
        mcells_per_s: 1.0,
        candidates: 1,
        timed: 1,
        reps: 1,
        predicted_rank: None,
    });
    assert_eq!(resolve_tuned(&mut job, &other, &plans).unwrap(), None);

    let c = Coordinator::start_with_cache(1, None, plans);
    let r = c.submit(job).recv().unwrap();
    assert!(r.ok, "fallback job failed: {}", r.detail);
    c.shutdown();
}
