//! Property-based tests over randomly generated stencil programs.
//!
//! The central invariant of the whole system: for ANY valid deck, the
//! fully fused + contracted + pipelined schedule computes exactly what
//! the unfused, fully materialized schedule computes — in both execution
//! modes, and through the compiled-C backend.
//!
//! (No proptest crate in the offline environment: a small deterministic
//! xorshift generator drives the cases; failures print the generated deck
//! for replay.)

use hfav::apps::{max_err, Variant};
use hfav::exec::{self, registry::Registry, ExecOptions, Mode};
use hfav::plan::{PlanSpec, Program};
use std::collections::BTreeMap;

fn compile_variant(deck: &str, v: Variant) -> Result<Program, String> {
    PlanSpec::deck_src(deck).variant(v).compile()
}

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15).max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    fn offset(&mut self, max_abs: i64) -> i64 {
        (self.below((2 * max_abs + 1) as u64) as i64) - max_abs
    }
    fn f64s(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| (self.next() >> 11) as f64 / (1u64 << 53) as f64).collect()
    }
}

fn off_str(var: &str, off: i64) -> String {
    match off.cmp(&0) {
        std::cmp::Ordering::Equal => format!("{var}?"),
        std::cmp::Ordering::Greater => format!("{var}?+{off}"),
        std::cmp::Ordering::Less => format!("{var}?{off}"),
    }
}

/// Generate a random chain-of-stencils deck over `ndims` dims with
/// `nstages` kernels, each reading the previous stage at 1–3 random
/// offsets. Returns (deck text, per-stage offsets) and registers matching
/// kernels (weighted sums, deterministic from the structure).
fn gen_chain_deck(rng: &mut Rng, ndims: usize, nstages: usize) -> (String, Registry) {
    let dims: Vec<&str> = match ndims {
        1 => vec!["i"],
        _ => vec!["j", "i"],
    };
    let mut deck = String::new();
    deck.push_str("name: prop\niteration:\n  order: [");
    deck.push_str(&dims.join(", "));
    deck.push_str("]\n  domains:\n");
    for d in &dims {
        // interior domain with room for offsets
        deck.push_str(&format!("    {d}: [3, N{d}-3]\n"));
    }
    deck.push_str("kernels:\n");
    let mut reg = Registry::new();
    for s in 0..nstages {
        let prev = if s == 0 { "u".to_string() } else { format!("t{}", s - 1) };
        let prev_term = if s == 0 {
            |subs: &str| format!("u[{subs}")
        } else {
            |subs: &str| format!("{subs}")
        };
        let _ = prev_term;
        let nreads = 1 + rng.below(3) as usize;
        let mut inputs = String::new();
        let mut offsets: Vec<Vec<i64>> = Vec::new();
        for r in 0..nreads {
            let offs: Vec<i64> = dims.iter().map(|_| rng.offset(1)).collect();
            let subs: Vec<String> =
                dims.iter().zip(&offs).map(|(d, o)| format!("[{}]", off_str(d, *o))).collect();
            let term = if s == 0 {
                format!("u?{}", subs.join(""))
            } else {
                format!("t{}(u{})", s - 1, subs.join(""))
            };
            inputs.push_str(&format!("      x{r} : {term}\n"));
            offsets.push(offs);
        }
        let _ = prev;
        let params: Vec<String> = (0..nreads).map(|r| format!("double x{r}")).collect();
        let out_subs: Vec<String> = dims.iter().map(|d| format!("[{d}?]")).collect();
        let out_base = if s == 0 { "u?" } else { "u" };
        deck.push_str(&format!(
            "  k{s}:\n    declaration: k{s}({}, double &y);\n    inputs: |\n{inputs}    outputs: |\n      y : t{s}({out_base}{})\n",
            params.join(", "),
            out_subs.join(""),
        ));
        // body: y = 1 + sum (r+1)*x_r  (also usable by the C backend)
        let body: Vec<String> =
            (0..nreads).map(|r| format!("{}.0*x{r}", r + 1)).collect();
        deck.push_str(&format!("    body: \"y = 1.0 + {};\"\n", body.join(" + ")));
        let n = nreads;
        reg.register(&format!("k{s}"), move |i: &[f64], o: &mut [f64]| {
            let mut acc = 1.0;
            for r in 0..n {
                acc += (r + 1) as f64 * i[r];
            }
            o[0] = acc;
        });
    }
    deck.push_str("globals:\n  inputs: |\n    double g_u");
    for d in &dims {
        deck.push_str(&format!("[{d}?]"));
    }
    deck.push_str(" => u");
    for d in &dims {
        deck.push_str(&format!("[{d}?]"));
    }
    deck.push_str("\n  outputs: |\n    ");
    deck.push_str(&format!("t{}(u", nstages - 1));
    for d in &dims {
        deck.push_str(&format!("[{d}]"));
    }
    deck.push_str(") => double g_o");
    for d in &dims {
        deck.push_str(&format!("[{d}]"));
    }
    deck.push('\n');
    (deck, reg)
}

fn extents_for(ndims: usize, n: i64) -> BTreeMap<String, i64> {
    let mut m = BTreeMap::new();
    m.insert("Ni".to_string(), n);
    if ndims > 1 {
        m.insert("Nj".to_string(), n - 3);
    }
    m
}

/// The invariant, for one generated deck.
fn check_deck(seed: u64, ndims: usize, nstages: usize) {
    let mut rng = Rng::new(seed);
    let (deck, reg) = gen_chain_deck(&mut rng, ndims, nstages);
    let fused = match compile_variant(&deck, Variant::Hfav) {
        Ok(p) => p,
        Err(e) => panic!("seed {seed}: compile failed: {e}\n--- deck ---\n{deck}"),
    };
    let naive = compile_variant(&deck, Variant::Autovec).unwrap();
    let ext = extents_for(ndims, 24);
    let mut inputs = BTreeMap::new();
    for (name, _, _) in fused.external_inputs() {
        let len = exec::external_len(&fused, &name, &ext).unwrap();
        inputs.insert(name, rng.f64s(len));
    }
    let base = exec::run(
        &naive,
        &reg,
        &ext,
        &inputs,
        ExecOptions { mode: Mode::Peeled, threads: 1 },
    )
    .unwrap_or_else(|e| panic!("seed {seed}: naive run failed: {e}\n{deck}"));
    for mode in [Mode::Peeled, Mode::Guarded] {
        let got = exec::run(&fused, &reg, &ext, &inputs, ExecOptions { mode, threads: 1 })
            .unwrap_or_else(|e| panic!("seed {seed} {mode:?}: fused run failed: {e}\n{deck}"));
        for (k, v) in &base {
            let err = max_err(v, &got[k]);
            assert!(
                err < 1e-12,
                "seed {seed} {mode:?}: fused != naive ({err:.2e})\n--- deck ---\n{deck}\nschedule:\n{}",
                fused.schedule_text()
            );
        }
    }
}

#[test]
fn prop_fused_equals_naive_1d() {
    for seed in 0..60 {
        check_deck(seed, 1, 1 + (seed % 4) as usize);
    }
}

#[test]
fn prop_fused_equals_naive_2d() {
    for seed in 100..140 {
        check_deck(seed, 2, 1 + (seed % 3) as usize);
    }
}

#[test]
fn prop_native_c_matches_executor() {
    // Smaller count: each case invokes the system C compiler.
    for seed in 300..308 {
        let mut rng = Rng::new(seed);
        let ndims = 1 + (seed % 2) as usize;
        let (deck, reg) = gen_chain_deck(&mut rng, ndims, 2 + (seed % 2) as usize);
        let prog = compile_variant(&deck, Variant::Hfav).unwrap();
        let ext = extents_for(ndims, 20);
        let mut inputs = BTreeMap::new();
        for (name, _, _) in prog.external_inputs() {
            let len = exec::external_len(&prog, &name, &ext).unwrap();
            inputs.insert(name, rng.f64s(len));
        }
        let want = exec::run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let module = hfav::codegen::native::build(&prog, &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: cc failed: {e}"));
        let mut arrays = inputs.clone();
        for name in &module.externals {
            if !arrays.contains_key(name) {
                let len = exec::external_len(&prog, name, &ext).unwrap();
                arrays.insert(name.clone(), vec![0.0; len]);
            }
        }
        module.run(&ext, &mut arrays).unwrap();
        for (name, w) in &want {
            let err = max_err(w, &arrays[name]);
            assert!(err < 1e-12, "seed {seed}: C backend diverged ({err:.2e})\n{deck}");
        }
    }
}

#[test]
fn prop_vector_expansion_preserves_semantics() {
    // Vector-expanded rolling buffers (Fig. 9c) must not change results.
    for seed in 400..412 {
        let mut rng = Rng::new(seed);
        let (deck, reg) = gen_chain_deck(&mut rng, 1, 3);
        let deck_vec = format!("{deck}vector_len: 8\n");
        let a = compile_variant(&deck, Variant::Hfav).unwrap();
        let b = compile_variant(&deck_vec, Variant::Hfav).unwrap();
        let ext = extents_for(1, 32);
        let mut inputs = BTreeMap::new();
        for (name, _, _) in a.external_inputs() {
            let len = exec::external_len(&a, &name, &ext).unwrap();
            inputs.insert(name, rng.f64s(len));
        }
        let ra = exec::run(&a, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let rb = exec::run(&b, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        for (k, v) in &ra {
            assert!(max_err(v, &rb[k]) < 1e-14, "seed {seed}: vector expansion changed results");
        }
    }
}

#[test]
fn prop_outer_auto_and_aligned_preserve_semantics() {
    // For random chain decks: `vec_dim auto` (which resolves to an outer
    // lane dim exactly when one is k-independent) and the aligned
    // specialization must both reproduce the scalar compile within
    // 1e-12. Failures print the resolved strategy and the deck.
    use hfav::analysis::VecDim;
    use hfav::plan::Vlen;
    for seed in 800..824 {
        let mut rng = Rng::new(seed);
        let ndims = 1 + (seed % 2) as usize;
        let (deck, reg) = gen_chain_deck(&mut rng, ndims, 2 + (seed % 3) as usize);
        let scalar = compile_variant(&deck, Variant::Hfav).unwrap();
        let auto = PlanSpec::deck_src(deck.as_str())
            .vlen(Vlen::Fixed(4))
            .vec_dim(VecDim::Auto)
            .compile()
            .unwrap_or_else(|e| panic!("seed {seed}: auto compile failed: {e}\n{deck}"));
        let aligned = PlanSpec::deck_src(deck.as_str())
            .vlen(Vlen::Fixed(4))
            .aligned(true)
            .compile()
            .unwrap_or_else(|e| panic!("seed {seed}: aligned compile failed: {e}\n{deck}"));
        let ext = extents_for(ndims, 26);
        let mut inputs = BTreeMap::new();
        for (name, _, _) in scalar.external_inputs() {
            let len = exec::external_len(&scalar, &name, &ext).unwrap();
            inputs.insert(name, rng.f64s(len));
        }
        let base = exec::run(&scalar, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        for (label, prog) in [("auto", &auto), ("aligned", &aligned)] {
            let got = exec::run(prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
            for (k, v) in &base {
                let err = max_err(v, &got[k]);
                assert!(
                    err < 1e-12,
                    "seed {seed} {label} (resolved {:?}): diverged ({err:.2e})\n{deck}",
                    prog.vec_dim()
                );
            }
        }
    }
}

/// The interpreter's schedule walk must visit kernel invocations in the
/// exact order the emitted code executes — for every app × strategy in
/// {scalar, inner, outer, aligned, tiled}. The emitted order is given by
/// the reference walker over the lowered tree
/// ([`hfav::schedule::Schedule::visit`], the structure both emitters
/// print verbatim); the executor side is the instrumented trace of
/// [`hfav::exec::run_traced`]. The two walkers are independent
/// implementations, so agreement pins the node semantics.
#[test]
fn prop_exec_trace_matches_schedule_walk() {
    use hfav::analysis::VecDim;
    use hfav::plan::Vlen;
    let apps: [(&str, &str, &str, hfav::exec::registry::Registry); 3] = [
        ("laplace", hfav::apps::laplace::DECK, "j", hfav::apps::laplace::registry()),
        (
            "normalize",
            hfav::apps::normalization::DECK,
            "j",
            hfav::apps::normalization::registry(),
        ),
        ("cosmo", hfav::apps::cosmo::DECK, "k", hfav::apps::cosmo::registry()),
    ];
    for (app, deck, outer, reg) in apps {
        let strategies: Vec<(&str, PlanSpec)> = vec![
            ("scalar", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(1))),
            ("inner", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(4))),
            (
                "outer",
                PlanSpec::deck_src(deck)
                    .vlen(Vlen::Fixed(4))
                    .vec_dim(VecDim::Outer(outer.to_string())),
            ),
            ("aligned", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(4)).aligned(true)),
            ("tiled", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(4)).tiled(true)),
        ];
        for (label, spec) in strategies {
            let prog = spec.compile().unwrap_or_else(|e| panic!("{app} {label}: {e}"));
            // Non-square extents so strips, remainders and (aligned)
            // heads are all exercised.
            let mut ext = BTreeMap::new();
            for (k, name) in
                hfav::codegen::c99::extent_names(&prog).into_iter().enumerate()
            {
                ext.insert(name, [13i64, 9, 7][k % 3]);
            }
            let mut inputs = BTreeMap::new();
            for (name, _, _) in prog.external_inputs() {
                let len = exec::external_len(&prog, &name, &ext).unwrap();
                inputs.insert(name, Rng::new(77).f64s(len));
            }
            let (_, got) = hfav::exec::run_traced(&prog, &reg, &ext, &inputs)
                .unwrap_or_else(|e| panic!("{app} {label}: {e}"));
            let mut want: Vec<(String, Vec<i64>)> = Vec::new();
            prog.sched
                .visit(&ext, &mut |np, mi, idx| {
                    let nest = &prog.fd.nests[prog.sched.nests[np].nest];
                    let cs = nest.members[mi].callsite;
                    want.push((prog.df.callsites[cs].name.clone(), idx.to_vec()));
                })
                .unwrap();
            assert_eq!(
                got.len(),
                want.len(),
                "{app} {label}: invocation counts diverge ({} vs {})",
                got.len(),
                want.len()
            );
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g, w,
                    "{app} {label}: invocation {k} diverges (exec {g:?} vs schedule {w:?})"
                );
            }
        }
    }
}

/// Chunked execution is an interleaving, never a reordering: project the
/// threaded trace of [`hfav::exec::run_traced_with`] onto any one chunk
/// of the parallel partition and it must replay that chunk's subsequence
/// of [`hfav::schedule::Schedule::visit_threads`] *exactly* — same
/// invocations, same order. Chunk identity is recomputed independently
/// here from [`hfav::schedule::chunk_spans`] over the lowered tree's
/// `Parallel` node, so the partition itself is pinned too (an executor
/// that split the iteration space differently would fail even if every
/// per-chunk order were internally consistent).
#[test]
fn prop_threaded_trace_partitions_schedule_walk() {
    use hfav::plan::Vlen;
    use hfav::schedule::{chunk_spans, Node};
    let apps: [(&str, &str, hfav::exec::registry::Registry); 2] = [
        ("laplace", hfav::apps::laplace::DECK, hfav::apps::laplace::registry()),
        ("cosmo", hfav::apps::cosmo::DECK, hfav::apps::cosmo::registry()),
    ];
    for (app, deck, reg) in apps {
        let strategies: Vec<(&str, PlanSpec)> = vec![
            ("scalar", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(1))),
            ("tiled", PlanSpec::deck_src(deck).vlen(Vlen::Fixed(4)).tiled(true)),
        ];
        for (label, spec) in strategies {
            let prog = spec.compile().unwrap_or_else(|e| panic!("{app} {label}: {e}"));
            let mut ext = BTreeMap::new();
            for (k, name) in
                hfav::codegen::c99::extent_names(&prog).into_iter().enumerate()
            {
                ext.insert(name, [14i64, 10, 6][k % 3]);
            }
            let mut inputs = BTreeMap::new();
            for (name, _, _) in prog.external_inputs() {
                let len = exec::external_len(&prog, &name, &ext).unwrap();
                inputs.insert(name, Rng::new(99).f64s(len));
            }
            // Every callsite name belongs to exactly one nest plan here,
            // so the trace side can recover `np` from the kernel name.
            let mut np_of: BTreeMap<String, usize> = BTreeMap::new();
            for (np, plan) in prog.sched.nests.iter().enumerate() {
                for m in &prog.fd.nests[plan.nest].members {
                    np_of.insert(prog.df.callsites[m.callsite].name.clone(), np);
                }
            }
            for threads in [2usize, 3] {
                let chunk_of = |np: usize, idx: &[i64]| -> usize {
                    let plan = &prog.sched.nests[np];
                    for n in &plan.body {
                        if let Node::Parallel(p) = n {
                            let lvl =
                                plan.dims.iter().position(|d| *d == p.dim).unwrap();
                            let lo = p.lo.eval(&ext).unwrap();
                            let hi = p.hi.eval(&ext).unwrap();
                            return chunk_spans(lo, hi, p.unit, threads)
                                .iter()
                                .position(|&(a, b)| a <= idx[lvl] && idx[lvl] < b)
                                .unwrap();
                        }
                    }
                    0 // no parallel level: everything is one chunk
                };
                let mut want: Vec<Vec<(String, Vec<i64>)>> = vec![Vec::new(); threads];
                prog.sched
                    .visit_threads(&ext, threads, &mut |np, mi, idx| {
                        let nest = &prog.fd.nests[prog.sched.nests[np].nest];
                        let cs = nest.members[mi].callsite;
                        want[chunk_of(np, idx)]
                            .push((prog.df.callsites[cs].name.clone(), idx.to_vec()));
                    })
                    .unwrap();
                if app == "cosmo" {
                    assert!(
                        want[1..].iter().any(|c| !c.is_empty()),
                        "{app} {label} t{threads}: partition degenerated to one chunk"
                    );
                }
                let (_, trace) =
                    hfav::exec::run_traced_with(&prog, &reg, &ext, &inputs, threads)
                        .unwrap_or_else(|e| panic!("{app} {label} t{threads}: {e}"));
                let mut got: Vec<Vec<(String, Vec<i64>)>> = vec![Vec::new(); threads];
                for (name, idx) in trace {
                    let np = np_of[&name];
                    got[chunk_of(np, &idx)].push((name, idx));
                }
                for (c, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g, w,
                        "{app} {label} t{threads}: chunk {c} subsequence diverges \
                         from the schedule walk"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_vector_expanded_windows_are_pow2_and_cover_lanes() {
    // For random chain decks × slack × vlen: every rolling window's alloc
    // is a power of two at least the logical window, and vector-expanded
    // innermost windows leave room for a full strip of lanes.
    use hfav::analysis::{AnalysisOptions, DimSize};
    use hfav::plan::{compile_src, CompileOptions};
    for seed in 700..740 {
        let mut rng = Rng::new(seed);
        let (deck, reg) = gen_chain_deck(&mut rng, 1, 1 + (seed % 3) as usize);
        let vl = [1usize, 2, 4, 8][(seed % 4) as usize];
        let slack = (seed % 3) as i64;
        let opts = CompileOptions {
            analysis: AnalysisOptions {
                vector_len: Some(vl),
                rotation_slack: slack,
                ..Default::default()
            },
            ..Default::default()
        };
        let prog = compile_src(&deck, opts).unwrap();
        for s in &prog.sp.storages {
            for sz in &s.sizes {
                if let DimSize::Window { w, alloc } = sz {
                    assert!(*alloc >= *w, "seed {seed}: alloc {alloc} < logical {w}\n{deck}");
                    assert!(
                        (*alloc as u64).is_power_of_two(),
                        "seed {seed}: alloc {alloc} not pow2\n{deck}"
                    );
                    if vl > 1 {
                        assert!(
                            *w >= vl as i64,
                            "seed {seed}: window {w} lacks lane room (vl {vl})\n{deck}"
                        );
                    }
                }
            }
        }
        // The expanded plan still computes the scalar answer (strips are
        // the default execution order for vector plans).
        let scalar = compile_variant(&deck, Variant::Hfav).unwrap();
        let ext = extents_for(1, 30);
        let mut inputs = BTreeMap::new();
        for (name, _, _) in scalar.external_inputs() {
            let len = exec::external_len(&scalar, &name, &ext).unwrap();
            inputs.insert(name, rng.f64s(len));
        }
        let a = exec::run(&scalar, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let b = exec::run(&prog, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        for (k, v) in &a {
            assert!(
                max_err(v, &b[k]) < 1e-14,
                "seed {seed} vl {vl}: vector expansion changed results\n{deck}"
            );
        }
    }
}

#[test]
fn prop_rotation_strips_never_read_stale_slots() {
    // Pure model of the emitted strip schedule: a producer writing
    // position t+head into slot (t+head) & mask, lane-fissioned by `vl`,
    // with consumers reading offsets within the reuse window. Under the
    // vector-expanded allocation (alloc ≥ w + vl − 1, pow2) no slot is
    // ever overwritten before its last reader — i.e. rotation never reads
    // a slot before it was written with the expected position.
    for seed in 600..680u64 {
        let mut rng = Rng::new(seed);
        let w = 1 + rng.below(6) as i64;
        let slack = rng.below(3) as i64;
        let vl = [1i64, 2, 4, 8, 16][rng.below(5) as usize];
        let head = rng.offset(2);
        // Mirrors analysis::contract_sizes.
        let logical = if w <= 1 {
            if vl > 1 {
                vl
            } else {
                1
            }
        } else {
            w + slack + vl - 1
        };
        if logical <= 1 {
            continue;
        }
        let alloc = (logical as u64).next_power_of_two() as i64;
        let mask = alloc - 1;
        let oldest = head - w + 1;
        let nreads = 1 + rng.below(3);
        let offsets: Vec<i64> =
            (0..nreads).map(|_| oldest + rng.below(w as u64) as i64).collect();
        let n = 48i64;
        let mut mem = vec![i64::MIN; alloc as usize];
        let mut t = 0i64;
        while t < n {
            let e = (t + vl).min(n);
            for l in t..e {
                let p = l + head;
                mem[(p & mask) as usize] = p;
            }
            for l in t..e {
                for &o in &offsets {
                    let q = l + o;
                    if q < head {
                        continue; // prologue positions never produced
                    }
                    assert_eq!(
                        mem[(q & mask) as usize],
                        q,
                        "seed {seed} w={w} slack={slack} vl={vl} head={head} o={o}: \
                         slot clobbered (or unwritten) before read"
                    );
                }
            }
            t = e;
        }
    }
}

#[test]
fn rotation_without_expansion_clobbers() {
    // Negative control: a window-3 buffer (alloc 4) driven by an 8-lane
    // strip overwrites slots the consumer still needs — the failure mode
    // the vector-expanded allocation exists to prevent.
    let (vl, head) = (8i64, 1i64);
    let alloc = 4i64;
    let mask = alloc - 1;
    let mut clobbered = false;
    let mut mem = vec![i64::MIN; alloc as usize];
    let mut t = 0i64;
    while t < 32 {
        let e = (t + vl).min(32);
        for l in t..e {
            let p = l + head;
            mem[(p & mask) as usize] = p;
        }
        for l in t..e {
            let q = l - 1; // oldest read of the window-3 pattern
            if q >= head && mem[(q & mask) as usize] != q {
                clobbered = true;
            }
        }
        t = e;
    }
    assert!(clobbered, "expected clobber without vector-expanded allocation");
}

#[test]
fn prop_rolled_inputs_preserve_semantics() {
    // Rolling terminal inputs through buffers (in/out chaining machinery)
    // must not change results.
    use hfav::plan::{compile_src, CompileOptions};
    for seed in 500..512 {
        let mut rng = Rng::new(seed);
        let ndims = 1 + (seed % 2) as usize;
        let (deck, reg) = gen_chain_deck(&mut rng, ndims, 2);
        let plain = compile_variant(&deck, Variant::Hfav).unwrap();
        let rolled = compile_src(
            &deck,
            CompileOptions { roll_all_inputs: true, ..Default::default() },
        )
        .unwrap();
        let ext = extents_for(ndims, 22);
        let mut inputs = BTreeMap::new();
        for (name, _, _) in plain.external_inputs() {
            let len = exec::external_len(&plain, &name, &ext).unwrap();
            inputs.insert(name, rng.f64s(len));
        }
        let ra = exec::run(&plain, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        let rb = exec::run(&rolled, &reg, &ext, &inputs, ExecOptions::default()).unwrap();
        for (k, v) in &ra {
            assert!(max_err(v, &rb[k]) < 1e-14, "seed {seed}: input rolling changed results");
        }
    }
}

#[test]
fn yaml_parser_never_panics_on_mutations() {
    // Fuzz-ish robustness: random line mutations of a valid deck must
    // produce Ok or Err, never a panic.
    let base = hfav::apps::laplace::DECK;
    let mut rng = Rng::new(7777);
    for _ in 0..300 {
        let lines: Vec<&str> = base.lines().collect();
        let mut mutated: Vec<String> = lines.iter().map(|s| s.to_string()).collect();
        match rng.below(4) {
            0 => {
                let k = rng.below(mutated.len() as u64) as usize;
                mutated.remove(k);
            }
            1 => {
                let k = rng.below(mutated.len() as u64) as usize;
                mutated[k] = format!("  {}", mutated[k]);
            }
            2 => {
                let k = rng.below(mutated.len() as u64) as usize;
                let len = mutated[k].len();
                mutated[k].insert(len / 2, ':');
            }
            _ => {
                let k = rng.below(mutated.len() as u64) as usize;
                mutated[k] = mutated[k].replace('?', "");
            }
        }
        let src = mutated.join("\n");
        let _ = hfav::plan::compile_src(&src, Default::default());
    }
}
