//! Serving-layer integration tests: the shared compiled-plan cache under
//! real traces — compile-count == distinct keys, correctness under
//! concurrent `run_batch` callers, distinct options → distinct entries,
//! and deterministic results regardless of batching/scheduling.

use hfav::apps::Variant;
use hfav::coordinator::{distinct_plan_keys, parse_trace_line, repeat_jobs, Coordinator, Job};
use hfav::plan::cache::PlanCache;
use hfav::plan::PlanSpec;
use std::sync::Arc;

fn job(id: u64, app: &str, variant: Variant, backend: &str, size: usize, steps: usize) -> Job {
    Job::new(id, PlanSpec::app(app).variant(variant), backend, size, steps)
}

/// N jobs over K distinct (app, variant, options) keys → exactly K
/// pipeline compilations, asserted via the plan-cache metrics counter.
#[test]
fn repeated_trace_compiles_once_per_distinct_key() {
    let trace = "\
laplace, hfav, exec, 48, 1
laplace, autovec, exec, 48, 1
normalize, hfav, exec, 32, 1
cosmo, hfav, exec, 16, 1
hydro2d, hfav, exec, 12, 1
";
    let template: Vec<Job> = trace
        .lines()
        .enumerate()
        .map(|(i, l)| parse_trace_line(i as u64, l).unwrap())
        .collect();
    let jobs = repeat_jobs(&template, 6);
    let n = jobs.len();
    assert_eq!(n, 30);
    let distinct = distinct_plan_keys(&jobs);
    assert_eq!(distinct, 5);

    let c = Coordinator::start(4, None);
    let results = c.run_batch(jobs);
    assert_eq!(results.len(), n);
    for r in &results {
        assert!(r.ok, "job {} failed: {}", r.id, r.detail);
        assert!(r.checksum.is_finite());
    }
    let stats = c.plans.stats();
    assert_eq!(
        stats.computes,
        distinct as u64,
        "expected exactly one compile per distinct key: {stats}"
    );
    assert!(stats.hits > 0, "repeats must hit the cache: {stats}");
    let report = c.report(std::time::Duration::from_millis(1));
    assert_eq!(report.completed, n as u64);
    assert_eq!(report.plans.computes, 5);
    c.shutdown();
}

/// Per-job vector lengths in a trace: each distinct vlen is its own plan
/// key (compiled once), and vectorized plans produce identical results.
#[test]
fn vlen_trace_jobs_compile_per_vlen() {
    let trace = "\
laplace, hfav, exec, 32, 1
laplace, hfav, exec, 32, 1, 1
laplace, hfav, exec, 32, 1, 4
laplace, hfav, exec, 32, 1, 8
";
    // Same id everywhere → same seeded inputs → comparable checksums.
    let jobs: Vec<Job> = trace
        .lines()
        .map(|l| parse_trace_line(0, l).unwrap())
        .collect();
    assert_eq!(distinct_plan_keys(&jobs), 4);
    let c = Coordinator::start(2, None);
    let results = c.run_batch(jobs);
    for r in &results {
        assert!(r.ok, "{}", r.detail);
        assert_eq!(r.checksum, results[0].checksum, "vlen changed results");
    }
    assert_eq!(c.plans.stats().computes, 4, "{}", c.plans.stats());
    c.shutdown();
}

/// Concurrent `run_batch` callers on one coordinator: results stay
/// correct and per-key compilation still happens exactly once.
#[test]
fn concurrent_run_batch_shares_one_cache() {
    let c = Arc::new(Coordinator::start(4, None));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let jobs: Vec<Job> = (0..6)
                .map(|i| {
                    let (app, size) = if i % 2 == 0 { ("laplace", 40) } else { ("normalize", 24) };
                    job(t * 100 + i, app, Variant::Hfav, "exec", size, 1)
                })
                .collect();
            c.run_batch(jobs)
        }));
    }
    let mut checksums: std::collections::BTreeMap<u64, f64> = std::collections::BTreeMap::new();
    for h in handles {
        for r in h.join().unwrap() {
            assert!(r.ok, "job {}: {}", r.id, r.detail);
            checksums.insert(r.id, r.checksum);
        }
    }
    assert_eq!(checksums.len(), 24);
    for v in checksums.values() {
        assert!(v.is_finite());
    }
    let stats = c.plans.stats();
    assert_eq!(stats.computes, 2, "laplace/hfav + normalize/hfav only: {stats}");
    Arc::try_unwrap(c).ok().expect("all clones joined").shutdown();
}

/// Differing spec fingerprints produce distinct cache entries — the
/// autovec and hfav shapes never collide.
#[test]
fn differing_options_get_distinct_entries() {
    let cache = PlanCache::new();
    let fused = PlanSpec::app("laplace").variant(Variant::Hfav);
    let unfused = PlanSpec::app("laplace").variant(Variant::Autovec);
    assert_ne!(fused.fingerprint(), unfused.fingerprint());

    let a = cache.compile_spec(&fused).unwrap();
    let b = cache.compile_spec(&unfused).unwrap();
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.stats().computes, 2);
    // And the cached plans really are the two different shapes.
    assert!(a.opts.fusion.enabled);
    assert!(!b.opts.fusion.enabled);
}

/// Determinism: serving the same trace twice (fresh coordinator, warm
/// cache vs cold cache) yields identical checksums — caching and batching
/// must not change results.
#[test]
fn warm_cache_results_match_cold_results() {
    let mk_jobs = || {
        vec![
            job(0, "laplace", Variant::Hfav, "exec", 32, 1),
            job(1, "normalize", Variant::Hfav, "exec", 24, 2),
            job(2, "cosmo", Variant::Autovec, "exec", 12, 1),
            job(3, "hydro2d", Variant::Hfav, "exec", 8, 2),
        ]
    };
    let cold = Coordinator::start(2, None);
    let cold_results = cold.run_batch(mk_jobs());
    let cold_compiles = cold.plans.stats().computes;
    cold.shutdown();

    let shared = Arc::new(PlanCache::new());
    let warm = Coordinator::start_with_cache(2, None, shared.clone());
    let first = warm.run_batch(mk_jobs());
    let second = warm.run_batch(mk_jobs());
    for ((a, b), c) in cold_results.iter().zip(first.iter()).zip(second.iter()) {
        assert!(a.ok && b.ok && c.ok);
        assert_eq!(a.checksum, b.checksum, "cold vs warm diverged on job {}", a.id);
        assert_eq!(b.checksum, c.checksum, "repeat diverged on job {}", b.id);
    }
    assert_eq!(shared.stats().computes, cold_compiles, "same distinct keys both times");
    warm.shutdown();
    // The externally shared cache outlives the coordinator.
    assert_eq!(shared.len() as u64, cold_compiles);
}
