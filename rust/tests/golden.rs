//! Golden snapshot tests for generated code: the C99 and Rust emissions
//! for small pipelined decks — scalar peeled loops (vlen 1), inner
//! strips with in-register rotation (vlen 4), outer-dim lane loops
//! (`rows2d` at `vec_dim outer:j`), multi-dim lane tiling (`rows2d`
//! tiled), the aligned specialization, the statically-provable
//! alignment case (`align0`, whose head peel is elided at compile time),
//! and temporal blocking (`chain1d` at `--time-tile 4` with warm-up
//! replays, cosmo at `--time-tile 2` with none) — are pinned under
//! `tests/golden/` so any emitter change shows up as a reviewable diff.
//!
//! Workflow:
//! * mismatch → the test fails and prints the path; run with
//!   `UPDATE_GOLDEN=1 cargo test --test golden` to regenerate, then
//!   review and commit the diff;
//! * missing file (fresh emitter target in a new checkout) → the file is
//!   created from the current emission and the test passes with a note —
//!   commit the generated file to pin it.

use hfav::plan::{compile_src, CompileOptions, Program};
use std::path::PathBuf;

/// A 1D two-stage pipelined chain: `dbl` runs one iteration ahead of
/// `diff`, so the emission exercises peeling, rolling windows and (at
/// vlen 4) strip-mined lane loops with window staging.
const DECK: &str = r#"
name: chain1d
iteration:
  order: [i]
  domains:
    i: [1, N-1]
kernels:
  dbl:
    declaration: dbl(double a, double &b);
    inputs: |
      a : u?[i?]
    outputs: |
      b : dbl(u?[i?])
    body: "b = 2.0*a;"
  diff:
    declaration: diff(double l, double r, double &d);
    inputs: |
      l : dbl(u?[i?-1])
      r : dbl(u?[i?+1])
    outputs: |
      d : diff(u?[i?])
    body: "d = r - l;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    diff(u[i]) => double g_d[i]
"#;

/// A 2-D variant of the chain with independent rows: `j` carries no
/// offsets, so it is a legal outer lane dim — the emission target for
/// the `outer:j` goldens (per-invocation lane loops, no window staging,
/// lane dim innermost in intermediate layouts).
const ROWS2D: &str = r#"
name: rows2d
iteration:
  order: [j, i]
  domains:
    j: [0, M]
    i: [1, N-1]
kernels:
  dbl:
    declaration: dbl(double a, double &b);
    inputs: |
      a : u?[j?][i?]
    outputs: |
      b : dbl(u?[j?][i?])
    body: "b = 2.0*a;"
  diff:
    declaration: diff(double l, double r, double &d);
    inputs: |
      l : dbl(u?[j?][i?-1])
      r : dbl(u?[j?][i?+1])
    outputs: |
      d : diff(u?[j?][i?])
    body: "d = r - l;"
globals:
  inputs: |
    double g_u[j?][i?] => u[j?][i?]
  outputs: |
    diff(u[j][i]) => double g_d[j][i]
"#;

/// A two-stage offset-0 chain over `i: [0, N]`: the single fused
/// segment starts at the constant 0, so under `--aligned` the schedule
/// lowering *proves* alignment at compile time and emits no scalar
/// alignment head — the target of the static-alignment goldens.
const ALIGN0: &str = r#"
name: align0
iteration:
  order: [i]
  domains:
    i: [0, N]
kernels:
  a:
    declaration: a(double x, double &y);
    inputs: |
      x : u?[i?]
    outputs: |
      y : mid(u?[i?])
    body: "y = 2.0*x;"
  b:
    declaration: b(double y, double &z);
    inputs: |
      y : mid(u?[i?])
    outputs: |
      z : fin(u?[i?])
    body: "z = y + 1.0;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    fin(u[i]) => double g_o[i]
"#;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn compile(vlen: usize) -> Program {
    compile_src(
        DECK,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

fn compile_aligned(vlen: usize) -> Program {
    compile_src(
        DECK,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                ..Default::default()
            },
            aligned: true,
            ..Default::default()
        },
    )
    .unwrap()
}

fn compile_outer(vlen: usize) -> Program {
    compile_src(
        ROWS2D,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                vec_dim: hfav::analysis::VecDim::Outer("j".to_string()),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

fn compile_tiled(vlen: usize) -> Program {
    compile_src(
        ROWS2D,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                vec_dim: hfav::analysis::VecDim::Outer("j".to_string()),
                tile: true,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

fn compile_align0(vlen: usize) -> Program {
    compile_src(
        ALIGN0,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                ..Default::default()
            },
            aligned: true,
            ..Default::default()
        },
    )
    .unwrap()
}

fn check(name: &str, got: &str) {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let update = std::env::var("UPDATE_GOLDEN").ok().as_deref() == Some("1");
    if update || !path.exists() {
        std::fs::write(&path, got).unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        if !update {
            eprintln!("golden: created {} — commit it to pin the emission", path.display());
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want,
        got,
        "generated code changed vs {} — review the diff and regenerate \
         with UPDATE_GOLDEN=1 if intended",
        path.display()
    );
}

#[test]
fn golden_c99_vlen1() {
    check("chain1d_vlen1.c", &hfav::codegen::c99::emit(&compile(1)).unwrap());
}

#[test]
fn golden_c99_vlen4() {
    check("chain1d_vlen4.c", &hfav::codegen::c99::emit(&compile(4)).unwrap());
}

#[test]
fn golden_rust_vlen1() {
    check("chain1d_vlen1.rs", &hfav::codegen::rs::emit(&compile(1)).unwrap());
}

#[test]
fn golden_rust_vlen4() {
    check("chain1d_vlen4.rs", &hfav::codegen::rs::emit(&compile(4)).unwrap());
}

#[test]
fn golden_c99_outer_vlen4() {
    check("rows2d_outer_vlen4.c", &hfav::codegen::c99::emit(&compile_outer(4)).unwrap());
}

#[test]
fn golden_rust_outer_vlen4() {
    check("rows2d_outer_vlen4.rs", &hfav::codegen::rs::emit(&compile_outer(4)).unwrap());
}

#[test]
fn golden_c99_aligned_vlen4() {
    check("chain1d_vlen4_aligned.c", &hfav::codegen::c99::emit(&compile_aligned(4)).unwrap());
}

#[test]
fn golden_rust_aligned_vlen4() {
    check("chain1d_vlen4_aligned.rs", &hfav::codegen::rs::emit(&compile_aligned(4)).unwrap());
}

#[test]
fn golden_c99_tiled_vlen4() {
    check("rows2d_tiled_vlen4.c", &hfav::codegen::c99::emit(&compile_tiled(4)).unwrap());
}

#[test]
fn golden_rust_tiled_vlen4() {
    check("rows2d_tiled_vlen4.rs", &hfav::codegen::rs::emit(&compile_tiled(4)).unwrap());
}

#[test]
fn golden_c99_static_aligned_vlen4() {
    check("align0_vlen4_aligned.c", &hfav::codegen::c99::emit(&compile_align0(4)).unwrap());
}

#[test]
fn golden_rust_static_aligned_vlen4() {
    check("align0_vlen4_aligned.rs", &hfav::codegen::rs::emit(&compile_align0(4)).unwrap());
}

/// Structural assertions that hold regardless of snapshot churn — the
/// properties reviewers should look for in the goldens.
#[test]
fn golden_structure() {
    let c1 = hfav::codegen::c99::emit(&compile(1)).unwrap();
    let c4 = hfav::codegen::c99::emit(&compile(4)).unwrap();
    assert!(!c1.contains("strip-mined"), "scalar emission must stay scalar");
    assert!(c4.contains("strip-mined by 4 lanes"), "{c4}");
    assert!(c4.contains("#pragma omp simd"), "{c4}");
    let r4 = hfav::codegen::rs::emit(&compile(4)).unwrap();
    assert!(r4.contains("while hfav_l < 4"), "{r4}");
}

/// Structural assertions for the outer-dim and aligned emissions.
#[test]
fn golden_structure_outer_and_aligned() {
    let co = hfav::codegen::c99::emit(&compile_outer(4)).unwrap();
    assert!(co.contains("outer-dim strip: 4 lanes along j"), "{co}");
    assert!(co.contains("#pragma omp simd"), "{co}");
    assert!(!co.contains("hfav_in_"), "outer strips need no window staging: {co}");
    assert!(!co.contains("strip-mined by"), "no inner strips under outer:j: {co}");
    let ro = hfav::codegen::rs::emit(&compile_outer(4)).unwrap();
    assert!(ro.contains("outer-dim strip: 4 lanes along j"), "{ro}");
    assert!(ro.contains("while hfav_ol < 4"), "{ro}");
    let ca = hfav::codegen::c99::emit(&compile_aligned(4)).unwrap();
    assert!(ca.contains("alignment head"), "{ca}");
    assert!(ca.contains("aligned_alloc(64"), "{ca}");
    assert!(ca.contains("__builtin_assume_aligned"), "{ca}");
    let ra = hfav::codegen::rs::emit(&compile_aligned(4)).unwrap();
    assert!(ra.contains("alignment head"), "{ra}");
}

/// Structural assertions for multi-dim lane tiling: outer strips and
/// inner strips coexist, and steady×steady invocations are vlen×vlen
/// tiles — with zero shape logic in either backend (both print the same
/// tree; the headers carry the same schedule digest).
#[test]
fn golden_structure_tiled() {
    let prog = compile_tiled(4);
    assert!(prog.tiled());
    let c = hfav::codegen::c99::emit(&prog).unwrap();
    assert!(c.contains("outer-dim strip: 4 lanes along j"), "{c}");
    assert!(c.contains("strip-mined by 4 lanes"), "{c}");
    assert!(c.contains("4x4 tile along i x j"), "{c}");
    let r = hfav::codegen::rs::emit(&prog).unwrap();
    assert!(r.contains("outer-dim strip: 4 lanes along j"), "{r}");
    assert!(r.contains("4x4 tile along i x j"), "{r}");
    let tag = format!("schedule: {:016x}", prog.schedule_digest());
    assert!(c.contains(&tag) && r.contains(&tag), "digest must match across backends");
}

/// The compile-time-provable alignment satellite: when a strip's lower
/// bound is statically a multiple of the vector length (align0's single
/// segment starts at the constant 0), the schedule lowering emits *no*
/// scalar alignment head under `--aligned` — the head node is absent
/// from the tree and from both emissions.
#[test]
fn golden_structure_static_alignment_elides_head() {
    let prog = compile_align0(4);
    // Tree-level: every strip is statically aligned, none carries a head.
    let mut strips = 0;
    for np in &prog.sched.nests {
        for node in &np.body {
            if let hfav::schedule::Node::Strip(s) = node {
                strips += 1;
                assert!(s.head.is_none(), "head must be elided: {}", prog.sched.render());
                assert!(s.static_aligned, "{}", prog.sched.render());
            }
        }
    }
    assert!(strips >= 1, "expected a strip: {}", prog.sched.render());
    // Emission-level: aligned allocations remain, head peels do not.
    let c = hfav::codegen::c99::emit(&prog).unwrap();
    assert!(c.contains("aligned_alloc(64"), "{c}");
    assert!(c.contains("alignment head elided"), "{c}");
    assert!(!c.contains("alignment head:"), "no runtime head peel:\n{c}");
    assert!(c.contains("strip-mined by 4 lanes"), "{c}");
    let r = hfav::codegen::rs::emit(&prog).unwrap();
    assert!(r.contains("alignment head elided"), "{r}");
    assert!(!r.contains("alignment head:"), "{r}");
    // Control: chain1d's steady segment starts at 1 → runtime head stays.
    let chained = hfav::codegen::c99::emit(&compile_aligned(4)).unwrap();
    assert!(chained.contains("alignment head:"), "{chained}");
}

fn compile_advect3d(vlen: usize) -> Program {
    compile_src(
        hfav::apps::advect3d::DECK,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn compile_time_tiled(deck: &str, vlen: usize, tt: usize) -> Program {
    compile_src(
        deck,
        CompileOptions {
            analysis: hfav::analysis::AnalysisOptions {
                vector_len: Some(vlen),
                time_tile: tt,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn golden_c99_time_tiled_chain1d() {
    check(
        "chain1d_vlen1_tt4.c",
        &hfav::codegen::c99::emit(&compile_time_tiled(DECK, 1, 4)).unwrap(),
    );
}

#[test]
fn golden_rust_time_tiled_cosmo() {
    check(
        "cosmo_vlen4_tt2.rs",
        &hfav::codegen::rs::emit(&compile_time_tiled(hfav::apps::cosmo::DECK, 4, 2)).unwrap(),
    );
}

/// Structural assertions for the temporal-blocking emissions: chain1d's
/// pipelined window forces per-member warm-up replays (gated on pass
/// > 0), cosmo's depth-0 members need none, and both backends print the
/// identical lowered tree (same schedule digest).
#[test]
fn golden_structure_time_tiled() {
    let chain = compile_time_tiled(DECK, 1, 4);
    assert_eq!(chain.time_tile(), 4);
    let c = hfav::codegen::c99::emit(&chain).unwrap();
    assert!(c.contains("time tile along i: 4 passes"), "{c}");
    assert!(c.contains("if (hfav_tt0_pass > 0)"), "warm-up replay gate missing:\n{c}");
    let r = hfav::codegen::rs::emit(&chain).unwrap();
    assert!(r.contains("time tile along i: 4 passes"), "{r}");
    let tag = format!("schedule: {:016x}", chain.schedule_digest());
    assert!(c.contains(&tag) && r.contains(&tag), "digest must match across backends");

    let cosmo = compile_time_tiled(hfav::apps::cosmo::DECK, 4, 2);
    assert_eq!(cosmo.time_tile(), 2);
    let rc = hfav::codegen::rs::emit(&cosmo).unwrap();
    assert!(rc.contains("time tile along k: 2 passes"), "{rc}");
    // All cosmo warm-up depths are 0, so no pass-gated replay block.
    assert!(!rc.contains("hfav_tt0_w"), "cosmo needs no warm-up syms:\n{rc}");
}

#[test]
fn golden_c99_advect3d_vlen1() {
    check("advect3d_vlen1.c", &hfav::codegen::c99::emit(&compile_advect3d(1)).unwrap());
}

#[test]
fn golden_c99_advect3d_vlen4() {
    check("advect3d_vlen4.c", &hfav::codegen::c99::emit(&compile_advect3d(4)).unwrap());
}

#[test]
fn golden_rust_advect3d_vlen4() {
    check("advect3d_vlen4.rs", &hfav::codegen::rs::emit(&compile_advect3d(4)).unwrap());
}

/// Structural assertions for the 3D advection emission: the three flux
/// stages and the update fuse into one nest, the carried `k-1`/`j-1`
/// reads force rolling windows on the outer dims, and the vlen-4
/// emission strip-mines the innermost dim like every other deck.
#[test]
fn golden_structure_advect3d() {
    let p1 = compile_advect3d(1);
    assert_eq!(p1.sched.nests.len(), 1, "advect3d must fuse into one nest");
    let c4 = hfav::codegen::c99::emit(&compile_advect3d(4)).unwrap();
    assert!(c4.contains("strip-mined by 4 lanes"), "{c4}");
    let r4 = hfav::codegen::rs::emit(&compile_advect3d(4)).unwrap();
    assert!(r4.contains("while hfav_l < 4"), "{r4}");
    let tag = format!("schedule: {:016x}", compile_advect3d(4).schedule_digest());
    assert!(c4.contains(&tag) && r4.contains(&tag), "digest must match across backends");
}
