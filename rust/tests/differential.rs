//! Differential test harness (the vectorization safety net): every app
//! deck × variant × engine × vector length must agree with the
//! hand-written scalar reference within 1e-12.
//!
//! * apps: hydro2d, cosmo, normalization, advect3d
//! * variants: Hfav (fused + contracted + pipelined), Autovec (unfused)
//! * engines: interpreter executor, generated C (cc + dlopen), generated
//!   Rust (rustc --crate-type cdylib + dlopen)
//! * vector lengths: 1 (scalar), 4, 8 — forced through the same
//!   `Option<usize>` override the coordinator's plan cache fingerprints
//! * strategies: inner strips (default), outer-dim lanes
//!   (`vec_dim outer:<dim>` on cosmo's `k` and normalization's `j`) and
//!   the aligned specialization — on non-square extents, so strips,
//!   remainders and alignment heads are all exercised
//!
//! The generated-Rust engine is skipped (with a note) when no `rustc` is
//! on PATH; under `cargo test` one always is.

use hfav::analysis::VecDim;
use hfav::apps::{self, Variant};
use hfav::codegen::native::{self, CcOptions, RustcOptions};
use hfav::exec::{self, ExecOptions};
use hfav::plan::{PlanSpec, Program, Vlen};
use std::collections::BTreeMap;

const VLENS: [usize; 3] = [1, 4, 8];
const TOL: f64 = 1e-12;

#[derive(Clone, Copy, PartialEq, Eq)]
enum Eng {
    Interp,
    NativeC,
    GenRust,
}

impl Eng {
    fn label(self) -> &'static str {
        match self {
            Eng::Interp => "interpreter",
            Eng::NativeC => "native-c",
            Eng::GenRust => "generated-rust",
        }
    }
}

fn engines() -> Vec<Eng> {
    let mut v = vec![Eng::Interp, Eng::NativeC];
    if native::rustc_available() {
        v.push(Eng::GenRust);
    } else {
        eprintln!("differential: no rustc on PATH — generated-Rust engine skipped");
    }
    v
}

fn compile(deck: &str, variant: Variant, vlen: usize) -> Program {
    PlanSpec::deck_src(deck)
        .variant(variant)
        .vlen(Vlen::Fixed(vlen))
        .compile()
        .unwrap_or_else(|e| panic!("compile {variant:?} vlen {vlen}: {e}"))
}

fn build_module(prog: &Program, eng: Eng) -> native::NativeModule {
    match eng {
        Eng::NativeC => native::build(prog, &CcOptions::default())
            .unwrap_or_else(|e| panic!("cc build failed: {e}")),
        Eng::GenRust => native::build_rust(prog, &RustcOptions::default())
            .unwrap_or_else(|e| panic!("rustc build failed: {e}")),
        Eng::Interp => unreachable!(),
    }
}

/// Run a stencil-shaped app on one engine; returns its external outputs.
fn run_stencil(
    prog: &Program,
    reg: &hfav::exec::registry::Registry,
    eng: Eng,
    ext: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
) -> BTreeMap<String, Vec<f64>> {
    run_stencil_threads(prog, reg, eng, ext, inputs, hfav::engine::Threads::Serial)
}

/// [`run_stencil`] at an explicit runtime worker count.
fn run_stencil_threads(
    prog: &Program,
    reg: &hfav::exec::registry::Registry,
    eng: Eng,
    ext: &BTreeMap<String, i64>,
    inputs: &BTreeMap<String, Vec<f64>>,
    threads: hfav::engine::Threads,
) -> BTreeMap<String, Vec<f64>> {
    match eng {
        Eng::Interp => {
            let opts = ExecOptions { threads: threads.resolve(), ..Default::default() };
            exec::run(prog, reg, ext, inputs, opts).unwrap()
        }
        _ => {
            let module = build_module(prog, eng);
            let mut arrays = inputs.clone();
            for name in &module.externals {
                if !arrays.contains_key(name) {
                    let len = exec::external_len(prog, name, ext).unwrap();
                    arrays.insert(name.clone(), vec![0.0; len]);
                }
            }
            module.run_with(ext, &mut arrays, threads).unwrap();
            let out_names: Vec<String> =
                prog.external_outputs().into_iter().map(|(n, _, _)| n).collect();
            arrays.into_iter().filter(|(k, _)| out_names.contains(k)).collect()
        }
    }
}

#[test]
fn differential_normalization() {
    let (nj, ni) = (7usize, 26usize);
    let q = apps::seeded(nj * (ni + 1), 11);
    let mut want = vec![0.0; nj * ni];
    apps::normalization::reference(&q, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_q".to_string(), q);
    let reg = apps::normalization::registry();
    let engines = engines();
    for variant in [Variant::Hfav, Variant::Autovec] {
        for vlen in VLENS {
            let prog = compile(apps::normalization::DECK, variant, vlen);
            assert_eq!(prog.vector_len(), vlen);
            for &eng in &engines {
                let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                let err = apps::max_err(&out["g_out"], &want);
                assert!(
                    err < TOL,
                    "normalize {variant:?} vlen {vlen} {}: err {err:.2e}",
                    eng.label()
                );
            }
        }
    }
}

#[test]
fn differential_cosmo() {
    let (nk, nj, ni) = (2usize, 11usize, 13usize);
    let u = apps::seeded(nk * nj * ni, 5);
    let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
    apps::cosmo::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::cosmo::registry();
    let engines = engines();
    for variant in [Variant::Hfav, Variant::Autovec] {
        for vlen in VLENS {
            let prog = compile(apps::cosmo::DECK, variant, vlen);
            for &eng in &engines {
                let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                let err = apps::max_err(&out["g_out"], &want);
                assert!(
                    err < TOL,
                    "cosmo {variant:?} vlen {vlen} {}: err {err:.2e}",
                    eng.label()
                );
            }
        }
    }
}

#[test]
fn differential_hydro2d() {
    use hfav::apps::hydro2d::solver::*;
    use hfav::apps::hydro2d::DECK;
    let (nx, ny, steps) = (32usize, 6usize, 2usize);
    // Reference trajectory: the hand-written unfused scalar sweeps.
    let mut ref_state = sod(nx, ny);
    let mut reference = RefSweeper;
    for _ in 0..steps {
        step(&mut ref_state, 1.0 / nx as f64, 0.4, &mut reference).unwrap();
    }
    let engines = engines();
    for variant in [Variant::Hfav, Variant::Autovec] {
        for vlen in VLENS {
            let prog = compile(DECK, variant, vlen);
            for &eng in &engines {
                let mut sweeper: Box<dyn Sweeper> = match eng {
                    Eng::Interp => Box::new(ExecSweeper::new(prog.clone())),
                    _ => Box::new(NativeSweeper { module: build_module(&prog, eng) }),
                };
                let mut state = sod(nx, ny);
                for _ in 0..steps {
                    step(&mut state, 1.0 / nx as f64, 0.4, sweeper.as_mut()).unwrap();
                }
                let fields: [(&[f64], &[f64], &str); 4] = [
                    (&state.rho, &ref_state.rho, "rho"),
                    (&state.rhou, &ref_state.rhou, "rhou"),
                    (&state.rhov, &ref_state.rhov, "rhov"),
                    (&state.e, &ref_state.e, "E"),
                ];
                for (got, want, name) in fields {
                    let err = apps::max_err(got, want);
                    assert!(
                        err < TOL,
                        "hydro2d {variant:?} vlen {vlen} {} field {name}: err {err:.2e}",
                        eng.label()
                    );
                }
            }
        }
    }
}

/// Outer-dimension vectorization and the aligned specialization on
/// non-square extents: every engine must match the hand-written scalar
/// reference within 1e-12. Nk=9 / Nj=11 / Ni=13 exercises outer strips
/// *and* their scalar remainders (and, aligned, the alignment heads) at
/// both vector lengths.
#[test]
fn differential_outer_dim_and_aligned_cosmo() {
    let (nk, nj, ni) = (9usize, 11usize, 13usize);
    let u = apps::seeded(nk * nj * ni, 17);
    let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
    apps::cosmo::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::cosmo::registry();
    let engines = engines();
    let specs: Vec<(&str, PlanSpec)> = vec![
        (
            "outer:k vlen4",
            PlanSpec::deck_src(apps::cosmo::DECK)
                .vlen(Vlen::Fixed(4))
                .vec_dim(VecDim::Outer("k".to_string())),
        ),
        (
            "outer:k vlen8 aligned",
            PlanSpec::deck_src(apps::cosmo::DECK)
                .vlen(Vlen::Fixed(8))
                .vec_dim(VecDim::Outer("k".to_string()))
                .aligned(true),
        ),
        (
            "auto(->outer:k) vlen4",
            PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(4)).vec_dim(VecDim::Auto),
        ),
        (
            "inner vlen4 aligned",
            PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(4)).aligned(true),
        ),
        (
            "inner vlen8 aligned",
            PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(8)).aligned(true),
        ),
        (
            "tiled:k vlen4",
            PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(4)).tiled(true),
        ),
        (
            "tiled:k vlen8 aligned",
            PlanSpec::deck_src(apps::cosmo::DECK)
                .vlen(Vlen::Fixed(8))
                .vec_dim(VecDim::Outer("k".to_string()))
                .tiled(true)
                .aligned(true),
        ),
    ];
    for (label, spec) in specs {
        let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
        for &eng in &engines {
            let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
            let err = apps::max_err(&out["g_out"], &want);
            assert!(err < TOL, "cosmo {label} {}: err {err:.2e}", eng.label());
        }
    }
}

/// Outer-dim lanes across an inner reduction: normalization's rows are
/// independent, so `outer:j` gives every lane its own accumulator slot.
/// Non-square (7 x 26), vlen 4 → strip + 3-row remainder.
#[test]
fn differential_outer_dim_normalization() {
    let (nj, ni) = (7usize, 26usize);
    let q = apps::seeded(nj * (ni + 1), 11);
    let mut want = vec![0.0; nj * ni];
    apps::normalization::reference(&q, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_q".to_string(), q);
    let reg = apps::normalization::registry();
    let engines = engines();
    for vlen in [4usize, 8] {
        for aligned in [false, true] {
            for tiled in [false, true] {
                let prog = PlanSpec::deck_src(apps::normalization::DECK)
                    .vlen(Vlen::Fixed(vlen))
                    .vec_dim(VecDim::Outer("j".to_string()))
                    .aligned(aligned)
                    .tiled(tiled)
                    .compile()
                    .unwrap();
                for &eng in &engines {
                    let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                    let err = apps::max_err(&out["g_out"], &want);
                    assert!(
                        err < TOL,
                        "normalize outer:j vlen {vlen} aligned {aligned} tiled {tiled} {}: \
                         err {err:.2e}",
                        eng.label()
                    );
                }
            }
        }
    }
}

/// Multi-dim lane tiling on hydro2d (outer lanes along the row dim `j`
/// × inner strips along the sweep dim `i`): the full eight-kernel
/// pipeline must reproduce the hand-written scalar sweeps within 1e-12
/// on a non-square tube, across every engine.
#[test]
fn differential_tiled_hydro2d() {
    use hfav::apps::hydro2d::solver::*;
    use hfav::apps::hydro2d::DECK;
    let (nx, ny, steps) = (32usize, 7usize, 2usize);
    let mut ref_state = sod(nx, ny);
    let mut reference = RefSweeper;
    for _ in 0..steps {
        step(&mut ref_state, 1.0 / nx as f64, 0.4, &mut reference).unwrap();
    }
    let engines = engines();
    for (label, spec) in [
        ("tiled", PlanSpec::deck_src(DECK).vlen(Vlen::Fixed(4)).tiled(true)),
        (
            "tiled+aligned",
            PlanSpec::deck_src(DECK).vlen(Vlen::Fixed(4)).tiled(true).aligned(true),
        ),
    ] {
        let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert!(prog.tiled(), "{label}");
        for &eng in &engines {
            let mut sweeper: Box<dyn Sweeper> = match eng {
                Eng::Interp => Box::new(ExecSweeper::new(prog.clone())),
                _ => Box::new(NativeSweeper { module: build_module(&prog, eng) }),
            };
            let mut state = sod(nx, ny);
            for _ in 0..steps {
                step(&mut state, 1.0 / nx as f64, 0.4, sweeper.as_mut()).unwrap();
            }
            let fields: [(&[f64], &[f64], &str); 4] = [
                (&state.rho, &ref_state.rho, "rho"),
                (&state.rhou, &ref_state.rhou, "rhou"),
                (&state.rhov, &ref_state.rhov, "rhov"),
                (&state.e, &ref_state.e, "E"),
            ];
            for (got, want, name) in fields {
                let err = apps::max_err(got, want);
                assert!(
                    err < TOL,
                    "hydro2d {label} {} field {name}: err {err:.2e}",
                    eng.label()
                );
            }
        }
    }
}

/// Parallel chunking is partitioning, never reassociation: at any worker
/// count every engine must reproduce its own serial output *bitwise* —
/// interpreter (persistent worker pool), native C (OpenMP chunks), and
/// generated Rust (scoped threads) — on non-square cosmo, both scalar
/// and tiled×threaded (threads over outer chunks, vlen lanes inside).
#[test]
fn differential_threads_bitwise_across_engines() {
    use hfav::engine::Threads;
    let (nk, nj, ni) = (7usize, 10usize, 13usize);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(nk * nj * ni, 31));
    let reg = apps::cosmo::registry();
    let engines = engines();
    let specs = [
        ("scalar", PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(1))),
        (
            "tiled:k vlen4",
            PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(4)).tiled(true),
        ),
    ];
    for (label, spec) in specs {
        let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
        for &eng in &engines {
            let serial = run_stencil_threads(&prog, &reg, eng, &ext, &inputs, Threads::Serial);
            for t in [Threads::Fixed(2), Threads::Fixed(3), Threads::Auto] {
                let out = run_stencil_threads(&prog, &reg, eng, &ext, &inputs, t);
                assert_eq!(
                    out["g_out"],
                    serial["g_out"],
                    "cosmo {label} {} at {t:?} diverged bitwise from serial",
                    eng.label()
                );
            }
        }
    }
}

/// Tile order is a pure reordering of independent work, so the
/// interpreter and the generated Rust engine must agree bit-for-bit on
/// cosmo under tiling (neither contracts FP).
#[test]
fn differential_tiled_interp_vs_rust_bitwise() {
    if !native::rustc_available() {
        eprintln!("differential: no rustc on PATH — tiled bitwise check skipped");
        return;
    }
    let (nk, nj, ni) = (6usize, 9usize, 11usize);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(nk * nj * ni, 29));
    let reg = apps::cosmo::registry();
    for vlen in [4usize, 8] {
        let prog = PlanSpec::deck_src(apps::cosmo::DECK)
            .vlen(Vlen::Fixed(vlen))
            .tiled(true)
            .compile()
            .unwrap();
        let a = run_stencil(&prog, &reg, Eng::Interp, &ext, &inputs);
        let b = run_stencil(&prog, &reg, Eng::GenRust, &ext, &inputs);
        assert_eq!(a["g_out"], b["g_out"], "vlen {vlen}: tiled generated Rust diverged bitwise");
    }
}

/// Outer lanes are fully independent, so the interpreter and the
/// generated Rust engine must agree bit-for-bit (no FP contraction on
/// either side) on cosmo under `outer:k`.
#[test]
fn differential_outer_interp_vs_rust_bitwise() {
    if !native::rustc_available() {
        eprintln!("differential: no rustc on PATH — outer bitwise check skipped");
        return;
    }
    let (nk, nj, ni) = (6usize, 9usize, 11usize);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(nk * nj * ni, 23));
    let reg = apps::cosmo::registry();
    for vlen in [4usize, 8] {
        let prog = PlanSpec::deck_src(apps::cosmo::DECK)
            .vlen(Vlen::Fixed(vlen))
            .vec_dim(VecDim::Outer("k".to_string()))
            .compile()
            .unwrap();
        let a = run_stencil(&prog, &reg, Eng::Interp, &ext, &inputs);
        let b = run_stencil(&prog, &reg, Eng::GenRust, &ext, &inputs);
        assert_eq!(a["g_out"], b["g_out"], "vlen {vlen}: generated Rust diverged bitwise");
    }
}

/// Strip-mining must not reassociate: the interpreter and the generated
/// Rust engine (neither contracts FP) agree bit-for-bit on laplace at
/// every vlen. (The C engine is held to the 1e-12 bound above instead —
/// `cc -O3` may fuse multiply-adds.)
#[test]
fn differential_interp_vs_rust_bitwise_on_laplace() {
    if !native::rustc_available() {
        eprintln!("differential: no rustc on PATH — bitwise check skipped");
        return;
    }
    let n = 24usize;
    let mut ext = BTreeMap::new();
    ext.insert("Nj".to_string(), n as i64);
    ext.insert("Ni".to_string(), n as i64);
    let u = apps::seeded(n * n, 3);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_cell".to_string(), u);
    let reg = apps::laplace::registry();
    for vlen in VLENS {
        let prog = compile(apps::laplace::DECK, Variant::Hfav, vlen);
        let a = run_stencil(&prog, &reg, Eng::Interp, &ext, &inputs);
        let b = run_stencil(&prog, &reg, Eng::GenRust, &ext, &inputs);
        assert_eq!(a["g_out"], b["g_out"], "vlen {vlen}: generated Rust diverged bitwise");
    }
}

/// 3D upwind advection: flux values are read at nonzero offsets along
/// ALL THREE dims — including the outermost — so every flux carries a
/// rolling window and no outer dim is legal. The full engine matrix at
/// every vector length against the hand-written reference.
#[test]
fn differential_advect3d() {
    let (nk, nj, ni) = (5usize, 9usize, 12usize);
    let u = apps::seeded(nk * nj * ni, 23);
    let mut want = vec![0.0; (nk - 1) * (nj - 1) * (ni - 1)];
    apps::advect3d::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::advect3d::registry();
    let engines = engines();
    for variant in [Variant::Hfav, Variant::Autovec] {
        for vlen in VLENS {
            let prog = compile(apps::advect3d::DECK, variant, vlen);
            for &eng in &engines {
                let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                let err = apps::max_err(&out["g_out"], &want);
                assert!(
                    err < TOL,
                    "advect3d {variant:?} vlen {vlen} {}: err {err:.2e}",
                    eng.label()
                );
            }
        }
    }
}

/// advect3d's *legal* knob corners on non-square extents: inner strips
/// with the aligned specialization, and `auto` vec-dim (which must fall
/// back to inner because the outermost-dim window disqualifies every
/// outer candidate). `outer:*`/`--tile` are compile errors here — that
/// is pinned in the app's own unit tests.
#[test]
fn differential_advect3d_knobs() {
    let (nk, nj, ni) = (6usize, 7usize, 21usize);
    let u = apps::seeded(nk * nj * ni, 41);
    let mut want = vec![0.0; (nk - 1) * (nj - 1) * (ni - 1)];
    apps::advect3d::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::advect3d::registry();
    let engines = engines();
    let specs: Vec<(&str, PlanSpec)> = vec![
        (
            "inner vlen4 aligned",
            PlanSpec::deck_src(apps::advect3d::DECK).vlen(Vlen::Fixed(4)).aligned(true),
        ),
        (
            "inner vlen8 aligned",
            PlanSpec::deck_src(apps::advect3d::DECK).vlen(Vlen::Fixed(8)).aligned(true),
        ),
        (
            "auto(->inner) vlen4",
            PlanSpec::deck_src(apps::advect3d::DECK).vlen(Vlen::Fixed(4)).vec_dim(VecDim::Auto),
        ),
    ];
    for (label, spec) in specs {
        let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
        for &eng in &engines {
            let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
            let err = apps::max_err(&out["g_out"], &want);
            assert!(err < TOL, "advect3d {label} {}: err {err:.2e}", eng.label());
        }
    }
}

/// Temporal blocking is observationally invisible: a plan compiled at
/// `--time-tile t` performs `t` cache-resident sweep passes per spatial
/// block, yet must reproduce the hand-written scalar reference at 1e-12
/// on every engine, on non-square extents, for t ∈ {2, 4} — including
/// the full tiled × threaded × time-tiled composition. cosmo is proven
/// eligible (all warm-up depths are 0), so the knob must actually lower
/// a time-tile level rather than silently falling back.
#[test]
fn differential_time_tiled_cosmo() {
    use hfav::engine::Threads;
    let (nk, nj, ni) = (9usize, 10usize, 13usize);
    let u = apps::seeded(nk * nj * ni, 43);
    let mut want = vec![0.0; nk * (nj - 4) * (ni - 4)];
    apps::cosmo::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::cosmo::registry();
    let engines = engines();
    for tt in [2usize, 4] {
        let specs: Vec<(String, PlanSpec)> = vec![
            (
                format!("tt{tt} scalar"),
                PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(1)).time_tile(tt),
            ),
            (
                format!("tt{tt} inner vlen4"),
                PlanSpec::deck_src(apps::cosmo::DECK).vlen(Vlen::Fixed(4)).time_tile(tt),
            ),
            (
                format!("tt{tt} tiled:k vlen4"),
                PlanSpec::deck_src(apps::cosmo::DECK)
                    .vlen(Vlen::Fixed(4))
                    .tiled(true)
                    .time_tile(tt),
            ),
        ];
        for (label, spec) in specs {
            let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(prog.time_tile(), tt, "{label}: the time-tile knob did not take");
            for &eng in &engines {
                let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                let err = apps::max_err(&out["g_out"], &want);
                assert!(err < TOL, "cosmo {label} {}: err {err:.2e}", eng.label());
                // Time-tiled chunking is still partitioning: threaded
                // runs reproduce the engine's own serial output bitwise.
                let serial =
                    run_stencil_threads(&prog, &reg, eng, &ext, &inputs, Threads::Serial);
                for th in [Threads::Fixed(2), Threads::Fixed(3)] {
                    let tout = run_stencil_threads(&prog, &reg, eng, &ext, &inputs, th);
                    assert_eq!(
                        tout["g_out"],
                        serial["g_out"],
                        "cosmo {label} {} at {th:?} diverged bitwise from serial",
                        eng.label()
                    );
                }
            }
        }
    }
}

/// Temporal blocking on advect3d: the deck rolls a window along the
/// *outermost* dim, so this exercises the legality gate's hardest
/// decision (tile with warm-up replays, or fall back untiled). Either
/// outcome must stay within 1e-12 of the hand-written reference on
/// every engine at non-square extents.
#[test]
fn differential_time_tiled_advect3d() {
    let (nk, nj, ni) = (6usize, 9usize, 12usize);
    let u = apps::seeded(nk * nj * ni, 47);
    let mut want = vec![0.0; (nk - 1) * (nj - 1) * (ni - 1)];
    apps::advect3d::reference(&u, nk, nj, ni, &mut want);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u);
    let reg = apps::advect3d::registry();
    let engines = engines();
    for tt in [2usize, 4] {
        for vlen in [1usize, 4] {
            let prog = PlanSpec::deck_src(apps::advect3d::DECK)
                .vlen(Vlen::Fixed(vlen))
                .time_tile(tt)
                .compile()
                .unwrap_or_else(|e| panic!("tt{tt} vlen{vlen}: {e}"));
            for &eng in &engines {
                let out = run_stencil(&prog, &reg, eng, &ext, &inputs);
                let err = apps::max_err(&out["g_out"], &want);
                assert!(
                    err < TOL,
                    "advect3d tt{tt} vlen{vlen} (effective t {}) {}: err {err:.2e}",
                    prog.time_tile(),
                    eng.label()
                );
            }
        }
    }
}

/// advect3d under runtime threading: every engine must reproduce its own
/// serial output bitwise at any worker count (chunking partitions the
/// outermost windowed dim's *chunks*, never reassociates arithmetic).
#[test]
fn differential_advect3d_threads_bitwise() {
    use hfav::engine::Threads;
    let (nk, nj, ni) = (7usize, 8usize, 13usize);
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), apps::seeded(nk * nj * ni, 37));
    let reg = apps::advect3d::registry();
    let engines = engines();
    for (label, spec) in [
        ("scalar", PlanSpec::deck_src(apps::advect3d::DECK).vlen(Vlen::Fixed(1))),
        ("inner vlen4", PlanSpec::deck_src(apps::advect3d::DECK).vlen(Vlen::Fixed(4))),
    ] {
        let prog = spec.compile().unwrap_or_else(|e| panic!("{label}: {e}"));
        for &eng in &engines {
            let serial = run_stencil_threads(&prog, &reg, eng, &ext, &inputs, Threads::Serial);
            for t in [Threads::Fixed(2), Threads::Fixed(3)] {
                let out = run_stencil_threads(&prog, &reg, eng, &ext, &inputs, t);
                assert_eq!(
                    out["g_out"],
                    serial["g_out"],
                    "advect3d {label} {} at {t:?} diverged bitwise from serial",
                    eng.label()
                );
            }
        }
    }
}
