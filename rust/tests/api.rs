//! Tests for the unified engine/plan API: backend-registry round-trips,
//! unknown-engine errors, `PlanSpec` fingerprint discipline, deck-file
//! serving through the coordinator, and the fails-closed property (a
//! `Job` cannot express a compile option its plan key does not cover).

use hfav::analysis::VecDim;
use hfav::apps::Variant;
use hfav::coordinator::{batch_key, parse_trace_line, Coordinator, Job};
use hfav::engine::{registry, Availability};
use hfav::plan::{PlanSpec, Vlen};

#[test]
fn registry_round_trip_parse_name_parse() {
    let reg = registry();
    for name in reg.names() {
        let backend = reg.get(name).unwrap();
        assert_eq!(backend.name(), name);
        // name → get → name is a fixed point.
        assert_eq!(reg.get(backend.name()).unwrap().name(), name);
    }
    assert_eq!(reg.names(), vec!["exec", "native", "rust", "pjrt"]);
}

#[test]
fn unknown_engine_error_names_the_alternatives() {
    let e = registry().get("cuda").unwrap_err();
    assert!(e.contains("unknown engine `cuda`"), "{e}");
    assert!(e.contains("exec") && e.contains("native") && e.contains("rust"), "{e}");
    assert!(e.contains("pjrt"), "{e}");
}

/// Every knob a spec can express must move the fingerprint, and equal
/// specs must agree — the fingerprint is the cache identity, so this is
/// the collision/stability contract.
#[test]
fn planspec_fingerprints_are_stable_and_distinct() {
    let base = PlanSpec::app("hydro2d");
    assert_eq!(base.fingerprint(), PlanSpec::app("hydro2d").fingerprint());
    assert_eq!(base.plan_key(), PlanSpec::app("hydro2d").plan_key());
    let variations = [
        base.clone().variant(Variant::Autovec),
        base.clone().vlen(Vlen::Fixed(1)),
        base.clone().vlen(Vlen::Fixed(4)),
        base.clone().vlen(Vlen::Fixed(8)),
        base.clone().tuned(true),
        base.clone().tuned(true).vlen(Vlen::Fixed(4)),
        base.clone().roll_all_inputs(true),
        base.clone().vec_dim(VecDim::Auto),
        base.clone().vec_dim(VecDim::Outer("j".to_string())),
        base.clone().vec_dim(VecDim::Outer("j".to_string())).vlen(Vlen::Fixed(4)),
        base.clone().aligned(true),
        base.clone().aligned(true).vlen(Vlen::Fixed(4)),
        base.clone().tiled(true),
        base.clone().tiled(true).vlen(Vlen::Fixed(4)),
        PlanSpec::app("laplace"),
        PlanSpec::deck_src("name: hydro2d\n"),
    ];
    let mut fps = vec![base.fingerprint()];
    fps.extend(variations.iter().map(|s| s.fingerprint()));
    for i in 0..fps.len() {
        for j in i + 1..fps.len() {
            assert_ne!(fps[i], fps[j], "spec {i} and spec {j} collide");
        }
    }
}

/// Deck-*file* specs fingerprint the content: same path, edited deck →
/// new identity; and a missing file fails at spec construction.
#[test]
fn deck_file_fingerprints_cover_content() {
    let dir = std::env::temp_dir().join(format!("hfav-api-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("content.yaml");
    std::fs::write(&path, hfav::apps::deck_of("laplace").unwrap()).unwrap();
    let a = PlanSpec::deck_file(&path).unwrap();
    std::fs::write(&path, hfav::apps::deck_of("normalize").unwrap()).unwrap();
    let b = PlanSpec::deck_file(&path).unwrap();
    assert_ne!(a.fingerprint(), b.fingerprint(), "content change must change identity");
    assert_eq!(a.plan_key().app, path.display().to_string());
    assert!(PlanSpec::deck_file(dir.join("missing.yaml")).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// An external deck file serves through the coordinator exactly like the
/// builtin app with the same content: same seeded inputs, same checksum
/// — but under its own plan-cache key.
#[test]
fn deck_file_serves_through_coordinator() {
    let dir = std::env::temp_dir().join(format!("hfav-api-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("laplace_copy.yaml");
    std::fs::write(&path, hfav::apps::deck_of("laplace").unwrap()).unwrap();

    // cosmo exercises the deck-name-keyed driver specials (the Nk plane
    // override must apply to the file copy too, not just the builtin).
    let cosmo_path = dir.join("cosmo_copy.yaml");
    std::fs::write(&cosmo_path, hfav::apps::deck_of("cosmo").unwrap()).unwrap();

    let c = Coordinator::start(2, None);
    let jobs = vec![
        Job::new(5, PlanSpec::app("laplace"), "exec", 32, 1),
        Job::new(5, PlanSpec::deck_file(&path).unwrap(), "exec", 32, 1),
        Job::new(6, PlanSpec::app("cosmo"), "exec", 16, 1),
        Job::new(6, PlanSpec::deck_file(&cosmo_path).unwrap(), "exec", 16, 1),
    ];
    assert_eq!(hfav::coordinator::distinct_plan_keys(&jobs), 4, "files get their own keys");
    let results = c.run_batch(jobs);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
    }
    assert_eq!(
        results[0].checksum, results[1].checksum,
        "same deck content must serve identical results"
    );
    assert_eq!(
        results[2].checksum, results[3].checksum,
        "cosmo deck file must serve identically to the builtin (same Nk planes)"
    );
    assert_eq!(c.plans.stats().computes, 4);
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The generated-Rust backend is a first-class engine: when a `rustc` is
/// on PATH (always true under `cargo test`), serving on `rust` matches
/// the interpreter bit-for-bit on laplace (neither contracts FP).
#[test]
fn rust_backend_serves_through_coordinator() {
    if let Availability::Missing(why) = registry().get("rust").unwrap().available() {
        eprintln!("skipping rust_backend_serves_through_coordinator: {why}");
        return;
    }
    let c = Coordinator::start(1, None);
    let jobs = vec![
        Job::new(3, PlanSpec::app("laplace"), "exec", 24, 1),
        Job::new(3, PlanSpec::app("laplace"), "rust", 24, 1),
    ];
    let results = c.run_batch(jobs);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
    }
    assert_eq!(results[0].checksum, results[1].checksum, "generated Rust diverged");
    // One plan, two prepared executables (interpreter + rustc module).
    assert_eq!(c.plans.stats().computes, 1);
    assert_eq!(c.prepared.stats().computes, 2);
    c.shutdown();
}

/// Unavailable backends surface their availability message as a per-job
/// failure (serving degrades; the CLI `run` path fails fast instead).
#[test]
fn unavailable_backend_degrades_per_job() {
    let c = Coordinator::start(1, None);
    let r = c.submit(Job::new(0, PlanSpec::app("laplace"), "pjrt", 16, 1)).recv().unwrap();
    assert!(!r.ok);
    assert!(r.detail.contains("PJRT") || r.detail.contains("artifacts"), "{}", r.detail);
    c.shutdown();
}

/// Vectorization knobs move the plan identity; extents overrides move
/// the *batch* identity but not the plan key — compiled plans are
/// shape-generic, so one compile serves every grid shape, while
/// differently-shaped jobs never share a warm-buffer batch group.
#[test]
fn vectorization_knobs_and_extents_identity() {
    let base = PlanSpec::app("cosmo").vlen(Vlen::Fixed(4));
    let knobs = [
        base.clone().vec_dim(VecDim::Outer("k".to_string())),
        base.clone().vec_dim(VecDim::Auto),
        base.clone().aligned(true),
        base.clone().vec_dim(VecDim::Outer("k".to_string())).aligned(true),
        base.clone().tiled(true),
        base.clone().vec_dim(VecDim::Outer("k".to_string())).tiled(true),
    ];
    for (i, k) in knobs.iter().enumerate() {
        assert_ne!(k.fingerprint(), base.fingerprint(), "knob {i} escaped the fingerprint");
        assert_ne!(
            format!("{:?}", k.compile_options()),
            format!("{:?}", base.compile_options()),
            "knob {i} does not change the compile options it claims to"
        );
    }
    let square = Job::new(1, base.clone(), "exec", 32, 1);
    let a = Job::new(2, base.clone(), "exec", 32, 1).with_extents(vec![13, 11, 3]);
    let b = Job::new(3, base.clone(), "exec", 32, 1).with_extents(vec![13, 11, 4]);
    assert_eq!(square.plan_key(), a.plan_key(), "plans are shape-generic");
    assert_eq!(a.plan_key(), b.plan_key());
    assert_ne!(batch_key(&square), batch_key(&a));
    assert_ne!(batch_key(&a), batch_key(&b));
}

/// A trace-v3 job with non-square `extents=` serves end-to-end through
/// the coordinator, on the interpreter *and* the native-C engine (same
/// seeded inputs → matching checksums), with cells metered from the
/// extents actually run.
#[test]
fn trace_v3_non_square_extents_serve_end_to_end() {
    let line = "cosmo, hfav, exec, 32, 2, 4, extents=13x11x6";
    let job = parse_trace_line(9, line).unwrap();
    assert_eq!(job.extents, Some(vec![13, 11, 6]));
    assert_eq!(job.spec.vlen_override(), Some(4));
    // Same id → same seeded inputs; outer-k + aligned native-C job must
    // produce the interpreter's checksum on the same non-square grid.
    let native = Job::new(
        9,
        PlanSpec::app("cosmo")
            .vlen(Vlen::Fixed(4))
            .vec_dim(VecDim::Outer("k".to_string()))
            .aligned(true),
        "native",
        32,
        2,
    )
    .with_extents(vec![13, 11, 6]);
    let c = Coordinator::start(2, None);
    let results = c.run_batch(vec![job, native]);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
    }
    let (a, b) = (results[0].checksum, results[1].checksum);
    assert!(
        (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs())),
        "exec vs native checksum: {a} vs {b}"
    );
    let rep = c.report(std::time::Duration::from_millis(1));
    // Ni=13, Nj=11, Nk=6 (sorted-name binding), 2 steps, 2 jobs.
    assert_eq!(rep.total_cells, 13 * 11 * 6 * 2 * 2);
    c.shutdown();
}

/// The `--threads` knob is a *runtime* knob by construction: it lives in
/// `RunConfig`/`Job.threads`, not in `PlanSpec`, so it can move neither
/// the plan key nor the batch identity — and a threaded job serves the
/// serial checksum bitwise from the same single compiled plan.
#[test]
fn threads_knob_is_outside_every_fingerprint() {
    use hfav::engine::Threads;
    let spec = PlanSpec::app("cosmo").vlen(Vlen::Fixed(4)).vec_dim(VecDim::Auto).tiled(true);
    let serial = Job::new(4, spec.clone(), "native", 24, 1);
    let threaded = Job::new(4, spec.clone(), "native", 24, 1).with_threads(Threads::Fixed(4));
    let auto = Job::new(4, spec, "native", 24, 1).with_threads(Threads::Auto);
    assert_eq!(serial.plan_key(), threaded.plan_key(), "threads leaked into the plan key");
    assert_eq!(serial.plan_key(), auto.plan_key());
    assert_eq!(batch_key(&serial), batch_key(&threaded), "threads leaked into the batch key");
    assert_eq!(batch_key(&serial), batch_key(&auto));
    let c = Coordinator::start(2, None);
    let results = c.run_batch(vec![serial, threaded, auto]);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
    }
    assert_eq!(results[0].checksum, results[1].checksum, "Fixed(4) moved results");
    assert_eq!(results[0].checksum, results[2].checksum, "Auto moved results");
    assert_eq!(c.plans.stats().computes, 1, "threads must not split the plan cache");
    c.shutdown();
}

/// Tuned-plan resolution stays outside `PlanKey`: resolving N repeat
/// `variant=tuned` jobs against one DB entry changes their knobs (the
/// specs really are rewritten) but costs exactly two compiles total —
/// the heuristic fallback (compiled once during resolution, shared) and
/// the resolved winner (compiled once when served) — no matter how many
/// jobs repeat the trace line.
#[test]
fn tuned_resolution_changes_knobs_not_compile_counts() {
    use hfav::plan::cache::PlanCache;
    use hfav::plan::tunedb::{deck_digest, ShapeClass, TunedDb, TunedEntry};
    use std::sync::Arc;

    let mut template = Vec::new();
    for i in 0..4u64 {
        template.push(parse_trace_line(i, "cosmo, tuned, exec, 16, 1").unwrap());
    }
    let fallback_fp = template[0].spec.fingerprint();
    let mut db = TunedDb::default();
    db.insert(TunedEntry {
        deck_digest: deck_digest(&template[0].spec).unwrap(),
        // size=16 cosmo runs at [16, 16, 4]: the class the grid driver's
        // default shape buckets into.
        shape_class: ShapeClass::of(&[16, 16, 4]).label(),
        target: "cosmo".to_string(),
        extents: "16x16x4".to_string(),
        tuned: false,
        vec_dim: "inner".to_string(),
        vlen: 2,
        aligned: false,
        tiled: false,
        time_tile: 1,
        threads: 1,
        mcells_per_s: 1.0,
        candidates: 1,
        timed: 1,
        reps: 1,
        predicted_rank: None,
    });

    let plans = Arc::new(PlanCache::new());
    for j in template.iter_mut() {
        let label = hfav::coordinator::resolve_tuned(j, &db, &plans).unwrap();
        assert!(label.expect("entry must hit").contains("vlen=2"));
        assert_ne!(j.spec.fingerprint(), fallback_fp, "knobs did not change");
        assert_eq!(j.spec.vlen_override(), Some(2));
        assert!(!j.spec.is_tuned());
    }
    assert_eq!(hfav::coordinator::distinct_plan_keys(&template), 1);
    // Resolution compiled the fallback exactly once, cache-shared.
    assert_eq!(plans.stats().computes, 1);

    let c = Coordinator::start_with_cache(2, None, plans.clone());
    let results = c.run_batch(template);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
    }
    assert_eq!(plans.stats().computes, 2, "fallback + resolved winner only: {}", plans.stats());
    c.shutdown();
}

/// Fails closed: a `Job` carries only a `PlanSpec` + backend name, its
/// plan key is derived solely from the spec, and every spec knob is
/// covered by the fingerprint — so there is no way to build two jobs
/// that compile differently but share a cache entry. (The parallel
/// `app`/`variant`/`vlen` job fields this replaced are gone; this test
/// pins the derivation so they cannot quietly come back.)
#[test]
fn job_plan_identity_is_spec_fingerprint() {
    let spec = PlanSpec::app("cosmo").variant(Variant::Autovec).vlen(Vlen::Fixed(4)).tuned(true);
    let job = Job::new(1, spec.clone(), "native", 64, 2);
    assert_eq!(job.plan_key(), spec.plan_key());
    assert_eq!(job.plan_key().fingerprint, spec.fingerprint());
    // Specs that differ in any knob produce jobs with distinct keys —
    // and identical option sets produce identical keys.
    let same = Job::new(9, spec.clone(), "exec", 8, 1);
    assert_eq!(same.plan_key(), job.plan_key(), "backend/size/steps must not affect identity");
    let knobs = [
        spec.clone().variant(Variant::Hfav),
        spec.clone().vlen(Vlen::Fixed(8)),
        spec.clone().vlen(Vlen::Deck),
        spec.clone().tuned(false),
        spec.clone().roll_all_inputs(true),
    ];
    for (i, k) in knobs.iter().enumerate() {
        assert_ne!(
            Job::new(1, k.clone(), "native", 64, 2).plan_key(),
            job.plan_key(),
            "knob {i} escaped the fingerprint"
        );
        assert_ne!(
            format!("{:?}", k.compile_options()),
            format!("{:?}", spec.compile_options()),
            "knob {i} does not change the compile options it claims to"
        );
    }
}
